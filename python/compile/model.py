"""L2 — the QNN model as a JAX computation calling the L1 kernels.

A small ternary CNN classifier (the mobile-recognition workload the
paper's introduction motivates): input quantize → ternary conv (via
im2col + `tnn_gemm`) → maxpool → ternary conv → pool → ternary dense →
f32 head. Weights are seeded, ternarized at trace time and folded into
the lowered HLO as constants — the paper's "weights packed once,
offline" (PackedB) in AOT form. Python never runs at serving time; the
Rust runtime executes the lowered artifact.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lowbit, ref

# Model hyper-parameters (kept small: the artifact is compiled for CPU
# PJRT in interpret mode; batch is fixed at AOT time).
INPUT_HW = 12
INPUT_C = 1
CLASSES = 10
BATCH = 8
DELTA = 0.4  # ternary activation threshold


def ternarize_weights(key, shape, thr_ratio=0.75):
    """TWN-style ternarization of random Gaussian weights; returns the
    2-bit planes and the scaling factor α."""
    w = jax.random.normal(key, shape) * 0.2
    delta = thr_ratio * jnp.mean(jnp.abs(w))
    t = jnp.where(w > delta, 1, jnp.where(w < -delta, -1, 0)).astype(jnp.int8)
    kept = jnp.abs(w) * (t != 0)
    alpha = jnp.sum(kept) / jnp.maximum(jnp.sum(t != 0), 1)
    return ref.ternary_planes(t), alpha


def ternary_act(x, delta=DELTA):
    """Ternary activation quantizer → planes (0/1 int8)."""
    xp = (x > delta).astype(jnp.int8)
    xm = (x < -delta).astype(jnp.int8)
    return xp, xm


def im2col(x, hk, wk):
    """Unroll NHWC `x` into (N, OH, OW, hk·wk·C) patches, SAME padding,
    stride 1, patch order (ky, kx, c) — matching the Rust engine."""
    n, h, w, c = x.shape
    ph, pw = hk // 2, wk // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = []
    for ky in range(hk):
        for kx in range(wk):
            cols.append(jax.lax.dynamic_slice(xp, (0, ky, kx, 0), (n, h, w, c)))
    return jnp.concatenate(cols, axis=-1)


def conv_ternary(x_planes, w_planes, scale, interpret=True):
    """Ternary 3×3 SAME conv via im2col + the TNN Pallas kernel.
    `x_planes` = (x⁺, x⁻) NHWC; `w_planes` = (w⁺, w⁻) of shape
    (9·C_in, C_out). Returns f32 NHWC activations (scaled accumulators).
    """
    xp, xm = x_planes
    n, h, w, _ = xp.shape
    cp = im2col(xp, 3, 3)
    cm = im2col(xm, 3, 3)
    depth = cp.shape[-1]
    ap = cp.reshape(n * h * w, depth)
    am = cm.reshape(n * h * w, depth)
    wp, wm = w_planes
    acc = lowbit.tnn_gemm(ap, am, wp, wm, interpret=interpret)
    out = acc.astype(jnp.float32) * scale
    return out.reshape(n, h, w, -1)


def maxpool2(x):
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))


class ModelWeights:
    """Seeded, ternarized weights (built once at trace/AOT time)."""

    def __init__(self, seed=0):
        keys = jax.random.split(jax.random.PRNGKey(seed), 8)
        # Folded scales normalize each layer's integer accumulator to
        # ~unit variance (acc std ≈ 0.67·√depth for random ternary dot
        # products), so activations straddle the ternary threshold —
        # the BN-fold a trained QNN would have.
        self.conv1, _ = ternarize_weights(keys[0], (9 * INPUT_C, 16))
        self.s1 = 2.0 / np.sqrt(9 * INPUT_C)
        self.conv2, _ = ternarize_weights(keys[1], (9 * 16, 32))
        self.s2 = 2.0 / np.sqrt(9 * 16)
        flat = (INPUT_HW // 4) * (INPUT_HW // 4) * 32
        self.dense, _ = ternarize_weights(keys[2], (flat, 64))
        self.s3 = 2.0 / np.sqrt(flat)
        self.head_w = jax.random.normal(keys[3], (64, CLASSES)) * 0.1
        self.head_b = jnp.zeros((CLASSES,))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _forward(x, conv1p, conv1m, s1, conv2p, conv2m, s2, densep, densem, s3,
             head_w, head_b, *, interpret=True):
    a = ternary_act(x)
    h1 = conv_ternary(a, (conv1p, conv1m), s1, interpret)
    h1 = maxpool2(h1)
    a1 = ternary_act(h1)
    h2 = conv_ternary(a1, (conv2p, conv2m), s2, interpret)
    h2 = maxpool2(h2)
    a2p, a2m = ternary_act(h2)
    n = a2p.shape[0]
    acc = lowbit.tnn_gemm(
        a2p.reshape(n, -1), a2m.reshape(n, -1), densep, densem, interpret=interpret
    )
    feats = acc.astype(jnp.float32) * s3
    return feats @ head_w + head_b


def forward(weights: ModelWeights, x, interpret=True):
    """Full model forward: f32 NHWC images → f32 logits (N, CLASSES)."""
    w = weights
    (c1p, c1m), (c2p, c2m), (dp, dm) = w.conv1, w.conv2, w.dense
    return _forward(x, c1p, c1m, w.s1, c2p, c2m, w.s2, dp, dm, w.s3,
                    w.head_w, w.head_b, interpret=interpret)


def reference_forward(weights: ModelWeights, x):
    """Oracle forward: identical math with dense jnp matmuls (no Pallas).
    Used by pytest to validate the kernel-backed forward exactly."""
    w = weights

    def conv_ref(planes, wplanes, scale):
        xp, xm = planes
        n, h, ww, _ = xp.shape
        cp = im2col(xp, 3, 3).reshape(n * h * ww, -1)
        cm = im2col(xm, 3, 3).reshape(n * h * ww, -1)
        acc = ref.tnn_ref_from_planes(cp, cm, *wplanes)
        return (acc.astype(jnp.float32) * scale).reshape(n, h, ww, -1)

    a = ternary_act(x)
    h1 = maxpool2(conv_ref(a, w.conv1, w.s1))
    a1 = ternary_act(h1)
    h2 = maxpool2(conv_ref(a1, w.conv2, w.s2))
    a2p, a2m = ternary_act(h2)
    n = a2p.shape[0]
    acc = ref.tnn_ref_from_planes(a2p.reshape(n, -1), a2m.reshape(n, -1), *w.dense)
    feats = acc.astype(jnp.float32) * w.s3
    return feats @ w.head_w + w.head_b
