"""L1 — Pallas kernels for the paper's low-bit matrix products, adapted
from ARM NEON to the TPU execution model.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU
kernels exist because CPUs have no sub-8-bit datapath — they emulate one
with XOR/AND + popcount over 128-bit registers. A TPU's throughput lives
in the MXU systolic array, which natively contracts int8/bf16 operands
with wide accumulation, so the *encoding stays* (the 2-bit (+,−) planes /
1-bit binary planes are the storage and interchange format — 16× smaller
HBM traffic than f32) and the *popcount trick is replaced* by on-the-fly
plane reconstruction feeding the MXU:

* TNN:  C = (A⁺ − A⁻) @ (B⁺ − B⁻)   (operands in {−1,0,1} as int8)
* TBN:  C = (A⁺ − A⁻) @ (1 − 2·B♭)
* BNN:  C = (1 − 2·A♭) @ (1 − 2·B♭)  — algebraically identical to the
  paper's eq. (6) `k − 2·popcount(a⊕b)`.

The paper's cache blocking (Ablock/Bblock in L1) becomes BlockSpec tiling
(HBM→VMEM): the grid walks (M/bm, N/bn) tiles with the full depth per
tile (depths in the paper's grid, ≤512, keep a (bm,K)+(K,bn)+(bm,bn)
working set far below VMEM); the 16×8 register microkernel becomes the
MXU's native 128×128 tile. Kernels run with interpret=True (CPU PJRT
cannot execute Mosaic custom-calls); on real TPU hardware the same code
lowers to MXU matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest divisor of `dim` that is ≤ preferred (tiles must divide the
    padded dims; the wrappers pad M/N to multiples of 8 first)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _pad_rows(x, mult):
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _pad_cols(x, mult):
    n = x.shape[1]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _tiled_matmul(x, y, *, interpret=True):
    """Shared Pallas driver: int8 operands in {−1,0,1}, int32 output.
    Grid over (M/bm, N/bn); each kernel instance contracts the full depth
    on the (emulated) MXU with int32 accumulation."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"depth mismatch {k} vs {k2}"
    bm = _pick_block(m)
    bn = _pick_block(n)

    def kernel(x_ref, y_ref, o_ref):
        xv = x_ref[...].astype(jnp.int32)
        yv = y_ref[...].astype(jnp.int32)
        o_ref[...] = jax.lax.dot_general(
            xv, yv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tnn_gemm(ap, am, bp, bm, *, interpret=True):
    """Ternary GEMM from 2-bit planes (0/1 int8): C = (A⁺−A⁻)(B⁺−B⁻).

    M and N are padded to a multiple of 8 (zero planes = the ternary
    value 0, contributing nothing) and the result is sliced back — the
    paper's edge-tile handling.
    """
    m, n = ap.shape[0], bp.shape[1]
    x = (ap.astype(jnp.int8) - am.astype(jnp.int8))
    y = (bp.astype(jnp.int8) - bm.astype(jnp.int8))
    x = _pad_rows(x, 8)
    y = _pad_cols(y, 8)
    return _tiled_matmul(x, y, interpret=interpret)[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def tbn_gemm(ap, am, bb, *, interpret=True):
    """Ternary×binary GEMM: ternary planes × binary bits (1→0, −1→1)."""
    m, n = ap.shape[0], bb.shape[1]
    x = (ap.astype(jnp.int8) - am.astype(jnp.int8))
    y = (1 - 2 * bb.astype(jnp.int8))
    x = _pad_rows(x, 8)
    # Binary has no zero: pad columns of the ±1 operand, then slice —
    # padded outputs are discarded so the pad value is irrelevant.
    y = _pad_cols(y, 8)
    return _tiled_matmul(x, y, interpret=interpret)[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bnn_gemm(ab, bb, *, interpret=True):
    """Binary GEMM from bit matrices (1→0, −1→1): eq. (6) in MXU form."""
    m, n = ab.shape[0], bb.shape[1]
    x = (1 - 2 * ab.astype(jnp.int8))
    y = (1 - 2 * bb.astype(jnp.int8))
    x = _pad_rows(x, 8)
    y = _pad_cols(y, 8)
    return _tiled_matmul(x, y, interpret=interpret)[:m, :n]


def vmem_bytes(m: int, n: int, k: int) -> int:
    """Estimated VMEM working set of one grid step (DESIGN.md §Perf):
    int8 x-tile + int8 y-tile + int32 out-tile."""
    bm_, bn = _pick_block(m), _pick_block(n)
    return bm_ * k + k * bn + bm_ * bn * 4
