"""Pure-jnp oracles for the low-bit matrix products — the L1 correctness
ground truth.

Values are dense int8 in {-1,0,1} (ternary) or {-1,1} (binary); the
oracles are straight dense matmuls, against which the Pallas kernels'
plane-decomposition outputs are asserted exactly (integer arithmetic, no
tolerance needed).
"""

import jax.numpy as jnp


def gemm_ref(a, b):
    """Dense integer GEMM oracle: int32 C = A @ B."""
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))


def ternary_planes(x):
    """The paper's 2-bit encoding as dense 0/1 planes: x -> (x+, x-)."""
    xp = (x > 0).astype(jnp.int8)
    xm = (x < 0).astype(jnp.int8)
    return xp, xm


def binary_bits(x):
    """The paper's 1-bit encoding: +1 -> 0, -1 -> 1."""
    return (x < 0).astype(jnp.int8)


def tnn_ref_from_planes(ap, am, bp, bm):
    """eq. (7) as plane matmuls:
    C = (A+ B+ + A- B-) - (A+ B- + A- B+)."""
    ap, am = ap.astype(jnp.int32), am.astype(jnp.int32)
    bp, bm = bp.astype(jnp.int32), bm.astype(jnp.int32)
    return (ap @ bp + am @ bm) - (ap @ bm + am @ bp)


def tbn_ref_from_planes(ap, am, bb):
    """TBN with binary bits: y+ = 1-bb, y- = bb."""
    ap, am, bb = ap.astype(jnp.int32), am.astype(jnp.int32), bb.astype(jnp.int32)
    bp, bm = 1 - bb, bb
    return (ap @ bp + am @ bm) - (ap @ bm + am @ bp)


def bnn_ref_from_bits(ab, bb, k):
    """eq. (6): C = k - 2 * xor-popcount, where the xor-sum expands to
    a(1-b) + (1-a)b over the 0/1 bit matrices."""
    ab, bb = ab.astype(jnp.int32), bb.astype(jnp.int32)
    xor_sum = ab @ (1 - bb) + (1 - ab) @ bb
    return k - 2 * xor_sum
