from . import lowbit, ref  # noqa: F401
