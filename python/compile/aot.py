"""AOT pipeline: lower the L2 model and standalone L1 kernel entry points
to HLO **text** artifacts the Rust runtime loads.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import lowbit


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default HLO printer elides large constant literals as
    # "{...}", which xla_extension's text *parser* silently zero-fills —
    # a model with folded weights then runs but outputs garbage/zeros.
    # Print with large constants included (and verify none were elided).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The old parser rejects newer metadata attributes
    # (source_end_line etc.) — strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant literal"
    return text


def lower_model(weights: model.ModelWeights):
    """The serving model: f32[B,H,W,C] -> f32[B,CLASSES] with weights
    folded in as constants (packed once, offline)."""

    def fn(x):
        return (model.forward(weights, x),)

    spec = jax.ShapeDtypeStruct(
        (model.BATCH, model.INPUT_HW, model.INPUT_HW, model.INPUT_C), jnp.float32
    )
    return jax.jit(fn).lower(spec)


def lower_tnn_gemm(m=72, n=24, k=256):
    """Standalone ternary GEMM on a paper-grid shape; f32 I/O (0/1 plane
    matrices in, f32 accumulators out) so the Rust side stays literal-
    friendly."""

    def fn(ap, am, bp, bm):
        out = lowbit.tnn_gemm(
            ap.astype(jnp.int8), am.astype(jnp.int8),
            bp.astype(jnp.int8), bm.astype(jnp.int8),
        )
        return (out.astype(jnp.float32),)

    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(fn).lower(a, a, b, b)


def lower_bnn_gemm(m=72, n=24, k=256):
    def fn(ab, bb):
        out = lowbit.bnn_gemm(ab.astype(jnp.int8), bb.astype(jnp.int8))
        return (out.astype(jnp.float32),)

    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(fn).lower(a, b)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    weights = model.ModelWeights(seed=args.seed)
    artifacts = {
        "model.hlo.txt": lower_model(weights),
        "tnn_gemm.hlo.txt": lower_tnn_gemm(),
        "bnn_gemm.hlo.txt": lower_bnn_gemm(),
    }
    for name, lowered in artifacts.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
