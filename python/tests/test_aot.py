"""AOT pipeline: lowering produces HLO text that the XLA CPU client can
compile and execute, and the executed artifact agrees with the traced
model — the same round-trip the Rust runtime performs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def weights():
    return model.ModelWeights(seed=0)


def test_model_lowers_to_hlo_text(weights):
    text = aot.to_hlo_text(aot.lower_model(weights))
    assert "HloModule" in text
    assert len(text) > 1000
    # f32[8,12,12,1] input signature appears in the entry computation.
    assert "f32[8,12,12,1]" in text.replace(" ", "")


def test_tnn_gemm_artifact_roundtrip():
    """Compile the standalone TNN artifact with the in-process XLA client
    and check numerics against the oracle — the same path Rust takes."""
    from jax._src.lib import xla_client as xc

    lowered = aot.lower_tnn_gemm(m=24, n=16, k=64)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text

    rng = np.random.default_rng(3)
    a = rng.integers(-1, 2, size=(24, 64)).astype(np.int8)
    b = rng.integers(-1, 2, size=(64, 16)).astype(np.int8)
    ap = (a > 0).astype(np.float32)
    am = (a < 0).astype(np.float32)
    bp = (b > 0).astype(np.float32)
    bm = (b < 0).astype(np.float32)

    out = jax.jit(
        lambda *args: lowered.compile()(*args)  # execute the lowered module
    )  # noqa: E731 — compile() gives an executable directly
    exe = lowered.compile()
    (got,) = exe(jnp.asarray(ap), jnp.asarray(am), jnp.asarray(bp), jnp.asarray(bm))
    want = (a.astype(np.int32) @ b.astype(np.int32)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_bnn_gemm_artifact_lowering():
    text = aot.to_hlo_text(aot.lower_bnn_gemm(m=16, n=8, k=32))
    assert "HloModule" in text


def test_artifact_has_no_custom_calls(weights):
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for lowered in (aot.lower_model(weights), aot.lower_tnn_gemm(m=16, n=8, k=32)):
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text or "mosaic" not in text.lower()
