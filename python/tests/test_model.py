"""L2 correctness: the kernel-backed model forward vs the dense oracle
forward, plus structural checks on the ternarized weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def weights():
    return model.ModelWeights(seed=0)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(42)
    return jax.random.normal(
        key, (model.BATCH, model.INPUT_HW, model.INPUT_HW, model.INPUT_C)
    )


def test_forward_shape_and_finite(weights, batch):
    logits = model.forward(weights, batch)
    assert logits.shape == (model.BATCH, model.CLASSES)
    assert bool(jnp.isfinite(logits).all())


def test_forward_matches_reference_exactly(weights, batch):
    """Pallas-kernel forward ≡ dense-oracle forward (same integer
    arithmetic, same f32 epilogues → bitwise-identical logits)."""
    got = model.forward(weights, batch)
    want = model.reference_forward(weights, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_network_is_not_dead(weights, batch):
    """Regression: mis-scaled folded affines once zeroed every activation
    after conv2, producing constant-zero logits. The network must produce
    non-trivial, image-dependent outputs."""
    logits = np.asarray(model.forward(weights, batch))
    assert np.abs(logits).max() > 0.1
    assert len(set(np.argmax(logits, axis=1).tolist())) > 1


def test_weights_are_valid_ternary(weights):
    for planes in (weights.conv1, weights.conv2, weights.dense):
        p, m = planes
        p, m = np.asarray(p), np.asarray(m)
        assert set(np.unique(p)) <= {0, 1}
        assert set(np.unique(m)) <= {0, 1}
        # (1,1) is an invalid 2-bit code
        assert not np.logical_and(p == 1, m == 1).any()
        # weights are not degenerate (both signs present)
        assert p.sum() > 0 and m.sum() > 0


def test_forward_deterministic(weights, batch):
    a = model.forward(weights, batch)
    b = model.forward(weights, batch)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ternary_act_planes_valid():
    x = jnp.asarray([[-1.0, -0.2, 0.0, 0.2, 1.0]])
    xp, xm = model.ternary_act(x, delta=0.4)
    np.testing.assert_array_equal(np.asarray(xp), [[0, 0, 0, 0, 1]])
    np.testing.assert_array_equal(np.asarray(xm), [[1, 0, 0, 0, 0]])


def test_im2col_patch_order():
    """Patch order must be (ky, kx, c) to match the Rust engine."""
    x = jnp.arange(9.0).reshape(1, 3, 3, 1)
    cols = model.im2col(x, 3, 3)
    assert cols.shape == (1, 3, 3, 9)
    # Center pixel (1,1): its patch is the whole image flattened.
    np.testing.assert_array_equal(np.asarray(cols[0, 1, 1]), np.arange(9.0))
    # Corner (0,0): taps at ky=0 and kx=0 read SAME-padding zeros; the
    # full (ky, kx)-ordered patch is the padded 3×3 window around (0,0).
    patch = np.asarray(cols[0, 0, 0])
    np.testing.assert_array_equal(patch, [0, 0, 0, 0, 0, 1, 0, 3, 4])


def test_maxpool2():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    p = model.maxpool2(x)
    assert p.shape == (1, 2, 2, 1)
    np.testing.assert_array_equal(np.asarray(p[0, :, :, 0]), [[5, 7], [13, 15]])
