"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Integer arithmetic throughout → exact equality, no tolerances.
Hypothesis sweeps shapes (including non-multiples of the tile size) and
value distributions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lowbit, ref

dims = st.integers(min_value=1, max_value=96)
depths = st.integers(min_value=1, max_value=160)
seeds = st.integers(min_value=0, max_value=2**32 - 1)


def random_ternary(rng, m, n):
    return rng.integers(-1, 2, size=(m, n)).astype(np.int8)


def random_binary(rng, m, n):
    return (rng.integers(0, 2, size=(m, n)) * 2 - 1).astype(np.int8)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=depths, seed=seeds)
def test_tnn_gemm_matches_oracle(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = random_ternary(rng, m, k)
    b = random_ternary(rng, k, n)
    ap, am = ref.ternary_planes(jnp.asarray(a))
    bp, bm = ref.ternary_planes(jnp.asarray(b))
    got = lowbit.tnn_gemm(ap, am, bp, bm)
    want = ref.gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=depths, seed=seeds)
def test_tbn_gemm_matches_oracle(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = random_ternary(rng, m, k)
    b = random_binary(rng, k, n)
    ap, am = ref.ternary_planes(jnp.asarray(a))
    bb = ref.binary_bits(jnp.asarray(b))
    got = lowbit.tbn_gemm(ap, am, bb)
    want = ref.gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=depths, seed=seeds)
def test_bnn_gemm_matches_oracle(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = random_binary(rng, m, k)
    b = random_binary(rng, k, n)
    ab = ref.binary_bits(jnp.asarray(a))
    bb = ref.binary_bits(jnp.asarray(b))
    got = lowbit.bnn_gemm(ab, bb)
    want = ref.gemm_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_plane_ref_identity_matches_dense():
    """eq. (7) plane decomposition is an identity."""
    rng = np.random.default_rng(7)
    a = random_ternary(rng, 33, 70)
    b = random_ternary(rng, 70, 21)
    ap, am = ref.ternary_planes(jnp.asarray(a))
    bp, bm = ref.ternary_planes(jnp.asarray(b))
    got = ref.tnn_ref_from_planes(ap, am, bp, bm)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    )


def test_bnn_eq6_identity():
    """eq. (6): k − 2·xor-popcount equals the dense product."""
    rng = np.random.default_rng(8)
    a = random_binary(rng, 17, 40)
    b = random_binary(rng, 40, 9)
    ab = ref.binary_bits(jnp.asarray(a))
    bb = ref.binary_bits(jnp.asarray(b))
    got = ref.bnn_ref_from_bits(ab, bb, 40)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    )


@pytest.mark.parametrize("m,n,k", [(72, 24, 128), (120, 48, 256), (16, 8, 8)])
def test_paper_grid_shapes_exact(m, n, k):
    rng = np.random.default_rng(m * 1000 + n * 10 + k)
    a = random_ternary(rng, m, k)
    b = random_ternary(rng, k, n)
    ap, am = ref.ternary_planes(jnp.asarray(a))
    bp, bm = ref.ternary_planes(jnp.asarray(b))
    got = lowbit.tnn_gemm(ap, am, bp, bm)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    )


def test_zero_ternary_matrix_gives_zero():
    z = jnp.zeros((16, 32), jnp.int8)
    ap, am = ref.ternary_planes(z)
    rng = np.random.default_rng(9)
    b = random_ternary(rng, 32, 8)
    bp, bm = ref.ternary_planes(jnp.asarray(b))
    out = lowbit.tnn_gemm(ap, am, bp, bm)
    assert not np.asarray(out).any()


def test_vmem_estimate_within_budget():
    """DESIGN.md §Perf: one grid step's working set must sit far below
    the 16 MiB VMEM of a TPU core for every paper-grid shape."""
    for m in (72, 120, 240, 360):
        for n in (24, 48, 72, 96):
            for k in (128, 256, 384, 512):
                assert lowbit.vmem_bytes(m, n, k) < 4 * 1024 * 1024
