//! Quickstart: the library's two public plan/execute boundaries in five
//! minutes.
//!
//! Part 1 — the **GEMM boundary** (`GemmPlan`): multiply a ternary
//! activation matrix by pre-packed ternary weights on all three backends
//! — the scalar oracle, the emulated-NEON path (the paper's exact
//! instruction sequences), and the native fast path — and check they
//! agree. Same for binary and ternary-binary products.
//!
//! Part 2 — the **network boundary** (`NetPlan`): build a mobile-class
//! ternary CNN plan (shapes and quantization domains verified once, at
//! build), run a handful of images with zero steady-state allocation,
//! check backend agreement end-to-end, and serve the same plan through
//! the batching coordinator's replica pool.
//!
//! Part 3 — the **autotuned path** (`tbgemm::tune`): rank the legal
//! execution configs for a shape with the cost model, refine the top of
//! the ranking with real timed runs, persist the winner to a tuning
//! file, and run `GemmConfig::tuned` / `NetPlanConfig::with_tuning`
//! plans that resolve their knobs from it — bit-identical results,
//! measured config.
//!
//! This example lives inside the `rust/` cargo package and is compiled
//! and executed by CI (`cargo run --release --example quickstart`).

use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine, ServerConfig};
use tbgemm::gemm::{Backend, GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
use tbgemm::nn::builder::{plan_from_config, NetConfig};
use tbgemm::nn::{NetOut, NetPlanConfig};
use tbgemm::tune::{self, measure};
use tbgemm::util::mat::MatI8;
use tbgemm::util::Rng;
use std::time::Duration;

/// Pack `b` once per backend, run `a · b`, and check all backends agree.
fn verify(kind: Kind, a: &MatI8, b: &MatI8) {
    let mut results: Vec<Vec<i32>> = Vec::new();
    // Caller-owned output + scratch, reused across every run.
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    for backend in Backend::ALL {
        // 1. Plan: pack the weights once, offline (the paper's PackedB).
        let plan = GemmPlan::new(GemmConfig::new(kind, backend), Weights::I8(b))
            .expect("valid weights for this kind");
        // 2. Execute into the caller-owned buffers (typed errors, no
        //    per-call allocation on the native hot path).
        plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("matching LHS");
        results.push(out.as_i32().expect("low-bit kinds produce i32").data.clone());
    }
    assert!(results.windows(2).all(|w| w[0] == w[1]));
    println!(
        "{:?} {}×{} · {}×{}: reference ≡ emulated ≡ native ✓",
        kind, a.rows, a.cols, b.rows, b.cols
    );
}

fn main() {
    // ---- part 1: the GEMM boundary -----------------------------------
    let mut rng = Rng::new(2022);
    // A 72×256 ternary activation matrix times a 256×24 ternary weight
    // matrix — one point of the paper's experimental grid.
    let (m, k, n) = (72, 256, 24);

    // TNN: ternary × ternary.
    let a = MatI8::random_ternary(m, k, &mut rng);
    let b = MatI8::random_ternary(k, n, &mut rng);
    verify(Kind::Tnn, &a, &b);

    // TBN: ternary activations × binary weights.
    let bw = MatI8::random_binary(k, n, &mut rng);
    verify(Kind::Tbn, &a, &bw);

    // BNN: binary × binary.
    let ab = MatI8::random_binary(m, k, &mut rng);
    verify(Kind::Bnn, &ab, &bw);

    // ---- part 2: the network boundary --------------------------------
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    // 1. Plan: realize the config (weights packed once per layer) and
    //    statically verify every shape and domain handoff.
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default())
        .expect("built-in config is a valid chain");
    // 2. Execute into caller-owned output + scratch: zero heap
    //    allocation after warm-up, typed NetError instead of panics.
    let mut scratch = plan.make_scratch();
    let mut out = NetOut::new();
    let mut hist = [0usize; 10];
    let images: Vec<Tensor3<f32>> = (0..16).map(|_| Tensor3::random(28, 28, 1, &mut rng)).collect();
    for img in &images {
        plan.run(img, &mut out, &mut scratch).expect("plan-shaped image");
        hist[out.predicted()] += 1;
    }
    println!("NetPlan {:?} → {} logits; prediction histogram {hist:?}", plan.input_dims(), plan.out_features());

    // Whole-network backend differential: the reference-backend plan
    // produces bit-identical logits (integer GEMMs, same f32 epilogues).
    let oracle = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default().with_backend(Backend::Reference))
        .expect("plan");
    let mut oracle_scratch = oracle.make_scratch();
    let mut oracle_out = NetOut::new();
    oracle.run(&images[0], &mut oracle_out, &mut oracle_scratch).expect("run");
    plan.run(&images[0], &mut out, &mut scratch).expect("run");
    assert_eq!(out.logits, oracle_out.logits);
    println!("NetPlan native ≡ reference logits ✓");

    // 3. Serve: the same plan behind the batching coordinator, batches
    //    split across 2 engine replicas sharing the packed weights.
    let served = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("plan");
    let server = InferenceServer::with_config(
        Box::new(NativeEngine::new(served, "quickstart")),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) })
            .with_replicas(2),
    );
    let pending: Vec<_> =
        images.iter().map(|img| server.submit(img.clone()).expect("server up")).collect();
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().expect("response").completed().expect("served, not shed");
        // Served logits are bit-identical to the local plan runs.
        plan.run(img, &mut out, &mut scratch).expect("run");
        assert_eq!(resp.logits, out.logits);
    }
    let metrics = server.shutdown();
    println!(
        "served {} requests over {} replicas (loads {:?}) ✓",
        metrics.requests,
        metrics.replica_requests.len(),
        metrics.replica_requests
    );

    // ---- part 3: the autotuned path ----------------------------------
    // Rank the legal execution configs for the part-1 TNN shape with the
    // cost model, refine the top of the ranking with real timed runs,
    // persist the winner, and point this process at the file — exactly
    // what `repro tune` does for the whole bench sweep.
    let shape = (m, n, k);
    let workers = tbgemm::util::pool::default_workers();
    let cands = tune::candidates(Kind::Tnn, shape, workers);
    let ranked = tune::rank_predicted(Kind::Tnn, shape, &cands);
    let top: Vec<_> = ranked.iter().map(|(c, _)| *c).collect();
    let timed = measure::refine(Kind::Tnn, shape, &top, measure::Budget::fast(), 7).expect("refine");
    let (winner, ns) = timed[0];
    let mut store = tune::TuningStore::empty();
    store.record(Kind::Tnn, shape, winner, ns, ranked[0].1.total());
    let path = std::env::temp_dir().join("tbgemm_quickstart_tune.json");
    store.save(&path).expect("write tuning file");
    // Must happen before the first tuned resolution — the process loads
    // the store exactly once.
    std::env::set_var("TBGEMM_TUNE_FILE", &path);
    println!("tuned TNN {shape:?}: {} ({ns:.0} ns/run) → {}", winner.label(), path.display());

    // A tuned plan resolves its knobs from that file at run time and
    // stays bit-identical to the reference oracle.
    let tuned = GemmPlan::new(GemmConfig::tuned(Kind::Tnn), Weights::I8(&b)).expect("plan");
    let tuned_oracle = GemmPlan::new(GemmConfig::reference(Kind::Tnn), Weights::I8(&b)).expect("plan");
    let (mut got, mut want) = (GemmOut::new_i32(), GemmOut::new_i32());
    let mut gemm_scratch = GemmScratch::new();
    tuned.run(Lhs::I8(&a), &mut got, &mut gemm_scratch).expect("run");
    tuned_oracle.run(Lhs::I8(&a), &mut want, &mut gemm_scratch).expect("run");
    assert_eq!(got.as_i32().expect("i32 out").data, want.as_i32().expect("i32 out").data);
    println!("GemmConfig::tuned(TNN) ≡ reference ✓");

    // The same toggle one boundary up: every GEMM layer of the network
    // resolves its config through the tuner, logits unchanged.
    let tuned_net =
        plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default().with_tuning(true)).expect("plan");
    let mut tuned_scratch = tuned_net.make_scratch();
    tuned_net.run(&images[0], &mut out, &mut tuned_scratch).expect("run");
    assert_eq!(out.logits, oracle_out.logits);
    println!("NetPlan with_tuning(true) ≡ reference logits ✓");

    println!("\nBoth plan/execute boundaries verified. Next steps:");
    println!("  repro table2                      # regenerate the paper's Table II");
    println!("  repro table3 --smoke              # a quick Table III run");
    println!("  repro tune --fast                 # autotune + persist kernel selection");
    println!("  repro serve --requests 256 --replicas 4");
}
