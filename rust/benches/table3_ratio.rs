//! Bench target for the paper's Table III: measures all seven algorithms
//! over the paper's H×W×D grid with the paper's protocol and prints the
//! ratio matrix next to the paper's values, followed by the predicted
//! (cost-model) matrix.
//!
//! Env knobs: `TABLE3_REPS` (default 3; the paper used 50),
//! `TABLE3_INNER` (default 5 = the paper's median-of-5),
//! `TABLE3_SMOKE=1` for the 4-point grid.
//!
//! Run: `cargo bench --bench table3_ratio`

use tbgemm::bench::{grid, predicted, ratio};
use tbgemm::gemm::Kind;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let reps = env_usize("TABLE3_REPS", 3);
    let inner = env_usize("TABLE3_INNER", 5);
    let smoke = std::env::var("TABLE3_SMOKE").is_ok();
    let g = if smoke { grid::smoke_grid() } else { grid::paper_grid() };

    eprintln!("table3_ratio: {} grid points, reps={reps}, inner={inner}", g.len());
    let times: Vec<_> = Kind::ALL
        .iter()
        .map(|&k| {
            eprintln!("  timing {}...", k.label());
            grid::time_algorithm(k, &g, reps, inner, 0x7AB1E6)
        })
        .collect();
    let m = ratio::ratio_matrix(&times);
    print!("{}", ratio::render_ratio_table(&m, "Table III (measured, native paths)"));

    println!("\nabsolute times (ms) at the grid corners:");
    for t in &times {
        let first = t.times.first().unwrap();
        let last = t.times.last().unwrap();
        println!(
            "  {:<6} {:?}: {:.3} ms   {:?}: {:.3} ms",
            t.kind.label(),
            first.0,
            first.1 * 1e3,
            last.0,
            last.1 * 1e3
        );
    }

    println!("\nheadline claims:");
    for (desc, ours, paper) in ratio::headline(&m) {
        println!("  {desc:<40} ours {ours:>5.2}  paper {paper:>5.2}");
    }

    let pm = ratio::ratio_matrix(&predicted::predict_grid(&grid::paper_grid()));
    print!("\n{}", ratio::render_ratio_table(&pm, "Table III (predicted, Cortex-A73 cost model)"));

    // Shape gates: the orderings the paper reports must hold in the
    // measurement (who wins), even though absolute factors shift hosts.
    assert!(m.get(Kind::F32, Kind::Tnn) > 1.0, "TNN must beat F32");
    assert!(m.get(Kind::Tnn, Kind::Bnn) > 1.0, "BNN must beat TNN");
    assert!(m.get(Kind::Tbn, Kind::Bnn) > 1.0, "BNN must beat TBN");
    println!("\ntable3_ratio OK");
}
