//! Benchmark of GeMM-based convolution layers across the three low-bit
//! kinds — the paper's deployment scenario (§IV discussion: "numbers of
//! channels ... should be multiples of 8" for maximal efficiency).
//! Also measures the penalty at non-multiple-of-8 channel counts.
//!
//! Run: `cargo bench --bench conv_layers`

use tbgemm::conv::conv2d::{ConvKind, ConvParams, LowBitConv};
use tbgemm::conv::tensor::Tensor3;
use tbgemm::util::mat::MatI8;
use tbgemm::util::timer::bench_loop;
use tbgemm::util::Rng;

fn bench_conv(kind: ConvKind, h: usize, w: usize, cin: usize, cout: usize) -> f64 {
    let mut rng = Rng::new(9);
    let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
    let weights = match kind {
        ConvKind::Tnn => MatI8::random_ternary(p.depth(cin), cout, &mut rng),
        _ => MatI8::random_binary(p.depth(cin), cout, &mut rng),
    };
    let conv = LowBitConv::new(kind, p, cin, &weights);
    let input = match kind {
        ConvKind::Bnn => Tensor3::random_binary(h, w, cin, &mut rng),
        _ => Tensor3::random_ternary(h, w, cin, &mut rng),
    };
    bench_loop(0.3, 200, || {
        std::hint::black_box(conv.forward(&input));
    })
    .mean
}

fn main() {
    println!("3×3 SAME conv, 28×28 input, low-bit GEMM path:");
    for (cin, cout) in [(32, 64), (64, 64), (64, 128)] {
        println!("  C_in={cin} C_out={cout}:");
        for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
            let t = bench_conv(kind, 28, 28, cin, cout);
            let macs = (28 * 28 * 9 * cin * cout) as f64;
            println!("    {kind:?}: {:>7.3} ms   {:>6.2} GMAC/s", t * 1e3, macs / t / 1e9);
        }
    }

    println!("\nchannel-alignment penalty (paper: multiples of 8 are optimal):");
    for cout in [64, 63, 65] {
        let t = bench_conv(ConvKind::Tnn, 28, 28, 64, cout);
        println!("  TNN C_out={cout}: {:>7.3} ms", t * 1e3);
    }
    println!("conv_layers OK");
}
