//! Benchmark of the packing routines (§III-B/C/D reordering): bytes/s for
//! each Ablock/Bblock format plus the native bit/plane packers. The paper
//! argues packing must be cheap relative to the microkernel — this bench
//! quantifies it.
//!
//! Run: `cargo bench --bench packing`

use tbgemm::gemm::native::{BitRows, PlaneRows};
use tbgemm::gemm::pack;
use tbgemm::util::mat::MatI8;
use tbgemm::util::timer::bench_loop;
use tbgemm::util::Rng;

fn main() {
    let (m, k) = (360, 512);
    let mut rng = Rng::new(3);
    let tern = MatI8::random_ternary(m, k, &mut rng);
    let bin = MatI8::random_binary(m, k, &mut rng);
    let elems = (m * k) as f64;

    let report = |name: &str, mean_s: f64| {
        println!("  {name:<28} {:>8.3} ms   {:>7.1} Melem/s", mean_s * 1e3, elems / mean_s / 1e6);
    };

    println!("packing {m}×{k}:");
    let s = bench_loop(0.2, 500, || {
        for r0 in (0..m).step_by(16) {
            std::hint::black_box(pack::pack_a_bnn(&bin, r0, k));
        }
    });
    report("emu pack_a_bnn (all panels)", s.mean);
    let s = bench_loop(0.2, 500, || {
        for r0 in (0..m).step_by(16) {
            std::hint::black_box(pack::pack_a_tnn(&tern, r0, k));
        }
    });
    report("emu pack_a_tnn (all panels)", s.mean);
    let s = bench_loop(0.2, 500, || {
        std::hint::black_box(BitRows::from_binary(&bin));
    });
    report("native BitRows", s.mean);
    let s = bench_loop(0.2, 500, || {
        std::hint::black_box(PlaneRows::from_ternary(&tern));
    });
    report("native PlaneRows", s.mean);
    let s = bench_loop(0.2, 500, || {
        std::hint::black_box(BitRows::from_binary_transposed(&bin));
    });
    report("native BitRows (transposed)", s.mean);
    println!("packing OK");
}
