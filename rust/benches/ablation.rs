//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. vectorized vs scalar popcount inner loops (the §Perf step 4 win),
//! 2. 2-column vs 1-column BNN kernel (step 5),
//! 3. vectorized vs scalar activation packing (step 3),
//! 4. stripe (memory-frugal) vs full-im2col convolution,
//! 5. U4 depth-block size (the eq. (4) 16-bit blocking).
//!
//! Run: `cargo bench --bench ablation`

use tbgemm::conv::conv2d::{ConvKind, ConvParams, LowBitConv};
use tbgemm::conv::stripe::StripeConv;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::gemm::native::pack_fast;
use tbgemm::gemm::native::simd_popcnt as sp;
use tbgemm::gemm::native::PlaneRows;
use tbgemm::util::mat::MatI8;
use tbgemm::util::timer::bench_loop;
use tbgemm::util::Rng;

fn main() {
    let mut rng = Rng::new(0xAB1A);
    let words = 8; // depth 512
    let rows = 120;
    let a: Vec<Vec<u64>> = (0..rows).map(|_| (0..words).map(|_| rng.next_u64()).collect()).collect();
    let b: Vec<Vec<u64>> = (0..48).map(|_| (0..words).map(|_| rng.next_u64()).collect()).collect();

    // 1. vectorized vs scalar popcount (BNN dot sweep).
    let vec_t = bench_loop(0.2, 400, || {
        let mut acc = 0u32;
        for ar in &a {
            for br in &b {
                acc = acc.wrapping_add(sp::xor_popcnt(ar, br));
            }
        }
        std::hint::black_box(acc);
    });
    let scl_t = bench_loop(0.2, 400, || {
        let mut acc = 0u32;
        for ar in &a {
            for br in &b {
                acc = acc.wrapping_add(sp::scalar_xor_popcnt(ar, br));
            }
        }
        std::hint::black_box(acc);
    });
    println!("1. popcount inner loop (120×48 dots, k=512):");
    println!(
        "   vectorized {:.3} ms, scalar {:.3} ms → {:.2}×",
        vec_t.mean * 1e3,
        scl_t.mean * 1e3,
        scl_t.mean / vec_t.mean
    );

    // 2. 2-column vs 1-column BNN kernel.
    let two_t = bench_loop(0.2, 400, || {
        let mut acc = 0u32;
        for ar in &a {
            for bc in b.chunks(2) {
                let (s0, s1) = sp::xor_popcnt2(ar, &bc[0], &bc[1]);
                acc = acc.wrapping_add(s0).wrapping_add(s1);
            }
        }
        std::hint::black_box(acc);
    });
    println!(
        "2. BNN column blocking: 1-col {:.3} ms, 2-col {:.3} ms → {:.2}×",
        vec_t.mean * 1e3,
        two_t.mean * 1e3,
        vec_t.mean / two_t.mean
    );

    // 3. vectorized vs scalar packing.
    let tern = MatI8::random_ternary(360, 512, &mut rng);
    let fast_t = bench_loop(0.2, 400, || {
        std::hint::black_box(PlaneRows::from_ternary(&tern));
    });
    let mut scratch = vec![0u64; 8];
    let mut scratch2 = vec![0u64; 8];
    let slow_t = bench_loop(0.2, 400, || {
        for r in 0..tern.rows {
            pack_fast::scalar_pack_ternary_row(tern.row(r), &mut scratch, &mut scratch2);
        }
        std::hint::black_box(&scratch);
    });
    println!(
        "3. ternary packing 360×512: vectorized {:.3} ms, scalar {:.3} ms → {:.2}×",
        fast_t.mean * 1e3,
        slow_t.mean * 1e3,
        slow_t.mean / fast_t.mean
    );

    // 4. stripe vs full-im2col convolution (time + memory).
    let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
    let w = MatI8::random_ternary(p.depth(32), 64, &mut rng);
    let input = Tensor3::random_ternary(28, 28, 32, &mut rng);
    let full = LowBitConv::new(ConvKind::Tnn, p, 32, &w);
    let stripe = StripeConv::new(ConvKind::Tnn, p, 32, &w);
    let full_t = bench_loop(0.3, 100, || {
        std::hint::black_box(full.forward(&input));
    });
    let stripe_t = bench_loop(0.3, 100, || {
        std::hint::black_box(stripe.forward(&input));
    });
    println!(
        "4. conv 28×28×32→64: full im2col {:.3} ms, stripe {:.3} ms ({:.0}% slower, {}× less scratch)",
        full_t.mean * 1e3,
        stripe_t.mean * 1e3,
        100.0 * (stripe_t.mean / full_t.mean - 1.0),
        28
    );

    // 5. U4 at depth 580 (two internal 290-deep 16-bit blocks + the
    // eq. (3) epilogue), through the plan API.
    use tbgemm::gemm::{GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
    use tbgemm::util::mat::MatU8;
    let au = MatU8::random_below(120, 580, 15, &mut rng);
    let bu = MatU8::random_below(580, 48, 15, &mut rng);
    let plan = GemmPlan::new(GemmConfig::native(Kind::U4), Weights::U8 { b: &bu, za: 3, zb: 5 })
        .expect("u4 plan");
    let mut c = GemmOut::new_i32();
    let mut gemm_scratch = GemmScratch::new();
    let t = bench_loop(0.2, 200, || {
        plan.run(Lhs::U8(&au), &mut c, &mut gemm_scratch).expect("u4 gemm");
    });
    println!("5. U4 GEMM 120×48×580 (two 290-blocks + epilogue): {:.3} ms", t.mean * 1e3);

    // Ablation gates: the optimizations must actually win.
    assert!(vec_t.mean < scl_t.mean, "vectorized popcount must beat scalar");
    assert!(fast_t.mean < slow_t.mean, "vectorized packing must beat scalar");
    println!("ablation OK");
}
