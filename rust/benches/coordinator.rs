//! Benchmark of the serving coordinator: throughput and latency vs batch
//! size, the coordinator's overhead over bare engine calls (DESIGN.md
//! §Perf target: <5% at batch 8), and replica-pool scaling at a fixed
//! batch size.
//!
//! Run: `cargo bench --bench coordinator`

use std::time::Duration;
use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine, ServerConfig};
use tbgemm::nn::builder::{plan_from_config, NetConfig};
use tbgemm::nn::{NetOut, NetPlanConfig};
use tbgemm::util::Rng;

fn serve(
    requests: &[Tensor3<f32>],
    max_batch: usize,
    replicas: usize,
) -> (f64, tbgemm::coordinator::MetricsSnapshot) {
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("plan");
    let server = InferenceServer::with_config(
        Box::new(NativeEngine::new(plan, "bench")),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
            .with_replicas(replicas)
            .with_depths(256, 256),
    );
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = requests.iter().map(|img| server.submit(img.clone()).expect("server up")).collect();
    for rx in pending {
        rx.recv().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    (dt, server.shutdown())
}

fn main() {
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    let requests = 128usize;
    let mut rng = Rng::new(17);
    let images: Vec<Tensor3<f32>> = (0..requests).map(|_| Tensor3::random(28, 28, 1, &mut rng)).collect();

    // Bare plan baseline (no coordinator).
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("plan");
    let mut scratch = plan.make_scratch();
    let mut out = NetOut::new();
    let t0 = std::time::Instant::now();
    for img in &images {
        plan.run(img, &mut out, &mut scratch).expect("run");
        std::hint::black_box(&out.logits);
    }
    let bare = t0.elapsed().as_secs_f64();
    println!("bare plan:        {requests} images in {:.3} s ({:.1} img/s)", bare, requests as f64 / bare);

    let mut batch8_time = None;
    for max_batch in [1usize, 4, 8, 16] {
        let (dt, m) = serve(&images, max_batch, 1);
        println!(
            "coordinator b={max_batch:>2}: {requests} images in {:.3} s ({:.1} img/s), mean batch {:.2}, p95 {} µs",
            dt,
            requests as f64 / dt,
            m.mean_batch_size,
            m.p95_latency_us.unwrap_or(0)
        );
        if max_batch == 8 {
            batch8_time = Some(dt);
        }
    }
    let overhead = (batch8_time.unwrap() - bare) / bare * 100.0;
    println!("\ncoordinator overhead at batch 8: {overhead:.1}% (target < 5%, single-producer load)");

    // Replica-pool scaling at batch 16 (the ROADMAP's batch-level
    // parallelism item): same stream, growing pool.
    println!("\nreplica pool at batch 16:");
    for replicas in [1usize, 2, 4] {
        let (dt, m) = serve(&images, 16, replicas);
        println!(
            "  replicas={replicas}: {:.3} s ({:.1} img/s), p50 {} µs, p99 {} µs, loads {:?}",
            dt,
            requests as f64 / dt,
            m.p50_latency_us.unwrap_or(0),
            m.p99_latency_us.unwrap_or(0),
            m.replica_requests
        );
    }
    println!("coordinator OK");
}
