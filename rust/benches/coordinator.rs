//! Benchmark of the serving coordinator: throughput and latency vs batch
//! size, plus the coordinator's overhead over bare engine calls (DESIGN.md
//! §Perf target: <5% at batch 8).
//!
//! Run: `cargo bench --bench coordinator`

use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine};
use tbgemm::nn::builder::{build_from_config, NetConfig};
use tbgemm::util::Rng;
use std::time::Duration;

fn main() {
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    let requests = 128usize;
    let mut rng = Rng::new(17);
    let images: Vec<Tensor3<f32>> = (0..requests).map(|_| Tensor3::random(28, 28, 1, &mut rng)).collect();

    // Bare engine baseline (no coordinator).
    let net = build_from_config(&cfg, 0xCAFE);
    let t0 = std::time::Instant::now();
    for img in &images {
        std::hint::black_box(net.logits(img));
    }
    let bare = t0.elapsed().as_secs_f64();
    println!("bare engine:      {requests} images in {:.3} s ({:.1} img/s)", bare, requests as f64 / bare);

    let mut batch8_time = None;
    for max_batch in [1usize, 4, 8, 16] {
        let net = build_from_config(&cfg, 0xCAFE);
        let server = InferenceServer::start(
            Box::new(NativeEngine::new(net, "bench")),
            BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
            256,
        );
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = images.iter().map(|img| server.submit(img.clone())).collect();
        for rx in pending {
            rx.recv().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        println!(
            "coordinator b={max_batch:>2}: {requests} images in {:.3} s ({:.1} img/s), mean batch {:.2}, p95 {} µs",
            dt,
            requests as f64 / dt,
            m.mean_batch_size,
            m.p95_latency_us
        );
        if max_batch == 8 {
            batch8_time = Some(dt);
        }
    }
    let overhead = (batch8_time.unwrap() - bare) / bare * 100.0;
    println!("\ncoordinator overhead at batch 8: {overhead:.1}% (target < 5%, single-producer load)");
    println!("coordinator OK");
}
