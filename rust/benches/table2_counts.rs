//! Bench target for the paper's Table II: regenerates the per-iteration
//! instruction counts from the emulated microkernels and prints the table
//! (plus per-mnemonic breakdowns). Deterministic — no timing involved.
//!
//! Run: `cargo bench --bench table2_counts`

use tbgemm::costmodel::table2;

fn main() {
    let rows = table2::generate();
    print!("{}", table2::render(&rows));
    println!("\nper-mnemonic breakdown:");
    for r in &rows {
        println!("{}:", r.kind.label());
        for (m, n) in &r.trace.by_mnemonic {
            println!("    {m:<12} {n}");
        }
    }
    // Sanity gates (the bench fails loudly if a refactor changes counts):
    let bnn = rows.iter().find(|r| r.kind == tbgemm::gemm::Kind::Bnn).unwrap();
    assert_eq!((bnn.com, bnn.ld, bnn.mov), (32, 2, 8), "BNN must match the paper exactly");
    let f32r = rows.iter().find(|r| r.kind == tbgemm::gemm::Kind::F32).unwrap();
    assert_eq!((f32r.com, f32r.ld, f32r.mov), (24, 5, 0), "F32 must match the paper exactly");
    println!("\ntable2_counts OK");
}
