//! Bench target for the paper's Table II: regenerates the per-iteration
//! instruction counts from the emulated microkernels and prints the table
//! (plus per-mnemonic breakdowns). Deterministic — no timing involved.
//!
//! The count *assertions* that used to live here are promoted to a real
//! pinned test (`tests/table2_counts.rs`, run on every `cargo test` and
//! on the aarch64/QEMU CI lane); this target is the human-readable
//! renderer.
//!
//! Run: `cargo bench --bench table2`

use tbgemm::costmodel::table2;

fn main() {
    let rows = table2::generate();
    print!("{}", table2::render(&rows));
    println!("\nper-mnemonic breakdown:");
    for r in &rows {
        println!("{}:", r.kind.label());
        for (m, n) in &r.trace.by_mnemonic {
            println!("    {m:<12} {n}");
        }
    }
    println!("\ntable2 OK");
}
