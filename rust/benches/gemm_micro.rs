//! Microbenchmark of the native GEMM kernels at a single paper-grid point
//! (120×48×256), reporting absolute time, GMAC/s, and the speedup ladder.
//!
//! Run: `cargo bench --bench gemm_micro`

use tbgemm::bench::grid::time_algorithm;
use tbgemm::gemm::Kind;
use tbgemm::util::timer::bench_loop;
use tbgemm::util::mat::{MatI32, MatI8};
use tbgemm::util::Rng;
use tbgemm::gemm::native::kernels::tnn_gemm;
use tbgemm::gemm::native::PlaneRows;

fn main() {
    let point = (120usize, 48usize, 256usize);
    let macs = (point.0 * point.1 * point.2) as f64;
    println!("native kernels at H×W×D = {point:?} ({:.1} MMAC):", macs / 1e6);
    let mut baseline_f32 = None;
    for kind in Kind::ALL {
        let gt = time_algorithm(kind, &[point], 5, 5, 42);
        let t = gt.times[0].1;
        if kind == Kind::F32 {
            baseline_f32 = Some(t);
        }
        let speedup = baseline_f32.map(|b| b / t).unwrap_or(1.0);
        println!(
            "  {:<6} {:>9.3} ms   {:>7.2} GMAC/s   {:>5.2}× vs F32",
            kind.label(),
            t * 1e3,
            macs / t / 1e9,
            speedup
        );
    }

    // Packing-vs-kernel split for TNN (how much of the timed region is
    // the A-repacking Algorithm 2 performs per call).
    let mut rng = Rng::new(7);
    let a = MatI8::random_ternary(point.0, point.2, &mut rng);
    let b = MatI8::random_ternary(point.2, point.1, &mut rng);
    let bt = PlaneRows::from_ternary_transposed(&b);
    let pack_stats = bench_loop(0.2, 200, || {
        std::hint::black_box(PlaneRows::from_ternary(&a));
    });
    let ap = PlaneRows::from_ternary(&a);
    let mut c = MatI32::zeros(point.0, point.1);
    let kernel_stats = bench_loop(0.2, 200, || {
        tnn_gemm(&ap, &bt, &mut c);
    });
    println!(
        "\nTNN split: pack-A {:.3} ms, kernel {:.3} ms ({:.0}% packing)",
        pack_stats.mean * 1e3,
        kernel_stats.mean * 1e3,
        100.0 * pack_stats.mean / (pack_stats.mean + kernel_stats.mean)
    );
    println!("gemm_micro OK");
}
