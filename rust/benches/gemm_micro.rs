//! Microbenchmark of the native GEMM path through the plan/execute API:
//! the seven-algorithm ladder at a paper-grid point (120×48×256), the
//! tiling/threading ladder at the acceptance shape (256×256×2048)
//! including the widened BNN 4×4 tile, the deep-K ladder, and the TNN
//! packing-vs-kernel split.
//!
//! Every variant runs through `GemmPlan::run`, so per-iteration cost
//! includes the Algorithm-2 A-packing into the reused scratch arena
//! (the paper's timed protocol: B packed offline, A packed per
//! multiplication) and zero per-call heap allocation.
//!
//! Emits `BENCH_gemm.json` — one record per (kind, variant, shape) with
//! ns/iter and effective GOPS (2·m·n·k ops) — compared against the
//! committed `BENCH_gemm.baseline.json` by `tools/bench_gate.py` in CI.
//!
//! Run: `cargo bench --bench gemm_micro`

use tbgemm::bench::grid::time_algorithm;
use tbgemm::gemm::{
    GemmConfig, GemmOut, GemmPlan, GemmScratch, KPanel, Kind, Lhs, Threading, Tile, Weights,
};
use tbgemm::util::mat::MatI8;
use tbgemm::util::timer::bench_loop;
use tbgemm::util::Rng;

/// One benchmark record destined for BENCH_gemm.json.
struct Record {
    kind: &'static str,
    variant: &'static str,
    m: usize,
    n: usize,
    k: usize,
    ns_per_iter: f64,
}

impl Record {
    fn gops(&self) -> f64 {
        2.0 * (self.m * self.n * self.k) as f64 / self.ns_per_iter
    }

    fn json(&self) -> String {
        format!(
            "{{\"kind\":\"{}\",\"variant\":\"{}\",\"m\":{},\"n\":{},\"k\":{},\"ns_per_iter\":{:.1},\"gops\":{:.3}}}",
            self.kind,
            self.variant,
            self.m,
            self.n,
            self.k,
            self.ns_per_iter,
            self.gops()
        )
    }
}

/// Build a native BNN/TNN/TBN plan with the given knobs.
fn lowbit_plan(kind: Kind, b: &MatI8, threading: Threading, k_panel: KPanel, tile: Tile) -> GemmPlan {
    GemmPlan::new(
        GemmConfig::native(kind).with_threading(threading).with_k_panel(k_panel).with_tile(tile),
        Weights::I8(b),
    )
    .expect("bench plan")
}

fn main() {
    let mut records: Vec<Record> = Vec::new();

    // --- the seven-algorithm ladder at a paper-grid point ---------------
    let point = (120usize, 48usize, 256usize);
    let macs = (point.0 * point.1 * point.2) as f64;
    println!("native plans at H×W×D = {point:?} ({:.1} MMAC):", macs / 1e6);
    let mut baseline_f32 = None;
    for kind in Kind::ALL {
        let gt = time_algorithm(kind, &[point], 5, 5, 42);
        let t = gt.times[0].1;
        if kind == Kind::F32 {
            baseline_f32 = Some(t);
        }
        let speedup = baseline_f32.map(|b| b / t).unwrap_or(1.0);
        println!(
            "  {:<6} {:>9.3} ms   {:>7.2} GMAC/s   {:>5.2}× vs F32",
            kind.label(),
            t * 1e3,
            macs / t / 1e9,
            speedup
        );
        records.push(Record {
            kind: kind.label(),
            variant: "tiled",
            m: point.0,
            n: point.1,
            k: point.2,
            ns_per_iter: t * 1e9,
        });
    }

    // --- tiling + threading ladder at the acceptance shape --------------
    let (m, n, k) = (256usize, 256usize, 2048usize);
    println!("\ntiling/threading ladder at {m}×{n}×{k} (plan run incl. A-packing):");
    let mut rng = Rng::new(0x517E);
    let ab = MatI8::random_binary(m, k, &mut rng);
    let bb = MatI8::random_binary(k, n, &mut rng);
    let at = MatI8::random_ternary(m, k, &mut rng);
    let bt3 = MatI8::random_ternary(k, n, &mut rng);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);

    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    let mut report = |kind: &'static str, variant: &'static str, t: f64, rowdot_t: f64, threads: usize| {
        println!(
            "  {kind:<4} {variant:<9} ({threads:>2} thr) {:>9.3} ms   {:>7.2} GMAC/s   {:>5.2}× vs rowdot",
            t * 1e3,
            (m * n * k) as f64 / t / 1e9,
            rowdot_t / t
        );
        records.push(Record { kind, variant, m, n, k, ns_per_iter: t * 1e9 });
    };

    // One config ladder per low-bit kind: rowdot → tiled → (wide tile) →
    // tiled_mt, all through the same plan entry point. The wide rungs are
    // the widened register tiles: BNN 4×4 ("wide4x4") and TNN 2×4
    // ("tnn_wide"); TBN has no wide tile yet.
    let ladders: [(&'static str, Kind, &MatI8, &MatI8, Option<&'static str>); 3] = [
        ("BNN", Kind::Bnn, &ab, &bb, Some("wide4x4")),
        ("TNN", Kind::Tnn, &at, &bt3, Some("tnn_wide")),
        ("TBN", Kind::Tbn, &at, &bb, None),
    ];
    for (label, kind, a, b, wide_variant) in ladders {
        let rowdot = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Rowdot);
        let t_rd = bench_loop(0.4, 50, || {
            rowdot.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        report(label, "rowdot", t_rd, t_rd, 1);
        let tiled = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Auto);
        let t = bench_loop(0.4, 50, || {
            tiled.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        report(label, "tiled", t, t_rd, 1);
        if let Some(variant) = wide_variant {
            let wide = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Wide);
            let t = bench_loop(0.4, 50, || {
                wide.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, variant, t, t_rd, 1);
        }
        let mt = lowbit_plan(kind, b, Threading::Auto, KPanel::Auto, Tile::Auto);
        let t = bench_loop(0.4, 50, || {
            mt.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        report(label, "tiled_mt", t, t_rd, cores);
        // Autotuned rung: whatever `tune::resolve` picks for this shape
        // (the tuning file when TBGEMM_TUNE_FILE is set, the cost model
        // otherwise). Laid next to the hand-picked rungs above, this is
        // the tuner-regression signal: "tuned" should track the best of
        // them.
        let tuned = GemmPlan::new(GemmConfig::tuned(kind), Weights::I8(b)).expect("bench plan");
        let t = bench_loop(0.4, 50, || {
            tuned.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        let resolved = tbgemm::tune::resolve(kind, (m, n, k));
        report(label, "tuned", t, t_rd, resolved.threading.worker_count(m));
    }

    // --- aarch64 only: explicit NEON vcnt rungs -------------------------
    // On aarch64 the tiled plans above already dispatch to the NEON
    // intrinsics (`gemm/native/simd_popcnt/neon.rs`); these rungs
    // re-time BNN/TNN under names that exist only on aarch64, so an ARM
    // run's records are self-describing when laid next to x86 runs for
    // the Table III comparison. Note baselines are per-host artifacts:
    // the committed baseline gates the x86 CI job and must be seeded on
    // an x86 host (the shared rung names carry no arch key) — an ARM
    // host's BENCH_gemm.json is measurement material, not CI baseline
    // material. See tools/bench_gate.py and README "ARM / NEON backend".
    if cfg!(target_arch = "aarch64") {
        println!("\nNEON vcnt rungs at {m}×{n}×{k}:");
        let neon_rungs: [(&'static str, &'static str, Kind, &MatI8, &MatI8); 2] =
            [("BNN", "bnn_neon", Kind::Bnn, &ab, &bb), ("TNN", "tnn_neon", Kind::Tnn, &at, &bt3)];
        for (label, variant, kind, a, b) in neon_rungs {
            let plan = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Auto);
            let t = bench_loop(0.4, 50, || {
                plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, variant, t, t, 1);
        }
    }

    // --- deep-K ladder: rowdot vs tiled vs K-paneled vs tiled_mt --------
    // The K-panel level caps in-panel accumulation at the 16-bit-safe
    // bound (32767); at K = 32768 `Auto` splits into two panels, below it
    // the paneled path must track the tiled path (acceptance: no slower
    // at K = 2048 — by construction, since Auto dispatches shallow K to
    // the unpaneled band; `kpanel_forced` tracks the real spill cost).
    println!("\ndeep-K ladder (BNN/TNN, 128×128×K, plan run incl. A-packing):");
    let (m, n) = (128usize, 128usize);
    for &k in &[2048usize, 8192, 32768] {
        let mut rng = Rng::new(0xDEE9 + k as u64);
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt3 = MatI8::random_ternary(k, n, &mut rng);
        let mut report = |kind: &'static str, variant: &'static str, t: f64, rowdot_t: f64, threads: usize| {
            println!(
                "  {kind:<4} K={k:<6} {variant:<13} ({threads:>2} thr) {:>9.3} ms  {:>7.2} GMAC/s  {:>5.2}× vs rowdot",
                t * 1e3,
                (m * n * k) as f64 / t / 1e9,
                rowdot_t / t
            );
            records.push(Record { kind, variant, m, n, k, ns_per_iter: t * 1e9 });
        };
        let deep_ladders: [(&'static str, Kind, &MatI8, &MatI8); 2] =
            [("BNN", Kind::Bnn, &ab, &bb), ("TNN", Kind::Tnn, &at, &bt3)];
        for (label, kind, a, b) in deep_ladders {
            let rowdot = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Rowdot);
            let t_rd = bench_loop(0.25, 30, || {
                rowdot.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, "rowdot", t_rd, t_rd, 1);
            // Production path (KPanel::Auto): dispatches shallow K to
            // the unpaneled band and splits past the 16-bit bound —
            // through the plan API this single rung subsumes the old
            // separate "tiled"/"kpanel" pair, which were the same config.
            let tiled = lowbit_plan(kind, b, Threading::Single, KPanel::Auto, Tile::Auto);
            let t = bench_loop(0.25, 30, || {
                tiled.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, "tiled", t, t_rd, 1);
            // Forced spill path (1024-bit panels): the true K-panel
            // overhead at every rung, not just past the 16-bit bound —
            // the dispatch-regression signal is "kpanel_forced" vs
            // "tiled" at shallow K (spill cost) converging past 32767
            // (where "tiled" spills too).
            let forced = lowbit_plan(kind, b, Threading::Single, KPanel::Depth(1024), Tile::Auto);
            let t = bench_loop(0.25, 30, || {
                forced.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, "kpanel_forced", t, t_rd, 1);
            let mt = lowbit_plan(kind, b, Threading::Auto, KPanel::Auto, Tile::Auto);
            let t = bench_loop(0.25, 30, || {
                mt.run(Lhs::I8(a), &mut out, &mut scratch).expect("gemm");
            })
            .mean;
            report(label, "tiled_mt", t, t_rd, cores);
        }
    }

    // --- dispatch-overhead rung: small shape, Fixed(4) ------------------
    // Table III's real TNN/TBN/BNN layers are small-matrix shapes where
    // per-call thread spawn/join used to dominate; with the persistent
    // worker pool the "small_pool4" vs "small_single" gap is the gated,
    // machine-readable dispatch-overhead number (pool dispatch cost per
    // call, not spawn cost). Fixed(4) genuinely splits 32 rows into four
    // 8-row bands.
    {
        let (m, n, k) = (32usize, 32usize, 256usize);
        println!("\ndispatch-overhead rung at {m}×{n}×{k} (pool-backed Fixed(4) vs single):");
        let mut rng = Rng::new(0x5A11);
        let a = MatI8::random_binary(m, k, &mut rng);
        let b = MatI8::random_binary(k, n, &mut rng);
        let single = lowbit_plan(Kind::Bnn, &b, Threading::Single, KPanel::Auto, Tile::Auto);
        let t1 = bench_loop(0.2, 400, || {
            single.run(Lhs::I8(&a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        let pooled = lowbit_plan(Kind::Bnn, &b, Threading::Fixed(4), KPanel::Auto, Tile::Auto);
        let t4 = bench_loop(0.2, 400, || {
            pooled.run(Lhs::I8(&a), &mut out, &mut scratch).expect("gemm");
        })
        .mean;
        println!(
            "  BNN  small_single ( 1 thr) {:>9.3} µs\n  BNN  small_pool4  ( 4 thr) {:>9.3} µs   {:>5.2}× vs single",
            t1 * 1e6,
            t4 * 1e6,
            t1 / t4
        );
        for (variant, t) in [("small_single", t1), ("small_pool4", t4)] {
            records.push(Record { kind: "BNN", variant, m, n, k, ns_per_iter: t * 1e9 });
        }
    }

    // --- packing-vs-kernel split for TNN --------------------------------
    // The plan packs A per run (Algorithm 2); splitting run time into
    // pack + kernel shows how much of the multiplication the request-path
    // packing costs.
    let point = (120usize, 48usize, 256usize);
    let mut rng = Rng::new(7);
    let a = MatI8::random_ternary(point.0, point.2, &mut rng);
    let b = MatI8::random_ternary(point.2, point.1, &mut rng);
    use tbgemm::gemm::native::PlaneRows;
    let pack_stats = bench_loop(0.2, 200, || {
        std::hint::black_box(PlaneRows::from_ternary(&a));
    });
    let plan = lowbit_plan(Kind::Tnn, &b, Threading::Single, KPanel::Auto, Tile::Auto);
    let run_stats = bench_loop(0.2, 200, || {
        plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("gemm");
    });
    println!(
        "\nTNN split: pack-A {:.3} ms of {:.3} ms plan run ({:.0}% packing)",
        pack_stats.mean * 1e3,
        run_stats.mean * 1e3,
        100.0 * pack_stats.mean / run_stats.mean
    );

    // --- machine-readable output ----------------------------------------
    let body: Vec<String> = records.iter().map(|r| format!("  {}", r.json())).collect();
    let json = format!("[\n{}\n]\n", body.join(",\n"));
    match std::fs::write("BENCH_gemm.json", &json) {
        Ok(()) => println!("\nwrote BENCH_gemm.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_gemm.json: {e}"),
    }
    println!("gemm_micro OK");
}
