//! Microbenchmark of the native GEMM kernels: the seven-algorithm ladder
//! at a paper-grid point (120×48×256), the tiling/threading speedup
//! ladder at the acceptance shape (256×256×2048), and the TNN
//! packing-vs-kernel split.
//!
//! Emits `BENCH_gemm.json` — one record per (kind, variant, shape) with
//! ns/iter and effective GOPS (2·m·n·k ops) — so later PRs can track the
//! perf trajectory mechanically.
//!
//! Run: `cargo bench --bench gemm_micro`

use tbgemm::bench::grid::time_algorithm;
use tbgemm::gemm::native::kernels as nk;
use tbgemm::gemm::native::{
    bnn_gemm_kp_mt, bnn_gemm_mt, tbn_gemm_mt, tnn_gemm_kp_mt, tnn_gemm_mt, BitRows, KPanel, PlaneRows, Threading,
};
use tbgemm::gemm::Kind;
use tbgemm::util::mat::{MatI32, MatI8};
use tbgemm::util::timer::bench_loop;
use tbgemm::util::Rng;

/// One benchmark record destined for BENCH_gemm.json.
struct Record {
    kind: &'static str,
    variant: &'static str,
    m: usize,
    n: usize,
    k: usize,
    ns_per_iter: f64,
}

impl Record {
    fn gops(&self) -> f64 {
        2.0 * (self.m * self.n * self.k) as f64 / self.ns_per_iter
    }

    fn json(&self) -> String {
        format!(
            "{{\"kind\":\"{}\",\"variant\":\"{}\",\"m\":{},\"n\":{},\"k\":{},\"ns_per_iter\":{:.1},\"gops\":{:.3}}}",
            self.kind,
            self.variant,
            self.m,
            self.n,
            self.k,
            self.ns_per_iter,
            self.gops()
        )
    }
}

fn main() {
    let mut records: Vec<Record> = Vec::new();

    // --- the seven-algorithm ladder at a paper-grid point ---------------
    let point = (120usize, 48usize, 256usize);
    let macs = (point.0 * point.1 * point.2) as f64;
    println!("native kernels at H×W×D = {point:?} ({:.1} MMAC):", macs / 1e6);
    let mut baseline_f32 = None;
    for kind in Kind::ALL {
        let gt = time_algorithm(kind, &[point], 5, 5, 42);
        let t = gt.times[0].1;
        if kind == Kind::F32 {
            baseline_f32 = Some(t);
        }
        let speedup = baseline_f32.map(|b| b / t).unwrap_or(1.0);
        println!(
            "  {:<6} {:>9.3} ms   {:>7.2} GMAC/s   {:>5.2}× vs F32",
            kind.label(),
            t * 1e3,
            macs / t / 1e9,
            speedup
        );
        records.push(Record {
            kind: kind.label(),
            variant: "tiled",
            m: point.0,
            n: point.1,
            k: point.2,
            ns_per_iter: t * 1e9,
        });
    }

    // --- tiling + threading ladder at the acceptance shape --------------
    let (m, n, k) = (256usize, 256usize, 2048usize);
    println!("\ntiling/threading ladder at {m}×{n}×{k} (kernel only, A pre-packed):");
    let mut rng = Rng::new(0x517E);
    let ab = MatI8::random_binary(m, k, &mut rng);
    let bb = MatI8::random_binary(k, n, &mut rng);
    let at = MatI8::random_ternary(m, k, &mut rng);
    let bt3 = MatI8::random_ternary(k, n, &mut rng);
    let a_bits = BitRows::from_binary(&ab);
    let b_bits = BitRows::from_binary_transposed(&bb);
    let a_planes = PlaneRows::from_ternary(&at);
    let b_planes = PlaneRows::from_ternary_transposed(&bt3);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);

    let mut c = MatI32::zeros(m, n);
    let mut report = |kind: &'static str, variant: &'static str, t: f64, rowdot_t: f64, threads: usize| {
        println!(
            "  {kind:<4} {variant:<9} ({threads:>2} thr) {:>9.3} ms   {:>7.2} GMAC/s   {:>5.2}× vs rowdot",
            t * 1e3,
            (m * n * k) as f64 / t / 1e9,
            rowdot_t / t
        );
        records.push(Record { kind, variant, m, n, k, ns_per_iter: t * 1e9 });
    };

    let t_rd = bench_loop(0.4, 50, || nk::bnn_gemm_rowdot(&a_bits, &b_bits, &mut c)).mean;
    report("BNN", "rowdot", t_rd, t_rd, 1);
    let t = bench_loop(0.4, 50, || nk::bnn_gemm(&a_bits, &b_bits, &mut c)).mean;
    report("BNN", "tiled", t, t_rd, 1);
    let t = bench_loop(0.4, 50, || bnn_gemm_mt(&a_bits, &b_bits, &mut c, Threading::Auto)).mean;
    report("BNN", "tiled_mt", t, t_rd, cores);

    let t_rd = bench_loop(0.4, 50, || nk::tnn_gemm_rowdot(&a_planes, &b_planes, &mut c)).mean;
    report("TNN", "rowdot", t_rd, t_rd, 1);
    let t = bench_loop(0.4, 50, || nk::tnn_gemm(&a_planes, &b_planes, &mut c)).mean;
    report("TNN", "tiled", t, t_rd, 1);
    let t = bench_loop(0.4, 50, || tnn_gemm_mt(&a_planes, &b_planes, &mut c, Threading::Auto)).mean;
    report("TNN", "tiled_mt", t, t_rd, cores);

    let t_rd = bench_loop(0.4, 50, || nk::tbn_gemm_rowdot(&a_planes, &b_bits, &mut c)).mean;
    report("TBN", "rowdot", t_rd, t_rd, 1);
    let t = bench_loop(0.4, 50, || nk::tbn_gemm(&a_planes, &b_bits, &mut c)).mean;
    report("TBN", "tiled", t, t_rd, 1);
    let t = bench_loop(0.4, 50, || tbn_gemm_mt(&a_planes, &b_bits, &mut c, Threading::Auto)).mean;
    report("TBN", "tiled_mt", t, t_rd, cores);

    // --- deep-K ladder: rowdot vs tiled vs K-paneled vs tiled_mt --------
    // The K-panel level caps in-panel accumulation at the 16-bit-safe
    // bound (32767); at K = 32768 `Auto` splits into two panels, below it
    // the paneled path must track the tiled path (acceptance: no slower
    // at K = 2048 — by construction, since Auto dispatches shallow K to
    // the unpaneled band; `kpanel_forced` tracks the real spill cost).
    println!("\ndeep-K ladder (BNN/TNN, 128×128×K, kernel only):");
    let (m, n) = (128usize, 128usize);
    for &k in &[2048usize, 8192, 32768] {
        let mut rng = Rng::new(0xDEE9 + k as u64);
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt3 = MatI8::random_ternary(k, n, &mut rng);
        let a_bits = BitRows::from_binary(&ab);
        let b_bits = BitRows::from_binary_transposed(&bb);
        let a_planes = PlaneRows::from_ternary(&at);
        let b_planes = PlaneRows::from_ternary_transposed(&bt3);
        let mut c = MatI32::zeros(m, n);
        let mut report = |kind: &'static str, variant: &'static str, t: f64, rowdot_t: f64, threads: usize| {
            println!(
                "  {kind:<4} K={k:<6} {variant:<9} ({threads:>2} thr) {:>9.3} ms  {:>7.2} GMAC/s  {:>5.2}× vs rowdot",
                t * 1e3,
                (m * n * k) as f64 / t / 1e9,
                rowdot_t / t
            );
            records.push(Record { kind, variant, m, n, k, ns_per_iter: t * 1e9 });
        };

        let t_rd = bench_loop(0.25, 30, || nk::bnn_gemm_rowdot(&a_bits, &b_bits, &mut c)).mean;
        report("BNN", "rowdot", t_rd, t_rd, 1);
        let t = bench_loop(0.25, 30, || nk::bnn_gemm(&a_bits, &b_bits, &mut c)).mean;
        report("BNN", "tiled", t, t_rd, 1);
        // Production path: Auto dispatches shallow K to the unpaneled
        // band, so rungs below the bound match "tiled" by construction —
        // recorded anyway as the regression signal: if the dispatch ever
        // breaks, "kpanel" diverges from "tiled" at shallow K.
        let t = bench_loop(0.25, 30, || {
            bnn_gemm_kp_mt(&a_bits, &b_bits, &mut c, Threading::Single, KPanel::Auto)
        })
        .mean;
        report("BNN", "kpanel", t, t_rd, 1);
        // Forced spill path (1024-bit panels): the true K-panel overhead
        // at every rung, not just past the 16-bit bound.
        let t = bench_loop(0.25, 30, || {
            bnn_gemm_kp_mt(&a_bits, &b_bits, &mut c, Threading::Single, KPanel::Depth(1024))
        })
        .mean;
        report("BNN", "kpanel_forced", t, t_rd, 1);
        let t = bench_loop(0.25, 30, || bnn_gemm_mt(&a_bits, &b_bits, &mut c, Threading::Auto)).mean;
        report("BNN", "tiled_mt", t, t_rd, cores);

        let t_rd = bench_loop(0.25, 30, || nk::tnn_gemm_rowdot(&a_planes, &b_planes, &mut c)).mean;
        report("TNN", "rowdot", t_rd, t_rd, 1);
        let t = bench_loop(0.25, 30, || nk::tnn_gemm(&a_planes, &b_planes, &mut c)).mean;
        report("TNN", "tiled", t, t_rd, 1);
        let t = bench_loop(0.25, 30, || {
            tnn_gemm_kp_mt(&a_planes, &b_planes, &mut c, Threading::Single, KPanel::Auto)
        })
        .mean;
        report("TNN", "kpanel", t, t_rd, 1);
        let t = bench_loop(0.25, 30, || {
            tnn_gemm_kp_mt(&a_planes, &b_planes, &mut c, Threading::Single, KPanel::Depth(1024))
        })
        .mean;
        report("TNN", "kpanel_forced", t, t_rd, 1);
        let t = bench_loop(0.25, 30, || tnn_gemm_mt(&a_planes, &b_planes, &mut c, Threading::Auto)).mean;
        report("TNN", "tiled_mt", t, t_rd, cores);
    }

    // --- packing-vs-kernel split for TNN --------------------------------
    let point = (120usize, 48usize, 256usize);
    let mut rng = Rng::new(7);
    let a = MatI8::random_ternary(point.0, point.2, &mut rng);
    let b = MatI8::random_ternary(point.2, point.1, &mut rng);
    let bt = PlaneRows::from_ternary_transposed(&b);
    let pack_stats = bench_loop(0.2, 200, || {
        std::hint::black_box(PlaneRows::from_ternary(&a));
    });
    let ap = PlaneRows::from_ternary(&a);
    let mut c = MatI32::zeros(point.0, point.1);
    let kernel_stats = bench_loop(0.2, 200, || {
        nk::tnn_gemm(&ap, &bt, &mut c);
    });
    println!(
        "\nTNN split: pack-A {:.3} ms, kernel {:.3} ms ({:.0}% packing)",
        pack_stats.mean * 1e3,
        kernel_stats.mean * 1e3,
        100.0 * pack_stats.mean / (pack_stats.mean + kernel_stats.mean)
    );

    // --- machine-readable output ----------------------------------------
    let body: Vec<String> = records.iter().map(|r| format!("  {}", r.json())).collect();
    let json = format!("[\n{}\n]\n", body.join(",\n"));
    match std::fs::write("BENCH_gemm.json", &json) {
        Ok(()) => println!("\nwrote BENCH_gemm.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_gemm.json: {e}"),
    }
    println!("gemm_micro OK");
}
