//! Property-based differential suite for the native GEMM path, driven
//! through the plan/execute API ([`GemmPlan`], `Backend::Native`).
//!
//! Every case draws a random `(m, n, k)` shape (deep-K cases cross the
//! 16-bit safe bound of 32767), a random thread count in 1..=8, a random
//! K-panel depth (or `Auto`) and — for BNN — a random register tile
//! (`Auto` 4×2 / `Wide` 4×4 / the seed's `Rowdot` baseline), regenerates
//! random inputs from the case seed, and checks the plan word-for-word
//! against the scalar oracles in `gemm/reference.rs` — for all six
//! threaded kernels: BNN, TNN, TBN, daBNN, U8 and F32 (U4 is covered by
//! the backend sweep in `tests/blocked_gemm.rs`; its native path has a
//! fixed internal depth block). Failures shrink to a minimal failing
//! shape via `util::proptest::check_shrink`.
//!
//! The base seed is deterministic; CI pins it explicitly through the
//! `TBGEMM_PROP_SEED` environment variable so the suite is replayable
//! byte-for-byte across runs.

use tbgemm::gemm::reference;
use tbgemm::gemm::{
    GemmConfig, GemmOut, GemmPlan, GemmScratch, KPanel, Kind, Lhs, Threading, Tile, Weights,
};
use tbgemm::util::mat::{MatF32, MatI8, MatU8};
use tbgemm::util::proptest::{check_shrink, gemm_shape, Config};
use tbgemm::util::Rng;

/// Per-test config: base seed from `TBGEMM_PROP_SEED` when set (CI pins
/// it; parsed once via the central env registry), with a per-test offset
/// so the six suites draw distinct cases.
fn cfg(offset: u64, cases: usize) -> Config {
    let base = tbgemm::util::env::prop_seed().unwrap_or(0x00C0_FFEE);
    Config { cases, base_seed: base.wrapping_add(offset) }
}

/// Random GEMM shape: mostly moderate, with a deep-K band (m, n kept
/// small there so the scalar oracle stays fast) that crosses the 16-bit
/// accumulation bound — K reaches ≥ 32768.
fn shape(rng: &mut Rng) -> (usize, usize, usize) {
    match rng.below(4) {
        // Deep K: straddles safe_k = 32767 from both sides.
        0 => (1 + rng.below(10), 1 + rng.below(8), 30_000 + rng.below(6_000)),
        // Medium K around im2col depths (3×3×512 = 4608).
        1 => (1 + rng.below(20), 1 + rng.below(16), 2_048 + rng.below(4_096)),
        // Small, boundary-biased shapes.
        _ => gemm_shape(rng, 33, 25, 300),
    }
}

/// Random K-panel config: `Auto` or an explicit depth in `1..=2k`
/// (explicit depths above the safe bound exercise the clamp).
fn k_panel(rng: &mut Rng, k: usize) -> KPanel {
    if rng.below(4) == 0 {
        KPanel::Auto
    } else {
        KPanel::Depth(1 + rng.below(2 * k))
    }
}

fn threads(rng: &mut Rng) -> Threading {
    Threading::Fixed(1 + rng.below(8))
}

/// A native plan for `kind` with randomized execution knobs.
fn native_plan(kind: Kind, weights: Weights<'_>, th: Threading, kp: KPanel, tile: Tile) -> GemmPlan {
    GemmPlan::new(GemmConfig::native(kind).with_threading(th).with_k_panel(kp).with_tile(tile), weights)
        .expect("plan build")
}

fn run(plan: &GemmPlan, lhs: Lhs<'_>) -> GemmOut {
    let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
    let mut scratch = GemmScratch::new();
    plan.run(lhs, &mut out, &mut scratch).expect("plan run");
    out
}

#[test]
fn bnn_plan_matches_reference() {
    check_shrink(cfg(0x10, 24), "bnn plan vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        // Randomize the register tile too: Auto (4×2), Wide (4×4), and
        // occasionally the seed Rowdot baseline (single-threaded).
        let tile = [Tile::Auto, Tile::Wide, Tile::Auto, Tile::Rowdot][rng.below(4)];
        let a = MatI8::random_binary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let plan = native_plan(Kind::Bnn, Weights::I8(&b), th, kp, tile);
        let out = run(&plan, Lhs::I8(&a));
        assert_eq!(
            out.as_i32().expect("i32 out").data,
            want.data,
            "m={m} n={n} k={k} th={th:?} kp={kp:?} tile={tile:?}"
        );
    });
}

#[test]
fn tnn_plan_matches_reference() {
    check_shrink(cfg(0x20, 24), "tnn plan vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        // Randomize the register tile like the BNN case: Auto, the
        // widened 2×4 Wide tile, or the seed Rowdot baseline.
        let tile = [Tile::Auto, Tile::Wide, Tile::Auto, Tile::Rowdot][rng.below(4)];
        let a = MatI8::random_ternary(m, k, rng);
        let b = MatI8::random_ternary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let plan = native_plan(Kind::Tnn, Weights::I8(&b), th, kp, tile);
        let out = run(&plan, Lhs::I8(&a));
        assert_eq!(
            out.as_i32().expect("i32 out").data,
            want.data,
            "m={m} n={n} k={k} th={th:?} kp={kp:?} tile={tile:?}"
        );
    });
}

#[test]
fn tbn_plan_matches_reference() {
    check_shrink(cfg(0x30, 24), "tbn plan vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_ternary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let plan = native_plan(Kind::Tbn, Weights::I8(&b), th, kp, Tile::Auto);
        let out = run(&plan, Lhs::I8(&a));
        assert_eq!(out.as_i32().expect("i32 out").data, want.data, "m={m} n={n} k={k} th={th:?} kp={kp:?}");
    });
}

#[test]
fn dabnn_plan_matches_reference() {
    check_shrink(cfg(0x40, 16), "dabnn plan vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_binary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let plan = native_plan(Kind::DaBnn, Weights::I8(&b), th, kp, Tile::Auto);
        let out = run(&plan, Lhs::I8(&a));
        // f32 popcount partials are exact integers below 2²³, so the
        // comparison is word-for-word after the integer cast.
        let c = out.as_f32().expect("f32 out");
        for i in 0..m {
            for j in 0..n {
                assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j}) m={m} n={n} k={k} th={th:?} kp={kp:?}");
            }
        }
    });
}

#[test]
fn u8_plan_matches_reference() {
    check_shrink(cfg(0x50, 16), "u8 plan vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let za = rng.below(256) as i32;
        let zb = rng.below(256) as i32;
        let a = MatU8::random(m, k, rng);
        let b = MatU8::random(k, n, rng);
        let want = reference::gemm_u8_centered(&a, &b, za, zb);
        let plan = native_plan(Kind::U8, Weights::U8 { b: &b, za, zb }, th, kp, Tile::Auto);
        let out = run(&plan, Lhs::U8(&a));
        assert_eq!(
            out.as_i32().expect("i32 out").data,
            want.data,
            "m={m} n={n} k={k} za={za} zb={zb} th={th:?} kp={kp:?}"
        );
    });
}

/// F32: with `KPanel::Auto` the depth stays one panel and threading
/// preserves per-output accumulation order, so the threaded plan is
/// bit-identical to the single-threaded one; explicit panels change the
/// rounding association, so all cases compare against the scalar oracle
/// with a depth-scaled tolerance.
#[test]
fn f32_plan_matches_reference() {
    check_shrink(
        cfg(0x60, 16),
        "f32 plan vs oracle",
        // f32 has no safe-K bound; cap the depth so the tolerance model
        // stays tight.
        |rng| {
            let (m, n, _) = gemm_shape(rng, 25, 20, 64);
            (m, n, 1 + rng.below(4096))
        },
        |m, n, k, rng| {
            let th = threads(rng);
            let kp = k_panel(rng, k);
            let a = MatF32::random(m, k, rng);
            let b = MatF32::random(k, n, rng);
            let plan = native_plan(Kind::F32, Weights::F32(&b), th, kp, Tile::Auto);
            let out = run(&plan, Lhs::F32(&a));
            let c = out.as_f32().expect("f32 out");
            if kp == KPanel::Auto {
                // Word-for-word against the single-threaded plan.
                let single = native_plan(Kind::F32, Weights::F32(&b), Threading::Single, kp, Tile::Auto);
                let sout = run(&single, Lhs::F32(&a));
                assert_eq!(c.data, sout.as_f32().expect("f32 out").data, "m={m} n={n} k={k} th={th:?}");
            }
            let want = reference::gemm_f32(&a, &b);
            // Absolute floor scales with √k (random-walk magnitude of the
            // partial sums), relative part with the result.
            let tol_scale = 1e-6 * (k as f32).max(64.0);
            for i in 0..m {
                for j in 0..n {
                    let (g, w) = (c.get(i, j), want.get(i, j));
                    assert!(
                        (g - w).abs() <= tol_scale * ((k as f32).sqrt() + w.abs()),
                        "({i},{j}): {g} vs {w}, m={m} n={n} k={k} th={th:?} kp={kp:?}"
                    );
                }
            }
        },
    );
}
