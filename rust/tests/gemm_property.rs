//! Property-based differential suite for the K-paneled native GEMM path.
//!
//! Every case draws a random `(m, n, k)` shape (deep-K cases cross the
//! 16-bit safe bound of 32767), a random thread count in 1..=8 and a
//! random K-panel depth (or `Auto`), regenerates random inputs from the
//! case seed, and checks the K-paneled multithreaded driver word-for-word
//! against the scalar oracles in `gemm/reference.rs` — for all six
//! kernels: BNN, TNN, TBN, daBNN, U8 and F32. Failures shrink to a
//! minimal failing shape via `util::proptest::check_shrink`.
//!
//! The base seed is deterministic; CI pins it explicitly through the
//! `TBGEMM_PROP_SEED` environment variable so the suite is replayable
//! byte-for-byte across runs.

use tbgemm::gemm::native::{
    bnn_gemm_kp_mt, dabnn_gemm_kp_mt, f32_gemm_kp_mt, tbn_gemm_kp_mt, tnn_gemm_kp_mt, u8_gemm_kp_mt, BitRows,
    KPanel, PlaneRows, Threading,
};
use tbgemm::gemm::native::{f32_gemm, kernels};
use tbgemm::gemm::reference;
use tbgemm::util::mat::{MatF32, MatI32, MatI8, MatU8};
use tbgemm::util::proptest::{check_shrink, gemm_shape, Config};
use tbgemm::util::Rng;

/// Per-test config: base seed from `TBGEMM_PROP_SEED` when set (CI pins
/// it), with a per-test offset so the six suites draw distinct cases.
fn cfg(offset: u64, cases: usize) -> Config {
    let base = std::env::var("TBGEMM_PROP_SEED").ok().and_then(|v| v.parse::<u64>().ok()).unwrap_or(0x00C0_FFEE);
    Config { cases, base_seed: base.wrapping_add(offset) }
}

/// Random GEMM shape: mostly moderate, with a deep-K band (m, n kept
/// small there so the scalar oracle stays fast) that crosses the 16-bit
/// accumulation bound — K reaches ≥ 32768.
fn shape(rng: &mut Rng) -> (usize, usize, usize) {
    match rng.below(4) {
        // Deep K: straddles safe_k = 32767 from both sides.
        0 => (1 + rng.below(10), 1 + rng.below(8), 30_000 + rng.below(6_000)),
        // Medium K around im2col depths (3×3×512 = 4608).
        1 => (1 + rng.below(20), 1 + rng.below(16), 2_048 + rng.below(4_096)),
        // Small, boundary-biased shapes.
        _ => gemm_shape(rng, 33, 25, 300),
    }
}

/// Random K-panel config: `Auto` or an explicit depth in `1..=2k`
/// (explicit depths above the safe bound exercise the clamp).
fn k_panel(rng: &mut Rng, k: usize) -> KPanel {
    if rng.below(4) == 0 {
        KPanel::Auto
    } else {
        KPanel::Depth(1 + rng.below(2 * k))
    }
}

fn threads(rng: &mut Rng) -> Threading {
    Threading::Fixed(1 + rng.below(8))
}

#[test]
fn bnn_kp_mt_matches_reference() {
    check_shrink(cfg(0x10, 24), "bnn kp vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_binary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let mut c = MatI32::zeros(m, n);
        bnn_gemm_kp_mt(&BitRows::from_binary(&a), &BitRows::from_binary_transposed(&b), &mut c, th, kp);
        assert_eq!(c.data, want.data, "m={m} n={n} k={k} th={th:?} kp={kp:?}");
    });
}

#[test]
fn tnn_kp_mt_matches_reference() {
    check_shrink(cfg(0x20, 24), "tnn kp vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_ternary(m, k, rng);
        let b = MatI8::random_ternary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let mut c = MatI32::zeros(m, n);
        tnn_gemm_kp_mt(&PlaneRows::from_ternary(&a), &PlaneRows::from_ternary_transposed(&b), &mut c, th, kp);
        assert_eq!(c.data, want.data, "m={m} n={n} k={k} th={th:?} kp={kp:?}");
    });
}

#[test]
fn tbn_kp_mt_matches_reference() {
    check_shrink(cfg(0x30, 24), "tbn kp vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_ternary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let mut c = MatI32::zeros(m, n);
        tbn_gemm_kp_mt(&PlaneRows::from_ternary(&a), &BitRows::from_binary_transposed(&b), &mut c, th, kp);
        assert_eq!(c.data, want.data, "m={m} n={n} k={k} th={th:?} kp={kp:?}");
    });
}

#[test]
fn dabnn_kp_mt_matches_reference() {
    check_shrink(cfg(0x40, 16), "dabnn kp vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let a = MatI8::random_binary(m, k, rng);
        let b = MatI8::random_binary(k, n, rng);
        let want = reference::gemm_i8(&a, &b);
        let mut c = MatF32::zeros(m, n);
        dabnn_gemm_kp_mt(&BitRows::from_binary(&a), &BitRows::from_binary_transposed(&b), &mut c, th, kp);
        // f32 popcount partials are exact integers below 2²³, so the
        // comparison is word-for-word after the integer cast.
        for i in 0..m {
            for j in 0..n {
                assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j}) m={m} n={n} k={k} th={th:?} kp={kp:?}");
            }
        }
    });
}

#[test]
fn u8_kp_mt_matches_reference() {
    check_shrink(cfg(0x50, 16), "u8 kp vs oracle", shape, |m, n, k, rng| {
        let th = threads(rng);
        let kp = k_panel(rng, k);
        let za = rng.below(256) as i32;
        let zb = rng.below(256) as i32;
        let a = MatU8::random(m, k, rng);
        let b = MatU8::random(k, n, rng);
        let panels = kernels::pack_b_panels_u8(&b);
        let col_sums: Vec<i32> = (0..n).map(|j| (0..k).map(|t| b.get(t, j) as i32).sum()).collect();
        let want = reference::gemm_u8_centered(&a, &b, za, zb);
        let mut c = MatI32::zeros(m, n);
        u8_gemm_kp_mt(&a, &panels, n, za, zb, &col_sums, &mut c, th, kp);
        assert_eq!(c.data, want.data, "m={m} n={n} k={k} za={za} zb={zb} th={th:?} kp={kp:?}");
    });
}

/// F32: with `KPanel::Auto` the depth stays one panel, so the paneled
/// driver is bit-identical to the unpaneled kernel; explicit panels
/// change the rounding association, so those cases compare against the
/// scalar oracle with a depth-scaled tolerance.
#[test]
fn f32_kp_mt_matches_reference() {
    check_shrink(
        cfg(0x60, 16),
        "f32 kp vs oracle",
        // f32 has no safe-K bound; cap the depth so the tolerance model
        // stays tight.
        |rng| {
            let (m, n, _) = gemm_shape(rng, 25, 20, 64);
            (m, n, 1 + rng.below(4096))
        },
        |m, n, k, rng| {
            let th = threads(rng);
            let kp = k_panel(rng, k);
            let a = MatF32::random(m, k, rng);
            let b = MatF32::random(k, n, rng);
            let panels = kernels::pack_b_panels_f32(&b);
            let mut c = MatF32::zeros(m, n);
            f32_gemm_kp_mt(&a, &panels, n, &mut c, th, kp);
            if kp == KPanel::Auto {
                // Word-for-word against the unpaneled kernel.
                let mut want = MatF32::zeros(m, n);
                f32_gemm(&a, &panels, n, &mut want);
                assert_eq!(c.data, want.data, "m={m} n={n} k={k} th={th:?}");
            }
            let want = reference::gemm_f32(&a, &b);
            // Absolute floor scales with √k (random-walk magnitude of the
            // partial sums), relative part with the result.
            let tol_scale = 1e-6 * (k as f32).max(64.0);
            for i in 0..m {
                for j in 0..n {
                    let (g, w) = (c.get(i, j), want.get(i, j));
                    assert!(
                        (g - w).abs() <= tol_scale * ((k as f32).sqrt() + w.abs()),
                        "({i},{j}): {g} vs {w}, m={m} n={n} k={k} th={th:?} kp={kp:?}"
                    );
                }
            }
        },
    );
}
