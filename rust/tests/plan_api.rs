//! Contract tests for the plan/execute API: typed error cases, plan
//! reuse (pack once / run many) with pointer-stability asserts on the
//! shared [`GemmScratch`] arena and the caller-owned output, and the
//! widened BNN 4×4 tile behind the plan config.

use tbgemm::gemm::reference;
use tbgemm::gemm::{
    Backend, GemmConfig, GemmError, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Threading, Tile, Weights,
};
use tbgemm::util::mat::{MatF32, MatI8, MatU8};
use tbgemm::util::Rng;

// ---- typed error cases -------------------------------------------------

/// Wrong LHS variant is a typed error on every backend, for a low-bit
/// kind, a byte kind, and the f32 baseline.
#[test]
fn wrong_lhs_variant_is_typed() {
    let mut rng = Rng::new(0xE40);
    let b_i8 = MatI8::random_binary(32, 4, &mut rng);
    let b_u8 = MatU8::random(32, 4, &mut rng);
    let b_f32 = MatF32::random(32, 4, &mut rng);
    let a_f32 = MatF32::random(2, 32, &mut rng);
    let a_i8 = MatI8::random_binary(2, 32, &mut rng);
    let mut scratch = GemmScratch::new();
    for backend in Backend::ALL {
        let plan = GemmPlan::new(GemmConfig::new(Kind::Bnn, backend), Weights::I8(&b_i8)).expect("plan");
        let mut out = GemmOut::new_i32();
        assert_eq!(
            plan.run(Lhs::F32(&a_f32), &mut out, &mut scratch),
            Err(GemmError::LhsMismatch { kind: Kind::Bnn, expected: "i8", got: "f32" }),
            "{backend:?}"
        );
        let plan = GemmPlan::new(GemmConfig::new(Kind::U8, backend), Weights::U8 { b: &b_u8, za: 1, zb: 2 })
            .expect("plan");
        assert_eq!(
            plan.run(Lhs::I8(&a_i8), &mut out, &mut scratch),
            Err(GemmError::LhsMismatch { kind: Kind::U8, expected: "u8", got: "i8" }),
            "{backend:?}"
        );
        let plan = GemmPlan::new(GemmConfig::new(Kind::F32, backend), Weights::F32(&b_f32)).expect("plan");
        let mut fout = GemmOut::new_f32();
        assert_eq!(
            plan.run(Lhs::I8(&a_i8), &mut fout, &mut scratch),
            Err(GemmError::LhsMismatch { kind: Kind::F32, expected: "f32", got: "i8" }),
            "{backend:?}"
        );
    }
}

/// K mismatch and zero-dim matrices are typed errors; nothing panics.
#[test]
fn depth_and_empty_dims_are_typed() {
    let mut rng = Rng::new(0xE41);
    let b = MatI8::random_ternary(48, 6, &mut rng);
    let plan = GemmPlan::new(GemmConfig::native(Kind::Tnn), Weights::I8(&b)).expect("plan");
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    let a_short = MatI8::random_ternary(3, 47, &mut rng);
    assert_eq!(
        plan.run(Lhs::I8(&a_short), &mut out, &mut scratch),
        Err(GemmError::DepthMismatch { expected: 48, got: 47 })
    );
    let a_empty = MatI8::zeros(0, 48);
    assert_eq!(
        plan.run(Lhs::I8(&a_empty), &mut out, &mut scratch),
        Err(GemmError::EmptyDim { dim: "m" })
    );
    // Empty weights fail at build time, per dimension.
    assert_eq!(
        GemmPlan::new(GemmConfig::native(Kind::Tnn), Weights::I8(&MatI8::zeros(0, 6))).err(),
        Some(GemmError::EmptyDim { dim: "k" })
    );
    assert_eq!(
        GemmPlan::new(GemmConfig::native(Kind::Tnn), Weights::I8(&MatI8::zeros(48, 0))).err(),
        Some(GemmError::EmptyDim { dim: "n" })
    );
}

/// The output-variant contract is typed: an f32 buffer for an i32 kind
/// (and vice versa) is rejected without touching the buffer.
#[test]
fn output_variant_is_typed() {
    let mut rng = Rng::new(0xE42);
    let b = MatI8::random_binary(16, 2, &mut rng);
    let a = MatI8::random_binary(1, 16, &mut rng);
    let mut scratch = GemmScratch::new();
    let bnn = GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&b)).expect("plan");
    let mut fout = GemmOut::new_f32();
    assert_eq!(
        bnn.run(Lhs::I8(&a), &mut fout, &mut scratch),
        Err(GemmError::OutputMismatch { kind: Kind::Bnn, expected: "i32", got: "f32" })
    );
    let dabnn = GemmPlan::new(GemmConfig::native(Kind::DaBnn), Weights::I8(&b)).expect("plan");
    let mut iout = GemmOut::new_i32();
    assert_eq!(
        dabnn.run(Lhs::I8(&a), &mut iout, &mut scratch),
        Err(GemmError::OutputMismatch { kind: Kind::DaBnn, expected: "f32", got: "i32" })
    );
}

/// The emulated backend rejects out-of-domain LHS values with a typed
/// error (its microkernel drivers would otherwise assert).
#[test]
fn emulated_lhs_domain_is_typed() {
    let mut rng = Rng::new(0xE43);
    let b = MatI8::random_binary(16, 2, &mut rng);
    let plan = GemmPlan::new(GemmConfig::emulated(Kind::Bnn), Weights::I8(&b)).expect("plan");
    let a_ternary = MatI8::zeros(2, 16); // zeros are not ±1
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    assert_eq!(
        plan.run(Lhs::I8(&a_ternary), &mut out, &mut scratch),
        Err(GemmError::LhsDomain { kind: Kind::Bnn, expected: "±1" })
    );
}

// ---- plan reuse: pack once, run many -----------------------------------

/// Pack once / run many times across "batches": after a warm-up run, no
/// buffer in the shared scratch arena or the caller-owned output may
/// reallocate, for every kind on the native backend, and every run must
/// match the reference backend.
#[test]
fn plan_reuse_is_zero_alloc_at_steady_state() {
    let mut rng = Rng::new(0xE44);
    let (m, n, k) = (13, 9, 200);
    let mut scratch = GemmScratch::new();
    for kind in Kind::ALL {
        // Weights + reference plan.
        let b_i8_bin = MatI8::random_binary(k, n, &mut rng);
        let b_i8_ter = MatI8::random_ternary(k, n, &mut rng);
        let b_u8 = MatU8::random_below(k, n, 15, &mut rng);
        let b_f32 = MatF32::random(k, n, &mut rng);
        let weights = match kind {
            Kind::Bnn | Kind::Tbn | Kind::DaBnn => Weights::I8(&b_i8_bin),
            Kind::Tnn => Weights::I8(&b_i8_ter),
            Kind::U8 | Kind::U4 => Weights::U8 { b: &b_u8, za: 3, zb: 5 },
            Kind::F32 => Weights::F32(&b_f32),
        };
        let plan = GemmPlan::new(GemmConfig::native(kind), weights).expect("plan");
        let reference = GemmPlan::new(GemmConfig::reference(kind), weights).expect("ref plan");
        let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
        let mut want = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };

        // Warm-up batch, then record every arena pointer.
        let batches: Vec<(Option<MatI8>, Option<MatU8>, Option<MatF32>)> = (0..4)
            .map(|_| match kind {
                Kind::Bnn | Kind::DaBnn => (Some(MatI8::random_binary(m, k, &mut rng)), None, None),
                Kind::Tnn | Kind::Tbn => (Some(MatI8::random_ternary(m, k, &mut rng)), None, None),
                Kind::U8 | Kind::U4 => (None, Some(MatU8::random_below(m, k, 15, &mut rng)), None),
                Kind::F32 => (None, None, Some(MatF32::random(m, k, &mut rng))),
            })
            .collect();
        fn as_lhs(batch: &(Option<MatI8>, Option<MatU8>, Option<MatF32>)) -> Lhs<'_> {
            match batch {
                (Some(a), _, _) => Lhs::I8(a),
                (_, Some(a), _) => Lhs::U8(a),
                (_, _, Some(a)) => Lhs::F32(a),
                _ => unreachable!("one LHS variant is always set"),
            }
        }
        plan.run(as_lhs(&batches[0]), &mut out, &mut scratch).expect("warm-up run");
        let bits_ptr = scratch.bits.data.as_ptr();
        let planes_ptr = scratch.planes.plus.as_ptr();
        let out_ptr = match &out {
            GemmOut::I32(c) => c.data.as_ptr() as usize,
            GemmOut::F32(c) => c.data.as_ptr() as usize,
        };

        for (i, batch) in batches.iter().enumerate() {
            plan.run(as_lhs(batch), &mut out, &mut scratch).expect("steady-state run");
            reference.run(as_lhs(batch), &mut want, &mut scratch).expect("reference run");
            // Results match the reference backend (f32 kinds at this
            // depth: daBNN exact; F32 tolerance below).
            match (&out, &want) {
                (GemmOut::I32(c), GemmOut::I32(w)) => assert_eq!(c.data, w.data, "{kind:?} batch {i}"),
                (GemmOut::F32(c), GemmOut::F32(w)) => {
                    for (x, y) in c.data.iter().zip(&w.data) {
                        assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{kind:?} batch {i}: {x} vs {y}");
                    }
                }
                _ => panic!("output variants diverged"),
            }
            // Pointer stability: no per-call heap allocation.
            assert_eq!(scratch.bits.data.as_ptr(), bits_ptr, "{kind:?}: bits arena reallocated");
            assert_eq!(scratch.planes.plus.as_ptr(), planes_ptr, "{kind:?}: plane arena reallocated");
            let now = match &out {
                GemmOut::I32(c) => c.data.as_ptr() as usize,
                GemmOut::F32(c) => c.data.as_ptr() as usize,
            };
            assert_eq!(now, out_ptr, "{kind:?}: output buffer reallocated");
        }
    }
}

// ---- the widened tiles (BNN 4×4, TNN 2×4) ------------------------------

/// `Tile::Wide` is bit-identical to `Tile::Auto` (and the oracle) on
/// column counts exercising every 4/2/1-column remainder, with and
/// without threading, for both widened kinds (BNN 4×4, TNN 2×4), and
/// falls back to the spill kernel on deep K.
#[test]
fn wide_tile_matches_auto_and_oracle() {
    let mut rng = Rng::new(0xE45);
    for kind in [Kind::Bnn, Kind::Tnn] {
        for &(m, n, k) in &[
            (4usize, 4usize, 64usize),
            (5, 1, 65),
            (6, 2, 127),
            (7, 3, 128),
            (9, 5, 130),
            (11, 6, 191),
            (13, 7, 257),
            (3, 9, 64),
        ] {
            let (a, b) = match kind {
                Kind::Tnn => (MatI8::random_ternary(m, k, &mut rng), MatI8::random_ternary(k, n, &mut rng)),
                _ => (MatI8::random_binary(m, k, &mut rng), MatI8::random_binary(k, n, &mut rng)),
            };
            let want = reference::gemm_i8(&a, &b);
            for th in [Threading::Single, Threading::Fixed(3)] {
                for tile in [Tile::Auto, Tile::Wide] {
                    let plan = GemmPlan::new(
                        GemmConfig::native(kind).with_threading(th).with_tile(tile),
                        Weights::I8(&b),
                    )
                    .expect("plan");
                    let mut out = GemmOut::new_i32();
                    let mut scratch = GemmScratch::new();
                    plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("run");
                    assert_eq!(
                        out.as_i32().expect("i32 out").data,
                        want.data,
                        "{kind:?} m={m} n={n} k={k} th={th:?} tile={tile:?}"
                    );
                }
            }
        }
    }
    // Deep K (> 32767): Wide falls back to the K-paneled spill kernels
    // and stays exact (all-ones inputs: every output equals K exactly).
    let k = 32_768;
    for kind in [Kind::Bnn, Kind::Tnn] {
        let a = MatI8::from_fn(2, k, |_, _| 1);
        let b = MatI8::from_fn(k, 5, |_, _| 1);
        let plan = GemmPlan::new(GemmConfig::native(kind).with_tile(Tile::Wide), Weights::I8(&b))
            .expect("plan");
        let mut out = GemmOut::new_i32();
        let mut scratch = GemmScratch::new();
        plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("run");
        assert!(out.as_i32().expect("i32 out").data.iter().all(|&v| v == k as i32), "{kind:?}");
    }
}

/// `Tile::Rowdot` (the seed baseline) agrees with the tiled default
/// through the same plan API, for all three low-bit kinds.
#[test]
fn rowdot_tile_matches_auto() {
    let mut rng = Rng::new(0xE46);
    let (m, n, k) = (9, 7, 130);
    let cases = [
        (Kind::Bnn, MatI8::random_binary(m, k, &mut rng), MatI8::random_binary(k, n, &mut rng)),
        (Kind::Tnn, MatI8::random_ternary(m, k, &mut rng), MatI8::random_ternary(k, n, &mut rng)),
        (Kind::Tbn, MatI8::random_ternary(m, k, &mut rng), MatI8::random_binary(k, n, &mut rng)),
    ];
    for (kind, a, b) in &cases {
        let mut results = Vec::new();
        for tile in [Tile::Auto, Tile::Rowdot] {
            let plan = GemmPlan::new(GemmConfig::native(*kind).with_tile(tile), Weights::I8(b))
                .expect("plan");
            let mut out = GemmOut::new_i32();
            let mut scratch = GemmScratch::new();
            plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("run");
            results.push(out.into_i32().expect("i32 out").data);
        }
        assert_eq!(results[0], results[1], "{kind:?}");
        assert_eq!(results[0], reference::gemm_i8(a, b).data, "{kind:?} vs oracle");
    }
}
