//! Pinned Table II instruction counts for the emulated microkernels —
//! promoted from the old `table2_counts` bench (now `benches/table2.rs`,
//! which only renders the table) so kernel refactors can't silently
//! drift the cost model: this runs on every `cargo test`, on x86 and on
//! the aarch64/QEMU CI lane alike.
//!
//! Two layers of pins:
//!
//! * per-iteration **class totals** (COM/LD/MOV) against the paper's
//!   Table II where our reconstruction matches it exactly (BNN, F32),
//!   in total (TNN), or with a documented divergence (TBN: 147 vs the
//!   paper's 155 — our BIC selector saves one arrangement op per
//!   column, see `gemm/micro/tbn.rs`),
//! * per-iteration **per-family counts** (AND/ORR/EOR/CNT + the 16-bit
//!   accumulation family) pinned exactly, so even a COM-neutral swap of
//!   one instruction for another is caught.

use std::collections::BTreeMap;
use tbgemm::costmodel::table2::{paper_reference, steady_state_trace};
use tbgemm::gemm::Kind;
use tbgemm::simd::trace::Trace;

fn pinned(trace: &Trace, want: &[(&str, u64)]) {
    let got = trace.families();
    let want: BTreeMap<&str, u64> = want.iter().copied().collect();
    assert_eq!(got, want);
}

/// BNN (Fig. 1): per iteration 8×(EOR + CNT) product/count pairs, 16
/// widening adds (SADDW + SADDW2), 2 loads, 8 DUP broadcasts — COM=32,
/// LD=2, MOV=8, exactly the paper's row. No AND/ORR/PADAL anywhere.
#[test]
fn bnn_counts_match_paper_exactly() {
    let t = steady_state_trace(Kind::Bnn);
    assert_eq!((t.com, t.ld, t.mov), (32, 2, 8));
    assert_eq!(paper_reference(Kind::Bnn), (32, 2, 8, 0.041));
    pinned(&t, &[("LD1", 2), ("DUP", 8), ("EOR", 8), ("CNT", 8), ("SADDW", 16)]);
    assert!((t.ins_metric(16, 8, 8) - 42.0 / 1024.0).abs() < 1e-9);
}

/// TNN (Fig. 2): per iteration 32 AND plane products, 32 CNT, 32
/// count-difference widenings (SSUBL + SSUBL2), 32 16-bit adds, 3 loads,
/// 32 arrangement ops (DUP + EXT). Total 163 = the paper's 96+3+64; the
/// COM/MOV split differs from the paper's assembly (documented in
/// `gemm/micro/tnn.rs`), the total and INS match exactly.
#[test]
fn tnn_counts_match_paper_total() {
    let t = steady_state_trace(Kind::Tnn);
    assert_eq!((t.com, t.ld, t.mov), (128, 3, 32));
    let paper = paper_reference(Kind::Tnn);
    assert_eq!(t.total(), paper.0 + paper.1 + paper.2);
    pinned(&t, &[("LD1", 3), ("DUP", 16), ("EXT", 16), ("AND", 32), ("CNT", 32), ("SSUBL", 32), ("ADD", 32)]);
    assert!((t.ins_metric(16, 8, 8) - 163.0 / 1024.0).abs() < 1e-9);
}

/// TBN (Fig. 3): per iteration 8 selector EORs, 16 AND + 16 BIC plane
/// products, 32 CNT, 32 count-difference widenings (SSUBL + SSUBL2),
/// 32 adds, 3 loads, 8 DUPs — total 147, below the paper's 155 (our BIC
/// form needs one fewer arrangement op per column). The paper's
/// orderings must still hold: BNN < TBN < TNN in per-iteration
/// instructions.
#[test]
fn tbn_counts_are_pinned_and_ordered() {
    let t = steady_state_trace(Kind::Tbn);
    assert_eq!((t.com, t.ld, t.mov), (136, 3, 8));
    assert_eq!(t.total(), 147);
    pinned(
        &t,
        &[("LD1", 3), ("DUP", 8), ("EOR", 8), ("AND", 16), ("BIC", 16), ("CNT", 32), ("SSUBL", 32), ("ADD", 32)],
    );
    let bnn = steady_state_trace(Kind::Bnn).total();
    let tnn = steady_state_trace(Kind::Tnn).total();
    assert!(bnn < t.total() && t.total() < tnn);
}

/// F32 stays the exact-match baseline row (24 FMLA-class COM, 5 loads,
/// no arrangement), anchoring the INS denominators the low-bit rows are
/// compared against.
#[test]
fn f32_counts_match_paper_exactly() {
    let t = steady_state_trace(Kind::F32);
    assert_eq!((t.com, t.ld, t.mov), (24, 5, 0));
    assert_eq!(paper_reference(Kind::F32), (24, 5, 0, 0.302));
}

/// The ORR family never appears in any emulated low-bit stream (the
/// kernels realize eq. (7) via the count-difference trick) — pinned so a
/// future refactor that introduces OR-based products shows up here and
/// updates `simd_popcnt::isa` + `tests/isa_parity.rs` deliberately.
#[test]
fn no_orr_in_emulated_low_bit_streams() {
    for kind in [Kind::Bnn, Kind::Tnn, Kind::Tbn] {
        let f = steady_state_trace(kind).families();
        assert!(!f.contains_key("ORR"), "{kind:?} traced an ORR");
    }
}
