//! Edge-case tests for the batching coordinator and the replica pool:
//! degenerate batch sizes, shutdown with an empty or partially drained
//! queue, dropped reply channels, replica-count invariance of the served
//! logits, graceful (typed, non-panicking) submission to a server whose
//! worker has died — and the overload contract, driven past saturation
//! on purpose with a slow-engine (injected-delay) fixture: `Overloaded`
//! rejection at a full queue, `DeadlineExceeded` for stale requests,
//! priority-lane ordering under pressure, policy-driven shedding,
//! bounded-drain shutdown, and bit-identical logits for every *accepted*
//! request while shedding.

use std::time::{Duration, Instant};
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{
    BatcherConfig, DelayEngine, InferenceEngine, InferenceServer, NativeEngine, Response,
    ServerConfig, ShedPolicy, SubmitError, SubmitOptions,
};
use tbgemm::gemm::{
    reference, GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Threading, Weights,
};
use tbgemm::nn::{plan_from_config, NetConfig, NetPlanConfig};
use tbgemm::util::mat::MatI8;
use tbgemm::util::Rng;

fn server(max_batch: usize, threading: Threading, replicas: usize) -> InferenceServer {
    let plan = plan_from_config(
        &NetConfig::tiny_tnn(8, 8, 1, 3),
        21,
        NetPlanConfig::default().with_threading(threading),
    )
    .expect("plan");
    let engine = Box::new(NativeEngine::new(plan, "edge"));
    InferenceServer::with_config(
        engine,
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
            .with_replicas(replicas)
            .with_depths(64, 64),
    )
}

/// The overload fixture: the tiny TNN plan wrapped in a [`DelayEngine`]
/// so service time is dominated by a deterministic injected delay —
/// saturation can then be driven with tiny request counts.
fn slow_server(per_image: Duration, cfg: ServerConfig) -> InferenceServer {
    let plan = plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 21, NetPlanConfig::default())
        .expect("plan");
    let engine = DelayEngine::new(Box::new(NativeEngine::new(plan, "slow")), per_image);
    InferenceServer::with_config(Box::new(engine), cfg)
}

/// `max_batch = 1` degenerates to strict one-request batches: every
/// response reports batch_size 1 and every request is answered.
#[test]
fn max_batch_one_serves_singletons() {
    let srv = server(1, Threading::Single, 1);
    let mut rng = Rng::new(31);
    let pending: Vec<_> =
        (0..12).map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up")).collect();
    for rx in pending {
        let resp = rx.recv().expect("response").completed().expect("served");
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.logits.len(), 3);
    }
    let m = srv.shutdown();
    assert_eq!(m.requests, 12);
    assert!((m.mean_batch_size - 1.0).abs() < 1e-9);
}

/// Shutting down a server whose queue never saw a request exits cleanly
/// (the worker is blocked on the empty queue's condvar at that moment).
#[test]
fn shutdown_on_empty_channel_is_clean() {
    let srv = server(4, Threading::Single, 2);
    let m = srv.shutdown();
    assert_eq!(m.requests, 0);
    assert_eq!(m.batches, 0);
}

/// Shutdown races a filling batch: requests submitted immediately before
/// shutdown are all drained and answered across the replica pool, none
/// dropped — the queue close lands mid-batch-collection.
#[test]
fn shutdown_mid_batch_drains_pending_requests() {
    for replicas in [1usize, 4] {
        for n in [1usize, 3, 7] {
            let srv = server(8, Threading::Single, replicas);
            let mut rng = Rng::new(32);
            let pending: Vec<_> = (0..n)
                .map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up"))
                .collect();
            let m = srv.shutdown(); // joins the worker: everything drains first
            assert_eq!(m.requests, n as u64, "replicas={replicas} n={n}");
            assert_eq!(m.replica_requests.iter().sum::<u64>(), n as u64, "replicas={replicas} n={n}");
            for rx in pending {
                let resp = rx.recv().expect("drained response").completed().expect("served");
                assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                assert_eq!(resp.logits.len(), 3);
            }
        }
    }
}

/// A caller abandoning its reply channel must not wedge the worker or
/// affect other requests in the same batch.
#[test]
fn dropped_reply_receiver_does_not_stall_worker() {
    let srv = server(4, Threading::Single, 2);
    let mut rng = Rng::new(33);
    drop(srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up")); // abandoned
    let resp = srv.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    assert_eq!(resp.completed().expect("served").logits.len(), 3);
    let m = srv.shutdown();
    assert_eq!(m.requests, 2);
}

/// NativeEngine results are identical across `--threads 1` and `auto`:
/// the row-band threading (and the scratch reuse behind it) never changes
/// logits bit-for-bit.
#[test]
fn engine_logits_identical_across_thread_counts() {
    let mut rng = Rng::new(34);
    let images: Vec<_> = (0..6).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let single = server(4, Threading::Fixed(1), 1);
    let auto = server(4, Threading::Auto, 1);
    for img in &images {
        let a = single.infer(img.clone()).expect("server up").completed().expect("served");
        let b = auto.infer(img.clone()).expect("server up").completed().expect("served");
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.predicted, b.predicted);
    }
    single.shutdown();
    auto.shutdown();
}

/// The replica-pool acceptance test: serving the same request stream
/// with `replicas = 1` and `replicas = 4` yields bit-identical logits
/// per request id, and the pool's metrics account for every request.
#[test]
fn replica_pool_logits_bit_identical_to_single() {
    let mut rng = Rng::new(35);
    let images: Vec<_> = (0..24).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let mut per_count: Vec<Vec<Vec<f32>>> = Vec::new();
    for replicas in [1usize, 4] {
        let srv = server(8, Threading::Single, replicas);
        let pending: Vec<_> =
            images.iter().map(|img| srv.submit(img.clone()).expect("server up")).collect();
        let mut responses: Vec<_> = pending
            .into_iter()
            .map(|rx| rx.recv().expect("response").completed().expect("served"))
            .collect();
        responses.sort_by_key(|r| r.id);
        per_count.push(responses.into_iter().map(|r| r.logits).collect());
        let m = srv.shutdown();
        assert_eq!(m.requests, images.len() as u64);
        assert_eq!(m.replica_requests.len(), replicas);
        assert_eq!(m.replica_requests.iter().sum::<u64>(), images.len() as u64);
    }
    assert_eq!(per_count[0], per_count[1], "replicas=4 logits differ from replicas=1");
}

/// An engine that dies mid-serve must not take the caller down:
/// `submit` / `infer` return `SubmitError::Closed` (typed, no panic)
/// once the worker is gone, and `shutdown` still joins cleanly.
#[test]
fn dead_worker_surfaces_as_closed() {
    struct PanickingEngine;
    impl InferenceEngine for PanickingEngine {
        fn infer_batch(&mut self, _images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
            panic!("engine crashed (test)");
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (8, 8, 1)
        }
        fn name(&self) -> String {
            "panicking".into()
        }
        fn replicate(&self) -> Box<dyn InferenceEngine> {
            Box::new(PanickingEngine)
        }
    }

    let srv = InferenceServer::with_config(
        Box::new(PanickingEngine),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) }),
    );
    let mut rng = Rng::new(36);
    // The first request kills the worker; its reply channel is dropped
    // during the unwind, so `infer` reports Closed rather than hanging
    // or panicking.
    assert_eq!(srv.infer(Tensor3::random(8, 8, 1, &mut rng)), Err(SubmitError::Closed));
    // The worker's exit guard closes the queue; within a bounded number
    // of attempts `submit` itself returns Closed.
    let mut saw_closed = false;
    for _ in 0..100 {
        match srv.submit(Tensor3::random(8, 8, 1, &mut rng)) {
            Err(SubmitError::Closed) => {
                saw_closed = true;
                break;
            }
            Err(SubmitError::Overloaded { .. }) | Ok(_) => {
                std::thread::sleep(Duration::from_millis(2))
            }
        }
    }
    assert!(saw_closed, "submit never reported Closed after worker death");
    srv.shutdown(); // joins the panicked worker without propagating
}

/// The acceptance-criteria test: a burst at far above capacity. `submit`
/// never blocks, no worker panics, admission rejects the overflow with
/// typed `Overloaded`, every *accepted* interactive request completes
/// within the latency the bounded queue implies (well under the
/// configured budget), and the snapshot accounts for accepted + rejected
/// exactly.
#[test]
fn overload_rejects_and_bounds_accepted_latency() {
    let budget = Duration::from_millis(100);
    let srv = slow_server(
        Duration::from_millis(4),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_depths(8, 8)
            .with_latency_budget(budget),
    );
    let mut rng = Rng::new(40);
    let burst = 64usize;
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..burst {
        match srv.submit(Tensor3::random(8, 8, 1, &mut rng)) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Overloaded { .. }) => rejected += 1,
            Err(SubmitError::Closed) => panic!("worker must survive overload"),
        }
    }
    let submit_elapsed = t0.elapsed();
    assert!(
        submit_elapsed < Duration::from_millis(500),
        "submit must not block under overload (burst took {submit_elapsed:?})"
    );
    assert!(rejected > 0, "a 64-burst into a depth-8 queue at 4 ms/request must shed");
    assert!(!pending.is_empty(), "some requests must be admitted");
    let mut max_latency = Duration::ZERO;
    for rx in pending {
        let c = rx.recv().expect("accepted requests are answered").completed().expect("served");
        max_latency = max_latency.max(Duration::from_micros(c.latency_us));
    }
    assert!(
        max_latency < budget,
        "accepted-request p99 (max {max_latency:?}) must stay within the {budget:?} budget"
    );
    let m = srv.shutdown();
    assert_eq!(m.rejected, rejected, "snapshot must report every admission rejection");
    assert_eq!(m.requests + m.rejected, burst as u64);
    assert_eq!(m.expired, 0);
}

/// Once the service-rate estimate is warm, the latency budget rejects at
/// *admission* — with the measured estimated wait in the error — not
/// after the request has already queued past its SLO.
#[test]
fn latency_budget_admission_rejects_when_estimate_exceeds_it() {
    let srv = slow_server(
        Duration::from_millis(10),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_latency_budget(Duration::from_millis(25)),
    );
    let mut rng = Rng::new(41);
    // Warm the estimator: one served request measures ~10 ms.
    srv.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up").completed().expect("served");
    let m = srv.metrics();
    assert!(m.service_estimate_us >= 10_000, "estimate {} µs too low", m.service_estimate_us);
    // Rapid-fire: the queue builds, the estimated wait crosses 25 ms
    // after ~2 queued requests, and admission starts rejecting.
    let mut overloaded = None;
    let mut pending = Vec::new();
    for _ in 0..16 {
        match srv.submit(Tensor3::random(8, 8, 1, &mut rng)) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Overloaded { estimated_wait_us, queued }) => {
                overloaded = Some((estimated_wait_us, queued));
                break;
            }
            Err(SubmitError::Closed) => panic!("worker must survive"),
        }
    }
    let (est, queued) = overloaded.expect("budget must reject before 16 × 10 ms queue up");
    assert!(est > 25_000, "rejection must carry the over-budget estimate (got {est} µs)");
    assert!(queued >= 1);
    srv.shutdown();
}

/// A request whose deadline passes while it waits behind a slow batch is
/// answered `DeadlineExceeded` at dequeue — the engine never runs it.
#[test]
fn stale_requests_are_dropped_at_dequeue() {
    let srv = slow_server(
        Duration::from_millis(40),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) }),
    );
    let mut rng = Rng::new(42);
    let blocker = srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    std::thread::sleep(Duration::from_millis(2)); // worker is now mid-blocker
    let doomed = srv
        .submit_with(
            Tensor3::random(8, 8, 1, &mut rng),
            SubmitOptions::default().deadline_in(Duration::from_millis(5)),
        )
        .expect("cold estimate admits; expiry happens in-queue");
    match doomed.recv().expect("expired request still gets an answer") {
        Response::DeadlineExceeded { waited_us, .. } => {
            assert!(waited_us >= 5_000, "waited {waited_us} µs < its 5 ms deadline")
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    blocker.recv().expect("blocker served").completed().expect("served");
    let m = srv.shutdown();
    assert_eq!(m.expired, 1);
    assert_eq!(m.requests, 1, "the engine must never run the expired request");
}

/// Under pressure the interactive lane is served strictly before queued
/// batch-lane work, even when the batch-lane requests arrived first.
#[test]
fn interactive_lane_is_served_before_batch_lane() {
    let srv = slow_server(
        Duration::from_millis(10),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) }),
    );
    let mut rng = Rng::new(43);
    let blocker = srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    std::thread::sleep(Duration::from_millis(3)); // blocker is in-flight
    let batch_rx: Vec<_> = (0..4)
        .map(|_| {
            srv.submit_with(Tensor3::random(8, 8, 1, &mut rng), SubmitOptions::batch())
                .expect("server up")
        })
        .collect();
    let inter_rx: Vec<_> = (0..4)
        .map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up"))
        .collect();
    blocker.recv().expect("served").completed().expect("served");
    // All interactive completions strictly precede all batch-lane
    // completions; with near-identical submit times that ordering is
    // visible as latency: every batch-lane latency exceeds every
    // interactive latency.
    let max_inter = inter_rx
        .into_iter()
        .map(|rx| rx.recv().expect("served").completed().expect("served").latency_us)
        .max()
        .unwrap();
    let min_batch = batch_rx
        .into_iter()
        .map(|rx| rx.recv().expect("served").completed().expect("served").latency_us)
        .min()
        .unwrap();
    assert!(
        min_batch > max_inter,
        "batch lane (min {min_batch} µs) must wait behind interactive (max {max_inter} µs)"
    );
    let m = srv.shutdown();
    assert_eq!(m.lane_requests, [5, 4]);
}

/// `EvictOldestBatch`: a full batch lane admits new batch work by
/// shedding its oldest queued entry, which is answered `Shed`.
#[test]
fn evict_oldest_batch_policy_sheds_queued_batch_work() {
    let srv = slow_server(
        Duration::from_millis(30),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) })
            .with_depths(8, 2)
            .with_shed_policy(ShedPolicy::EvictOldestBatch),
    );
    let mut rng = Rng::new(44);
    let blocker = srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    std::thread::sleep(Duration::from_millis(2)); // blocker in-flight, queue empty
    let rxs: Vec<_> = (0..3)
        .map(|_| {
            srv.submit_with(Tensor3::random(8, 8, 1, &mut rng), SubmitOptions::batch())
                .expect("eviction admits the newcomer")
        })
        .collect();
    let mut outcomes: Vec<_> = rxs.into_iter().map(|rx| rx.recv().expect("answered")).collect();
    match outcomes.remove(0) {
        Response::Shed { .. } => {}
        other => panic!("oldest queued batch request must be evicted, got {other:?}"),
    }
    for o in outcomes {
        o.completed().expect("the two admitted batch requests are served");
    }
    blocker.recv().expect("served").completed().expect("served");
    let m = srv.shutdown();
    assert_eq!(m.shed, 1);
    assert_eq!(m.requests, 3);
}

/// Bounded-drain shutdown: in-flight work is flushed, the backlog past
/// the drain deadline is shed with an answer — shutdown cannot hang
/// behind a deep queue.
#[test]
fn shutdown_within_serves_inflight_and_sheds_backlog() {
    let srv = slow_server(
        Duration::from_millis(30),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) }),
    );
    let mut rng = Rng::new(45);
    let blocker = srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    std::thread::sleep(Duration::from_millis(2)); // blocker dequeued, in-flight
    let backlog: Vec<_> = (0..5)
        .map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up"))
        .collect();
    let t0 = Instant::now();
    let m = srv.shutdown_within(Duration::from_millis(1));
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "bounded drain must not serve the whole 5 × 30 ms backlog"
    );
    blocker.recv().expect("in-flight work is flushed").completed().expect("served");
    for rx in backlog {
        match rx.recv().expect("backlog still gets answers") {
            Response::Shed { .. } => {}
            other => panic!("expected Shed, got {other:?}"),
        }
    }
    assert_eq!(m.requests, 1);
    assert_eq!(m.shed, 5);
}

/// Shedding never corrupts what *is* served: at 1 and at 4 replicas,
/// every accepted request's logits are bit-identical to a direct local
/// plan run of the same image, even while the queue is rejecting a
/// large fraction of the burst.
#[test]
fn accepted_logits_bit_identical_under_shedding() {
    let plan = plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 21, NetPlanConfig::default())
        .expect("plan");
    let mut scratch = plan.make_scratch();
    let mut out = tbgemm::nn::NetOut::new();
    let mut rng = Rng::new(46);
    let images: Vec<_> = (0..32).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let want: Vec<Vec<f32>> = images
        .iter()
        .map(|img| {
            plan.run(img, &mut out, &mut scratch).expect("run");
            out.logits.clone()
        })
        .collect();
    for replicas in [1usize, 4] {
        let srv = slow_server(
            Duration::from_millis(2),
            ServerConfig::default()
                .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) })
                .with_replicas(replicas)
                .with_depths(4, 4),
        );
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for (i, img) in images.iter().enumerate() {
            match srv.submit(img.clone()) {
                Ok(rx) => accepted.push((i, rx)),
                Err(SubmitError::Overloaded { .. }) => rejected += 1,
                Err(SubmitError::Closed) => panic!("worker must survive overload"),
            }
        }
        assert!(rejected > 0, "replicas={replicas}: a 32-burst into depth 4 must shed");
        for (i, rx) in accepted {
            let c = rx.recv().expect("answered").completed().expect("accepted requests are served");
            assert_eq!(c.logits, want[i], "replicas={replicas} image {i}: served logits differ");
        }
        let m = srv.shutdown();
        assert_eq!(m.requests + m.rejected, images.len() as u64, "replicas={replicas}");
    }
}

/// The deprecated positional-args constructor still serves (one release
/// of migration room for external callers).
#[test]
#[allow(deprecated)]
fn legacy_start_signature_still_serves() {
    let plan = plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 21, NetPlanConfig::default())
        .expect("plan");
    let srv = InferenceServer::start(
        Box::new(NativeEngine::new(plan, "legacy")),
        BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        64,
        2,
    );
    let mut rng = Rng::new(47);
    let resp = srv.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    assert_eq!(resp.completed().expect("served").logits.len(), 3);
    let m = srv.shutdown();
    assert_eq!(m.requests, 1);
}

/// Worker-pool contention stress (satellite of the pool PR): the server
/// executes replica-chunked, row-band-threaded batches through the one
/// process-wide pool **while** foreground threads run their own
/// multithreaded `GemmPlan`s through the same pool. Both sides must stay
/// bit-identical to their single-threaded references — contention for
/// the shared workers can reorder scheduling but never results — and
/// nothing may deadlock even when replica-chunk tasks fan nested GEMM
/// band tasks into the already-busy pool.
#[test]
fn server_and_gemm_plans_share_the_pool_bit_identically() {
    // Single-threaded reference logits from a local plan run.
    let plan = plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 21, NetPlanConfig::default())
        .expect("plan");
    let mut scratch = plan.make_scratch();
    let mut out = tbgemm::nn::NetOut::new();
    let mut rng = Rng::new(48);
    let images: Vec<_> = (0..24).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let want_logits: Vec<Vec<f32>> = images
        .iter()
        .map(|img| {
            plan.run(img, &mut out, &mut scratch).expect("run");
            out.logits.clone()
        })
        .collect();
    // Single-threaded reference for the contending raw GEMM.
    let (m, n, k) = (33usize, 19usize, 257usize);
    let at = MatI8::random_ternary(m, k, &mut rng);
    let bt = MatI8::random_ternary(k, n, &mut rng);
    let want_gemm = reference::gemm_i8(&at, &bt);
    let gemm_plan = GemmPlan::new(
        GemmConfig::native(Kind::Tnn).with_threading(Threading::Fixed(4)),
        Weights::I8(&bt),
    )
    .expect("plan");

    // 4 replicas + per-GEMM Fixed(2): chunk tasks and nested band tasks
    // both land in the pool, concurrently with the foreground plans.
    let srv = server(8, Threading::Fixed(2), 4);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let (gemm_plan, want_gemm, at) = (&gemm_plan, &want_gemm, &at);
            s.spawn(move || {
                let mut out = GemmOut::new_i32();
                let mut scratch = GemmScratch::new();
                for rep in 0..24 {
                    gemm_plan.run(Lhs::I8(at), &mut out, &mut scratch).expect("plan run");
                    assert_eq!(
                        out.as_i32().expect("i32 out").data,
                        want_gemm.data,
                        "rep={rep}: contended GEMM diverged"
                    );
                }
            });
        }
        for round in 0..4 {
            let pending: Vec<_> =
                images.iter().map(|img| srv.submit(img.clone()).expect("server up")).collect();
            for (i, rx) in pending.into_iter().enumerate() {
                let c = rx.recv().expect("response").completed().expect("served");
                assert_eq!(
                    c.logits, want_logits[i],
                    "round={round} image {i}: served logits diverged under pool contention"
                );
            }
        }
    });
    let m = srv.shutdown();
    assert_eq!(m.requests, 4 * images.len() as u64);
}
