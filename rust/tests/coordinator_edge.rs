//! Edge-case tests for the batching coordinator and the native engine:
//! degenerate batch sizes, shutdown with an empty or partially drained
//! queue, dropped reply channels, and thread-count invariance of the
//! engine's results.

use std::time::Duration;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine};
use tbgemm::gemm::native::Threading;
use tbgemm::nn::{build_from_config, NetConfig};
use tbgemm::util::Rng;

fn server(max_batch: usize, threading: Threading) -> InferenceServer {
    let net = build_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 21);
    let engine = Box::new(NativeEngine::new(net, "edge").with_threading(threading));
    InferenceServer::start(engine, BatcherConfig { max_batch, max_wait: Duration::from_millis(1) }, 64)
}

/// `max_batch = 1` degenerates to strict one-request batches: every
/// response reports batch_size 1 and every request is answered.
#[test]
fn max_batch_one_serves_singletons() {
    let srv = server(1, Threading::Single);
    let mut rng = Rng::new(31);
    let pending: Vec<_> = (0..12).map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng))).collect();
    for rx in pending {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.logits.len(), 3);
    }
    let m = srv.shutdown();
    assert_eq!(m.requests, 12);
    assert!((m.mean_batch_size - 1.0).abs() < 1e-9);
}

/// Shutting down a server whose channel never saw a request exits
/// cleanly (the worker is blocked on the empty channel at that moment).
#[test]
fn shutdown_on_empty_channel_is_clean() {
    let srv = server(4, Threading::Single);
    let m = srv.shutdown();
    assert_eq!(m.requests, 0);
    assert_eq!(m.batches, 0);
}

/// Shutdown races a filling batch: requests submitted immediately before
/// shutdown are all drained and answered, none dropped — the batcher's
/// channel close lands mid-batch-collection.
#[test]
fn shutdown_mid_batch_drains_pending_requests() {
    for n in [1usize, 3, 7] {
        let srv = server(8, Threading::Single);
        let mut rng = Rng::new(32);
        let pending: Vec<_> = (0..n).map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng))).collect();
        let m = srv.shutdown(); // joins the worker: everything drains first
        assert_eq!(m.requests, n as u64, "n={n}");
        for rx in pending {
            let resp = rx.recv().expect("drained response");
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
    }
}

/// A caller abandoning its reply channel must not wedge the worker or
/// affect other requests in the same batch.
#[test]
fn dropped_reply_receiver_does_not_stall_worker() {
    let srv = server(4, Threading::Single);
    let mut rng = Rng::new(33);
    drop(srv.submit(Tensor3::random(8, 8, 1, &mut rng))); // abandoned
    let resp = srv.infer(Tensor3::random(8, 8, 1, &mut rng));
    assert_eq!(resp.logits.len(), 3);
    let m = srv.shutdown();
    assert_eq!(m.requests, 2);
}

/// NativeEngine results are identical across `--threads 1` and `auto`:
/// the row-band threading (and the scratch reuse behind it) never changes
/// logits bit-for-bit.
#[test]
fn engine_logits_identical_across_thread_counts() {
    let mut rng = Rng::new(34);
    let images: Vec<_> = (0..6).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let single = server(4, Threading::Fixed(1));
    let auto = server(4, Threading::Auto);
    for img in &images {
        let a = single.infer(img.clone());
        let b = auto.infer(img.clone());
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.predicted, b.predicted);
    }
    single.shutdown();
    auto.shutdown();
}
