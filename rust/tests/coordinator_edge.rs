//! Edge-case tests for the batching coordinator and the replica pool:
//! degenerate batch sizes, shutdown with an empty or partially drained
//! queue, dropped reply channels, replica-count invariance of the served
//! logits, and graceful (typed, non-panicking) submission to a server
//! whose worker has died.

use std::time::Duration;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{
    BatcherConfig, InferenceEngine, InferenceServer, NativeEngine, ServerClosed,
};
use tbgemm::gemm::Threading;
use tbgemm::nn::{plan_from_config, NetConfig, NetPlanConfig};
use tbgemm::util::Rng;

fn server(max_batch: usize, threading: Threading, replicas: usize) -> InferenceServer {
    let plan = plan_from_config(
        &NetConfig::tiny_tnn(8, 8, 1, 3),
        21,
        NetPlanConfig::default().with_threading(threading),
    )
    .expect("plan");
    let engine = Box::new(NativeEngine::new(plan, "edge"));
    InferenceServer::start(
        engine,
        BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
        64,
        replicas,
    )
}

/// `max_batch = 1` degenerates to strict one-request batches: every
/// response reports batch_size 1 and every request is answered.
#[test]
fn max_batch_one_serves_singletons() {
    let srv = server(1, Threading::Single, 1);
    let mut rng = Rng::new(31);
    let pending: Vec<_> =
        (0..12).map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up")).collect();
    for rx in pending {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.logits.len(), 3);
    }
    let m = srv.shutdown();
    assert_eq!(m.requests, 12);
    assert!((m.mean_batch_size - 1.0).abs() < 1e-9);
}

/// Shutting down a server whose channel never saw a request exits
/// cleanly (the worker is blocked on the empty channel at that moment).
#[test]
fn shutdown_on_empty_channel_is_clean() {
    let srv = server(4, Threading::Single, 2);
    let m = srv.shutdown();
    assert_eq!(m.requests, 0);
    assert_eq!(m.batches, 0);
}

/// Shutdown races a filling batch: requests submitted immediately before
/// shutdown are all drained and answered across the replica pool, none
/// dropped — the batcher's channel close lands mid-batch-collection.
#[test]
fn shutdown_mid_batch_drains_pending_requests() {
    for replicas in [1usize, 4] {
        for n in [1usize, 3, 7] {
            let srv = server(8, Threading::Single, replicas);
            let mut rng = Rng::new(32);
            let pending: Vec<_> = (0..n)
                .map(|_| srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up"))
                .collect();
            let m = srv.shutdown(); // joins the worker: everything drains first
            assert_eq!(m.requests, n as u64, "replicas={replicas} n={n}");
            assert_eq!(m.replica_requests.iter().sum::<u64>(), n as u64, "replicas={replicas} n={n}");
            for rx in pending {
                let resp = rx.recv().expect("drained response");
                assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                assert_eq!(resp.logits.len(), 3);
            }
        }
    }
}

/// A caller abandoning its reply channel must not wedge the worker or
/// affect other requests in the same batch.
#[test]
fn dropped_reply_receiver_does_not_stall_worker() {
    let srv = server(4, Threading::Single, 2);
    let mut rng = Rng::new(33);
    drop(srv.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up")); // abandoned
    let resp = srv.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
    assert_eq!(resp.logits.len(), 3);
    let m = srv.shutdown();
    assert_eq!(m.requests, 2);
}

/// NativeEngine results are identical across `--threads 1` and `auto`:
/// the row-band threading (and the scratch reuse behind it) never changes
/// logits bit-for-bit.
#[test]
fn engine_logits_identical_across_thread_counts() {
    let mut rng = Rng::new(34);
    let images: Vec<_> = (0..6).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let single = server(4, Threading::Fixed(1), 1);
    let auto = server(4, Threading::Auto, 1);
    for img in &images {
        let a = single.infer(img.clone()).expect("server up");
        let b = auto.infer(img.clone()).expect("server up");
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.predicted, b.predicted);
    }
    single.shutdown();
    auto.shutdown();
}

/// The replica-pool acceptance test: serving the same request stream
/// with `replicas = 1` and `replicas = 4` yields bit-identical logits
/// per request id, and the pool's metrics account for every request.
#[test]
fn replica_pool_logits_bit_identical_to_single() {
    let mut rng = Rng::new(35);
    let images: Vec<_> = (0..24).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let mut per_count: Vec<Vec<Vec<f32>>> = Vec::new();
    for replicas in [1usize, 4] {
        let srv = server(8, Threading::Single, replicas);
        let pending: Vec<_> =
            images.iter().map(|img| srv.submit(img.clone()).expect("server up")).collect();
        let mut responses: Vec<_> = pending.into_iter().map(|rx| rx.recv().expect("response")).collect();
        responses.sort_by_key(|r| r.id);
        per_count.push(responses.into_iter().map(|r| r.logits).collect());
        let m = srv.shutdown();
        assert_eq!(m.requests, images.len() as u64);
        assert_eq!(m.replica_requests.len(), replicas);
        assert_eq!(m.replica_requests.iter().sum::<u64>(), images.len() as u64);
    }
    assert_eq!(per_count[0], per_count[1], "replicas=4 logits differ from replicas=1");
}

/// An engine that dies mid-serve must not take the caller down:
/// `submit` / `infer` return `ServerClosed` (typed, no panic) once the
/// worker is gone, and `shutdown` still joins cleanly.
#[test]
fn dead_worker_surfaces_as_server_closed() {
    struct PanickingEngine;
    impl InferenceEngine for PanickingEngine {
        fn infer_batch(&mut self, _images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
            panic!("engine crashed (test)");
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (8, 8, 1)
        }
        fn name(&self) -> String {
            "panicking".into()
        }
        fn replicate(&self) -> Box<dyn InferenceEngine> {
            Box::new(PanickingEngine)
        }
    }

    let srv = InferenceServer::start(
        Box::new(PanickingEngine),
        BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        64,
        1,
    );
    let mut rng = Rng::new(36);
    // The first request kills the worker; its reply channel is dropped,
    // so `infer` reports ServerClosed rather than hanging or panicking.
    assert_eq!(srv.infer(Tensor3::random(8, 8, 1, &mut rng)), Err(ServerClosed));
    // Once the worker is gone the queue disconnects; within a bounded
    // number of attempts `submit` itself returns ServerClosed.
    let mut saw_closed = false;
    for _ in 0..100 {
        if srv.submit(Tensor3::random(8, 8, 1, &mut rng)).is_err() {
            saw_closed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(saw_closed, "submit never reported ServerClosed after worker death");
    srv.shutdown(); // joins the panicked worker without propagating
}
