//! Contract tests for the plan-time autotuner (`tbgemm::tune`): the
//! tuning store's JSON round-trip, every loader failure mode degrading
//! to a typed error (never a panic), deterministic candidate rankings,
//! and the headline differential — tuned plans bit-identical to
//! `Backend::Reference` across all 7 kinds, at both the GEMM and the
//! network level.

use tbgemm::conv::tensor::Tensor3;
use tbgemm::gemm::{
    Backend, GemmConfig, GemmOut, GemmPlan, GemmScratch, KPanel, Kind, Lhs, Threading, Tile, Weights,
};
use tbgemm::nn::builder::plan_from_config;
use tbgemm::nn::{NetConfig, NetOut, NetPlanConfig};
use tbgemm::tune::{self, measure, Choice, StoreError, TuningStore};
use tbgemm::util::mat::{MatF32, MatI8, MatU8};
use tbgemm::util::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("tbgemm_tuner_{}_{name}.json", std::process::id()))
}

// ---- the persisted store ------------------------------------------------

/// The full serialized vocabulary survives a JSON round-trip: every tile,
/// K-panel, and threading spelling, plus measurement floats (values exact
/// at the 3-decimal precision `to_json` writes).
#[test]
fn store_json_round_trips() {
    let mut s = TuningStore::empty();
    s.record(
        Kind::Bnn,
        (120, 48, 256),
        Choice { tile: Tile::Wide, threading: Threading::Fixed(4), ..Choice::default() },
        1812.5,
        41200.0,
    );
    s.record(
        Kind::Tnn,
        (256, 256, 2048),
        Choice { k_panel: KPanel::Depth(4096), ..Choice::default() },
        0.0,
        99.125,
    );
    s.record(Kind::Tbn, (16, 8, 64), Choice { tile: Tile::Rowdot, ..Choice::default() }, 3.0, 4.0);
    s.record(Kind::U4, (16, 8, 64), Choice::default(), 3.5, 4.75);
    s.record(Kind::F32, (1, 10, 256), Choice { threading: Threading::Auto, ..Choice::default() }, 7.5, 8.25);
    assert_eq!(TuningStore::from_json(&s.to_json()), Ok(s));
}

/// Every way a tuning file can be unusable is a typed `StoreError`, and a
/// file this host wrote loads back equal. `resolve` maps each failure to
/// the empty store, so none of these can break inference.
#[test]
fn loader_failure_modes_are_typed() {
    // Missing file.
    let missing = tmp("missing");
    let _ = std::fs::remove_file(&missing);
    assert!(matches!(TuningStore::load(&missing), Err(StoreError::Io(_))));

    // Corrupt JSON.
    let corrupt = tmp("corrupt");
    std::fs::write(&corrupt, "{not json").expect("write corrupt");
    assert!(matches!(TuningStore::load(&corrupt), Err(StoreError::Parse(_))));
    std::fs::remove_file(&corrupt).expect("cleanup");

    // Unknown format version.
    let vers = tmp("version");
    std::fs::write(&vers, "{\"version\": 99, \"host\": \"x\", \"entries\": []}").expect("write version");
    assert_eq!(TuningStore::load(&vers), Err(StoreError::Version { got: 99 }));
    std::fs::remove_file(&vers).expect("cleanup");

    // A `"tile": "tuned"` entry is rejected (resolution must terminate).
    let tuned = tmp("tuned_tile");
    std::fs::write(
        &tuned,
        format!(
            "{{\"version\": 1, \"host\": \"{}\", \"entries\": [{{\"kind\": \"BNN\", \
             \"m\": 16, \"n\": 8, \"k\": 64, \"threading\": \"single\", \"k_panel\": \"auto\", \
             \"tile\": \"tuned\", \"measured_ns\": 0, \"predicted_cycles\": 0}}]}}",
            tune::store::host_fingerprint()
        ),
    )
    .expect("write tuned-tile");
    assert!(matches!(TuningStore::load(&tuned), Err(StoreError::Parse(_))));
    std::fs::remove_file(&tuned).expect("cleanup");

    // Wrong host fingerprint: parses, but this process must not use it.
    let alien = tmp("host");
    let mut s = TuningStore::empty();
    s.host = "alien-arch-w999".into();
    s.save(&alien).expect("write alien");
    match TuningStore::load(&alien) {
        Err(StoreError::HostMismatch { got, want }) => {
            assert_eq!(got, "alien-arch-w999");
            assert_eq!(want, tune::store::host_fingerprint());
        }
        other => panic!("expected HostMismatch, got {other:?}"),
    }
    std::fs::remove_file(&alien).expect("cleanup");

    // A file written by this host loads back equal.
    let good = tmp("good");
    let mut s = TuningStore::empty();
    s.record(Kind::Bnn, (120, 48, 256), Choice { tile: Tile::Wide, ..Choice::default() }, 100.0, 200.0);
    s.save(&good).expect("write good");
    assert_eq!(TuningStore::load(&good), Ok(s));
    std::fs::remove_file(&good).expect("cleanup");
}

// ---- deterministic rankings ---------------------------------------------

/// Candidate enumeration and both rankings are bit-reproducible, and the
/// measured ranking is the exact stable order of its timing table.
#[test]
fn rankings_are_deterministic() {
    for kind in Kind::ALL {
        for &shape in &[(120usize, 48usize, 256usize), (256, 256, 2048)] {
            let c1 = tune::candidates(kind, shape, 8);
            let c2 = tune::candidates(kind, shape, 8);
            assert_eq!(c1, c2, "{kind:?} {shape:?} candidates");
            assert_eq!(
                tune::rank_predicted(kind, shape, &c1),
                tune::rank_predicted(kind, shape, &c2),
                "{kind:?} {shape:?} predicted ranking"
            );
        }
    }
    // Fixed measurement table → exact order; the 3.0 tie keeps input
    // order (stable sort), and a short table truncates the ranking.
    let c0 = Choice::default();
    let c1 = Choice { threading: Threading::Fixed(2), ..Choice::default() };
    let c2 = Choice { threading: Threading::Fixed(4), ..Choice::default() };
    let c3 = Choice { tile: Tile::Wide, ..Choice::default() };
    assert_eq!(tune::rank_measured(&[c0, c1, c2, c3], &[5.0, 3.0, 3.0, 1.0]), vec![c3, c1, c2, c0]);
    assert_eq!(tune::rank_measured(&[c0, c1, c2, c3], &[2.0]), vec![c0]);
}

/// The whole pipeline at API level: enumerate → rank → refine → record →
/// look up from a bucketed neighbor shape.
#[test]
fn refine_and_record_round_trip() {
    let shape = (48, 32, 256);
    let cands = tune::candidates(Kind::Tnn, shape, 4);
    let ranked = tune::rank_predicted(Kind::Tnn, shape, &cands);
    let top: Vec<Choice> = ranked.iter().map(|(c, _)| *c).collect();
    let budget = measure::Budget { top_k: 2, min_time_s: 0.0, max_iters: 2 };
    let timed = measure::refine(Kind::Tnn, shape, &top, budget, 42).expect("refine");
    let (winner, ns) = timed[0];
    let mut store = TuningStore::empty();
    store.record(Kind::Tnn, shape, winner, ns, ranked[0].1.total());
    // (40, 20, 250) buckets to the same (64, 32, 256) key.
    assert_eq!(store.lookup(Kind::Tnn, (40, 20, 250)), Some(winner));
    assert_eq!(store.lookup(Kind::Tnn, (400, 20, 250)), None);
}

// ---- tuned ≡ reference differentials ------------------------------------

/// `GemmConfig::tuned` resolves per-shape execution knobs at run time,
/// and the result stays bit-identical to the untuned native plan for
/// every kind (tuning never moves the packed layout) and to
/// `Backend::Reference` (exactly for integer kinds; f32 kinds within the
/// blocked-accumulation tolerance the backend differential tests use).
#[test]
fn tuned_plans_match_reference_all_kinds() {
    let mut rng = Rng::new(0x7E57);
    for &(m, n, k) in &[(13usize, 31usize, 130usize), (65, 24, 512)] {
        let b_bin = MatI8::random_binary(k, n, &mut rng);
        let b_ter = MatI8::random_ternary(k, n, &mut rng);
        let b_u8 = MatU8::random_below(k, n, 15, &mut rng);
        let b_f32 = MatF32::random(k, n, &mut rng);
        let a_bin = MatI8::random_binary(m, k, &mut rng);
        let a_ter = MatI8::random_ternary(m, k, &mut rng);
        let a_u8 = MatU8::random_below(m, k, 15, &mut rng);
        let a_f32 = MatF32::random(m, k, &mut rng);
        for kind in Kind::ALL {
            let weights = match kind {
                Kind::Bnn | Kind::Tbn | Kind::DaBnn => Weights::I8(&b_bin),
                Kind::Tnn => Weights::I8(&b_ter),
                Kind::U8 | Kind::U4 => Weights::U8 { b: &b_u8, za: 3, zb: 5 },
                Kind::F32 => Weights::F32(&b_f32),
            };
            let lhs = match kind {
                Kind::Bnn | Kind::DaBnn => Lhs::I8(&a_bin),
                Kind::Tnn | Kind::Tbn => Lhs::I8(&a_ter),
                Kind::U8 | Kind::U4 => Lhs::U8(&a_u8),
                Kind::F32 => Lhs::F32(&a_f32),
            };
            let tuned = GemmPlan::new(GemmConfig::tuned(kind), weights).expect("tuned plan");
            let native = GemmPlan::new(GemmConfig::native(kind), weights).expect("native plan");
            let reference = GemmPlan::new(GemmConfig::reference(kind), weights).expect("reference plan");
            let mut scratch = GemmScratch::new();
            let mut out_t = if tuned.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
            let mut out_n = if tuned.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
            let mut out_r = if tuned.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
            tuned.run(lhs, &mut out_t, &mut scratch).expect("tuned run");
            native.run(lhs, &mut out_n, &mut scratch).expect("native run");
            reference.run(lhs, &mut out_r, &mut scratch).expect("reference run");
            // Tuning only moves execution knobs of the packed plan: the
            // tuned output is bit-identical to the untuned native one.
            match (&out_t, &out_n) {
                (GemmOut::I32(c), GemmOut::I32(w)) => assert_eq!(c.data, w.data, "{kind:?} {m}x{n}x{k} vs native"),
                (GemmOut::F32(c), GemmOut::F32(w)) => assert_eq!(c.data, w.data, "{kind:?} {m}x{n}x{k} vs native"),
                _ => panic!("{kind:?}: output variants diverged"),
            }
            match (&out_t, &out_r) {
                (GemmOut::I32(c), GemmOut::I32(w)) => {
                    assert_eq!(c.data, w.data, "{kind:?} {m}x{n}x{k} vs reference")
                }
                (GemmOut::F32(c), GemmOut::F32(w)) => {
                    for (x, y) in c.data.iter().zip(&w.data) {
                        assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{kind:?} {m}x{n}x{k}: {x} vs {y}");
                    }
                }
                _ => panic!("{kind:?}: output variants diverged"),
            }
        }
    }
}

/// A store-driven choice (the path `resolve` takes on a store hit) runs
/// through the plan API and stays exact against the reference oracle.
#[test]
fn store_choice_drives_the_plan() {
    let mut store = TuningStore::empty();
    let shape = (65, 24, 512);
    let choice = Choice { tile: Tile::Wide, threading: Threading::Fixed(2), ..Choice::default() };
    store.record(Kind::Bnn, shape, choice, 0.0, 0.0);
    let resolved = store.lookup(Kind::Bnn, (70, 20, 500)).expect("same bucket");
    assert_eq!(resolved, choice);
    let mut rng = Rng::new(0x57);
    let a = MatI8::random_binary(65, 512, &mut rng);
    let b = MatI8::random_binary(512, 24, &mut rng);
    let plan = GemmPlan::new(resolved.to_config(Kind::Bnn), Weights::I8(&b)).expect("tuned-choice plan");
    let oracle = GemmPlan::new(GemmConfig::reference(Kind::Bnn), Weights::I8(&b)).expect("reference plan");
    let (mut out, mut want) = (GemmOut::new_i32(), GemmOut::new_i32());
    let mut scratch = GemmScratch::new();
    plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("tuned-choice run");
    oracle.run(Lhs::I8(&a), &mut want, &mut scratch).expect("reference run");
    assert_eq!(out.as_i32().expect("i32 out").data, want.as_i32().expect("i32 out").data);
}

/// `NetPlanConfig::with_tuning(true)` resolves every GEMM layer's config
/// through the tuner and the whole-network logits stay bit-identical to
/// the Reference backend — the issue's acceptance differential.
#[test]
fn tuned_net_plan_logits_match_reference() {
    let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
    let tuned =
        plan_from_config(&cfg, 0xBEEF, NetPlanConfig::default().with_tuning(true)).expect("tuned plan");
    let reference = plan_from_config(&cfg, 0xBEEF, NetPlanConfig::default().with_backend(Backend::Reference))
        .expect("reference plan");
    let (mut out_t, mut out_r) = (NetOut::new(), NetOut::new());
    let (mut s_t, mut s_r) = (tuned.make_scratch(), reference.make_scratch());
    let mut rng = Rng::new(0x11);
    for i in 0..4 {
        let img = Tensor3::random(8, 8, 1, &mut rng);
        tuned.run(&img, &mut out_t, &mut s_t).expect("tuned run");
        reference.run(&img, &mut out_r, &mut s_r).expect("reference run");
        assert_eq!(out_t.logits, out_r.logits, "image {i}");
    }
}
