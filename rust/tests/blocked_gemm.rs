//! Differential tests for the blocked execution layer: tiled and
//! multithreaded native kernels vs the scalar oracles and vs the seed's
//! row-dot kernels, on adversarial shapes — m/n not multiples of the
//! register tile, k not a multiple of 64 (partial last word), single-row
//! and single-column matrices — at 1 through 8 threads.

use tbgemm::gemm::native::kernels as nk;
use tbgemm::gemm::native::{
    bnn_gemm_mt, dabnn_gemm_mt, f32_gemm_mt, tbn_gemm_mt, tnn_gemm_mt, u8_gemm_mt, BitRows, PlaneRows, Threading,
};
use tbgemm::gemm::reference;
use tbgemm::util::mat::{MatF32, MatI32, MatI8, MatU8};
use tbgemm::util::Rng;

/// Shapes chosen to break every blocking boundary: register tiles (4×2,
/// 2×2, 4×8), the 64-bit word, the L1 column panel, and the row bands.
const SHAPES: [(usize, usize, usize); 9] = [
    (1, 1, 1),
    (1, 17, 64),
    (17, 1, 63),
    (3, 2, 65),
    (5, 5, 127),
    (8, 9, 128),
    (13, 31, 130),
    (33, 7, 257),
    (65, 24, 512),
];

const THREADS: std::ops::RangeInclusive<usize> = 1..=8;

#[test]
fn lowbit_mt_matches_oracle_all_shapes_and_threads() {
    let mut rng = Rng::new(0xB0B);
    for &(m, n, k) in &SHAPES {
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt = MatI8::random_ternary(k, n, &mut rng);
        let a_bits = BitRows::from_binary(&ab);
        let b_bits = BitRows::from_binary_transposed(&bb);
        let a_planes = PlaneRows::from_ternary(&at);
        let b_planes = PlaneRows::from_ternary_transposed(&bt);
        let want_bnn = reference::gemm_i8(&ab, &bb);
        let want_tnn = reference::gemm_i8(&at, &bt);
        let want_tbn = reference::gemm_i8(&at, &bb);
        for threads in THREADS {
            let th = Threading::Fixed(threads);
            let mut c = MatI32::zeros(m, n);
            bnn_gemm_mt(&a_bits, &b_bits, &mut c, th);
            assert_eq!(c.data, want_bnn.data, "bnn m={m} n={n} k={k} t={threads}");
            let mut c = MatI32::zeros(m, n);
            tnn_gemm_mt(&a_planes, &b_planes, &mut c, th);
            assert_eq!(c.data, want_tnn.data, "tnn m={m} n={n} k={k} t={threads}");
            let mut c = MatI32::zeros(m, n);
            tbn_gemm_mt(&a_planes, &b_bits, &mut c, th);
            assert_eq!(c.data, want_tbn.data, "tbn m={m} n={n} k={k} t={threads}");
        }
    }
}

/// The tiled single-thread kernels equal the seed row-dot kernels exactly
/// (same popcount arithmetic, different loop order — integers, so any
/// reordering must be invisible).
#[test]
fn tiled_matches_rowdot_kernels() {
    let mut rng = Rng::new(0xB0C);
    for &(m, n, k) in &SHAPES {
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let a_bits = BitRows::from_binary(&ab);
        let b_bits = BitRows::from_binary_transposed(&bb);
        let a_planes = PlaneRows::from_ternary(&at);

        let (mut tiled, mut rowdot) = (MatI32::zeros(m, n), MatI32::zeros(m, n));
        nk::bnn_gemm(&a_bits, &b_bits, &mut tiled);
        nk::bnn_gemm_rowdot(&a_bits, &b_bits, &mut rowdot);
        assert_eq!(tiled.data, rowdot.data, "bnn m={m} n={n} k={k}");

        let (mut tiled, mut rowdot) = (MatI32::zeros(m, n), MatI32::zeros(m, n));
        nk::tbn_gemm(&a_planes, &b_bits, &mut tiled);
        nk::tbn_gemm_rowdot(&a_planes, &b_bits, &mut rowdot);
        assert_eq!(tiled.data, rowdot.data, "tbn m={m} n={n} k={k}");
    }
}

/// daBNN keeps per-output f32 accumulation order under tiling and
/// threading, so it stays bit-identical to the i32 oracle at these depths.
#[test]
fn dabnn_mt_matches_oracle() {
    let mut rng = Rng::new(0xB0D);
    for &(m, n, k) in &[(1usize, 5usize, 64usize), (9, 6, 130), (21, 13, 384)] {
        let a = MatI8::random_binary(m, k, &mut rng);
        let b = MatI8::random_binary(k, n, &mut rng);
        let ab = BitRows::from_binary(&a);
        let bb = BitRows::from_binary_transposed(&b);
        let want = reference::gemm_i8(&a, &b);
        for threads in [1usize, 3, 8] {
            let mut c = MatF32::zeros(m, n);
            dabnn_gemm_mt(&ab, &bb, &mut c, Threading::Fixed(threads));
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j}) t={threads}");
                }
            }
        }
    }
}

/// f32 threading preserves per-output accumulation order: threaded output
/// is bit-identical to the single-threaded kernel.
#[test]
fn f32_mt_matches_single_thread_exactly() {
    let mut rng = Rng::new(0xB0E);
    for &(m, n, k) in &[(1usize, 9usize, 40usize), (13, 17, 33), (37, 25, 64)] {
        let a = MatF32::random(m, k, &mut rng);
        let b = MatF32::random(k, n, &mut rng);
        let panels = nk::pack_b_panels_f32(&b);
        let mut want = MatF32::zeros(m, n);
        nk::f32_gemm(&a, &panels, n, &mut want);
        for threads in THREADS {
            let mut c = MatF32::zeros(m, n);
            f32_gemm_mt(&a, &panels, n, &mut c, Threading::Fixed(threads));
            assert_eq!(c.data, want.data, "m={m} n={n} k={k} t={threads}");
        }
    }
}

#[test]
fn u8_mt_matches_oracle() {
    let mut rng = Rng::new(0xB0F);
    for &(m, n, k) in &[(1usize, 8usize, 50usize), (11, 9, 64), (30, 23, 100)] {
        let a = MatU8::random(m, k, &mut rng);
        let b = MatU8::random(k, n, &mut rng);
        let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
        let panels = nk::pack_b_panels_u8(&b);
        let col_sums: Vec<i32> = (0..n).map(|j| (0..k).map(|t| b.get(t, j) as i32).sum()).collect();
        let want = reference::gemm_u8_centered(&a, &b, za, zb);
        for threads in [1usize, 2, 5, 8] {
            let mut c = MatI32::zeros(m, n);
            u8_gemm_mt(&a, &panels, n, za, zb, &col_sums, &mut c, Threading::Fixed(threads));
            assert_eq!(c.data, want.data, "m={m} n={n} k={k} t={threads}");
        }
    }
}
