//! Backend-sweep differential tests through the one [`GemmPlan`] API:
//! every kind × every backend × a thread spread, on adversarial shapes —
//! m/n not multiples of the register tiles (4×2, 2×2, 4×8, 4×4 wide),
//! k not a multiple of 64 (partial last word), single-row and
//! single-column matrices. What used to be per-kind copy-paste over the
//! `*_gemm_mt` free-function zoo is now one loop over [`Backend::ALL`].

use tbgemm::gemm::reference;
use tbgemm::gemm::{
    Backend, GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Threading, Weights,
};
use tbgemm::util::mat::{MatF32, MatI8, MatU8};
use tbgemm::util::Rng;

/// Shapes chosen to break every blocking boundary: register tiles, the
/// 64-bit word, the L1 column panel, and the row bands.
const SHAPES: [(usize, usize, usize); 9] = [
    (1, 1, 1),
    (1, 17, 64),
    (17, 1, 63),
    (3, 2, 65),
    (5, 5, 127),
    (8, 9, 128),
    (13, 31, 130),
    (33, 7, 257),
    (65, 24, 512),
];

/// Threads exercised on the native backend (the other backends ignore
/// the config; one pass suffices there).
const NATIVE_THREADS: [usize; 4] = [1, 2, 5, 8];

fn run_plan(plan: &GemmPlan, lhs: Lhs<'_>) -> GemmOut {
    let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
    let mut scratch = GemmScratch::new();
    plan.run(lhs, &mut out, &mut scratch).expect("plan run");
    out
}

/// For each backend (and each thread count on native), build a plan,
/// run it, and hand the result to `check(label, out)`.
fn sweep(kind: Kind, weights: Weights<'_>, lhs: Lhs<'_>, check: &dyn Fn(&str, &GemmOut)) {
    for backend in Backend::ALL {
        let threads: &[usize] = if backend == Backend::Native { &NATIVE_THREADS } else { &[1] };
        for &t in threads {
            let cfg = GemmConfig::new(kind, backend).with_threading(Threading::Fixed(t));
            let plan = GemmPlan::new(cfg, weights).expect("plan");
            let out = run_plan(&plan, lhs);
            check(&format!("{kind:?} {backend:?} t={t}"), &out);
        }
    }
}

#[test]
fn lowbit_kinds_all_backends_match_oracle() {
    let mut rng = Rng::new(0xB0B);
    for &(m, n, k) in &SHAPES {
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt = MatI8::random_ternary(k, n, &mut rng);
        let cases: [(Kind, &MatI8, &MatI8); 3] =
            [(Kind::Bnn, &ab, &bb), (Kind::Tnn, &at, &bt), (Kind::Tbn, &at, &bb)];
        for (kind, a, b) in cases {
            let want = reference::gemm_i8(a, b);
            sweep(kind, Weights::I8(b), Lhs::I8(a), &|label, out| {
                let got = out.as_i32().expect("i32 out");
                assert_eq!(got.data, want.data, "{label} m={m} n={n} k={k}");
            });
        }
    }
}

/// daBNN produces f32 whose values are exact integers at these depths,
/// on every backend.
#[test]
fn dabnn_all_backends_match_oracle() {
    let mut rng = Rng::new(0xB0D);
    for &(m, n, k) in &[(1usize, 5usize, 64usize), (9, 6, 130), (21, 13, 384)] {
        let a = MatI8::random_binary(m, k, &mut rng);
        let b = MatI8::random_binary(k, n, &mut rng);
        let want = reference::gemm_i8(&a, &b);
        sweep(Kind::DaBnn, Weights::I8(&b), Lhs::I8(&a), &|label, out| {
            let got = out.as_f32().expect("f32 out");
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(got.get(i, j) as i32, want.get(i, j), "{label} ({i},{j})");
                }
            }
        });
    }
}

/// F32: native threading preserves per-output accumulation order
/// (bit-identical across thread counts); every backend matches the
/// oracle within tolerance.
#[test]
fn f32_all_backends_match_oracle() {
    let mut rng = Rng::new(0xB0E);
    for &(m, n, k) in &[(1usize, 9usize, 40usize), (13, 17, 33), (37, 25, 64)] {
        let a = MatF32::random(m, k, &mut rng);
        let b = MatF32::random(k, n, &mut rng);
        let want = reference::gemm_f32(&a, &b);
        // Threading must not change native f32 results at all.
        let plan1 = GemmPlan::new(GemmConfig::native(Kind::F32), Weights::F32(&b)).expect("plan");
        let single = run_plan(&plan1, Lhs::F32(&a));
        sweep(Kind::F32, Weights::F32(&b), Lhs::F32(&a), &|label, out| {
            let got = out.as_f32().expect("f32 out");
            if label.contains("Native") {
                assert_eq!(got.data, single.as_f32().expect("f32 out").data, "{label}");
            }
            for i in 0..m {
                for j in 0..n {
                    let (g, w) = (got.get(i, j), want.get(i, j));
                    assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{label} ({i},{j}): {g} vs {w}");
                }
            }
        });
    }
}

#[test]
fn u8_and_u4_all_backends_match_oracle() {
    let mut rng = Rng::new(0xB0F);
    for &(m, n, k) in &[(1usize, 8usize, 50usize), (11, 9, 64), (30, 23, 100)] {
        // U8: full-range values and zero points.
        let a = MatU8::random(m, k, &mut rng);
        let b = MatU8::random(k, n, &mut rng);
        let (za, zb) = (rng.below(256) as i32, rng.below(256) as i32);
        let want = reference::gemm_u8_centered(&a, &b, za, zb);
        sweep(Kind::U8, Weights::U8 { b: &b, za, zb }, Lhs::U8(&a), &|label, out| {
            assert_eq!(out.as_i32().expect("i32 out").data, want.data, "{label} m={m} n={n} k={k}");
        });
        // U4: 4-bit values and zero points (crosses its 290 depth block
        // in the k=300+ property suite; here the adversarial shapes).
        let a4 = MatU8::random_below(m, k, 15, &mut rng);
        let b4 = MatU8::random_below(k, n, 15, &mut rng);
        let (za4, zb4) = (rng.below(16) as i32, rng.below(16) as i32);
        let want4 = reference::gemm_u8_centered(&a4, &b4, za4, zb4);
        sweep(Kind::U4, Weights::U8 { b: &b4, za: za4, zb: zb4 }, Lhs::U8(&a4), &|label, out| {
            assert_eq!(out.as_i32().expect("i32 out").data, want4.data, "{label} m={m} n={n} k={k}");
        });
    }
}

/// `GemmConfig::tuned` resolves execution knobs at run time — from the
/// cost model, or from the tuning file when `TBGEMM_TUNE_FILE` is set
/// (CI re-runs this test exactly that way after `repro tune --fast`).
/// Whatever the resolution source, every kind stays bit-identical to the
/// untuned native plan on the adversarial shapes.
#[test]
fn tuned_plans_match_untuned_on_adversarial_shapes() {
    let mut rng = Rng::new(0xB10);
    for &(m, n, k) in &SHAPES {
        let ab = MatI8::random_binary(m, k, &mut rng);
        let bb = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt = MatI8::random_ternary(k, n, &mut rng);
        let a8 = MatU8::random_below(m, k, 15, &mut rng);
        let b8 = MatU8::random_below(k, n, 15, &mut rng);
        let af = MatF32::random(m, k, &mut rng);
        let bf = MatF32::random(k, n, &mut rng);
        for kind in Kind::ALL {
            let (weights, lhs): (Weights<'_>, Lhs<'_>) = match kind {
                Kind::Bnn | Kind::DaBnn => (Weights::I8(&bb), Lhs::I8(&ab)),
                Kind::Tnn => (Weights::I8(&bt), Lhs::I8(&at)),
                Kind::Tbn => (Weights::I8(&bb), Lhs::I8(&at)),
                Kind::U8 | Kind::U4 => (Weights::U8 { b: &b8, za: 3, zb: 5 }, Lhs::U8(&a8)),
                Kind::F32 => (Weights::F32(&bf), Lhs::F32(&af)),
            };
            let tuned = GemmPlan::new(GemmConfig::tuned(kind), weights).expect("tuned plan");
            let native = GemmPlan::new(GemmConfig::native(kind), weights).expect("native plan");
            let (got, want) = (run_plan(&tuned, lhs), run_plan(&native, lhs));
            match (&got, &want) {
                (GemmOut::I32(c), GemmOut::I32(w)) => {
                    assert_eq!(c.data, w.data, "{kind:?} m={m} n={n} k={k}")
                }
                (GemmOut::F32(c), GemmOut::F32(w)) => {
                    assert_eq!(c.data, w.data, "{kind:?} m={m} n={n} k={k}")
                }
                _ => panic!("{kind:?}: output variants diverged"),
            }
        }
    }
}

/// Worker-pool stress: many caller threads hammer multithreaded
/// `GemmPlan::run`s through the one process-wide pool **concurrently**
/// (shared plans, per-caller scratch — exactly the serving stack's
/// shape), asserting every run bit-identical to the single-threaded
/// oracle. Contention for pool workers must never change a result or
/// deadlock the fixed-size pool.
#[test]
fn concurrent_plans_share_the_pool_bit_identically() {
    let mut rng = Rng::new(0x9001);
    let (m, n, k) = (33usize, 19usize, 257usize);
    let ab = MatI8::random_binary(m, k, &mut rng);
    let bb = MatI8::random_binary(k, n, &mut rng);
    let at = MatI8::random_ternary(m, k, &mut rng);
    let bt = MatI8::random_ternary(k, n, &mut rng);
    let cases: [(Kind, &MatI8, &MatI8); 3] =
        [(Kind::Bnn, &ab, &bb), (Kind::Tnn, &at, &bt), (Kind::Tbn, &at, &bb)];
    for (kind, a, b) in cases {
        let want = reference::gemm_i8(a, b);
        // One shared plan per thread-count config, run from 8 threads at
        // once: caps resolve per call against the pool, never per caller.
        for threads in [2usize, 4, 8] {
            let cfg = GemmConfig::native(kind).with_threading(Threading::Fixed(threads));
            let plan = GemmPlan::new(cfg, Weights::I8(b)).expect("plan");
            std::thread::scope(|s| {
                for _ in 0..8 {
                    let (plan, want) = (&plan, &want);
                    s.spawn(move || {
                        let mut out = GemmOut::new_i32();
                        let mut scratch = GemmScratch::new();
                        for rep in 0..12 {
                            plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("plan run");
                            let got = out.as_i32().expect("i32 out");
                            assert_eq!(
                                got.data, want.data,
                                "{kind:?} t={threads} rep={rep}: pooled run diverged"
                            );
                        }
                    });
                }
            });
        }
    }
}
