//! Cross-ISA parity pins between the emulated NEON microkernels and the
//! native aarch64 intrinsics path (`gemm/native/simd_popcnt/neon.rs`).
//!
//! The emulator (`simd::reg::Neon`) is the instruction-level oracle for
//! the paper's Table II; the intrinsics path is the shipping ARM code.
//! These tests pin the relationship between the two so neither can drift
//! silently:
//!
//! 1. the emulated BNN/TNN/TBN microkernels' steady-state instruction
//!    streams are pinned exactly (by mnemonic family),
//! 2. every *product-forming* instruction the emulator traces is one the
//!    intrinsics path emits (`simd_popcnt::isa`) — the compute core is
//!    the exact subset shared across ISAs,
//! 3. every mnemonic the intrinsics path emits is modeled by the
//!    emulator, so the emulator remains a complete cost model for the
//!    shipping kernels.
//!
//! Differences outside the compute core are *accumulation shape*, not
//! arithmetic: the emulator accumulates with the paper's widening adds
//! (`SADDW`/`SSUBL`+`ADD`) after broadcasting B values (`DUP`/`EXT`),
//! while the intrinsics path streams packed words and accumulates with
//! `UADALP` (`vpadalq`) — both are 16-bit in-register accumulation per
//! Table II. Bit-identity of the *results* is proven separately by the
//! differential suite, which CI runs on aarch64 under `qemu-user`.

use std::collections::BTreeSet;
use tbgemm::costmodel::table2::steady_state_trace;
use tbgemm::gemm::native::simd_popcnt::isa;
use tbgemm::gemm::Kind;
use tbgemm::simd::trace::family;

/// Steady-state mnemonic families of one emulated microkernel iteration.
fn traced_families(kind: Kind) -> BTreeSet<&'static str> {
    steady_state_trace(kind).families().into_keys().collect()
}

fn set(names: &[&'static str]) -> BTreeSet<&'static str> {
    names.iter().copied().collect()
}

/// Pin the emulated streams exactly (family granularity). A kernel
/// refactor that adds or removes an instruction class must update this
/// test *and* re-justify the Table II counts.
#[test]
fn emulated_streams_are_pinned() {
    assert_eq!(traced_families(Kind::Bnn), set(&["LD1", "DUP", "EOR", "CNT", "SADDW"]));
    assert_eq!(traced_families(Kind::Tnn), set(&["LD1", "DUP", "EXT", "AND", "CNT", "SSUBL", "ADD"]));
    assert_eq!(traced_families(Kind::Tbn), set(&["LD1", "DUP", "EOR", "AND", "BIC", "CNT", "SSUBL", "ADD"]));
}

/// The product-forming logic + CNT the emulator traces must be exactly a
/// subset of what the intrinsics path emits for the same kind. TBN is
/// the one asymmetry: the emulated kernel spends an EOR per column
/// *building the selector* `[¬y♭×8 | y♭×8]` from the hoisted mask (an
/// arrangement role), where the intrinsics path folds the negation into
/// BIC — that selector EOR is excluded below, and its count is pinned so
/// the exclusion stays honest.
#[test]
fn emulated_compute_core_is_subset_of_native_isa() {
    let logic_and_cnt = |kind: Kind| -> BTreeSet<&'static str> {
        let mut logic = set(isa::LOGIC);
        logic.insert("CNT");
        traced_families(kind).intersection(&logic).copied().collect()
    };
    let native = [(Kind::Bnn, isa::BNN), (Kind::Tnn, isa::TNN), (Kind::Tbn, isa::TBN)];
    for (kind, declared) in native {
        let declared = set(declared);
        let mut core = logic_and_cnt(kind);
        if kind == Kind::Tbn {
            core.remove("EOR"); // selector construction, see doc above
        }
        assert!(
            core.is_subset(&declared),
            "{kind:?}: emulated compute core {core:?} not a subset of native ISA {declared:?}"
        );
    }
    // The TBN selector EOR is exactly 1 per column = 8 per iteration.
    let tbn = steady_state_trace(Kind::Tbn);
    assert_eq!(tbn.families()["EOR"], 8, "TBN selector EORs per iteration");
}

/// Closure in the other direction: the intrinsics path emits no mnemonic
/// the emulator does not model, so the emulator remains a complete
/// instruction-level oracle for the shipping ARM kernels.
#[test]
fn native_isa_is_modeled_by_emulator() {
    // Every mnemonic family `simd::reg::Neon` implements (traced names,
    // collapsed by `family`), plus MOVI (accumulator zeroing).
    let emulator_vocab = set(&[
        "LD1", "ST1", "EOR", "AND", "ORR", "ORN", "BIC", "MVN", "CNT", "SADDW", "SSUBL", "ADD", "UADALP",
        "ADDV", "FMLA", "UMLAL", "USHR", "DUP", "EXT", "UXTL", "INS", "MOVI", "UCVTF", "FADD",
    ]);
    for declared in [isa::BNN, isa::TNN, isa::TBN, isa::LOGIC] {
        for m in declared {
            assert_eq!(family(m), *m, "ISA lists must already be family-normalized: {m}");
            assert!(emulator_vocab.contains(m), "native ISA mnemonic {m} is not modeled by the emulator");
        }
    }
}

/// The per-kind ISA lists are consistent with each other: TBN = TNN with
/// one AND pair replaced by BIC; BNN is the XOR core; everything shares
/// the LD1/MOVI/CNT/UADALP/ADDV skeleton.
#[test]
fn native_isa_lists_are_consistent() {
    let (bnn, tnn, tbn) = (set(isa::BNN), set(isa::TNN), set(isa::TBN));
    let skeleton = set(&["LD1", "MOVI", "CNT", "UADALP", "ADDV"]);
    for s in [&bnn, &tnn, &tbn] {
        assert!(skeleton.is_subset(s));
    }
    assert!(bnn.contains("EOR") && !tnn.contains("EOR") && !tbn.contains("EOR"));
    assert!(tbn.contains("BIC") && !tnn.contains("BIC"));
    assert!(tnn.contains("AND") && tnn.contains("ORR"));
}
