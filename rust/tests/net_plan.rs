//! Contract tests for the network-level plan/execute API
//! (`tbgemm::nn::NetPlan`), mirroring `tests/plan_api.rs` one boundary
//! up: typed `NetError` pinning (every variant constructible from safe
//! inputs, nothing panics), pack-once / run-many pointer stability on
//! both ping-pong activation arenas, and whole-network backend
//! differentials (Native ≡ Reference ≡ Emulated logits, bit-exact).

use tbgemm::conv::conv2d::{ConvKind, ConvParams, LowBitConv};
use tbgemm::conv::tensor::Tensor3;
use tbgemm::gemm::{Backend, GemmError, Threading};
use tbgemm::nn::builder::{build_layers, plan_from_config, LayerSpec};
use tbgemm::nn::{
    Activation, InputQuant, Layer, NetConfig, NetError, NetOut, NetPlan, NetPlanConfig, QConv2d,
};
use tbgemm::util::mat::MatI8;
use tbgemm::util::Rng;

fn tnn_conv_layer(rng: &mut Rng, c_in: usize, c_out: usize) -> Layer {
    let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
    let w = MatI8::random_ternary(p.depth(c_in), c_out, rng);
    Layer::QConv(QConv2d {
        conv: LowBitConv::new(ConvKind::Tnn, p, c_in, &w),
        scale: vec![0.1; c_out],
        bias: vec![0.0; c_out],
        act: Activation::Ternary { delta: 0.3 },
    })
}

// ---- typed NetError pinning --------------------------------------------

/// Every `NetError` variant is constructible from safe inputs, and none
/// of the paths panic (the `plan_api`-style pinning test the replica
/// pool and serving path build on).
#[test]
fn net_error_variants_are_typed_and_pinned() {
    let mut rng = Rng::new(0xA0);

    // UnsupportedChain: empty network.
    assert_eq!(
        NetPlan::build((8, 8, 1), Vec::new(), NetPlanConfig::default()).err(),
        Some(NetError::UnsupportedChain { layer: 0, reason: "network has no layers" })
    );

    // DomainMismatch: a quantized conv directly on the f32 input.
    let layers = vec![tnn_conv_layer(&mut rng, 1, 4)];
    match NetPlan::build((8, 8, 1), layers, NetPlanConfig::default()) {
        Err(NetError::DomainMismatch { layer: 0, expected, got }) => {
            assert_eq!((expected, got), ("ternary", "f32"));
        }
        other => panic!("expected DomainMismatch, got {:?}", other.err()),
    }

    // UnsupportedChain: conv channel count breaks mid-chain.
    let layers = vec![
        Layer::InputQuant(InputQuant { act: Activation::Ternary { delta: 0.4 } }),
        tnn_conv_layer(&mut rng, 2, 4), // input has 1 channel, conv expects 2
    ];
    match NetPlan::build((8, 8, 1), layers, NetPlanConfig::default()) {
        Err(NetError::UnsupportedChain { layer: 1, reason }) => {
            assert!(reason.contains("channel"), "reason: {reason}");
        }
        other => panic!("expected UnsupportedChain, got {:?}", other.err()),
    }

    // InputMismatch: a run-time image of the wrong shape.
    let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
    let plan = plan_from_config(&cfg, 1, NetPlanConfig::default()).expect("plan");
    let (mut out, mut scratch) = (NetOut::new(), plan.make_scratch());
    let wrong = Tensor3::random(8, 7, 1, &mut rng);
    assert_eq!(
        plan.run(&wrong, &mut out, &mut scratch),
        Err(NetError::InputMismatch { expected: (8, 8, 1), got: (8, 7, 1) })
    );

    // OutputMismatch: run_batch with mismatched output slots.
    let images: Vec<_> = (0..3).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let mut outs = vec![NetOut::new(); 2];
    assert_eq!(
        plan.run_batch(&images, &mut outs, &mut scratch),
        Err(NetError::OutputMismatch { expected: 3, got: 2 })
    );

    // Every variant renders a non-empty, layer-bearing message.
    for e in [
        NetError::InputMismatch { expected: (8, 8, 1), got: (1, 1, 1) },
        NetError::DomainMismatch { layer: 3, expected: "binary", got: "ternary" },
        NetError::UnsupportedChain { layer: 2, reason: "test" },
        NetError::OutputMismatch { expected: 4, got: 2 },
        NetError::Gemm { layer: 1, error: GemmError::EmptyDim { dim: "m" } },
    ] {
        assert!(!e.to_string().is_empty());
    }
    assert!(NetError::DomainMismatch { layer: 3, expected: "binary", got: "ternary" }
        .to_string()
        .contains("layer 3"));
}

// ---- pack once / run many: ping-pong arena pointer stability -----------

/// After a warm-up run, `run_batch` performs zero heap allocation:
/// every buffer of **both** ping-pong arenas (plus the conv accumulator)
/// keeps its pointer across ≥ 3 batches, and the logits match one-shot
/// fresh-scratch runs bit-for-bit.
#[test]
fn run_batch_is_zero_alloc_across_batches() {
    for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
        let cfg = NetConfig::mobile_cnn(kind, 16, 16, 1, 10);
        let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("plan");
        let mut rng = Rng::new(0xA1);
        let batches: Vec<Vec<Tensor3<f32>>> = (0..3)
            .map(|_| (0..4).map(|_| Tensor3::random(16, 16, 1, &mut rng)).collect())
            .collect();
        let mut scratch = plan.make_scratch();
        let mut outs = vec![NetOut::new(); 4];

        // Warm-up batch, then record every arena pointer.
        plan.run_batch(&batches[0], &mut outs, &mut scratch).expect("warm-up batch");
        let ptrs = [
            scratch.arenas[0].q.data.as_ptr() as usize,
            scratch.arenas[0].f.data.as_ptr() as usize,
            scratch.arenas[1].q.data.as_ptr() as usize,
            scratch.arenas[1].f.data.as_ptr() as usize,
            scratch.conv_acc.data.as_ptr() as usize,
        ];
        let out_ptrs: Vec<usize> = outs.iter().map(|o| o.logits.as_ptr() as usize).collect();

        for (b, batch) in batches.iter().enumerate() {
            plan.run_batch(batch, &mut outs, &mut scratch).expect("steady-state batch");
            let now = [
                scratch.arenas[0].q.data.as_ptr() as usize,
                scratch.arenas[0].f.data.as_ptr() as usize,
                scratch.arenas[1].q.data.as_ptr() as usize,
                scratch.arenas[1].f.data.as_ptr() as usize,
                scratch.conv_acc.data.as_ptr() as usize,
            ];
            assert_eq!(now, ptrs, "{kind:?} batch {b}: a ping-pong arena reallocated");
            let out_now: Vec<usize> = outs.iter().map(|o| o.logits.as_ptr() as usize).collect();
            assert_eq!(out_now, out_ptrs, "{kind:?} batch {b}: an output buffer reallocated");
            // Bit-identical to one-shot runs with fresh scratch.
            for (img, out) in batch.iter().zip(&outs) {
                let mut fresh = plan.make_scratch();
                let mut one = NetOut::new();
                plan.run(img, &mut one, &mut fresh).expect("fresh run");
                assert_eq!(out.logits, one.logits, "{kind:?} batch {b}");
            }
        }
    }
}

// ---- whole-network backend differential --------------------------------

/// The same seeded network produces bit-identical logits on all three
/// GEMM backends: the conv/dense GEMMs are exact integer products on
/// every backend and the f32 epilogues run in the same order, so the
/// network boundary inherits the GEMM boundary's differential property.
#[test]
fn backends_agree_on_whole_network_logits() {
    for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        // tiny_tnn is TNN-only; use mobile_cnn for per-kind coverage.
        let cfg = if kind == ConvKind::Tnn { cfg } else { NetConfig::mobile_cnn(kind, 12, 12, 1, 4) };
        let mut rng = Rng::new(0xA2);
        let images: Vec<_> = (0..3).map(|_| Tensor3::random(12, 12, 1, &mut rng)).collect();
        let mut per_backend: Vec<Vec<Vec<f32>>> = Vec::new();
        for backend in Backend::ALL {
            let plan = plan_from_config(&cfg, 0xBEEF, NetPlanConfig::default().with_backend(backend))
                .expect("plan");
            assert_eq!(plan.config().backend, backend);
            let mut scratch = plan.make_scratch();
            let mut out = NetOut::new();
            let logits: Vec<Vec<f32>> = images
                .iter()
                .map(|img| {
                    plan.run(img, &mut out, &mut scratch).expect("run");
                    out.logits.clone()
                })
                .collect();
            per_backend.push(logits);
        }
        assert_eq!(per_backend[0], per_backend[1], "{kind:?}: reference vs emulated");
        assert_eq!(per_backend[1], per_backend[2], "{kind:?}: emulated vs native");
    }
}

/// Per-GEMM row-band threading never changes logits through the network
/// plan (composes with the coordinator's replica splitting).
#[test]
fn threading_is_logit_invariant_through_plan() {
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 16, 16, 1, 10);
    let single = plan_from_config(&cfg, 7, NetPlanConfig::default()).expect("plan");
    let threaded =
        plan_from_config(&cfg, 7, NetPlanConfig::default().with_threading(Threading::Fixed(4)))
            .expect("plan");
    let mut rng = Rng::new(0xA3);
    let (mut s1, mut s2) = (single.make_scratch(), threaded.make_scratch());
    let (mut o1, mut o2) = (NetOut::new(), NetOut::new());
    for _ in 0..4 {
        let img = Tensor3::random(16, 16, 1, &mut rng);
        single.run(&img, &mut o1, &mut s1).expect("run");
        threaded.run(&img, &mut o2, &mut s2).expect("run");
        assert_eq!(o1.logits, o2.logits);
    }
}

/// `build_layers` + `NetPlan::build` equals `plan_from_config` (the two
/// construction paths share one realization).
#[test]
fn build_layers_and_from_config_agree() {
    let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
    let (input, layers) = build_layers(&cfg, 21);
    let a = NetPlan::build(input, layers, NetPlanConfig::default()).expect("plan");
    let b = plan_from_config(&cfg, 21, NetPlanConfig::default()).expect("plan");
    let mut rng = Rng::new(0xA4);
    let img = Tensor3::random(8, 8, 1, &mut rng);
    let (mut sa, mut sb) = (a.make_scratch(), b.make_scratch());
    let (mut oa, mut ob) = (NetOut::new(), NetOut::new());
    a.run(&img, &mut oa, &mut sa).expect("run");
    b.run(&img, &mut ob, &mut sb).expect("run");
    assert_eq!(oa.logits, ob.logits);
    // LayerSpec sanity: the declarative description matches the chain.
    assert_eq!(cfg.layers.len(), a.num_layers());
    assert!(matches!(cfg.layers[0], LayerSpec::InputQuant { .. }));
}
