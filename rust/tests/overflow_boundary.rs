//! Overflow-boundary regression tests pinning the per-kind safe-K bounds
//! of the K-paneled accumulation scheme (paper Table II / eq. (4)),
//! driven through the plan/execute API (`GemmPlan`, native backend).
//!
//! Adversarial all-ones and alternating-sign inputs are placed at depths
//! just below and just above the 16-bit accumulation limit, asserting
//! that the paneled path stays exact exactly where a pure 16-bit
//! accumulator would wrap. The i16 wrap itself is demonstrated on the
//! expected values, so the bound is pinned on both sides: 32767 fits,
//! 32768 does not. Run under `--release` in CI as well, so the overflow
//! behavior is checked with optimizations (and without debug overflow
//! checks) enabled.

use tbgemm::gemm::reference;
use tbgemm::gemm::{
    safe_k, GemmConfig, GemmOut, GemmPlan, GemmScratch, KPanel, Kind, Lhs, Threading, Weights,
};
use tbgemm::util::mat::{MatI8, MatU8};
use tbgemm::util::Rng;

/// The 16-bit bound for the low-bit kinds and its neighbours.
const K_SAFE: usize = 32767;

#[test]
fn safe_k_bounds_are_pinned() {
    assert_eq!(safe_k(Kind::Bnn), K_SAFE);
    assert_eq!(safe_k(Kind::Tnn), K_SAFE);
    assert_eq!(safe_k(Kind::Tbn), K_SAFE);
    assert_eq!(safe_k(Kind::U8), 66051);
    assert_eq!(safe_k(Kind::U4), 291);
    assert_eq!(safe_k(Kind::DaBnn), (1 << 23) - 1);
    // The boundary itself: ±32767 round-trips through i16, ±32768 wraps.
    assert_eq!(K_SAFE as i16 as i32, K_SAFE as i32);
    assert_eq!(-(K_SAFE as i32) as i16 as i32, -(K_SAFE as i32));
    assert_ne!((K_SAFE as i32 + 1) as i16 as i32, K_SAFE as i32 + 1);
}

/// Run one adversarial low-bit case at depth `k` against the oracle, for
/// a spread of panel configs (including single-word panels) and threads,
/// through the plan API.
fn assert_lowbit_exact(kind: Kind, a: &MatI8, b: &MatI8, k: usize) {
    let want = reference::gemm_i8(a, b);
    let panels = [KPanel::Auto, KPanel::Depth(64), KPanel::Depth(4096), KPanel::Depth(k)];
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    for kp in panels {
        for th in [Threading::Single, Threading::Fixed(4)] {
            let plan = GemmPlan::new(
                GemmConfig::native(kind).with_threading(th).with_k_panel(kp),
                Weights::I8(b),
            )
            .expect("plan");
            plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("run");
            assert_eq!(out.as_i32().expect("i32 out").data, want.data, "k={k} kp={kp:?} th={th:?}");
        }
    }
}

/// BNN all-ones at the boundary: same-sign inputs drive the output to
/// +k; at k = 32768 the 16-bit epilogue value would wrap to −32768 while
/// the paneled i32 path stays exact. Opposite signs pin −k (which first
/// exceeds i16 at −32769).
#[test]
fn bnn_all_ones_straddles_16bit_bound() {
    for k in [K_SAFE, K_SAFE + 1] {
        let a = MatI8::from_fn(2, k, |_, _| 1);
        let b_same = MatI8::from_fn(k, 2, |_, _| 1);
        let b_opp = MatI8::from_fn(k, 2, |_, _| -1);
        assert_lowbit_exact(Kind::Bnn, &a, &b_same, k);
        assert_lowbit_exact(Kind::Bnn, &a, &b_opp, k);
        // The pinned expected values.
        let want = reference::gemm_i8(&a, &b_same);
        assert_eq!(want.get(0, 0), k as i32);
        if k > K_SAFE {
            // A 16-bit accumulator would report −32768 here.
            assert_ne!(want.get(0, 0) as i16 as i32, want.get(0, 0));
        } else {
            assert_eq!(want.get(0, 0) as i16 as i32, want.get(0, 0));
        }
    }
}

/// BNN alternating signs at the boundary: fully cancelling products keep
/// the output at 0 (or ±1 for odd k) no matter the depth — the paneled
/// path must agree with the oracle bit-for-bit through the cancellation.
#[test]
fn bnn_alternating_sign_cancels_exactly() {
    for k in [K_SAFE, K_SAFE + 1] {
        let a = MatI8::from_fn(2, k, |_, t| if t % 2 == 0 { 1 } else { -1 });
        let b = MatI8::from_fn(k, 2, |_, _| 1);
        assert_lowbit_exact(Kind::Bnn, &a, &b, k);
        let want = reference::gemm_i8(&a, &b);
        assert_eq!(want.get(0, 0), (k % 2) as i32);
    }
}

/// TNN all-ones: z⁺ = k drives the plane difference to +k, first
/// overflowing i16 at 32768.
#[test]
fn tnn_all_ones_straddles_16bit_bound() {
    for k in [K_SAFE, K_SAFE + 1] {
        let a = MatI8::from_fn(2, k, |_, _| 1);
        let b = MatI8::from_fn(k, 2, |_, _| 1);
        assert_lowbit_exact(Kind::Tnn, &a, &b, k);
        assert_eq!(reference::gemm_i8(&a, &b).get(0, 0), k as i32);
    }
}

/// TNN alternating ternary (+1/0/−1 pattern) above the bound: partial
/// cancellation with a nonzero residue, exact through the panels.
#[test]
fn tnn_alternating_pattern_above_bound() {
    let k = K_SAFE + 1;
    let a = MatI8::from_fn(2, k, |_, t| [1i8, 0, -1][t % 3]);
    let b = MatI8::from_fn(k, 2, |t, _| if t % 2 == 0 { 1 } else { -1 });
    assert_lowbit_exact(Kind::Tnn, &a, &b, k);
}

/// TBN all-ones at the boundary (ternary activations × binary weights).
#[test]
fn tbn_all_ones_straddles_16bit_bound() {
    for k in [K_SAFE, K_SAFE + 1] {
        let a = MatI8::from_fn(2, k, |_, _| 1);
        let b = MatI8::from_fn(k, 2, |_, _| -1);
        assert_lowbit_exact(Kind::Tbn, &a, &b, k);
        assert_eq!(reference::gemm_i8(&a, &b).get(0, 0), -(k as i32));
    }
}

/// U8 at its u32 bound (k_max = 66051): all-255 inputs make the raw dot
/// product exceed u32::MAX one element past the bound, so an unpaneled
/// 32-bit accumulation would wrap; the paneled path (u32 in-panel, i64
/// spill) stays exact. Zero points of 255 keep the centered result at 0,
/// well inside i32.
#[test]
fn u8_all_max_straddles_u32_bound() {
    let bound = safe_k(Kind::U8);
    for k in [bound, bound + 1] {
        let (m, n) = (2usize, 2usize);
        let a = MatU8 { rows: m, cols: k, data: vec![255; m * k] };
        let b = MatU8 { rows: k, cols: n, data: vec![255; k * n] };
        let (za, zb) = (255, 255);
        let want = reference::gemm_u8_centered(&a, &b, za, zb);
        assert_eq!(want.get(0, 0), 0);
        let mut out = GemmOut::new_i32();
        let mut scratch = GemmScratch::new();
        for kp in [KPanel::Auto, KPanel::Depth(1 << 20)] {
            let plan = GemmPlan::new(
                GemmConfig::native(Kind::U8).with_k_panel(kp),
                Weights::U8 { b: &b, za, zb },
            )
            .expect("plan");
            plan.run(Lhs::U8(&a), &mut out, &mut scratch).expect("run");
            assert_eq!(out.as_i32().expect("i32 out").data, want.data, "k={k} kp={kp:?}");
        }
        // The raw dot itself crosses u32::MAX exactly past the bound.
        let raw = k as u64 * 255 * 255;
        if k > bound {
            assert!(raw > u32::MAX as u64);
        } else {
            assert!(raw <= u32::MAX as u64);
        }
    }
}

/// The emulated backend's fixed depth blocks stay exact across the same
/// boundary (its driver widens into i32 between 4096-deep blocks) — the
/// two backends agree word-for-word just past the 16-bit bound.
#[test]
fn emulated_backend_exact_past_the_bound() {
    let k = K_SAFE + 1;
    let mut rng = Rng::new(0x0B1);
    let a = MatI8::random_ternary(2, k, &mut rng);
    let b = MatI8::random_ternary(k, 2, &mut rng);
    let want = reference::gemm_i8(&a, &b);
    let plan = GemmPlan::new(GemmConfig::emulated(Kind::Tnn), Weights::I8(&b)).expect("plan");
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("run");
    assert_eq!(out.as_i32().expect("i32 out").data, want.data);
}
