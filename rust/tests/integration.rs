//! Integration tests across module boundaries: GEMM drivers under
//! convolution, networks under the coordinator, cost model over real
//! traces, and paper-grid consistency between the emulated and native
//! paths.

use tbgemm::bench::{grid, predicted, ratio};
use tbgemm::conv::conv2d::{direct_conv_i8, ConvKind, ConvParams, LowBitConv};
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{BatcherConfig, InferenceServer, NativeEngine, ServerConfig};
use tbgemm::gemm::reference::gemm_i8;
use tbgemm::gemm::{Backend, GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
use tbgemm::nn::builder::{build_from_config, NetConfig};
use tbgemm::quant::{c_in_max, k_max};
use tbgemm::util::mat::MatI8;
use tbgemm::util::Rng;
use std::time::Duration;

/// Run a TNN multiplication through the plan API on the given backend.
fn tnn_plan_run(backend: Backend, a: &MatI8, b: &MatI8) -> Vec<i32> {
    let plan = GemmPlan::new(GemmConfig::new(Kind::Tnn, backend), Weights::I8(b)).expect("plan");
    let mut out = GemmOut::new_i32();
    let mut scratch = GemmScratch::new();
    plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("run");
    out.into_i32().expect("i32 out").data
}

/// Paper-grid shape: emulated backend ≡ native backend ≡ oracle at a
/// full 64-point-grid member (72×24×128), through one GemmPlan loop.
#[test]
fn paper_grid_point_consistency() {
    let (h, w, d) = (72, 24, 128);
    let mut rng = Rng::new(0x1111);
    let a = MatI8::random_ternary(h, d, &mut rng);
    let b = MatI8::random_ternary(d, w, &mut rng);
    let oracle = gemm_i8(&a, &b);
    for backend in Backend::ALL {
        assert_eq!(tnn_plan_run(backend, &a, &b), oracle.data, "{backend:?}");
    }
}

/// A conv layer built on the packed GEMM equals the direct convolution
/// at CNN-realistic shapes (the paper's eq. (5) applicability argument).
#[test]
fn conv_matches_direct_at_cnn_scale() {
    let mut rng = Rng::new(0x2222);
    let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
    let c_in = 16;
    let c_out = 24;
    // eq. (5): 3×3 TNN supports up to 3640 input channels; 16 is safe.
    assert!(c_in as u64 <= c_in_max(k_max(2, 16).max(32767), 3, 3));
    let w = MatI8::random_ternary(p.depth(c_in), c_out, &mut rng);
    let conv = LowBitConv::new(ConvKind::Tnn, p, c_in, &w);
    let input = Tensor3::random_ternary(14, 14, c_in, &mut rng);
    let got = conv.forward(&input);
    let want = direct_conv_i8(&input, &w, &p, 0);
    assert_eq!(got.data, want.data);
}

/// The three network kinds produce different outputs but all live
/// (non-constant) predictions.
#[test]
fn all_three_network_kinds_are_live() {
    let mut rng = Rng::new(0x3333);
    let images: Vec<Tensor3<f32>> = (0..12).map(|_| Tensor3::random(16, 16, 1, &mut rng)).collect();
    for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
        let net = build_from_config(&NetConfig::mobile_cnn(kind, 16, 16, 1, 10), 0xCAFE);
        let preds: std::collections::BTreeSet<usize> = images.iter().map(|i| net.predict(i)).collect();
        assert!(preds.len() > 1, "{kind:?} network predicts a constant class");
    }
}

/// Coordinator end-to-end: responses match direct engine outputs
/// (the batcher and the replica pool must not permute or corrupt
/// request/response pairing).
#[test]
fn coordinator_matches_direct_inference() {
    let cfg = NetConfig::tiny_tnn(8, 8, 1, 4);
    let direct = build_from_config(&cfg, 77);
    let served = build_from_config(&cfg, 77).into_plan();
    let server = InferenceServer::with_config(
        Box::new(NativeEngine::new(served, "it")),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) })
            .with_replicas(2)
            .with_depths(32, 32),
    );
    let mut rng = Rng::new(0x4444);
    let images: Vec<Tensor3<f32>> = (0..16).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
    let pending: Vec<_> = images.iter().map(|img| server.submit(img.clone()).expect("server up")).collect();
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().unwrap().completed().expect("served, not shed");
        assert_eq!(resp.logits, direct.logits(img), "batched result differs from direct");
    }
    let m = server.shutdown();
    assert_eq!(m.replica_requests.iter().sum::<u64>(), images.len() as u64);
}

/// The cost model over real traces predicts the paper's qualitative
/// ordering on the full grid.
#[test]
fn predicted_table3_ordering() {
    let m = ratio::ratio_matrix(&predicted::predict_grid(&grid::paper_grid()));
    let faster = |a: Kind, b: Kind| m.get(a, b) > 1.0; // b faster than a
    assert!(faster(Kind::F32, Kind::U8));
    assert!(faster(Kind::U8, Kind::U4));
    assert!(faster(Kind::U4, Kind::Tnn));
    assert!(faster(Kind::Tnn, Kind::Bnn));
    assert!(faster(Kind::Tbn, Kind::Bnn));
}

/// Measured smoke benchmark: low-bit kinds must beat F32 on this host
/// (the minimal Table III shape-claim, kept fast for CI).
#[test]
fn measured_lowbit_beats_f32_smoke() {
    let g = vec![(72, 24, 256)];
    let f32t = grid::time_algorithm(Kind::F32, &g, 2, 3, 1).times[0].1;
    let tnnt = grid::time_algorithm(Kind::Tnn, &g, 2, 3, 1).times[0].1;
    let bnnt = grid::time_algorithm(Kind::Bnn, &g, 2, 3, 1).times[0].1;
    assert!(tnnt < f32t, "TNN ({tnnt:.2e}s) must beat F32 ({f32t:.2e}s)");
    assert!(bnnt < tnnt, "BNN ({bnnt:.2e}s) must beat TNN ({tnnt:.2e}s)");
}

/// Deep-depth TNN through the emulated backend (depth-block widening)
/// at a CNN-like extreme: 3×3 conv over 1024 channels → depth 9216.
#[test]
fn deep_depth_widening_correct() {
    let mut rng = Rng::new(0x5555);
    let d = 9216;
    let a = MatI8::random_ternary(2, d, &mut rng);
    let b = MatI8::random_ternary(d, 3, &mut rng);
    assert_eq!(tnn_plan_run(Backend::Emulated, &a, &b), gemm_i8(&a, &b).data);
}
