//! A 128-bit NEON vector register and the instruction subset the paper's
//! microkernels use, emulated with exact lane semantics.
//!
//! Pure lane operations live as methods on [`Reg128`]; the traced wrappers
//! (which also count instruction classes) live in [`Neon`]. Microkernels
//! call only the traced wrappers so that one kernel iteration yields the
//! paper's Table II counts.

use crate::simd::trace::{InsnClass, Trace};

/// One 128-bit NEON `Q` register, stored as 16 little-endian bytes.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Reg128(pub [u8; 16]);

impl std::fmt::Debug for Reg128 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reg128[")?;
        for (i, b) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{b:02x}")?;
        }
        write!(f, "]")
    }
}

impl Reg128 {
    pub const ZERO: Reg128 = Reg128([0; 16]);

    // ---- typed views -------------------------------------------------

    pub fn from_bytes(b: [u8; 16]) -> Self {
        Reg128(b)
    }

    pub fn from_i16x8(v: [i16; 8]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[2 * i..2 * i + 2].copy_from_slice(&x.to_le_bytes());
        }
        Reg128(b)
    }

    pub fn to_i16x8(self) -> [i16; 8] {
        let mut v = [0i16; 8];
        for (i, x) in v.iter_mut().enumerate() {
            *x = i16::from_le_bytes([self.0[2 * i], self.0[2 * i + 1]]);
        }
        v
    }

    pub fn from_u16x8(v: [u16; 8]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[2 * i..2 * i + 2].copy_from_slice(&x.to_le_bytes());
        }
        Reg128(b)
    }

    pub fn to_u16x8(self) -> [u16; 8] {
        let mut v = [0u16; 8];
        for (i, x) in v.iter_mut().enumerate() {
            *x = u16::from_le_bytes([self.0[2 * i], self.0[2 * i + 1]]);
        }
        v
    }

    pub fn from_u32x4(v: [u32; 4]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
        Reg128(b)
    }

    pub fn to_u32x4(self) -> [u32; 4] {
        let mut v = [0u32; 4];
        for (i, x) in v.iter_mut().enumerate() {
            *x = u32::from_le_bytes([self.0[4 * i], self.0[4 * i + 1], self.0[4 * i + 2], self.0[4 * i + 3]]);
        }
        v
    }

    pub fn from_f32x4(v: [f32; 4]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
        Reg128(b)
    }

    pub fn to_f32x4(self) -> [f32; 4] {
        let mut v = [0f32; 4];
        for (i, x) in v.iter_mut().enumerate() {
            *x = f32::from_le_bytes([self.0[4 * i], self.0[4 * i + 1], self.0[4 * i + 2], self.0[4 * i + 3]]);
        }
        v
    }

    // ---- raw lane semantics (untraced) -------------------------------

    #[inline]
    fn map2(self, o: Reg128, f: impl Fn(u8, u8) -> u8) -> Reg128 {
        let mut r = [0u8; 16];
        for i in 0..16 {
            r[i] = f(self.0[i], o.0[i]);
        }
        Reg128(r)
    }

    pub fn eor_raw(self, o: Reg128) -> Reg128 {
        self.map2(o, |a, b| a ^ b)
    }

    pub fn and_raw(self, o: Reg128) -> Reg128 {
        self.map2(o, |a, b| a & b)
    }

    pub fn orr_raw(self, o: Reg128) -> Reg128 {
        self.map2(o, |a, b| a | b)
    }

    /// ORN: `a | !b`.
    pub fn orn_raw(self, o: Reg128) -> Reg128 {
        self.map2(o, |a, b| a | !b)
    }

    /// BIC: `a & !b`.
    pub fn bic_raw(self, o: Reg128) -> Reg128 {
        self.map2(o, |a, b| a & !b)
    }

    pub fn mvn_raw(self) -> Reg128 {
        let mut r = [0u8; 16];
        for i in 0..16 {
            r[i] = !self.0[i];
        }
        Reg128(r)
    }

    /// CNT: per-byte popcount.
    pub fn cnt_raw(self) -> Reg128 {
        let mut r = [0u8; 16];
        for i in 0..16 {
            r[i] = self.0[i].count_ones() as u8;
        }
        Reg128(r)
    }
}

/// The traced NEON "CPU": every method emulates one instruction and
/// records it in the [`Trace`].
pub struct Neon {
    pub trace: Trace,
}

impl Neon {
    pub fn new() -> Self {
        Neon { trace: Trace::new() }
    }

    pub fn recording() -> Self {
        Neon { trace: Trace::recording() }
    }

    // ---- loads / stores ----------------------------------------------

    /// LD1 of a full 128-bit register.
    #[inline]
    pub fn ld1q(&mut self, src: &[u8]) -> Reg128 {
        self.trace.hit(InsnClass::Ld, "LD1.16B");
        let mut b = [0u8; 16];
        b.copy_from_slice(&src[..16]);
        Reg128(b)
    }

    /// LD1 of a 64-bit half register (low half; high half zeroed).
    #[inline]
    pub fn ld1d(&mut self, src: &[u8]) -> Reg128 {
        self.trace.hit(InsnClass::Ld, "LD1.8B");
        let mut b = [0u8; 16];
        b[..8].copy_from_slice(&src[..8]);
        Reg128(b)
    }

    /// ST1 of a full register.
    #[inline]
    pub fn st1q(&mut self, r: Reg128, dst: &mut [u8]) {
        self.trace.hit(InsnClass::St, "ST1.16B");
        dst[..16].copy_from_slice(&r.0);
    }

    // ---- logic (COM) ---------------------------------------------------

    #[inline]
    pub fn eor(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "EOR");
        a.eor_raw(b)
    }

    #[inline]
    pub fn and(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "AND");
        a.and_raw(b)
    }

    #[inline]
    pub fn orr(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "ORR");
        a.orr_raw(b)
    }

    #[inline]
    pub fn orn(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "ORN");
        a.orn_raw(b)
    }

    #[inline]
    pub fn bic(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "BIC");
        a.bic_raw(b)
    }

    #[inline]
    pub fn mvn(&mut self, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "MVN");
        a.mvn_raw()
    }

    /// CNT: per-byte popcount.
    #[inline]
    pub fn cnt(&mut self, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "CNT");
        a.cnt_raw()
    }

    // ---- widening adds / subs (COM) -----------------------------------

    /// SADDW: `acc.8h + sxtl(lo(a).8b)` — widen the LOW eight bytes
    /// (signed) and add into eight i16 lanes.
    #[inline]
    pub fn saddw(&mut self, acc: Reg128, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "SADDW");
        let mut v = acc.to_i16x8();
        for i in 0..8 {
            v[i] = v[i].wrapping_add(a.0[i] as i8 as i16);
        }
        Reg128::from_i16x8(v)
    }

    /// SADDW2: same, for the HIGH eight bytes.
    #[inline]
    pub fn saddw2(&mut self, acc: Reg128, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "SADDW2");
        let mut v = acc.to_i16x8();
        for i in 0..8 {
            v[i] = v[i].wrapping_add(a.0[8 + i] as i8 as i16);
        }
        Reg128::from_i16x8(v)
    }

    /// SSUBL: `sxtl(lo(a)) - sxtl(lo(b))` into eight i16 lanes.
    #[inline]
    pub fn ssubl(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "SSUBL");
        let mut v = [0i16; 8];
        for i in 0..8 {
            v[i] = (a.0[i] as i8 as i16) - (b.0[i] as i8 as i16);
        }
        Reg128::from_i16x8(v)
    }

    /// SSUBL2: high-half variant of SSUBL.
    #[inline]
    pub fn ssubl2(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "SSUBL2");
        let mut v = [0i16; 8];
        for i in 0..8 {
            v[i] = (a.0[8 + i] as i8 as i16) - (b.0[8 + i] as i8 as i16);
        }
        Reg128::from_i16x8(v)
    }

    /// ADD on eight i16 lanes.
    #[inline]
    pub fn add16(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "ADD.8H");
        let x = a.to_i16x8();
        let y = b.to_i16x8();
        let mut v = [0i16; 8];
        for i in 0..8 {
            v[i] = x[i].wrapping_add(y[i]);
        }
        Reg128::from_i16x8(v)
    }

    /// UADALP: unsigned pairwise add of sixteen u8 into eight u16 lanes,
    /// accumulating (daBNN-style binary accumulation).
    #[inline]
    pub fn uadalp(&mut self, acc: Reg128, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UADALP");
        let mut v = acc.to_u16x8();
        for i in 0..8 {
            v[i] = v[i].wrapping_add(a.0[2 * i] as u16 + a.0[2 * i + 1] as u16);
        }
        Reg128::from_u16x8(v)
    }

    /// ADDV: horizontal reduction of sixteen u8 lanes to a scalar.
    #[inline]
    pub fn addv(&mut self, a: Reg128) -> u32 {
        self.trace.hit(InsnClass::Com, "ADDV");
        a.0.iter().map(|&b| b as u32).sum()
    }

    // ---- multiply-accumulate (COM) ------------------------------------

    /// FMLA by-element: `acc.4s + a.4s * b.s[lane]`.
    #[inline]
    pub fn fmla_lane(&mut self, acc: Reg128, a: Reg128, b: Reg128, lane: usize) -> Reg128 {
        self.trace.hit(InsnClass::Com, "FMLA");
        let s = b.to_f32x4()[lane];
        let x = a.to_f32x4();
        let mut v = acc.to_f32x4();
        for i in 0..4 {
            v[i] += x[i] * s;
        }
        Reg128::from_f32x4(v)
    }

    /// UMLAL by-element (16-bit): `acc.4s + uxtl(lo(a).4h) * b.h[lane]`.
    #[inline]
    pub fn umlal_lane(&mut self, acc: Reg128, a: Reg128, b: Reg128, lane: usize) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UMLAL");
        let s = b.to_u16x8()[lane] as u32;
        let x = a.to_u16x8();
        let mut v = acc.to_u32x4();
        for i in 0..4 {
            v[i] = v[i].wrapping_add(x[i] as u32 * s);
        }
        Reg128::from_u32x4(v)
    }

    /// UMLAL2 by-element: high four u16 lanes of `a`.
    #[inline]
    pub fn umlal2_lane(&mut self, acc: Reg128, a: Reg128, b: Reg128, lane: usize) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UMLAL2");
        let s = b.to_u16x8()[lane] as u32;
        let x = a.to_u16x8();
        let mut v = acc.to_u32x4();
        for i in 0..4 {
            v[i] = v[i].wrapping_add(x[4 + i] as u32 * s);
        }
        Reg128::from_u32x4(v)
    }

    /// UMLAL (vector, 8-bit): `acc.8h + uxtl(lo(a).8b) * uxtl(lo(b).8b)`
    /// — the 4-bit path's multiply-accumulate into u16 lanes.
    #[inline]
    pub fn umlal_v8(&mut self, acc: Reg128, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UMLAL.8B");
        let mut v = acc.to_u16x8();
        for i in 0..8 {
            v[i] = v[i].wrapping_add(a.0[i] as u16 * b.0[i] as u16);
        }
        Reg128::from_u16x8(v)
    }

    /// UMLAL2 (vector, 8-bit): high-half variant of [`Neon::umlal_v8`].
    #[inline]
    pub fn umlal2_v8(&mut self, acc: Reg128, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UMLAL2.16B");
        let mut v = acc.to_u16x8();
        for i in 0..8 {
            v[i] = v[i].wrapping_add(a.0[8 + i] as u16 * b.0[8 + i] as u16);
        }
        Reg128::from_u16x8(v)
    }

    /// ADD on four u32 lanes.
    #[inline]
    pub fn add32(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "ADD.4S");
        let x = a.to_u32x4();
        let y = b.to_u32x4();
        let mut v = [0u32; 4];
        for i in 0..4 {
            v[i] = x[i].wrapping_add(y[i]);
        }
        Reg128::from_u32x4(v)
    }

    /// USHR: per-byte logical shift right (nibble unpack in the 4-bit path).
    #[inline]
    pub fn ushr8(&mut self, a: Reg128, shift: u32) -> Reg128 {
        self.trace.hit(InsnClass::Com, "USHR");
        let mut r = [0u8; 16];
        for i in 0..16 {
            r[i] = a.0[i] >> shift;
        }
        Reg128(r)
    }

    // ---- register arrangement (MOV class) ------------------------------

    /// DUP: broadcast byte `lane` of `a` to all 16 byte lanes.
    #[inline]
    pub fn dup_b(&mut self, a: Reg128, lane: usize) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "DUP.16B");
        Reg128([a.0[lane]; 16])
    }

    /// EXT: concatenate `a` and `b` and extract 16 bytes starting at `n`:
    /// result = `[a[n..16], b[0..n]]`.
    #[inline]
    pub fn ext(&mut self, a: Reg128, b: Reg128, n: usize) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "EXT");
        let mut r = [0u8; 16];
        for i in 0..16 {
            r[i] = if i + n < 16 { a.0[i + n] } else { b.0[i + n - 16] };
        }
        Reg128(r)
    }

    /// UXTL: zero-extend the LOW eight bytes to eight u16 lanes.
    #[inline]
    pub fn uxtl(&mut self, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "UXTL");
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = a.0[i] as u16;
        }
        Reg128::from_u16x8(v)
    }

    /// UXTL2: zero-extend the HIGH eight bytes to eight u16 lanes.
    #[inline]
    pub fn uxtl2(&mut self, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "UXTL2");
        let mut v = [0u16; 8];
        for i in 0..8 {
            v[i] = a.0[8 + i] as u16;
        }
        Reg128::from_u16x8(v)
    }

    /// INS: insert a scalar u32 into lane `lane` of `a` (daBNN ADDV path).
    #[inline]
    pub fn ins_u32(&mut self, a: Reg128, lane: usize, v: u32) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "INS");
        let mut w = a.to_u32x4();
        w[lane] = v;
        Reg128::from_u32x4(w)
    }

    /// MOVI #0 — zero a register (used for accumulator init, not in the
    /// steady-state iteration).
    #[inline]
    pub fn movi0(&mut self) -> Reg128 {
        self.trace.hit(InsnClass::Mov, "MOVI");
        Reg128::ZERO
    }

    /// UCVTF: u32 lanes -> f32 lanes (daBNN converts popcount sums to f32).
    #[inline]
    pub fn ucvtf(&mut self, a: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "UCVTF");
        let x = a.to_u32x4();
        Reg128::from_f32x4([x[0] as f32, x[1] as f32, x[2] as f32, x[3] as f32])
    }

    /// FADD on four f32 lanes (daBNN accumulation).
    #[inline]
    pub fn fadd(&mut self, a: Reg128, b: Reg128) -> Reg128 {
        self.trace.hit(InsnClass::Com, "FADD");
        let x = a.to_f32x4();
        let y = b.to_f32x4();
        Reg128::from_f32x4([x[0] + y[0], x[1] + y[1], x[2] + y[2], x[3] + y[3]])
    }
}

impl Default for Neon {
    fn default() -> Self {
        Neon::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n() -> Neon {
        Neon::new()
    }

    #[test]
    fn eor_and_cnt_lanes() {
        let mut cpu = n();
        let a = Reg128::from_bytes([0b1010_1010; 16]);
        let b = Reg128::from_bytes([0b0101_0101; 16]);
        let x = cpu.eor(a, b);
        assert_eq!(x.0, [0xFF; 16]);
        let c = cpu.cnt(x);
        assert_eq!(c.0, [8; 16]);
        assert_eq!(cpu.trace.com, 2);
    }

    #[test]
    fn saddw_low_and_high_halves() {
        let mut cpu = n();
        let mut bytes = [0u8; 16];
        for (i, v) in bytes.iter_mut().enumerate() {
            *v = i as u8;
        }
        let a = Reg128::from_bytes(bytes);
        let acc = Reg128::from_i16x8([100; 8]);
        let lo = cpu.saddw(acc, a).to_i16x8();
        let hi = cpu.saddw2(acc, a).to_i16x8();
        for i in 0..8 {
            assert_eq!(lo[i], 100 + i as i16);
            assert_eq!(hi[i], 100 + 8 + i as i16);
        }
    }

    #[test]
    fn saddw_is_signed() {
        let mut cpu = n();
        let a = Reg128::from_bytes([0xFF; 16]); // -1 as i8
        let acc = Reg128::from_i16x8([0; 8]);
        assert_eq!(cpu.saddw(acc, a).to_i16x8(), [-1; 8]);
    }

    #[test]
    fn ssubl_widens_difference() {
        let mut cpu = n();
        let mut a = [0u8; 16];
        let mut b = [0u8; 16];
        a[0] = 8;
        b[0] = 3;
        a[8] = 1;
        b[8] = 7;
        let d = cpu.ssubl(Reg128::from_bytes(a), Reg128::from_bytes(b)).to_i16x8();
        assert_eq!(d[0], 5);
        let d2 = cpu.ssubl2(Reg128::from_bytes(a), Reg128::from_bytes(b)).to_i16x8();
        assert_eq!(d2[0], -6);
    }

    #[test]
    fn ext_concats() {
        let mut cpu = n();
        let a = Reg128::from_bytes([1; 16]);
        let b = Reg128::from_bytes([2; 16]);
        let r = cpu.ext(a, b, 8);
        assert_eq!(&r.0[..8], &[1; 8]);
        assert_eq!(&r.0[8..], &[2; 8]);
    }

    #[test]
    fn dup_broadcasts_lane() {
        let mut cpu = n();
        let mut bytes = [0u8; 16];
        bytes[3] = 42;
        let r = cpu.dup_b(Reg128::from_bytes(bytes), 3);
        assert_eq!(r.0, [42; 16]);
        assert_eq!(cpu.trace.mov, 1);
    }

    #[test]
    fn fmla_lane_semantics() {
        let mut cpu = n();
        let acc = Reg128::from_f32x4([1.0, 2.0, 3.0, 4.0]);
        let a = Reg128::from_f32x4([1.0, 1.0, 1.0, 1.0]);
        let b = Reg128::from_f32x4([10.0, 20.0, 30.0, 40.0]);
        let r = cpu.fmla_lane(acc, a, b, 2).to_f32x4();
        assert_eq!(r, [31.0, 32.0, 33.0, 34.0]);
    }

    #[test]
    fn umlal_lane_widens() {
        let mut cpu = n();
        let acc = Reg128::from_u32x4([1, 1, 1, 1]);
        let a = Reg128::from_u16x8([300, 2, 3, 4, 5, 6, 7, 8]);
        let b = Reg128::from_u16x8([0, 1000, 0, 0, 0, 0, 0, 0]);
        let r = cpu.umlal_lane(acc, a, b, 1).to_u32x4();
        assert_eq!(r, [300_001, 2001, 3001, 4001]);
        let r2 = cpu.umlal2_lane(acc, a, b, 1).to_u32x4();
        assert_eq!(r2, [5001, 6001, 7001, 8001]);
    }

    #[test]
    fn umlal_v8_bytes() {
        let mut cpu = n();
        let acc = Reg128::from_u16x8([0; 8]);
        let mut a = [0u8; 16];
        let mut b = [0u8; 16];
        a[0] = 15;
        b[0] = 15;
        let r = cpu.umlal_v8(acc, Reg128::from_bytes(a), Reg128::from_bytes(b)).to_u16x8();
        assert_eq!(r[0], 225);
    }

    #[test]
    fn uadalp_pairwise() {
        let mut cpu = n();
        let acc = Reg128::from_u16x8([10; 8]);
        let a = Reg128::from_bytes([1; 16]);
        let r = cpu.uadalp(acc, a).to_u16x8();
        assert_eq!(r, [12; 8]);
    }

    #[test]
    fn orn_bic_mvn() {
        let mut cpu = n();
        let a = Reg128::from_bytes([0b1100; 16]);
        let b = Reg128::from_bytes([0b1010; 16]);
        assert_eq!(cpu.orn(a, b).0, [0b1100 | !0b1010u8; 16]);
        assert_eq!(cpu.bic(a, b).0, [0b0100; 16]);
        assert_eq!(cpu.mvn(a).0, [!0b1100u8; 16]);
    }

    #[test]
    fn addv_reduces() {
        let mut cpu = n();
        let a = Reg128::from_bytes([3; 16]);
        assert_eq!(cpu.addv(a), 48);
    }

    #[test]
    fn loads_count_in_ld_class() {
        let mut cpu = n();
        let buf = [7u8; 32];
        let q = cpu.ld1q(&buf);
        let d = cpu.ld1d(&buf);
        assert_eq!(q.0, [7; 16]);
        assert_eq!(&d.0[..8], &[7; 8]);
        assert_eq!(&d.0[8..], &[0; 8]);
        assert_eq!(cpu.trace.ld, 2);
    }
}
