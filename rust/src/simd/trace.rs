//! Instruction-stream tracing for the emulated NEON microkernels.

use std::collections::BTreeMap;

/// The paper's instruction classes (Table II columns), plus stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum InsnClass {
    /// Computational SIMD instruction (FMLA, UMLAL, EOR, AND, ORR, ORN,
    /// MVN, CNT, SADDW, SSUBL, ADD, USHR, ...).
    Com,
    /// SIMD register load (LD1 and friends).
    Ld,
    /// Register-arrangement instruction (MOV, DUP, INS, EXT, UXTL, ...).
    Mov,
    /// SIMD register store (ST1). The paper does not report stores per
    /// iteration (results stay in registers); tracked for completeness.
    St,
}

/// Collapse a traced mnemonic to its base A64 family: drop the
/// arrangement suffix (`"LD1.16B"` → `"LD1"`, `"ADD.8H"` → `"ADD"`) and
/// a trailing high-half `2` (`"SADDW2"` → `"SADDW"`, `"UMLAL2"` →
/// `"UMLAL"`). `tests/isa_parity.rs` compares traces against the native
/// NEON intrinsics path at this granularity — the intrinsics make no
/// low/high-half or arrangement distinction visible.
pub fn family(mnemonic: &str) -> &str {
    let base = mnemonic.split('.').next().unwrap_or(mnemonic);
    base.strip_suffix('2').unwrap_or(base)
}

/// Aggregated instruction counts, by class and by mnemonic.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub com: u64,
    pub ld: u64,
    pub mov: u64,
    pub st: u64,
    /// Per-mnemonic counts (e.g. "EOR" -> 8), for the `repro explain`
    /// textual rendering of the paper's Figs. 1-3.
    pub by_mnemonic: BTreeMap<&'static str, u64>,
    /// When true, every instruction is also appended to `log` — used by
    /// `repro explain` to print the full stream of one iteration.
    pub record_stream: bool,
    pub log: Vec<&'static str>,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    /// A trace that records the full instruction stream.
    pub fn recording() -> Self {
        Trace { record_stream: true, ..Trace::default() }
    }

    #[inline]
    pub fn hit(&mut self, class: InsnClass, mnemonic: &'static str) {
        match class {
            InsnClass::Com => self.com += 1,
            InsnClass::Ld => self.ld += 1,
            InsnClass::Mov => self.mov += 1,
            InsnClass::St => self.st += 1,
        }
        *self.by_mnemonic.entry(mnemonic).or_insert(0) += 1;
        if self.record_stream {
            self.log.push(mnemonic);
        }
    }

    /// Total SIMD instructions (the numerator of the paper's INS metric).
    pub fn total(&self) -> u64 {
        self.com + self.ld + self.mov
    }

    /// The paper's INS metric: instructions per microkernel output element
    /// per depth step, `(COM + LD + MOV) / (m*n*k)`.
    pub fn ins_metric(&self, m: usize, n: usize, k: usize) -> f64 {
        self.total() as f64 / (m * n * k) as f64
    }

    /// Per-family instruction counts: [`family`] collapses arrangement
    /// and high-half variants, so e.g. `SADDW` + `SADDW2` report as one
    /// `SADDW` entry and `LD1.16B` + `LD1.8B` as one `LD1` entry.
    pub fn families(&self) -> BTreeMap<&'static str, u64> {
        let mut out = BTreeMap::new();
        for (m, n) in &self.by_mnemonic {
            *out.entry(family(m)).or_insert(0) += n;
        }
        out
    }

    /// Difference of two traces (e.g. two iterations minus one iteration,
    /// to isolate steady-state per-iteration cost).
    pub fn delta(&self, earlier: &Trace) -> Trace {
        let mut by = BTreeMap::new();
        for (k, v) in &self.by_mnemonic {
            let e = earlier.by_mnemonic.get(k).copied().unwrap_or(0);
            if *v > e {
                by.insert(*k, v - e);
            }
        }
        Trace {
            com: self.com - earlier.com,
            ld: self.ld - earlier.ld,
            mov: self.mov - earlier.mov,
            st: self.st - earlier.st,
            by_mnemonic: by,
            record_stream: false,
            log: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_counts_by_class() {
        let mut t = Trace::new();
        t.hit(InsnClass::Com, "EOR");
        t.hit(InsnClass::Com, "CNT");
        t.hit(InsnClass::Ld, "LD1");
        t.hit(InsnClass::Mov, "DUP");
        assert_eq!((t.com, t.ld, t.mov, t.st), (2, 1, 1, 0));
        assert_eq!(t.total(), 4);
        assert_eq!(t.by_mnemonic["EOR"], 1);
    }

    #[test]
    fn ins_metric_matches_formula() {
        let mut t = Trace::new();
        for _ in 0..42 {
            t.hit(InsnClass::Com, "X");
        }
        // BNN microkernel: 42 instructions / (16*8*8) = 0.041
        let ins = t.ins_metric(16, 8, 8);
        assert!((ins - 0.041_015_625).abs() < 1e-9);
    }

    #[test]
    fn family_collapses_variants() {
        assert_eq!(family("LD1.16B"), "LD1");
        assert_eq!(family("LD1.8B"), "LD1");
        assert_eq!(family("SADDW2"), "SADDW");
        assert_eq!(family("UMLAL2.16B"), "UMLAL");
        assert_eq!(family("ADD.8H"), "ADD");
        assert_eq!(family("CNT"), "CNT");
    }

    #[test]
    fn families_merges_counts() {
        let mut t = Trace::new();
        t.hit(InsnClass::Com, "SADDW");
        t.hit(InsnClass::Com, "SADDW2");
        t.hit(InsnClass::Ld, "LD1.16B");
        t.hit(InsnClass::Ld, "LD1.8B");
        t.hit(InsnClass::Com, "CNT");
        let f = t.families();
        assert_eq!(f["SADDW"], 2);
        assert_eq!(f["LD1"], 2);
        assert_eq!(f["CNT"], 1);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn delta_subtracts() {
        let mut a = Trace::new();
        a.hit(InsnClass::Com, "EOR");
        let mut b = a.clone();
        b.hit(InsnClass::Com, "EOR");
        b.hit(InsnClass::Ld, "LD1");
        let d = b.delta(&a);
        assert_eq!((d.com, d.ld), (1, 1));
        assert_eq!(d.by_mnemonic["EOR"], 1);
    }
}
