//! Inference engines the coordinator can drive, and the replica pool
//! that fans a dynamic batch out across them.

use crate::conv::tensor::Tensor3;
use crate::nn::plan::{NetOut, NetPlan};
use crate::nn::NetScratch;
use std::sync::Arc;

/// A batched inference engine. Implementations must be `Send` so worker
/// and replica threads can own them; `infer_batch` takes `&mut self` so
/// each engine can hold plain (lock-free) scratch state.
pub trait InferenceEngine: Send {
    /// Classify a batch of images; returns one logit vector per image.
    fn infer_batch(&mut self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>>;

    /// Expected input dims.
    fn input_dims(&self) -> (usize, usize, usize);

    fn name(&self) -> String;

    /// Clone this engine for the replica pool: replicas share the
    /// immutable packed plan (weights are packed exactly once, however
    /// many replicas serve them) and own fresh per-replica scratch.
    fn replicate(&self) -> Box<dyn InferenceEngine>;
}

/// The native low-bit engine: a thin **plan + scratch holder** — an
/// `Arc`-shared built-once [`NetPlan`] (the paper's kernels behind the
/// network-level plan/execute boundary) plus this replica's private
/// [`NetScratch`] / [`NetOut`], reused across requests and batches so
/// steady-state inference performs no heap allocation on the network
/// path.
pub struct NativeEngine {
    plan: Arc<NetPlan>,
    label: String,
    scratch: NetScratch,
    out: NetOut,
}

impl NativeEngine {
    /// Wrap a built plan (the common single-engine entry point).
    pub fn new(plan: NetPlan, label: impl Into<String>) -> Self {
        Self::shared(Arc::new(plan), label)
    }

    /// Wrap an already-shared plan (replicas of one pool).
    pub fn shared(plan: Arc<NetPlan>, label: impl Into<String>) -> Self {
        let scratch = plan.make_scratch();
        NativeEngine { plan, label: label.into(), scratch, out: NetOut::new() }
    }

    /// The underlying network plan.
    pub fn plan(&self) -> &NetPlan {
        &self.plan
    }
}

impl InferenceEngine for NativeEngine {
    fn infer_batch(&mut self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
        images
            .iter()
            .map(|img| match self.plan.run(img, &mut self.out, &mut self.scratch) {
                Ok(()) => self.out.logits.clone(),
                // A mis-shaped image (the one per-call NetError a caller
                // can cause) yields empty logits instead of killing the
                // worker; the serving CLI and tests always submit
                // plan-shaped images.
                Err(_) => Vec::new(),
            })
            .collect()
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.plan.input_dims()
    }

    fn name(&self) -> String {
        self.label.clone()
    }

    fn replicate(&self) -> Box<dyn InferenceEngine> {
        Box::new(NativeEngine::shared(Arc::clone(&self.plan), self.label.clone()))
    }
}

/// Wraps any engine with an injected per-image delay. This is the
/// slow-engine fixture behind the overload tests and
/// `repro bench-serve --delay-us`: it makes service time deterministic
/// and large relative to queueing, so saturation can be driven on
/// purpose with tiny request counts. Replicas each wrap a replica of
/// the inner engine (same shared packed plan, same delay).
pub struct DelayEngine {
    inner: Box<dyn InferenceEngine>,
    per_image: std::time::Duration,
}

impl DelayEngine {
    pub fn new(inner: Box<dyn InferenceEngine>, per_image: std::time::Duration) -> Self {
        DelayEngine { inner, per_image }
    }
}

impl InferenceEngine for DelayEngine {
    fn infer_batch(&mut self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
        std::thread::sleep(self.per_image.saturating_mul(images.len() as u32));
        self.inner.infer_batch(images)
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.inner.input_dims()
    }

    fn name(&self) -> String {
        format!("{}+delay", self.inner.name())
    }

    fn replicate(&self) -> Box<dyn InferenceEngine> {
        Box::new(DelayEngine { inner: self.inner.replicate(), per_image: self.per_image })
    }
}

/// A pool of engine replicas serving one model: replica 0 is the engine
/// the pool was built from, the rest are [`InferenceEngine::replicate`]
/// clones sharing its packed weights. [`EnginePool::infer_batch`] splits
/// each dynamic batch into contiguous per-replica chunks and dispatches
/// them to the process-wide worker pool ([`crate::util::pool`]) —
/// **batch-level** parallelism drawing from the same core budget as the
/// per-GEMM row-band [`crate::gemm::Threading`] inside each replica
/// (replica-chunk tasks fan their GEMM bands into the same pool; nested
/// dispatch is deadlock-free because waiting scopes execute queued
/// tasks). Chunking preserves request order and every image is computed
/// by the same plan, so logits are bit-identical for any replica count.
pub struct EnginePool {
    engines: Vec<Box<dyn InferenceEngine>>,
}

impl EnginePool {
    /// Build a pool of `replicas` engines (clamped to ≥ 1) from one
    /// prototype engine.
    pub fn new(engine: Box<dyn InferenceEngine>, replicas: usize) -> Self {
        let mut engines = Vec::with_capacity(replicas.max(1));
        for _ in 1..replicas.max(1) {
            engines.push(engine.replicate());
        }
        engines.insert(0, engine);
        EnginePool { engines }
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    pub fn input_dims(&self) -> (usize, usize, usize) {
        self.engines[0].input_dims()
    }

    pub fn name(&self) -> String {
        self.engines[0].name()
    }

    /// Run a batch split across the replicas. Returns the outputs in
    /// request order plus the per-replica request counts (for
    /// [`crate::coordinator::metrics::Metrics`]). A single chunk runs
    /// inline on replica 0 — no pool dispatch for work one engine would
    /// serve anyway.
    pub fn infer_batch(&mut self, images: &[Tensor3<f32>]) -> (Vec<Vec<f32>>, Vec<usize>) {
        let replicas = self.engines.len();
        let mut loads = vec![0usize; replicas];
        if images.is_empty() {
            return (Vec::new(), loads);
        }
        let chunk_len = images.len().div_ceil(replicas);
        if images.len() <= chunk_len {
            loads[0] = images.len();
            return (self.engines[0].infer_batch(images), loads);
        }
        let chunk_sizes: Vec<usize> = images.chunks(chunk_len).map(|c| c.len()).collect();
        let mut results: Vec<Option<Vec<Vec<f32>>>> = vec![None; chunk_sizes.len()];
        let tasks: Vec<crate::util::pool::ScopedTask<'_>> = images
            .chunks(chunk_len)
            .zip(self.engines.iter_mut())
            .zip(results.iter_mut())
            .map(|((chunk, engine), slot)| {
                Box::new(move || {
                    // A panicked replica contributes a chunk of *empty*
                    // logits of its full assigned length, so downstream
                    // request/response pairing stays aligned: only that
                    // replica's callers see empty logits, never another
                    // request's results. Catching here (not in the pool
                    // scope) keeps the degradation per-chunk instead of
                    // taking down the whole batch.
                    let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        engine.infer_batch(chunk)
                    }));
                    *slot = Some(got.unwrap_or_else(|_| vec![Vec::new(); chunk.len()]));
                }) as crate::util::pool::ScopedTask<'_>
            })
            .collect();
        crate::util::pool::global().run_scoped(tasks);
        let mut outputs = Vec::with_capacity(images.len());
        for (i, slot) in results.into_iter().enumerate() {
            let chunk = slot.unwrap_or_else(|| vec![Vec::new(); chunk_sizes[i]]);
            loads[i] = chunk.len();
            outputs.extend(chunk);
        }
        (outputs, loads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::{plan_from_config, NetConfig};
    use crate::nn::NetPlanConfig;
    use crate::util::Rng;

    fn tiny_plan(seed: u64) -> NetPlan {
        plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), seed, NetPlanConfig::default()).expect("plan")
    }

    #[test]
    fn native_engine_batches() {
        let mut engine = NativeEngine::new(tiny_plan(1), "tnn-tiny");
        let mut rng = Rng::new(2);
        let images: Vec<_> = (0..4).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        let out = engine.infer_batch(&images);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|l| l.len() == 3));
        assert_eq!(engine.input_dims(), (8, 8, 1));
    }

    /// A threaded engine produces the same logits as a single-threaded one.
    #[test]
    fn threaded_engine_matches_single() {
        use crate::gemm::Threading;
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
        let mut single = NativeEngine::new(
            plan_from_config(&cfg, 1, NetPlanConfig::default()).expect("plan"),
            "single",
        );
        let mut threaded = NativeEngine::new(
            plan_from_config(&cfg, 1, NetPlanConfig::default().with_threading(Threading::Fixed(4)))
                .expect("plan"),
            "mt",
        );
        let mut rng = Rng::new(3);
        let images: Vec<_> = (0..3).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        assert_eq!(single.infer_batch(&images), threaded.infer_batch(&images));
    }

    /// Pool outputs are bit-identical across replica counts, stay in
    /// request order, and the per-replica loads account for every image.
    #[test]
    fn pool_is_replica_count_invariant() {
        let mut rng = Rng::new(4);
        let images: Vec<_> = (0..11).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        let mut pool1 = EnginePool::new(Box::new(NativeEngine::new(tiny_plan(9), "p1")), 1);
        let (want, loads1) = pool1.infer_batch(&images);
        assert_eq!(loads1, vec![11]);
        for replicas in [2usize, 3, 4, 8] {
            let mut pool = EnginePool::new(Box::new(NativeEngine::new(tiny_plan(9), "pN")), replicas);
            assert_eq!(pool.replicas(), replicas);
            let (got, loads) = pool.infer_batch(&images);
            assert_eq!(got, want, "replicas={replicas}");
            assert_eq!(loads.len(), replicas);
            assert_eq!(loads.iter().sum::<usize>(), images.len(), "replicas={replicas}");
        }
    }

    /// A mis-shaped image yields empty logits, not a panic.
    #[test]
    fn mis_shaped_image_yields_empty_logits() {
        let mut engine = NativeEngine::new(tiny_plan(5), "shape");
        let mut rng = Rng::new(6);
        let out = engine.infer_batch(&[Tensor3::random(9, 9, 1, &mut rng)]);
        assert_eq!(out, vec![Vec::<f32>::new()]);
    }

    /// A panicking replica must not shift other requests' results: its
    /// chunk degrades to empty logits of the right length, and the
    /// healthy replica's outputs stay paired with their own images.
    #[test]
    fn panicked_replica_keeps_outputs_aligned() {
        struct HealthyThenPanicking(NativeEngine);
        impl InferenceEngine for HealthyThenPanicking {
            fn infer_batch(&mut self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
                self.0.infer_batch(images)
            }
            fn input_dims(&self) -> (usize, usize, usize) {
                self.0.input_dims()
            }
            fn name(&self) -> String {
                self.0.name()
            }
            fn replicate(&self) -> Box<dyn InferenceEngine> {
                struct Panicking;
                impl InferenceEngine for Panicking {
                    fn infer_batch(&mut self, _images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
                        panic!("replica crashed (test)");
                    }
                    fn input_dims(&self) -> (usize, usize, usize) {
                        (8, 8, 1)
                    }
                    fn name(&self) -> String {
                        "panicking".into()
                    }
                    fn replicate(&self) -> Box<dyn InferenceEngine> {
                        Box::new(Panicking)
                    }
                }
                Box::new(Panicking)
            }
        }

        let mut rng = Rng::new(7);
        let images: Vec<_> = (0..4).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        let mut healthy = NativeEngine::new(tiny_plan(9), "h");
        let want = healthy.infer_batch(&images);
        // Replica 0 healthy, replica 1 panics: chunks of 2 images each.
        let mut pool =
            EnginePool::new(Box::new(HealthyThenPanicking(NativeEngine::new(tiny_plan(9), "h"))), 2);
        let (got, loads) = pool.infer_batch(&images);
        assert_eq!(got.len(), 4);
        assert_eq!(got[0], want[0]);
        assert_eq!(got[1], want[1]);
        assert_eq!(got[2], Vec::<f32>::new());
        assert_eq!(got[3], Vec::<f32>::new());
        assert_eq!(loads, vec![2, 2]);
    }
}
