//! Inference engines the coordinator can drive.

use crate::conv::tensor::Tensor3;
use crate::nn::layers::NetScratch;
use crate::nn::network::Network;
use std::cell::RefCell;

/// A batched inference engine. Implementations must be `Send` so the
/// worker thread can own them.
pub trait InferenceEngine: Send {
    /// Classify a batch of images; returns one logit vector per image.
    fn infer_batch(&self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>>;

    /// Expected input dims.
    fn input_dims(&self) -> (usize, usize, usize);

    fn name(&self) -> String;
}

/// The native low-bit engine: the paper's kernels under a [`Network`]
/// of built-once [`crate::gemm::GemmPlan`]s. Holds a per-engine
/// [`NetScratch`] arena (conv + dense arenas over the unified
/// [`crate::gemm::GemmScratch`]) reused across requests and batches, so
/// steady-state inference performs no heap allocation on the GEMM paths
/// (the worker thread owns the engine, so the `RefCell` is never
/// contended).
pub struct NativeEngine {
    pub network: Network,
    pub label: String,
    scratch: RefCell<NetScratch>,
}

impl NativeEngine {
    pub fn new(network: Network, label: impl Into<String>) -> Self {
        NativeEngine { network, label: label.into(), scratch: RefCell::new(NetScratch::new()) }
    }

    /// Run every conv GEMM under this threading config. Intra-op
    /// parallelism composes with the coordinator's batching: the worker
    /// thread fans each convolution out over row bands.
    pub fn with_threading(mut self, threading: crate::gemm::Threading) -> Self {
        self.network.set_threading(threading);
        self
    }
}

impl InferenceEngine for NativeEngine {
    fn infer_batch(&self, images: &[Tensor3<f32>]) -> Vec<Vec<f32>> {
        let scratch = &mut *self.scratch.borrow_mut();
        images.iter().map(|img| self.network.logits_with(img, scratch)).collect()
    }

    fn input_dims(&self) -> (usize, usize, usize) {
        self.network.input_dims
    }

    fn name(&self) -> String {
        self.label.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::{build_from_config, NetConfig};
    use crate::util::Rng;

    #[test]
    fn native_engine_batches() {
        let net = build_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 1);
        let engine = NativeEngine::new(net, "tnn-tiny");
        let mut rng = Rng::new(2);
        let images: Vec<_> = (0..4).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        let out = engine.infer_batch(&images);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|l| l.len() == 3));
        assert_eq!(engine.input_dims(), (8, 8, 1));
    }

    /// A threaded engine produces the same logits as a single-threaded one.
    #[test]
    fn threaded_engine_matches_single() {
        use crate::gemm::native::Threading;
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
        let single = NativeEngine::new(build_from_config(&cfg, 1), "single");
        let threaded = NativeEngine::new(build_from_config(&cfg, 1), "mt").with_threading(Threading::Fixed(4));
        let mut rng = Rng::new(3);
        let images: Vec<_> = (0..3).map(|_| Tensor3::random(8, 8, 1, &mut rng)).collect();
        assert_eq!(single.infer_batch(&images), threaded.infer_batch(&images));
    }
}
