//! Dynamic batching policy.
//!
//! The batcher only *groups* requests; how a batch is then executed is
//! the worker's business — since the replica-pool redesign it is split
//! into contiguous per-replica chunks by
//! [`crate::coordinator::engine::EnginePool::infer_batch`], so a larger
//! `max_batch` directly widens the batch-level parallelism available to
//! the pool.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest batch the worker executes at once.
    pub max_batch: usize,
    /// Longest the batcher waits after the first request of a batch.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Drain one batch from `rx` under the policy: block for the first item,
/// then collect until `max_batch` items or `max_wait` elapsed. Returns
/// `None` when the channel is closed and empty (shutdown).
pub fn next_batch<T>(rx: &Receiver<T>, cfg: &BatcherConfig) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + cfg.max_wait;
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn drains_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn returns_partial_batch_on_timeout() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b, vec![1]);
    }

    #[test]
    fn returns_none_on_shutdown() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let cfg = BatcherConfig::default();
        assert!(next_batch(&rx, &cfg).is_none());
    }

    #[test]
    fn preserves_fifo_order() {
        let (tx, rx) = channel();
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let cfg = BatcherConfig { max_batch: 7, max_wait: Duration::from_millis(1) };
        let mut seen = Vec::new();
        while let Some(b) = next_batch(&rx, &cfg) {
            assert!(b.len() <= 7);
            seen.extend(b);
        }
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
    }
}
