//! Dynamic batching policy over the server's two-lane bounded queue.
//!
//! [`LaneQueue`] is the serving layer's admission boundary: two bounded
//! FIFO lanes ([`Lane::Interactive`] always drained ahead of
//! [`Lane::Batch`]), a rolling per-request service-time estimate (EWMA,
//! fed by the worker after every executed batch) that turns queue depth
//! into an estimated wait, and a [`ShedPolicy`] for what happens when a
//! lane is full. `push` never blocks: a request that cannot meet its
//! deadline or the configured latency budget — or that finds its lane
//! full — is rejected with
//! [`SubmitError::Overloaded`](crate::coordinator::server::SubmitError)
//! instead of queueing doomed work, and [`LaneQueue::next_batch`] drops
//! already-expired requests at dequeue (answering
//! [`Response::DeadlineExceeded`]) rather than wasting engine time on
//! them.
//!
//! The batch-collection window is anchored to the *arrival* of the
//! first request in the batch (`submitted + max_wait`), not to the
//! moment the worker happened to dequeue it, so `max_wait` is an actual
//! bound on the latency the batcher itself adds — a request that
//! already waited out its window behind a slow batch is served
//! immediately with whatever else is queued.
//!
//! How a batch is then executed is the worker's business — it is split
//! into contiguous per-replica chunks by
//! [`crate::coordinator::engine::EnginePool::infer_batch`], so a larger
//! `max_batch` directly widens the batch-level parallelism available to
//! the pool.
//!
//! Synchronization goes through [`crate::util::sync`], the std/loom
//! seam: the CI loom lane model-checks the producer/consumer handoff,
//! the close-and-shed race against a concurrent `push`, and the bounded
//! admission invariant under exhaustive preemption-bounded
//! interleavings (the `loom_tests` module below).

use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{Request, Response, SubmitError};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{self, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching configuration.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Largest batch the worker executes at once.
    pub max_batch: usize,
    /// Longest a batch is held open after its first request *arrived*
    /// (an upper bound on the latency batching itself adds).
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Priority lane of a request. Interactive work is always dequeued
/// before batch-lane work, and only the interactive lane is gated by
/// the server's latency budget at admission.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Lane {
    /// Latency-sensitive traffic: drained first, admission-checked
    /// against the configured latency budget.
    #[default]
    Interactive = 0,
    /// Throughput traffic: served when the interactive lane is empty,
    /// bounded only by its queue depth (and per-request deadlines).
    Batch = 1,
}

/// What to do when a lane's bounded queue is full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the incoming request with `Overloaded` (the caller can
    /// retry with backoff; nothing already queued is disturbed).
    #[default]
    RejectNewest,
    /// On *batch-lane* overflow, evict the oldest queued batch-lane
    /// request (it is answered with [`Response::Shed`]) and admit the
    /// newer one. Interactive-lane overflow still rejects the newcomer:
    /// evicting batch work cannot create interactive-lane capacity.
    EvictOldestBatch,
}

/// Admission knobs, copied out of the public
/// [`ServerConfig`](crate::coordinator::server::ServerConfig).
#[derive(Clone, Copy, Debug)]
pub(crate) struct QueuePolicy {
    pub interactive_depth: usize,
    pub batch_depth: usize,
    pub latency_budget: Option<Duration>,
    pub shed_policy: ShedPolicy,
}

struct Inner {
    /// Indexed by `Lane as usize`.
    lanes: [VecDeque<Request>; 2],
    closed: bool,
    /// Set by a draining close: once past it, the remaining backlog is
    /// shed instead of served.
    drain_deadline: Option<Instant>,
}

/// The bounded two-lane submission queue shared by the server handle
/// (producer side: `push`) and the worker (consumer side: `next_batch`).
pub(crate) struct LaneQueue {
    policy: QueuePolicy,
    /// Rolling per-request service-time estimate, µs (0 = no data yet,
    /// which admits everything — cold starts are permissive).
    ewma_us: AtomicU64,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl LaneQueue {
    pub(crate) fn new(policy: QueuePolicy) -> Self {
        LaneQueue {
            policy,
            ewma_us: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                lanes: [VecDeque::new(), VecDeque::new()],
                closed: false,
                drain_deadline: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit or reject `req`. Never blocks: estimated-wait admission
    /// first (deadline / latency budget), then the lane depth bound
    /// under the shed policy.
    pub(crate) fn push(&self, req: Request, metrics: &Metrics) -> Result<(), SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        let lane = req.lane;
        // Work ahead of this request: the interactive lane always
        // drains first, so batch-lane requests queue behind both.
        let ahead = inner.lanes[Lane::Interactive as usize].len()
            + if lane == Lane::Batch { inner.lanes[Lane::Batch as usize].len() } else { 0 };
        let est_us = self.ewma_us.load(Ordering::Relaxed).saturating_mul(ahead as u64 + 1);
        let est = Duration::from_micros(est_us);
        let now = Instant::now();
        let misses_deadline = req.deadline.is_some_and(|d| now + est > d);
        let over_budget =
            lane == Lane::Interactive && self.policy.latency_budget.is_some_and(|b| est > b);
        if misses_deadline || over_budget {
            metrics.record_rejected();
            return Err(SubmitError::Overloaded { estimated_wait_us: est_us, queued: ahead });
        }
        let depth = match lane {
            Lane::Interactive => self.policy.interactive_depth,
            Lane::Batch => self.policy.batch_depth,
        };
        if inner.lanes[lane as usize].len() >= depth.max(1) {
            let mut admitted_by_eviction = false;
            if self.policy.shed_policy == ShedPolicy::EvictOldestBatch && lane == Lane::Batch {
                if let Some(victim) = inner.lanes[Lane::Batch as usize].pop_front() {
                    shed_one(victim, now, metrics);
                    admitted_by_eviction = true;
                }
            }
            if !admitted_by_eviction {
                metrics.record_rejected();
                return Err(SubmitError::Overloaded { estimated_wait_us: est_us, queued: ahead });
            }
        }
        inner.lanes[lane as usize].push_back(req);
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Drain one batch: block for the first live request, then collect
    /// until `max_batch` items or the first request's arrival-anchored
    /// window (`submitted + max_wait`) closes. Expired requests are
    /// answered `DeadlineExceeded` and skipped at every pop. Returns
    /// `None` when the queue is closed and drained — or, past a drain
    /// deadline, after shedding the remaining backlog.
    pub(crate) fn next_batch(&self, cfg: &BatcherConfig, metrics: &Metrics) -> Option<Vec<Request>> {
        let mut inner = self.inner.lock().unwrap();
        let first = loop {
            let now = Instant::now();
            if inner.closed && inner.drain_deadline.is_some_and(|d| now >= d) {
                shed_all(&mut inner, metrics);
                return None;
            }
            match pop_live(&mut inner, now, metrics) {
                Some(req) => break req,
                None if inner.closed => return None,
                None => inner = self.cv.wait(inner).unwrap(),
            }
        };
        let window_end = first.submitted + cfg.max_wait;
        let mut batch = vec![first];
        while batch.len() < cfg.max_batch.max(1) {
            let now = Instant::now();
            if let Some(req) = pop_live(&mut inner, now, metrics) {
                batch.push(req);
                continue;
            }
            if inner.closed || now >= window_end {
                break;
            }
            inner = sync::wait_timeout(&self.cv, inner, window_end - now);
        }
        Some(batch)
    }

    /// Stop accepting submissions. `drain: None` keeps serving until
    /// the backlog is empty; `Some(d)` serves for at most `d` longer,
    /// then the worker sheds whatever is still queued.
    pub(crate) fn close(&self, drain: Option<Duration>) {
        let mut inner = self.inner.lock().unwrap();
        if !inner.closed {
            inner.closed = true;
            inner.drain_deadline = drain.map(|d| Instant::now() + d);
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Close and immediately shed the whole backlog (the dead-worker
    /// path: nobody will ever serve these, so answer them now).
    pub(crate) fn close_and_shed(&self, metrics: &Metrics) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        shed_all(&mut inner, metrics);
        drop(inner);
        self.cv.notify_all();
    }

    /// Fold one measured per-request service time (µs) into the rolling
    /// estimate (EWMA, α = 1/4; single writer: the worker).
    pub(crate) fn update_service_rate(&self, sample_us: u64) {
        let old = self.ewma_us.load(Ordering::Relaxed);
        let new = if old == 0 { sample_us } else { (old * 3 + sample_us) / 4 };
        // Never fall back to the "no data" 0 once anything was measured.
        self.ewma_us.store(new.max(1), Ordering::Relaxed);
    }

    /// The current per-request service-time estimate, µs (0 until the
    /// first batch completes).
    pub(crate) fn service_estimate_us(&self) -> u64 {
        self.ewma_us.load(Ordering::Relaxed)
    }
}

/// Pop the next *live* request, interactive lane first. Requests whose
/// deadline already passed are answered `DeadlineExceeded` and skipped
/// — the engine never runs work nobody is waiting for.
fn pop_live(inner: &mut Inner, now: Instant, metrics: &Metrics) -> Option<Request> {
    for lane in [Lane::Interactive, Lane::Batch] {
        while let Some(req) = inner.lanes[lane as usize].pop_front() {
            let waited_us = now.saturating_duration_since(req.submitted).as_micros() as u64;
            if req.deadline.is_some_and(|d| d <= now) {
                metrics.record_expired();
                let id = req.id;
                req.finish(Response::DeadlineExceeded { id, waited_us });
                continue;
            }
            metrics.record_queue_wait(waited_us);
            return Some(req);
        }
    }
    None
}

fn shed_one(req: Request, now: Instant, metrics: &Metrics) {
    let waited_us = now.saturating_duration_since(req.submitted).as_micros() as u64;
    metrics.record_shed();
    let id = req.id;
    req.finish(Response::Shed { id, waited_us });
}

fn shed_all(inner: &mut Inner, metrics: &Metrics) {
    let now = Instant::now();
    for lane in [Lane::Interactive, Lane::Batch] {
        while let Some(req) = inner.lanes[lane as usize].pop_front() {
            shed_one(req, now, metrics);
        }
    }
}

#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use crate::conv::tensor::Tensor3;
    use std::sync::mpsc::{channel, Receiver};

    fn policy() -> QueuePolicy {
        QueuePolicy {
            interactive_depth: 64,
            batch_depth: 64,
            latency_budget: None,
            shed_policy: ShedPolicy::RejectNewest,
        }
    }

    fn req(id: u64, lane: Lane, deadline: Option<Instant>) -> (Request, Receiver<Response>) {
        let (reply, rx) = channel();
        let r = Request {
            id,
            image: Tensor3::zeros(1, 1, 1),
            submitted: Instant::now(),
            deadline,
            lane,
            reply,
        };
        (r, rx)
    }

    fn push_ok(q: &LaneQueue, m: &Metrics, id: u64, lane: Lane) -> Receiver<Response> {
        let (r, rx) = req(id, lane, None);
        q.push(r, m).expect("admitted");
        rx
    }

    #[test]
    fn drains_up_to_max_batch_in_fifo_order() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        for i in 0..10 {
            push_ok(&q, &m, i, Lane::Interactive);
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) };
        let ids = |b: Vec<Request>| b.into_iter().map(|r| r.id).collect::<Vec<_>>();
        assert_eq!(ids(q.next_batch(&cfg, &m).unwrap()), vec![0, 1, 2, 3]);
        assert_eq!(ids(q.next_batch(&cfg, &m).unwrap()), vec![4, 5, 6, 7]);
    }

    /// The collection window is anchored to the first request's
    /// *arrival*: a request that already out-waited `max_wait` in the
    /// queue is served immediately instead of being held another full
    /// window (the old per-`recv_timeout` drift).
    #[test]
    fn window_is_anchored_to_first_arrival() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        let (mut r, _rx) = req(0, Lane::Interactive, None);
        r.submitted = Instant::now()
            .checked_sub(Duration::from_millis(500))
            .expect("monotonic clock far enough from boot");
        q.push(r, &m).expect("admitted");
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(200) };
        let t0 = Instant::now();
        let b = q.next_batch(&cfg, &m).unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "stale first request must not re-open the batch window"
        );
    }

    #[test]
    fn interactive_lane_is_drained_before_batch_lane() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        for i in 0..3 {
            push_ok(&q, &m, i, Lane::Batch);
        }
        for i in 10..12 {
            push_ok(&q, &m, i, Lane::Interactive);
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) };
        let b = q.next_batch(&cfg, &m).unwrap();
        let ids: Vec<u64> = b.into_iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 0, 1], "interactive first, then batch lane, FIFO within each");
    }

    #[test]
    fn expired_requests_are_answered_and_skipped_at_dequeue() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        let (dead, dead_rx) = req(1, Lane::Interactive, Some(Instant::now()));
        q.push(dead, &m).expect("cold estimate admits everything");
        let live_rx = push_ok(&q, &m, 2, Lane::Interactive);
        std::thread::sleep(Duration::from_millis(2)); // let the deadline pass
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) };
        let b = q.next_batch(&cfg, &m).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 2);
        match dead_rx.recv().expect("expired request still gets an answer") {
            Response::DeadlineExceeded { id: 1, .. } => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(m.snapshot().expired, 1);
        drop(live_rx);
    }

    #[test]
    fn full_lane_rejects_newest_by_default() {
        let mut p = policy();
        p.interactive_depth = 2;
        let (q, m) = (LaneQueue::new(p), Metrics::new());
        let _a = push_ok(&q, &m, 0, Lane::Interactive);
        let _b = push_ok(&q, &m, 1, Lane::Interactive);
        let (r, _rx) = req(2, Lane::Interactive, None);
        match q.push(r, &m) {
            Err(SubmitError::Overloaded { queued, .. }) => assert_eq!(queued, 2),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(m.snapshot().rejected, 1);
    }

    #[test]
    fn evict_oldest_batch_policy_sheds_the_oldest_queued_batch_request() {
        let mut p = policy();
        p.batch_depth = 2;
        p.interactive_depth = 2;
        p.shed_policy = ShedPolicy::EvictOldestBatch;
        let (q, m) = (LaneQueue::new(p), Metrics::new());
        let victim_rx = push_ok(&q, &m, 0, Lane::Batch);
        let _keep = push_ok(&q, &m, 1, Lane::Batch);
        let _newest = push_ok(&q, &m, 2, Lane::Batch); // evicts id 0
        match victim_rx.recv().expect("evicted request still gets an answer") {
            Response::Shed { id: 0, .. } => {}
            other => panic!("expected Shed, got {other:?}"),
        }
        assert_eq!(m.snapshot().shed, 1);
        // Interactive overflow still rejects the newcomer: evicting
        // batch work cannot create interactive capacity.
        let _i0 = push_ok(&q, &m, 10, Lane::Interactive);
        let _i1 = push_ok(&q, &m, 11, Lane::Interactive);
        let (r, _rx) = req(12, Lane::Interactive, None);
        assert!(matches!(q.push(r, &m), Err(SubmitError::Overloaded { .. })));
    }

    /// Once the service-rate estimate warms up, admission rejects
    /// requests whose estimated wait misses their deadline or the
    /// configured interactive latency budget.
    #[test]
    fn admission_estimates_wait_from_the_service_rate() {
        let mut p = policy();
        p.latency_budget = Some(Duration::from_millis(30));
        let (q, m) = (LaneQueue::new(p), Metrics::new());
        q.update_service_rate(10_000); // 10 ms per request
        assert_eq!(q.service_estimate_us(), 10_000);
        for i in 0..5 {
            let (r, _rx) = req(i, Lane::Batch, None);
            q.push(r, &m).expect("batch lane ignores the latency budget");
        }
        // Interactive: 0 interactive ahead → est 10 ms ≤ 30 ms budget.
        let _ok = push_ok(&q, &m, 10, Lane::Interactive);
        let _ok2 = push_ok(&q, &m, 11, Lane::Interactive);
        // Third interactive: est (2+1)·10 ms = 30 ms, still ≤ budget;
        // fourth: 40 ms > budget → rejected.
        let _ok3 = push_ok(&q, &m, 12, Lane::Interactive);
        let (r, _rx) = req(13, Lane::Interactive, None);
        match q.push(r, &m) {
            Err(SubmitError::Overloaded { estimated_wait_us, .. }) => {
                assert!(estimated_wait_us > 30_000)
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // A deadline the estimate already misses is rejected on any lane
        // (3 interactive + 5 batch ahead → est 90 ms > 20 ms deadline).
        let (r, _rx) = req(14, Lane::Batch, Some(Instant::now() + Duration::from_millis(20)));
        assert!(matches!(q.push(r, &m), Err(SubmitError::Overloaded { .. })));
        assert_eq!(m.snapshot().rejected, 2);
    }

    #[test]
    fn close_serves_backlog_then_returns_none() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        let _a = push_ok(&q, &m, 0, Lane::Interactive);
        let _b = push_ok(&q, &m, 1, Lane::Interactive);
        q.close(None);
        let (r, _rx) = req(2, Lane::Interactive, None);
        assert_eq!(q.push(r, &m), Err(SubmitError::Closed));
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) };
        assert_eq!(q.next_batch(&cfg, &m).unwrap().len(), 2);
        assert!(q.next_batch(&cfg, &m).is_none());
    }

    #[test]
    fn drain_deadline_sheds_the_backlog() {
        let (q, m) = (LaneQueue::new(policy()), Metrics::new());
        let rxs: Vec<Receiver<Response>> =
            (0..3).map(|i| push_ok(&q, &m, i, Lane::Interactive)).collect();
        q.close(Some(Duration::ZERO));
        std::thread::sleep(Duration::from_millis(1)); // deadline passes
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) };
        assert!(q.next_batch(&cfg, &m).is_none());
        for rx in rxs {
            match rx.recv().expect("shed requests still get an answer") {
                Response::Shed { .. } => {}
                other => panic!("expected Shed, got {other:?}"),
            }
        }
        assert_eq!(m.snapshot().shed, 3);
    }
}

/// Exhaustive-interleaving models of the queue's producer/consumer
/// protocol, run by the CI loom lane (`cargo test --features loom --lib
/// -- loom_`). The batch window is always zero-width here so the
/// loom-degraded `wait_timeout` (a plain `wait`, see
/// [`crate::util::sync::wait_timeout`]) is never the only wake-up on
/// any modeled path.
#[cfg(all(test, feature = "loom"))]
mod loom_tests {
    use super::*;
    use crate::conv::tensor::Tensor3;
    use crate::util::sync::Arc;
    use loom::model::Builder;
    use std::sync::mpsc::{channel, Receiver};

    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = Builder::new();
        b.preemption_bound = Some(2);
        b.check(f);
    }

    fn policy() -> QueuePolicy {
        QueuePolicy {
            interactive_depth: 64,
            batch_depth: 64,
            latency_budget: None,
            shed_policy: ShedPolicy::RejectNewest,
        }
    }

    fn req(id: u64, lane: Lane) -> (Request, Receiver<Response>) {
        let (reply, rx) = channel();
        let r = Request {
            id,
            image: Tensor3::zeros(1, 1, 1),
            submitted: Instant::now(),
            deadline: None,
            lane,
            reply,
        };
        (r, rx)
    }

    /// A zero-width batch window: `next_batch` never parks in the
    /// timeout wait, so every modeled blocking edge is a `wait` with a
    /// matching `notify` (push or close).
    fn cfg() -> BatcherConfig {
        BatcherConfig { max_batch: 1, max_wait: Duration::ZERO }
    }

    /// Producer pushes one request and closes; the consumer, on every
    /// interleaving (including parking in `cv.wait` before the push),
    /// drains exactly that one request and then sees `None`.
    #[test]
    fn loom_push_vs_drain_handoff() {
        model(|| {
            let q = Arc::new(LaneQueue::new(policy()));
            let m = Arc::new(Metrics::new());
            let (r, rx) = req(1, Lane::Interactive);
            let (qp, mp) = (Arc::clone(&q), Arc::clone(&m));
            let producer = loom::thread::spawn(move || {
                qp.push(r, &mp).expect("open queue with cold estimate admits");
                qp.close(None);
            });
            let mut got = 0;
            while let Some(batch) = q.next_batch(&cfg(), &m) {
                got += batch.len();
            }
            assert_eq!(got, 1, "the handoff neither loses nor duplicates the request");
            producer.join().unwrap();
            drop(rx);
        });
    }

    /// `close_and_shed` racing a concurrent `push`: on every
    /// interleaving the request gets exactly one coherent outcome —
    /// admitted-then-shed (a `Shed` answer) or rejected at the closed
    /// gate (`Err(Closed)`, reply channel dropped unanswered) — and the
    /// worker-side `next_batch` never serves it.
    #[test]
    fn loom_close_and_shed_races_push() {
        model(|| {
            let q = Arc::new(LaneQueue::new(policy()));
            let m = Arc::new(Metrics::new());
            let (r, rx) = req(7, Lane::Batch);
            let (qp, mp) = (Arc::clone(&q), Arc::clone(&m));
            let pusher = loom::thread::spawn(move || qp.push(r, &mp).is_ok());
            q.close_and_shed(&m);
            let pushed = pusher.join().unwrap();
            assert!(q.next_batch(&cfg(), &m).is_none(), "a shed-closed queue serves nothing");
            match rx.try_recv() {
                Ok(Response::Shed { id: 7, .. }) => assert!(pushed, "a Shed answer implies the push won"),
                Err(_) => assert!(!pushed, "no answer implies the push lost to the close"),
                Ok(other) => panic!("request must be shed or rejected, got {other:?}"),
            }
        });
    }

    /// Bounded admission under racing producers: with depth 1, exactly
    /// one of two concurrent pushes is admitted on every interleaving,
    /// and the queue then drains exactly one request.
    #[test]
    fn loom_bounded_lane_admits_exactly_depth() {
        model(|| {
            let mut p = policy();
            p.interactive_depth = 1;
            let q = Arc::new(LaneQueue::new(p));
            let m = Arc::new(Metrics::new());
            let mut rxs = Vec::new();
            let handles: Vec<_> = (0..2u64)
                .map(|id| {
                    let (r, rx) = req(id, Lane::Interactive);
                    rxs.push(rx);
                    let (qp, mp) = (Arc::clone(&q), Arc::clone(&m));
                    loom::thread::spawn(move || qp.push(r, &mp).is_ok())
                })
                .collect();
            let admitted = handles.into_iter().map(|h| h.join().unwrap()).filter(|&ok| ok).count();
            assert_eq!(admitted, 1, "a depth-1 lane admits exactly one of two racing pushes");
            q.close(None);
            let batch = q.next_batch(&cfg(), &m).expect("the one admitted request drains");
            assert_eq!(batch.len(), 1);
            assert!(q.next_batch(&cfg(), &m).is_none(), "closed and drained");
            drop(rxs);
        });
    }
}
