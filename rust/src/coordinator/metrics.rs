//! Serving metrics: request counts, latency quantiles, batch-size
//! histogram, and per-replica load counters.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Shared metrics accumulator (worker writes, callers snapshot).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    latencies_us: Vec<u64>,
    batch_size_hist: BTreeMap<usize, u64>,
    replica_requests: Vec<u64>,
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub p99_latency_us: u64,
    pub max_latency_us: u64,
    /// Executed-batch-size histogram: `(batch_size, batches)` ascending.
    pub batch_size_hist: Vec<(usize, u64)>,
    /// Requests served by each engine replica (index = replica id).
    pub replica_requests: Vec<u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one executed batch: the end-to-end latency of each of its
    /// requests (µs) and how many of them each replica served.
    pub fn record_batch(&self, latencies_us: &[u64], replica_loads: &[usize]) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.requests += latencies_us.len() as u64;
        m.batch_size_sum += latencies_us.len() as u64;
        m.latencies_us.extend_from_slice(latencies_us);
        *m.batch_size_hist.entry(latencies_us.len()).or_insert(0) += 1;
        if m.replica_requests.len() < replica_loads.len() {
            m.replica_requests.resize(replica_loads.len(), 0);
        }
        for (i, &load) in replica_loads.iter().enumerate() {
            m.replica_requests[i] += load as u64;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies_us.clone();
        lat.sort_unstable();
        let q = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p) as usize]
            }
        };
        MetricsSnapshot {
            requests: m.requests,
            batches: m.batches,
            mean_batch_size: if m.batches > 0 { m.batch_size_sum as f64 / m.batches as f64 } else { 0.0 },
            p50_latency_us: q(0.5),
            p95_latency_us: q(0.95),
            p99_latency_us: q(0.99),
            max_latency_us: lat.last().copied().unwrap_or(0),
            batch_size_hist: m.batch_size_hist.iter().map(|(&s, &n)| (s, n)).collect(),
            replica_requests: m.replica_requests.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(&[100, 200, 300], &[2, 1]);
        m.record_batch(&[400], &[1, 0]);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.max_latency_us, 400);
        assert!(s.p50_latency_us >= 100 && s.p50_latency_us <= 300);
        assert!(s.p95_latency_us <= s.p99_latency_us && s.p99_latency_us <= s.max_latency_us);
    }

    #[test]
    fn batch_size_histogram_counts_batches() {
        let m = Metrics::new();
        m.record_batch(&[1, 2, 3], &[3]);
        m.record_batch(&[4, 5, 6], &[3]);
        m.record_batch(&[7], &[1]);
        let s = m.snapshot();
        assert_eq!(s.batch_size_hist, vec![(1, 1), (3, 2)]);
    }

    #[test]
    fn replica_counters_accumulate_per_index() {
        let m = Metrics::new();
        m.record_batch(&[10, 20, 30, 40], &[2, 2]);
        m.record_batch(&[50, 60, 70], &[2, 1]);
        // A later batch may report more replicas (pool resized counters).
        m.record_batch(&[80], &[0, 0, 1]);
        let s = m.snapshot();
        assert_eq!(s.replica_requests, vec![4, 3, 1]);
        assert_eq!(s.replica_requests.iter().sum::<u64>(), s.requests);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p95_latency_us, 0);
        assert_eq!(s.p99_latency_us, 0);
        assert!(s.batch_size_hist.is_empty());
        assert!(s.replica_requests.is_empty());
    }
}
