//! Serving metrics: request counts, latency quantiles, batch-size stats.

use std::sync::Mutex;

/// Shared metrics accumulator (worker writes, callers snapshot).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    latencies_us: Vec<u64>,
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_us: u64,
    pub p95_latency_us: u64,
    pub max_latency_us: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one executed batch and the end-to-end latency of each of
    /// its requests (µs).
    pub fn record_batch(&self, latencies_us: &[u64]) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.requests += latencies_us.len() as u64;
        m.batch_size_sum += latencies_us.len() as u64;
        m.latencies_us.extend_from_slice(latencies_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut lat = m.latencies_us.clone();
        lat.sort_unstable();
        let q = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p) as usize]
            }
        };
        MetricsSnapshot {
            requests: m.requests,
            batches: m.batches,
            mean_batch_size: if m.batches > 0 { m.batch_size_sum as f64 / m.batches as f64 } else { 0.0 },
            p50_latency_us: q(0.5),
            p95_latency_us: q(0.95),
            max_latency_us: lat.last().copied().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(&[100, 200, 300]);
        m.record_batch(&[400]);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.max_latency_us, 400);
        assert!(s.p50_latency_us >= 100 && s.p50_latency_us <= 300);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p95_latency_us, 0);
    }
}
