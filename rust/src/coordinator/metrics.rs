//! Serving metrics: request counts, latency and queue-wait quantiles,
//! batch-size histogram, per-lane and per-replica load counters, and
//! the overload counters (rejected / shed / expired).
//!
//! Latency and queue wait are recorded into a bounded log-linear
//! histogram ([`Hist`]): exact below 16 µs, then 8 sub-buckets per
//! power of two (≤ 12.5 % quantile error), with the exact maximum
//! tracked on the side. Memory is a fixed few KiB however long the
//! server runs — the previous unbounded `Vec<u64>` of latencies grew
//! without limit under sustained traffic, which is exactly the regime
//! the overload work targets. Quantiles are `Option<u64>`: `None` on an
//! empty histogram instead of an interpolated garbage value.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// 16 exact buckets + 8 sub-buckets for each power of two from 2^4 up
/// through 2^63.
const HIST_BUCKETS: usize = 16 + 60 * 8;

/// Bounded log-linear histogram of u64 samples (µs in this module).
#[derive(Clone)]
struct Hist {
    counts: Vec<u64>,
    total: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: vec![0; HIST_BUCKETS], total: 0, max: 0 }
    }
}

impl Hist {
    fn bucket(v: u64) -> usize {
        if v < 16 {
            return v as usize;
        }
        let top = 63 - v.leading_zeros() as usize; // ≥ 4
        let sub = ((v >> (top - 3)) - 8) as usize; // 0..8
        16 + (top - 4) * 8 + sub
    }

    /// Largest value that maps to bucket `i` (computed in u128: the top
    /// bucket's bound would overflow u64).
    fn bucket_upper(i: usize) -> u64 {
        if i < 16 {
            return i as u64;
        }
        let top = (i - 16) / 8 + 4;
        let sub = ((i - 16) % 8) as u128;
        let upper = ((9 + sub) << (top - 3)) - 1;
        upper.min(u64::MAX as u128) as u64
    }

    fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.max = self.max.max(v);
    }

    /// Nearest-rank quantile, `None` when empty. The bucket upper bound
    /// is clamped to the exact observed max, so `quantile(1.0)` — and
    /// any quantile landing in the last occupied bucket — is exact.
    fn quantile(&self, p: f64) -> Option<u64> {
        if self.total == 0 {
            return None;
        }
        let rank = ((self.total - 1) as f64 * p) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Some(Self::bucket_upper(i).min(self.max));
            }
        }
        Some(self.max)
    }
}

/// Shared metrics accumulator (worker and admission path write, callers
/// snapshot).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    batches: u64,
    batch_size_sum: u64,
    latency: Hist,
    queue_wait: Hist,
    batch_size_hist: BTreeMap<usize, u64>,
    replica_requests: Vec<u64>,
    lane_requests: [u64; 2],
    rejected: u64,
    shed: u64,
    expired: u64,
}

/// A point-in-time copy of the metrics. Quantiles are `None` until at
/// least one sample exists.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests actually served (completions only).
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub p50_latency_us: Option<u64>,
    pub p95_latency_us: Option<u64>,
    pub p99_latency_us: Option<u64>,
    pub max_latency_us: u64,
    /// Time served requests spent queued before reaching the engine
    /// (the quantity the admission estimate predicts).
    pub queue_wait_p50_us: Option<u64>,
    pub queue_wait_p99_us: Option<u64>,
    pub queue_wait_max_us: u64,
    /// Executed-batch-size histogram: `(batch_size, batches)` ascending.
    pub batch_size_hist: Vec<(usize, u64)>,
    /// Requests served by each engine replica (index = replica id).
    pub replica_requests: Vec<u64>,
    /// Served requests per lane: `[interactive, batch]`.
    pub lane_requests: [u64; 2],
    /// Submissions refused at admission (`SubmitError::Overloaded`).
    pub rejected: u64,
    /// Accepted requests dropped by load shedding (eviction, bounded
    /// drain, dead worker).
    pub shed: u64,
    /// Accepted requests whose deadline passed in the queue.
    pub expired: u64,
    /// Rolling per-request service-time estimate feeding admission, µs
    /// (0 until the first batch executes; filled in by the server).
    pub service_estimate_us: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one executed batch: the end-to-end latency of each of its
    /// requests (µs), how many of them each replica served, and how
    /// many came from each lane.
    pub fn record_batch(&self, latencies_us: &[u64], replica_loads: &[usize], lane_counts: [u64; 2]) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.requests += latencies_us.len() as u64;
        m.batch_size_sum += latencies_us.len() as u64;
        for &l in latencies_us {
            m.latency.record(l);
        }
        *m.batch_size_hist.entry(latencies_us.len()).or_insert(0) += 1;
        if m.replica_requests.len() < replica_loads.len() {
            m.replica_requests.resize(replica_loads.len(), 0);
        }
        for (i, &load) in replica_loads.iter().enumerate() {
            m.replica_requests[i] += load as u64;
        }
        m.lane_requests[0] += lane_counts[0];
        m.lane_requests[1] += lane_counts[1];
    }

    /// Queue wait of a request popped live for execution, µs. Expired
    /// and shed requests are counted separately, not here: the wait
    /// histogram describes served traffic.
    pub fn record_queue_wait(&self, wait_us: u64) {
        self.inner.lock().unwrap().queue_wait.record(wait_us);
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: m.requests,
            batches: m.batches,
            mean_batch_size: if m.batches > 0 { m.batch_size_sum as f64 / m.batches as f64 } else { 0.0 },
            p50_latency_us: m.latency.quantile(0.5),
            p95_latency_us: m.latency.quantile(0.95),
            p99_latency_us: m.latency.quantile(0.99),
            max_latency_us: m.latency.max,
            queue_wait_p50_us: m.queue_wait.quantile(0.5),
            queue_wait_p99_us: m.queue_wait.quantile(0.99),
            queue_wait_max_us: m.queue_wait.max,
            batch_size_hist: m.batch_size_hist.iter().map(|(&s, &n)| (s, n)).collect(),
            replica_requests: m.replica_requests.clone(),
            lane_requests: m.lane_requests,
            rejected: m.rejected,
            shed: m.shed,
            expired: m.expired,
            service_estimate_us: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(&[100, 200, 300], &[2, 1], [3, 0]);
        m.record_batch(&[400], &[1, 0], [0, 1]);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.max_latency_us, 400);
        let p50 = s.p50_latency_us.unwrap();
        assert!((100..=300).contains(&p50) || p50 <= 300 + 300 / 8, "p50 {} within bucket error", p50);
        assert!(s.p95_latency_us.unwrap() <= s.p99_latency_us.unwrap());
        assert!(s.p99_latency_us.unwrap() <= s.max_latency_us);
        assert_eq!(s.lane_requests, [3, 1]);
    }

    #[test]
    fn batch_size_histogram_counts_batches() {
        let m = Metrics::new();
        m.record_batch(&[1, 2, 3], &[3], [3, 0]);
        m.record_batch(&[4, 5, 6], &[3], [3, 0]);
        m.record_batch(&[7], &[1], [1, 0]);
        let s = m.snapshot();
        assert_eq!(s.batch_size_hist, vec![(1, 1), (3, 2)]);
    }

    #[test]
    fn replica_counters_accumulate_per_index() {
        let m = Metrics::new();
        m.record_batch(&[10, 20, 30, 40], &[2, 2], [4, 0]);
        m.record_batch(&[50, 60, 70], &[2, 1], [3, 0]);
        // A later batch may report more replicas (pool resized counters).
        m.record_batch(&[80], &[0, 0, 1], [1, 0]);
        let s = m.snapshot();
        assert_eq!(s.replica_requests, vec![4, 3, 1]);
        assert_eq!(s.replica_requests.iter().sum::<u64>(), s.requests);
    }

    /// The empty-histogram satellite: no samples → quantiles are `None`,
    /// never an interpolated garbage value.
    #[test]
    fn empty_snapshot_has_no_quantiles() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_latency_us, None);
        assert_eq!(s.p95_latency_us, None);
        assert_eq!(s.p99_latency_us, None);
        assert_eq!(s.queue_wait_p50_us, None);
        assert_eq!(s.queue_wait_p99_us, None);
        assert_eq!(s.max_latency_us, 0);
        assert!(s.batch_size_hist.is_empty());
        assert!(s.replica_requests.is_empty());
        assert_eq!((s.rejected, s.shed, s.expired), (0, 0, 0));
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let m = Metrics::new();
        m.record_batch(&[12_345], &[1], [1, 0]);
        let s = m.snapshot();
        // One sample: every quantile is that sample (clamped to max).
        assert_eq!(s.p50_latency_us, Some(12_345));
        assert_eq!(s.p99_latency_us, Some(12_345));
        assert_eq!(s.max_latency_us, 12_345);
    }

    #[test]
    fn hist_is_exact_below_16() {
        let mut h = Hist::default();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), Some(0));
        assert_eq!(h.quantile(1.0), Some(15));
        // rank = 15*0.5 = 7 → 8th sample (0-indexed 7) = 7.
        assert_eq!(h.quantile(0.5), Some(7));
    }

    #[test]
    fn hist_bucket_error_is_bounded() {
        let mut h = Hist::default();
        for &v in &[1_000u64, 10_000, 100_000, 1_000_000] {
            h.record(v);
            // The bucket upper bound overestimates by at most 12.5 %.
            let q = Hist { counts: h.counts.clone(), total: h.total, max: u64::MAX }
                .quantile(1.0)
                .unwrap();
            assert!(q >= v && (q - v) as f64 <= v as f64 * 0.125 + 1.0, "v={v} q={q}");
        }
    }

    #[test]
    fn hist_handles_extreme_values_without_overflow() {
        let mut h = Hist::default();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        assert_eq!(h.quantile(1.0), Some(u64::MAX));
        assert_eq!(h.quantile(0.0), Some(u64::MAX)); // clamped to exact max
    }

    #[test]
    fn queue_wait_and_overload_counters() {
        let m = Metrics::new();
        m.record_queue_wait(500);
        m.record_queue_wait(1_500);
        m.record_rejected();
        m.record_rejected();
        m.record_shed();
        m.record_expired();
        let s = m.snapshot();
        assert!(s.queue_wait_p50_us.is_some());
        assert_eq!(s.queue_wait_max_us, 1_500);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.expired, 1);
    }
}
