//! The inference server: bounded two-lane submission queue → dynamic
//! batcher → worker thread → replica pool → per-request response
//! channels.
//!
//! The worker owns an [`EnginePool`]: each dynamic batch is split into
//! contiguous per-replica chunks executed on scoped threads
//! (batch-level parallelism), composing with the per-GEMM row-band
//! threading inside each replica's plan.
//!
//! Submission never blocks and is fully typed. Every request enters a
//! priority lane ([`Lane::Interactive`] by default) and may carry a
//! deadline; [`InferenceServer::submit`] rejects with
//! [`SubmitError::Overloaded`] when the lane is full or when the
//! queue's estimated wait — queue depth × a rolling per-request
//! service-time estimate — would miss the deadline or the configured
//! interactive latency budget. Requests whose deadline passes while
//! queued are answered [`Response::DeadlineExceeded`] at dequeue
//! instead of wasting engine time, and the [`ShedPolicy`] decides
//! whether a full batch lane rejects newcomers or evicts its oldest
//! entry ([`Response::Shed`]). [`InferenceServer::shutdown`] drains
//! every pending request before joining;
//! [`InferenceServer::shutdown_within`] bounds the drain and sheds
//! whatever is still queued past the deadline.

use crate::conv::tensor::Tensor3;
use crate::coordinator::batcher::{BatcherConfig, Lane, LaneQueue, QueuePolicy, ShedPolicy};
use crate::coordinator::engine::{EnginePool, InferenceEngine};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A classification request (internal queue entry; constructed by
/// [`InferenceServer::submit`]).
pub struct Request {
    pub(crate) id: u64,
    pub(crate) image: Tensor3<f32>,
    pub(crate) submitted: Instant,
    pub(crate) deadline: Option<Instant>,
    pub(crate) lane: Lane,
    pub(crate) reply: Sender<Response>,
}

impl Request {
    /// Answer this request. The caller may have dropped its receiver
    /// (gave up waiting); that is not an error.
    pub(crate) fn finish(self, resp: Response) {
        let _ = self.reply.send(resp);
    }
}

/// A successfully served request.
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// End-to-end latency (submit → response), µs.
    pub latency_us: u64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

/// The answer to a submitted request. Under overload not every accepted
/// request completes: it may expire in the queue or be shed by policy —
/// but every accepted request gets exactly one `Response`.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Served: logits and latency.
    Completed(Completion),
    /// The request's deadline passed while it waited in the queue; the
    /// engine never ran it.
    DeadlineExceeded { id: u64, waited_us: u64 },
    /// Dropped by load shedding ([`ShedPolicy::EvictOldestBatch`]
    /// eviction, a bounded-drain shutdown, or a dead worker's backlog).
    Shed { id: u64, waited_us: u64 },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Completed(c) => c.id,
            Response::DeadlineExceeded { id, .. } | Response::Shed { id, .. } => *id,
        }
    }

    /// The completion, if this request was actually served.
    pub fn completed(self) -> Option<Completion> {
        match self {
            Response::Completed(c) => Some(c),
            _ => None,
        }
    }
}

/// Why a submission was refused. `submit` never blocks: under pressure
/// it answers immediately with `Overloaded` so the caller can back off,
/// downgrade to [`Lane::Batch`], or shed upstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control refused the request: the lane is full, or the
    /// estimated queue wait misses the request's deadline / the
    /// configured interactive latency budget.
    Overloaded {
        /// Estimated wait before this request would reach the engine,
        /// µs (queue depth × rolling per-request service time).
        estimated_wait_us: u64,
        /// Requests that were ahead of it in the queue.
        queued: usize,
    },
    /// The server is shut down or its worker died; no response will
    /// ever be produced.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded { estimated_wait_us, queued } => write!(
                f,
                "server overloaded: estimated wait {estimated_wait_us} µs behind {queued} queued requests"
            ),
            SubmitError::Closed => write!(f, "inference server is closed (worker stopped)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The server's queue is closed: the worker has shut down or died (e.g.
/// an engine panic), so no further responses will ever be produced.
///
/// Legacy error type of the pre-`ServerConfig` API; current signatures
/// report [`SubmitError::Closed`] instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerClosed;

impl std::fmt::Display for ServerClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inference server is closed (worker stopped)")
    }
}

impl std::error::Error for ServerClosed {}

/// Server configuration: batching, replication, and overload behavior.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Engine replicas in the pool (clamped to ≥ 1).
    pub replicas: usize,
    /// Bound on queued interactive-lane requests.
    pub interactive_depth: usize,
    /// Bound on queued batch-lane requests.
    pub batch_depth: usize,
    /// Interactive-lane SLO: reject at admission when the estimated
    /// queue wait exceeds it. `None` disables the budget check (depth
    /// bounds and per-request deadlines still apply).
    pub latency_budget: Option<Duration>,
    pub shed_policy: ShedPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            replicas: 1,
            interactive_depth: 64,
            batch_depth: 256,
            latency_budget: None,
            shed_policy: ShedPolicy::default(),
        }
    }
}

impl ServerConfig {
    pub fn with_batcher(mut self, batcher: BatcherConfig) -> Self {
        self.batcher = batcher;
        self
    }

    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    pub fn with_depths(mut self, interactive: usize, batch: usize) -> Self {
        self.interactive_depth = interactive;
        self.batch_depth = batch;
        self
    }

    pub fn with_latency_budget(mut self, budget: Duration) -> Self {
        self.latency_budget = Some(budget);
        self
    }

    pub fn with_shed_policy(mut self, policy: ShedPolicy) -> Self {
        self.shed_policy = policy;
        self
    }
}

/// Per-submission options: priority lane and optional deadline.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    pub lane: Lane,
    /// Absolute deadline; admission rejects the request when the
    /// estimated wait already misses it, and the batcher drops it with
    /// [`Response::DeadlineExceeded`] if it expires while queued.
    pub deadline: Option<Instant>,
}

impl SubmitOptions {
    /// Throughput traffic: [`Lane::Batch`], no deadline.
    pub fn batch() -> Self {
        SubmitOptions { lane: Lane::Batch, deadline: None }
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Deadline `d` from now.
    pub fn deadline_in(self, d: Duration) -> Self {
        self.with_deadline(Instant::now() + d)
    }
}

/// A running inference server (one worker thread over a replica pool).
pub struct InferenceServer {
    queue: Arc<LaneQueue>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl InferenceServer {
    /// Start the server over `cfg.replicas` copies of `engine`
    /// (replicas share the engine's packed plan via
    /// [`InferenceEngine::replicate`]).
    pub fn with_config(engine: Box<dyn InferenceEngine>, cfg: ServerConfig) -> Self {
        let pool = EnginePool::new(engine, cfg.replicas);
        let queue = Arc::new(LaneQueue::new(QueuePolicy {
            interactive_depth: cfg.interactive_depth,
            batch_depth: cfg.batch_depth,
            latency_budget: cfg.latency_budget,
            shed_policy: cfg.shed_policy,
        }));
        let metrics = Arc::new(Metrics::new());
        let worker_queue = Arc::clone(&queue);
        let worker_metrics = Arc::clone(&metrics);
        let batcher = cfg.batcher;
        // A dedicated long-lived thread, deliberately *not* a pool task
        // (structural-lint `thread-spawn` allowlist): pool tasks must
        // complete for their scope to return, while this loop runs for
        // the server's whole lifetime — parking it in the pool would
        // permanently eat a worker from the shared compute budget.
        let worker = std::thread::Builder::new()
            .name("tbgemm-worker".into())
            .spawn(move || worker_loop(worker_queue, pool, batcher, worker_metrics))
            .expect("spawning worker");
        InferenceServer { queue, worker: Some(worker), metrics, next_id: 0.into() }
    }

    /// Legacy constructor. `queue_depth` becomes both lane depths; the
    /// other overload knobs take their defaults. Note the semantics
    /// change that came with admission control: a full queue now
    /// *rejects* (`SubmitError::Overloaded`) instead of blocking the
    /// submitter.
    #[deprecated(since = "0.6.0", note = "use InferenceServer::with_config(engine, ServerConfig)")]
    pub fn start(
        engine: Box<dyn InferenceEngine>,
        cfg: BatcherConfig,
        queue_depth: usize,
        replicas: usize,
    ) -> Self {
        InferenceServer::with_config(
            engine,
            ServerConfig::default()
                .with_batcher(cfg)
                .with_replicas(replicas)
                .with_depths(queue_depth, queue_depth),
        )
    }

    /// Submit an image on the interactive lane with no deadline.
    /// Returns the receiver for its response. Never blocks: under
    /// pressure it returns [`SubmitError::Overloaded`] immediately.
    pub fn submit(&self, image: Tensor3<f32>) -> Result<Receiver<Response>, SubmitError> {
        self.submit_with(image, SubmitOptions::default())
    }

    /// Submit with an explicit lane and/or deadline.
    pub fn submit_with(
        &self,
        image: Tensor3<f32>,
        opts: SubmitOptions,
    ) -> Result<Receiver<Response>, SubmitError> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = Request {
            id,
            image,
            submitted: Instant::now(),
            deadline: opts.deadline,
            lane: opts.lane,
            reply,
        };
        self.queue.push(req, &self.metrics)?;
        Ok(rx)
    }

    /// Submit and wait for the response. [`SubmitError::Closed`] also
    /// covers a worker that died after accepting the request (dropped
    /// reply channel).
    pub fn infer(&self, image: Tensor3<f32>) -> Result<Response, SubmitError> {
        self.submit(image)?.recv().map_err(|_| SubmitError::Closed)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.enriched_snapshot()
    }

    fn enriched_snapshot(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        s.service_estimate_us = self.queue.service_estimate_us();
        s
    }

    /// Drain and stop the worker: the queue closes, the worker serves
    /// every already-submitted request (mid-batch shutdown included),
    /// then exits and is joined.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.queue.close(None);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.enriched_snapshot()
    }

    /// Bounded-drain shutdown: in-flight and already-dequeued work is
    /// flushed, but once `drain` has elapsed the remaining backlog is
    /// shed ([`Response::Shed`]) instead of served.
    pub fn shutdown_within(mut self, drain: Duration) -> MetricsSnapshot {
        self.queue.close(Some(drain));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.enriched_snapshot()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.queue.close(None);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Closes the queue when the worker exits — including by panic (a died
/// engine), so queued requests are shed with an answer and later
/// submissions get `SubmitError::Closed` instead of queueing forever.
struct CloseOnExit {
    queue: Arc<LaneQueue>,
    metrics: Arc<Metrics>,
}

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        self.queue.close_and_shed(&self.metrics);
    }
}

fn worker_loop(queue: Arc<LaneQueue>, mut pool: EnginePool, cfg: BatcherConfig, metrics: Arc<Metrics>) {
    let _guard = CloseOnExit { queue: Arc::clone(&queue), metrics: Arc::clone(&metrics) };
    while let Some(batch) = queue.next_batch(&cfg, &metrics) {
        let images: Vec<Tensor3<f32>> = batch.iter().map(|r| r.image.clone()).collect();
        let exec_start = Instant::now();
        let (outputs, replica_loads) = pool.infer_batch(&images);
        let exec_us = exec_start.elapsed().as_micros() as u64;
        // Feed the admission estimator: amortized per-request service
        // time of this batch (len ≥ 1 by construction).
        queue.update_service_rate(exec_us / batch.len() as u64);
        let mut latencies = Vec::with_capacity(batch.len());
        let mut lane_counts = [0u64; 2];
        let bsize = batch.len();
        // The pool keeps `outputs` aligned with `images` even when a
        // replica dies (its chunk degrades to empty logits), so this zip
        // never mispairs; a panic on the single-replica inline path
        // kills the worker instead, surfacing as `SubmitError::Closed`.
        for (req, logits) in batch.into_iter().zip(outputs) {
            let latency_us = req.submitted.elapsed().as_micros() as u64;
            latencies.push(latency_us);
            lane_counts[req.lane as usize] += 1;
            let predicted = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let id = req.id;
            req.finish(Response::Completed(Completion { id, logits, predicted, latency_us, batch_size: bsize }));
        }
        metrics.record_batch(&latencies, &replica_loads, lane_counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::nn::builder::{plan_from_config, NetConfig};
    use crate::nn::NetPlanConfig;
    use crate::util::proptest::{check, Config};
    use crate::util::Rng;

    fn tiny_server(max_batch: usize, replicas: usize) -> InferenceServer {
        let plan =
            plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 11, NetPlanConfig::default()).expect("plan");
        let engine = Box::new(NativeEngine::new(plan, "test"));
        InferenceServer::with_config(
            engine,
            ServerConfig::default()
                .with_batcher(BatcherConfig { max_batch, max_wait: Duration::from_millis(1) })
                .with_replicas(replicas)
                .with_depths(64, 64),
        )
    }

    fn complete(resp: Response) -> Completion {
        resp.completed().expect("request should have been served")
    }

    #[test]
    fn single_request_roundtrip() {
        let server = tiny_server(4, 1);
        let mut rng = Rng::new(1);
        let resp = complete(server.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up"));
        assert_eq!(resp.logits.len(), 3);
        assert!(resp.predicted < 3);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
        assert!(m.service_estimate_us > 0, "worker must feed the admission estimator");
    }

    /// Property: every submitted request receives exactly one response
    /// with its own id, regardless of batch boundaries and replica count.
    #[test]
    fn every_request_answered_exactly_once() {
        check(Config { cases: 6, base_seed: 0xF0 }, "requests answered", |rng| {
            let n = 1 + rng.below(24);
            let max_batch = 1 + rng.below(8);
            let replicas = 1 + rng.below(4);
            let server = tiny_server(max_batch, replicas);
            let mut pending = Vec::new();
            for _ in 0..n {
                let img = Tensor3::random(8, 8, 1, rng);
                pending.push(server.submit(img).expect("server up"));
            }
            let mut ids: Vec<u64> =
                pending.iter().map(|rx| rx.recv().expect("response").id()).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "each id exactly once");
            let m = server.shutdown();
            assert_eq!(m.requests, n as u64);
            assert_eq!(m.replica_requests.iter().sum::<u64>(), n as u64);
        });
    }

    /// Property: observed batch sizes never exceed max_batch, and the
    /// metrics account for every request.
    #[test]
    fn batch_sizes_bounded() {
        check(Config { cases: 4, base_seed: 0xF1 }, "batch bound", |rng| {
            let max_batch = 1 + rng.below(6);
            let server = tiny_server(max_batch, 2);
            let n = 20;
            let mut pending = Vec::new();
            for _ in 0..n {
                pending.push(server.submit(Tensor3::random(8, 8, 1, rng)).expect("server up"));
            }
            for rx in pending {
                let resp = complete(rx.recv().unwrap());
                assert!(resp.batch_size <= max_batch, "batch {} > {}", resp.batch_size, max_batch);
            }
            let m = server.shutdown();
            assert_eq!(m.requests, n as u64);
            assert!(m.mean_batch_size <= max_batch as f64 + 1e-9);
            assert_eq!(m.batch_size_hist.iter().map(|&(s, c)| s as u64 * c).sum::<u64>(), n as u64);
            assert_eq!(m.lane_requests, [n as u64, 0], "default submissions are interactive-lane");
        });
    }

    #[test]
    fn deterministic_logits_for_same_image() {
        let server = tiny_server(4, 2);
        let mut rng = Rng::new(5);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        let a = complete(server.infer(img.clone()).expect("server up"));
        let b = complete(server.infer(img).expect("server up"));
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn metrics_latency_populated() {
        let server = tiny_server(2, 1);
        let mut rng = Rng::new(6);
        for _ in 0..5 {
            server.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
        }
        let m = server.shutdown();
        assert_eq!(m.requests, 5);
        assert!(m.max_latency_us > 0);
        let (p50, p95, p99) = (
            m.p50_latency_us.expect("5 samples"),
            m.p95_latency_us.expect("5 samples"),
            m.p99_latency_us.expect("5 samples"),
        );
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 <= m.max_latency_us);
        assert!(m.queue_wait_p50_us.expect("served requests record queue wait") <= m.queue_wait_max_us);
    }

    #[test]
    fn batch_lane_submissions_are_counted_per_lane() {
        let server = tiny_server(4, 1);
        let mut rng = Rng::new(7);
        let rx_batch = server
            .submit_with(Tensor3::random(8, 8, 1, &mut rng), SubmitOptions::batch())
            .expect("server up");
        let rx_inter = server.submit(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
        complete(rx_batch.recv().unwrap());
        complete(rx_inter.recv().unwrap());
        let m = server.shutdown();
        assert_eq!(m.lane_requests, [1, 1]);
    }

    /// A generous deadline is met; responses still complete normally.
    #[test]
    fn generous_deadline_completes() {
        let server = tiny_server(4, 1);
        let mut rng = Rng::new(8);
        let rx = server
            .submit_with(
                Tensor3::random(8, 8, 1, &mut rng),
                SubmitOptions::default().deadline_in(Duration::from_secs(10)),
            )
            .expect("server up");
        complete(rx.recv().unwrap());
        let m = server.shutdown();
        assert_eq!(m.expired, 0);
        assert_eq!(m.rejected, 0);
    }
}
