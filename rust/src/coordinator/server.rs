//! The inference server: bounded submission queue → dynamic batcher →
//! worker thread → replica pool → per-request response channels.
//!
//! The worker owns an [`EnginePool`]: each dynamic batch is split into
//! contiguous per-replica chunks executed on scoped threads (batch-level
//! parallelism), composing with the per-GEMM row-band threading inside
//! each replica's plan. Submission is fully typed: [`InferenceServer::submit`]
//! returns [`ServerClosed`] instead of panicking when the worker has
//! stopped (shutdown or a died engine), and shutdown drains every
//! pending request before joining.

use crate::conv::tensor::Tensor3;
use crate::coordinator::batcher::{next_batch, BatcherConfig};
use crate::coordinator::engine::{EnginePool, InferenceEngine};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A classification request.
pub struct Request {
    pub id: u64,
    pub image: Tensor3<f32>,
    submitted: Instant,
    reply: Sender<Response>,
}

/// A classification response.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    pub latency_us: u64,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

/// The server's queue is closed: the worker has shut down or died (e.g.
/// an engine panic), so no further responses will ever be produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerClosed;

impl std::fmt::Display for ServerClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inference server is closed (worker stopped)")
    }
}

impl std::error::Error for ServerClosed {}

/// A running inference server (one worker thread over a replica pool).
pub struct InferenceServer {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl InferenceServer {
    /// Start the server over `replicas` copies of `engine` (clamped to
    /// ≥ 1; replicas share the engine's packed plan via
    /// [`InferenceEngine::replicate`]). `queue_depth` bounds the
    /// submission queue (backpressure: submit blocks when full).
    pub fn start(
        engine: Box<dyn InferenceEngine>,
        cfg: BatcherConfig,
        queue_depth: usize,
        replicas: usize,
    ) -> Self {
        let pool = EnginePool::new(engine, replicas);
        let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(queue_depth);
        let metrics = Arc::new(Metrics::new());
        let worker_metrics = Arc::clone(&metrics);
        let worker = std::thread::Builder::new()
            .name("tbgemm-worker".into())
            .spawn(move || worker_loop(rx, pool, cfg, worker_metrics))
            .expect("spawning worker");
        InferenceServer { tx: Some(tx), worker: Some(worker), metrics, next_id: 0.into() }
    }

    /// Submit an image; returns the receiver for its response, or
    /// [`ServerClosed`] when the worker is gone (never panics). Blocks
    /// while the queue is full (backpressure).
    pub fn submit(&self, image: Tensor3<f32>) -> Result<Receiver<Response>, ServerClosed> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = Request { id, image, submitted: Instant::now(), reply };
        match self.tx.as_ref() {
            Some(tx) => tx.send(req).map_err(|_| ServerClosed)?,
            None => return Err(ServerClosed),
        }
        Ok(rx)
    }

    /// Submit and wait for the response. [`ServerClosed`] also covers a
    /// worker that died after accepting the request (dropped reply).
    pub fn infer(&self, image: Tensor3<f32>) -> Result<Response, ServerClosed> {
        self.submit(image)?.recv().map_err(|_| ServerClosed)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain and stop the worker: the queue closes, the worker serves
    /// every already-submitted request (mid-batch shutdown included),
    /// then exits and is joined.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.tx.take(); // close the channel; worker drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Receiver<Request>, mut pool: EnginePool, cfg: BatcherConfig, metrics: Arc<Metrics>) {
    while let Some(batch) = next_batch(&rx, &cfg) {
        let images: Vec<Tensor3<f32>> = batch.iter().map(|r| r.image.clone()).collect();
        let (outputs, replica_loads) = pool.infer_batch(&images);
        let mut latencies = Vec::with_capacity(batch.len());
        let bsize = batch.len();
        // The pool keeps `outputs` aligned with `images` even when a
        // replica dies (its chunk degrades to empty logits), so this zip
        // never mispairs; a panic on the single-replica inline path
        // kills the worker instead, surfacing as `ServerClosed`.
        for (req, logits) in batch.into_iter().zip(outputs) {
            let latency_us = req.submitted.elapsed().as_micros() as u64;
            latencies.push(latency_us);
            let predicted = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0);
            // Receiver may have been dropped (caller gave up): ignore.
            let _ = req.reply.send(Response { id: req.id, logits, predicted, latency_us, batch_size: bsize });
        }
        metrics.record_batch(&latencies, &replica_loads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::nn::builder::{plan_from_config, NetConfig};
    use crate::nn::NetPlanConfig;
    use crate::util::proptest::{check, Config};
    use crate::util::Rng;
    use std::time::Duration;

    fn tiny_server(max_batch: usize, replicas: usize) -> InferenceServer {
        let plan =
            plan_from_config(&NetConfig::tiny_tnn(8, 8, 1, 3), 11, NetPlanConfig::default()).expect("plan");
        let engine = Box::new(NativeEngine::new(plan, "test"));
        InferenceServer::start(
            engine,
            BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
            64,
            replicas,
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let server = tiny_server(4, 1);
        let mut rng = Rng::new(1);
        let resp = server.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
        assert_eq!(resp.logits.len(), 3);
        assert!(resp.predicted < 3);
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
    }

    /// Property: every submitted request receives exactly one response
    /// with its own id, regardless of batch boundaries and replica count.
    #[test]
    fn every_request_answered_exactly_once() {
        check(Config { cases: 6, base_seed: 0xF0 }, "requests answered", |rng| {
            let n = 1 + rng.below(24);
            let max_batch = 1 + rng.below(8);
            let replicas = 1 + rng.below(4);
            let server = tiny_server(max_batch, replicas);
            let mut pending = Vec::new();
            for _ in 0..n {
                let img = Tensor3::random(8, 8, 1, rng);
                pending.push(server.submit(img).expect("server up"));
            }
            let mut ids: Vec<u64> = pending.iter().map(|rx| rx.recv().expect("response").id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "each id exactly once");
            let m = server.shutdown();
            assert_eq!(m.requests, n as u64);
            assert_eq!(m.replica_requests.iter().sum::<u64>(), n as u64);
        });
    }

    /// Property: observed batch sizes never exceed max_batch, and the
    /// metrics account for every request.
    #[test]
    fn batch_sizes_bounded() {
        check(Config { cases: 4, base_seed: 0xF1 }, "batch bound", |rng| {
            let max_batch = 1 + rng.below(6);
            let server = tiny_server(max_batch, 2);
            let n = 20;
            let mut pending = Vec::new();
            for _ in 0..n {
                pending.push(server.submit(Tensor3::random(8, 8, 1, rng)).expect("server up"));
            }
            for rx in pending {
                let resp = rx.recv().unwrap();
                assert!(resp.batch_size <= max_batch, "batch {} > {}", resp.batch_size, max_batch);
            }
            let m = server.shutdown();
            assert_eq!(m.requests, n as u64);
            assert!(m.mean_batch_size <= max_batch as f64 + 1e-9);
            assert_eq!(m.batch_size_hist.iter().map(|&(s, c)| s as u64 * c).sum::<u64>(), n as u64);
        });
    }

    #[test]
    fn deterministic_logits_for_same_image() {
        let server = tiny_server(4, 2);
        let mut rng = Rng::new(5);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        let a = server.infer(img.clone()).expect("server up");
        let b = server.infer(img).expect("server up");
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn metrics_latency_populated() {
        let server = tiny_server(2, 1);
        let mut rng = Rng::new(6);
        for _ in 0..5 {
            server.infer(Tensor3::random(8, 8, 1, &mut rng)).expect("server up");
        }
        let m = server.shutdown();
        assert_eq!(m.requests, 5);
        assert!(m.max_latency_us > 0);
        assert!(m.p50_latency_us <= m.p95_latency_us);
        assert!(m.p95_latency_us <= m.p99_latency_us);
    }
}
