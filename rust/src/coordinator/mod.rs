//! The serving coordinator: a batching inference server over a pool of
//! QNN engine replicas, in the style of production model routers.
//!
//! The paper motivates its kernels with "recognition on mobile devices";
//! this module is the deployment harness around them: requests enter a
//! bounded two-lane queue ([`batcher::Lane::Interactive`] drained ahead
//! of [`batcher::Lane::Batch`]) guarded by deadline-aware admission
//! control, a dynamic batcher groups them (up to `max_batch`, waiting
//! at most `max_wait` after the first request *arrived*), a worker
//! thread splits each batch across the [`engine::EnginePool`]'s
//! replicas — thin [`crate::nn::NetPlan`] + scratch holders sharing one
//! set of packed weights — and latency / queue-wait / throughput /
//! per-lane / per-replica metrics are recorded. Replica-level batch
//! parallelism composes with the per-GEMM row-band
//! [`crate::gemm::Threading`] inside each plan.
//!
//! Overload is a typed, first-class outcome, not an emergent stall:
//! `submit` never blocks — admission rejects with
//! [`server::SubmitError::Overloaded`] when a lane is full or the
//! estimated wait misses the deadline / latency budget, queued requests
//! whose deadline passes are answered
//! [`server::Response::DeadlineExceeded`] at dequeue, and the
//! [`batcher::ShedPolicy`] + bounded-drain
//! [`server::InferenceServer::shutdown_within`] decide what gets shed
//! under pressure. `repro bench-serve` measures the resulting
//! saturation curve as `BENCH_overload.json`.
//!
//! Everything is std-only (threads + channels + Condvar): the build
//! environment has no async runtime, and a CPU inference server at this
//! scale is well-served by one worker thread fanning out to scoped
//! replica threads.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{BatcherConfig, Lane, ShedPolicy};
pub use engine::{DelayEngine, EnginePool, InferenceEngine, NativeEngine};
pub use metrics::MetricsSnapshot;
pub use server::{
    Completion, InferenceServer, Request, Response, ServerClosed, ServerConfig, SubmitError,
    SubmitOptions,
};
