//! The serving coordinator: a batching inference server over a pool of
//! QNN engine replicas, in the style of production model routers.
//!
//! The paper motivates its kernels with "recognition on mobile devices";
//! this module is the deployment harness around them: requests enter a
//! bounded queue, a dynamic batcher groups them (up to `max_batch`,
//! waiting at most `max_wait` after the first request), a worker thread
//! splits each batch across the [`engine::EnginePool`]'s replicas —
//! thin [`crate::nn::NetPlan`] + scratch holders sharing one set of
//! packed weights — and latency / throughput / per-replica metrics are
//! recorded. Replica-level batch parallelism composes with the per-GEMM
//! row-band [`crate::gemm::Threading`] inside each plan.
//!
//! Everything is std-only (threads + channels): the build environment has
//! no async runtime, and a CPU inference server at this scale is
//! well-served by one worker thread fanning out to scoped replica
//! threads.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::BatcherConfig;
pub use engine::{EnginePool, InferenceEngine, NativeEngine};
pub use metrics::MetricsSnapshot;
pub use server::{InferenceServer, Request, Response, ServerClosed};
