//! The serving coordinator: a batching inference server over the QNN
//! engine (or an XLA-compiled model), in the style of production model
//! routers.
//!
//! The paper motivates its kernels with "recognition on mobile devices";
//! this module is the deployment harness around them: requests enter a
//! bounded queue, a dynamic batcher groups them (up to `max_batch`,
//! waiting at most `max_wait` after the first request), a worker thread
//! executes the batch on an [`engine::InferenceEngine`], and latency /
//! throughput metrics are recorded.
//!
//! Everything is std-only (threads + channels): the build environment has
//! no async runtime, and a CPU inference server at this scale is
//! well-served by a worker thread per engine.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::BatcherConfig;
pub use engine::{InferenceEngine, NativeEngine};
pub use metrics::MetricsSnapshot;
pub use server::{InferenceServer, Request, Response};
