//! `repro` — the command-line entry point of the reproduction.
//!
//! Subcommands map one-to-one onto the paper's experiments (see
//! DESIGN.md's per-experiment index):
//!
//! * `table1`   — print the ternary / ternary-binary truth tables.
//! * `table2`   — regenerate Table II from the emulated microkernels.
//! * `table3`   — measure the Table III ratio matrix on the native paths
//!                (`--predicted` for the Cortex-A73 cost-model variant,
//!                `--smoke` for a 4-point grid, `--reps N`, `--inner N`).
//! * `headline` — the abstract's speedup claims, ours vs the paper's.
//! * `limits`   — eq. (4)/(5) overflow and channel limits.
//! * `explain <algo>` — the microkernel's instruction stream (the textual
//!                rendering of the paper's Figs. 1–3).
//! * `infer`    — run the QNN engine on synthetic images (TNN/TBN/BNN).
//! * `serve`    — start the batching coordinator over a replica pool
//!                (`--replicas N`) and run a load test; emits the
//!                machine-readable `BENCH_serve.json`.
//! * `bench-serve` — open-loop overload benchmark: Poisson arrivals at
//!                `--rps` for `--duration` seconds (`--ramp` sweeps a
//!                multiplier grid to expose the throughput/p99 knee);
//!                emits `BENCH_overload.json`.
//! * `tune`     — autotune the native GEMM configs over the bench shapes
//!                and persist the winners (`--fast` for the CI-sized
//!                budget, `--out PATH` to pick the file; defaults to
//!                `TBGEMM_TUNE_FILE` or `tbgemm_tune.json`).
//! * `xla <artifact>` — load an AOT artifact and execute it.

use tbgemm::bench::{grid, predicted, ratio};
use tbgemm::conv::conv2d::ConvKind;
use tbgemm::conv::tensor::Tensor3;
use tbgemm::coordinator::{
    BatcherConfig, DelayEngine, InferenceEngine, InferenceServer, NativeEngine, Response,
    ServerConfig, SubmitError,
};
use tbgemm::costmodel::table2;
use tbgemm::gemm::encode;
use tbgemm::gemm::Kind;
use tbgemm::nn::builder::{plan_from_config, NetConfig};
use tbgemm::nn::{NetOut, NetPlanConfig};
use tbgemm::quant::overflow;
#[cfg(feature = "xla")]
use tbgemm::runtime::XlaRuntime;
use tbgemm::simd::reg::Neon;
use tbgemm::tune::{self, measure};
use tbgemm::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str| -> Option<String> {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
    };
    match cmd {
        "table1" => cmd_table1(),
        "table2" => cmd_table2(),
        "table3" => {
            let reps: usize = opt("--reps").and_then(|s| s.parse().ok()).unwrap_or(3);
            let inner: usize = opt("--inner").and_then(|s| s.parse().ok()).unwrap_or(5);
            cmd_table3(flag("--predicted"), flag("--smoke"), reps, inner);
        }
        "headline" => {
            let reps: usize = opt("--reps").and_then(|s| s.parse().ok()).unwrap_or(3);
            cmd_headline(reps);
        }
        "limits" => cmd_limits(),
        "explain" => cmd_explain(args.get(1).map(String::as_str).unwrap_or("tnn")),
        "infer" => cmd_infer(
            opt("--kind").unwrap_or_else(|| "tnn".into()),
            opt("--images").and_then(|s| s.parse().ok()).unwrap_or(32),
        ),
        "serve" => cmd_serve(
            opt("--requests").and_then(|s| s.parse().ok()).unwrap_or(256),
            opt("--batch").and_then(|s| s.parse().ok()).unwrap_or(8),
            parse_threading(opt("--threads").as_deref()),
            opt("--replicas").and_then(|s| s.parse().ok()).unwrap_or(1),
        ),
        "bench-serve" => cmd_bench_serve(BenchServeOpts {
            rps: opt("--rps").and_then(|s| s.parse().ok()).unwrap_or(100.0),
            duration_s: opt("--duration").and_then(|s| s.parse().ok()).unwrap_or(2.0),
            ramp: flag("--ramp"),
            batch: opt("--batch").and_then(|s| s.parse().ok()).unwrap_or(8),
            threading: parse_threading(opt("--threads").as_deref()),
            replicas: opt("--replicas").and_then(|s| s.parse().ok()).unwrap_or(1),
            budget_ms: opt("--budget-ms").and_then(|s| s.parse().ok()),
            delay_us: opt("--delay-us").and_then(|s| s.parse().ok()).unwrap_or(0),
        }),
        "tune" => cmd_tune(flag("--fast"), opt("--out")),
        #[cfg(feature = "xla")]
        "xla" => cmd_xla(args.get(1).map(String::as_str).unwrap_or("artifacts/model.hlo.txt")),
        #[cfg(not(feature = "xla"))]
        "xla" => {
            eprintln!(
                "this binary was built without the `xla` feature; add the `xla` and `anyhow` \
                 crates to rust/Cargo.toml [dependencies] (kept out of the offline default \
                 build — see the Cargo.toml [features] note), then rebuild with `--features xla`"
            );
            std::process::exit(1);
        }
        _ => {
            println!("repro — 'Fast matrix multiplication for binary and ternary CNNs' reproduction");
            println!(
                "usage: repro <table1|table2|table3|headline|limits|explain|infer|serve|bench-serve|tune|xla> \
                 [flags]"
            );
            println!("  table3 flags: --predicted --smoke --reps N --inner N");
            println!("  tune flags:   --fast --out PATH");
            println!("  infer flags:  --kind tnn|tbn|bnn --images N");
            println!("  serve flags:  --requests N --batch N --threads auto|N --replicas N");
            println!(
                "  bench-serve flags: --rps N --duration S --ramp --batch N --threads auto|N \
                 --replicas N --budget-ms N --delay-us N"
            );
        }
    }
}

fn cmd_table1() {
    println!("Table I — ternary multiplication z = x·y (2-bit encoding)");
    println!(" x  y |  z | x+ x- y+ y- z+ z-");
    for x in [1i8, 0, -1] {
        for y in [1i8, 0, -1] {
            let (xp, xm) = encode::encode_ternary(x);
            let (yp, ym) = encode::encode_ternary(y);
            let (zp, zm) = encode::ternary_mul(xp, xm, yp, ym);
            println!("{x:>2} {y:>2} | {:>2} |  {xp}  {xm}  {yp}  {ym}  {zp}  {zm}", x * y);
        }
    }
    println!("\nTable I — ternary-binary multiplication u = x·y");
    println!(" x  y |  u | x+ x- yb u+ u-");
    for x in [1i8, 0, -1] {
        for y in [1i8, -1] {
            let (xp, xm) = encode::encode_ternary(x);
            let yb = encode::encode_binary(y);
            let (up, um) = encode::tbn_mul(xp, xm, yb);
            println!("{x:>2} {y:>2} | {:>2} |  {xp}  {xm}  {yb}  {up}  {um}", x * y);
        }
    }
}

fn cmd_table2() {
    let rows = table2::generate();
    print!("{}", table2::render(&rows));
}

/// The (kind, shape) points `repro tune` sweeps: every kind at the small
/// bench shape, plus the deep/threaded shapes where the config choice
/// actually matters. `--fast` keeps only the small shapes so the CI
/// smoke finishes in seconds.
fn tune_sweep(fast: bool) -> Vec<(Kind, (usize, usize, usize))> {
    let mut points: Vec<(Kind, (usize, usize, usize))> =
        Kind::ALL.iter().map(|&k| (k, (120, 48, 256))).collect();
    points.push((Kind::Bnn, (32, 32, 256)));
    if !fast {
        for kind in [Kind::Bnn, Kind::Tnn, Kind::Tbn] {
            points.push((kind, (256, 256, 2048)));
        }
        for kind in [Kind::Bnn, Kind::Tnn] {
            points.push((kind, (128, 128, 8192)));
            points.push((kind, (128, 128, 32768)));
        }
    }
    points
}

/// `repro tune [--fast] [--out PATH]` — rank every legal candidate with
/// the cost model, time the top of the ranking through real plan runs,
/// record each point's measured winner, write the tuning file, and prove
/// it round-trips through the loader this host will use.
fn cmd_tune(fast: bool, out: Option<String>) {
    let budget = if fast { measure::Budget::fast() } else { measure::Budget::full() };
    let workers = tbgemm::util::pool::default_workers();
    let points = tune_sweep(fast);
    println!(
        "autotuning {} (kind, shape) points on host {} (top-{} candidates, ≤{} iters or {:.0} ms each)...",
        points.len(),
        tune::store::host_fingerprint(),
        budget.top_k,
        budget.max_iters,
        budget.min_time_s * 1e3,
    );
    let mut store = tune::TuningStore::empty();
    for (kind, shape) in points {
        let cands = tune::candidates(kind, shape, workers);
        let ranked = tune::rank_predicted(kind, shape, &cands);
        let top: Vec<tune::Choice> = ranked.iter().map(|(c, _)| *c).collect();
        let timed = match measure::refine(kind, shape, &top, budget, 0x7AB1E5) {
            Ok(timed) => timed,
            Err(e) => {
                eprintln!("  {} {shape:?}: skipped ({e})", kind.label());
                continue;
            }
        };
        // `candidates` never returns an empty set, so refine timed ≥ 1.
        let (winner, ns) = timed[0];
        let predicted =
            ranked.iter().find(|(c, _)| *c == winner).map(|(_, cost)| cost.total()).unwrap_or(0.0);
        println!(
            "  {:<6} {:>5}x{:<5}x{:<6} -> {:<24} {:>12.0} ns/run  ({} of {} candidates timed)",
            kind.label(),
            shape.0,
            shape.1,
            shape.2,
            winner.label(),
            ns,
            timed.len(),
            cands.len(),
        );
        store.record(kind, shape, winner, ns, predicted);
    }
    let path =
        out.or_else(tbgemm::util::env::tune_file).unwrap_or_else(|| "tbgemm_tune.json".into());
    match store.save(&path) {
        Ok(()) => println!("wrote {path} ({} entries)", store.entries.len()),
        Err(e) => {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
    }
    // Acceptance check: the file we just wrote must load cleanly through
    // the exact (parse + version + host) path `resolve` will use.
    let reloaded = tune::TuningStore::load(&path).expect("the tuning file just written must load");
    assert_eq!(reloaded, store, "tuning file must round-trip losslessly");
    println!("round-trip OK: {path} loads on this host; export TBGEMM_TUNE_FILE={path} to use it");
}

fn cmd_table3(use_predicted: bool, smoke: bool, reps: usize, inner: usize) {
    let g = if smoke { grid::smoke_grid() } else { grid::paper_grid() };
    let times = if use_predicted {
        println!("predicting with the Cortex-A73 cost model over {} grid points...", g.len());
        predicted::predict_grid(&g)
    } else {
        println!("measuring native paths over {} grid points (reps={reps}, inner={inner})...", g.len());
        Kind::ALL
            .iter()
            .map(|&k| {
                eprintln!("  timing {}...", k.label());
                grid::time_algorithm(k, &g, reps, inner, 0x7AB1E3)
            })
            .collect()
    };
    let m = ratio::ratio_matrix(&times);
    let title = if use_predicted {
        "Table III (predicted, Cortex-A73 cost model)"
    } else {
        "Table III (measured, native paths on this host)"
    };
    print!("{}", ratio::render_ratio_table(&m, title));
    println!("\nHeadline comparisons:");
    for (desc, ours, paper) in ratio::headline(&m) {
        println!("  {desc:<40} ours {ours:>5.2}  paper {paper:>5.2}");
    }
}

fn cmd_headline(reps: usize) {
    let g = grid::paper_grid();
    println!("measuring native paths over the paper grid (reps={reps})...");
    let times: Vec<_> = Kind::ALL.iter().map(|&k| grid::time_algorithm(k, &g, reps, 5, 0x7AB1E4)).collect();
    let m = ratio::ratio_matrix(&times);
    for (desc, ours, paper) in ratio::headline(&m) {
        println!("{desc:<40} ours {ours:>5.2}  paper {paper:>5.2}");
    }
}

fn cmd_limits() {
    println!("eq. (4) overflow limits (k_max) and eq. (5) channel limits (3×3 kernel):");
    println!("{:<6} {:>9} {:>12}", "Algo", "k_max", "C_in_max@3x3");
    for k in Kind::ALL {
        match k.k_max() {
            Some(km) => println!("{:<6} {:>9} {:>12}", k.label(), km, overflow::c_in_max(km, 3, 3)),
            None => println!("{:<6} {:>9} {:>12}", k.label(), "—", "—"),
        }
    }
}

fn cmd_explain(algo: &str) {
    use tbgemm::gemm::micro;
    use tbgemm::gemm::pack;
    use tbgemm::util::mat::MatI8;
    let mut rng = Rng::new(1);
    let mut cpu = Neon::recording();
    match algo {
        "bnn" => {
            let a = MatI8::random_binary(16, 8, &mut rng);
            let b = MatI8::random_binary(8, 8, &mut rng);
            micro::bnn_microkernel(&mut cpu, &pack::pack_a_bnn(&a, 0, 8), &pack::pack_b_bnn(&b, 0, 8), 1);
            println!("BNN microkernel (Fig. 1), one 16×8×8 iteration:");
        }
        "tnn" => {
            let a = MatI8::random_ternary(16, 8, &mut rng);
            let b = MatI8::random_ternary(8, 8, &mut rng);
            micro::tnn_microkernel(&mut cpu, &pack::pack_a_tnn(&a, 0, 8), &pack::pack_b_tnn(&b, 0, 8), 1);
            println!("TNN microkernel (Fig. 2), one 16×8×8 iteration:");
        }
        "tbn" => {
            let a = MatI8::random_ternary(16, 8, &mut rng);
            let b = MatI8::random_binary(8, 8, &mut rng);
            micro::tbn_microkernel(&mut cpu, &pack::pack_a_tnn(&a, 0, 8), &pack::pack_b_bnn(&b, 0, 8), 1);
            println!("TBN microkernel (Fig. 3), one 16×8×8 iteration:");
        }
        other => {
            println!("unknown algo '{other}' (expected bnn|tnn|tbn)");
            return;
        }
    }
    for (i, mnem) in cpu.trace.log.iter().enumerate() {
        print!("{mnem:<11}");
        if (i + 1) % 8 == 0 {
            println!();
        }
    }
    println!();
    println!(
        "totals: COM={} LD={} MOV={} (INS = {:.3})",
        cpu.trace.com,
        cpu.trace.ld,
        cpu.trace.mov,
        cpu.trace.ins_metric(16, 8, 8)
    );
}

fn parse_kind(s: &str) -> ConvKind {
    match s {
        "bnn" => ConvKind::Bnn,
        "tbn" => ConvKind::Tbn,
        _ => ConvKind::Tnn,
    }
}

/// `--threads auto|N` → a GEMM threading config (default single). The
/// config lands on every layer's [`tbgemm::gemm::GemmPlan`] through the
/// [`NetPlanConfig`] handed to `NetPlan::build`.
fn parse_threading(s: Option<&str>) -> tbgemm::gemm::Threading {
    use tbgemm::gemm::Threading;
    match s {
        Some("auto") => Threading::Auto,
        Some(n) => n.parse().map(Threading::Fixed).unwrap_or(Threading::Single),
        None => Threading::Single,
    }
}

fn cmd_infer(kind: String, images: usize) {
    let kind = parse_kind(&kind);
    let cfg = NetConfig::mobile_cnn(kind, 28, 28, 1, 10);
    println!("building {kind:?} mobile CNN plan ({} params)...", cfg.param_count());
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("valid built-in config");
    let mut scratch = plan.make_scratch();
    let mut out = NetOut::new();
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let mut hist = [0usize; 10];
    for _ in 0..images {
        let img = Tensor3::random(28, 28, 1, &mut rng);
        plan.run(&img, &mut out, &mut scratch).expect("plan-shaped image");
        hist[out.predicted()] += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("classified {images} images in {:.1} ms ({:.1} img/s)", dt * 1e3, images as f64 / dt);
    println!("class histogram: {hist:?}");
}

/// Build the serving model (the TNN mobile CNN every serving command
/// uses), optionally wrapped in a [`DelayEngine`] to make service time
/// deterministic for overload experiments.
fn serving_engine(threading: tbgemm::gemm::Threading, delay_us: u64) -> Box<dyn InferenceEngine> {
    let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
    let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default().with_threading(threading))
        .expect("valid built-in config");
    let engine: Box<dyn InferenceEngine> = Box::new(NativeEngine::new(plan, "tnn-mobile"));
    if delay_us > 0 {
        Box::new(DelayEngine::new(engine, std::time::Duration::from_micros(delay_us)))
    } else {
        engine
    }
}

fn cmd_serve(requests: usize, batch: usize, threading: tbgemm::gemm::Threading, replicas: usize) {
    let server = InferenceServer::with_config(
        serving_engine(threading, 0),
        ServerConfig::default()
            .with_batcher(BatcherConfig { max_batch: batch, ..Default::default() })
            .with_replicas(replicas)
            .with_depths(128, 128),
    );
    println!(
        "serving {requests} requests (max_batch={batch}, replicas={replicas}, gemm threading {threading:?})..."
    );
    let mut rng = Rng::new(9);
    let t0 = std::time::Instant::now();
    // Closed-loop with a generously deep queue: ride out transient
    // Overloaded rejections (admission never blocks) with a short nap.
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        let img = Tensor3::random(28, 28, 1, &mut rng);
        loop {
            match server.submit(img.clone()) {
                Ok(rx) => break pending.push(rx),
                Err(SubmitError::Overloaded { .. }) => {
                    std::thread::sleep(std::time::Duration::from_micros(200))
                }
                Err(SubmitError::Closed) => panic!("server closed mid-benchmark"),
            }
        }
    }
    for rx in pending {
        rx.recv().expect("response");
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    let throughput = requests as f64 / dt;
    let (p50, p95, p99) = (
        m.p50_latency_us.unwrap_or(0),
        m.p95_latency_us.unwrap_or(0),
        m.p99_latency_us.unwrap_or(0),
    );
    println!("throughput: {throughput:.1} req/s");
    println!(
        "batches: {} (mean size {:.2}); latency p50={}µs p95={}µs p99={}µs max={}µs",
        m.batches, m.mean_batch_size, p50, p95, p99, m.max_latency_us
    );
    println!("per-replica requests: {:?}", m.replica_requests);
    // Machine-readable record for the serving bench gate (the serving
    // sibling of gemm_micro's BENCH_gemm.json).
    let hist: Vec<String> = m.batch_size_hist.iter().map(|(s, n)| format!("[{s},{n}]")).collect();
    let reps: Vec<String> = m.replica_requests.iter().map(|r| r.to_string()).collect();
    let json = format!(
        "{{\"requests\":{requests},\"max_batch\":{batch},\"replicas\":{replicas},\
\"throughput_rps\":{throughput:.1},\"p50_latency_us\":{p50},\"p95_latency_us\":{p95},\
\"p99_latency_us\":{p99},\"max_latency_us\":{},\"mean_batch_size\":{:.3},\
\"batch_size_hist\":[{}],\"replica_requests\":[{}]}}\n",
        m.max_latency_us,
        m.mean_batch_size,
        hist.join(","),
        reps.join(","),
    );
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("failed to write BENCH_serve.json: {e}"),
    }
}

struct BenchServeOpts {
    rps: f64,
    duration_s: f64,
    ramp: bool,
    batch: usize,
    threading: tbgemm::gemm::Threading,
    replicas: usize,
    budget_ms: Option<u64>,
    delay_us: u64,
}

/// One measured point of the saturation curve.
struct OverloadPoint {
    rps: f64,
    offered: u64,
    completed: u64,
    rejected: u64,
    expired: u64,
    shed: u64,
    throughput_rps: f64,
    drain_s: f64,
    p50_latency_us: u64,
    p99_latency_us: u64,
    max_latency_us: u64,
}

/// Nearest-rank percentile over an already-sorted sample (client-side
/// exact, unlike the server's bounded histogram): the ⌈p·N⌉-th smallest
/// sample (1-based), clamped to the sample range. 0 on an empty sample.
fn pctl(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Drive one open-loop point: Poisson arrivals at `rps` against a fresh
/// server for `duration_s` seconds, then drain and classify every
/// accepted request's outcome. Served throughput is measured over the
/// offer window only; the shutdown drain is timed separately
/// (`drain_s`), so throughput near and past the knee is not understated
/// by the drain tail.
fn drive_open_loop(opts: &BenchServeOpts, rps: f64, rng: &mut Rng) -> OverloadPoint {
    let mut cfg = ServerConfig::default()
        .with_batcher(BatcherConfig { max_batch: opts.batch, ..Default::default() })
        .with_replicas(opts.replicas);
    if let Some(ms) = opts.budget_ms {
        cfg = cfg.with_latency_budget(std::time::Duration::from_millis(ms));
    }
    let server = InferenceServer::with_config(serving_engine(opts.threading, opts.delay_us), cfg);
    let images: Vec<_> = (0..16).map(|_| Tensor3::random(28, 28, 1, rng)).collect();
    let t0 = std::time::Instant::now();
    let end = t0 + std::time::Duration::from_secs_f64(opts.duration_s);
    let mut next = t0;
    let (mut offered, mut rejected) = (0u64, 0u64);
    let mut pending = Vec::new();
    // Offer exactly the arrivals scheduled inside [t0, end): once `next`
    // crosses `end` the window is closed and nothing more is submitted —
    // the sleep target is always `next < end`, so it never sleeps past
    // the window and then offers a request outside it.
    while next < end {
        let now = std::time::Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        let img = images[offered as usize % images.len()].clone();
        offered += 1;
        match server.submit(img) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Overloaded { .. }) => rejected += 1,
            Err(SubmitError::Closed) => break,
        }
        // Exponential inter-arrival gap (open loop: `next` advances on
        // the schedule regardless of how the submission fared).
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        next += std::time::Duration::from_secs_f64(-(1.0 - u).ln() / rps);
    }
    // Serve window closes here; the drain is its own measurement, not
    // part of the throughput denominator.
    let wall = t0.elapsed().as_secs_f64();
    let drain_t0 = std::time::Instant::now();
    let m = server.shutdown(); // full drain: the queue is depth-bounded
    let drain_s = drain_t0.elapsed().as_secs_f64();
    let (mut expired, mut shed) = (0u64, 0u64);
    let mut latencies = Vec::with_capacity(pending.len());
    for rx in pending {
        match rx.recv() {
            Ok(Response::Completed(c)) => latencies.push(c.latency_us),
            Ok(Response::DeadlineExceeded { .. }) => expired += 1,
            Ok(Response::Shed { .. }) | Err(_) => shed += 1,
        }
    }
    latencies.sort_unstable();
    debug_assert_eq!(m.requests, latencies.len() as u64);
    OverloadPoint {
        rps,
        offered,
        completed: latencies.len() as u64,
        rejected,
        expired,
        shed,
        throughput_rps: latencies.len() as f64 / wall,
        drain_s,
        p50_latency_us: pctl(&latencies, 0.5),
        p99_latency_us: pctl(&latencies, 0.99),
        max_latency_us: latencies.last().copied().unwrap_or(0),
    }
}

fn cmd_bench_serve(opts: BenchServeOpts) {
    let multipliers: &[f64] = if opts.ramp { &[0.25, 0.5, 0.75, 1.0, 1.5, 2.0] } else { &[1.0] };
    println!(
        "overload bench: base {} rps × {:?}, {}s per point (batch={}, replicas={}, budget={:?} ms, \
         injected delay {} µs/img)",
        opts.rps, multipliers, opts.duration_s, opts.batch, opts.replicas, opts.budget_ms, opts.delay_us
    );
    let mut rng = Rng::new(0x0BE5);
    let mut points = Vec::new();
    for &mult in multipliers {
        let rps = opts.rps * mult;
        let p = drive_open_loop(&opts, rps, &mut rng);
        println!(
            "  rps {:>8.1}: offered {:>6}, completed {:>6}, rejected {:>5}, expired {:>4}, shed {:>4} \
             | {:>8.1} rps served, drain {:>6.3} s, p50 {:>7} µs, p99 {:>7} µs",
            p.rps, p.offered, p.completed, p.rejected, p.expired, p.shed, p.throughput_rps,
            p.drain_s, p.p50_latency_us, p.p99_latency_us
        );
        points.push(p);
    }
    if let Some(knee) =
        points.iter().max_by(|a, b| a.throughput_rps.partial_cmp(&b.throughput_rps).unwrap())
    {
        println!(
            "knee: {:.1} rps served at offered {:.1} rps (p99 {} µs); beyond it admission sheds load \
             instead of growing the queue",
            knee.throughput_rps, knee.rps, knee.p99_latency_us
        );
    }
    let body: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"rps\":{:.1},\"offered\":{},\"completed\":{},\"rejected\":{},\"expired\":{},\
\"shed\":{},\"throughput_rps\":{:.1},\"drain_s\":{:.3},\"p50_latency_us\":{},\
\"p99_latency_us\":{},\"max_latency_us\":{}}}",
                p.rps, p.offered, p.completed, p.rejected, p.expired, p.shed, p.throughput_rps,
                p.drain_s, p.p50_latency_us, p.p99_latency_us, p.max_latency_us
            )
        })
        .collect();
    let json = format!(
        "{{\"base_rps\":{:.1},\"duration_s\":{:.1},\"max_batch\":{},\"replicas\":{},\"ramp\":{},\
\"budget_ms\":{},\"delay_us\":{},\"points\":[{}]}}\n",
        opts.rps,
        opts.duration_s,
        opts.batch,
        opts.replicas,
        opts.ramp,
        opts.budget_ms.map_or_else(|| "null".to_string(), |v| v.to_string()),
        opts.delay_us,
        body.join(","),
    );
    match std::fs::write("BENCH_overload.json", &json) {
        Ok(()) => println!("wrote BENCH_overload.json"),
        Err(e) => eprintln!("failed to write BENCH_overload.json: {e}"),
    }
}

#[cfg(feature = "xla")]
fn cmd_xla(path: &str) {
    let rt = match XlaRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("PJRT unavailable: {e:#}");
            std::process::exit(1);
        }
    };
    println!("platform: {}", rt.platform());
    match rt.load_hlo_text(path) {
        Ok(model) => {
            println!("loaded artifact '{}'", model.name);
            if model.name.starts_with("tnn_gemm") {
                // Standalone ternary GEMM artifact: all-(+1) × all-(+1)
                // must give C ≡ k = 256 everywhere.
                let ap = vec![1f32; 72 * 256];
                let am = vec![0f32; 72 * 256];
                let bp = vec![1f32; 256 * 24];
                let bm = vec![0f32; 256 * 24];
                match model.run_f32(&[
                    (ap, vec![72, 256]),
                    (am, vec![72, 256]),
                    (bp, vec![256, 24]),
                    (bm, vec![256, 24]),
                ]) {
                    Ok(outs) => println!("C[0..4] = {:?} (expect 256)", &outs[0][..4]),
                    Err(e) => eprintln!("execute failed: {e:#}"),
                }
            }
            if model.name.starts_with("probe") {
                // Debug probes: f32[8,12,12,1] ones -> small f32 vector.
                let data = vec![1.0f32; 8 * 12 * 12];
                match model.run_f32(&[(data, vec![8, 12, 12, 1])]) {
                    Ok(outs) => println!("probe out = {:?}", outs[0]),
                    Err(e) => eprintln!("execute failed: {e:#}"),
                }
            }
            if model.name.starts_with("model") {
                // The serving model: f32[8,12,12,1] -> f32[8,10].
                // `--ones` feeds a constant input whose expected logits
                // are printed by python/tests (debug aid).
                let ones = std::env::args().any(|a| a == "--ones");
                let mut rng = Rng::new(11);
                let data: Vec<f32> = if ones {
                    vec![1.0; 8 * 12 * 12]
                } else {
                    (0..8 * 12 * 12).map(|_| rng.normalish()).collect()
                };
                match model.run_f32(&[(data, vec![8, 12, 12, 1])]) {
                    Ok(outs) => {
                        println!("logits[0][0..10] = {:?}", &outs[0][..10.min(outs[0].len())]);
                    }
                    Err(e) => eprintln!("execute failed: {e:#}"),
                }
            }
        }
        Err(e) => {
            eprintln!("load failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::pctl;

    /// Satellite pin: true nearest-rank — the ⌈p·N⌉-th smallest sample —
    /// including the 100-sample case and a case where the old
    /// `floor((N−1)·p)` indexing genuinely differed.
    #[test]
    fn pctl_is_true_nearest_rank() {
        // 100 samples 1..=100: p99 is the 99th smallest (⌈0.99·100⌉ = 99),
        // p50 the 50th, p100 the maximum.
        let hundred: Vec<u64> = (1..=100).collect();
        assert_eq!(pctl(&hundred, 0.99), 99);
        assert_eq!(pctl(&hundred, 0.5), 50);
        assert_eq!(pctl(&hundred, 1.0), 100);
        // 10 samples 1..=10: ⌈0.99·10⌉ = 10 → the maximum. The floored
        // `(N−1)·p` indexing returned sorted[8] = 9 here — biased low.
        let ten: Vec<u64> = (1..=10).collect();
        assert_eq!(pctl(&ten, 0.99), 10);
        assert_eq!(pctl(&ten, 0.9), 9);
        assert_eq!(pctl(&ten, 0.91), 10);
        // Degenerate samples and the p→0 clamp to the minimum.
        assert_eq!(pctl(&[], 0.99), 0);
        assert_eq!(pctl(&[7], 0.5), 7);
        assert_eq!(pctl(&ten, 0.0), 1);
    }
}
