//! The reusable cost-predictor core of the autotuner: per-kind
//! steady-state microkernel traces (measured once, cached) and
//! [`predict`] — predicted Cortex-A73 cycles for a full `(M, N, K)`
//! multiplication under a concrete [`GemmConfig`].
//!
//! This is the refactored heart of what `costmodel/table2.rs` used to do
//! only for rendering: [`crate::costmodel::table2`] now renders the
//! paper-comparison table *from this module's traces*, while
//! [`crate::tune`] ranks candidate execution configs with [`predict`]
//! and [`crate::bench::predicted`] reconstructs the paper's Table III
//! ratios from the same numbers — one measurement, three consumers.
//!
//! The per-iteration model is [`CostModel`] (see the module docs there);
//! this module extends it with the *execution-config* terms the render
//! path never needed:
//!
//! * **tile** — the row-dot baseline pays a load/reuse penalty over the
//!   register-tiled default; the widened BNN 4×4 / TNN 2×4 tiles
//!   amortize loads across columns (shallow-K only, mirroring the
//!   kernel dispatch's fallback).
//! * **k_panel** — panels beyond the first pay a spill pass (read + add
//!   + write of the 32-bit partials) per output element.
//! * **threading** — the kernel term divides across the resolved worker
//!   count; A-packing and the epilogue stay serial, and each dispatched
//!   band pays a fixed pool-dispatch overhead.

use crate::costmodel::CostModel;
use crate::gemm::micro;
use crate::gemm::pack;
use crate::gemm::plan::{GemmConfig, Tile};
use crate::gemm::{safe_k, KPanel, Kind};
use crate::simd::reg::Neon;
use crate::simd::trace::Trace;
use crate::util::mat::{MatF32, MatI8, MatU8};
use crate::util::Rng;
use std::sync::OnceLock;

/// Row-dot kernels recompute column loads per output instead of reusing
/// a register tile; calibrated against the `rowdot` vs `tiled` rungs of
/// `benches/gemm_micro.rs`.
const ROWDOT_KERNEL_FACTOR: f64 = 1.7;
/// The widened tiles feed each loaded word to 4 columns instead of 2.
const WIDE_KERNEL_FACTOR: f64 = 0.9;
/// Fixed pool-dispatch cost per worker per multiplication (the
/// `small_pool4` vs `small_single` gap, in cycles).
const DISPATCH_CYCLES_PER_WORKER: f64 = 4000.0;
/// One spill pass (read + widening add + write of a 32-bit partial) per
/// output element per K panel beyond the first.
const SPILL_CYCLES_PER_OUTPUT: f64 = 2.0;

/// The paper's Table II reference values `(COM, LD, MOV, INS)`.
pub fn paper_reference(kind: Kind) -> (u64, u64, u64, f64) {
    match kind {
        Kind::F32 => (24, 5, 0, 0.302),
        Kind::U8 => (48, 5, 5, 0.302),
        Kind::U4 => (48, 5, 16, 0.180),
        Kind::Tnn => (96, 3, 64, 0.159),
        Kind::Tbn => (96, 3, 56, 0.151),
        Kind::Bnn => (32, 2, 8, 0.041),
        Kind::DaBnn => (156, 12, 36, 0.033),
    }
}

/// Measure the steady-state per-iteration trace of `kind`'s emulated
/// microkernel (two iterations minus one, isolating loop-body cost from
/// hoisted constants). Deterministic: fixed seed, fixed shapes.
fn measure_steady_state(kind: Kind) -> Trace {
    let mut rng = Rng::new(0x7AB1E2);
    let (m, _n, kstep) = kind.micro_shape();
    let k1 = kstep;
    let k2 = 2 * kstep;
    let run = |k: usize| -> Trace {
        let mut cpu = Neon::new();
        match kind {
            Kind::Bnn => {
                let a = MatI8::random_binary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_bnn(&a, 0, k);
                let pb = pack::pack_b_bnn(&b, 0, k);
                micro::bnn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::Tnn => {
                let a = MatI8::random_ternary(m, k, &mut rng.clone());
                let b = MatI8::random_ternary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_tnn(&a, 0, k);
                let pb = pack::pack_b_tnn(&b, 0, k);
                micro::tnn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::Tbn => {
                let a = MatI8::random_ternary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_tnn(&a, 0, k);
                let pb = pack::pack_b_bnn(&b, 0, k);
                micro::tbn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::F32 => {
                let a = MatF32::random(m, k, &mut rng.clone());
                let b = MatF32::random(k, 8, &mut rng.clone());
                let pa = pack::pack_a_f32(&a, 0, k);
                let pb = pack::pack_b_f32(&b, 0, k);
                micro::f32_microkernel(&mut cpu, &pa, &pb, k);
            }
            Kind::U8 => {
                let a = MatU8::random(m, k, &mut rng.clone());
                let b = MatU8::random(k, 8, &mut rng.clone());
                let pa = pack::pack_a_u8(&a, 0, k);
                let pb = pack::pack_b_u8(&b, 0, k);
                micro::u8_microkernel(&mut cpu, &pa, &pb, k / 2);
            }
            Kind::U4 => {
                let a = MatU8::random_below(m, k, 15, &mut rng.clone());
                let b = MatU8::random_below(k, 8, 15, &mut rng.clone());
                let pa = pack::pack_a_u4(&a, 0, k);
                let pb = pack::pack_b_u4(&b, 0, k);
                micro::u4_microkernel(&mut cpu, &pa, &pb, k / 2);
            }
            Kind::DaBnn => {
                let a = MatI8::random_binary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 6, &mut rng.clone());
                let pa = pack::pack_a_dabnn(&a, 0, k);
                let pb = pack::pack_b_dabnn(&b, 0, k);
                micro::dabnn_microkernel(&mut cpu, &pa, &pb, k / 128);
            }
        }
        cpu.trace
    };
    let t1 = run(k1);
    let t2 = run(k2);
    t2.delta(&t1)
}

/// All seven steady-state traces, measured once per process. The
/// emulated microkernels are deterministic, so caching is observationally
/// identical to remeasuring — just ~1000× cheaper for the tuner, which
/// calls [`predict`] per candidate per shape.
fn traces() -> &'static [(Kind, Trace)] {
    static TRACES: OnceLock<Vec<(Kind, Trace)>> = OnceLock::new();
    TRACES.get_or_init(|| Kind::ALL.iter().map(|&k| (k, measure_steady_state(k))).collect())
}

/// The cached steady-state trace for `kind`.
pub fn kind_trace(kind: Kind) -> &'static Trace {
    let all = traces();
    match all.iter().find(|(k, _)| *k == kind) {
        Some((_, t)) => t,
        // Kind::ALL enumerates every variant, so the lookup always
        // succeeds; fall back to the first entry to stay total.
        None => &all[0].1,
    }
}

/// Per-kind epilogue cost (cycles per output element) fed to the model:
/// the quantized kinds pay the eq. (3) zero-point compensation, the
/// binary kinds the `k − 2s` fixup.
pub fn epilogue_cost(model: &CostModel, kind: Kind) -> f64 {
    match kind {
        Kind::U8 | Kind::U4 => model.epilogue_u8,
        Kind::Bnn | Kind::DaBnn => 1.0,
        _ => 0.5,
    }
}

/// Predicted cost of one `(M, N, K)` multiplication, broken into the
/// terms the execution config moves. Compare candidates by [`total`]
/// (f64 — use `total().total_cmp(..)` for ordering).
///
/// [`total`]: Cost::total
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cost {
    /// Microkernel cycles, already divided across the resolved workers.
    pub kernel: f64,
    /// Per-multiplication A-packing (serial, on the caller).
    pub packing: f64,
    /// Per-output epilogue (zero-point compensation / fixup).
    pub epilogue: f64,
    /// Inter-panel 32-bit spill passes beyond the first panel.
    pub spill: f64,
    /// Fixed pool-dispatch overhead for the resolved worker count.
    pub dispatch: f64,
}

impl Cost {
    /// Total predicted cycles.
    pub fn total(&self) -> f64 {
        self.kernel + self.packing + self.epilogue + self.spill + self.dispatch
    }
}

/// The tile the native dispatch would actually execute for this config:
/// row-dot exists for the three paper kinds, the widened tiles for
/// shallow-K BNN/TNN; everything else falls back to the default tile.
/// `Tile::Tuned` is a *resolution request*, not a kernel — model it as
/// the default.
fn effective_tile(kind: Kind, k: usize, tile: Tile) -> Tile {
    match (kind, tile) {
        (Kind::Bnn | Kind::Tnn | Kind::Tbn, Tile::Rowdot) => Tile::Rowdot,
        (Kind::Bnn | Kind::Tnn, Tile::Wide) if k <= safe_k(kind) => Tile::Wide,
        _ => Tile::Auto,
    }
}

/// Number of K panels the depth blocking resolves to (mirrors
/// `KPanel::{words,elems}` at the granularity the cost model needs).
fn panel_count(kind: Kind, k: usize, k_panel: KPanel) -> usize {
    let bound = safe_k(kind);
    match k_panel {
        KPanel::Auto => k.div_ceil(bound.max(1)).max(1),
        KPanel::Depth(d) if d >= k && k <= bound => 1,
        KPanel::Depth(d) => k.div_ceil(d.clamp(1, bound)).max(1),
    }
}

/// Predicted cycles for multiplying an `M×K` LHS by the packed `K×N`
/// weights of `kind` under `config` (native-path model; the backend
/// field of `config` is ignored). Deterministic for a fixed process
/// environment — candidate rankings built on it are reproducible.
pub fn predict(kind: Kind, shape: (usize, usize, usize), config: &GemmConfig) -> Cost {
    let model = CostModel::cortex_a73();
    let trace = kind_trace(kind);
    let (m, n, k) = shape;
    let (mk, nk, kk) = kind.micro_shape();
    let tiles_m = m.div_ceil(mk).max(1);
    let tiles_n = n.div_ceil(nk).max(1);
    let iters = k.div_ceil(kk).max(1);
    let mut kernel = model.cycles_per_iteration(trace) * (tiles_m * tiles_n * iters) as f64;
    let tile = effective_tile(kind, k, config.tile);
    match tile {
        Tile::Rowdot => kernel *= ROWDOT_KERNEL_FACTOR,
        Tile::Wide => kernel *= WIDE_KERNEL_FACTOR,
        _ => {}
    }
    // U4 is single-threaded by construction and row-dot ignores the
    // threading cap — mirror the dispatch.
    let workers = if kind == Kind::U4 || tile == Tile::Rowdot { 1 } else { config.threading.worker_count(m) };
    let dispatch = if workers > 1 { DISPATCH_CYCLES_PER_WORKER * workers as f64 } else { 0.0 };
    kernel /= workers as f64;
    let panels = panel_count(kind, k, config.k_panel);
    let spill = SPILL_CYCLES_PER_OUTPUT * (panels - 1) as f64 * (m * n) as f64;
    let packing = model.pack_per_elem * (tiles_m * mk) as f64 * k as f64;
    let epilogue = epilogue_cost(&model, kind) * (m * n) as f64;
    Cost { kernel, packing, epilogue, spill, dispatch }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Threading;

    #[test]
    fn traces_cover_all_kinds_and_are_cached() {
        for kind in Kind::ALL {
            let t1 = kind_trace(kind);
            let t2 = kind_trace(kind);
            assert!(std::ptr::eq(t1, t2), "{kind:?} trace must be cached");
            assert!(t1.com > 0, "{kind:?} trace must be non-empty");
        }
    }

    #[test]
    fn predict_is_deterministic_and_positive() {
        let cfg = GemmConfig::native(Kind::Tnn);
        let a = predict(Kind::Tnn, (120, 48, 256), &cfg);
        let b = predict(Kind::Tnn, (120, 48, 256), &cfg);
        assert_eq!(a, b);
        assert!(a.total() > 0.0);
    }

    #[test]
    fn threading_cuts_the_kernel_term_but_adds_dispatch() {
        let single = predict(Kind::Bnn, (256, 256, 2048), &GemmConfig::native(Kind::Bnn));
        let four = predict(
            Kind::Bnn,
            (256, 256, 2048),
            &GemmConfig::native(Kind::Bnn).with_threading(Threading::Fixed(4)),
        );
        assert!(four.kernel < single.kernel / 3.0);
        assert!(four.dispatch > 0.0 && single.dispatch == 0.0);
    }

    #[test]
    fn rowdot_is_never_predicted_faster_than_tiled() {
        for kind in [Kind::Bnn, Kind::Tnn, Kind::Tbn] {
            let tiled = predict(kind, (128, 128, 1024), &GemmConfig::native(kind));
            let rowdot = predict(kind, (128, 128, 1024), &GemmConfig::native(kind).with_tile(Tile::Rowdot));
            assert!(rowdot.total() > tiled.total(), "{kind:?}");
        }
    }

    #[test]
    fn forced_panels_cost_spill() {
        let auto = predict(Kind::Bnn, (128, 128, 8192), &GemmConfig::native(Kind::Bnn));
        let forced =
            predict(Kind::Bnn, (128, 128, 8192), &GemmConfig::native(Kind::Bnn).with_k_panel(KPanel::Depth(1024)));
        assert_eq!(auto.spill, 0.0, "8192 fits one 16-bit-safe panel");
        assert!(forced.spill > 0.0);
    }

    #[test]
    fn deep_k_splits_panels_automatically() {
        // Past the 16-bit bound Auto must split — and the model must see it.
        let deep = predict(Kind::Bnn, (64, 64, 40000), &GemmConfig::native(Kind::Bnn));
        assert!(deep.spill > 0.0);
    }
}
