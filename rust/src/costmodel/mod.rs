//! A Cortex-A73 throughput model over the emulated microkernels' traced
//! instruction streams — the analytical half of the paper's evaluation.
//!
//! The paper's Table II compares microkernels by instruction counts; its
//! Table III measures wall-clock on a Cortex-A73. This module bridges the
//! two on a non-ARM host: it executes one steady-state iteration of each
//! emulated microkernel, takes the per-class instruction counts from the
//! [`crate::simd::Trace`], and applies a simple dual-issue throughput
//! model of the A73's NEON unit to predict cycles — from which a
//! *predicted* Table III ratio matrix follows.
//!
//! Model (see `EXPERIMENTS.md` for validation against the paper):
//!
//! * The A73 executes NEON through two 64-bit pipes. 128-bit logical /
//!   CNT / widening-add ops split into two μops that dual-issue → ~1
//!   cycle each.
//! * FP multiply-accumulate (FMLA) only executes on the FP/multiplier
//!   datapath → ~2 cycles per 128-bit instruction; integer
//!   multiply-accumulate (UMLAL) is cheaper on the A73 → ~1.25 cycles
//!   (this split is what reproduces the paper's U8-beats-F32 ratio).
//! * Register-arrangement ops (DUP/EXT/INS/UXTL/MOVI) dual-issue freely →
//!   ~0.5 cycles.
//! * Cross-lane reductions (ADDV) are slow → ~3 cycles.
//! * Loads go through the separate load/store pipe and overlap with
//!   compute: per iteration, `cycles = max(compute, loads)`.

pub mod predict;
pub mod table2;

use crate::simd::trace::Trace;

/// Per-instruction-class reciprocal throughputs (cycles per instruction).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub logic: f64,
    pub cnt: f64,
    pub widening: f64,
    /// FP multiply-accumulate (FMLA).
    pub mul: f64,
    /// Integer multiply-accumulate (UMLAL and friends).
    pub mul_int: f64,
    pub addv: f64,
    pub cvt: f64,
    pub arrange: f64,
    pub load: f64,
    /// Epilogue cost per output element (zero-point compensation etc.).
    pub epilogue_u8: f64,
    /// Per-A-element packing cost in cycles (bit-packing reads+writes).
    pub pack_per_elem: f64,
}

impl CostModel {
    /// The calibrated Cortex-A73 model described in the module docs.
    pub fn cortex_a73() -> Self {
        CostModel {
            logic: 1.0,
            cnt: 1.0,
            widening: 1.0,
            mul: 2.0,
            mul_int: 1.25,
            addv: 3.0,
            cvt: 2.0,
            arrange: 0.5,
            load: 1.0,
            epilogue_u8: 4.0,
            pack_per_elem: 0.25,
        }
    }

    fn class_cost(&self, mnemonic: &str) -> f64 {
        match mnemonic {
            "EOR" | "AND" | "ORR" | "ORN" | "BIC" | "MVN" | "USHR" => self.logic,
            "CNT" => self.cnt,
            "SADDW" | "SADDW2" | "SSUBL" | "SSUBL2" | "ADD.8H" | "ADD.4S" | "UADALP" | "FADD" => self.widening,
            "FMLA" => self.mul,
            "UMLAL" | "UMLAL2" | "UMLAL.8B" | "UMLAL2.16B" => self.mul_int,
            "ADDV" => self.addv,
            "UCVTF" => self.cvt,
            "DUP.16B" | "EXT" | "INS" | "UXTL" | "UXTL2" | "MOVI" => self.arrange,
            "LD1.16B" | "LD1.8B" => 0.0, // loads modeled on the load pipe
            "ST1.16B" => 0.0,
            other => panic!("no cost for mnemonic {other}"),
        }
    }

    /// Predicted cycles for one steady-state microkernel iteration whose
    /// instruction stream is summarized by `trace`.
    pub fn cycles_per_iteration(&self, trace: &Trace) -> f64 {
        let compute: f64 = trace.by_mnemonic.iter().map(|(m, &n)| self.class_cost(m) * n as f64).sum();
        let loads = (trace.ld as f64) * self.load;
        compute.max(loads)
    }

    /// Predicted cycles per multiply-accumulate: cycles / (m·n·k).
    pub fn cycles_per_mac(&self, trace: &Trace, shape: (usize, usize, usize)) -> f64 {
        let (m, n, k) = shape;
        self.cycles_per_iteration(trace) / (m * n * k) as f64
    }

    /// Predicted cycles for a full (height, width, depth) multiplication
    /// with the paper's Algorithm 2 structure: microkernel tiles plus A
    /// re-packing per row panel and the per-output epilogue.
    pub fn predict_gemm(
        &self,
        trace: &Trace,
        shape: (usize, usize, usize),
        problem: (usize, usize, usize),
        epilogue: f64,
    ) -> f64 {
        let (mk, nk, kk) = shape;
        let (h, w, d) = problem;
        let tiles_m = h.div_ceil(mk);
        let tiles_n = w.div_ceil(nk);
        let iters = d.div_ceil(kk);
        let kernel = self.cycles_per_iteration(trace) * (tiles_m * tiles_n * iters) as f64;
        let packing = self.pack_per_elem * (tiles_m * mk) as f64 * d as f64;
        let epi = epilogue * (h * w) as f64;
        kernel + packing + epi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::trace::InsnClass;

    fn trace_of(items: &[(&'static str, InsnClass, u64)]) -> Trace {
        let mut t = Trace::new();
        for &(m, c, n) in items {
            for _ in 0..n {
                t.hit(c, m);
            }
        }
        t
    }

    #[test]
    fn bnn_iteration_cost() {
        let m = CostModel::cortex_a73();
        let t = trace_of(&[
            ("EOR", InsnClass::Com, 8),
            ("CNT", InsnClass::Com, 8),
            ("SADDW", InsnClass::Com, 8),
            ("SADDW2", InsnClass::Com, 8),
            ("DUP.16B", InsnClass::Mov, 8),
            ("LD1.16B", InsnClass::Ld, 1),
            ("LD1.8B", InsnClass::Ld, 1),
        ]);
        // 32 logic/cnt/widening + 8*0.5 arrange = 36, loads 2 → max = 36
        assert!((m.cycles_per_iteration(&t) - 36.0).abs() < 1e-9);
        // per MAC: 36/1024
        assert!((m.cycles_per_mac(&t, (16, 8, 8)) - 36.0 / 1024.0).abs() < 1e-12);
    }

    #[test]
    fn fmla_is_mul_class() {
        let m = CostModel::cortex_a73();
        let t = trace_of(&[("FMLA", InsnClass::Com, 24), ("LD1.16B", InsnClass::Ld, 5)]);
        assert!((m.cycles_per_iteration(&t) - 48.0).abs() < 1e-9);
    }

    #[test]
    fn loads_can_dominate() {
        let m = CostModel::cortex_a73();
        let t = trace_of(&[("EOR", InsnClass::Com, 1), ("LD1.16B", InsnClass::Ld, 14)]);
        assert!((m.cycles_per_iteration(&t) - 14.0).abs() < 1e-9);
    }

    #[test]
    fn predict_gemm_scales_with_problem() {
        let m = CostModel::cortex_a73();
        let t = trace_of(&[("EOR", InsnClass::Com, 32), ("LD1.16B", InsnClass::Ld, 2)]);
        // 160 and 320 are exact multiples of the 16-row microkernel, so
        // doubling the height exactly doubles every cost term.
        let small = m.predict_gemm(&t, (16, 8, 8), (160, 24, 128), 1.0);
        let big = m.predict_gemm(&t, (16, 8, 8), (320, 24, 128), 1.0);
        assert!(big > 1.9 * small && big < 2.1 * small);
    }
}
