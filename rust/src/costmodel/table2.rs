//! Regeneration of the paper's Table II from the shared predictor core
//! ([`crate::costmodel::predict`]): the steady-state traces are measured
//! (and cached) there — this module only derives the per-row INS/k_max
//! columns and renders ours-vs-paper text for `repro table2`. The
//! autotuner ([`crate::tune`]) ranks execution configs from the same
//! traces, so the table and the scheduler can never drift apart.

use crate::costmodel::predict;
use crate::gemm::Kind;
use crate::simd::trace::Trace;

// Kept at this path for existing consumers (`tests/table2_counts.rs`,
// `bench/predicted.rs`); the values now live beside the predictor.
pub use crate::costmodel::predict::paper_reference;

/// One row of the regenerated Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub kind: Kind,
    pub shape: (usize, usize, usize),
    pub com: u64,
    pub ld: u64,
    pub mov: u64,
    pub ins: f64,
    pub k_max: Option<u64>,
    /// The paper's reported (COM, LD, MOV, INS) for comparison.
    pub paper: (u64, u64, u64, f64),
    /// The steady-state trace (consumed by the cost model).
    pub trace: Trace,
}

/// Measure the steady-state per-iteration trace of `kind`'s microkernel
/// (two iterations minus one, isolating loop-body cost from hoisted
/// constants). The measurement itself lives in
/// [`predict::kind_trace`] and is cached per process; the emulated
/// microkernels are deterministic, so this returns the same trace a
/// fresh measurement would.
pub fn steady_state_trace(kind: Kind) -> Trace {
    predict::kind_trace(kind).clone()
}

/// Regenerate all rows of Table II.
pub fn generate() -> Vec<Table2Row> {
    Kind::ALL
        .iter()
        .map(|&kind| {
            let trace = steady_state_trace(kind);
            let shape = kind.micro_shape();
            let ins = trace.ins_metric(shape.0, shape.1, shape.2);
            Table2Row {
                kind,
                shape,
                com: trace.com,
                ld: trace.ld,
                mov: trace.mov,
                ins,
                k_max: kind.k_max(),
                paper: paper_reference(kind),
                trace,
            }
        })
        .collect()
}

/// Render the regenerated table (ours vs paper) as text.
pub fn render(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s.push_str("Table II — microkernel comparison (measured on the emulated NEON path)\n");
    s.push_str(&format!(
        "{:<6} {:<11} {:>5} {:>4} {:>5} {:>7} {:>9}   | paper: COM LD MOV INS\n",
        "Algo", "m×n×k", "COM", "LD", "MOV", "INS", "k_max"
    ));
    for r in rows {
        let kmax = r.k_max.map(|v| v.to_string()).unwrap_or_else(|| "—".into());
        s.push_str(&format!(
            "{:<6} {:<11} {:>5} {:>4} {:>5} {:>7.3} {:>9}   | {:>5} {:>3} {:>4} {:>6.3}\n",
            r.kind.label(),
            format!("{}×{}×{}", r.shape.0, r.shape.1, r.shape.2),
            r.com,
            r.ld,
            r.mov,
            r.ins,
            kmax,
            r.paper.0,
            r.paper.1,
            r.paper.2,
            r.paper.3,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_all_seven_rows() {
        let rows = generate();
        assert_eq!(rows.len(), 7);
    }

    /// The INS ordering of Table II must hold on the measured traces:
    /// daBNN < BNN < TBN < TNN < U4 < U8 ≈ F32.
    #[test]
    fn ins_ordering_matches_paper() {
        let rows = generate();
        let ins = |k: Kind| rows.iter().find(|r| r.kind == k).unwrap().ins;
        assert!(ins(Kind::DaBnn) < ins(Kind::Bnn));
        assert!(ins(Kind::Bnn) < ins(Kind::Tbn));
        assert!(ins(Kind::Tbn) < ins(Kind::Tnn));
        assert!(ins(Kind::Tnn) < ins(Kind::U4));
        assert!(ins(Kind::U4) < ins(Kind::U8));
        // U8 and F32 tie at 0.302 in the paper; ours are within 5%.
        assert!((ins(Kind::U8) - ins(Kind::F32)).abs() / ins(Kind::F32) < 0.05);
    }

    /// BNN and F32 match the paper's counts exactly; TNN matches in
    /// total; the k_max column matches everywhere.
    #[test]
    fn exact_rows_match_paper() {
        let rows = generate();
        let row = |k: Kind| rows.iter().find(|r| r.kind == k).unwrap();
        let bnn = row(Kind::Bnn);
        assert_eq!((bnn.com, bnn.ld, bnn.mov), (32, 2, 8));
        let f32r = row(Kind::F32);
        assert_eq!((f32r.com, f32r.ld, f32r.mov), (24, 5, 0));
        let tnn = row(Kind::Tnn);
        assert_eq!(tnn.com + tnn.ld + tnn.mov, 96 + 3 + 64);
        for r in &rows {
            let paper_kmax = match r.kind {
                Kind::F32 => None,
                Kind::U8 => Some(66051),
                Kind::U4 => Some(291),
                Kind::Tnn | Kind::Tbn | Kind::Bnn => Some(32767),
                Kind::DaBnn => Some(8_388_607),
            };
            assert_eq!(r.k_max, paper_kmax, "{:?}", r.kind);
        }
    }

    #[test]
    fn render_contains_all_labels() {
        let text = render(&generate());
        for k in Kind::ALL {
            assert!(text.contains(k.label()), "{}", k.label());
        }
    }
}
