//! Regeneration of the paper's Table II: execute one steady-state
//! iteration of every emulated microkernel, count instructions by class,
//! and derive INS and k_max. The paper's reported values are carried
//! alongside for comparison (`repro table2` prints both).

use crate::gemm::micro;
use crate::gemm::pack;
use crate::gemm::Kind;
use crate::simd::reg::Neon;
use crate::simd::trace::Trace;
use crate::util::mat::{MatF32, MatI8, MatU8};
use crate::util::Rng;

/// One row of the regenerated Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub kind: Kind,
    pub shape: (usize, usize, usize),
    pub com: u64,
    pub ld: u64,
    pub mov: u64,
    pub ins: f64,
    pub k_max: Option<u64>,
    /// The paper's reported (COM, LD, MOV, INS) for comparison.
    pub paper: (u64, u64, u64, f64),
    /// The steady-state trace (consumed by the cost model).
    pub trace: Trace,
}

/// The paper's Table II reference values.
pub fn paper_reference(kind: Kind) -> (u64, u64, u64, f64) {
    match kind {
        Kind::F32 => (24, 5, 0, 0.302),
        Kind::U8 => (48, 5, 5, 0.302),
        Kind::U4 => (48, 5, 16, 0.180),
        Kind::Tnn => (96, 3, 64, 0.159),
        Kind::Tbn => (96, 3, 56, 0.151),
        Kind::Bnn => (32, 2, 8, 0.041),
        Kind::DaBnn => (156, 12, 36, 0.033),
    }
}

/// Measure the steady-state per-iteration trace of `kind`'s microkernel
/// (two iterations minus one, isolating loop-body cost from hoisted
/// constants).
pub fn steady_state_trace(kind: Kind) -> Trace {
    let mut rng = Rng::new(0x7AB1E2);
    let (m, _n, kstep) = kind.micro_shape();
    let k1 = kstep;
    let k2 = 2 * kstep;
    let run = |k: usize| -> Trace {
        let mut cpu = Neon::new();
        match kind {
            Kind::Bnn => {
                let a = MatI8::random_binary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_bnn(&a, 0, k);
                let pb = pack::pack_b_bnn(&b, 0, k);
                micro::bnn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::Tnn => {
                let a = MatI8::random_ternary(m, k, &mut rng.clone());
                let b = MatI8::random_ternary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_tnn(&a, 0, k);
                let pb = pack::pack_b_tnn(&b, 0, k);
                micro::tnn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::Tbn => {
                let a = MatI8::random_ternary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 8, &mut rng.clone());
                let pa = pack::pack_a_tnn(&a, 0, k);
                let pb = pack::pack_b_bnn(&b, 0, k);
                micro::tbn_microkernel(&mut cpu, &pa, &pb, k / 8);
            }
            Kind::F32 => {
                let a = MatF32::random(m, k, &mut rng.clone());
                let b = MatF32::random(k, 8, &mut rng.clone());
                let pa = pack::pack_a_f32(&a, 0, k);
                let pb = pack::pack_b_f32(&b, 0, k);
                micro::f32_microkernel(&mut cpu, &pa, &pb, k);
            }
            Kind::U8 => {
                let a = MatU8::random(m, k, &mut rng.clone());
                let b = MatU8::random(k, 8, &mut rng.clone());
                let pa = pack::pack_a_u8(&a, 0, k);
                let pb = pack::pack_b_u8(&b, 0, k);
                micro::u8_microkernel(&mut cpu, &pa, &pb, k / 2);
            }
            Kind::U4 => {
                let a = MatU8::random_below(m, k, 15, &mut rng.clone());
                let b = MatU8::random_below(k, 8, 15, &mut rng.clone());
                let pa = pack::pack_a_u4(&a, 0, k);
                let pb = pack::pack_b_u4(&b, 0, k);
                micro::u4_microkernel(&mut cpu, &pa, &pb, k / 2);
            }
            Kind::DaBnn => {
                let a = MatI8::random_binary(m, k, &mut rng.clone());
                let b = MatI8::random_binary(k, 6, &mut rng.clone());
                let pa = pack::pack_a_dabnn(&a, 0, k);
                let pb = pack::pack_b_dabnn(&b, 0, k);
                micro::dabnn_microkernel(&mut cpu, &pa, &pb, k / 128);
            }
        }
        cpu.trace
    };
    let t1 = run(k1);
    let t2 = run(k2);
    t2.delta(&t1)
}

/// Regenerate all rows of Table II.
pub fn generate() -> Vec<Table2Row> {
    Kind::ALL
        .iter()
        .map(|&kind| {
            let trace = steady_state_trace(kind);
            let shape = kind.micro_shape();
            let ins = trace.ins_metric(shape.0, shape.1, shape.2);
            Table2Row {
                kind,
                shape,
                com: trace.com,
                ld: trace.ld,
                mov: trace.mov,
                ins,
                k_max: kind.k_max(),
                paper: paper_reference(kind),
                trace,
            }
        })
        .collect()
}

/// Render the regenerated table (ours vs paper) as text.
pub fn render(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s.push_str("Table II — microkernel comparison (measured on the emulated NEON path)\n");
    s.push_str(&format!(
        "{:<6} {:<11} {:>5} {:>4} {:>5} {:>7} {:>9}   | paper: COM LD MOV INS\n",
        "Algo", "m×n×k", "COM", "LD", "MOV", "INS", "k_max"
    ));
    for r in rows {
        let kmax = r.k_max.map(|v| v.to_string()).unwrap_or_else(|| "—".into());
        s.push_str(&format!(
            "{:<6} {:<11} {:>5} {:>4} {:>5} {:>7.3} {:>9}   | {:>5} {:>3} {:>4} {:>6.3}\n",
            r.kind.label(),
            format!("{}×{}×{}", r.shape.0, r.shape.1, r.shape.2),
            r.com,
            r.ld,
            r.mov,
            r.ins,
            kmax,
            r.paper.0,
            r.paper.1,
            r.paper.2,
            r.paper.3,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_all_seven_rows() {
        let rows = generate();
        assert_eq!(rows.len(), 7);
    }

    /// The INS ordering of Table II must hold on the measured traces:
    /// daBNN < BNN < TBN < TNN < U4 < U8 ≈ F32.
    #[test]
    fn ins_ordering_matches_paper() {
        let rows = generate();
        let ins = |k: Kind| rows.iter().find(|r| r.kind == k).unwrap().ins;
        assert!(ins(Kind::DaBnn) < ins(Kind::Bnn));
        assert!(ins(Kind::Bnn) < ins(Kind::Tbn));
        assert!(ins(Kind::Tbn) < ins(Kind::Tnn));
        assert!(ins(Kind::Tnn) < ins(Kind::U4));
        assert!(ins(Kind::U4) < ins(Kind::U8));
        // U8 and F32 tie at 0.302 in the paper; ours are within 5%.
        assert!((ins(Kind::U8) - ins(Kind::F32)).abs() / ins(Kind::F32) < 0.05);
    }

    /// BNN and F32 match the paper's counts exactly; TNN matches in
    /// total; the k_max column matches everywhere.
    #[test]
    fn exact_rows_match_paper() {
        let rows = generate();
        let row = |k: Kind| rows.iter().find(|r| r.kind == k).unwrap();
        let bnn = row(Kind::Bnn);
        assert_eq!((bnn.com, bnn.ld, bnn.mov), (32, 2, 8));
        let f32r = row(Kind::F32);
        assert_eq!((f32r.com, f32r.ld, f32r.mov), (24, 5, 0));
        let tnn = row(Kind::Tnn);
        assert_eq!(tnn.com + tnn.ld + tnn.mov, 96 + 3 + 64);
        for r in &rows {
            let paper_kmax = match r.kind {
                Kind::F32 => None,
                Kind::U8 => Some(66051),
                Kind::U4 => Some(291),
                Kind::Tnn | Kind::Tbn | Kind::Bnn => Some(32767),
                Kind::DaBnn => Some(8_388_607),
            };
            assert_eq!(r.k_max, paper_kmax, "{:?}", r.kind);
        }
    }

    #[test]
    fn render_contains_all_labels() {
        let text = render(&generate());
        for k in Kind::ALL {
            assert!(text.contains(k.label()), "{}", k.label());
        }
    }
}
