//! Binarization and ternarization of full-precision tensors — the
//! quantizers feeding the paper's BNN / TNN / TBN multiplications.
//!
//! * Binarization (XNOR-Net style, ref. [21]): `sign(x)` with a
//!   per-tensor scaling factor `α = mean(|x|)` so that `x ≈ α·sign(x)`.
//! * Ternarization (TWN-style, ref. [25]): threshold `Δ`:
//!   `+1 if x > Δ, −1 if x < −Δ, 0 otherwise`, with
//!   `α = mean(|x| : |x| > Δ)` and the common heuristic
//!   `Δ = 0.75·mean(|x|)`.

use crate::util::mat::MatI8;

/// How the ternarization threshold Δ is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TernaryThreshold {
    /// Fixed absolute threshold.
    Fixed(f32),
    /// `Δ = ratio · mean(|x|)` (0.75 is the TWN heuristic).
    MeanRatio(f32),
}

/// Binarize: returns the sign matrix (`{-1, +1}`, zeros map to `+1`) and
/// the scaling factor `α = mean(|x|)`.
pub fn binarize(rows: usize, cols: usize, xs: &[f32]) -> (MatI8, f32) {
    assert_eq!(xs.len(), rows * cols);
    let data: Vec<i8> = xs.iter().map(|&x| if x < 0.0 { -1 } else { 1 }).collect();
    let alpha = if xs.is_empty() { 0.0 } else { xs.iter().map(|x| x.abs()).sum::<f32>() / xs.len() as f32 };
    (MatI8 { rows, cols, data }, alpha)
}

/// Ternarize: returns the `{-1, 0, +1}` matrix and the scaling factor
/// `α = mean(|x| over non-zeroed entries)` (0 when everything is zeroed).
pub fn ternarize(rows: usize, cols: usize, xs: &[f32], thr: TernaryThreshold) -> (MatI8, f32) {
    assert_eq!(xs.len(), rows * cols);
    let delta = match thr {
        TernaryThreshold::Fixed(d) => d,
        TernaryThreshold::MeanRatio(r) => {
            let mean_abs = if xs.is_empty() { 0.0 } else { xs.iter().map(|x| x.abs()).sum::<f32>() / xs.len() as f32 };
            r * mean_abs
        }
    };
    let mut kept_sum = 0f32;
    let mut kept = 0usize;
    let data: Vec<i8> = xs
        .iter()
        .map(|&x| {
            if x > delta {
                kept_sum += x;
                kept += 1;
                1
            } else if x < -delta {
                kept_sum += -x;
                kept += 1;
                -1
            } else {
                0
            }
        })
        .collect();
    let alpha = if kept > 0 { kept_sum / kept as f32 } else { 0.0 };
    (MatI8 { rows, cols, data }, alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn binarize_signs_and_alpha() {
        let xs = [1.0f32, -2.0, 3.0, -4.0];
        let (m, alpha) = binarize(2, 2, &xs);
        assert_eq!(m.data, vec![1, -1, 1, -1]);
        assert!((alpha - 2.5).abs() < 1e-6);
        assert!(m.is_binary());
    }

    #[test]
    fn binarize_zero_maps_to_plus_one() {
        let (m, _) = binarize(1, 1, &[0.0]);
        assert_eq!(m.data, vec![1]);
    }

    #[test]
    fn ternarize_fixed_threshold() {
        let xs = [0.1f32, -0.1, 0.6, -0.9, 0.0];
        let (m, alpha) = ternarize(1, 5, &xs, TernaryThreshold::Fixed(0.5));
        assert_eq!(m.data, vec![0, 0, 1, -1, 0]);
        assert!((alpha - 0.75).abs() < 1e-6);
        assert!(m.is_ternary());
    }

    #[test]
    fn ternarize_mean_ratio_zeroes_small_values() {
        let mut rng = Rng::new(95);
        let xs: Vec<f32> = (0..1000).map(|_| rng.normalish()).collect();
        let (m, _) = ternarize(10, 100, &xs, TernaryThreshold::MeanRatio(0.75));
        let zeros = m.data.iter().filter(|&&v| v == 0).count();
        // With Δ = 0.75·mean|x| a substantial fraction must be zeroed,
        // but not everything.
        assert!(zeros > 100 && zeros < 900, "zeros={zeros}");
    }

    #[test]
    fn ternarize_all_below_threshold() {
        let xs = [0.01f32; 4];
        let (m, alpha) = ternarize(2, 2, &xs, TernaryThreshold::Fixed(1.0));
        assert!(m.data.iter().all(|&v| v == 0));
        assert_eq!(alpha, 0.0);
    }

    /// Reconstruction α·t(x) is closer to x than α·sign(x) when many
    /// values are near zero — the reason TNNs beat BNNs on quality.
    #[test]
    fn ternary_reconstruction_beats_binary_on_sparse_data() {
        let mut rng = Rng::new(96);
        let xs: Vec<f32> = (0..2000)
            .map(|i| if i % 4 == 0 { rng.normalish() } else { rng.f32_range(-0.05, 0.05) })
            .collect();
        let (bm, ba) = binarize(1, xs.len(), &xs);
        let (tm, ta) = ternarize(1, xs.len(), &xs, TernaryThreshold::MeanRatio(0.75));
        let be: f32 = xs.iter().zip(&bm.data).map(|(&x, &s)| (x - ba * s as f32).powi(2)).sum();
        let te: f32 = xs.iter().zip(&tm.data).map(|(&x, &s)| (x - ta * s as f32).powi(2)).sum();
        assert!(te < be, "ternary mse {te} must beat binary mse {be}");
    }
}
