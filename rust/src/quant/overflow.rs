//! Accumulator-overflow limits — the paper's eq. (4) and eq. (5).
//!
//! With p-bit operands accumulated in q-bit registers the maximum safe
//! depth is `k_max = ⌊(2^q − 1)/(2^p − 1)²⌋` (eq. 4); in GeMM-based
//! convolution with an `H_k × W_k` kernel the corresponding input-channel
//! bound is `C_in_max = ⌊k_max/(H_k·W_k)⌋` (eq. 5).

/// eq. (4): maximum depth for p-bit unsigned operands in q-bit
/// accumulators.
pub fn k_max(p_bits: u32, q_bits: u32) -> u64 {
    assert!(p_bits >= 1 && q_bits >= p_bits && q_bits <= 64);
    let max_operand = (1u128 << p_bits) - 1;
    let max_acc = (1u128 << q_bits) - 1;
    (max_acc / (max_operand * max_operand)) as u64
}

/// eq. (5): maximum input channels for a `hk × wk` convolution kernel.
pub fn c_in_max(k_max: u64, hk: usize, wk: usize) -> u64 {
    k_max / (hk as u64 * wk as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Kind;

    /// The paper's Table II k_max column comes out of eq. (4).
    #[test]
    fn table2_kmax_from_eq4() {
        assert_eq!(k_max(8, 32), 66051); // U8
        assert_eq!(k_max(4, 16), 291); // U4
    }

    #[test]
    fn kind_kmax_consistent_with_eq4() {
        assert_eq!(Kind::U8.k_max().unwrap(), k_max(8, 32));
        assert_eq!(Kind::U4.k_max().unwrap(), k_max(4, 16));
    }

    /// eq. (5) examples: the paper argues U4 only suits small CNNs —
    /// with a 3×3 kernel it allows just 32 input channels, while TNN
    /// allows 3640.
    #[test]
    fn channel_limits_3x3() {
        assert_eq!(c_in_max(291, 3, 3), 32);
        assert_eq!(c_in_max(32767, 3, 3), 3640);
        assert_eq!(c_in_max(66051, 3, 3), 7339);
        assert_eq!(c_in_max(8_388_607, 3, 3), 932067);
    }

    #[test]
    fn kmax_monotone_in_accumulator_width() {
        assert!(k_max(8, 32) > k_max(8, 16));
        assert!(k_max(4, 32) > k_max(4, 16));
    }

    #[test]
    fn binary_interpretation() {
        // For ±1 products accumulated in signed 16-bit the bound is the
        // register range itself (the paper's argument, not eq. 4 — the
        // products have |z| ≤ 1).
        assert_eq!(Kind::Bnn.k_max().unwrap(), 32767);
    }
}
