//! Linear quantization, the paper's eq. (1):
//!
//! `x̂ = max(min(⌊x/s − z⌋, Q), 0)` with scale `s`, zero-point `z`,
//! `Q = 2ⁿ − 1`.
//!
//! Note the paper's formula subtracts the zero-point *inside* the floor;
//! the dequantization consistent with eq. (2) is `x ≈ s·(x̂ − z_eff)`
//! where `z_eff = −z` shifts the representable range. We follow the
//! gemmlowp convention (`x ≈ s·(x̂ − z)`, `0̂ = z`), which is what eq. (2)
//! actually uses, and provide calibration from min/max statistics.

use crate::util::mat::MatU8;

/// Parameters of an n-bit linear quantizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearQuant {
    pub scale: f32,
    pub zero_point: i32,
    pub bits: u8,
}

impl LinearQuant {
    /// Maximum quantized value `Q = 2ⁿ − 1`.
    pub fn q_max(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    /// Calibrate a quantizer so that `[lo, hi]` maps onto `[0, Q]` with a
    /// representable zero (the gemmlowp scheme). `lo ≤ 0 ≤ hi` is
    /// enforced by widening the range if necessary.
    pub fn calibrate(lo: f32, hi: f32, bits: u8) -> Self {
        assert!(bits >= 2 && bits <= 8, "supported bit-widths: 2..=8");
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let q = ((1u32 << bits) - 1) as f32;
        let scale = if hi > lo { (hi - lo) / q } else { 1.0 };
        // zero-point: the quantized value representing real 0.
        let zp = (-lo / scale).round() as i32;
        LinearQuant { scale, zero_point: zp.clamp(0, q as i32), bits }
    }

    /// Quantize one value: `clamp(round(x/s) + z, 0, Q)`.
    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        let v = (x / self.scale).round() as i32 + self.zero_point;
        v.clamp(0, self.q_max()) as u8
    }

    /// Dequantize one value: `s·(x̂ − z)`.
    #[inline]
    pub fn dequantize(&self, q: u8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }

    /// Quantize a slice into a fresh buffer.
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<u8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }
}

/// A quantized tensor: u8 storage plus its quantizer.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub data: MatU8,
    pub q: LinearQuant,
}

impl QuantizedTensor {
    /// Quantize a row-major f32 buffer with per-tensor min/max calibration.
    pub fn from_f32(rows: usize, cols: usize, xs: &[f32], bits: u8) -> Self {
        assert_eq!(xs.len(), rows * cols);
        let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let q = LinearQuant::calibrate(lo, hi, bits);
        let data = MatU8 { rows, cols, data: q.quantize_slice(xs) };
        QuantizedTensor { data, q }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zero_is_exactly_representable() {
        for bits in [4u8, 8] {
            let q = LinearQuant::calibrate(-3.0, 5.0, bits);
            assert_eq!(q.dequantize(q.quantize(0.0)), 0.0);
        }
    }

    #[test]
    fn quantize_clamps_to_range() {
        let q = LinearQuant::calibrate(-1.0, 1.0, 8);
        assert_eq!(q.quantize(100.0), 255);
        assert_eq!(q.quantize(-100.0), 0);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let mut rng = Rng::new(90);
        for bits in [4u8, 8] {
            let q = LinearQuant::calibrate(-2.0, 2.0, bits);
            for _ in 0..500 {
                let x = rng.f32_range(-2.0, 2.0);
                let err = (q.dequantize(q.quantize(x)) - x).abs();
                assert!(err <= q.scale * 0.5 + 1e-6, "bits={bits} x={x} err={err}");
            }
        }
    }

    #[test]
    fn q_max_by_bits() {
        assert_eq!(LinearQuant::calibrate(-1.0, 1.0, 8).q_max(), 255);
        assert_eq!(LinearQuant::calibrate(-1.0, 1.0, 4).q_max(), 15);
    }

    #[test]
    fn tensor_calibration_covers_data() {
        let mut rng = Rng::new(91);
        let xs: Vec<f32> = (0..64).map(|_| rng.normalish()).collect();
        let t = QuantizedTensor::from_f32(8, 8, &xs, 8);
        // every value dequantizes within half a scale step
        for (i, &x) in xs.iter().enumerate() {
            let err = (t.q.dequantize(t.data.data[i]) - x).abs();
            assert!(err <= t.q.scale * 0.5 + 1e-5);
        }
    }

    #[test]
    fn degenerate_range_does_not_panic() {
        let q = LinearQuant::calibrate(0.0, 0.0, 8);
        assert_eq!(q.quantize(0.0), q.zero_point as u8);
    }
}
