//! Quantization (§II-B of the paper): linear quantization eq. (1), the
//! zero-point decomposition eq. (3), the overflow bounds eq. (4)–(5),
//! and the binarization / ternarization used by BNN/TNN/TBN layers.

pub mod linear;
pub mod lowbit;
pub mod overflow;

pub use linear::{LinearQuant, QuantizedTensor};
pub use lowbit::{binarize, ternarize, TernaryThreshold};
pub use overflow::{c_in_max, k_max};
