//! Plan-time autotuning: cost-model-seeded, measurement-refined
//! selection of the native execution config per `(kind, M, N, K)`.
//!
//! The paper's Table II gives per-iteration instruction counts for every
//! microkernel; [`crate::costmodel::predict`] turns them into a cost
//! predictor over full multiplication shapes *and* execution configs.
//! This module closes the loop into a production scheduler:
//!
//! 1. [`candidates`] enumerates the legal config space for a shape —
//!    tile × K-panel × threading cap, pruned to what the native dispatch
//!    can actually execute (no `Wide` beyond `safe_k`, no threading for
//!    U4 or row-dot, no caps the row-band split would collapse anyway).
//! 2. [`rank_predicted`] orders candidates by predicted cycles
//!    (deterministic: cached traces, stable sort).
//! 3. [`crate::tune::measure`] optionally refines the top-k through real
//!    [`crate::gemm::GemmPlan::run`] calls under a bounded budget.
//! 4. [`crate::tune::store`] persists measured winners as versioned JSON
//!    keyed by (host fingerprint, kind, shape bucket); `repro tune`
//!    writes it, `TBGEMM_TUNE_FILE` points later processes at it.
//! 5. [`resolve`] is the run-time entry point used by
//!    [`Tile::Tuned`] plans and tuning-enabled
//!    [`crate::nn::NetPlanConfig`]: store hit → stored choice; miss,
//!    corrupt file, wrong host, or no file → cost-model-only ranking;
//!    `TBGEMM_TUNE_DISABLE` → the default config.
//!
//! Every choice this module returns only moves the *execution knobs*
//! (`threading` / `k_panel` / `tile`) of an already-packed plan — never
//! the packed layout — so tuned plans stay bit-identical to
//! `Backend::Reference` by the same argument as the hand-picked configs
//! (pinned by `tests/tuner.rs` across all 7 kinds).

pub mod measure;
pub mod store;

use crate::costmodel::predict::{predict, Cost};
use crate::gemm::{safe_k, GemmConfig, KPanel, Kind, Threading, Tile};

pub use store::{StoreEntry, StoreError, TuningStore, STORE_VERSION};

/// One tunable execution config: the three knobs of [`GemmConfig`] that
/// can change after packing. The default is the crate-wide default
/// config (single thread, automatic K panels, per-kind default tile).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Choice {
    pub threading: Threading,
    pub k_panel: KPanel,
    pub tile: Tile,
}

impl Choice {
    /// The native [`GemmConfig`] this choice denotes for `kind`.
    pub fn to_config(self, kind: Kind) -> GemmConfig {
        GemmConfig::native(kind).with_threading(self.threading).with_k_panel(self.k_panel).with_tile(self.tile)
    }

    /// Compact human label, `tile/k_panel/threading` (e.g.
    /// `wide/auto/fixed:4`) — the store's serialized vocabulary.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}",
            store::tile_str(self.tile),
            store::k_panel_str(self.k_panel),
            store::threading_str(self.threading)
        )
    }
}

fn add(cands: &mut Vec<Choice>, choice: Choice) {
    if !cands.contains(&choice) {
        cands.push(choice);
    }
}

/// Worker caps worth trying for an `m`-row product: powers of two up to
/// `max_workers` plus `max_workers` itself, deduplicated by the worker
/// count the row-band split actually resolves them to (a 20-row product
/// can't use more workers than `Fixed(3)` would, however large the cap).
fn thread_caps(m: usize, max_workers: usize) -> Vec<usize> {
    let mut caps: Vec<usize> = Vec::new();
    let mut effective: Vec<usize> = Vec::new();
    let mut consider = |caps: &mut Vec<usize>, effective: &mut Vec<usize>, cap: usize| {
        let workers = Threading::Fixed(cap).worker_count(m);
        if workers > 1 && !effective.contains(&workers) {
            caps.push(cap);
            effective.push(workers);
        }
    };
    let mut cap = 2usize;
    while cap <= max_workers {
        consider(&mut caps, &mut effective, cap);
        cap *= 2;
    }
    if max_workers >= 2 {
        consider(&mut caps, &mut effective, max_workers);
    }
    caps
}

/// Enumerate the legal candidate configs for one `(kind, shape)` given a
/// `max_workers` budget (typically [`crate::util::pool::default_workers`]).
///
/// Deterministic: the same arguments always produce the same candidates
/// in the same order, and the first candidate is always
/// [`Choice::default`] — rankings built on this are reproducible and
/// ties resolve toward the default config.
pub fn candidates(kind: Kind, shape: (usize, usize, usize), max_workers: usize) -> Vec<Choice> {
    let (m, _n, k) = shape;
    let mut cands = Vec::new();
    add(&mut cands, Choice::default());
    // U4 has no execution knobs: fixed 16-bit-safe depth blocks,
    // single-threaded by construction.
    if kind == Kind::U4 {
        return cands;
    }
    let caps = thread_caps(m, max_workers);
    for &cap in &caps {
        add(&mut cands, Choice { threading: Threading::Fixed(cap), ..Choice::default() });
    }
    // Widened register tiles: BNN/TNN, shallow-K only (the dispatch
    // falls back past `safe_k`, so deeper candidates would be aliases).
    if matches!(kind, Kind::Bnn | Kind::Tnn) && k <= safe_k(kind) {
        add(&mut cands, Choice { tile: Tile::Wide, ..Choice::default() });
        for &cap in &caps {
            add(&mut cands, Choice { threading: Threading::Fixed(cap), tile: Tile::Wide, ..Choice::default() });
        }
    }
    // The seed's row-dot baseline (single-threaded, single-panel): the
    // cost model never picks it, but keeping it in the set lets the
    // measurement refiner prove that — and catch hosts where the blocked
    // path regresses.
    if matches!(kind, Kind::Bnn | Kind::Tnn | Kind::Tbn) {
        add(&mut cands, Choice { tile: Tile::Rowdot, ..Choice::default() });
    }
    // A forced half-depth K panel for deep products: predicted slower
    // (spill passes), but cache-resident B panels can win on real
    // hardware — exactly what measurement refinement is for.
    if matches!(kind, Kind::Bnn | Kind::Tnn | Kind::Tbn) && k > 8192 {
        add(&mut cands, Choice { k_panel: KPanel::Depth(4096), ..Choice::default() });
        if let Some(&cap) = caps.last() {
            add(
                &mut cands,
                Choice { threading: Threading::Fixed(cap), k_panel: KPanel::Depth(4096), ..Choice::default() },
            );
        }
    }
    cands
}

/// Rank `cands` by predicted cost, cheapest first. The sort is stable,
/// so equal-cost candidates keep their [`candidates`] order and the
/// ranking is deterministic end to end.
pub fn rank_predicted(kind: Kind, shape: (usize, usize, usize), cands: &[Choice]) -> Vec<(Choice, Cost)> {
    let mut ranked: Vec<(Choice, Cost)> =
        cands.iter().map(|&c| (c, predict(kind, shape, &c.to_config(kind)))).collect();
    ranked.sort_by(|a, b| a.1.total().total_cmp(&b.1.total()));
    ranked
}

/// Order `cands` by a measurement table (ns per iteration, parallel to
/// `cands`), fastest first; stable on ties. Extracted from the refiner
/// so determinism is testable against a fixed table without timing.
pub fn rank_measured(cands: &[Choice], measured_ns: &[f64]) -> Vec<Choice> {
    let mut order: Vec<usize> = (0..cands.len().min(measured_ns.len())).collect();
    order.sort_by(|&a, &b| measured_ns[a].total_cmp(&measured_ns[b]));
    order.into_iter().map(|i| cands[i]).collect()
}

/// The best cost-model candidate for `(kind, shape)` under a worker
/// budget — the store-miss fallback.
pub fn best_predicted(kind: Kind, shape: (usize, usize, usize), max_workers: usize) -> Choice {
    let cands = candidates(kind, shape, max_workers);
    match rank_predicted(kind, shape, &cands).into_iter().next() {
        Some((choice, _)) => choice,
        // `candidates` always yields at least the default.
        None => Choice::default(),
    }
}

/// A stored choice can never contain `Tile::Tuned` (the store parser
/// rejects the label), but resolution must not recurse regardless.
fn sanitize(choice: Choice) -> Choice {
    if choice.tile == Tile::Tuned {
        Choice { tile: Tile::Auto, ..choice }
    } else {
        choice
    }
}

/// Resolve the execution config for one native multiplication — the
/// run-time entry point behind [`Tile::Tuned`] and tuning-enabled
/// `NetPlan`s. Never fails: `TBGEMM_TUNE_DISABLE` → the default config;
/// store hit → the persisted winner; anything else (no file, corrupt
/// file, wrong host or version, unknown shape) → cost-model ranking
/// against the full worker pool.
pub fn resolve(kind: Kind, shape: (usize, usize, usize)) -> Choice {
    if crate::util::env::tune_disable() {
        return Choice::default();
    }
    if let Some(choice) = store::global().lookup(kind, shape) {
        return sanitize(choice);
    }
    best_predicted(kind, shape, crate::util::pool::default_workers())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_always_first_candidate() {
        for kind in Kind::ALL {
            let cands = candidates(kind, (120, 48, 256), 8);
            assert_eq!(cands[0], Choice::default(), "{kind:?}");
        }
    }

    #[test]
    fn u4_has_no_knobs() {
        assert_eq!(candidates(Kind::U4, (512, 512, 256), 8), vec![Choice::default()]);
    }

    #[test]
    fn candidates_are_unique() {
        for kind in Kind::ALL {
            for &shape in &[(16, 8, 64), (256, 256, 2048), (128, 128, 40000)] {
                let cands = candidates(kind, shape, 8);
                for (i, a) in cands.iter().enumerate() {
                    assert!(!cands[i + 1..].contains(a), "{kind:?} {shape:?} duplicates {a:?}");
                }
            }
        }
    }

    #[test]
    fn small_m_gets_no_threaded_candidates() {
        // 8 rows resolve to 1 worker at any cap — threading candidates
        // would all alias the default.
        for c in candidates(Kind::Bnn, (8, 64, 256), 8) {
            assert_eq!(c.threading, Threading::Single, "{c:?}");
        }
    }

    #[test]
    fn wide_candidates_respect_safe_k() {
        let deep = safe_k(Kind::Bnn) + 1;
        assert!(!candidates(Kind::Bnn, (256, 256, deep), 8).iter().any(|c| c.tile == Tile::Wide));
        assert!(candidates(Kind::Bnn, (256, 256, 2048), 8).iter().any(|c| c.tile == Tile::Wide));
    }

    #[test]
    fn thread_caps_dedupe_by_effective_workers() {
        // 20 rows → at most 3 row bands: caps 4, 8, and the pool max all
        // resolve to 3 workers, so only the first distinct cap survives.
        let caps = thread_caps(20, 8);
        assert_eq!(caps.len(), 2, "{caps:?}"); // 2 workers, then 3
    }

    #[test]
    fn resolve_returns_a_legal_candidate() {
        for kind in Kind::ALL {
            let shape = (120, 48, 256);
            let choice = resolve(kind, shape);
            let legal = candidates(kind, shape, crate::util::pool::default_workers());
            assert!(
                choice == Choice::default() || legal.contains(&choice),
                "{kind:?} resolved to {choice:?}, not in {legal:?}"
            );
        }
    }
}
