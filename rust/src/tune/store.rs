//! The persisted tuning store: versioned JSON keyed by host
//! fingerprint, kind, and power-of-two shape bucket.
//!
//! File format (`STORE_VERSION` 1):
//!
//! ```json
//! {
//!   "version": 1,
//!   "host": "x86_64-linux-w8",
//!   "entries": [
//!     {"kind": "BNN", "m": 256, "n": 256, "k": 2048,
//!      "threading": "fixed:4", "k_panel": "auto", "tile": "wide",
//!      "measured_ns": 181250.0, "predicted_cycles": 412000.0}
//!   ]
//! }
//! ```
//!
//! `m`/`n`/`k` are **bucketed** dimensions (next power of two), so one
//! entry covers the neighborhood of shapes it was measured at. The host
//! fingerprint ties measurements to the machine class that produced
//! them; a mismatched file is rejected at load (and [`global`] then
//! falls back to cost-model-only ranking, never an error). Tuning files
//! are advisory by contract: every failure mode — missing, corrupt,
//! wrong version, wrong host — degrades to the untuned prediction path.

use crate::gemm::{KPanel, Kind, Threading, Tile};
use crate::tune::Choice;
use crate::util::json::{self, Json};
use std::sync::OnceLock;

/// Current tuning-file format version. Bump on any schema change — old
/// readers reject newer files (and vice versa) instead of misreading.
pub const STORE_VERSION: u64 = 1;

/// The machine class a tuning file is valid for: architecture, OS, and
/// the resolved worker-pool size (a 4-core measurement is wrong for the
/// same binary on 64 cores).
pub fn host_fingerprint() -> String {
    format!("{}-{}-w{}", std::env::consts::ARCH, std::env::consts::OS, crate::util::pool::default_workers())
}

/// Bucket one dimension to the next power of two (minimum 1), so nearby
/// shapes share a tuning entry.
pub fn bucket(dim: usize) -> usize {
    let d = dim.max(1);
    d.checked_next_power_of_two().unwrap_or(d)
}

/// Bucket all three GEMM dimensions.
pub fn bucket_shape(shape: (usize, usize, usize)) -> (usize, usize, usize) {
    (bucket(shape.0), bucket(shape.1), bucket(shape.2))
}

/// One tuned record: the winning choice for a (kind, shape bucket).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreEntry {
    pub kind: Kind,
    /// Bucketed dimensions (see [`bucket_shape`]).
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub choice: Choice,
    /// Measured ns/iteration of the winner (0 when cost-model-seeded
    /// without refinement).
    pub measured_ns: f64,
    /// The cost model's predicted cycles for the winner, kept beside the
    /// measurement so prediction drift is visible in the file itself.
    pub predicted_cycles: f64,
}

/// An in-memory tuning store (see the module docs for the on-disk form).
#[derive(Clone, Debug, PartialEq)]
pub struct TuningStore {
    pub host: String,
    pub entries: Vec<StoreEntry>,
}

/// Why a tuning file could not be used. All variants are non-fatal to
/// resolution — [`global`] maps every one to the empty store.
#[derive(Clone, Debug, PartialEq)]
pub enum StoreError {
    /// The file could not be read.
    Io(String),
    /// The file is not valid JSON or misses required fields.
    Parse(String),
    /// The file's format version is not [`STORE_VERSION`].
    Version { got: u64 },
    /// The file was tuned on a different machine class.
    HostMismatch { got: String, want: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "tuning file unreadable: {e}"),
            StoreError::Parse(e) => write!(f, "tuning file malformed: {e}"),
            StoreError::Version { got } => {
                write!(f, "tuning file version {got} (this build reads {STORE_VERSION})")
            }
            StoreError::HostMismatch { got, want } => {
                write!(f, "tuning file for host {got}, this host is {want}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

impl TuningStore {
    /// An empty store for this host.
    pub fn empty() -> Self {
        TuningStore { host: host_fingerprint(), entries: Vec::new() }
    }

    /// Insert or replace the entry for `entry`'s (kind, bucket) key.
    pub fn insert(&mut self, entry: StoreEntry) {
        let key = (entry.kind, entry.m, entry.n, entry.k);
        if let Some(slot) = self.entries.iter_mut().find(|e| (e.kind, e.m, e.n, e.k) == key) {
            *slot = entry;
        } else {
            self.entries.push(entry);
        }
    }

    /// Record a winner for an (unbucketed) shape.
    pub fn record(
        &mut self,
        kind: Kind,
        shape: (usize, usize, usize),
        choice: Choice,
        measured_ns: f64,
        predicted_cycles: f64,
    ) {
        let (m, n, k) = bucket_shape(shape);
        self.insert(StoreEntry { kind, m, n, k, choice, measured_ns, predicted_cycles });
    }

    /// The stored choice for an (unbucketed) shape, if its bucket has one.
    pub fn lookup(&self, kind: Kind, shape: (usize, usize, usize)) -> Option<Choice> {
        let (m, n, k) = bucket_shape(shape);
        self.entries.iter().find(|e| e.kind == kind && (e.m, e.n, e.k) == (m, n, k)).map(|e| e.choice)
    }

    /// Serialize to the versioned JSON file format.
    pub fn to_json(&self) -> String {
        let entries: Vec<String> = self
            .entries
            .iter()
            .map(|e| {
                format!(
                    "    {{\"kind\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \
                     \"threading\": \"{}\", \"k_panel\": \"{}\", \"tile\": \"{}\", \
                     \"measured_ns\": {:.3}, \"predicted_cycles\": {:.3}}}",
                    e.kind.label(),
                    e.m,
                    e.n,
                    e.k,
                    threading_str(e.choice.threading),
                    k_panel_str(e.choice.k_panel),
                    tile_str(e.choice.tile),
                    e.measured_ns,
                    e.predicted_cycles,
                )
            })
            .collect();
        format!(
            "{{\n  \"version\": {},\n  \"host\": \"{}\",\n  \"entries\": [\n{}\n  ]\n}}\n",
            STORE_VERSION,
            self.host,
            entries.join(",\n")
        )
    }

    /// Parse the JSON file format. Checks the version, not the host —
    /// host validation happens at [`load`](TuningStore::load), where
    /// "this process should use this file" is the question.
    pub fn from_json(text: &str) -> Result<TuningStore, StoreError> {
        let root = json::parse(text).map_err(|e| StoreError::Parse(e.to_string()))?;
        let version = root
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| StoreError::Parse("missing or non-integer \"version\"".into()))?;
        if version != STORE_VERSION {
            return Err(StoreError::Version { got: version });
        }
        let host = root
            .get("host")
            .and_then(Json::as_str)
            .ok_or_else(|| StoreError::Parse("missing \"host\"".into()))?
            .to_string();
        let list = root
            .get("entries")
            .and_then(Json::as_array)
            .ok_or_else(|| StoreError::Parse("missing \"entries\" array".into()))?;
        let mut entries = Vec::with_capacity(list.len());
        for item in list {
            entries.push(parse_entry(item)?);
        }
        Ok(TuningStore { host, entries })
    }

    /// Read and validate a tuning file for *this* process: parse,
    /// version check, host-fingerprint check.
    pub fn load<P: AsRef<std::path::Path>>(path: P) -> Result<TuningStore, StoreError> {
        let text = std::fs::read_to_string(path).map_err(|e| StoreError::Io(e.to_string()))?;
        let store = Self::from_json(&text)?;
        let want = host_fingerprint();
        if store.host != want {
            return Err(StoreError::HostMismatch { got: store.host, want });
        }
        Ok(store)
    }

    /// Write the store to `path`.
    pub fn save<P: AsRef<std::path::Path>>(&self, path: P) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// The process-wide store behind [`crate::tune::resolve`], loaded once:
/// the file named by `TBGEMM_TUNE_FILE` when it loads cleanly, the
/// empty store (→ cost-model-only ranking) on any failure or when the
/// variable is unset. Failures are deliberately silent — a stale or
/// foreign tuning file must never break inference.
pub fn global() -> &'static TuningStore {
    static STORE: OnceLock<TuningStore> = OnceLock::new();
    STORE.get_or_init(|| match crate::util::env::tune_file() {
        Some(path) => TuningStore::load(&path).unwrap_or_else(|_| TuningStore::empty()),
        None => TuningStore::empty(),
    })
}

pub fn threading_str(threading: Threading) -> String {
    match threading {
        Threading::Single => "single".into(),
        Threading::Fixed(n) => format!("fixed:{n}"),
        Threading::Auto => "auto".into(),
    }
}

pub fn k_panel_str(k_panel: KPanel) -> String {
    match k_panel {
        KPanel::Auto => "auto".into(),
        KPanel::Depth(d) => format!("depth:{d}"),
    }
}

pub fn tile_str(tile: Tile) -> String {
    match tile {
        Tile::Auto => "auto".into(),
        Tile::Rowdot => "rowdot".into(),
        Tile::Wide => "wide".into(),
        // Never serialized: a store resolves *to* concrete tiles. Kept
        // total so `Choice::label` can print any value.
        Tile::Tuned => "tuned".into(),
    }
}

fn parse_kind(s: &str) -> Option<Kind> {
    Kind::ALL.into_iter().find(|k| k.label() == s)
}

fn parse_threading(s: &str) -> Option<Threading> {
    match s {
        "single" => Some(Threading::Single),
        "auto" => Some(Threading::Auto),
        _ => s.strip_prefix("fixed:").and_then(|n| n.parse::<usize>().ok()).map(Threading::Fixed),
    }
}

fn parse_k_panel(s: &str) -> Option<KPanel> {
    match s {
        "auto" => Some(KPanel::Auto),
        _ => s.strip_prefix("depth:").and_then(|d| d.parse::<usize>().ok()).map(KPanel::Depth),
    }
}

fn parse_tile(s: &str) -> Option<Tile> {
    match s {
        "auto" => Some(Tile::Auto),
        "rowdot" => Some(Tile::Rowdot),
        "wide" => Some(Tile::Wide),
        // "tuned" is intentionally rejected: resolution must terminate.
        _ => None,
    }
}

fn parse_entry(item: &Json) -> Result<StoreEntry, StoreError> {
    let field = |name: &'static str| {
        item.get(name).ok_or_else(|| StoreError::Parse(format!("entry missing \"{name}\"")))
    };
    let str_field = |name: &'static str| {
        field(name)?.as_str().map(str::to_string).ok_or_else(|| StoreError::Parse(format!("\"{name}\" not a string")))
    };
    let dim_field = |name: &'static str| {
        field(name)?
            .as_u64()
            .map(|v| v as usize)
            .ok_or_else(|| StoreError::Parse(format!("\"{name}\" not a non-negative integer")))
    };
    let kind_s = str_field("kind")?;
    let kind = parse_kind(&kind_s).ok_or_else(|| StoreError::Parse(format!("unknown kind \"{kind_s}\"")))?;
    let threading_s = str_field("threading")?;
    let threading = parse_threading(&threading_s)
        .ok_or_else(|| StoreError::Parse(format!("unknown threading \"{threading_s}\"")))?;
    let k_panel_s = str_field("k_panel")?;
    let k_panel =
        parse_k_panel(&k_panel_s).ok_or_else(|| StoreError::Parse(format!("unknown k_panel \"{k_panel_s}\"")))?;
    let tile_s = str_field("tile")?;
    let tile = parse_tile(&tile_s).ok_or_else(|| StoreError::Parse(format!("unknown tile \"{tile_s}\"")))?;
    Ok(StoreEntry {
        kind,
        m: dim_field("m")?,
        n: dim_field("n")?,
        k: dim_field("k")?,
        choice: Choice { threading, k_panel, tile },
        measured_ns: field("measured_ns")?.as_f64().unwrap_or(0.0),
        predicted_cycles: field("predicted_cycles")?.as_f64().unwrap_or(0.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(bucket(0), 1);
        assert_eq!(bucket(1), 1);
        assert_eq!(bucket(100), 128);
        assert_eq!(bucket(128), 128);
        assert_eq!(bucket_shape((120, 48, 256)), (128, 64, 256));
    }

    #[test]
    fn insert_replaces_same_bucket() {
        let mut s = TuningStore::empty();
        s.record(Kind::Bnn, (120, 48, 256), Choice::default(), 1.0, 2.0);
        s.record(Kind::Bnn, (100, 40, 200), Choice { tile: Tile::Wide, ..Choice::default() }, 3.0, 4.0);
        assert_eq!(s.entries.len(), 1, "same bucket must replace");
        assert_eq!(s.lookup(Kind::Bnn, (128, 64, 256)).map(|c| c.tile), Some(Tile::Wide));
        assert_eq!(s.lookup(Kind::Bnn, (1000, 48, 256)), None);
        assert_eq!(s.lookup(Kind::Tnn, (120, 48, 256)), None);
    }

    #[test]
    fn choice_vocabulary_round_trips() {
        let choices = [
            Choice::default(),
            Choice { threading: Threading::Fixed(4), ..Choice::default() },
            Choice { threading: Threading::Auto, k_panel: KPanel::Depth(4096), tile: Tile::Wide },
            Choice { tile: Tile::Rowdot, ..Choice::default() },
        ];
        for c in choices {
            assert_eq!(parse_threading(&threading_str(c.threading)), Some(c.threading));
            assert_eq!(parse_k_panel(&k_panel_str(c.k_panel)), Some(c.k_panel));
            assert_eq!(parse_tile(&tile_str(c.tile)), Some(c.tile));
        }
        assert_eq!(parse_tile("tuned"), None, "a store must never resolve to Tuned");
    }

    #[test]
    fn empty_store_serializes_and_parses() {
        let s = TuningStore::empty();
        assert_eq!(TuningStore::from_json(&s.to_json()), Ok(s));
    }
}
