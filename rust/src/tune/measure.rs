//! The microbenchmark refiner: time the top-k cost-model candidates
//! through real [`GemmPlan::run`] calls under a bounded budget and
//! return measured ns/iteration, fastest first.
//!
//! Measurement is optional by design — [`crate::tune::resolve`] never
//! needs it — and bounded: `Budget` caps both how many candidates are
//! timed and how long each one runs, so `repro tune --fast` stays
//! CI-sized. Inputs are synthesized per kind exactly as
//! `bench::grid::time_algorithm` does, so refined numbers are comparable
//! to the paper-grid benches.

use crate::gemm::{GemmError, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
use crate::tune::Choice;
use crate::util::mat::{MatF32, MatU8};
use crate::util::timer::bench_loop;
use crate::util::{MatI8, Rng};

/// How much measuring [`refine`] may do.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Number of candidates timed (the head of the predicted ranking).
    pub top_k: usize,
    /// Minimum wall-clock per candidate, seconds.
    pub min_time_s: f64,
    /// Iteration cap per candidate.
    pub max_iters: usize,
}

impl Budget {
    /// CI-sized: 2 candidates, ≤ 20 iterations or 50 ms each.
    pub fn fast() -> Self {
        Budget { top_k: 2, min_time_s: 0.05, max_iters: 20 }
    }

    /// The `repro tune` default: 4 candidates, ≤ 60 iterations or
    /// 250 ms each.
    pub fn full() -> Self {
        Budget { top_k: 4, min_time_s: 0.25, max_iters: 60 }
    }
}

/// Time the first `budget.top_k` of `cands` (pass them ranked — see
/// [`crate::tune::rank_predicted`]) on synthesized inputs for
/// `(kind, shape)`. Returns `(choice, ns_per_iteration)` sorted fastest
/// first (stable: ties keep the incoming ranking order). Fails only on
/// plan-construction errors, which a legal candidate cannot cause.
pub fn refine(
    kind: Kind,
    shape: (usize, usize, usize),
    cands: &[Choice],
    budget: Budget,
    seed: u64,
) -> Result<Vec<(Choice, f64)>, GemmError> {
    let (m, n, k) = shape;
    let mut rng = Rng::new(seed);
    // Synthesize (A, B) per kind, mirroring bench::grid::time_algorithm
    // (same value domains and U8/U4 zero points).
    let (a_i8, b_i8): (Option<MatI8>, Option<MatI8>) = match kind {
        Kind::Bnn | Kind::DaBnn => {
            (Some(MatI8::random_binary(m, k, &mut rng)), Some(MatI8::random_binary(k, n, &mut rng)))
        }
        Kind::Tnn => (Some(MatI8::random_ternary(m, k, &mut rng)), Some(MatI8::random_ternary(k, n, &mut rng))),
        Kind::Tbn => (Some(MatI8::random_ternary(m, k, &mut rng)), Some(MatI8::random_binary(k, n, &mut rng))),
        _ => (None, None),
    };
    let (a_u8, b_u8): (Option<MatU8>, Option<MatU8>) = match kind {
        Kind::U8 => (Some(MatU8::random(m, k, &mut rng)), Some(MatU8::random(k, n, &mut rng))),
        Kind::U4 => {
            (Some(MatU8::random_below(m, k, 15, &mut rng)), Some(MatU8::random_below(k, n, 15, &mut rng)))
        }
        _ => (None, None),
    };
    let (a_f32, b_f32): (Option<MatF32>, Option<MatF32>) = match kind {
        Kind::F32 => (Some(MatF32::random(m, k, &mut rng)), Some(MatF32::random(k, n, &mut rng))),
        _ => (None, None),
    };
    let mut measured: Vec<(Choice, f64)> = Vec::new();
    for &choice in cands.iter().take(budget.top_k) {
        let config = choice.to_config(kind);
        let plan = match (&b_i8, &b_u8, &b_f32) {
            (Some(b), _, _) => GemmPlan::new(config, Weights::I8(b))?,
            (_, Some(b), _) => GemmPlan::new(config, Weights::U8 { b, za: 3, zb: 5 })?,
            (_, _, Some(b)) => GemmPlan::new(config, Weights::F32(b))?,
            // Every kind fills exactly one matrix group above.
            _ => return Err(GemmError::EmptyDim { dim: "k" }),
        };
        let lhs = match (&a_i8, &a_u8, &a_f32) {
            (Some(a), _, _) => Lhs::I8(a),
            (_, Some(a), _) => Lhs::U8(a),
            (_, _, Some(a)) => Lhs::F32(a),
            _ => return Err(GemmError::EmptyDim { dim: "m" }),
        };
        let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
        let mut scratch = GemmScratch::new();
        // One validated run outside the timed loop: surfaces contract
        // errors as a typed Result and warms the scratch arena, so the
        // loop below measures steady state only.
        plan.run(lhs, &mut out, &mut scratch)?;
        let stats = bench_loop(budget.min_time_s, budget.max_iters, || {
            // Validated above; per-iteration results are discarded.
            let _ = plan.run(lhs, &mut out, &mut scratch);
        });
        measured.push((choice, stats.mean * 1e9));
    }
    measured.sort_by(|a, b| a.1.total_cmp(&b.1));
    Ok(measured)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::candidates;

    /// A tiny budget against a tiny shape: refine must return one timing
    /// per requested candidate, all positive.
    #[test]
    fn refine_times_top_k() {
        let shape = (32, 16, 64);
        let cands = candidates(Kind::Bnn, shape, 2);
        let budget = Budget { top_k: 2, min_time_s: 0.0, max_iters: 2 };
        let timed = refine(Kind::Bnn, shape, &cands, budget, 0xBEEF).expect("refine");
        assert_eq!(timed.len(), 2.min(cands.len()));
        assert!(timed.iter().all(|(_, ns)| *ns > 0.0));
        // Sorted ascending.
        assert!(timed.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    /// Every kind synthesizes a legal input set (the match in `refine`
    /// covers all seven).
    #[test]
    fn refine_covers_all_kinds() {
        let budget = Budget { top_k: 1, min_time_s: 0.0, max_iters: 1 };
        for kind in Kind::ALL {
            let shape = (16, 8, 256);
            let cands = candidates(kind, shape, 1);
            let timed = refine(kind, shape, &cands, budget, 7).expect("refine");
            assert_eq!(timed.len(), 1, "{kind:?}");
        }
    }
}
