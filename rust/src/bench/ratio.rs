//! The paper's Table III: the efficiency-ratio matrix.
//!
//! "Each cell compares algorithms A and B as E_θ(T_B(θ)/T_A(θ))" over the
//! grid θ — i.e. cell (row A, column B) is the mean over grid points of
//! (time of row algorithm / time of column algorithm)... note the paper's
//! header is `B\A`, so cell (row, col) = E[T_row / T_col]: values > 1
//! mean the *column* algorithm is faster.

use crate::bench::grid::GridTimes;
use crate::gemm::Kind;

/// The 7×7 ratio matrix over the algorithm order of [`Kind::ALL`].
#[derive(Clone, Debug)]
pub struct RatioMatrix {
    pub kinds: Vec<Kind>,
    /// `ratios[i][j] = E[T_kinds[i] / T_kinds[j]]`.
    pub ratios: Vec<Vec<f64>>,
}

/// Compute the ratio matrix from per-algorithm grid times. All inputs
/// must cover the same grid in the same order.
pub fn ratio_matrix(times: &[GridTimes]) -> RatioMatrix {
    assert!(!times.is_empty());
    let npoints = times[0].times.len();
    for t in times {
        assert_eq!(t.times.len(), npoints, "grids must match");
    }
    let n = times.len();
    let mut ratios = vec![vec![0f64; n]; n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..npoints {
                debug_assert_eq!(times[i].times[p].0, times[j].times[p].0);
                acc += times[i].times[p].1 / times[j].times[p].1;
            }
            ratios[i][j] = acc / npoints as f64;
        }
    }
    RatioMatrix { kinds: times.iter().map(|t| t.kind).collect(), ratios }
}

impl RatioMatrix {
    /// Ratio `E[T_a / T_b]` (how much faster `b` is than `a`).
    pub fn get(&self, a: Kind, b: Kind) -> f64 {
        let i = self.kinds.iter().position(|&k| k == a).expect("row kind");
        let j = self.kinds.iter().position(|&k| k == b).expect("col kind");
        self.ratios[i][j]
    }
}

/// The paper's Table III reference values, `paper[i][j] = E[T_i/T_j]` in
/// [`Kind::ALL`] order (F32, U8, U4, TNN, TBN, BNN, daBNN).
pub fn paper_table3() -> Vec<Vec<f64>> {
    vec![
        vec![1.00, 1.44, 2.52, 3.63, 3.75, 10.9, 9.60],
        vec![0.69, 1.00, 1.75, 2.51, 2.60, 7.52, 6.63],
        vec![0.40, 0.57, 1.00, 1.44, 1.49, 4.32, 3.81],
        vec![0.28, 0.40, 0.70, 1.00, 1.03, 2.99, 2.64],
        vec![0.27, 0.39, 0.67, 0.97, 1.00, 2.90, 2.55],
        vec![0.093, 0.13, 0.23, 0.34, 0.35, 1.00, 0.88],
        vec![0.11, 0.15, 0.27, 0.39, 0.40, 1.15, 1.00],
    ]
}

/// Render a ratio matrix side by side with the paper's values.
pub fn render_ratio_table(m: &RatioMatrix, title: &str) -> String {
    let paper = paper_table3();
    let mut s = format!("{title}\n");
    s.push_str("rows = algorithm A, cols = algorithm B; cell = E[T_A/T_B] (ours | paper)\n");
    s.push_str(&format!("{:>7}", "B\\A"));
    for k in &m.kinds {
        s.push_str(&format!(" {:>13}", k.label()));
    }
    s.push('\n');
    for (i, ka) in m.kinds.iter().enumerate() {
        s.push_str(&format!("{:>7}", ka.label()));
        for j in 0..m.kinds.len() {
            s.push_str(&format!(" {:>6.2} |{:>5.2}", m.ratios[i][j], paper[i][j]));
        }
        s.push('\n');
    }
    s
}

/// The abstract's headline comparisons extracted from a ratio matrix:
/// (description, ours, paper).
pub fn headline(m: &RatioMatrix) -> Vec<(&'static str, f64, f64)> {
    vec![
        ("TNN vs F32 (×, higher = TNN faster)", m.get(Kind::F32, Kind::Tnn), 3.63),
        ("TNN vs U8", m.get(Kind::U8, Kind::Tnn), 2.51),
        ("TNN vs U4", m.get(Kind::U4, Kind::Tnn), 1.44),
        ("TBN vs F32", m.get(Kind::F32, Kind::Tbn), 3.75),
        ("BNN vs F32", m.get(Kind::F32, Kind::Bnn), 10.9),
        ("BNN vs TNN", m.get(Kind::Tnn, Kind::Bnn), 2.99),
        ("BNN vs TBN", m.get(Kind::Tbn, Kind::Bnn), 2.90),
        ("BNN vs daBNN", m.get(Kind::DaBnn, Kind::Bnn), 1.15),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::grid::GridTimes;

    fn fake_times(kind: Kind, t: f64) -> GridTimes {
        GridTimes { kind, times: vec![((72, 24, 128), t), ((120, 48, 256), 2.0 * t)] }
    }

    #[test]
    fn ratio_matrix_of_constant_factors() {
        let times = vec![fake_times(Kind::F32, 4.0), fake_times(Kind::Tnn, 1.0)];
        let m = ratio_matrix(&times);
        assert!((m.get(Kind::F32, Kind::Tnn) - 4.0).abs() < 1e-12);
        assert!((m.get(Kind::Tnn, Kind::F32) - 0.25).abs() < 1e-12);
        assert!((m.get(Kind::F32, Kind::F32) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_table3_is_consistent() {
        // Diagonal of ones, and (i,j)·(j,i) ≈ 1 within the paper's
        // two-significant-digit rounding.
        let p = paper_table3();
        for i in 0..7 {
            assert_eq!(p[i][i], 1.00);
            for j in 0..7 {
                let prod = p[i][j] * p[j][i];
                assert!((prod - 1.0).abs() < 0.12, "({i},{j}): {prod}");
            }
        }
    }

    #[test]
    fn headline_pulls_correct_cells() {
        let times = vec![
            fake_times(Kind::F32, 36.3),
            fake_times(Kind::U8, 25.1),
            fake_times(Kind::U4, 14.4),
            fake_times(Kind::Tnn, 10.0),
            fake_times(Kind::Tbn, 9.7),
            fake_times(Kind::Bnn, 3.34),
            fake_times(Kind::DaBnn, 3.85),
        ];
        let m = ratio_matrix(&times);
        let h = headline(&m);
        let tnn_f32 = h.iter().find(|x| x.0.starts_with("TNN vs F32")).unwrap();
        assert!((tnn_f32.1 - 3.63).abs() < 1e-9);
    }

    #[test]
    fn render_mentions_all_kinds() {
        let times: Vec<GridTimes> = Kind::ALL.iter().map(|&k| fake_times(k, 1.0)).collect();
        let s = render_ratio_table(&ratio_matrix(&times), "test");
        for k in Kind::ALL {
            assert!(s.contains(k.label()));
        }
    }
}
