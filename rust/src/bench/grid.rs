//! The paper's experimental grid and timed per-algorithm runs.
//!
//! §IV-B: "We ran time measurements for different values of height
//! (H ∈ {72,120,240,360}), width (W ∈ {24,48,72,96}) and depth
//! (D ∈ {128,256,384,512}). Those values are chosen to be multiples of
//! the microkernel size for each algorithm... For each value of
//! parameters, we took the median of 5 measurements... and repeated the
//! whole experiment [50] times, taking the average."

use crate::gemm::native::kernels as nk;
use crate::gemm::native::{BitRows, PlaneRows};
use crate::gemm::Kind;
use crate::util::mat::{MatF32, MatI32, MatI8, MatU8};
use crate::util::timer::paper_protocol;
use crate::util::Rng;

/// One (height, width, depth) point of the grid.
pub type GridPoint = (usize, usize, usize);

/// The paper's H×W×D grid (64 points).
pub fn paper_grid() -> Vec<GridPoint> {
    let hs = [72usize, 120, 240, 360];
    let ws = [24usize, 48, 72, 96];
    let ds = [128usize, 256, 384, 512];
    let mut g = Vec::with_capacity(64);
    for &h in &hs {
        for &w in &ws {
            for &d in &ds {
                g.push((h, w, d));
            }
        }
    }
    g
}

/// A reduced grid for quick smoke runs (one point per corner).
pub fn smoke_grid() -> Vec<GridPoint> {
    vec![(72, 24, 128), (72, 96, 512), (360, 24, 512), (360, 96, 128)]
}

/// Measured seconds per grid point for one algorithm.
#[derive(Clone, Debug)]
pub struct GridTimes {
    pub kind: Kind,
    pub times: Vec<(GridPoint, f64)>,
}

/// Time one algorithm over `grid` with the paper's protocol
/// (`reps` × median-of-`inner`). The right matrix is pre-packed outside
/// the timed region ("one can reorder it... beforehand"); packing the
/// left matrix is part of the timed multiplication, as in Algorithm 2.
pub fn time_algorithm(kind: Kind, grid: &[GridPoint], reps: usize, inner: usize, seed: u64) -> GridTimes {
    let mut rng = Rng::new(seed);
    let mut times = Vec::with_capacity(grid.len());
    for &(h, w, d) in grid {
        let t = match kind {
            Kind::Bnn => {
                let a = MatI8::random_binary(h, d, &mut rng);
                let b = MatI8::random_binary(d, w, &mut rng);
                let bt = BitRows::from_binary_transposed(&b);
                let mut c = MatI32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    let ab = BitRows::from_binary(&a);
                    nk::bnn_gemm(&ab, &bt, &mut c);
                })
            }
            Kind::Tnn => {
                let a = MatI8::random_ternary(h, d, &mut rng);
                let b = MatI8::random_ternary(d, w, &mut rng);
                let bt = PlaneRows::from_ternary_transposed(&b);
                let mut c = MatI32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    let ap = PlaneRows::from_ternary(&a);
                    nk::tnn_gemm(&ap, &bt, &mut c);
                })
            }
            Kind::Tbn => {
                let a = MatI8::random_ternary(h, d, &mut rng);
                let b = MatI8::random_binary(d, w, &mut rng);
                let bt = BitRows::from_binary_transposed(&b);
                let mut c = MatI32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    let ap = PlaneRows::from_ternary(&a);
                    nk::tbn_gemm(&ap, &bt, &mut c);
                })
            }
            Kind::DaBnn => {
                let a = MatI8::random_binary(h, d, &mut rng);
                let b = MatI8::random_binary(d, w, &mut rng);
                let bt = BitRows::from_binary_transposed(&b);
                let mut c = MatF32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    let ab = BitRows::from_binary(&a);
                    nk::dabnn_gemm(&ab, &bt, &mut c);
                })
            }
            Kind::F32 => {
                let a = MatF32::random(h, d, &mut rng);
                let b = MatF32::random(d, w, &mut rng);
                let panels = nk::pack_b_panels_f32(&b);
                let mut c = MatF32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    nk::f32_gemm(&a, &panels, w, &mut c);
                })
            }
            Kind::U8 => {
                let a = MatU8::random(h, d, &mut rng);
                let b = MatU8::random(d, w, &mut rng);
                let panels = nk::pack_b_panels_u8(&b);
                let col_sums: Vec<i32> = (0..w).map(|j| (0..d).map(|t| b.get(t, j) as i32).sum()).collect();
                let mut c = MatI32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    nk::u8_gemm(&a, &panels, w, 3, 5, &col_sums, &mut c);
                })
            }
            Kind::U4 => {
                let a = MatU8::random_below(h, d, 15, &mut rng);
                let b = MatU8::random_below(d, w, 15, &mut rng);
                let panels = nk::pack_b_panels_u8(&b);
                let col_sums: Vec<i32> = (0..w).map(|j| (0..d).map(|t| b.get(t, j) as i32).sum()).collect();
                let mut c = MatI32::zeros(h, w);
                paper_protocol(reps, inner, || {
                    nk::u4_gemm(&a, &panels, w, 3, 5, &col_sums, &mut c);
                })
            }
        };
        times.push(((h, w, d), t));
    }
    GridTimes { kind, times }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_is_64_points_of_the_right_values() {
        let g = paper_grid();
        assert_eq!(g.len(), 64);
        assert!(g.contains(&(72, 24, 128)));
        assert!(g.contains(&(360, 96, 512)));
        for (h, w, d) in g {
            assert!([72, 120, 240, 360].contains(&h));
            assert!([24, 48, 72, 96].contains(&w));
            assert!([128, 256, 384, 512].contains(&d));
        }
    }

    #[test]
    fn timing_one_point_gives_positive_times() {
        for kind in [Kind::Bnn, Kind::Tnn] {
            let gt = time_algorithm(kind, &[(72, 24, 128)], 1, 2, 42);
            assert_eq!(gt.times.len(), 1);
            assert!(gt.times[0].1 > 0.0);
        }
    }
}
