//! The paper's experimental grid and timed per-algorithm runs.
//!
//! §IV-B: "We ran time measurements for different values of height
//! (H ∈ {72,120,240,360}), width (W ∈ {24,48,72,96}) and depth
//! (D ∈ {128,256,384,512}). Those values are chosen to be multiples of
//! the microkernel size for each algorithm... For each value of
//! parameters, we took the median of 5 measurements... and repeated the
//! whole experiment [50] times, taking the average."

use crate::gemm::{GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
use crate::util::mat::{MatF32, MatI8, MatU8};
use crate::util::timer::paper_protocol;
use crate::util::Rng;

/// One (height, width, depth) point of the grid.
pub type GridPoint = (usize, usize, usize);

/// The paper's H×W×D grid (64 points).
pub fn paper_grid() -> Vec<GridPoint> {
    let hs = [72usize, 120, 240, 360];
    let ws = [24usize, 48, 72, 96];
    let ds = [128usize, 256, 384, 512];
    let mut g = Vec::with_capacity(64);
    for &h in &hs {
        for &w in &ws {
            for &d in &ds {
                g.push((h, w, d));
            }
        }
    }
    g
}

/// A reduced grid for quick smoke runs (one point per corner).
pub fn smoke_grid() -> Vec<GridPoint> {
    vec![(72, 24, 128), (72, 96, 512), (360, 24, 512), (360, 96, 128)]
}

/// Measured seconds per grid point for one algorithm.
#[derive(Clone, Debug)]
pub struct GridTimes {
    pub kind: Kind,
    pub times: Vec<(GridPoint, f64)>,
}

/// Time one algorithm over `grid` with the paper's protocol
/// (`reps` × median-of-`inner`), through the plan/execute API
/// ([`GemmPlan`], native backend). The right matrix is packed into the
/// plan outside the timed region ("one can reorder it... beforehand");
/// packing the left matrix is part of the timed multiplication, as in
/// Algorithm 2 — `run` repacks it into the reused scratch arena, so the
/// timed region performs no heap allocation at steady state.
pub fn time_algorithm(kind: Kind, grid: &[GridPoint], reps: usize, inner: usize, seed: u64) -> GridTimes {
    let mut rng = Rng::new(seed);
    let mut times = Vec::with_capacity(grid.len());
    for &(h, w, d) in grid {
        // Synthesize (A, B) for this kind; B is packed into the plan.
        let (lhs_i8, lhs_u8, lhs_f32, plan): (Option<MatI8>, Option<MatU8>, Option<MatF32>, GemmPlan) =
            match kind {
                Kind::Bnn | Kind::DaBnn => {
                    let a = MatI8::random_binary(h, d, &mut rng);
                    let b = MatI8::random_binary(d, w, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::I8(&b)).expect("plan");
                    (Some(a), None, None, plan)
                }
                Kind::Tnn => {
                    let a = MatI8::random_ternary(h, d, &mut rng);
                    let b = MatI8::random_ternary(d, w, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::I8(&b)).expect("plan");
                    (Some(a), None, None, plan)
                }
                Kind::Tbn => {
                    let a = MatI8::random_ternary(h, d, &mut rng);
                    let b = MatI8::random_binary(d, w, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::I8(&b)).expect("plan");
                    (Some(a), None, None, plan)
                }
                Kind::F32 => {
                    let a = MatF32::random(h, d, &mut rng);
                    let b = MatF32::random(d, w, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::F32(&b)).expect("plan");
                    (None, None, Some(a), plan)
                }
                Kind::U8 => {
                    let a = MatU8::random(h, d, &mut rng);
                    let b = MatU8::random(d, w, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::U8 { b: &b, za: 3, zb: 5 })
                        .expect("plan");
                    (None, Some(a), None, plan)
                }
                Kind::U4 => {
                    let a = MatU8::random_below(h, d, 15, &mut rng);
                    let b = MatU8::random_below(d, w, 15, &mut rng);
                    let plan = GemmPlan::new(GemmConfig::native(kind), Weights::U8 { b: &b, za: 3, zb: 5 })
                        .expect("plan");
                    (None, Some(a), None, plan)
                }
            };
        let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
        let mut scratch = GemmScratch::new();
        let t = paper_protocol(reps, inner, || {
            let lhs = match (&lhs_i8, &lhs_u8, &lhs_f32) {
                (Some(a), _, _) => Lhs::I8(a),
                (_, Some(a), _) => Lhs::U8(a),
                _ => Lhs::F32(lhs_f32.as_ref().expect("an LHS variant is always set")),
            };
            plan.run(lhs, &mut out, &mut scratch).expect("grid gemm");
        });
        times.push(((h, w, d), t));
    }
    GridTimes { kind, times }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_is_64_points_of_the_right_values() {
        let g = paper_grid();
        assert_eq!(g.len(), 64);
        assert!(g.contains(&(72, 24, 128)));
        assert!(g.contains(&(360, 96, 512)));
        for (h, w, d) in g {
            assert!([72, 120, 240, 360].contains(&h));
            assert!([24, 48, 72, 96].contains(&w));
            assert!([128, 256, 384, 512].contains(&d));
        }
    }

    #[test]
    fn timing_one_point_gives_positive_times() {
        for kind in [Kind::Bnn, Kind::Tnn] {
            let gt = time_algorithm(kind, &[(72, 24, 128)], 1, 2, 42);
            assert_eq!(gt.times.len(), 1);
            assert!(gt.times[0].1 > 0.0);
        }
    }
}
