//! Benchmark harness reproducing the paper's §IV evaluation.
//!
//! * [`grid`] — the paper's experimental grid: heights {72,120,240,360},
//!   widths {24,48,72,96}, depths {128,256,384,512}; workload generation
//!   and per-algorithm timed runs under the paper's protocol (median of 5
//!   inner runs, averaged over repetitions).
//! * [`ratio`] — the Table III efficiency-ratio matrix `E[T_B/T_A]` and
//!   its rendering, plus the abstract's headline numbers.
//! * [`predicted`] — the same ratio matrix *predicted* by the Cortex-A73
//!   cost model from the emulated microkernel traces (the analytical
//!   counterpart run when ARM hardware is unavailable).

pub mod grid;
pub mod predicted;
pub mod ratio;

pub use grid::{paper_grid, time_algorithm, GridPoint, GridTimes};
pub use ratio::{headline, ratio_matrix, render_ratio_table, RatioMatrix};
