//! Predicted Table III: the Cortex-A73 cost model applied to the
//! emulated microkernel traces over the paper's grid.
//!
//! This is the ARM-free analytical counterpart of the measured table —
//! on the original hardware the measured table is ground truth; here the
//! prediction reconstructs the paper's setting while the native-path
//! measurement (bench::grid) reflects this host.

use crate::bench::grid::GridPoint;
use crate::bench::grid::GridTimes;
use crate::costmodel::predict::epilogue_cost;
use crate::costmodel::table2::{generate, Table2Row};
use crate::costmodel::CostModel;

/// Predict grid "times" (cycles, consistent across kinds so ratios are
/// meaningful) for every algorithm.
pub fn predict_grid(grid: &[GridPoint]) -> Vec<GridTimes> {
    let model = CostModel::cortex_a73();
    let rows: Vec<Table2Row> = generate();
    rows.iter()
        .map(|row| {
            let times = grid
                .iter()
                .map(|&p| {
                    let cycles = model.predict_gemm(&row.trace, row.shape, p, epilogue_cost(&model, row.kind));
                    (p, cycles)
                })
                .collect();
            GridTimes { kind: row.kind, times }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::grid::paper_grid;
    use crate::bench::ratio::ratio_matrix;
    use crate::gemm::Kind;

    #[test]
    fn predicted_ordering_matches_paper() {
        let times = predict_grid(&paper_grid());
        let m = ratio_matrix(&times);
        // The paper's ordering: BNN fastest, then daBNN, then TBN ≈ TNN,
        // then U4, U8, F32 slowest.
        assert!(m.get(Kind::F32, Kind::Tnn) > 1.5, "TNN must beat F32 clearly");
        assert!(m.get(Kind::U8, Kind::Tnn) > 1.2, "TNN must beat U8");
        assert!(m.get(Kind::U4, Kind::Tnn) > 1.0, "TNN must beat U4");
        assert!(m.get(Kind::Tnn, Kind::Bnn) > 2.0, "BNN much faster than TNN");
        assert!(m.get(Kind::Tbn, Kind::Tnn) < 1.05, "TBN not slower than TNN");
    }

    #[test]
    fn predicted_tnn_vs_f32_near_paper() {
        let times = predict_grid(&paper_grid());
        let m = ratio_matrix(&times);
        let r = m.get(Kind::F32, Kind::Tnn);
        // Paper: 3.63. The model should land within a factor ~1.5.
        assert!(r > 2.4 && r < 5.5, "predicted TNN/F32 speedup {r}");
    }
}
