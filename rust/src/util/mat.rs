//! Dense row-major matrices used as the un-packed interchange format.
//!
//! Matrix multiplication in this crate follows the paper's naming:
//! `A` is the left (activation) matrix of shape `m × k` ("height" ×
//! "depth"), `B` is the right (weight) matrix of shape `k × n` ("depth" ×
//! "width") and `C = A·B` is `m × n`.

use crate::util::Rng;

/// Dense row-major `i8` matrix holding binary (`{-1,1}`) or ternary
/// (`{-1,0,1}`) values before packing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatI8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
}

impl MatI8 {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI8 { rows, cols, data: vec![0; rows * cols] }
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i8) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatI8 { rows, cols, data }
    }

    /// Random binary matrix (values in `{-1, 1}`).
    pub fn random_binary(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_binary(&mut m.data);
        m
    }

    /// Random ternary matrix (values in `{-1, 0, 1}`).
    pub fn random_ternary(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = MatI8::zeros(rows, cols);
        rng.fill_ternary(&mut m.data);
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i8) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Self {
        MatI8::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// True if every element is in `{-1, 1}`.
    pub fn is_binary(&self) -> bool {
        self.data.iter().all(|&v| v == 1 || v == -1)
    }

    /// True if every element is in `{-1, 0, 1}`.
    pub fn is_ternary(&self) -> bool {
        self.data.iter().all(|&v| (-1..=1).contains(&v))
    }
}

/// Dense row-major `i32` matrix (accumulator / output side).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI32 { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        self.data[r * self.cols + c] = v;
    }
}

/// Dense row-major `i16` matrix — the output type of the paper's BNN /
/// TNN / TBN multiplications (results are accumulated in signed 16-bit).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatI16 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i16>,
}

impl MatI16 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatI16 { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i16 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i16) {
        self.data[r * self.cols + c] = v;
    }
}

/// Dense row-major `f32` matrix (full-precision baseline + NN tensors).
#[derive(Clone, Debug, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        MatF32 { rows, cols, data }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        MatF32::from_fn(rows, cols, |_, _| rng.normalish())
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }
}

/// Dense row-major `u8` matrix (8-bit quantized path).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatU8 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl MatU8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatU8 { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut m = MatU8::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.next_u32() as u8;
        }
        m
    }

    /// Random matrix with values restricted to `[0, max]` (e.g. 4-bit: 15).
    pub fn random_below(rows: usize, cols: usize, max: u8, rng: &mut Rng) -> Self {
        let mut m = MatU8::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.below(max as usize + 1) as u8;
        }
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> u8 {
        self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_row_major() {
        let m = MatI8::from_fn(2, 3, |r, c| (r * 3 + c) as i8);
        assert_eq!(m.data, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(m.get(1, 2), 5);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(5);
        let m = MatI8::random_ternary(7, 13, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn random_binary_is_binary() {
        let mut rng = Rng::new(11);
        assert!(MatI8::random_binary(16, 64, &mut rng).is_binary());
    }

    #[test]
    fn random_ternary_is_ternary() {
        let mut rng = Rng::new(11);
        let m = MatI8::random_ternary(16, 64, &mut rng);
        assert!(m.is_ternary());
        // and actually uses all three values with overwhelming probability
        assert!(m.data.iter().any(|&v| v == 0));
        assert!(m.data.iter().any(|&v| v == 1));
        assert!(m.data.iter().any(|&v| v == -1));
    }

    #[test]
    fn row_slice_matches_get() {
        let m = MatI8::from_fn(4, 5, |r, c| (r + c) as i8);
        for r in 0..4 {
            for c in 0..5 {
                assert_eq!(m.row(r)[c], m.get(r, c));
            }
        }
    }
}
