//! Deterministic pseudo-random number generation (SplitMix64 + xoshiro256**).
//!
//! All experiments in this repo are seeded so that every table and figure
//! is reproducible bit-for-bit. The generator is Blackman & Vigna's
//! xoshiro256** seeded through SplitMix64, which is the standard way to
//! expand a 64-bit seed into the 256-bit state.

/// A small, fast, deterministic PRNG (xoshiro256**).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift reduction; unbiased
    /// enough for test workloads).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i32
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard-normal-ish f32 (sum of 4 uniforms, Irwin–Hall, good enough
    /// for synthetic weights/activations).
    #[inline]
    pub fn normalish(&mut self) -> f32 {
        ((self.f32() + self.f32() + self.f32() + self.f32()) - 2.0) * 1.732
    }

    /// A random value in `{-1, 1}`.
    #[inline]
    pub fn binary(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 { 1 } else { -1 }
    }

    /// A random value in `{-1, 0, 1}` (uniform over the three).
    #[inline]
    pub fn ternary(&mut self) -> i8 {
        (self.below(3) as i8) - 1
    }

    /// Fill a slice with values in `{-1, 1}`.
    pub fn fill_binary(&mut self, buf: &mut [i8]) {
        for v in buf.iter_mut() {
            *v = self.binary();
        }
    }

    /// Fill a slice with values in `{-1, 0, 1}`.
    pub fn fill_ternary(&mut self, buf: &mut [i8]) {
        for v in buf.iter_mut() {
            *v = self.ternary();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn ternary_hits_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[(r.ternary() + 1) as usize] = true;
        }
        assert_eq!(seen, [true, true, true]);
    }

    #[test]
    fn binary_hits_both_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 2];
        for _ in 0..100 {
            let v = r.binary();
            assert!(v == 1 || v == -1);
            seen[((v + 1) / 2) as usize] = true;
        }
        assert_eq!(seen, [true, true]);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
