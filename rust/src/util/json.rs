//! A minimal recursive-descent JSON parser for the tuning store (the
//! crate is std-only by design, so no serde). Scope: everything the
//! store's own writer emits plus enough generality to reject malformed
//! files with a positioned error instead of a panic.
//!
//! Deliberate simplifications (documented, not accidental): numbers are
//! parsed as `f64`; `\uXXXX` escapes outside the Basic Multilingual
//! Plane (surrogate pairs) decode to U+FFFD; raw control characters
//! inside strings are accepted. Object keys keep file order and
//! duplicate keys resolve to the first occurrence.

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first occurrence); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Non-negative integer view of a number (rejects fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parse failure: byte offset into the input plus a static message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: &'static str,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.offset)
    }
}

impl std::error::Error for JsonError {}

/// Parse `text` as a single JSON value (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let value = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after the top-level value"));
    }
    Ok(value)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { offset: self.pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, byte: u8, msg: &'static str) -> Result<(), JsonError> {
        if self.peek() == Some(byte) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(msg))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &'static str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err("malformed literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        // The input is a &str and the slice spans only ASCII number
        // bytes, so it is always valid UTF-8.
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => Err(JsonError { offset: start, msg: "malformed number" }),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"', "expected '\"'")?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = match self.peek() {
                        Some(c) => c,
                        None => return Err(self.err("unterminated escape")),
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let code = self.hex4()?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    // ASCII delimiters never occur inside UTF-8
                    // continuation bytes, so the chunk is valid UTF-8.
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or(""));
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut value = 0u32;
        for _ in 0..4 {
            let digit = match self.peek().map(|c| (c as char).to_digit(16)) {
                Some(Some(d)) => d,
                _ => return Err(self.err("malformed \\u escape")),
            };
            value = value * 16 + digit;
            self.pos += 1;
        }
        Ok(value)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[', "expected '['")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{', "expected '{'")?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "expected ':' after object key")?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{ "a": [1, 2, {"b": "c"}], "d": null }"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_array).map(<[Json]>::len), Some(3));
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[2].get("b").and_then(Json::as_str), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn empty_containers_with_whitespace() {
        assert_eq!(parse("[ \n ]").unwrap(), Json::Array(Vec::new()));
        assert_eq!(parse("{ \n }").unwrap(), Json::Object(Vec::new()));
    }

    #[test]
    fn decodes_escapes() {
        let v = parse(r#""a\n\t\"\\A né""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A né"));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "[1,", "tru", "\"open", "{\"a\" 1}", "1 2", "[1,]", "nan", "1e999"] {
            assert!(parse(bad).is_err(), "{bad:?} must fail");
        }
        let err = parse("[1, x]").unwrap_err();
        assert!(err.offset > 0 && err.to_string().contains("byte"));
    }

    #[test]
    fn u64_view_rejects_fractions_and_negatives() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_u64(), None);
        assert_eq!(parse("-7").unwrap().as_u64(), None);
    }
}
