//! Central, read-once access to every `TBGEMM_*` environment knob.
//!
//! All environment configuration flows through this module. The
//! structural lint (`tools/structural_lint.py`, rule `env-var`) rejects
//! `env::var` anywhere else under `src/`, so the complete knob set is
//! auditable right here and a misspelled variable name in some far-away
//! module cannot silently no-op. Each accessor parses its variable
//! **once per process** into a `OnceLock` — hot callers (the SIMD
//! dispatch preamble, the pool sizing path) pay a cached load, never an
//! environment lookup — and returns a typed value instead of a string.
//!
//! The knobs:
//!
//! * `TBGEMM_POOL_THREADS` — worker-pool size override ([`pool_threads`]).
//! * `TBGEMM_FORCE_SCALAR` — force the scalar SIMD fallbacks
//!   ([`force_scalar`]); the CI scalar lane sets this.
//! * `TBGEMM_PROP_SEED` — property-suite base seed ([`prop_seed`]); the
//!   CI property lane pins a second seed with it.
//! * `TBGEMM_TUNE_FILE` — path to the persisted tuning store
//!   ([`tune_file`]); `repro tune` writes it, [`crate::tune`] loads it.
//! * `TBGEMM_TUNE_DISABLE` — kill switch for the autotuner
//!   ([`tune_disable`]): `Tile::Tuned` and `NetPlanConfig` tuning
//!   resolve to the default config instead.

use std::sync::OnceLock;

/// `TBGEMM_POOL_THREADS`: requested worker-pool size, parsed and
/// clamped to ≥ 1. `None` when unset or unparseable — the pool then
/// falls back to `available_parallelism`
/// (see [`crate::util::pool::default_workers`]).
pub fn pool_threads() -> Option<usize> {
    static VALUE: OnceLock<Option<usize>> = OnceLock::new();
    *VALUE.get_or_init(|| {
        std::env::var("TBGEMM_POOL_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
    })
}

/// `TBGEMM_FORCE_SCALAR`: true for any non-empty value other than `0`.
/// Forces every `simd_dispatch!` wrapper down its scalar arm (step 1 of
/// the documented dispatch order), which is how CI exercises the scalar
/// fallbacks on hosts whose best SIMD arm would otherwise shadow them.
pub fn force_scalar() -> bool {
    static VALUE: OnceLock<bool> = OnceLock::new();
    *VALUE.get_or_init(|| matches!(std::env::var("TBGEMM_FORCE_SCALAR"), Ok(v) if !v.is_empty() && v != "0"))
}

/// `TBGEMM_PROP_SEED`: base seed for the property-testing suites.
/// `None` when unset or unparseable — the suites then use their
/// built-in default seed, keeping every run replayable either way.
pub fn prop_seed() -> Option<u64> {
    static VALUE: OnceLock<Option<u64>> = OnceLock::new();
    *VALUE.get_or_init(|| std::env::var("TBGEMM_PROP_SEED").ok().and_then(|s| s.trim().parse::<u64>().ok()))
}

/// `TBGEMM_TUNE_FILE`: path to the persisted tuning store consulted by
/// [`crate::tune::resolve`]. `None` when unset or empty — tuned plans
/// then fall back to cost-model-only ranking. Read lazily on first
/// tuned resolution (not at startup), so a process may set it before
/// building its first tuned plan.
pub fn tune_file() -> Option<String> {
    static VALUE: OnceLock<Option<String>> = OnceLock::new();
    VALUE.get_or_init(|| std::env::var("TBGEMM_TUNE_FILE").ok().filter(|s| !s.is_empty())).clone()
}

/// `TBGEMM_TUNE_DISABLE`: true for any non-empty value other than `0`.
/// Disables autotuned resolution entirely — `Tile::Tuned` plans and
/// tuning-enabled `NetPlan`s run the default config, store or no store.
/// The escape hatch for debugging a suspect tuning file.
pub fn tune_disable() -> bool {
    static VALUE: OnceLock<bool> = OnceLock::new();
    *VALUE.get_or_init(|| matches!(std::env::var("TBGEMM_TUNE_DISABLE"), Ok(v) if !v.is_empty() && v != "0"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The accessors are cached: repeated calls agree with themselves
    /// (and with each other) regardless of later environment mutation.
    /// We deliberately do **not** set variables here — these are
    /// process-wide caches, and writing the environment from a threaded
    /// test harness would race other tests reading it.
    #[test]
    fn accessors_are_stable_across_calls() {
        let (p0, f0, s0) = (pool_threads(), force_scalar(), prop_seed());
        let (t0, d0) = (tune_file(), tune_disable());
        for _ in 0..3 {
            assert_eq!(pool_threads(), p0);
            assert_eq!(force_scalar(), f0);
            assert_eq!(prop_seed(), s0);
            assert_eq!(tune_file(), t0);
            assert_eq!(tune_disable(), d0);
        }
        if let Some(n) = p0 {
            assert!(n >= 1, "pool_threads is clamped to >= 1");
        }
        if let Some(path) = &t0 {
            assert!(!path.is_empty(), "tune_file filters empty values");
        }
    }
}
