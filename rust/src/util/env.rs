//! Central, read-once access to every `TBGEMM_*` environment knob.
//!
//! All environment configuration flows through this module. The
//! structural lint (`tools/structural_lint.py`, rule `env-var`) rejects
//! `env::var` anywhere else under `src/`, so the complete knob set is
//! auditable right here and a misspelled variable name in some far-away
//! module cannot silently no-op. Each accessor parses its variable
//! **once per process** into a `OnceLock` — hot callers (the SIMD
//! dispatch preamble, the pool sizing path) pay a cached load, never an
//! environment lookup — and returns a typed value instead of a string.
//!
//! The knobs:
//!
//! * `TBGEMM_POOL_THREADS` — worker-pool size override ([`pool_threads`]).
//! * `TBGEMM_FORCE_SCALAR` — force the scalar SIMD fallbacks
//!   ([`force_scalar`]); the CI scalar lane sets this.
//! * `TBGEMM_PROP_SEED` — property-suite base seed ([`prop_seed`]); the
//!   CI property lane pins a second seed with it.

use std::sync::OnceLock;

/// `TBGEMM_POOL_THREADS`: requested worker-pool size, parsed and
/// clamped to ≥ 1. `None` when unset or unparseable — the pool then
/// falls back to `available_parallelism`
/// (see [`crate::util::pool::default_workers`]).
pub fn pool_threads() -> Option<usize> {
    static VALUE: OnceLock<Option<usize>> = OnceLock::new();
    *VALUE.get_or_init(|| {
        std::env::var("TBGEMM_POOL_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
    })
}

/// `TBGEMM_FORCE_SCALAR`: true for any non-empty value other than `0`.
/// Forces every `simd_dispatch!` wrapper down its scalar arm (step 1 of
/// the documented dispatch order), which is how CI exercises the scalar
/// fallbacks on hosts whose best SIMD arm would otherwise shadow them.
pub fn force_scalar() -> bool {
    static VALUE: OnceLock<bool> = OnceLock::new();
    *VALUE.get_or_init(|| matches!(std::env::var("TBGEMM_FORCE_SCALAR"), Ok(v) if !v.is_empty() && v != "0"))
}

/// `TBGEMM_PROP_SEED`: base seed for the property-testing suites.
/// `None` when unset or unparseable — the suites then use their
/// built-in default seed, keeping every run replayable either way.
pub fn prop_seed() -> Option<u64> {
    static VALUE: OnceLock<Option<u64>> = OnceLock::new();
    *VALUE.get_or_init(|| std::env::var("TBGEMM_PROP_SEED").ok().and_then(|s| s.trim().parse::<u64>().ok()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The accessors are cached: repeated calls agree with themselves
    /// (and with each other) regardless of later environment mutation.
    /// We deliberately do **not** set variables here — these are
    /// process-wide caches, and writing the environment from a threaded
    /// test harness would race other tests reading it.
    #[test]
    fn accessors_are_stable_across_calls() {
        let (p0, f0, s0) = (pool_threads(), force_scalar(), prop_seed());
        for _ in 0..3 {
            assert_eq!(pool_threads(), p0);
            assert_eq!(force_scalar(), f0);
            assert_eq!(prop_seed(), s0);
        }
        if let Some(n) = p0 {
            assert!(n >= 1, "pool_threads is clamped to >= 1");
        }
    }
}
