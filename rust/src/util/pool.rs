//! The process-wide persistent worker pool behind every parallel code
//! path in the crate: GEMM row bands
//! ([`crate::gemm::native::block::parallel_row_bands`]) and the replica
//! chunks of [`crate::coordinator::engine::EnginePool`] both dispatch
//! through [`global`], so they draw from **one shared core budget**
//! instead of spawning fresh scoped `std::thread`s per call and
//! oversubscribing each other.
//!
//! Design (rten's process-global pool is the exemplar):
//!
//! * A fixed set of long-lived workers, sized **once** per process by
//!   [`default_workers`] — the `TBGEMM_POOL_THREADS` env override (read
//!   through [`crate::util::env`]), else
//!   `std::thread::available_parallelism`. [`crate::gemm::Threading`]
//!   stays a *per-call parallelism cap* resolved against this size.
//! * Per-worker run queues with work stealing: a worker pops its own
//!   queue front and steals from the back of its siblings' queues;
//!   submission round-robins across queues.
//! * A scoped execution API, [`WorkerPool::run_scoped`]: borrowing
//!   closures run on the pool and the call does not return until every
//!   task has completed — the same structured-concurrency contract as
//!   `std::thread::scope`, without the per-call spawn/join cost.
//! * **Waiting callers participate**: while a scope waits for its latch
//!   it executes queued pool tasks. That makes nested dispatch (a
//!   replica-chunk task fanning its GEMMs' row bands into the same
//!   pool) deadlock-free even when every worker is itself blocked in an
//!   inner scope — some participant always runs the queued leaves.
//! * Panic semantics match `std::thread::scope`: every task signals its
//!   latch even on unwind, the first panic payload is captured, and the
//!   scope re-raises it *after* all tasks finish (so no task can still
//!   borrow the caller's data when the scope returns).
//!
//! Scheduling never affects results: band/chunk splits are pure
//! functions of the caller's `Threading` cap and problem shape, and
//! tasks write disjoint output regions — so results stay bit-identical
//! at any worker count, the invariant the differential suites pin.
//!
//! All synchronization goes through [`crate::util::sync`], the
//! std/loom seam: `cargo test --features loom --lib -- loom_` runs the
//! `loom_tests` module below, which model-checks the latch count-down
//! and panic-payload handoff, own-queue-pop vs sibling-steal races,
//! nested dispatch on a one-worker pool, and panic-during-steal under
//! every preemption-bounded interleaving — not just the ones a stress
//! test happens to hit.

use crate::util::sync::{self, Arc, Condvar, Mutex};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

/// A borrowing task submitted to [`WorkerPool::run_scoped`]. The scope
/// guarantees completion before it returns, which is what makes the
/// non-`'static` borrow sound.
pub type ScopedTask<'env> = Box<dyn FnOnce() + Send + 'env>;

/// An erased, queued task (lifetime already promoted by the scope).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Worker-pool size, resolved **once** per process:
/// [`crate::util::env::pool_threads`] (`TBGEMM_POOL_THREADS`, parsed and
/// clamped to ≥ 1) if set, else `available_parallelism`. This is also
/// what [`crate::gemm::Threading::Auto`] resolves to, so "Auto" means
/// "use the whole pool" — and costs no syscall on the GEMM hot path.
pub fn default_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| {
        crate::util::env::pool_threads()
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// The process-global pool (built on first use, sized by
/// [`default_workers`], lives for the process). All production dispatch
/// goes through this; [`WorkerPool::new`] exists for tests that need a
/// private pool with a chosen size.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(default_workers()))
}

/// Everything a worker shares with the pool handle: the run queues (one
/// per worker, all under one mutex — tasks here are coarse row bands and
/// replica chunks, so queue-lock cost is noise next to kernel work) and
/// the condvar workers sleep on.
struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct PoolState {
    /// One run queue per worker; `queues[i]` is worker `i`'s own queue.
    queues: Vec<VecDeque<Task>>,
    /// Round-robin submission cursor.
    next: usize,
    shutdown: bool,
}

impl PoolState {
    /// Steal one task for worker `me`: own queue front first, then the
    /// back of each sibling queue.
    fn take_for(&mut self, me: usize) -> Option<Task> {
        if let Some(t) = self.queues[me].pop_front() {
            return Some(t);
        }
        let n = self.queues.len();
        for off in 1..n {
            if let Some(t) = self.queues[(me + off) % n].pop_back() {
                return Some(t);
            }
        }
        None
    }

    /// Pop any queued task (used by waiting scope callers, which have no
    /// queue of their own).
    fn take_any(&mut self) -> Option<Task> {
        self.queues.iter_mut().find_map(|q| q.pop_front())
    }
}

/// Completion latch of one scope: remaining-task count plus the first
/// captured panic payload.
struct Latch {
    state: Mutex<(usize, Option<Box<dyn Any + Send>>)>,
    done_cv: Condvar,
}

impl Latch {
    fn new(tasks: usize) -> Self {
        Latch { state: Mutex::new((tasks, None)), done_cv: Condvar::new() }
    }

    /// Signal one task finished; always called, panic or not. Only the
    /// **first** panic payload is kept (matching `std::thread::scope`,
    /// which re-raises the panic of the first thread that panicked).
    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if st.1.is_none() {
            st.1 = panic;
        }
        if st.0 == 0 {
            self.done_cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    /// Block until every task has signalled.
    fn wait_done(&self) {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.done_cv.wait(st).unwrap();
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.state.lock().unwrap().1.take()
    }
}

/// A fixed set of long-lived worker threads with per-worker run queues
/// and work stealing. Production code uses the one [`global`] pool; own
/// instances are for tests.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<sync::thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Build a pool of `workers` threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                next: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                sync::spawn_named(format!("tbgemm-pool-{i}"), move || worker_loop(&shared, i))
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run borrowing tasks on the pool and return once **all** of them
    /// have completed — the `std::thread::scope` contract on long-lived
    /// threads. A single task runs inline on the caller. If any task
    /// panics, the first payload is re-raised here after every task has
    /// finished (no task may outlive the call: they borrow `'env`).
    ///
    /// The caller participates while waiting: it executes queued pool
    /// tasks instead of blocking, so nested `run_scoped` calls from
    /// inside pool tasks cannot deadlock the fixed-size pool.
    pub fn run_scoped<'env>(&self, tasks: Vec<ScopedTask<'env>>) {
        match tasks.len() {
            0 => return,
            1 => return tasks.into_iter().next().unwrap()(),
            _ => {}
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for task in tasks {
                // SAFETY: the `'env` lifetime is erased to `'static` so
                // the closure can sit in the pool's queues, which
                // outlive this stack frame. That is sound because this
                // function re-bounds the erased lifetime: it does not
                // return until the latch has counted down to zero, the
                // latch is decremented exactly once per task by the
                // wrapper below, and that decrement happens only
                // *after* the task body has finished or unwound
                // (`catch_unwind` turns an unwind into a normal return
                // ahead of `latch.complete`). Queued-but-unrun tasks
                // cannot be dropped out from under the scope either:
                // workers and participating callers only ever
                // pop-and-run, and `Drop` joins every worker — which
                // drains the queues — before the queues are freed. So
                // every task, and every `'env` borrow it captures, is
                // dead before `run_scoped` returns, and the promoted
                // closure never actually outlives `'env`.
                let task: Task = unsafe { std::mem::transmute::<ScopedTask<'env>, ScopedTask<'static>>(task) };
                let latch = Arc::clone(&latch);
                let wrapped: Task = Box::new(move || {
                    let panic = catch_unwind(AssertUnwindSafe(task)).err();
                    latch.complete(panic);
                });
                let q = st.next % self.workers;
                st.next = st.next.wrapping_add(1);
                st.queues[q].push_back(wrapped);
            }
            self.shared.work_cv.notify_all();
        }
        // Work-stealing join: run queued tasks (this scope's or anyone
        // else's) until our latch closes; only block when no task is
        // queued anywhere — then every remaining task of ours is already
        // executing on some thread and will signal the latch.
        while !latch.is_done() {
            let task = self.shared.state.lock().unwrap().take_any();
            match task {
                Some(task) => task(),
                None => latch.wait_done(),
            }
        }
        if let Some(payload) = latch.take_panic() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, me: usize) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = st.take_for(me) {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        match task {
            Some(task) => task(),
            None => return,
        }
    }
}

#[cfg(all(test, not(feature = "loom")))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn default_workers_is_cached_and_positive() {
        let first = default_workers();
        assert!(first >= 1);
        for _ in 0..3 {
            assert_eq!(default_workers(), first);
        }
        assert_eq!(global().workers(), first);
    }

    #[test]
    fn runs_every_task_with_more_tasks_than_workers() {
        let pool = WorkerPool::new(2);
        let ran = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    ran.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(ran.load(Ordering::SeqCst), 64);
    }

    /// The scoped contract: tasks may borrow the caller's stack mutably
    /// (disjoint regions) and every write is visible when `run_scoped`
    /// returns.
    #[test]
    fn scoped_tasks_write_borrowed_bands() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0usize; 61];
        let tasks: Vec<ScopedTask<'_>> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, band)| {
                Box::new(move || {
                    for x in band.iter_mut() {
                        *x = i + 1;
                    }
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, j / 7 + 1, "element {j}");
        }
    }

    /// Nested dispatch from inside pool tasks must not deadlock, even on
    /// a pool smaller than the outer fan-out: waiting scopes execute
    /// queued tasks themselves.
    #[test]
    fn nested_scopes_do_not_deadlock_a_tiny_pool() {
        let pool = WorkerPool::new(1);
        let ran = AtomicUsize::new(0);
        let outer: Vec<ScopedTask<'_>> = (0..4)
            .map(|_| {
                let (pool, ran) = (&pool, &ran);
                Box::new(move || {
                    let inner: Vec<ScopedTask<'_>> = (0..4)
                        .map(|_| {
                            Box::new(|| {
                                ran.fetch_add(1, Ordering::SeqCst);
                            }) as ScopedTask<'_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(ran.load(Ordering::SeqCst), 16);
    }

    /// A panicking task is re-raised by the scope — after the healthy
    /// tasks finished (their side effects are all visible).
    #[test]
    fn panic_propagates_after_all_tasks_complete() {
        let pool = WorkerPool::new(2);
        let healthy = AtomicUsize::new(0);
        let mut tasks: Vec<ScopedTask<'_>> = (0..8)
            .map(|_| {
                let healthy = &healthy;
                Box::new(move || {
                    healthy.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        tasks.push(Box::new(|| panic!("task panic (test)")));
        let result = catch_unwind(AssertUnwindSafe(|| pool.run_scoped(tasks)));
        assert!(result.is_err(), "scope must re-raise the task panic");
        assert_eq!(healthy.load(Ordering::SeqCst), 8);
        // The pool survives a panicked scope and keeps serving.
        let again = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..4)
            .map(|_| {
                let again = &again;
                Box::new(move || {
                    again.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(again.load(Ordering::SeqCst), 4);
    }

    /// Concurrent scopes from many threads share one pool without
    /// cross-talk: every scope sees exactly its own writes. (Shrunk
    /// under Miri: the interpreter runs real threads, slowly.)
    #[test]
    fn concurrent_scopes_share_one_pool() {
        let pool = WorkerPool::new(2);
        let (spawners, rounds) = if cfg!(miri) { (3usize, 2usize) } else { (6, 8) };
        std::thread::scope(|s| {
            for seed in 0..spawners {
                let pool = &pool;
                s.spawn(move || {
                    for round in 0..rounds {
                        let mut data = vec![0usize; 24];
                        let tasks: Vec<ScopedTask<'_>> = data
                            .chunks_mut(6)
                            .map(|band| {
                                Box::new(move || {
                                    for x in band.iter_mut() {
                                        *x = seed * 100 + round;
                                    }
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        pool.run_scoped(tasks);
                        assert!(data.iter().all(|&x| x == seed * 100 + round));
                    }
                });
            }
        });
    }
}

/// Exhaustive-interleaving models of the pool's unsafe core, run by the
/// CI loom lane (`cargo test --features loom --lib -- loom_`). Every
/// test body executes under `loom::model`, which explores all
/// preemption-bounded thread interleavings of the loom-backed
/// primitives in [`crate::util::sync`] — so these assertions hold on
/// *every* schedule, not just the ones a stress run happens to produce.
#[cfg(all(test, feature = "loom"))]
mod loom_tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use loom::model::Builder;

    /// Model with a preemption bound of 2: loom's own guidance for
    /// keeping state-space exploration tractable while still catching
    /// essentially all realistic bugs; it also bounds the CI lane's
    /// wall-clock.
    fn model(f: impl Fn() + Sync + Send + 'static) {
        let mut b = Builder::new();
        b.preemption_bound = Some(2);
        b.check(f);
    }

    /// Suppress per-iteration panic output: these models panic inside
    /// tasks on purpose, thousands of interleavings per test.
    fn silence_panics() {
        std::panic::set_hook(Box::new(|_| {}));
    }

    /// Latch count-down handoff: two completers signal concurrently, a
    /// waiter blocks until both have; no payload is fabricated.
    #[test]
    fn loom_latch_countdown_handoff() {
        model(|| {
            let latch = Arc::new(Latch::new(2));
            let a = Arc::clone(&latch);
            let b = Arc::clone(&latch);
            let t1 = loom::thread::spawn(move || a.complete(None));
            let t2 = loom::thread::spawn(move || b.complete(None));
            latch.wait_done();
            assert!(latch.is_done());
            assert!(latch.take_panic().is_none());
            t1.join().unwrap();
            t2.join().unwrap();
        });
    }

    /// First-payload-wins, deterministically: the payload stored first
    /// survives a concurrent second `complete(Some(..))`, and a `None`
    /// completion never erases a stored payload.
    #[test]
    fn loom_latch_first_payload_wins() {
        model(|| {
            let latch = Arc::new(Latch::new(3));
            latch.complete(Some(Box::new("first")));
            let a = Arc::clone(&latch);
            let b = Arc::clone(&latch);
            let t1 = loom::thread::spawn(move || a.complete(Some(Box::new("second"))));
            let t2 = loom::thread::spawn(move || b.complete(None));
            latch.wait_done();
            t1.join().unwrap();
            t2.join().unwrap();
            let payload = latch.take_panic().expect("a payload was stored");
            assert_eq!(*payload.downcast_ref::<&str>().expect("str payload"), "first");
            assert!(latch.take_panic().is_none(), "take_panic consumes the payload");
        });
    }

    /// Scoped dispatch under every interleaving of two workers plus the
    /// participating caller: three tasks land in two run queues
    /// (round-robin), so every schedule mixes own-queue pops with
    /// sibling steals and caller participation — and each disjoint
    /// borrowed write must still be visible when `run_scoped` returns.
    #[test]
    fn loom_own_pop_vs_sibling_steal() {
        model(|| {
            let pool = WorkerPool::new(2);
            let mut data = [0usize; 3];
            let tasks: Vec<ScopedTask<'_>> = data
                .chunks_mut(1)
                .enumerate()
                .map(|(i, band)| Box::new(move || band[0] = i + 1) as ScopedTask<'_>)
                .collect();
            pool.run_scoped(tasks);
            assert_eq!(data, [1, 2, 3]);
        });
    }

    /// Nested dispatch on a one-worker pool: the outer scope's waiting
    /// caller and the single worker must between them run both outer
    /// tasks and all inner tasks without deadlock, on every schedule.
    #[test]
    fn loom_nested_dispatch_single_worker() {
        model(|| {
            let pool = WorkerPool::new(1);
            let ran = AtomicUsize::new(0);
            let outer: Vec<ScopedTask<'_>> = (0..2)
                .map(|_| {
                    let (pool, ran) = (&pool, &ran);
                    Box::new(move || {
                        let inner: Vec<ScopedTask<'_>> = (0..2)
                            .map(|_| {
                                Box::new(|| {
                                    ran.fetch_add(1, Ordering::SeqCst);
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        pool.run_scoped(inner);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run_scoped(outer);
            assert_eq!(ran.load(Ordering::SeqCst), 4);
        });
    }

    /// A task panics while its siblings are being popped/stolen by the
    /// other worker and the caller: on every interleaving the panic is
    /// re-raised only after both healthy tasks ran, and their writes
    /// are visible despite the unwind.
    #[test]
    fn loom_panic_during_sibling_steal() {
        silence_panics();
        model(|| {
            let pool = WorkerPool::new(2);
            let ran = AtomicUsize::new(0);
            let mut tasks: Vec<ScopedTask<'_>> = (0..2)
                .map(|_| {
                    let ran = &ran;
                    Box::new(move || {
                        ran.fetch_add(1, Ordering::SeqCst);
                    }) as ScopedTask<'_>
                })
                .collect();
            tasks.push(Box::new(|| panic!("loom task panic")));
            let result = catch_unwind(AssertUnwindSafe(|| pool.run_scoped(tasks)));
            let payload = result.expect_err("scope must re-raise the task panic");
            assert_eq!(*payload.downcast_ref::<&str>().expect("str payload"), "loom task panic");
            assert_eq!(ran.load(Ordering::SeqCst), 2, "healthy siblings completed before the re-raise");
        });
    }

    /// Two tasks panic concurrently: exactly one payload (one of the
    /// two) is re-raised, and the pool keeps serving scopes afterwards.
    #[test]
    fn loom_two_panics_single_payload_pool_survives() {
        silence_panics();
        model(|| {
            let pool = WorkerPool::new(1);
            let tasks: Vec<ScopedTask<'_>> =
                vec![Box::new(|| panic!("first panic")), Box::new(|| panic!("second panic"))];
            let payload = catch_unwind(AssertUnwindSafe(|| pool.run_scoped(tasks)))
                .expect_err("scope must re-raise one panic");
            let msg = *payload.downcast_ref::<&str>().expect("str payload");
            assert!(msg == "first panic" || msg == "second panic", "payload is one of the two: {msg}");
            let ran = AtomicUsize::new(0);
            let tasks: Vec<ScopedTask<'_>> = (0..2)
                .map(|_| {
                    let ran = &ran;
                    Box::new(move || {
                        ran.fetch_add(1, Ordering::SeqCst);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run_scoped(tasks);
            assert_eq!(ran.load(Ordering::SeqCst), 2, "pool serves scopes after a panicked scope");
        });
    }
}
