//! The process-wide persistent worker pool behind every parallel code
//! path in the crate: GEMM row bands
//! ([`crate::gemm::native::block::parallel_row_bands`]) and the replica
//! chunks of [`crate::coordinator::engine::EnginePool`] both dispatch
//! through [`global`], so they draw from **one shared core budget**
//! instead of spawning fresh scoped `std::thread`s per call and
//! oversubscribing each other.
//!
//! Design (rten's process-global pool is the exemplar):
//!
//! * A fixed set of long-lived workers, sized **once** per process by
//!   [`default_workers`] — the `TBGEMM_POOL_THREADS` env override, else
//!   `std::thread::available_parallelism`. [`crate::gemm::Threading`]
//!   stays a *per-call parallelism cap* resolved against this size.
//! * Per-worker run queues with work stealing: a worker pops its own
//!   queue front and steals from the back of its siblings' queues;
//!   submission round-robins across queues.
//! * A scoped execution API, [`WorkerPool::run_scoped`]: borrowing
//!   closures run on the pool and the call does not return until every
//!   task has completed — the same structured-concurrency contract as
//!   `std::thread::scope`, without the per-call spawn/join cost.
//! * **Waiting callers participate**: while a scope waits for its latch
//!   it executes queued pool tasks. That makes nested dispatch (a
//!   replica-chunk task fanning its GEMMs' row bands into the same
//!   pool) deadlock-free even when every worker is itself blocked in an
//!   inner scope — some participant always runs the queued leaves.
//! * Panic semantics match `std::thread::scope`: every task signals its
//!   latch even on unwind, the first panic payload is captured, and the
//!   scope re-raises it *after* all tasks finish (so no task can still
//!   borrow the caller's data when the scope returns).
//!
//! Scheduling never affects results: band/chunk splits are pure
//! functions of the caller's `Threading` cap and problem shape, and
//! tasks write disjoint output regions — so results stay bit-identical
//! at any worker count, the invariant the differential suites pin.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A borrowing task submitted to [`WorkerPool::run_scoped`]. The scope
/// guarantees completion before it returns, which is what makes the
/// non-`'static` borrow sound.
pub type ScopedTask<'env> = Box<dyn FnOnce() + Send + 'env>;

/// An erased, queued task (lifetime already promoted by the scope).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Worker-pool size, resolved **once** per process: `TBGEMM_POOL_THREADS`
/// (parsed, clamped to ≥ 1) if set, else `available_parallelism`. This is
/// also what [`crate::gemm::Threading::Auto`] resolves to, so "Auto"
/// means "use the whole pool" — and costs no syscall on the GEMM hot
/// path.
pub fn default_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| {
        std::env::var("TBGEMM_POOL_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// The process-global pool (built on first use, sized by
/// [`default_workers`], lives for the process). All production dispatch
/// goes through this; [`WorkerPool::new`] exists for tests that need a
/// private pool with a chosen size.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(default_workers()))
}

/// Everything a worker shares with the pool handle: the run queues (one
/// per worker, all under one mutex — tasks here are coarse row bands and
/// replica chunks, so queue-lock cost is noise next to kernel work) and
/// the condvar workers sleep on.
struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct PoolState {
    /// One run queue per worker; `queues[i]` is worker `i`'s own queue.
    queues: Vec<VecDeque<Task>>,
    /// Round-robin submission cursor.
    next: usize,
    shutdown: bool,
}

impl PoolState {
    /// Steal one task for worker `me`: own queue front first, then the
    /// back of each sibling queue.
    fn take_for(&mut self, me: usize) -> Option<Task> {
        if let Some(t) = self.queues[me].pop_front() {
            return Some(t);
        }
        let n = self.queues.len();
        for off in 1..n {
            if let Some(t) = self.queues[(me + off) % n].pop_back() {
                return Some(t);
            }
        }
        None
    }

    /// Pop any queued task (used by waiting scope callers, which have no
    /// queue of their own).
    fn take_any(&mut self) -> Option<Task> {
        self.queues.iter_mut().find_map(|q| q.pop_front())
    }
}

/// Completion latch of one scope: remaining-task count plus the first
/// captured panic payload.
struct Latch {
    state: Mutex<(usize, Option<Box<dyn Any + Send>>)>,
    done_cv: Condvar,
}

impl Latch {
    fn new(tasks: usize) -> Self {
        Latch { state: Mutex::new((tasks, None)), done_cv: Condvar::new() }
    }

    /// Signal one task finished; always called, panic or not.
    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if st.1.is_none() {
            st.1 = panic;
        }
        if st.0 == 0 {
            self.done_cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    /// Block until every task has signalled.
    fn wait_done(&self) {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.done_cv.wait(st).unwrap();
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.state.lock().unwrap().1.take()
    }
}

/// A fixed set of long-lived worker threads with per-worker run queues
/// and work stealing. Production code uses the one [`global`] pool; own
/// instances are for tests.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Build a pool of `workers` threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                next: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tbgemm-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run borrowing tasks on the pool and return once **all** of them
    /// have completed — the `std::thread::scope` contract on long-lived
    /// threads. A single task runs inline on the caller. If any task
    /// panics, the first payload is re-raised here after every task has
    /// finished (no task may outlive the call: they borrow `'env`).
    ///
    /// The caller participates while waiting: it executes queued pool
    /// tasks instead of blocking, so nested `run_scoped` calls from
    /// inside pool tasks cannot deadlock the fixed-size pool.
    pub fn run_scoped<'env>(&self, tasks: Vec<ScopedTask<'env>>) {
        match tasks.len() {
            0 => return,
            1 => return tasks.into_iter().next().unwrap()(),
            _ => {}
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for task in tasks {
                // SAFETY: promoting `'env` to `'static` is sound because
                // this function does not return until the latch reports
                // every task complete (the wrapper below signals even on
                // unwind), so no task outlives the borrows it captures.
                let task: Task = unsafe {
                    std::mem::transmute::<ScopedTask<'env>, ScopedTask<'static>>(task)
                };
                let latch = Arc::clone(&latch);
                let wrapped: Task = Box::new(move || {
                    let panic = catch_unwind(AssertUnwindSafe(task)).err();
                    latch.complete(panic);
                });
                let q = st.next % self.workers;
                st.next = st.next.wrapping_add(1);
                st.queues[q].push_back(wrapped);
            }
            self.shared.work_cv.notify_all();
        }
        // Work-stealing join: run queued tasks (this scope's or anyone
        // else's) until our latch closes; only block when no task is
        // queued anywhere — then every remaining task of ours is already
        // executing on some thread and will signal the latch.
        while !latch.is_done() {
            let task = self.shared.state.lock().unwrap().take_any();
            match task {
                Some(task) => task(),
                None => latch.wait_done(),
            }
        }
        if let Some(payload) = latch.take_panic() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, me: usize) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = st.take_for(me) {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        match task {
            Some(task) => task(),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn default_workers_is_cached_and_positive() {
        let first = default_workers();
        assert!(first >= 1);
        for _ in 0..3 {
            assert_eq!(default_workers(), first);
        }
        assert_eq!(global().workers(), first);
    }

    #[test]
    fn runs_every_task_with_more_tasks_than_workers() {
        let pool = WorkerPool::new(2);
        let ran = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    ran.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(ran.load(Ordering::SeqCst), 64);
    }

    /// The scoped contract: tasks may borrow the caller's stack mutably
    /// (disjoint regions) and every write is visible when `run_scoped`
    /// returns.
    #[test]
    fn scoped_tasks_write_borrowed_bands() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0usize; 61];
        let tasks: Vec<ScopedTask<'_>> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(i, band)| {
                Box::new(move || {
                    for x in band.iter_mut() {
                        *x = i + 1;
                    }
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        for (j, &x) in data.iter().enumerate() {
            assert_eq!(x, j / 7 + 1, "element {j}");
        }
    }

    /// Nested dispatch from inside pool tasks must not deadlock, even on
    /// a pool smaller than the outer fan-out: waiting scopes execute
    /// queued tasks themselves.
    #[test]
    fn nested_scopes_do_not_deadlock_a_tiny_pool() {
        let pool = WorkerPool::new(1);
        let ran = AtomicUsize::new(0);
        let outer: Vec<ScopedTask<'_>> = (0..4)
            .map(|_| {
                let (pool, ran) = (&pool, &ran);
                Box::new(move || {
                    let inner: Vec<ScopedTask<'_>> = (0..4)
                        .map(|_| {
                            Box::new(|| {
                                ran.fetch_add(1, Ordering::SeqCst);
                            }) as ScopedTask<'_>
                        })
                        .collect();
                    pool.run_scoped(inner);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(outer);
        assert_eq!(ran.load(Ordering::SeqCst), 16);
    }

    /// A panicking task is re-raised by the scope — after the healthy
    /// tasks finished (their side effects are all visible).
    #[test]
    fn panic_propagates_after_all_tasks_complete() {
        let pool = WorkerPool::new(2);
        let healthy = AtomicUsize::new(0);
        let mut tasks: Vec<ScopedTask<'_>> = (0..8)
            .map(|_| {
                let healthy = &healthy;
                Box::new(move || {
                    healthy.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        tasks.push(Box::new(|| panic!("task panic (test)")));
        let result = catch_unwind(AssertUnwindSafe(|| pool.run_scoped(tasks)));
        assert!(result.is_err(), "scope must re-raise the task panic");
        assert_eq!(healthy.load(Ordering::SeqCst), 8);
        // The pool survives a panicked scope and keeps serving.
        let again = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..4)
            .map(|_| {
                let again = &again;
                Box::new(move || {
                    again.fetch_add(1, Ordering::SeqCst);
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(again.load(Ordering::SeqCst), 4);
    }

    /// Concurrent scopes from many threads share one pool without
    /// cross-talk: every scope sees exactly its own writes.
    #[test]
    fn concurrent_scopes_share_one_pool() {
        let pool = WorkerPool::new(2);
        std::thread::scope(|s| {
            for seed in 0..6usize {
                let pool = &pool;
                s.spawn(move || {
                    for round in 0..8usize {
                        let mut data = vec![0usize; 24];
                        let tasks: Vec<ScopedTask<'_>> = data
                            .chunks_mut(6)
                            .map(|band| {
                                Box::new(move || {
                                    for x in band.iter_mut() {
                                        *x = seed * 100 + round;
                                    }
                                }) as ScopedTask<'_>
                            })
                            .collect();
                        pool.run_scoped(tasks);
                        assert!(data.iter().all(|&x| x == seed * 100 + round));
                    }
                });
            }
        });
    }
}
