//! Small self-contained utilities: deterministic PRNG, dense matrices,
//! timing helpers, a light property-testing harness, and the
//! process-wide persistent worker pool ([`pool`]) every parallel code
//! path dispatches through.
//!
//! The build environment is fully offline, so this crate cannot depend on
//! `rand`, `criterion` or `proptest`; these modules provide the small
//! subset of their functionality the rest of the crate needs.

pub mod mat;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod timer;

pub use mat::MatI8;
pub use prng::Rng;
