//! Small self-contained utilities: deterministic PRNG, dense matrices,
//! timing helpers and a light property-testing harness.
//!
//! The build environment is fully offline, so this crate cannot depend on
//! `rand`, `criterion` or `proptest`; these modules provide the small
//! subset of their functionality the rest of the crate needs.

pub mod mat;
pub mod prng;
pub mod proptest;
pub mod timer;

pub use mat::MatI8;
pub use prng::Rng;
