//! Small self-contained utilities: deterministic PRNG, dense matrices,
//! timing helpers, a light property-testing harness, the process-wide
//! persistent worker pool ([`pool`]) every parallel code path
//! dispatches through, the std/loom synchronization seam ([`sync`])
//! that pool is model-checked through, and the central `TBGEMM_*`
//! environment-knob registry ([`env`]).
//!
//! The build environment is fully offline, so this crate cannot depend on
//! `rand`, `criterion` or `proptest`; these modules provide the small
//! subset of their functionality the rest of the crate needs.

pub mod env;
pub mod json;
pub mod mat;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod sync;
pub mod timer;

pub use mat::MatI8;
pub use prng::Rng;
