//! Timing helpers implementing the paper's measurement protocol.
//!
//! §IV-B: "for each value of parameters, we took the median of 5
//! measurements (to exclude random errors) and repeated the whole
//! experiment 50 times, taking the average of the measurements".

use std::time::Instant;

/// Wall-clock one invocation of `f`, in seconds.
#[inline]
pub fn time_once<R>(f: impl FnOnce() -> R) -> (f64, R) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Median of `n` timings of `f` (the paper's inner loop, n = 5).
pub fn median_of<R>(n: usize, mut f: impl FnMut() -> R) -> f64 {
    assert!(n > 0);
    let mut ts: Vec<f64> = (0..n).map(|_| time_once(&mut f).0).collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if n % 2 == 1 {
        ts[n / 2]
    } else {
        0.5 * (ts[n / 2 - 1] + ts[n / 2])
    }
}

/// The paper's full protocol: mean over `reps` of (median of `inner`).
pub fn paper_protocol<R>(reps: usize, inner: usize, mut f: impl FnMut() -> R) -> f64 {
    assert!(reps > 0);
    let total: f64 = (0..reps).map(|_| median_of(inner, &mut f)).sum();
    total / reps as f64
}

/// Simple statistics over a sample of timings.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

/// Compute [`Stats`] for a non-empty slice.
pub fn stats(xs: &[f64]) -> Stats {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Stats {
        mean,
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        stddev: var.sqrt(),
    }
}

/// A tiny fixed-work benchmark runner used by the `cargo bench` harnesses
/// (criterion is unavailable offline). Runs `f` until at least
/// `min_time_s` seconds or `max_iters` iterations, whichever first, and
/// reports per-iteration time.
pub fn bench_loop(min_time_s: f64, max_iters: usize, mut f: impl FnMut()) -> Stats {
    // Warm-up.
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    let mut iters = 0usize;
    while iters < max_iters && (start.elapsed().as_secs_f64() < min_time_s || samples.len() < 3) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
    }
    stats(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_is_order_insensitive() {
        // Just checks median_of runs and returns a positive finite value.
        let t = median_of(5, || std::hint::black_box((0..1000).sum::<u64>()));
        assert!(t.is_finite() && t >= 0.0);
    }

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn bench_loop_respects_max_iters() {
        let mut count = 0;
        let _ = bench_loop(10.0, 5, || count += 1);
        assert!(count <= 6); // warm-up + 5
    }
}
