//! The one seam between the crate's concurrency core and its
//! synchronization primitives: `std::sync` in normal builds,
//! `loom::sync` under `--features loom`.
//!
//! [`crate::util::pool`] and [`crate::coordinator::batcher`] import
//! `Arc`/`Mutex`/`Condvar`/atomics from here instead of `std::sync`, so
//! the **same** production code that runs in release builds is what the
//! loom lane model-checks under exhaustive preemption-bounded
//! interleavings (`cargo test --features loom --lib -- loom_` in CI,
//! after `tools/enable_loom.py` splices the dependency into the
//! otherwise offline-only manifest).
//!
//! Two operations need real shims rather than re-exports:
//!
//! * [`spawn_named`] — loom's `thread::spawn` takes no builder, so the
//!   thread name is carried only in std builds. Thread spawning lives
//!   here and in [`crate::util::pool`] alone; the structural lint
//!   (rule `thread-spawn`) keeps it that way.
//! * [`wait_timeout`] — loom's `Condvar::wait_timeout` does not model a
//!   clock, so under loom it degrades to a plain `wait`. Loom models
//!   must therefore never rely on a timeout for progress (the batcher
//!   loom tests use zero-width batch windows so the timeout path is
//!   never their only wake-up).

#[cfg(not(feature = "loom"))]
pub use std::sync::{atomic, Arc, Condvar, Mutex, MutexGuard};

#[cfg(feature = "loom")]
pub use loom::sync::{atomic, Arc, Condvar, Mutex, MutexGuard};

/// Thread handles from the active runtime.
pub mod thread {
    #[cfg(not(feature = "loom"))]
    pub use std::thread::JoinHandle;

    #[cfg(feature = "loom")]
    pub use loom::thread::JoinHandle;
}

/// Spawn a named thread on the active runtime. Loom has no thread
/// names, so the name is dropped there; in std builds it shows up in
/// panic messages and debuggers (`tbgemm-pool-0`, …).
#[cfg(not(feature = "loom"))]
pub fn spawn_named<F>(name: String, f: F) -> thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new().name(name).spawn(f).expect("spawn named thread")
}

/// Spawn a named thread on the active runtime (loom build: the name is
/// dropped, loom threads are anonymous model threads).
#[cfg(feature = "loom")]
pub fn spawn_named<F>(name: String, f: F) -> thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    let _ = name;
    loom::thread::spawn(f)
}

/// Condvar wait bounded by `dur`. Under loom this is a plain `wait` —
/// loom has no virtual clock — so callers must guarantee a matching
/// `notify` exists on every modeled path and treat the timeout purely
/// as a liveness bound, never as the sole wake-up mechanism.
#[cfg(not(feature = "loom"))]
pub fn wait_timeout<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>, dur: std::time::Duration) -> MutexGuard<'a, T> {
    cv.wait_timeout(guard, dur).expect("queue mutex poisoned").0
}

/// Condvar wait bounded by `dur` (loom build: degrades to `wait`).
#[cfg(feature = "loom")]
pub fn wait_timeout<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>, dur: std::time::Duration) -> MutexGuard<'a, T> {
    let _ = dur;
    cv.wait(guard).expect("queue mutex poisoned")
}
