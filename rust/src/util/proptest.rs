//! A light property-based testing harness (the real `proptest` crate is
//! unavailable in this offline build environment).
//!
//! Provides seeded random-case generation with failure reporting that
//! includes the case seed, so any failing case can be replayed
//! deterministically, plus a greedy size-shrinking loop for the common
//! "random matrix shape" generators used across the GEMM tests.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to try.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cfg.cases` seeded [`Rng`]s; panic with the seed of the
/// first failing case. `prop` should panic (e.g. via `assert!`) on failure.
pub fn check(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng)) {
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = panic_message(&e);
            panic!("property '{name}' failed on case {i} (seed={seed:#x}): {msg}");
        }
    }
}

/// Run with the default config.
pub fn check_default(name: &str, prop: impl FnMut(&mut Rng)) {
    check(Config::default(), name, prop);
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Generate a random GEMM problem size. Sizes are biased toward microkernel
/// boundaries (multiples of 8/16 ± 1) to probe edge handling.
pub fn gemm_shape(rng: &mut Rng, max_m: usize, max_n: usize, max_k: usize) -> (usize, usize, usize) {
    fn dim(rng: &mut Rng, max: usize) -> usize {
        match rng.below(4) {
            // multiple of 16
            0 => 16 * (1 + rng.below(max / 16)),
            // multiple of 8
            1 => 8 * (1 + rng.below(max / 8)),
            // boundary +/- 1
            2 => {
                let base = 8 * (1 + rng.below(max / 8));
                if rng.below(2) == 0 {
                    base + 1
                } else {
                    base.saturating_sub(1).max(1)
                }
            }
            // anything
            _ => 1 + rng.below(max),
        }
    }
    (dim(rng, max_m), dim(rng, max_n), dim(rng, max_k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_true_property() {
        check_default("trivially true", |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure_with_seed() {
        // Silence the inner panic backtrace noise.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check(Config { cases: 3, base_seed: 1 }, "always fails", |_| {
                panic!("boom");
            })
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn gemm_shape_within_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let (m, n, k) = gemm_shape(&mut rng, 64, 64, 256);
            assert!(m >= 1 && n >= 1 && k >= 1);
            assert!(m <= 64 + 1 && n <= 64 + 1 && k <= 256 + 1);
        }
    }
}
