//! A light property-based testing harness (the real `proptest` crate is
//! unavailable in this offline build environment).
//!
//! Provides seeded random-case generation with failure reporting that
//! includes the case seed, so any failing case can be replayed
//! deterministically ([`check`]), plus a greedy size-shrinking loop for
//! the common "random matrix shape" properties used across the GEMM
//! tests ([`check_shrink`]): on failure the harness halves/decrements
//! each dimension while the property keeps failing, and reports the
//! minimal failing shape alongside the original one.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to try.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cfg.cases` seeded [`Rng`]s; panic with the seed of the
/// first failing case. `prop` should panic (e.g. via `assert!`) on failure.
pub fn check(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng)) {
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = panic_message(&e);
            panic!("property '{name}' failed on case {i} (seed={seed:#x}): {msg}");
        }
    }
}

/// Run with the default config.
pub fn check_default(name: &str, prop: impl FnMut(&mut Rng)) {
    check(Config::default(), name, prop);
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Run a shape-based property with greedy shrinking. Per case, `gen_shape`
/// draws a random `(m, n, k)`; `prop` must regenerate its data from the
/// given [`Rng`] (re-seeded identically for every replay of the case) and
/// panic on failure. On a failing case the harness shrinks the shape to a
/// minimal failing one — halving, then decrementing, each dimension while
/// the failure persists — and reports both shapes plus the case seed.
pub fn check_shrink(
    cfg: Config,
    name: &str,
    gen_shape: impl Fn(&mut Rng) -> (usize, usize, usize),
    prop: impl Fn(usize, usize, usize, &mut Rng),
) {
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let shape = gen_shape(&mut rng);
        if let Some(msg) = shape_failure(&prop, shape, seed) {
            let (min, min_msg) = shrink_shape(&prop, shape, seed, msg);
            panic!(
                "property '{name}' failed on case {i} (seed={seed:#x}) at shape (m,n,k)={shape:?}; \
                 minimal failing shape {min:?}: {min_msg}"
            );
        }
    }
}

/// Run `prop` once at `shape` with a deterministic data Rng; `Some(msg)`
/// if it panicked.
fn shape_failure(
    prop: &impl Fn(usize, usize, usize, &mut Rng),
    (m, n, k): (usize, usize, usize),
    seed: u64,
) -> Option<String> {
    let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(m, n, k, &mut rng)))
        .err()
        .map(|e| panic_message(&e))
}

/// Greedy shrink: repeatedly try halving, then decrementing, each
/// dimension (floor 1), keeping any candidate that still fails. Converges
/// in O(log) steps per dimension; capped defensively.
fn shrink_shape(
    prop: &impl Fn(usize, usize, usize, &mut Rng),
    mut shape: (usize, usize, usize),
    seed: u64,
    mut msg: String,
) -> ((usize, usize, usize), String) {
    // Shrink replays panic internally by design, which makes the default
    // hook print a backtrace per replay. Deliberately left alone: the
    // panic hook is process-global, and swapping it here would race with
    // parallel test threads (a concurrent failing suite could restore
    // the silent hook last, muting diagnostics for the rest of the run).
    // Shrinking only happens on an already-failing property, where the
    // extra noise is tolerable.
    let mut budget = 512;
    loop {
        let mut advanced = false;
        for dim in 0..3 {
            let cur = [shape.0, shape.1, shape.2][dim];
            for cand_val in [cur / 2, cur.saturating_sub(1)] {
                if cand_val < 1 || cand_val >= cur {
                    continue;
                }
                let mut cand = shape;
                match dim {
                    0 => cand.0 = cand_val,
                    1 => cand.1 = cand_val,
                    _ => cand.2 = cand_val,
                }
                budget -= 1;
                if let Some(m2) = shape_failure(prop, cand, seed) {
                    shape = cand;
                    msg = m2;
                    advanced = true;
                    break;
                }
            }
            if advanced {
                break;
            }
        }
        if !advanced || budget <= 0 {
            break;
        }
    }
    (shape, msg)
}

/// Generate a random GEMM problem size. Sizes are biased toward microkernel
/// boundaries (multiples of 8/16 ± 1) to probe edge handling.
pub fn gemm_shape(rng: &mut Rng, max_m: usize, max_n: usize, max_k: usize) -> (usize, usize, usize) {
    fn dim(rng: &mut Rng, max: usize) -> usize {
        match rng.below(4) {
            // multiple of 16
            0 => 16 * (1 + rng.below(max / 16)),
            // multiple of 8
            1 => 8 * (1 + rng.below(max / 8)),
            // boundary +/- 1
            2 => {
                let base = 8 * (1 + rng.below(max / 8));
                if rng.below(2) == 0 {
                    base + 1
                } else {
                    base.saturating_sub(1).max(1)
                }
            }
            // anything
            _ => 1 + rng.below(max),
        }
    }
    (dim(rng, max_m), dim(rng, max_n), dim(rng, max_k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_true_property() {
        check_default("trivially true", |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure_with_seed() {
        // Silence the inner panic backtrace noise.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check(Config { cases: 3, base_seed: 1 }, "always fails", |_| {
                panic!("boom");
            })
        });
        std::panic::set_hook(prev);
        std::panic::resume_unwind(r.unwrap_err());
    }

    #[test]
    fn check_shrink_passes_for_true_property() {
        check_shrink(
            Config { cases: 16, base_seed: 7 },
            "shapes are positive",
            |rng| gemm_shape(rng, 32, 32, 64),
            |m, n, k, _| assert!(m >= 1 && n >= 1 && k >= 1),
        );
    }

    /// Shrinking finds the minimal failing shape: a property failing iff
    /// `m ≥ 3 ∧ k ≥ 5` must be reported at exactly (3, 1, 5).
    #[test]
    fn check_shrink_reports_minimal_shape() {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = std::panic::catch_unwind(|| {
            check_shrink(
                Config { cases: 8, base_seed: 1 },
                "m<3 or k<5",
                |_| (20, 9, 40),
                |m, _, k, _| assert!(m < 3 || k < 5, "too big"),
            )
        });
        std::panic::set_hook(prev);
        let msg = panic_message(&r.expect_err("property must fail"));
        assert!(msg.contains("minimal failing shape (3, 1, 5)"), "got: {msg}");
        assert!(msg.contains("(m,n,k)=(20, 9, 40)"), "got: {msg}");
    }

    #[test]
    fn gemm_shape_within_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let (m, n, k) = gemm_shape(&mut rng, 64, 64, 256);
            assert!(m >= 1 && n >= 1 && k >= 1);
            assert!(m <= 64 + 1 && n <= 64 + 1 && k <= 256 + 1);
        }
    }
}
