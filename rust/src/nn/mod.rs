//! A QNN inference engine over the low-bit GEMM core — the "inference of
//! convolutional and fully connected layers of TNNs, TBNs, and BNNs" the
//! paper's abstract promises.
//!
//! Design follows production low-bit runtimes (daBNN, Larq CE):
//!
//! * Low-bit conv/dense layers compute integer outputs with the paper's
//!   GEMM kernels, then apply a **folded** per-channel affine
//!   (`y = a·acc + b`) that absorbs batch-norm, the XNOR/TWN scaling
//!   factors α, and the bias, in f32.
//! * The next layer's quantizer (sign / ternary threshold) turns the f32
//!   activations back into `{-1,1}` or `{-1,0,1}` — so the hot path only
//!   ever runs low-bit GEMMs plus cheap elementwise epilogues.
//! * The classifier head stays in f32 (standard practice: first and last
//!   layers are the quality-critical ones).
//!
//! **[`plan::NetPlan`] is the public boundary** (the network-level twin
//! of [`crate::gemm::GemmPlan`]): [`NetPlan::build`](plan::NetPlan::build)
//! verifies every layer shape and quantization-domain handoff once and
//! packs all weights, and [`NetPlan::run`](plan::NetPlan::run) executes
//! into caller-owned output with zero heap allocation after warm-up and
//! typed [`plan::NetError`]s. [`builder`] provides config-driven
//! construction ([`plan_from_config`]); [`network::Network`] survives
//! only as a thin deprecated shim over a one-shot plan.

pub mod builder;
pub mod layers;
pub mod network;
pub mod plan;
pub mod twin;

pub use builder::{build_from_config, build_layers, plan_from_config, LayerSpec, NetConfig};
pub use layers::{
    ActArena, Activation, DenseF32, DenseScratch, Domain, InputQuant, Layer, NetScratch, QConv2d, QDense,
};
pub use network::Network;
pub use plan::{LayerTiming, NetError, NetOut, NetPlan, NetPlanConfig};
pub use twin::{agreement, build_f32_twin, plan_agreement, F32Twin};
