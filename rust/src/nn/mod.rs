//! A QNN inference engine over the low-bit GEMM core — the "inference of
//! convolutional and fully connected layers of TNNs, TBNs, and BNNs" the
//! paper's abstract promises.
//!
//! Design follows production low-bit runtimes (daBNN, Larq CE):
//!
//! * Low-bit conv/dense layers compute integer outputs with the paper's
//!   GEMM kernels, then apply a **folded** per-channel affine
//!   (`y = a·acc + b`) that absorbs batch-norm, the XNOR/TWN scaling
//!   factors α, and the bias, in f32.
//! * The next layer's quantizer (sign / ternary threshold) turns the f32
//!   activations back into `{-1,1}` or `{-1,0,1}` — so the hot path only
//!   ever runs low-bit GEMMs plus cheap elementwise epilogues.
//! * The classifier head stays in f32 (standard practice: first and last
//!   layers are the quality-critical ones).
//!
//! [`network::Network`] is a sequential graph of [`layers::Layer`];
//! [`builder`] provides config-driven construction plus reference models
//! used by the examples and the serving coordinator.

pub mod builder;
pub mod layers;
pub mod network;
pub mod twin;

pub use builder::{build_from_config, LayerSpec, NetConfig};
pub use layers::{Activation, DenseScratch, Feature, Layer, NetScratch};
pub use network::Network;
pub use twin::{agreement, build_f32_twin, F32Twin};
