//! Layers of the QNN engine.
//!
//! Since the [`crate::nn::plan::NetPlan`] redesign, layers expose only
//! **hot-path `*_into` forwards**: each writes its output into a
//! caller-owned buffer (usually one of the two ping-pong [`ActArena`]s
//! of [`NetScratch`]) and returns typed [`GemmError`]s instead of
//! panicking. Shape and quantization-domain compatibility between
//! consecutive layers is checked **once**, by [`NetPlan::build`]
//! (`crate::nn::plan`) — the per-layer `expect_q` / `expect_f` panics
//! and asserts of the old `Feature`-passing executor are gone.

use crate::conv::conv2d::{ConvKind, ConvScratch, LowBitConv};
use crate::conv::tensor::Tensor3;
use crate::gemm::{
    Backend, GemmConfig, GemmError, GemmOut, GemmPlan, GemmScratch, KPanel, Lhs, Threading, Tile, Weights,
};
use crate::util::mat::{MatF32, MatI8};

/// Activation quantizer applied after the folded affine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Activation {
    /// `sign(x)` → `{-1, +1}` (BNN-style; 0 maps to +1).
    Sign,
    /// Ternary threshold: `+1 if x > Δ, −1 if x < −Δ, else 0`.
    Ternary { delta: f32 },
    /// Keep f32 (for the head).
    None,
}

/// The value domain of an activation tensor flowing between layers —
/// what the old `Feature` enum carried at run time, now inferred once at
/// plan-build time ([`crate::nn::plan::NetPlan::build`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Full-precision activations.
    F32,
    /// Binary activations `{-1, +1}`.
    Binary,
    /// Ternary activations `{-1, 0, +1}`.
    Ternary,
}

impl Domain {
    /// Human-readable name (used in [`crate::nn::plan::NetError`]).
    pub fn label(self) -> &'static str {
        match self {
            Domain::F32 => "f32",
            Domain::Binary => "binary",
            Domain::Ternary => "ternary",
        }
    }

    /// True for the low-bit (i8-carried) domains.
    pub fn is_quantized(self) -> bool {
        self != Domain::F32
    }
}

impl Activation {
    /// The domain this quantizer produces.
    pub fn out_domain(self) -> Domain {
        match self {
            Activation::Sign => Domain::Binary,
            Activation::Ternary { .. } => Domain::Ternary,
            Activation::None => Domain::F32,
        }
    }
}

pub(crate) fn apply_activation(x: f32, act: Activation) -> i8 {
    match act {
        Activation::Sign => {
            if x < 0.0 {
                -1
            } else {
                1
            }
        }
        Activation::Ternary { delta } => {
            if x > delta {
                1
            } else if x < -delta {
                -1
            } else {
                0
            }
        }
        // `NetPlan::build` rejects quantizing layers configured with
        // `None`, so this is unreachable through a built plan.
        Activation::None => unreachable!("None is not a quantizer"),
    }
}

/// One ping-pong activation slot: a low-bit tensor and an f32 tensor
/// sharing the slot (a layer writes whichever its output domain needs —
/// statically known per layer, so each buffer grows once to its
/// per-parity maximum and is then reused forever).
pub struct ActArena {
    /// Low-bit activations (`{-1,1}` or `{-1,0,1}`).
    pub q: Tensor3<i8>,
    /// Full-precision activations.
    pub f: Tensor3<f32>,
}

impl ActArena {
    pub fn new() -> Self {
        ActArena { q: Tensor3::zeros(0, 0, 0), f: Tensor3::zeros(0, 0, 0) }
    }
}

/// A low-bit convolution layer: GEMM kernel → folded per-channel affine →
/// activation quantizer (or f32 output when `act == None`).
pub struct QConv2d {
    pub conv: LowBitConv,
    /// Per-output-channel scale (absorbs α_w·α_a and BN γ/σ).
    pub scale: Vec<f32>,
    /// Per-output-channel bias (absorbs BN β−μγ/σ and conv bias).
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl QConv2d {
    /// Hot-path forward into the arena: conv GEMM into `acc`, then the
    /// folded affine + quantizer into `out.q` (or `out.f` when
    /// `act == None`). Zero heap allocation at steady state; typed
    /// errors, no panics (shape compatibility is the plan's build-time
    /// contract).
    pub fn forward_into(
        &self,
        input: &Tensor3<i8>,
        scratch: &mut ConvScratch,
        acc: &mut Tensor3<i32>,
        out: &mut ActArena,
    ) -> Result<(), GemmError> {
        self.conv.forward_into(input, scratch, acc)?;
        let c = acc.c;
        match self.act {
            Activation::None => {
                out.f.resize_to(acc.h, acc.w, c);
                for (o, (i, &v)) in out.f.data.iter_mut().zip(acc.data.iter().enumerate()) {
                    let ch = i % c;
                    *o = self.scale[ch] * v as f32 + self.bias[ch];
                }
            }
            act => {
                out.q.resize_to(acc.h, acc.w, c);
                for (o, (i, &v)) in out.q.data.iter_mut().zip(acc.data.iter().enumerate()) {
                    let ch = i % c;
                    *o = apply_activation(self.scale[ch] * v as f32 + self.bias[ch], act);
                }
            }
        }
        Ok(())
    }
}

/// Reusable scratch arena for [`QDense::forward_into`], mirroring
/// [`ConvScratch`]: the flattened activation row, the shared GEMM
/// packing arena ([`crate::gemm::GemmScratch`]), and the GEMM output
/// row. Grown on demand and reused, so steady-state dense forwards
/// perform no heap allocation in the GEMM.
pub struct DenseScratch {
    a: MatI8,
    /// The plan's LHS packing arena.
    pub gemm: GemmScratch,
    c: GemmOut,
}

impl DenseScratch {
    pub fn new() -> Self {
        DenseScratch { a: MatI8::zeros(0, 0), gemm: GemmScratch::new(), c: GemmOut::new_i32() }
    }

    /// Pre-grow the flatten row to `flat` elements (the plan-build
    /// warm-up; steady-state forwards then never reallocate it).
    pub(crate) fn reserve(&mut self, flat: usize) {
        self.a.data.reserve(flat.saturating_sub(self.a.data.len()));
    }
}

impl Default for DenseScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-plan scratch threaded through [`crate::nn::plan::NetPlan`] runs:
/// one conv arena + integer accumulator shared by all conv layers, one
/// dense arena shared by all dense layers (both embedding the unified
/// [`crate::gemm::GemmScratch`] packing arena), and the **two ping-pong
/// activation arenas** layer outputs alternate between. Every buffer
/// grows monotonically to its per-plan maximum — sized up front by
/// [`crate::nn::plan::NetPlan::make_scratch`] — so `run`/`run_batch`
/// perform zero heap allocation after warm-up.
pub struct NetScratch {
    pub conv: ConvScratch,
    pub dense: DenseScratch,
    /// Reused integer accumulator tensor for conv layers.
    pub conv_acc: Tensor3<i32>,
    /// The ping-pong activation arenas: layer `i` writes arena `i % 2`
    /// and reads the other (layer 0 reads the input image).
    pub arenas: [ActArena; 2],
}

impl NetScratch {
    pub fn new() -> Self {
        NetScratch {
            conv: ConvScratch::new(),
            dense: DenseScratch::new(),
            conv_acc: Tensor3::zeros(0, 0, 0),
            arenas: [ActArena::new(), ActArena::new()],
        }
    }
}

impl Default for NetScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A low-bit fully-connected layer over flattened features, running a
/// built-once [`GemmPlan`].
pub struct QDense {
    pub kind: ConvKind,
    pub in_features: usize,
    pub out_features: usize,
    plan: GemmPlan,
    /// Retained quantized weights (for backend rebuilds, as in
    /// [`LowBitConv`]).
    weights: MatI8,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl QDense {
    /// `weights`: `in_features × out_features`.
    pub fn new(kind: ConvKind, weights: &MatI8, scale: Vec<f32>, bias: Vec<f32>, act: Activation) -> Self {
        // Single activation row: nothing to thread over, so the plan
        // keeps the default single-thread / auto-K-panel config (the
        // K-panel level still keeps very deep flattened features exact).
        let plan = GemmPlan::new(GemmConfig::native(kind.gemm_kind()), Weights::I8(weights))
            .unwrap_or_else(|e| panic!("{kind:?} dense weights rejected: {e}"));
        assert_eq!(scale.len(), weights.cols);
        assert_eq!(bias.len(), weights.cols);
        QDense {
            kind,
            in_features: weights.rows,
            out_features: weights.cols,
            plan,
            weights: weights.clone(),
            scale,
            bias,
            act,
        }
    }

    /// Apply a full execution config (see [`LowBitConv::configure`]).
    pub fn configure(
        &mut self,
        backend: Backend,
        threading: Threading,
        k_panel: KPanel,
        tile: Tile,
    ) -> Result<(), GemmError> {
        if backend == self.plan.backend() {
            self.plan.set_threading(threading);
            self.plan.set_k_panel(k_panel);
            self.plan.set_tile(tile);
        } else {
            let config = GemmConfig { kind: self.kind.gemm_kind(), backend, threading, k_panel, tile };
            self.plan = GemmPlan::new(config, Weights::I8(&self.weights))?;
        }
        Ok(())
    }

    /// Hot-path forward into the arena: flatten + GEMM + affine +
    /// quantizer, reusing every buffer (zero heap allocation at steady
    /// state). A flattened-size mismatch surfaces as the plan's typed
    /// [`GemmError::DepthMismatch`].
    pub fn forward_into(
        &self,
        input: &Tensor3<i8>,
        scratch: &mut DenseScratch,
        out: &mut ActArena,
    ) -> Result<(), GemmError> {
        scratch.a.rows = 1;
        scratch.a.cols = input.data.len();
        scratch.a.data.clear();
        scratch.a.data.extend_from_slice(&input.data);
        self.plan.run(Lhs::I8(&scratch.a), &mut scratch.c, &mut scratch.gemm)?;
        let c = match &scratch.c {
            GemmOut::I32(m) => m,
            // The dense kinds all produce i32; stay total regardless.
            GemmOut::F32(_) => {
                return Err(GemmError::OutputMismatch {
                    kind: self.kind.gemm_kind(),
                    expected: "i32",
                    got: "f32",
                })
            }
        };
        match self.act {
            Activation::None => {
                out.f.resize_to(1, 1, self.out_features);
                for (j, (o, &v)) in out.f.data.iter_mut().zip(&c.data).enumerate() {
                    *o = self.scale[j] * v as f32 + self.bias[j];
                }
            }
            act => {
                out.q.resize_to(1, 1, self.out_features);
                for (j, (o, &v)) in out.q.data.iter_mut().zip(&c.data).enumerate() {
                    *o = apply_activation(self.scale[j] * v as f32 + self.bias[j], act);
                }
            }
        }
        Ok(())
    }
}

/// A plain f32 dense head (first/last layers stay full-precision).
pub struct DenseF32 {
    pub weights: MatF32,
    pub bias: Vec<f32>,
}

impl DenseF32 {
    /// Hot-path forward into `out` (resized to `1 × 1 × cols` in place).
    /// A flattened-size mismatch surfaces as a typed
    /// [`GemmError::DepthMismatch`], mirroring [`QDense::forward_into`].
    pub fn forward_into(&self, input: &Tensor3<f32>, out: &mut Tensor3<f32>) -> Result<(), GemmError> {
        if input.data.len() != self.weights.rows {
            return Err(GemmError::DepthMismatch { expected: self.weights.rows, got: input.data.len() });
        }
        let n = self.weights.cols;
        out.resize_to(1, 1, n);
        for (j, o) in out.data.iter_mut().enumerate() {
            let mut acc = self.bias[j];
            for (t, &x) in input.data.iter().enumerate() {
                acc += x * self.weights.get(t, j);
            }
            *o = acc;
        }
        Ok(())
    }
}

/// 2×2 max-pool, stride 2, over low-bit activations (max of `{-1,0,1}`
/// is well-defined and standard in BNN/TNN stacks), into a caller-owned
/// output tensor.
pub fn maxpool2x2_into(t: &Tensor3<i8>, out: &mut Tensor3<i8>) {
    let (oh, ow) = (t.h / 2, t.w / 2);
    out.resize_to(oh, ow, t.c);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..t.c {
                let m = t
                    .get(2 * y, 2 * x, ch)
                    .max(t.get(2 * y, 2 * x + 1, ch))
                    .max(t.get(2 * y + 1, 2 * x, ch))
                    .max(t.get(2 * y + 1, 2 * x + 1, ch));
                out.set(y, x, ch, m);
            }
        }
    }
}

/// Allocating convenience wrapper around [`maxpool2x2_into`].
pub fn maxpool2x2_i8(t: &Tensor3<i8>) -> Tensor3<i8> {
    let mut out = Tensor3::zeros(0, 0, 0);
    maxpool2x2_into(t, &mut out);
    out
}

/// First-layer quantizer: turns an f32 input image into low-bit planes.
pub struct InputQuant {
    pub act: Activation,
}

impl InputQuant {
    /// Hot-path forward into `out` (resized in place).
    pub fn forward_into(&self, input: &Tensor3<f32>, out: &mut Tensor3<i8>) {
        out.resize_to(input.h, input.w, input.c);
        for (o, &x) in out.data.iter_mut().zip(&input.data) {
            *o = apply_activation(x, self.act);
        }
    }
}

/// A network layer (sequential graph node). Executed by
/// [`crate::nn::plan::NetPlan`], which owns the inter-layer shape /
/// domain contract.
pub enum Layer {
    /// Quantize an f32 input into low-bit activations.
    InputQuant(InputQuant),
    /// Low-bit convolution + folded affine + quantizer.
    QConv(QConv2d),
    /// Low-bit dense + folded affine + quantizer.
    QDense(QDense),
    /// f32 classifier head.
    DenseF32(DenseF32),
    /// 2×2 max pool on low-bit activations.
    MaxPool2,
}

impl Layer {
    pub fn name(&self) -> &'static str {
        match self {
            Layer::InputQuant(_) => "input_quant",
            Layer::QConv(_) => "qconv2d",
            Layer::QDense(_) => "qdense",
            Layer::DenseF32(_) => "dense_f32",
            Layer::MaxPool2 => "maxpool2",
        }
    }

    /// Propagate a threading config to the layers that run a blocked GEMM
    /// (currently the convolutions; the dense layers are single-row
    /// multiplications with nothing to parallelize over).
    pub fn set_threading(&mut self, threading: Threading) {
        if let Layer::QConv(l) = self {
            l.conv.set_threading(threading);
        }
    }

    /// Apply a full GEMM execution config to this layer's plan (used by
    /// [`crate::nn::plan::NetPlan::build`]); a backend change repacks the
    /// layer's weights for the new backend.
    pub(crate) fn configure_gemm(
        &mut self,
        backend: Backend,
        threading: Threading,
        k_panel: KPanel,
        tile: Tile,
    ) -> Result<(), GemmError> {
        match self {
            Layer::QConv(l) => l.conv.configure(backend, threading, k_panel, tile),
            // Dense rows have nothing to thread over; keep them
            // single-threaded regardless of the plan-wide config.
            Layer::QDense(l) => l.configure(backend, Threading::Single, k_panel, tile),
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d::ConvParams;
    use crate::util::Rng;

    #[test]
    fn activation_sign_and_ternary() {
        assert_eq!(apply_activation(0.5, Activation::Sign), 1);
        assert_eq!(apply_activation(-0.5, Activation::Sign), -1);
        assert_eq!(apply_activation(0.0, Activation::Sign), 1);
        let t = Activation::Ternary { delta: 0.3 };
        assert_eq!(apply_activation(0.5, t), 1);
        assert_eq!(apply_activation(-0.5, t), -1);
        assert_eq!(apply_activation(0.1, t), 0);
        assert_eq!(Activation::Sign.out_domain(), Domain::Binary);
        assert_eq!(t.out_domain(), Domain::Ternary);
        assert_eq!(Activation::None.out_domain(), Domain::F32);
    }

    #[test]
    fn maxpool_takes_max() {
        let t = Tensor3 { h: 2, w: 2, c: 1, data: vec![-1, 0, 1, -1] };
        let p = maxpool2x2_i8(&t);
        assert_eq!(p.data, vec![1]);
        let mut out = Tensor3::zeros(0, 0, 0);
        maxpool2x2_into(&t, &mut out);
        assert_eq!(out.data, vec![1]);
    }

    #[test]
    fn qconv_applies_folded_affine_and_quantizer() {
        let mut rng = Rng::new(0xE0);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let w = MatI8::random_ternary(p.depth(4), 8, &mut rng);
        let conv = LowBitConv::new(ConvKind::Tnn, p, 4, &w);
        let layer = QConv2d { conv, scale: vec![0.1; 8], bias: vec![0.0; 8], act: Activation::Ternary { delta: 0.2 } };
        let input = Tensor3::random_ternary(6, 6, 4, &mut rng);
        let (mut scratch, mut acc, mut out) = (ConvScratch::new(), Tensor3::zeros(0, 0, 0), ActArena::new());
        layer.forward_into(&input, &mut scratch, &mut acc, &mut out).expect("conv forward");
        assert_eq!((out.q.h, out.q.w, out.q.c), (6, 6, 8));
        assert!(out.q.data.iter().all(|&v| (-1..=1).contains(&v)));
    }

    #[test]
    fn qdense_shapes_and_f32_head() {
        let mut rng = Rng::new(0xE1);
        let w = MatI8::random_binary(32, 10, &mut rng);
        let dense = QDense::new(ConvKind::Bnn, &w, vec![1.0; 10], vec![0.0; 10], Activation::None);
        let input = Tensor3 { h: 2, w: 2, c: 8, data: vec![1; 32] };
        let (mut scratch, mut out) = (DenseScratch::new(), ActArena::new());
        dense.forward_into(&input, &mut scratch, &mut out).expect("dense forward");
        assert_eq!(out.f.c, 10);
    }

    /// A flattened-size mismatch is a typed error, not a panic.
    #[test]
    fn qdense_size_mismatch_is_typed() {
        let mut rng = Rng::new(0xE3);
        let w = MatI8::random_binary(32, 10, &mut rng);
        let dense = QDense::new(ConvKind::Bnn, &w, vec![1.0; 10], vec![0.0; 10], Activation::None);
        let input = Tensor3 { h: 1, w: 1, c: 31, data: vec![1; 31] };
        let (mut scratch, mut out) = (DenseScratch::new(), ActArena::new());
        assert_eq!(
            dense.forward_into(&input, &mut scratch, &mut out),
            Err(crate::gemm::GemmError::DepthMismatch { expected: 32, got: 31 })
        );
    }

    /// Steady-state dense forwards perform no reallocation in the arena
    /// — mirroring the `ConvScratch` pointer-stability tests.
    #[test]
    fn dense_scratch_is_zero_alloc_at_steady_state() {
        let mut rng = Rng::new(0xE2);
        for kind in [ConvKind::Bnn, ConvKind::Tnn, ConvKind::Tbn] {
            let w = match kind {
                ConvKind::Tnn => MatI8::random_ternary(48, 10, &mut rng),
                _ => MatI8::random_binary(48, 10, &mut rng),
            };
            let dense = QDense::new(kind, &w, vec![1.0; 10], vec![0.0; 10], Activation::None);
            let input = match kind {
                ConvKind::Bnn => Tensor3::random_binary(2, 3, 8, &mut rng),
                _ => Tensor3::random_ternary(2, 3, 8, &mut rng),
            };
            let mut scratch = DenseScratch::new();
            let mut out = ActArena::new();
            dense.forward_into(&input, &mut scratch, &mut out).expect("dense forward");
            let want = out.f.data.clone();
            let (a_ptr, c_ptr) =
                (scratch.a.data.as_ptr(), scratch.c.as_i32().expect("i32 out").data.as_ptr());
            let bits_ptr = scratch.gemm.bits.data.as_ptr();
            let planes_ptr = scratch.gemm.planes.plus.as_ptr();
            let out_ptr = out.f.data.as_ptr();
            dense.forward_into(&input, &mut scratch, &mut out).expect("dense forward");
            assert_eq!(out.f.data, want, "{kind:?} second pass");
            assert_eq!(scratch.a.data.as_ptr(), a_ptr, "{kind:?}: flatten buffer reallocated");
            assert_eq!(
                scratch.c.as_i32().expect("i32 out").data.as_ptr(),
                c_ptr,
                "{kind:?}: output buffer reallocated"
            );
            assert_eq!(out.f.data.as_ptr(), out_ptr, "{kind:?}: arena f buffer reallocated");
            match kind {
                ConvKind::Bnn => assert_eq!(scratch.gemm.bits.data.as_ptr(), bits_ptr, "bits reallocated"),
                _ => assert_eq!(scratch.gemm.planes.plus.as_ptr(), planes_ptr, "planes reallocated"),
            }
        }
    }

    #[test]
    fn input_quant_binarizes_image() {
        let q = InputQuant { act: Activation::Sign };
        let img = Tensor3 { h: 1, w: 2, c: 1, data: vec![0.3, -0.3] };
        let mut out = Tensor3::zeros(0, 0, 0);
        q.forward_into(&img, &mut out);
        assert_eq!(out.data, vec![1, -1]);
    }
}
