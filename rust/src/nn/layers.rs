//! Layers of the QNN engine.

use crate::conv::conv2d::{ConvKind, ConvScratch, LowBitConv};
use crate::conv::tensor::Tensor3;
use crate::gemm::{GemmConfig, GemmOut, GemmPlan, GemmScratch, Lhs, Weights};
use crate::util::mat::{MatF32, MatI8};

/// Activation quantizer applied after the folded affine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Activation {
    /// `sign(x)` → `{-1, +1}` (BNN-style; 0 maps to +1).
    Sign,
    /// Ternary threshold: `+1 if x > Δ, −1 if x < −Δ, else 0`.
    Ternary { delta: f32 },
    /// Keep f32 (for the head).
    None,
}

/// A feature map flowing through the network.
#[derive(Clone, Debug)]
pub enum Feature {
    /// Low-bit activations (`{-1,1}` or `{-1,0,1}`).
    Q(Tensor3<i8>),
    /// Full-precision activations.
    F(Tensor3<f32>),
}

impl Feature {
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            Feature::Q(t) => (t.h, t.w, t.c),
            Feature::F(t) => (t.h, t.w, t.c),
        }
    }

    pub fn expect_q(&self) -> &Tensor3<i8> {
        match self {
            Feature::Q(t) => t,
            _ => panic!("expected quantized feature"),
        }
    }

    pub fn expect_f(&self) -> &Tensor3<f32> {
        match self {
            Feature::F(t) => t,
            _ => panic!("expected f32 feature"),
        }
    }
}

fn apply_activation(x: f32, act: Activation) -> i8 {
    match act {
        Activation::Sign => {
            if x < 0.0 {
                -1
            } else {
                1
            }
        }
        Activation::Ternary { delta } => {
            if x > delta {
                1
            } else if x < -delta {
                -1
            } else {
                0
            }
        }
        Activation::None => unreachable!("None is not a quantizer"),
    }
}

/// A low-bit convolution layer: GEMM kernel → folded per-channel affine →
/// activation quantizer (or f32 output when `act == None`).
pub struct QConv2d {
    pub conv: LowBitConv,
    /// Per-output-channel scale (absorbs α_w·α_a and BN γ/σ).
    pub scale: Vec<f32>,
    /// Per-output-channel bias (absorbs BN β−μγ/σ and conv bias).
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl QConv2d {
    /// One-shot forward (allocates fresh scratch). Hot callers hold a
    /// [`ConvScratch`] + accumulator tensor and use
    /// [`QConv2d::forward_with`].
    pub fn forward(&self, input: &Tensor3<i8>) -> Feature {
        let mut scratch = ConvScratch::new();
        let mut acc = Tensor3::zeros(0, 0, 0);
        self.forward_with(input, &mut scratch, &mut acc)
    }

    /// Forward using caller-owned conv scratch and accumulator storage.
    pub fn forward_with(&self, input: &Tensor3<i8>, scratch: &mut ConvScratch, acc: &mut Tensor3<i32>) -> Feature {
        self.conv.forward_into(input, scratch, acc);
        let c = acc.c;
        match self.act {
            Activation::None => {
                let mut out = Tensor3::zeros(acc.h, acc.w, c);
                for (i, &v) in acc.data.iter().enumerate() {
                    let ch = i % c;
                    out.data[i] = self.scale[ch] * v as f32 + self.bias[ch];
                }
                Feature::F(out)
            }
            act => {
                let mut out = Tensor3::zeros(acc.h, acc.w, c);
                for (i, &v) in acc.data.iter().enumerate() {
                    let ch = i % c;
                    out.data[i] = apply_activation(self.scale[ch] * v as f32 + self.bias[ch], act);
                }
                Feature::Q(out)
            }
        }
    }
}

/// Reusable scratch arena for [`QDense::forward_with`], mirroring
/// [`ConvScratch`]: the flattened activation row, the shared GEMM
/// packing arena ([`crate::gemm::GemmScratch`]), and the GEMM output
/// row. Grown on demand and reused, so steady-state dense forwards
/// perform no heap allocation in the GEMM.
pub struct DenseScratch {
    a: MatI8,
    /// The plan's LHS packing arena.
    pub gemm: GemmScratch,
    c: GemmOut,
}

impl DenseScratch {
    pub fn new() -> Self {
        DenseScratch { a: MatI8::zeros(0, 0), gemm: GemmScratch::new(), c: GemmOut::new_i32() }
    }
}

impl Default for DenseScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-network scratch threaded through [`crate::nn::Network`] forward
/// passes: one conv arena + accumulator tensor shared by all conv layers
/// (shapes only shrink or grow monotonically toward the largest layer)
/// and one dense arena shared by all dense layers. Both arenas embed the
/// unified [`crate::gemm::GemmScratch`] packing arena the GEMM plans
/// run into.
pub struct NetScratch {
    pub conv: ConvScratch,
    pub dense: DenseScratch,
    /// Reused integer accumulator tensor for conv layers.
    pub conv_acc: Tensor3<i32>,
}

impl NetScratch {
    pub fn new() -> Self {
        NetScratch { conv: ConvScratch::new(), dense: DenseScratch::new(), conv_acc: Tensor3::zeros(0, 0, 0) }
    }
}

impl Default for NetScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A low-bit fully-connected layer over flattened features, running a
/// built-once [`GemmPlan`].
pub struct QDense {
    pub kind: ConvKind,
    pub in_features: usize,
    pub out_features: usize,
    plan: GemmPlan,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub act: Activation,
}

impl QDense {
    /// `weights`: `in_features × out_features`.
    pub fn new(kind: ConvKind, weights: &MatI8, scale: Vec<f32>, bias: Vec<f32>, act: Activation) -> Self {
        // Single activation row: nothing to thread over, so the plan
        // keeps the default single-thread / auto-K-panel config (the
        // K-panel level still keeps very deep flattened features exact).
        let plan = GemmPlan::new(GemmConfig::native(kind.gemm_kind()), Weights::I8(weights))
            .unwrap_or_else(|e| panic!("{kind:?} dense weights rejected: {e}"));
        assert_eq!(scale.len(), weights.cols);
        assert_eq!(bias.len(), weights.cols);
        QDense {
            kind,
            in_features: weights.rows,
            out_features: weights.cols,
            plan,
            scale,
            bias,
            act,
        }
    }

    /// One-shot forward (allocates fresh scratch). Hot callers hold a
    /// [`DenseScratch`] and use [`QDense::forward_with`].
    pub fn forward(&self, input: &Tensor3<i8>) -> Feature {
        let mut scratch = DenseScratch::new();
        self.forward_with(input, &mut scratch)
    }

    /// Forward using caller-owned scratch: the flatten, the bit/plane
    /// packing and the GEMM output all reuse the arena's buffers, so a
    /// steady-state sequence of calls performs no heap allocation on the
    /// GEMM path (the returned `Feature` still owns fresh storage).
    pub fn forward_with(&self, input: &Tensor3<i8>, scratch: &mut DenseScratch) -> Feature {
        let flat = input.h * input.w * input.c;
        assert_eq!(flat, self.in_features, "dense input size mismatch");
        scratch.a.rows = 1;
        scratch.a.cols = flat;
        scratch.a.data.clear();
        scratch.a.data.extend_from_slice(&input.data);
        self.plan
            .run(Lhs::I8(&scratch.a), &mut scratch.c, &mut scratch.gemm)
            .unwrap_or_else(|e| panic!("dense GEMM plan invariant violated: {e}"));
        let c = match &scratch.c {
            GemmOut::I32(m) => m,
            GemmOut::F32(_) => unreachable!("dense kinds produce i32 output"),
        };
        match self.act {
            Activation::None => {
                let data = c.data.iter().enumerate().map(|(j, &v)| self.scale[j] * v as f32 + self.bias[j]).collect();
                Feature::F(Tensor3 { h: 1, w: 1, c: self.out_features, data })
            }
            act => {
                let data = c
                    .data
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| apply_activation(self.scale[j] * v as f32 + self.bias[j], act))
                    .collect();
                Feature::Q(Tensor3 { h: 1, w: 1, c: self.out_features, data })
            }
        }
    }
}

/// A plain f32 dense head (first/last layers stay full-precision).
pub struct DenseF32 {
    pub weights: MatF32,
    pub bias: Vec<f32>,
}

impl DenseF32 {
    pub fn forward(&self, input: &Tensor3<f32>) -> Tensor3<f32> {
        let flat = input.h * input.w * input.c;
        assert_eq!(flat, self.weights.rows);
        let n = self.weights.cols;
        let mut out = vec![0f32; n];
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = self.bias[j];
            for (t, &x) in input.data.iter().enumerate() {
                acc += x * self.weights.get(t, j);
            }
            *o = acc;
        }
        Tensor3 { h: 1, w: 1, c: n, data: out }
    }
}

/// 2×2 max-pool, stride 2, over low-bit activations (max of `{-1,0,1}`
/// is well-defined and standard in BNN/TNN stacks).
pub fn maxpool2x2_i8(t: &Tensor3<i8>) -> Tensor3<i8> {
    let (oh, ow) = (t.h / 2, t.w / 2);
    let mut out = Tensor3::zeros(oh, ow, t.c);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..t.c {
                let m = t
                    .get(2 * y, 2 * x, ch)
                    .max(t.get(2 * y, 2 * x + 1, ch))
                    .max(t.get(2 * y + 1, 2 * x, ch))
                    .max(t.get(2 * y + 1, 2 * x + 1, ch));
                out.set(y, x, ch, m);
            }
        }
    }
    out
}

/// First-layer quantizer: turns an f32 input image into low-bit planes.
pub struct InputQuant {
    pub act: Activation,
}

impl InputQuant {
    pub fn forward(&self, input: &Tensor3<f32>) -> Tensor3<i8> {
        let mut out = Tensor3::zeros(input.h, input.w, input.c);
        for (o, &x) in out.data.iter_mut().zip(&input.data) {
            *o = apply_activation(x, self.act);
        }
        out
    }
}

/// A network layer (sequential graph node).
pub enum Layer {
    /// Quantize an f32 input into low-bit activations.
    InputQuant(InputQuant),
    /// Low-bit convolution + folded affine + quantizer.
    QConv(QConv2d),
    /// Low-bit dense + folded affine + quantizer.
    QDense(QDense),
    /// f32 classifier head.
    DenseF32(DenseF32),
    /// 2×2 max pool on low-bit activations.
    MaxPool2,
}

impl Layer {
    pub fn forward(&self, x: Feature) -> Feature {
        let mut scratch = NetScratch::new();
        self.forward_with(x, &mut scratch)
    }

    /// Forward with a shared per-network scratch arena (the zero-alloc
    /// hot path used by [`crate::nn::Network::forward_with`]).
    pub fn forward_with(&self, x: Feature, scratch: &mut NetScratch) -> Feature {
        match self {
            Layer::InputQuant(l) => Feature::Q(l.forward(x.expect_f())),
            Layer::QConv(l) => l.forward_with(x.expect_q(), &mut scratch.conv, &mut scratch.conv_acc),
            Layer::QDense(l) => l.forward_with(x.expect_q(), &mut scratch.dense),
            Layer::DenseF32(l) => {
                // The head accepts either f32 features or low-bit
                // activations (which it widens to f32 — standard for a
                // full-precision classifier after a quantized backbone).
                let f = match x {
                    Feature::F(t) => t,
                    Feature::Q(t) => Tensor3 {
                        h: t.h,
                        w: t.w,
                        c: t.c,
                        data: t.data.iter().map(|&v| v as f32).collect(),
                    },
                };
                Feature::F(l.forward(&f))
            }
            Layer::MaxPool2 => Feature::Q(maxpool2x2_i8(x.expect_q())),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Layer::InputQuant(_) => "input_quant",
            Layer::QConv(_) => "qconv2d",
            Layer::QDense(_) => "qdense",
            Layer::DenseF32(_) => "dense_f32",
            Layer::MaxPool2 => "maxpool2",
        }
    }

    /// Propagate a threading config to the layers that run a blocked GEMM
    /// (currently the convolutions; the dense layers are single-row
    /// multiplications with nothing to parallelize over).
    pub fn set_threading(&mut self, threading: crate::gemm::Threading) {
        if let Layer::QConv(l) = self {
            l.conv.set_threading(threading);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d::ConvParams;
    use crate::util::Rng;

    #[test]
    fn activation_sign_and_ternary() {
        assert_eq!(apply_activation(0.5, Activation::Sign), 1);
        assert_eq!(apply_activation(-0.5, Activation::Sign), -1);
        assert_eq!(apply_activation(0.0, Activation::Sign), 1);
        let t = Activation::Ternary { delta: 0.3 };
        assert_eq!(apply_activation(0.5, t), 1);
        assert_eq!(apply_activation(-0.5, t), -1);
        assert_eq!(apply_activation(0.1, t), 0);
    }

    #[test]
    fn maxpool_takes_max() {
        let t = Tensor3 { h: 2, w: 2, c: 1, data: vec![-1, 0, 1, -1] };
        let p = maxpool2x2_i8(&t);
        assert_eq!(p.data, vec![1]);
    }

    #[test]
    fn qconv_applies_folded_affine_and_quantizer() {
        let mut rng = Rng::new(0xE0);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let w = MatI8::random_ternary(p.depth(4), 8, &mut rng);
        let conv = LowBitConv::new(ConvKind::Tnn, p, 4, &w);
        let layer = QConv2d { conv, scale: vec![0.1; 8], bias: vec![0.0; 8], act: Activation::Ternary { delta: 0.2 } };
        let input = Tensor3::random_ternary(6, 6, 4, &mut rng);
        match layer.forward(&input) {
            Feature::Q(out) => {
                assert_eq!((out.h, out.w, out.c), (6, 6, 8));
                assert!(out.data.iter().all(|&v| (-1..=1).contains(&v)));
            }
            _ => panic!("expected quantized output"),
        }
    }

    #[test]
    fn qdense_shapes_and_f32_head() {
        let mut rng = Rng::new(0xE1);
        let w = MatI8::random_binary(32, 10, &mut rng);
        let dense = QDense::new(ConvKind::Bnn, &w, vec![1.0; 10], vec![0.0; 10], Activation::None);
        let input = Tensor3 { h: 2, w: 2, c: 8, data: vec![1; 32] };
        match dense.forward(&input) {
            Feature::F(out) => assert_eq!(out.c, 10),
            _ => panic!("expected f32 output"),
        }
    }

    /// `forward_with` matches `forward` and, at steady state, the dense
    /// scratch arena performs no reallocation — mirroring the
    /// `ConvScratch` pointer-stability tests.
    #[test]
    fn dense_scratch_is_zero_alloc_at_steady_state() {
        let mut rng = Rng::new(0xE2);
        for kind in [ConvKind::Bnn, ConvKind::Tnn, ConvKind::Tbn] {
            let w = match kind {
                ConvKind::Tnn => MatI8::random_ternary(48, 10, &mut rng),
                _ => MatI8::random_binary(48, 10, &mut rng),
            };
            let dense = QDense::new(kind, &w, vec![1.0; 10], vec![0.0; 10], Activation::None);
            let input = match kind {
                ConvKind::Bnn => Tensor3::random_binary(2, 3, 8, &mut rng),
                _ => Tensor3::random_ternary(2, 3, 8, &mut rng),
            };
            let want = match dense.forward(&input) {
                Feature::F(t) => t.data,
                _ => panic!("expected f32 output"),
            };
            let mut scratch = DenseScratch::new();
            let got = match dense.forward_with(&input, &mut scratch) {
                Feature::F(t) => t.data,
                _ => panic!("expected f32 output"),
            };
            assert_eq!(got, want, "{kind:?}");
            let (a_ptr, c_ptr) =
                (scratch.a.data.as_ptr(), scratch.c.as_i32().expect("i32 out").data.as_ptr());
            let bits_ptr = scratch.gemm.bits.data.as_ptr();
            let planes_ptr = scratch.gemm.planes.plus.as_ptr();
            let got2 = match dense.forward_with(&input, &mut scratch) {
                Feature::F(t) => t.data,
                _ => panic!("expected f32 output"),
            };
            assert_eq!(got2, want, "{kind:?} second pass");
            assert_eq!(scratch.a.data.as_ptr(), a_ptr, "{kind:?}: flatten buffer reallocated");
            assert_eq!(
                scratch.c.as_i32().expect("i32 out").data.as_ptr(),
                c_ptr,
                "{kind:?}: output buffer reallocated"
            );
            match kind {
                ConvKind::Bnn => assert_eq!(scratch.gemm.bits.data.as_ptr(), bits_ptr, "bits reallocated"),
                _ => assert_eq!(scratch.gemm.planes.plus.as_ptr(), planes_ptr, "planes reallocated"),
            }
        }
    }

    #[test]
    fn input_quant_binarizes_image() {
        let q = InputQuant { act: Activation::Sign };
        let img = Tensor3 { h: 1, w: 2, c: 1, data: vec![0.3, -0.3] };
        assert_eq!(q.forward(&img).data, vec![1, -1]);
    }
}
