//! Config-driven network construction.
//!
//! [`NetConfig`] is a small declarative model description (the kind of
//! thing a deployment config file holds); [`build_from_config`] realizes
//! it with seeded synthetic weights — binarized / ternarized from random
//! Gaussians exactly as a trained-then-quantized network would be, with
//! the XNOR/TWN scaling factors folded into the per-channel affine.
//! A parallel [`build_f32_twin`] constructs the matching full-precision
//! network (used by examples to compare QNN against F32 output).
//!
//! Construction is the **plan-build** phase of the plan/execute split:
//! every `LowBitConv` / `QDense` built here packs its weights once into
//! a [`crate::gemm::GemmPlan`], and [`plan_from_config`] wraps the
//! result into a [`crate::nn::plan::NetPlan`] — the network-level plan
//! whose `run` is all the serving hot path ever calls.

use crate::conv::conv2d::{ConvKind, ConvParams, LowBitConv};
use crate::nn::layers::{Activation, DenseF32, InputQuant, Layer, QConv2d, QDense};
use crate::nn::network::Network;
use crate::nn::plan::{NetError, NetPlan, NetPlanConfig};
use crate::quant::lowbit::{binarize, ternarize, TernaryThreshold};
use crate::util::mat::{MatF32, MatI8};
use crate::util::Rng;

/// One layer of the declarative model description.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerSpec {
    /// Quantize the f32 input (Sign for BNN, Ternary for TNN/TBN).
    InputQuant { ternary: bool, delta: f32 },
    /// Low-bit 3×3/5×5/… convolution.
    Conv { kind: ConvKind, c_out: usize, hk: usize, wk: usize, stride: usize, pad: usize, ternary_out: bool },
    /// 2×2 max pool.
    MaxPool2,
    /// Low-bit dense producing f32 features (head) or re-quantized.
    Dense { kind: ConvKind, out: usize, ternary_out: Option<bool> },
    /// f32 classifier head.
    DenseF32 { out: usize },
}

/// Declarative network description.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub input: (usize, usize, usize),
    pub layers: Vec<LayerSpec>,
    /// Ternary activation threshold used throughout.
    pub delta: f32,
}

impl NetConfig {
    /// A tiny TNN for unit tests: conv(8) → pool → dense head.
    pub fn tiny_tnn(h: usize, w: usize, c: usize, classes: usize) -> Self {
        NetConfig {
            input: (h, w, c),
            layers: vec![
                LayerSpec::InputQuant { ternary: true, delta: 0.5 },
                LayerSpec::Conv { kind: ConvKind::Tnn, c_out: 8, hk: 3, wk: 3, stride: 1, pad: 1, ternary_out: true },
                LayerSpec::MaxPool2,
                LayerSpec::Dense { kind: ConvKind::Tnn, out: classes, ternary_out: None },
            ],
            delta: 0.5,
        }
    }

    /// The paper-motivated benchmark CNN: a small/medium mobile-class
    /// network (the regime the paper's H/W/D grid represents).
    /// `kind` selects TNN / TBN / BNN for all hidden layers.
    pub fn mobile_cnn(kind: ConvKind, h: usize, w: usize, c: usize, classes: usize) -> Self {
        let ternary_in = kind != ConvKind::Bnn;
        NetConfig {
            input: (h, w, c),
            layers: vec![
                LayerSpec::InputQuant { ternary: ternary_in, delta: 0.4 },
                LayerSpec::Conv { kind, c_out: 32, hk: 3, wk: 3, stride: 1, pad: 1, ternary_out: ternary_in },
                LayerSpec::MaxPool2,
                LayerSpec::Conv { kind, c_out: 64, hk: 3, wk: 3, stride: 1, pad: 1, ternary_out: ternary_in },
                LayerSpec::MaxPool2,
                LayerSpec::Conv { kind, c_out: 64, hk: 3, wk: 3, stride: 1, pad: 1, ternary_out: ternary_in },
                LayerSpec::Dense { kind, out: 128, ternary_out: Some(ternary_in) },
                LayerSpec::DenseF32 { out: classes },
            ],
            delta: 0.4,
        }
    }

    /// Total logical weight count (for reporting).
    pub fn param_count(&self) -> usize {
        let (mut h, mut w, mut c) = self.input;
        let mut total = 0usize;
        for l in &self.layers {
            match *l {
                LayerSpec::InputQuant { .. } => {}
                LayerSpec::Conv { c_out, hk, wk, stride, pad, .. } => {
                    let p = ConvParams { hk, wk, stride, pad };
                    total += p.depth(c) * c_out + 2 * c_out;
                    let (oh, ow) = p.out_dims(h, w);
                    h = oh;
                    w = ow;
                    c = c_out;
                }
                LayerSpec::MaxPool2 => {
                    h /= 2;
                    w /= 2;
                }
                LayerSpec::Dense { out, .. } => {
                    total += h * w * c * out + 2 * out;
                    h = 1;
                    w = 1;
                    c = out;
                }
                LayerSpec::DenseF32 { out } => {
                    total += h * w * c * out + out;
                    h = 1;
                    w = 1;
                    c = out;
                }
            }
        }
        total
    }
}

fn quantize_weights(kind: ConvKind, rows: usize, cols: usize, xs: &[f32]) -> (MatI8, f32) {
    match kind {
        ConvKind::Bnn | ConvKind::Tbn => binarize(rows, cols, xs),
        ConvKind::Tnn => ternarize(rows, cols, xs, TernaryThreshold::MeanRatio(0.75)),
    }
}

/// Build the network with seeded synthetic weights — the deprecated
/// [`Network`] shim form of [`plan_from_config`].
pub fn build_from_config(cfg: &NetConfig, seed: u64) -> Network {
    let (input, layers) = build_layers(cfg, seed);
    Network::new(input, layers)
}

/// Build a [`NetPlan`] directly from the declarative config: realize the
/// layers with seeded synthetic weights (packing every layer's weights
/// once) and run full static shape/domain inference under `plan_cfg`.
pub fn plan_from_config(cfg: &NetConfig, seed: u64, plan_cfg: NetPlanConfig) -> Result<NetPlan, NetError> {
    let (input, layers) = build_layers(cfg, seed);
    NetPlan::build(input, layers, plan_cfg)
}

/// Realize a config into raw layers (plus the input dims): the common
/// construction path behind [`build_from_config`] / [`plan_from_config`].
pub fn build_layers(cfg: &NetConfig, seed: u64) -> ((usize, usize, usize), Vec<Layer>) {
    let mut rng = Rng::new(seed);
    let (mut h, mut w, mut c) = cfg.input;
    let mut layers = Vec::new();
    for spec in &cfg.layers {
        match *spec {
            LayerSpec::InputQuant { ternary, delta } => {
                let act = if ternary { Activation::Ternary { delta } } else { Activation::Sign };
                layers.push(Layer::InputQuant(InputQuant { act }));
            }
            LayerSpec::Conv { kind, c_out, hk, wk, stride, pad, ternary_out } => {
                let p = ConvParams { hk, wk, stride, pad };
                let depth = p.depth(c);
                let raw: Vec<f32> = (0..depth * c_out).map(|_| rng.normalish() * 0.2).collect();
                let (wq, _alpha) = quantize_weights(kind, depth, c_out, &raw);
                let conv = LowBitConv::new(kind, p, c, &wq);
                // Folded affine: normalize the integer accumulator (std ≈
                // 0.67·√fan_in for random low-bit dot products) to ~unit
                // variance so activations straddle the quantizer threshold
                // — the BN-fold a trained QNN would carry.
                let fan_in = depth as f32;
                let scale: Vec<f32> = (0..c_out).map(|_| 2.0 * rng.f32_range(0.8, 1.2) / fan_in.sqrt()).collect();
                let bias: Vec<f32> = (0..c_out).map(|_| rng.f32_range(-0.05, 0.05)).collect();
                let act = if ternary_out { Activation::Ternary { delta: cfg.delta } } else { Activation::Sign };
                layers.push(Layer::QConv(QConv2d { conv, scale, bias, act }));
                let (oh, ow) = p.out_dims(h, w);
                h = oh;
                w = ow;
                c = c_out;
            }
            LayerSpec::MaxPool2 => {
                layers.push(Layer::MaxPool2);
                h /= 2;
                w /= 2;
            }
            LayerSpec::Dense { kind, out, ternary_out } => {
                let flat = h * w * c;
                let raw: Vec<f32> = (0..flat * out).map(|_| rng.normalish() * 0.2).collect();
                let (wq, _alpha) = quantize_weights(kind, flat, out, &raw);
                let fan_in = flat as f32;
                let scale: Vec<f32> = (0..out).map(|_| 2.0 / fan_in.sqrt()).collect();
                let bias: Vec<f32> = (0..out).map(|_| rng.f32_range(-0.05, 0.05)).collect();
                let act = match ternary_out {
                    None => Activation::None,
                    Some(true) => Activation::Ternary { delta: cfg.delta },
                    Some(false) => Activation::Sign,
                };
                layers.push(Layer::QDense(QDense::new(kind, &wq, scale, bias, act)));
                h = 1;
                w = 1;
                c = out;
            }
            LayerSpec::DenseF32 { out } => {
                let flat = h * w * c;
                let weights = MatF32::from_fn(flat, out, |_, _| rng.normalish() * 0.1 / (flat as f32).sqrt());
                let bias: Vec<f32> = (0..out).map(|_| rng.f32_range(-0.02, 0.02)).collect();
                layers.push(Layer::DenseF32(DenseF32 { weights, bias }));
                h = 1;
                w = 1;
                c = out;
            }
        }
    }
    (cfg.input, layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::tensor::Tensor3;

    #[test]
    fn tiny_config_builds_and_runs() {
        let cfg = NetConfig::tiny_tnn(8, 8, 2, 3);
        let net = build_from_config(&cfg, 42);
        let mut rng = Rng::new(5);
        let img = Tensor3::random(8, 8, 2, &mut rng);
        assert_eq!(net.logits(&img).len(), 3);
    }

    #[test]
    fn mobile_cnn_all_kinds_build() {
        for kind in [ConvKind::Tnn, ConvKind::Tbn, ConvKind::Bnn] {
            let cfg = NetConfig::mobile_cnn(kind, 16, 16, 3, 10);
            let net = build_from_config(&cfg, 42);
            let mut rng = Rng::new(6);
            let img = Tensor3::random(16, 16, 3, &mut rng);
            let logits = net.logits(&img);
            assert_eq!(logits.len(), 10, "{kind:?}");
            assert!(logits.iter().all(|v| v.is_finite()), "{kind:?}");
        }
    }

    #[test]
    fn param_count_mobile() {
        let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 28, 28, 1, 10);
        // conv1: 9*32 + 64, conv2: 288*64 + 128, conv3: 576*64 + 128,
        // dense: 7*7*64*128 + 256, head: 128*10 + 10
        let count = cfg.param_count();
        assert!(count > 400_000 && count < 500_000, "count={count}");
    }

    #[test]
    fn different_seeds_give_different_nets() {
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
        let a = build_from_config(&cfg, 1);
        let b = build_from_config(&cfg, 2);
        let mut rng = Rng::new(7);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        assert_ne!(a.logits(&img), b.logits(&img));
    }
}
