//! **Deprecated shim** over [`crate::nn::plan::NetPlan`].
//!
//! `Network` predates the network-level plan/execute API: it allocated
//! fresh scratch per forward pass and panicked on shape/domain mistakes.
//! It survives only as a thin convenience wrapper for tests, benches and
//! examples — every method is one call into an owned [`NetPlan`], and
//! construction panics where `NetPlan::build` would return a typed
//! [`crate::nn::plan::NetError`]. New code should hold a `NetPlan` (plus
//! a [`NetScratch`] and [`NetOut`]) directly; see the migration table in
//! the repository README.

use crate::conv::tensor::Tensor3;
use crate::nn::layers::{Layer, NetScratch};
use crate::nn::plan::{LayerTiming, NetOut, NetPlan, NetPlanConfig};

/// A sequential QNN — a deprecated one-shot wrapper around [`NetPlan`].
pub struct Network {
    plan: NetPlan,
}

impl Network {
    /// Build from raw layers with the default plan config.
    ///
    /// Deprecated construction path: panics on an invalid layer chain.
    /// Use [`NetPlan::build`] for typed errors.
    pub fn new(input_dims: (usize, usize, usize), layers: Vec<Layer>) -> Self {
        let plan = NetPlan::build(input_dims, layers, NetPlanConfig::default())
            .unwrap_or_else(|e| panic!("Network::new (deprecated shim over NetPlan::build): {e}"));
        Network { plan }
    }

    /// Wrap an already-built plan.
    pub fn from_plan(plan: NetPlan) -> Self {
        Network { plan }
    }

    /// The underlying network plan.
    pub fn plan(&self) -> &NetPlan {
        &self.plan
    }

    /// Unwrap into the underlying plan (the migration escape hatch).
    pub fn into_plan(self) -> NetPlan {
        self.plan
    }

    /// Input image dims (h, w, c) the network expects.
    pub fn input_dims(&self) -> (usize, usize, usize) {
        self.plan.input_dims()
    }

    /// Deprecated one-shot forward: returns the final logits, allocating
    /// fresh scratch and panicking on a mis-shaped image. Hot callers use
    /// [`NetPlan::run`] with caller-owned [`NetOut`] + [`NetScratch`].
    pub fn forward(&self, image: &Tensor3<f32>) -> Vec<f32> {
        self.logits(image)
    }

    /// Forward returning classifier logits (one-shot scratch).
    pub fn logits(&self, image: &Tensor3<f32>) -> Vec<f32> {
        let mut scratch = self.plan.make_scratch();
        self.logits_with(image, &mut scratch)
    }

    /// As [`Network::logits`] with caller-owned scratch.
    pub fn logits_with(&self, image: &Tensor3<f32>, scratch: &mut NetScratch) -> Vec<f32> {
        let mut out = NetOut::new();
        self.plan
            .run(image, &mut out, scratch)
            .unwrap_or_else(|e| panic!("Network::logits (deprecated shim over NetPlan::run): {e}"));
        out.logits
    }

    /// Argmax class prediction.
    pub fn predict(&self, image: &Tensor3<f32>) -> usize {
        let mut scratch = self.plan.make_scratch();
        let mut out = NetOut::new();
        self.plan
            .run(image, &mut out, &mut scratch)
            .unwrap_or_else(|e| panic!("Network::predict (deprecated shim over NetPlan::run): {e}"));
        out.predicted()
    }

    /// Instrumented forward pass: logits plus per-layer wall-clock.
    pub fn forward_timed(&self, image: &Tensor3<f32>) -> (Vec<f32>, Vec<LayerTiming>) {
        let mut scratch = self.plan.make_scratch();
        let mut out = NetOut::new();
        let mut timings = Vec::new();
        self.plan
            .run_timed(image, &mut out, &mut scratch, &mut timings)
            .unwrap_or_else(|e| panic!("Network::forward_timed (deprecated shim over NetPlan::run_timed): {e}"));
        (out.logits, timings)
    }

    pub fn num_layers(&self) -> usize {
        self.plan.num_layers()
    }

    /// Set the GEMM threading config on every layer that runs one.
    pub fn set_threading(&mut self, threading: crate::gemm::Threading) {
        self.plan.set_threading(threading);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::{build_from_config, NetConfig};
    use crate::util::Rng;

    #[test]
    fn forward_produces_logit_vector() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 7);
        let mut rng = Rng::new(1);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let logits = net.logits(&img);
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predict_is_argmax() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 8);
        let mut rng = Rng::new(2);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let logits = net.logits(&img);
        let pred = net.predict(&img);
        assert!(logits[pred] >= logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) - 1e-6);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 9);
        let mut rng = Rng::new(3);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        assert_eq!(net.logits(&img), net.logits(&img));
    }

    /// Scratch-reusing forwards match fresh-scratch forwards (the shim's
    /// contract on top of the plan's own pointer-stability tests).
    #[test]
    fn logits_with_reuses_scratch_across_images() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 11);
        let mut rng = Rng::new(5);
        let imgs: Vec<_> = (0..3).map(|_| Tensor3::random(12, 12, 1, &mut rng)).collect();
        let mut scratch = net.plan().make_scratch();
        for img in &imgs {
            assert_eq!(net.logits_with(img, &mut scratch), net.logits(img));
        }
    }

    #[test]
    fn timed_forward_reports_all_layers() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 10);
        let mut rng = Rng::new(4);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let (logits, t) = net.forward_timed(&img);
        assert_eq!(t.len(), net.num_layers());
        assert_eq!(logits.len(), 4);
    }
}
