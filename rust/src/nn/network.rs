//! Sequential network executor with per-layer timing.

use crate::conv::tensor::Tensor3;
use crate::nn::layers::{Feature, Layer, NetScratch};
use std::time::Instant;

/// Per-layer timing record from an instrumented forward pass.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub name: &'static str,
    pub seconds: f64,
    pub out_dims: (usize, usize, usize),
}

/// A sequential QNN.
pub struct Network {
    pub layers: Vec<Layer>,
    /// Input image dims (h, w, c) the network expects.
    pub input_dims: (usize, usize, usize),
}

impl Network {
    pub fn new(input_dims: (usize, usize, usize), layers: Vec<Layer>) -> Self {
        Network { layers, input_dims }
    }

    /// Forward an f32 image through the network; returns the final
    /// feature (logits for classifier nets). Allocates fresh scratch;
    /// hot callers (the batched engine) hold a [`NetScratch`] and use
    /// [`Network::forward_with`].
    pub fn forward(&self, image: &Tensor3<f32>) -> Feature {
        let mut scratch = NetScratch::new();
        self.forward_with(image, &mut scratch)
    }

    /// Forward reusing a caller-owned scratch arena across layers (and,
    /// via the caller, across images): the conv and dense GEMM paths
    /// perform no heap allocation once the arena has grown to the
    /// largest layer's shapes.
    pub fn forward_with(&self, image: &Tensor3<f32>, scratch: &mut NetScratch) -> Feature {
        assert_eq!((image.h, image.w, image.c), self.input_dims, "input dims mismatch");
        let mut x = Feature::F(image.clone());
        for layer in &self.layers {
            x = layer.forward_with(x, scratch);
        }
        x
    }

    /// Forward returning classifier logits.
    pub fn logits(&self, image: &Tensor3<f32>) -> Vec<f32> {
        let mut scratch = NetScratch::new();
        self.logits_with(image, &mut scratch)
    }

    /// As [`Network::logits`] with caller-owned scratch.
    pub fn logits_with(&self, image: &Tensor3<f32>, scratch: &mut NetScratch) -> Vec<f32> {
        match self.forward_with(image, scratch) {
            Feature::F(t) => t.data,
            Feature::Q(t) => t.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Argmax class prediction.
    pub fn predict(&self, image: &Tensor3<f32>) -> usize {
        let logits = self.logits(image);
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Instrumented forward pass: per-layer wall-clock.
    pub fn forward_timed(&self, image: &Tensor3<f32>) -> (Feature, Vec<LayerTiming>) {
        let mut x = Feature::F(image.clone());
        let mut timings = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let t0 = Instant::now();
            x = layer.forward(x);
            timings.push(LayerTiming { name: layer.name(), seconds: t0.elapsed().as_secs_f64(), out_dims: x.dims() });
        }
        (x, timings)
    }

    /// Rough parameter count (low-bit weights count as their storage bits
    /// / 8 would undersell them; we count logical weights).
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Set the GEMM threading config on every layer that runs one (the
    /// config lands on each layer's [`crate::gemm::GemmPlan`]).
    pub fn set_threading(&mut self, threading: crate::gemm::Threading) {
        for layer in &mut self.layers {
            layer.set_threading(threading);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::{build_from_config, NetConfig};
    use crate::util::Rng;

    #[test]
    fn forward_produces_logit_vector() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 7);
        let mut rng = Rng::new(1);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let logits = net.logits(&img);
        assert_eq!(logits.len(), 4);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predict_is_argmax() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 8);
        let mut rng = Rng::new(2);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let logits = net.logits(&img);
        let pred = net.predict(&img);
        assert!(logits[pred] >= logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) - 1e-6);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 9);
        let mut rng = Rng::new(3);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        assert_eq!(net.logits(&img), net.logits(&img));
    }

    /// Scratch-reusing forwards match fresh-scratch forwards and keep the
    /// arena's buffers stable across images at steady state.
    #[test]
    fn logits_with_reuses_scratch_across_images() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 11);
        let mut rng = Rng::new(5);
        let imgs: Vec<_> = (0..3).map(|_| Tensor3::random(12, 12, 1, &mut rng)).collect();
        let mut scratch = NetScratch::new();
        // Warm the arena, then record pointers.
        assert_eq!(net.logits_with(&imgs[0], &mut scratch), net.logits(&imgs[0]));
        let acc_ptr = scratch.conv_acc.data.as_ptr();
        for img in &imgs {
            assert_eq!(net.logits_with(img, &mut scratch), net.logits(img));
        }
        assert_eq!(scratch.conv_acc.data.as_ptr(), acc_ptr, "conv accumulator reallocated at steady state");
    }

    #[test]
    fn timed_forward_reports_all_layers() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let net = build_from_config(&cfg, 10);
        let mut rng = Rng::new(4);
        let img = Tensor3::random(12, 12, 1, &mut rng);
        let (_, t) = net.forward_timed(&img);
        assert_eq!(t.len(), net.num_layers());
    }
}
