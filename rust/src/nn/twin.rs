//! The f32 "twin" of a quantized network, and QNN-vs-f32 agreement
//! metrics — the quality side of the paper's "trade-off between
//! recognition quality ... and efficiency gain from low-bit quantization"
//! (§IV discussion).
//!
//! The twin is built from the **same seed stream** as the quantized
//! network, so its weights are the pre-quantization Gaussians whose
//! binarized/ternarized versions the QNN carries. Comparing the two
//! forward passes over a probe set measures how much of the full-
//! precision network's behaviour the low-bit version preserves — the
//! proxy this synthetic setting offers for the paper's accuracy
//! discussion.

use crate::conv::conv2d::{ConvKind, ConvParams};
use crate::conv::tensor::Tensor3;
use crate::nn::builder::{LayerSpec, NetConfig};
use crate::util::mat::MatF32;
use crate::util::Rng;

/// A full-precision sequential CNN mirroring a [`NetConfig`].
pub struct F32Twin {
    pub input: (usize, usize, usize),
    layers: Vec<TwinLayer>,
}

enum TwinLayer {
    /// Conv with f32 weights `(depth × c_out)` + per-channel scale/bias
    /// and tanh-ish activation standing in for the quantizer stage.
    Conv { params: ConvParams, c_in: usize, w: MatF32, scale: Vec<f32>, bias: Vec<f32> },
    MaxPool2,
    Dense { w: MatF32, scale: Vec<f32>, bias: Vec<f32> },
    Head { w: MatF32, bias: Vec<f32> },
}

/// Build the f32 twin with the same seed as `build_from_config(cfg, seed)`
/// — it consumes the RNG in the same order, so `w` here is the raw weight
/// whose quantized form the QNN uses.
pub fn build_f32_twin(cfg: &NetConfig, seed: u64) -> F32Twin {
    let mut rng = Rng::new(seed);
    let (mut h, mut w, mut c) = cfg.input;
    let mut layers = Vec::new();
    for spec in &cfg.layers {
        match *spec {
            LayerSpec::InputQuant { .. } => {}
            LayerSpec::Conv { c_out, hk, wk, stride, pad, .. } => {
                let p = ConvParams { hk, wk, stride, pad };
                let depth = p.depth(c);
                let raw: Vec<f32> = (0..depth * c_out).map(|_| rng.normalish() * 0.2).collect();
                let wm = MatF32 { rows: depth, cols: c_out, data: raw };
                let fan_in = depth as f32;
                let scale: Vec<f32> = (0..c_out).map(|_| 2.0 * rng.f32_range(0.8, 1.2) / fan_in.sqrt()).collect();
                let bias: Vec<f32> = (0..c_out).map(|_| rng.f32_range(-0.05, 0.05)).collect();
                layers.push(TwinLayer::Conv { params: p, c_in: c, w: wm, scale, bias });
                let (oh, ow) = p.out_dims(h, w);
                h = oh;
                w = ow;
                c = c_out;
            }
            LayerSpec::MaxPool2 => {
                layers.push(TwinLayer::MaxPool2);
                h /= 2;
                w /= 2;
            }
            LayerSpec::Dense { out, .. } => {
                let flat = h * w * c;
                let raw: Vec<f32> = (0..flat * out).map(|_| rng.normalish() * 0.2).collect();
                let wm = MatF32 { rows: flat, cols: out, data: raw };
                let fan_in = flat as f32;
                let scale: Vec<f32> = (0..out).map(|_| 2.0 / fan_in.sqrt()).collect();
                let bias: Vec<f32> = (0..out).map(|_| rng.f32_range(-0.05, 0.05)).collect();
                layers.push(TwinLayer::Dense { w: wm, scale, bias });
                h = 1;
                w = 1;
                c = out;
            }
            LayerSpec::DenseF32 { out } => {
                let flat = h * w * c;
                let wm = MatF32::from_fn(flat, out, |_, _| rng.normalish() * 0.1 / (flat as f32).sqrt());
                let bias: Vec<f32> = (0..out).map(|_| rng.f32_range(-0.02, 0.02)).collect();
                layers.push(TwinLayer::Head { w: wm, bias });
                h = 1;
                w = 1;
                c = out;
            }
        }
    }
    F32Twin { input: cfg.input, layers }
}

fn conv_f32(input: &Tensor3<f32>, p: &ConvParams, w: &MatF32) -> Tensor3<f32> {
    let c_out = w.cols;
    let (oh, ow) = p.out_dims(input.h, input.w);
    let mut out = Tensor3::zeros(oh, ow, c_out);
    for oy in 0..oh {
        for ox in 0..ow {
            for f in 0..c_out {
                let mut acc = 0f32;
                let mut d = 0;
                for ky in 0..p.hk {
                    let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                    for kx in 0..p.wk {
                        let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                        for ch in 0..input.c {
                            if iy >= 0 && (iy as usize) < input.h && ix >= 0 && (ix as usize) < input.w {
                                acc += input.get(iy as usize, ix as usize, ch) * w.get(d, f);
                            }
                            d += 1;
                        }
                    }
                }
                out.set(oy, ox, f, acc);
            }
        }
    }
    out
}

fn maxpool2_f32(t: &Tensor3<f32>) -> Tensor3<f32> {
    let (oh, ow) = (t.h / 2, t.w / 2);
    let mut out = Tensor3::zeros(oh, ow, t.c);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..t.c {
                let m = t
                    .get(2 * y, 2 * x, ch)
                    .max(t.get(2 * y, 2 * x + 1, ch))
                    .max(t.get(2 * y + 1, 2 * x, ch))
                    .max(t.get(2 * y + 1, 2 * x + 1, ch));
                out.set(y, x, ch, m);
            }
        }
    }
    out
}

impl F32Twin {
    pub fn logits(&self, image: &Tensor3<f32>) -> Vec<f32> {
        let mut x = image.clone();
        for layer in &self.layers {
            x = match layer {
                TwinLayer::Conv { params, c_in, w, scale, bias } => {
                    assert_eq!(x.c, *c_in);
                    let mut y = conv_f32(&x, params, w);
                    for (i, v) in y.data.iter_mut().enumerate() {
                        let ch = i % y.c;
                        // tanh keeps the twin's activations in the same
                        // bounded regime the quantizer imposes on the QNN.
                        *v = (scale[ch] * *v + bias[ch]).tanh();
                    }
                    y
                }
                TwinLayer::MaxPool2 => maxpool2_f32(&x),
                TwinLayer::Dense { w, scale, bias } => {
                    let flat = x.h * x.w * x.c;
                    assert_eq!(flat, w.rows);
                    let mut data = vec![0f32; w.cols];
                    for (j, o) in data.iter_mut().enumerate() {
                        let mut acc = 0f32;
                        for (t, &v) in x.data.iter().enumerate() {
                            acc += v * w.get(t, j);
                        }
                        *o = (scale[j] * acc + bias[j]).tanh();
                    }
                    Tensor3 { h: 1, w: 1, c: w.cols, data }
                }
                TwinLayer::Head { w, bias } => {
                    let mut data = vec![0f32; w.cols];
                    for (j, o) in data.iter_mut().enumerate() {
                        let mut acc = bias[j];
                        for (t, &v) in x.data.iter().enumerate() {
                            acc += v * w.get(t, j);
                        }
                        *o = acc;
                    }
                    Tensor3 { h: 1, w: 1, c: w.cols, data }
                }
            };
        }
        x.data
    }

    pub fn predict(&self, image: &Tensor3<f32>) -> usize {
        let l = self.logits(image);
        l.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
    }
}

/// Top-1 agreement between a built [`crate::nn::plan::NetPlan`] and the
/// f32 twin over a probe set — the zero-alloc form of [`agreement`] for
/// the QNN side: one plan scratch + output is shared across all probes,
/// and a mis-shaped probe surfaces as a typed
/// [`crate::nn::plan::NetError`] instead of a panic. An empty probe set
/// is vacuous agreement (1.0).
pub fn plan_agreement(
    plan: &crate::nn::plan::NetPlan,
    twin: &F32Twin,
    probes: &[Tensor3<f32>],
) -> Result<f64, crate::nn::plan::NetError> {
    if probes.is_empty() {
        return Ok(1.0);
    }
    let mut scratch = plan.make_scratch();
    let mut out = crate::nn::plan::NetOut::new();
    let mut same = 0usize;
    for img in probes {
        plan.run(img, &mut out, &mut scratch)?;
        if out.predicted() == twin.predict(img) {
            same += 1;
        }
    }
    Ok(same as f64 / probes.len() as f64)
}

/// Top-1 agreement between two classifiers over a probe set.
pub fn agreement(
    qnn_predict: impl Fn(&Tensor3<f32>) -> usize,
    f32_predict: impl Fn(&Tensor3<f32>) -> usize,
    probes: &[Tensor3<f32>],
) -> f64 {
    assert!(!probes.is_empty());
    let same = probes.iter().filter(|img| qnn_predict(img) == f32_predict(img)).count();
    same as f64 / probes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::build_from_config;

    #[test]
    fn twin_builds_and_runs() {
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 4);
        let twin = build_f32_twin(&cfg, 42);
        let mut rng = Rng::new(1);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        let l = twin.logits(&img);
        assert_eq!(l.len(), 4);
        assert!(l.iter().all(|v| v.is_finite()));
    }

    /// Agreement metric mechanics: identical classifiers agree fully,
    /// the value is a valid probability, and self-agreement of the QNN
    /// is deterministic. (With *untrained* random weights the QNN-vs-twin
    /// agreement itself is near chance — random deep nets decorrelate
    /// after a few layers — so the example reports it rather than a test
    /// asserting a threshold; a trained model is where the paper's
    /// quality discussion applies.)
    #[test]
    fn agreement_metric_mechanics() {
        let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 16, 16, 1, 10);
        let qnn = build_from_config(&cfg, 0xCAFE);
        let twin = build_f32_twin(&cfg, 0xCAFE);
        let mut rng = Rng::new(2);
        let probes: Vec<Tensor3<f32>> = (0..20).map(|_| Tensor3::random(16, 16, 1, &mut rng)).collect();
        let self_agree = agreement(|i| qnn.predict(i), |i| qnn.predict(i), &probes);
        assert_eq!(self_agree, 1.0);
        let cross = agreement(|i| qnn.predict(i), |i| twin.predict(i), &probes);
        assert!((0.0..=1.0).contains(&cross));
    }

    /// `plan_agreement` equals the closure-based metric over the same
    /// probes, and self-agreement through the plan is exact.
    #[test]
    fn plan_agreement_matches_closure_form() {
        use crate::nn::builder::plan_from_config;
        use crate::nn::plan::NetPlanConfig;
        let cfg = NetConfig::mobile_cnn(ConvKind::Tnn, 16, 16, 1, 10);
        let plan = plan_from_config(&cfg, 0xCAFE, NetPlanConfig::default()).expect("plan");
        let qnn = build_from_config(&cfg, 0xCAFE);
        let twin = build_f32_twin(&cfg, 0xCAFE);
        let mut rng = Rng::new(4);
        let probes: Vec<Tensor3<f32>> = (0..10).map(|_| Tensor3::random(16, 16, 1, &mut rng)).collect();
        let via_plan = plan_agreement(&plan, &twin, &probes).expect("probes match plan input");
        let via_closures = agreement(|i| qnn.predict(i), |i| twin.predict(i), &probes);
        assert!((via_plan - via_closures).abs() < 1e-12);
        assert_eq!(plan_agreement(&plan, &twin, &[]).expect("vacuous"), 1.0);
    }

    #[test]
    fn twin_is_deterministic_per_seed() {
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 4);
        let a = build_f32_twin(&cfg, 7);
        let b = build_f32_twin(&cfg, 7);
        let mut rng = Rng::new(3);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        assert_eq!(a.logits(&img), b.logits(&img));
    }
}
