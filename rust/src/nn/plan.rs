//! The plan/execute API at the **network** boundary —
//! [`crate::gemm::GemmPlan`]'s pack-offline / execute-hot split lifted
//! from one multiplication to a whole CNN (the level the paper actually
//! serves: "inference of convolutional and fully connected layers of
//! TNNs, TBNs, and BNNs").
//!
//! [`NetPlan::build`] performs **full static inference over the layer
//! chain once**: every conv / pool / dense input and output shape, every
//! quantization-domain handoff (Sign → binary, Ternary → ternary, None →
//! f32) and every folded-affine length is checked at build time and
//! surfaces as a typed [`NetError`] — so the hot path carries no
//! `expect_q` / `expect_f` panics and no per-layer asserts. Weights are
//! already packed into built-once [`crate::gemm::GemmPlan`]s by layer
//! construction;
//! `build` re-targets them at the configured [`Backend`] and applies the
//! plan-wide [`Threading`] / [`KPanel`] / [`Tile`] knobs without
//! repacking where possible.
//!
//! [`NetPlan::run`] then executes the network into a caller-owned
//! [`NetOut`] using a [`NetScratch`] whose **two ping-pong activation
//! arenas** (layer `i` writes arena `i % 2`, reads the other) are sized
//! at build time to the per-parity layer maxima — so `run` and
//! [`NetPlan::run_batch`] perform **zero heap allocation after
//! warm-up**, and return typed [`NetError`]s instead of panicking on
//! every contract violation a caller can cause.
//!
//! ```
//! use tbgemm::conv::tensor::Tensor3;
//! use tbgemm::nn::{plan_from_config, NetConfig, NetOut, NetPlanConfig};
//! use tbgemm::util::Rng;
//!
//! // Plan: static shape/domain inference + weights packed once.
//! let cfg = NetConfig::tiny_tnn(8, 8, 1, 4);
//! let plan = plan_from_config(&cfg, 42, NetPlanConfig::default())?;
//!
//! // Execute: run many images through caller-owned output + scratch.
//! let (mut out, mut scratch) = (NetOut::new(), plan.make_scratch());
//! let img = Tensor3::random(8, 8, 1, &mut Rng::new(7));
//! plan.run(&img, &mut out, &mut scratch)?;
//! assert_eq!(out.logits.len(), 4);
//! # Ok::<(), tbgemm::nn::NetError>(())
//! ```

use crate::conv::tensor::Tensor3;
use crate::gemm::{Backend, GemmError, KPanel, Threading, Tile};
use crate::nn::layers::{maxpool2x2_into, ActArena, Domain, Layer, NetScratch};

/// Everything that selects *how* a network plan executes. The weights
/// themselves live in the layers; these knobs land on every layer's
/// [`crate::gemm::GemmPlan`] at [`NetPlan::build`] time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NetPlanConfig {
    /// GEMM backend for every layer (Native is the serving path;
    /// Reference/Emulated turn the whole network into a differential
    /// oracle — the integer epilogues make logits bit-identical).
    pub backend: Backend,
    /// Row-band worker threads for the conv GEMMs (composes with the
    /// coordinator's replica-level batch splitting).
    pub threading: Threading,
    /// Deep-K depth blocking.
    pub k_panel: KPanel,
    /// Register tile (e.g. the widened BNN 4×4 / TNN 2×4 tiles).
    pub tile: Tile,
    /// Autotune each GEMM layer: [`NetPlan::build`] resolves the layer's
    /// execution knobs per shape through [`crate::tune::resolve`] (the
    /// persisted tuning store, falling back to cost-model ranking),
    /// overriding the plan-wide `threading` / `k_panel` / `tile` for
    /// those layers. Native backend only; ignored otherwise.
    pub tuning: bool,
}

impl Default for NetPlanConfig {
    fn default() -> Self {
        NetPlanConfig {
            backend: Backend::Native,
            threading: Threading::Single,
            k_panel: KPanel::Auto,
            tile: Tile::Auto,
            tuning: false,
        }
    }
}

impl NetPlanConfig {
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_threading(mut self, threading: Threading) -> Self {
        self.threading = threading;
        self
    }

    pub fn with_k_panel(mut self, k_panel: KPanel) -> Self {
        self.k_panel = k_panel;
        self
    }

    pub fn with_tile(mut self, tile: Tile) -> Self {
        self.tile = tile;
        self
    }

    /// Enable per-layer autotuned config resolution (see
    /// [`NetPlanConfig::tuning`]).
    pub fn with_tuning(mut self, tuning: bool) -> Self {
        self.tuning = tuning;
        self
    }
}

/// Typed failure of network-plan construction or execution. No entry
/// point on the network path panics on caller input; every contract
/// violation surfaces here (at [`NetPlan::build`] for anything static,
/// at [`NetPlan::run`] only for per-call inputs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NetError {
    /// The image handed to `run` does not match the plan's input dims.
    InputMismatch { expected: (usize, usize, usize), got: (usize, usize, usize) },
    /// A layer's required activation domain differs from what the
    /// previous layer produces (e.g. binary activations into a ternary
    /// conv, or a quantized layer directly on the f32 input).
    DomainMismatch { layer: usize, expected: &'static str, got: &'static str },
    /// The layer chain is structurally invalid at `layer` (shape
    /// mismatch between consecutive layers, affine length mismatch,
    /// empty network, degenerate spatial dims, ...).
    UnsupportedChain { layer: usize, reason: &'static str },
    /// `run_batch` was handed `got` output slots for `expected` images.
    OutputMismatch { expected: usize, got: usize },
    /// A layer's GEMM plan rejected its configuration or execution
    /// (e.g. repacking for a new backend failed).
    Gemm { layer: usize, error: GemmError },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::InputMismatch { expected, got } => {
                write!(f, "input dims mismatch: plan expects {expected:?}, got {got:?}")
            }
            NetError::DomainMismatch { layer, expected, got } => {
                write!(f, "layer {layer}: expects {expected} activations, got {got}")
            }
            NetError::UnsupportedChain { layer, reason } => {
                write!(f, "layer {layer}: unsupported layer chain: {reason}")
            }
            NetError::OutputMismatch { expected, got } => {
                write!(f, "output batch mismatch: {expected} images but {got} output slots")
            }
            NetError::Gemm { layer, error } => write!(f, "layer {layer}: GEMM plan error: {error}"),
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Gemm { error, .. } => Some(error),
            _ => None,
        }
    }
}

/// Caller-owned output of a network run: the classifier logits, resized
/// in place (steady state: no reallocation).
#[derive(Clone, Debug, Default)]
pub struct NetOut {
    pub logits: Vec<f32>,
}

impl NetOut {
    pub fn new() -> Self {
        NetOut { logits: Vec::new() }
    }

    /// Argmax class prediction (0 for empty logits).
    pub fn predicted(&self) -> usize {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in self.logits.iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }
}

/// Per-layer timing record from an instrumented [`NetPlan::run_timed`].
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub name: &'static str,
    pub seconds: f64,
    pub out_dims: (usize, usize, usize),
}

/// Statically inferred per-layer output info (computed once at build).
#[derive(Clone, Copy, Debug)]
struct Stage {
    out_dims: (usize, usize, usize),
    out_domain: Domain,
}

/// A built-once network plan: the layer chain with statically verified
/// shapes/domains, packed weights, and a precomputed scratch layout.
/// See the [module docs](self) for the API story.
pub struct NetPlan {
    layers: Vec<Layer>,
    input_dims: (usize, usize, usize),
    stages: Vec<Stage>,
    cfg: NetPlanConfig,
    /// Per-parity ping-pong arena maxima (elements), quantized / f32.
    max_q: [usize; 2],
    max_f: [usize; 2],
    /// Conv accumulator / im2col / dense-flatten maxima (elements).
    max_conv_acc: usize,
    max_im2col: usize,
    max_dense_flat: usize,
}

impl NetPlan {
    /// Build a plan over `layers` for images of `input_dims`, verifying
    /// the whole chain statically and applying `cfg` to every layer's
    /// GEMM plan. All shape/domain errors a misassembled network can
    /// produce surface here, once — never in the hot path.
    pub fn build(
        input_dims: (usize, usize, usize),
        mut layers: Vec<Layer>,
        cfg: NetPlanConfig,
    ) -> Result<NetPlan, NetError> {
        if layers.is_empty() {
            return Err(NetError::UnsupportedChain { layer: 0, reason: "network has no layers" });
        }
        let quantized = "quantized (binary/ternary)";
        let (mut h, mut w, mut c) = input_dims;
        let mut domain = Domain::F32;
        let mut stages = Vec::with_capacity(layers.len());
        let mut max_q = [0usize; 2];
        let mut max_f = [0usize; 2];
        let (mut max_conv_acc, mut max_im2col, mut max_dense_flat) = (0usize, 0usize, 0usize);
        for (i, layer) in layers.iter_mut().enumerate() {
            layer
                .configure_gemm(cfg.backend, cfg.threading, cfg.k_panel, cfg.tile)
                .map_err(|error| NetError::Gemm { layer: i, error })?;
            let (out_dims, out_domain) = match &*layer {
                Layer::InputQuant(l) => {
                    if domain != Domain::F32 {
                        return Err(NetError::DomainMismatch {
                            layer: i,
                            expected: "f32",
                            got: domain.label(),
                        });
                    }
                    let out = l.act.out_domain();
                    if out == Domain::F32 {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "input quantizer must produce a quantized domain",
                        });
                    }
                    ((h, w, c), out)
                }
                Layer::QConv(l) => {
                    let required = conv_domain(l.conv.kind);
                    if domain != required {
                        return Err(NetError::DomainMismatch {
                            layer: i,
                            expected: required.label(),
                            got: domain.label(),
                        });
                    }
                    if c != l.conv.c_in {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "conv input channel count differs from the packed weights",
                        });
                    }
                    if l.scale.len() != l.conv.c_out || l.bias.len() != l.conv.c_out {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "conv affine length differs from output channels",
                        });
                    }
                    let (oh, ow) = l.conv.params.out_dims(h, w);
                    if oh == 0 || ow == 0 {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "conv output is empty for this input size",
                        });
                    }
                    max_conv_acc = max_conv_acc.max(oh * ow * l.conv.c_out);
                    max_im2col = max_im2col.max(oh * ow * l.conv.params.depth(l.conv.c_in));
                    ((oh, ow, l.conv.c_out), l.act.out_domain())
                }
                Layer::MaxPool2 => {
                    if !domain.is_quantized() {
                        return Err(NetError::DomainMismatch {
                            layer: i,
                            expected: quantized,
                            got: domain.label(),
                        });
                    }
                    if h < 2 || w < 2 {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "2x2 max-pool needs height and width of at least 2",
                        });
                    }
                    ((h / 2, w / 2, c), domain)
                }
                Layer::QDense(l) => {
                    let required = conv_domain(l.kind);
                    if domain != required {
                        return Err(NetError::DomainMismatch {
                            layer: i,
                            expected: required.label(),
                            got: domain.label(),
                        });
                    }
                    if h * w * c != l.in_features {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "dense input size differs from the packed weights",
                        });
                    }
                    if l.scale.len() != l.out_features || l.bias.len() != l.out_features {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "dense affine length differs from output features",
                        });
                    }
                    max_dense_flat = max_dense_flat.max(l.in_features);
                    ((1, 1, l.out_features), l.act.out_domain())
                }
                Layer::DenseF32(l) => {
                    if h * w * c != l.weights.rows {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "f32 head input size differs from its weights",
                        });
                    }
                    if l.bias.len() != l.weights.cols {
                        return Err(NetError::UnsupportedChain {
                            layer: i,
                            reason: "f32 head bias length differs from output features",
                        });
                    }
                    if domain.is_quantized() && i > 0 {
                        // The head widens low-bit input into the *read*
                        // arena's f32 buffer before the matmul.
                        let r = (i + 1) % 2;
                        max_f[r] = max_f[r].max(h * w * c);
                    }
                    ((1, 1, l.weights.cols), Domain::F32)
                }
            };
            // With tuning enabled, re-resolve this layer's execution
            // knobs now that its GEMM shape is known (tuning-store hit,
            // else cost-model ranking). The backend is already applied
            // above, so this second configure only moves knobs — it
            // never repacks.
            if cfg.tuning && cfg.backend == Backend::Native {
                let gemm_shape = match &*layer {
                    Layer::QConv(l) => Some((
                        l.conv.kind.gemm_kind(),
                        (out_dims.0 * out_dims.1, l.conv.c_out, l.conv.params.depth(l.conv.c_in)),
                    )),
                    Layer::QDense(l) => Some((l.kind.gemm_kind(), (1, l.out_features, l.in_features))),
                    _ => None,
                };
                if let Some((kind, shape)) = gemm_shape {
                    let choice = crate::tune::resolve(kind, shape);
                    layer
                        .configure_gemm(cfg.backend, choice.threading, choice.k_panel, choice.tile)
                        .map_err(|error| NetError::Gemm { layer: i, error })?;
                }
            }
            let elems = out_dims.0 * out_dims.1 * out_dims.2;
            let parity = i % 2;
            if out_domain.is_quantized() {
                max_q[parity] = max_q[parity].max(elems);
            } else {
                max_f[parity] = max_f[parity].max(elems);
            }
            stages.push(Stage { out_dims, out_domain });
            (h, w, c) = out_dims;
            domain = out_domain;
        }
        Ok(NetPlan {
            layers,
            input_dims,
            stages,
            cfg,
            max_q,
            max_f,
            max_conv_acc,
            max_im2col,
            max_dense_flat,
        })
    }

    /// The plan's execution config.
    pub fn config(&self) -> NetPlanConfig {
        self.cfg
    }

    /// Input image dims `(h, w, c)` the plan expects.
    pub fn input_dims(&self) -> (usize, usize, usize) {
        self.input_dims
    }

    /// Number of layers in the chain.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Length of the logits vector every run produces.
    pub fn out_features(&self) -> usize {
        self.stages.last().map(|s| s.out_dims.0 * s.out_dims.1 * s.out_dims.2).unwrap_or(0)
    }

    /// Statically inferred output dims of layer `i`.
    pub fn stage_dims(&self, i: usize) -> Option<(usize, usize, usize)> {
        self.stages.get(i).map(|s| s.out_dims)
    }

    /// A scratch arena pre-sized to this plan's layout: both ping-pong
    /// activation arenas at their per-parity maxima plus the conv /
    /// dense GEMM buffers, so even the *first* run performs no
    /// activation-arena allocation (the GEMM bit-packing arenas inside
    /// [`crate::gemm::GemmScratch`] still grow once, on warm-up).
    pub fn make_scratch(&self) -> NetScratch {
        let mut s = NetScratch::new();
        for (parity, arena) in s.arenas.iter_mut().enumerate() {
            arena.q.data.reserve(self.max_q[parity]);
            arena.f.data.reserve(self.max_f[parity]);
        }
        s.conv_acc.data.reserve(self.max_conv_acc);
        s.conv.reserve(self.max_im2col);
        s.dense.reserve(self.max_dense_flat);
        s
    }

    /// Re-target the per-GEMM row-band threading without repacking
    /// (composes with the coordinator's replica-level parallelism).
    pub fn set_threading(&mut self, threading: Threading) {
        self.cfg.threading = threading;
        for layer in &mut self.layers {
            layer.set_threading(threading);
        }
    }

    /// Execute the network on one image into the caller-owned `out`,
    /// reusing `scratch`. Zero heap allocation after warm-up; the only
    /// run-time error a caller can cause is [`NetError::InputMismatch`]
    /// (everything else was verified at build).
    pub fn run(&self, image: &Tensor3<f32>, out: &mut NetOut, scratch: &mut NetScratch) -> Result<(), NetError> {
        self.run_inner(image, out, scratch, None)
    }

    /// As [`NetPlan::run`], recording per-layer wall-clock into
    /// `timings` (cleared first).
    pub fn run_timed(
        &self,
        image: &Tensor3<f32>,
        out: &mut NetOut,
        scratch: &mut NetScratch,
        timings: &mut Vec<LayerTiming>,
    ) -> Result<(), NetError> {
        self.run_inner(image, out, scratch, Some(timings))
    }

    /// Execute the network on a batch of images, one output slot per
    /// image (`outs.len()` must equal `images.len()`), sharing one
    /// scratch across the whole batch.
    pub fn run_batch(
        &self,
        images: &[Tensor3<f32>],
        outs: &mut [NetOut],
        scratch: &mut NetScratch,
    ) -> Result<(), NetError> {
        if images.len() != outs.len() {
            return Err(NetError::OutputMismatch { expected: images.len(), got: outs.len() });
        }
        for (image, out) in images.iter().zip(outs.iter_mut()) {
            self.run(image, out, scratch)?;
        }
        Ok(())
    }

    fn run_inner(
        &self,
        image: &Tensor3<f32>,
        out: &mut NetOut,
        scratch: &mut NetScratch,
        mut timings: Option<&mut Vec<LayerTiming>>,
    ) -> Result<(), NetError> {
        let got = (image.h, image.w, image.c);
        if got != self.input_dims {
            return Err(NetError::InputMismatch { expected: self.input_dims, got });
        }
        if let Some(ts) = timings.as_mut() {
            ts.clear();
        }
        let NetScratch { conv, dense, conv_acc, arenas } = scratch;
        for (i, layer) in self.layers.iter().enumerate() {
            let t0 = timings.is_some().then(std::time::Instant::now);
            let (dst, src) = arena_pair(arenas, i % 2);
            match layer {
                Layer::InputQuant(l) => {
                    let f_in = if i == 0 { image } else { &src.f };
                    l.forward_into(f_in, &mut dst.q);
                }
                Layer::QConv(l) => {
                    if i == 0 {
                        // Build rejects quantized layers on the f32
                        // input; stay total (and panic-free) regardless.
                        return Err(quantized_on_input(i));
                    }
                    l.forward_into(&src.q, conv, conv_acc, dst)
                        .map_err(|error| NetError::Gemm { layer: i, error })?;
                }
                Layer::MaxPool2 => {
                    if i == 0 {
                        return Err(quantized_on_input(i));
                    }
                    maxpool2x2_into(&src.q, &mut dst.q);
                }
                Layer::QDense(l) => {
                    if i == 0 {
                        return Err(quantized_on_input(i));
                    }
                    l.forward_into(&src.q, dense, dst)
                        .map_err(|error| NetError::Gemm { layer: i, error })?;
                }
                Layer::DenseF32(l) => {
                    let result = if i == 0 {
                        l.forward_into(image, &mut dst.f)
                    } else if self.stages[i - 1].out_domain.is_quantized() {
                        // Widen the low-bit activations into the read
                        // arena's f32 buffer (idle at this point), then
                        // run the full-precision head from there.
                        src.f.resize_to(src.q.h, src.q.w, src.q.c);
                        for (o, &v) in src.f.data.iter_mut().zip(&src.q.data) {
                            *o = v as f32;
                        }
                        l.forward_into(&src.f, &mut dst.f)
                    } else {
                        l.forward_into(&src.f, &mut dst.f)
                    };
                    result.map_err(|error| NetError::Gemm { layer: i, error })?;
                }
            }
            if let (Some(ts), Some(t0)) = (timings.as_mut(), t0) {
                ts.push(LayerTiming {
                    name: layer.name(),
                    seconds: t0.elapsed().as_secs_f64(),
                    out_dims: self.stages[i].out_dims,
                });
            }
        }
        // Copy the final activation into the caller-owned logits buffer
        // (i8 domains widen to f32), reusing its capacity.
        let last = (self.layers.len() - 1) % 2;
        let arena = &arenas[last];
        out.logits.clear();
        match self.stages[self.layers.len() - 1].out_domain {
            Domain::F32 => out.logits.extend_from_slice(&arena.f.data),
            _ => out.logits.extend(arena.q.data.iter().map(|&v| v as f32)),
        }
        Ok(())
    }
}

/// The activation domain a low-bit kind consumes.
fn conv_domain(kind: crate::conv::conv2d::ConvKind) -> Domain {
    match kind {
        crate::conv::conv2d::ConvKind::Bnn => Domain::Binary,
        crate::conv::conv2d::ConvKind::Tnn | crate::conv::conv2d::ConvKind::Tbn => Domain::Ternary,
    }
}

fn quantized_on_input(layer: usize) -> NetError {
    NetError::DomainMismatch { layer, expected: "quantized (binary/ternary)", got: "f32" }
}

/// Split the ping-pong pair into (write arena `w`, read arena `1 - w`).
fn arena_pair(arenas: &mut [ActArena; 2], w: usize) -> (&mut ActArena, &mut ActArena) {
    let (a, b) = arenas.split_at_mut(1);
    if w == 0 {
        (&mut a[0], &mut b[0])
    } else {
        (&mut b[0], &mut a[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::builder::{build_layers, plan_from_config, NetConfig};
    use crate::util::Rng;

    #[test]
    fn build_infers_stage_dims() {
        let cfg = NetConfig::tiny_tnn(12, 12, 1, 4);
        let plan = plan_from_config(&cfg, 7, NetPlanConfig::default()).expect("plan");
        // input_quant → conv(8) → pool → dense(4)
        assert_eq!(plan.num_layers(), 4);
        assert_eq!(plan.stage_dims(0), Some((12, 12, 1)));
        assert_eq!(plan.stage_dims(1), Some((12, 12, 8)));
        assert_eq!(plan.stage_dims(2), Some((6, 6, 8)));
        assert_eq!(plan.stage_dims(3), Some((1, 1, 4)));
        assert_eq!(plan.out_features(), 4);
        assert_eq!(plan.input_dims(), (12, 12, 1));
    }

    #[test]
    fn empty_chain_is_rejected() {
        assert_eq!(
            NetPlan::build((8, 8, 1), Vec::new(), NetPlanConfig::default()).err(),
            Some(NetError::UnsupportedChain { layer: 0, reason: "network has no layers" })
        );
    }

    #[test]
    fn run_matches_per_seed_and_validates_input() {
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
        let plan = plan_from_config(&cfg, 11, NetPlanConfig::default()).expect("plan");
        let mut rng = Rng::new(5);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        let (mut out, mut scratch) = (NetOut::new(), plan.make_scratch());
        plan.run(&img, &mut out, &mut scratch).expect("run");
        let first = out.logits.clone();
        assert_eq!(first.len(), 3);
        plan.run(&img, &mut out, &mut scratch).expect("run");
        assert_eq!(out.logits, first, "deterministic across runs");
        let wrong = Tensor3::random(9, 8, 1, &mut rng);
        assert_eq!(
            plan.run(&wrong, &mut out, &mut scratch),
            Err(NetError::InputMismatch { expected: (8, 8, 1), got: (9, 8, 1) })
        );
    }

    #[test]
    fn run_timed_reports_every_layer() {
        let cfg = NetConfig::tiny_tnn(8, 8, 1, 3);
        let plan = plan_from_config(&cfg, 11, NetPlanConfig::default()).expect("plan");
        let mut rng = Rng::new(6);
        let img = Tensor3::random(8, 8, 1, &mut rng);
        let (mut out, mut scratch) = (NetOut::new(), plan.make_scratch());
        let mut timings = Vec::new();
        plan.run_timed(&img, &mut out, &mut scratch, &mut timings).expect("run");
        assert_eq!(timings.len(), plan.num_layers());
        assert_eq!(timings[0].name, "input_quant");
    }

    #[test]
    fn domain_handoff_is_checked_at_build() {
        use crate::conv::conv2d::ConvKind;
        // A BNN config whose input quantizer produces *ternary*
        // activations: rejected at layer 1 (the binary conv), at build.
        let cfg = NetConfig {
            input: (8, 8, 1),
            layers: vec![
                crate::nn::builder::LayerSpec::InputQuant { ternary: true, delta: 0.4 },
                crate::nn::builder::LayerSpec::Conv {
                    kind: ConvKind::Bnn,
                    c_out: 4,
                    hk: 3,
                    wk: 3,
                    stride: 1,
                    pad: 1,
                    ternary_out: false,
                },
            ],
            delta: 0.4,
        };
        let (input, layers) = build_layers(&cfg, 3);
        assert_eq!(
            NetPlan::build(input, layers, NetPlanConfig::default()).err(),
            Some(NetError::DomainMismatch { layer: 1, expected: "binary", got: "ternary" })
        );
    }
}
