//! §III-B/C/D of the paper: `Ablock` / `Bblock` storage orders.
//!
//! Each microkernel consumes panels of `A` and `B` reordered so that its
//! inner loop is a stream of contiguous SIMD loads:
//!
//! * **BNN A** (16 rows): rows are bit-packed (8 depth bits per byte, the
//!   paper's single-bit encoding `1→0, −1→1`) and stored chunk-major: for
//!   each 8-deep chunk, 16 bytes — one per row. One chunk = one `LD1.16B`.
//! * **BNN B** (8 cols): columns bit-packed, chunk-major, 8 bytes per
//!   chunk. One chunk = one `LD1.8B`.
//! * **TNN A** (16 rows): the `(+,−)` planes are bit-packed separately and
//!   stored per chunk as `[A⁺ r0..8 | A⁻ r0..8 | A⁺ r8..16 | A⁻ r8..16]`
//!   (32 bytes = two `LD1.16B`) — the paper's §III-C order.
//! * **TNN B** (8 cols): per chunk, interleaved `[B⁺c0, B⁻c0, …, B⁺c7,
//!   B⁻c7]` (16 bytes = one `LD1.16B`).
//! * **TBN**: A as TNN-A, B as BNN-B.
//! * Baseline panel packs (F32 / U8 / U4) follow the classic GotoBLAS
//!   row-panel / column-panel orders described in §II-A.
//!
//! Padding convention: rows/columns beyond the matrix edge and depth bits
//! beyond `k` are packed as **zero bits**. For the ternary planes a zero
//! bit-pair is the value `0`, which contributes nothing — no correction
//! needed. For the binary encodings a zero bit decodes to `+1`, so the
//! driver subtracts the depth padding (`k_pad − k`) from every output of a
//! binary product (eq. (6) correction), and edge rows/cols are simply not
//! copied out of the microkernel scratch tile.

use crate::gemm::encode::{encode_binary, encode_ternary};
use crate::util::mat::{MatF32, MatI8, MatU8};

/// Round `k` up to a multiple of `step`.
#[inline]
pub fn round_up(k: usize, step: usize) -> usize {
    k.div_ceil(step) * step
}

/// Bit-pack one logical row/column of binary values into bytes
/// (LSB-first within each byte). `get(t)` returns the t-th element;
/// out-of-range elements must be handled by the caller's closure.
fn pack_bits_into(bytes: &mut [u8], k: usize, get: impl Fn(usize) -> u8) {
    for (chunk, byte) in bytes.iter_mut().enumerate() {
        let mut b = 0u8;
        for bit in 0..8 {
            let t = chunk * 8 + bit;
            if t < k {
                b |= get(t) << bit;
            }
        }
        *byte = b;
    }
}

// ---------------------------------------------------------------------
// BNN packing (§III-B)
// ---------------------------------------------------------------------

/// Pack 16 rows of a binary matrix starting at `row0` into the BNN
/// `Ablock` order. Output: `k_chunks * 16` bytes, chunk-major.
/// Rows past `a.rows` pack as zero (decoded `+1`); the driver never copies
/// those outputs.
pub fn pack_a_bnn(a: &MatI8, row0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 8) / 8;
    let mut out = vec![0u8; chunks * 16];
    let mut tmp = vec![0u8; chunks];
    for r in 0..16 {
        let row = row0 + r;
        if row < a.rows {
            pack_bits_into(&mut tmp, k, |t| encode_binary(a.get(row, t)));
        } else {
            tmp.iter_mut().for_each(|b| *b = 0);
        }
        for (d, &b) in tmp.iter().enumerate() {
            out[d * 16 + r] = b;
        }
    }
    out
}

/// Pack 8 columns of a binary matrix starting at `col0` into the BNN
/// `Bblock` order. Output: `k_chunks * 8` bytes, chunk-major.
pub fn pack_b_bnn(b: &MatI8, col0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 8) / 8;
    let mut out = vec![0u8; chunks * 8];
    let mut tmp = vec![0u8; chunks];
    for c in 0..8 {
        let col = col0 + c;
        if col < b.cols {
            pack_bits_into(&mut tmp, k.min(b.rows), |t| encode_binary(b.get(t, col)));
        } else {
            tmp.iter_mut().for_each(|x| *x = 0);
        }
        for (d, &x) in tmp.iter().enumerate() {
            out[d * 8 + c] = x;
        }
    }
    out
}

// ---------------------------------------------------------------------
// TNN packing (§III-C)
// ---------------------------------------------------------------------

/// Pack 16 rows of a ternary matrix into the TNN `Ablock` order:
/// per chunk `[A⁺ r0..8 | A⁻ r0..8 | A⁺ r8..16 | A⁻ r8..16]` (32 bytes).
pub fn pack_a_tnn(a: &MatI8, row0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 8) / 8;
    let mut out = vec![0u8; chunks * 32];
    let mut plus = vec![0u8; chunks];
    let mut minus = vec![0u8; chunks];
    for r in 0..16 {
        let row = row0 + r;
        if row < a.rows {
            pack_bits_into(&mut plus, k, |t| encode_ternary(a.get(row, t)).0);
            pack_bits_into(&mut minus, k, |t| encode_ternary(a.get(row, t)).1);
        } else {
            plus.iter_mut().for_each(|b| *b = 0);
            minus.iter_mut().for_each(|b| *b = 0);
        }
        let (group, within) = (r / 8, r % 8);
        for d in 0..chunks {
            out[d * 32 + group * 16 + within] = plus[d];
            out[d * 32 + group * 16 + 8 + within] = minus[d];
        }
    }
    out
}

/// Pack 8 columns of a ternary matrix into the TNN `Bblock` order:
/// per chunk `[B⁺c0, B⁻c0, B⁺c1, B⁻c1, …]` (16 bytes).
pub fn pack_b_tnn(b: &MatI8, col0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 8) / 8;
    let mut out = vec![0u8; chunks * 16];
    let mut plus = vec![0u8; chunks];
    let mut minus = vec![0u8; chunks];
    for c in 0..8 {
        let col = col0 + c;
        if col < b.cols {
            pack_bits_into(&mut plus, k.min(b.rows), |t| encode_ternary(b.get(t, col)).0);
            pack_bits_into(&mut minus, k.min(b.rows), |t| encode_ternary(b.get(t, col)).1);
        } else {
            plus.iter_mut().for_each(|x| *x = 0);
            minus.iter_mut().for_each(|x| *x = 0);
        }
        for d in 0..chunks {
            out[d * 16 + 2 * c] = plus[d];
            out[d * 16 + 2 * c + 1] = minus[d];
        }
    }
    out
}

// ---------------------------------------------------------------------
// Baseline panel packing (§II-A, GotoBLAS order)
// ---------------------------------------------------------------------

/// Pack 12 rows of an f32 matrix, chunk-major with one column (depth step)
/// per chunk: `[A[r][d] for r in 0..12]`, padded to 12 with zeros.
pub fn pack_a_f32(a: &MatF32, row0: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * 12];
    for d in 0..k {
        for r in 0..12 {
            let row = row0 + r;
            if row < a.rows {
                out[d * 12 + r] = a.get(row, d);
            }
        }
    }
    out
}

/// Pack 8 columns of an f32 matrix: per depth step `[B[d][c] for c in 0..8]`.
pub fn pack_b_f32(b: &MatF32, col0: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * 8];
    for d in 0..k.min(b.rows) {
        for c in 0..8 {
            let col = col0 + c;
            if col < b.cols {
                out[d * 8 + c] = b.get(d, col);
            }
        }
    }
    out
}

/// Pack 12 rows of a u8 matrix for the U8 microkernel. Per 2-deep chunk:
/// `[A[r][2d] r=0..12, pad4 | A[r][2d+1] r=0..12, pad4]` (32 bytes = two
/// `LD1.16B`). Depth padding packs zeros; with the gemmlowp convention the
/// driver compensates zero-points over the true `k` only.
pub fn pack_a_u8(a: &MatU8, row0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 2) / 2;
    let mut out = vec![0u8; chunks * 32];
    for d in 0..chunks {
        for t in 0..2 {
            let depth = 2 * d + t;
            for r in 0..12 {
                let row = row0 + r;
                if depth < k && row < a.rows {
                    out[d * 32 + t * 16 + r] = a.get(row, depth);
                }
            }
        }
    }
    out
}

/// Pack 8 columns of a u8 matrix for the U8 microkernel. Per 2-deep chunk:
/// `[B[2d][c] c=0..8 | B[2d+1][c] c=0..8]` (16 bytes = one `LD1.16B`).
pub fn pack_b_u8(b: &MatU8, col0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 2) / 2;
    let mut out = vec![0u8; chunks * 16];
    for d in 0..chunks {
        for t in 0..2 {
            let depth = 2 * d + t;
            for c in 0..8 {
                let col = col0 + c;
                if depth < k.min(b.rows) && col < b.cols {
                    out[d * 16 + t * 8 + c] = b.get(depth, col);
                }
            }
        }
    }
    out
}

/// Pack 24 rows of a 4-bit matrix (values 0..=15 stored one per u8) for
/// the U4 microkernel. Per 2-deep chunk: 24 bytes, byte `r` holding
/// `A[r][2d] | A[r][2d+1] << 4`.
pub fn pack_a_u4(a: &MatU8, row0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 2) / 2;
    let mut out = vec![0u8; chunks * 24];
    for d in 0..chunks {
        for r in 0..24 {
            let row = row0 + r;
            let lo = if 2 * d < k && row < a.rows { a.get(row, 2 * d) } else { 0 };
            let hi = if 2 * d + 1 < k && row < a.rows { a.get(row, 2 * d + 1) } else { 0 };
            debug_assert!(lo < 16 && hi < 16, "U4 values must be 4-bit");
            out[d * 24 + r] = lo | (hi << 4);
        }
    }
    out
}

/// Pack 8 columns of a 4-bit matrix for the U4 microkernel. Per 2-deep
/// chunk: 8 bytes, byte `c` holding `B[2d][c] | B[2d+1][c] << 4`.
pub fn pack_b_u4(b: &MatU8, col0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 2) / 2;
    let mut out = vec![0u8; chunks * 8];
    for d in 0..chunks {
        for c in 0..8 {
            let col = col0 + c;
            let kb = k.min(b.rows);
            let lo = if 2 * d < kb && col < b.cols { b.get(2 * d, col) } else { 0 };
            let hi = if 2 * d + 1 < kb && col < b.cols { b.get(2 * d + 1, col) } else { 0 };
            debug_assert!(lo < 16 && hi < 16, "U4 values must be 4-bit");
            out[d * 8 + c] = lo | (hi << 4);
        }
    }
    out
}

// ---------------------------------------------------------------------
// daBNN packing (8×6×128 microkernel)
// ---------------------------------------------------------------------

/// Pack 8 rows of a binary matrix for the daBNN microkernel: per 128-deep
/// chunk, 8 × 16 bytes (one full `LD1.16B` per row).
pub fn pack_a_dabnn(a: &MatI8, row0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 128) / 128;
    let mut out = vec![0u8; chunks * 8 * 16];
    let bytes = round_up(k, 8) / 8;
    let mut tmp = vec![0u8; bytes];
    for r in 0..8 {
        let row = row0 + r;
        if row < a.rows {
            pack_bits_into(&mut tmp, k, |t| encode_binary(a.get(row, t)));
        } else {
            tmp.iter_mut().for_each(|b| *b = 0);
        }
        for d in 0..chunks {
            for byte in 0..16 {
                let src = d * 16 + byte;
                out[d * 128 + r * 16 + byte] = if src < bytes { tmp[src] } else { 0 };
            }
        }
    }
    out
}

/// Pack 6 columns of a binary matrix for the daBNN microkernel: per
/// 128-deep chunk, 6 × 16 bytes.
pub fn pack_b_dabnn(b: &MatI8, col0: usize, k: usize) -> Vec<u8> {
    let chunks = round_up(k, 128) / 128;
    let mut out = vec![0u8; chunks * 6 * 16];
    let bytes = round_up(k, 8) / 8;
    let mut tmp = vec![0u8; bytes];
    for c in 0..6 {
        let col = col0 + c;
        if col < b.cols {
            pack_bits_into(&mut tmp, k.min(b.rows), |t| encode_binary(b.get(t, col)));
        } else {
            tmp.iter_mut().for_each(|x| *x = 0);
        }
        for d in 0..chunks {
            for byte in 0..16 {
                let src = d * 16 + byte;
                out[d * 96 + c * 16 + byte] = if src < bytes { tmp[src] } else { 0 };
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bnn_a_chunk_major_layout() {
        // A 16×8 matrix of all -1 encodes to bytes of 0xFF.
        let a = MatI8::from_fn(16, 8, |_, _| -1);
        let p = pack_a_bnn(&a, 0, 8);
        assert_eq!(p.len(), 16);
        assert!(p.iter().all(|&b| b == 0xFF));
        // all +1 encodes to 0x00
        let a = MatI8::from_fn(16, 8, |_, _| 1);
        assert!(pack_a_bnn(&a, 0, 8).iter().all(|&b| b == 0));
    }

    #[test]
    fn bnn_a_bit_addressing() {
        // Row 3, depth bit 10 set to -1, everything else +1:
        // chunk 1 (bits 8..16), byte index 3, bit 2.
        let mut a = MatI8::from_fn(16, 16, |_, _| 1);
        a.set(3, 10, -1);
        let p = pack_a_bnn(&a, 0, 16);
        assert_eq!(p.len(), 32);
        assert_eq!(p[16 + 3], 1 << 2);
        assert!(p.iter().enumerate().all(|(i, &b)| i == 19 || b == 0));
    }

    #[test]
    fn bnn_b_bit_addressing() {
        // Col 5, depth bit 9: chunk 1, byte 5, bit 1.
        let mut b = MatI8::from_fn(16, 8, |_, _| 1);
        b.set(9, 5, -1);
        let p = pack_b_bnn(&b, 0, 16);
        assert_eq!(p.len(), 16);
        assert_eq!(p[8 + 5], 1 << 1);
    }

    #[test]
    fn tnn_a_group_layout() {
        // +1 in row 2 → A⁺ plane, group 0, byte offset 2.
        // -1 in row 11 → A⁻ plane, group 1, byte offset 16+8+(11-8)=27.
        let mut a = MatI8::zeros(16, 8);
        a.set(2, 0, 1);
        a.set(11, 0, -1);
        let p = pack_a_tnn(&a, 0, 8);
        assert_eq!(p.len(), 32);
        assert_eq!(p[2], 1); // A⁺ r0..8
        assert_eq!(p[16 + 8 + 3], 1); // A⁻ r8..16
        let set: usize = p.iter().map(|b| b.count_ones() as usize).sum();
        assert_eq!(set, 2);
    }

    #[test]
    fn tnn_b_interleaved_layout() {
        let mut b = MatI8::zeros(8, 8);
        b.set(0, 3, 1); // B⁺ col 3 bit 0 → byte 2*3
        b.set(1, 4, -1); // B⁻ col 4 bit 1 → byte 2*4+1
        let p = pack_b_tnn(&b, 0, 8);
        assert_eq!(p.len(), 16);
        assert_eq!(p[6], 1);
        assert_eq!(p[9], 1 << 1);
    }

    #[test]
    fn f32_pack_shapes_and_padding() {
        let mut rng = Rng::new(1);
        let a = MatF32::random(10, 5, &mut rng); // fewer than 12 rows
        let p = pack_a_f32(&a, 0, 5);
        assert_eq!(p.len(), 60);
        assert_eq!(p[0], a.get(0, 0));
        assert_eq!(p[12 + 1], a.get(1, 1));
        assert_eq!(p[10], 0.0); // padded row
        assert_eq!(p[11], 0.0);
    }

    #[test]
    fn u8_pack_layout() {
        let mut b = MatU8::zeros(4, 8);
        b.data[1 * 8 + 2] = 99; // B[1][2] → chunk 0, t=1, c=2
        let p = pack_b_u8(&b, 0, 4);
        assert_eq!(p.len(), 32);
        assert_eq!(p[8 + 2], 99);
    }

    #[test]
    fn u4_nibble_packing() {
        let mut a = MatU8::zeros(24, 2);
        a.data[0 * 2 + 0] = 0x5;
        a.data[0 * 2 + 1] = 0xA;
        let p = pack_a_u4(&a, 0, 2);
        assert_eq!(p.len(), 24);
        assert_eq!(p[0], 0x5 | (0xA << 4));
    }

    #[test]
    fn dabnn_pack_row_major_128() {
        let a = MatI8::from_fn(8, 128, |_, _| -1);
        let p = pack_a_dabnn(&a, 0, 128);
        assert_eq!(p.len(), 128);
        assert!(p.iter().all(|&b| b == 0xFF));
    }

    #[test]
    fn depth_padding_is_zero_bits() {
        // k=5 pads bits 5..8 with 0 in both A and B packs.
        let a = MatI8::from_fn(16, 5, |_, _| -1);
        let p = pack_a_bnn(&a, 0, 5);
        for &byte in &p[..16] {
            assert_eq!(byte, 0b0001_1111);
        }
    }

    #[test]
    fn row0_offset_selects_rows() {
        let a = MatI8::from_fn(32, 8, |r, _| if r >= 16 { -1 } else { 1 });
        let p = pack_a_bnn(&a, 16, 8);
        assert!(p.iter().all(|&b| b == 0xFF));
    }
}
