//! The paper's Algorithm 2: the blocked GEMM loop with a pre-reordered
//! right-hand matrix ("PackedB").
//!
//! In NN inference the right matrix is the weight matrix: it is small,
//! constant, and packed **once, offline**. Per multiplication the driver
//! walks the depth in `k_blk` blocks and the rows in `m_mk` panels,
//! packing each small `Ablock` on the fly (the paper's memory-frugal
//! variant: the `A_buf` holds only `m_mk` rows), then calls the
//! microkernel and writes the valid sub-tile of `C` through the
//! per-algorithm epilogue (eq. (6) for the binary kinds, eq. (3)
//! zero-point compensation for U8/U4).
//!
//! Two execution paths share this driver's packing and epilogues:
//! the **emulated** path (instruction-exact NEON sequences from
//! [`crate::gemm::micro`], used for correctness and Table II) and the
//! **native** path ([`crate::gemm::native`], used for Table III wall-clock
//! benchmarks). Both are tested against the scalar oracles.
//!
//! This module is a crate-internal engine: the public entry point is the
//! plan/execute API in [`crate::gemm::plan`], which wraps this driver as
//! [`crate::gemm::Backend::Emulated`].

use crate::gemm::micro;
use crate::gemm::pack;
use crate::gemm::plan::{GemmOut, Lhs};
use crate::gemm::Kind;
use crate::simd::reg::Neon;
use crate::util::mat::{MatF32, MatI32, MatI8, MatU8};

/// Depth-block size for the 16-bit-accumulated low-bit kinds: the paper's
/// k_max = 32767 bounds correctness; we use a cache-friendly block well
/// below it and widen into i32 between blocks, removing the depth limit
/// entirely while keeping in-block arithmetic identical to the paper's.
/// The native path enforces the same bound through
/// [`crate::gemm::native::block::safe_k`] / `KPanel` (a test below pins
/// the two views of the Table II bounds to each other).
pub const K_BLK_LOWBIT: usize = 4096;
/// Depth-block for U4 (16-bit accumulators, k_max = 291 ⇒ largest even
/// block is 290).
pub const K_BLK_U4: usize = 290;
/// Depth-block for U8 (32-bit accumulators, k_max = 66051). The u8
/// driver accumulates a full product in u32 (its shapes stay far below
/// the bound); the constant documents the limit and pins it to the
/// native `safe_k` view in the tests below.
#[cfg_attr(not(test), allow(dead_code))]
pub const K_BLK_U8: usize = 66050;

/// Algorithm selector for [`GemmDriver`]. `Algo` owns the packed right
/// matrix and any constants the epilogue needs.
pub enum Algo {
    /// Binary×binary, paper §III-B.
    Bnn { panels: Vec<Vec<u8>> },
    /// Ternary×ternary, §III-C.
    Tnn { panels: Vec<Vec<u8>> },
    /// Ternary×binary, §III-D.
    Tbn { panels: Vec<Vec<u8>> },
    /// f32 baseline.
    F32 { panels: Vec<Vec<f32>> },
    /// gemmlowp-style u8 with zero points; `col_sums` are precomputed at
    /// pack time for the eq. (3) epilogue.
    U8 { panels: Vec<Vec<u8>>, za: i32, zb: i32, col_sums: Vec<i32> },
    /// 4-bit path with zero points.
    U4 { panels: Vec<Vec<u8>>, za: i32, zb: i32, col_sums: Vec<i32> },
    /// daBNN-style binary (8×6×128 microkernel, f32 output).
    DaBnn { panels: Vec<Vec<u8>> },
}

/// A GEMM engine with a pre-packed right-hand (weight) matrix, following
/// the paper's Algorithm 2.
pub struct GemmDriver {
    pub kind: Kind,
    /// Depth (rows of B).
    pub k: usize,
    /// Width (cols of B).
    pub n: usize,
    algo: Algo,
}

impl GemmDriver {
    /// Pack a binary matrix for the paper's BNN multiplication.
    pub fn new_bnn(b: &MatI8) -> Self {
        assert!(b.is_binary(), "BNN weights must be ±1");
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_bnn(b, cb * 8, b.rows)).collect();
        GemmDriver { kind: Kind::Bnn, k: b.rows, n: b.cols, algo: Algo::Bnn { panels } }
    }

    /// Pack a ternary matrix for the paper's TNN multiplication.
    pub fn new_tnn(b: &MatI8) -> Self {
        assert!(b.is_ternary(), "TNN weights must be in {{-1,0,1}}");
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_tnn(b, cb * 8, b.rows)).collect();
        GemmDriver { kind: Kind::Tnn, k: b.rows, n: b.cols, algo: Algo::Tnn { panels } }
    }

    /// Pack a binary matrix for the paper's TBN multiplication (ternary
    /// activations × binary weights).
    pub fn new_tbn(b: &MatI8) -> Self {
        assert!(b.is_binary(), "TBN weights must be ±1");
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_bnn(b, cb * 8, b.rows)).collect();
        GemmDriver { kind: Kind::Tbn, k: b.rows, n: b.cols, algo: Algo::Tbn { panels } }
    }

    /// Pack an f32 matrix for the baseline multiplication.
    pub fn new_f32(b: &MatF32) -> Self {
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_f32(b, cb * 8, b.rows)).collect();
        GemmDriver { kind: Kind::F32, k: b.rows, n: b.cols, algo: Algo::F32 { panels } }
    }

    /// Pack a u8 matrix with zero points `(za, zb)` for the gemmlowp-style
    /// multiplication. Column sums for eq. (3) are computed here, offline.
    pub fn new_u8(b: &MatU8, za: i32, zb: i32) -> Self {
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_u8(b, cb * 8, b.rows)).collect();
        let col_sums = (0..b.cols).map(|j| (0..b.rows).map(|t| b.get(t, j) as i32).sum()).collect();
        GemmDriver { kind: Kind::U8, k: b.rows, n: b.cols, algo: Algo::U8 { panels, za, zb, col_sums } }
    }

    /// Pack a 4-bit matrix (values 0..=15) with zero points.
    pub fn new_u4(b: &MatU8, za: i32, zb: i32) -> Self {
        assert!(b.data.iter().all(|&v| v < 16), "U4 weights must be 4-bit");
        let panels = (0..b.cols.div_ceil(8)).map(|cb| pack::pack_b_u4(b, cb * 8, b.rows)).collect();
        let col_sums = (0..b.cols).map(|j| (0..b.rows).map(|t| b.get(t, j) as i32).sum()).collect();
        GemmDriver { kind: Kind::U4, k: b.rows, n: b.cols, algo: Algo::U4 { panels, za, zb, col_sums } }
    }

    /// Pack a binary matrix for the daBNN-style multiplication.
    pub fn new_dabnn(b: &MatI8) -> Self {
        assert!(b.is_binary(), "daBNN weights must be ±1");
        let panels = (0..b.cols.div_ceil(6)).map(|cb| pack::pack_b_dabnn(b, cb * 6, b.rows)).collect();
        GemmDriver { kind: Kind::DaBnn, k: b.rows, n: b.cols, algo: Algo::DaBnn { panels } }
    }

    /// Multiply using the **emulated** NEON microkernels. `a` must match
    /// the driver's input type and have `a.cols == self.k`.
    pub fn multiply_emulated(&self, a: Lhs<'_>) -> GemmOut {
        let mut cpu = Neon::new();
        self.multiply_with_cpu(a, &mut cpu)
    }

    /// As [`Self::multiply_emulated`] but with an externally supplied
    /// (e.g. recording) CPU — used by the Table II harness.
    pub fn multiply_with_cpu(&self, a: Lhs<'_>, cpu: &mut Neon) -> GemmOut {
        match (&self.algo, a) {
            (Algo::Bnn { panels }, Lhs::I8(a)) => GemmOut::I32(self.run_bnn(a, panels, cpu)),
            (Algo::Tnn { panels }, Lhs::I8(a)) => GemmOut::I32(self.run_tnn(a, panels, cpu, false)),
            (Algo::Tbn { panels }, Lhs::I8(a)) => GemmOut::I32(self.run_tnn(a, panels, cpu, true)),
            (Algo::F32 { panels }, Lhs::F32(a)) => GemmOut::F32(self.run_f32(a, panels, cpu)),
            (Algo::U8 { panels, za, zb, col_sums }, Lhs::U8(a)) => {
                GemmOut::I32(self.run_u8(a, panels, *za, *zb, col_sums, cpu))
            }
            (Algo::U4 { panels, za, zb, col_sums }, Lhs::U8(a)) => {
                GemmOut::I32(self.run_u4(a, panels, *za, *zb, col_sums, cpu))
            }
            (Algo::DaBnn { panels }, Lhs::I8(a)) => GemmOut::F32(self.run_dabnn(a, panels, cpu)),
            _ => panic!("left-hand matrix type does not match algorithm {:?}", self.kind),
        }
    }

    // ---- per-kind emulated drivers -----------------------------------

    fn run_bnn(&self, a: &MatI8, panels: &[Vec<u8>], cpu: &mut Neon) -> MatI32 {
        assert_eq!(a.cols, self.k);
        assert!(a.is_binary());
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatI32::zeros(m, n);
        let chunks_total = k.div_ceil(8);
        for r0 in (0..m).step_by(16) {
            let pa = pack::pack_a_bnn(a, r0, k);
            let m_eff = (m - r0).min(16);
            for (cb, panel) in panels.iter().enumerate() {
                let n_eff = (n - cb * 8).min(8);
                let tile = micro::bnn_microkernel(cpu, &pa, panel, chunks_total);
                for r in 0..m_eff {
                    for j in 0..n_eff {
                        // eq. (6): C = k − 2·Σ(a⊕b). Depth padding packs
                        // 0-bits on both sides and contributes nothing.
                        c.set(r0 + r, cb * 8 + j, k as i32 - 2 * tile[r * 8 + j] as i32);
                    }
                }
            }
        }
        c
    }

    /// Shared TNN/TBN driver (identical structure; TBN loads binary B).
    fn run_tnn(&self, a: &MatI8, panels: &[Vec<u8>], cpu: &mut Neon, tbn: bool) -> MatI32 {
        assert_eq!(a.cols, self.k);
        assert!(a.is_ternary());
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatI32::zeros(m, n);
        // Depth blocking: in-block accumulation is 16-bit (the paper's
        // scheme, valid to k_max=32767); blocks widen into i32.
        let kb = K_BLK_LOWBIT;
        for d0 in (0..k).step_by(kb) {
            let k_eff = (k - d0).min(kb);
            let a_sub = MatI8::from_fn(m, k_eff, |r, t| a.get(r, d0 + t));
            let chunks = k_eff.div_ceil(8);
            let panel_off = d0 / 8; // panels are chunk-major over full k
            for r0 in (0..m).step_by(16) {
                let pa = pack::pack_a_tnn(&a_sub, r0, k_eff);
                let m_eff = (m - r0).min(16);
                for (cb, panel) in panels.iter().enumerate() {
                    let n_eff = (n - cb * 8).min(8);
                    let stride = if tbn { 8 } else { 16 };
                    let pb = &panel[panel_off * stride..];
                    let tile = if tbn {
                        micro::tbn_microkernel(cpu, &pa, pb, chunks)
                    } else {
                        micro::tnn_microkernel(cpu, &pa, pb, chunks)
                    };
                    for r in 0..m_eff {
                        for j in 0..n_eff {
                            let v = c.get(r0 + r, cb * 8 + j) + tile[r * 8 + j] as i32;
                            c.set(r0 + r, cb * 8 + j, v);
                        }
                    }
                }
            }
        }
        c
    }

    fn run_f32(&self, a: &MatF32, panels: &[Vec<f32>], cpu: &mut Neon) -> MatF32 {
        assert_eq!(a.cols, self.k);
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatF32::zeros(m, n);
        for r0 in (0..m).step_by(12) {
            let pa = pack::pack_a_f32(a, r0, k);
            let m_eff = (m - r0).min(12);
            for (cb, panel) in panels.iter().enumerate() {
                let n_eff = (n - cb * 8).min(8);
                let tile = micro::f32_microkernel(cpu, &pa, panel, k);
                for r in 0..m_eff {
                    for j in 0..n_eff {
                        c.set(r0 + r, cb * 8 + j, tile[r * 8 + j]);
                    }
                }
            }
        }
        c
    }

    fn run_u8(&self, a: &MatU8, panels: &[Vec<u8>], za: i32, zb: i32, col_sums: &[i32], cpu: &mut Neon) -> MatI32 {
        assert_eq!(a.cols, self.k);
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatI32::zeros(m, n);
        let row_sums: Vec<i32> = (0..m).map(|i| (0..k).map(|t| a.get(i, t) as i32).sum()).collect();
        let chunks = k.div_ceil(2);
        for r0 in (0..m).step_by(12) {
            let pa = pack::pack_a_u8(a, r0, k);
            let m_eff = (m - r0).min(12);
            for (cb, panel) in panels.iter().enumerate() {
                let n_eff = (n - cb * 8).min(8);
                let tile = micro::u8_microkernel(cpu, &pa, panel, chunks);
                for r in 0..m_eff {
                    for j in 0..n_eff {
                        // eq. (3) zero-point compensation.
                        let raw = tile[r * 8 + j] as i32;
                        let v = raw - zb * row_sums[r0 + r] - za * col_sums[cb * 8 + j] + k as i32 * za * zb;
                        c.set(r0 + r, cb * 8 + j, v);
                    }
                }
            }
        }
        c
    }

    fn run_u4(&self, a: &MatU8, panels: &[Vec<u8>], za: i32, zb: i32, col_sums: &[i32], cpu: &mut Neon) -> MatI32 {
        assert_eq!(a.cols, self.k);
        assert!(a.data.iter().all(|&v| v < 16), "U4 activations must be 4-bit");
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatI32::zeros(m, n);
        let row_sums: Vec<i32> = (0..m).map(|i| (0..k).map(|t| a.get(i, t) as i32).sum()).collect();
        // eq. (4)/(5): 16-bit accumulators limit in-block depth to 290;
        // the driver widens into i32 between blocks (the scheme of [20]).
        let kb = K_BLK_U4;
        for d0 in (0..k).step_by(kb) {
            let k_eff = (k - d0).min(kb);
            let a_sub = MatU8 {
                rows: m,
                cols: k_eff,
                data: (0..m).flat_map(|r| (0..k_eff).map(move |t| (r, t))).map(|(r, t)| a.get(r, d0 + t)).collect(),
            };
            let chunks = k_eff.div_ceil(2);
            let panel_off = d0 / 2;
            for r0 in (0..m).step_by(24) {
                let pa = pack::pack_a_u4(&a_sub, r0, k_eff);
                let m_eff = (m - r0).min(24);
                for (cb, panel) in panels.iter().enumerate() {
                    let n_eff = (n - cb * 8).min(8);
                    let tile = micro::u4_microkernel(cpu, &pa, &panel[panel_off * 8..], chunks);
                    for r in 0..m_eff {
                        for j in 0..n_eff {
                            let v = c.get(r0 + r, cb * 8 + j) + tile[r * 8 + j] as i32;
                            c.set(r0 + r, cb * 8 + j, v);
                        }
                    }
                }
            }
        }
        // eq. (3) epilogue over the full depth.
        let mut out = MatI32::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let v = c.get(i, j) - zb * row_sums[i] - za * col_sums[j] + k as i32 * za * zb;
                out.set(i, j, v);
            }
        }
        out
    }

    fn run_dabnn(&self, a: &MatI8, panels: &[Vec<u8>], cpu: &mut Neon) -> MatF32 {
        assert_eq!(a.cols, self.k);
        assert!(a.is_binary());
        let (m, n, k) = (a.rows, self.n, self.k);
        let mut c = MatF32::zeros(m, n);
        let chunks = k.div_ceil(128);
        for r0 in (0..m).step_by(8) {
            let pa = pack::pack_a_dabnn(a, r0, k);
            let m_eff = (m - r0).min(8);
            for (cb, panel) in panels.iter().enumerate() {
                let n_eff = (n - cb * 6).min(6);
                let tile = micro::dabnn_microkernel(cpu, &pa, panel, chunks);
                for r in 0..m_eff {
                    for j in 0..n_eff {
                        c.set(r0 + r, cb * 6 + j, (k as i32 - 2 * tile[r * 6 + j] as i32) as f32);
                    }
                }
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::reference;
    use crate::util::proptest::{check, gemm_shape, Config};
    use crate::util::Rng;

    /// Test-side destructuring (the public API's typed accessor is
    /// [`GemmOut::as_i32`]; panicking here is test-failure reporting).
    fn i32_out(out: GemmOut) -> MatI32 {
        match out {
            GemmOut::I32(m) => m,
            GemmOut::F32(_) => panic!("expected i32 output"),
        }
    }

    fn f32_out(out: GemmOut) -> MatF32 {
        match out {
            GemmOut::F32(m) => m,
            GemmOut::I32(_) => panic!("expected f32 output"),
        }
    }

    fn assert_i32_eq(got: &MatI32, want: &MatI32, ctx: &str) {
        assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{ctx}");
        for i in 0..got.rows {
            for j in 0..got.cols {
                assert_eq!(got.get(i, j), want.get(i, j), "{ctx} at ({i},{j})");
            }
        }
    }

    #[test]
    fn bnn_driver_arbitrary_shapes() {
        check(Config { cases: 24, base_seed: 0xB0 }, "bnn driver vs oracle", |rng| {
            let (m, n, k) = gemm_shape(rng, 48, 40, 96);
            let a = MatI8::random_binary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let drv = GemmDriver::new_bnn(&b);
            let c = i32_out(drv.multiply_emulated(Lhs::I8(&a)));
            assert_i32_eq(&c, &reference::gemm_i8(&a, &b), &format!("m={m} n={n} k={k}"));
        });
    }

    #[test]
    fn tnn_driver_arbitrary_shapes() {
        check(Config { cases: 24, base_seed: 0xB1 }, "tnn driver vs oracle", |rng| {
            let (m, n, k) = gemm_shape(rng, 48, 40, 96);
            let a = MatI8::random_ternary(m, k, rng);
            let b = MatI8::random_ternary(k, n, rng);
            let drv = GemmDriver::new_tnn(&b);
            let c = i32_out(drv.multiply_emulated(Lhs::I8(&a)));
            assert_i32_eq(&c, &reference::gemm_i8(&a, &b), &format!("m={m} n={n} k={k}"));
        });
    }

    #[test]
    fn tbn_driver_arbitrary_shapes() {
        check(Config { cases: 24, base_seed: 0xB2 }, "tbn driver vs oracle", |rng| {
            let (m, n, k) = gemm_shape(rng, 48, 40, 96);
            let a = MatI8::random_ternary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let drv = GemmDriver::new_tbn(&b);
            let c = i32_out(drv.multiply_emulated(Lhs::I8(&a)));
            assert_i32_eq(&c, &reference::gemm_i8(&a, &b), &format!("m={m} n={n} k={k}"));
        });
    }

    #[test]
    fn u8_driver_with_zero_points() {
        check(Config { cases: 16, base_seed: 0xB3 }, "u8 driver vs eq(3) oracle", |rng| {
            let (m, n, k) = gemm_shape(rng, 30, 20, 40);
            let a = MatU8::random(m, k, rng);
            let b = MatU8::random(k, n, rng);
            let za = rng.below(256) as i32;
            let zb = rng.below(256) as i32;
            let drv = GemmDriver::new_u8(&b, za, zb);
            let c = i32_out(drv.multiply_emulated(Lhs::U8(&a)));
            assert_i32_eq(&c, &reference::gemm_u8_centered(&a, &b, za, zb), &format!("m={m} n={n} k={k}"));
        });
    }

    #[test]
    fn u4_driver_with_zero_points_and_deep_k() {
        check(Config { cases: 10, base_seed: 0xB4 }, "u4 driver vs eq(3) oracle", |rng| {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(20);
            // Deliberately cross the 290 depth-block boundary.
            let k = 250 + rng.below(200);
            let a = MatU8::random_below(m, k, 15, rng);
            let b = MatU8::random_below(k, n, 15, rng);
            let za = rng.below(16) as i32;
            let zb = rng.below(16) as i32;
            let drv = GemmDriver::new_u4(&b, za, zb);
            let c = i32_out(drv.multiply_emulated(Lhs::U8(&a)));
            assert_i32_eq(&c, &reference::gemm_u8_centered(&a, &b, za, zb), &format!("m={m} n={n} k={k}"));
        });
    }

    #[test]
    fn f32_driver_matches_oracle() {
        let mut rng = Rng::new(0xB5);
        for _ in 0..8 {
            let (m, n, k) = gemm_shape(&mut rng, 40, 30, 64);
            let a = MatF32::random(m, k, &mut rng);
            let b = MatF32::random(k, n, &mut rng);
            let drv = GemmDriver::new_f32(&b);
            let c = f32_out(drv.multiply_emulated(Lhs::F32(&a)));
            let want = reference::gemm_f32(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    let (g, w) = (c.get(i, j), want.get(i, j));
                    assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "({i},{j}): {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn dabnn_driver_matches_oracle() {
        check(Config { cases: 16, base_seed: 0xB6 }, "dabnn driver vs oracle", |rng| {
            let (m, n, k) = gemm_shape(rng, 32, 24, 300);
            let a = MatI8::random_binary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let drv = GemmDriver::new_dabnn(&b);
            let c = f32_out(drv.multiply_emulated(Lhs::I8(&a)));
            let want = reference::gemm_i8(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j}) m={m} n={n} k={k}");
                }
            }
        });
    }

    #[test]
    fn tnn_deep_k_crosses_block_boundary() {
        // k > K_BLK_LOWBIT exercises the i32 widening between blocks.
        let mut rng = Rng::new(0xB7);
        let k = K_BLK_LOWBIT + 100;
        let a = MatI8::random_ternary(4, k, &mut rng);
        let b = MatI8::random_ternary(k, 4, &mut rng);
        let drv = GemmDriver::new_tnn(&b);
        let c = i32_out(drv.multiply_emulated(Lhs::I8(&a)));
        assert_i32_eq(&c, &reference::gemm_i8(&a, &b), "deep k");
    }

    /// The emulated driver's depth blocks and the native path's K-panel
    /// bounds are two views of the same Table II `k_max` limits.
    #[test]
    fn depth_blocks_respect_native_safe_k() {
        use crate::gemm::native::block::safe_k;
        assert!(K_BLK_LOWBIT <= safe_k(Kind::Tnn));
        assert!(K_BLK_U4 < safe_k(Kind::U8)); // U4 u16 bound is far stricter
        assert_eq!(K_BLK_U4 + 1, Kind::U4.k_max().unwrap() as usize);
        assert_eq!(K_BLK_U8 + 1, safe_k(Kind::U8));
    }

    #[test]
    #[should_panic(expected = "BNN weights must be ±1")]
    fn bnn_rejects_ternary_weights() {
        let b = MatI8::zeros(8, 8);
        let _ = GemmDriver::new_bnn(&b);
    }
}
