//! The plan/execute GEMM API: the one public boundary of the matmul core.
//!
//! The paper's Algorithm 2 packs the constant right-hand (weight) matrix
//! **once, offline** and reuses it per multiplication. [`GemmPlan`] is
//! that idea as an API: a [`GemmConfig`] (kind, backend, threading, depth
//! blocking, register tile) plus weights build a plan; [`GemmPlan::run`]
//! then executes `C = A·B` into caller-owned output with **zero per-call
//! heap allocation** on the native hot path and **typed errors**
//! ([`GemmError`]) instead of panics for every contract violation a
//! caller can cause (wrong LHS variant, depth mismatch, wrong output
//! variant, empty dimensions).
//!
//! One plan dispatches all kinds ([`Kind`]) over three backends:
//!
//! * [`Backend::Reference`] — the scalar oracles, computed in place
//!   (allocation-free; the ground truth every other path is tested
//!   against).
//! * [`Backend::Emulated`] — the instruction-exact NEON microkernel
//!   emulation of [`crate::gemm::micro`] (used for Table II; allocates
//!   internally, it is a correctness/tracing path, not a fast path).
//! * [`Backend::Native`] — the blocked, multithreaded wall-clock path of
//!   [`crate::gemm::native`]; LHS packing reuses the caller's
//!   [`GemmScratch`] arena, so steady-state runs perform no heap
//!   allocation.
//!
//! Differential tests and benches become one loop over [`Backend::ALL`]
//! instead of per-kind copy-paste, and a future NEON-intrinsics backend
//! is one new enum arm — not a new API.
//!
//! ```
//! use tbgemm::gemm::{GemmConfig, GemmOut, GemmPlan, GemmScratch, Kind, Lhs, Weights};
//! use tbgemm::util::mat::MatI8;
//!
//! // Weights (k=2, n=2), packed once.
//! let b = MatI8 { rows: 2, cols: 2, data: vec![1, -1, 1, 1] };
//! let plan = GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&b))?;
//!
//! // Run many times into caller-owned output + scratch.
//! let a = MatI8 { rows: 1, cols: 2, data: vec![1, 1] };
//! let (mut out, mut scratch) = (GemmOut::new_i32(), GemmScratch::new());
//! plan.run(Lhs::I8(&a), &mut out, &mut scratch)?;
//! assert_eq!(out.at(0, 0), 2.0); // 1·1 + 1·1
//! assert_eq!(out.at(0, 1), 0.0); // 1·(−1) + 1·1
//! # Ok::<(), tbgemm::gemm::GemmError>(())
//! ```

use crate::gemm::driver::GemmDriver;
use crate::gemm::native::bits::{BitRows, PlaneRows};
use crate::gemm::native::block::{
    bnn_gemm_kp_mt, bnn_gemm_wide_mt, dabnn_gemm_kp_mt, f32_gemm_kp_mt, tbn_gemm_kp_mt, tnn_gemm_kp_mt,
    tnn_gemm_wide_mt, u8_gemm_kp_mt, KPanel, Threading,
};
use crate::gemm::native::kernels::{
    bnn_gemm_rowdot, pack_b_panels_f32, pack_b_panels_u8, tbn_gemm_rowdot, tnn_gemm_rowdot, u4_gemm,
};
use crate::gemm::Kind;
use crate::util::mat::{MatF32, MatI32, MatI8, MatU8};

/// Which implementation executes the multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Scalar oracle loops, computed in place. Ground truth.
    Reference,
    /// Instruction-exact emulated NEON microkernels (Table II substrate).
    Emulated,
    /// Blocked, register-tiled, multithreaded native path (Table III
    /// substrate; the production hot path). Its inner loops are real
    /// NEON `vcnt` kernels on aarch64 and AVX2 nibble-LUT popcounts on
    /// x86-64, with scalar fallback — see
    /// [`crate::gemm::native::simd_popcnt`] for the dispatch order.
    Native,
}

impl Backend {
    /// All backends, for differential sweeps.
    pub const ALL: [Backend; 3] = [Backend::Reference, Backend::Emulated, Backend::Native];
}

/// Register-tile selector for the native backend.
///
/// Ignored by the other backends, and by the native kinds that have a
/// single tile shape (F32, U8, U4, daBNN fall back to [`Tile::Auto`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Tile {
    /// The per-kind default (4×2 BNN/daBNN, 2×2 TNN/TBN, 4×8 F32/U8).
    #[default]
    Auto,
    /// The seed's one-output-at-a-time row-dot kernels (BNN/TNN/TBN
    /// only): the benchmark baseline. Single-threaded, single-panel.
    Rowdot,
    /// Widened register tiles: 4×4 for BNN (each loaded A word feeds 4
    /// columns and each B word 4 rows) and 2×4 for TNN (each loaded A
    /// plane pair feeds 4 columns). Shallow-K only; deep-K products and
    /// the other kinds fall back to [`Tile::Auto`].
    Wide,
    /// Autotuned: [`GemmPlan::run`] resolves the full execution config
    /// (tile, K panels, threading cap) per `(kind, M, N, K)` through
    /// [`crate::tune::resolve`] — the persisted tuning store when
    /// `TBGEMM_TUNE_FILE` names one, the cost-model ranking otherwise.
    /// Native backend only; the other backends treat it as [`Tile::Auto`].
    Tuned,
}

/// Everything that selects *how* a plan multiplies. Packing depends only
/// on `kind` and `backend`; `threading`, `k_panel` and `tile` may be
/// changed after the plan is built ([`GemmPlan::set_threading`] and
/// friends) without repacking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmConfig {
    pub kind: Kind,
    pub backend: Backend,
    /// Row-band worker threads (native backend only).
    pub threading: Threading,
    /// Depth blocking (native backend only; the emulated driver carries
    /// its own fixed depth blocks, the reference oracle needs none).
    pub k_panel: KPanel,
    /// Register tile (native backend only).
    pub tile: Tile,
}

impl GemmConfig {
    /// A config with default execution knobs (single thread, automatic
    /// K panels, per-kind default tile).
    pub fn new(kind: Kind, backend: Backend) -> Self {
        GemmConfig {
            kind,
            backend,
            threading: Threading::Single,
            k_panel: KPanel::Auto,
            tile: Tile::Auto,
        }
    }

    /// Shorthand for [`Backend::Native`].
    pub fn native(kind: Kind) -> Self {
        Self::new(kind, Backend::Native)
    }

    /// Shorthand for [`Backend::Emulated`].
    pub fn emulated(kind: Kind) -> Self {
        Self::new(kind, Backend::Emulated)
    }

    /// Shorthand for [`Backend::Reference`].
    pub fn reference(kind: Kind) -> Self {
        Self::new(kind, Backend::Reference)
    }

    /// An autotuned native config: every run resolves tile / K panels /
    /// threading per shape via [`crate::tune::resolve`] (see
    /// [`Tile::Tuned`]).
    pub fn tuned(kind: Kind) -> Self {
        Self::native(kind).with_tile(Tile::Tuned)
    }

    pub fn with_threading(mut self, threading: Threading) -> Self {
        self.threading = threading;
        self
    }

    pub fn with_k_panel(mut self, k_panel: KPanel) -> Self {
        self.k_panel = k_panel;
        self
    }

    pub fn with_tile(mut self, tile: Tile) -> Self {
        self.tile = tile;
        self
    }
}

/// Typed failure of plan construction or execution. No multiply-path
/// entry point panics on caller input; every contract violation surfaces
/// here.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GemmError {
    /// The weights variant does not fit the configured kind (e.g. f32
    /// weights for a BNN plan).
    WeightsMismatch { kind: Kind, expected: &'static str, got: &'static str },
    /// Weight values outside the kind's domain (BNN/TBN/daBNN: ±1,
    /// TNN: {−1,0,1}, U4: 0..=15).
    WeightDomain { kind: Kind, expected: &'static str },
    /// The left-hand matrix variant does not fit the configured kind.
    LhsMismatch { kind: Kind, expected: &'static str, got: &'static str },
    /// Left-hand values outside the kind's domain. Checked eagerly on
    /// the emulated backend (whose driver would otherwise panic); the
    /// native backend checks the domain in debug builds only.
    LhsDomain { kind: Kind, expected: &'static str },
    /// LHS depth (columns) differs from the packed weights' depth.
    DepthMismatch { expected: usize, got: usize },
    /// The output variant does not fit the kind's result type (i32 for
    /// the integer kinds, f32 for F32/daBNN).
    OutputMismatch { kind: Kind, expected: &'static str, got: &'static str },
    /// A dimension is zero: empty weights at build time (`k`, `n`) or an
    /// empty LHS at run time (`m`).
    EmptyDim { dim: &'static str },
}

impl std::fmt::Display for GemmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GemmError::WeightsMismatch { kind, expected, got } => {
                write!(f, "{} plan expects {expected} weights, got {got}", kind.label())
            }
            GemmError::WeightDomain { kind, expected } => {
                write!(f, "{} weights must be {expected}", kind.label())
            }
            GemmError::LhsMismatch { kind, expected, got } => {
                write!(f, "{} plan expects an {expected} left-hand matrix, got {got}", kind.label())
            }
            GemmError::LhsDomain { kind, expected } => {
                write!(f, "{} left-hand values must be {expected}", kind.label())
            }
            GemmError::DepthMismatch { expected, got } => {
                write!(f, "depth mismatch: plan packed K={expected}, left-hand matrix has K={got}")
            }
            GemmError::OutputMismatch { kind, expected, got } => {
                write!(f, "{} plan produces {expected} output, got a {got} output buffer", kind.label())
            }
            GemmError::EmptyDim { dim } => write!(f, "empty dimension: {dim} = 0"),
        }
    }
}

impl std::error::Error for GemmError {}

/// Left-hand input accepted by [`GemmPlan::run`]: i8 for the low-bit
/// kinds (BNN/TNN/TBN/daBNN), u8 for U8/U4, f32 for the F32 baseline.
#[derive(Clone, Copy)]
pub enum Lhs<'a> {
    I8(&'a MatI8),
    U8(&'a MatU8),
    F32(&'a MatF32),
}

impl Lhs<'_> {
    fn dims(&self) -> (usize, usize) {
        match self {
            Lhs::I8(m) => (m.rows, m.cols),
            Lhs::U8(m) => (m.rows, m.cols),
            Lhs::F32(m) => (m.rows, m.cols),
        }
    }

    fn variant(&self) -> &'static str {
        match self {
            Lhs::I8(_) => "i8",
            Lhs::U8(_) => "u8",
            Lhs::F32(_) => "f32",
        }
    }
}

/// Caller-owned output of a multiplication. The integer kinds produce
/// i32 (widened from the in-kernel 16-bit accumulators); F32 and daBNN
/// produce f32. [`GemmPlan::run`] resizes the buffer in place (steady
/// state: no reallocation once capacity has grown to the largest shape).
#[derive(Clone, Debug)]
pub enum GemmOut {
    I32(MatI32),
    F32(MatF32),
}

impl GemmOut {
    /// An empty i32 output buffer for the integer kinds.
    pub fn new_i32() -> Self {
        GemmOut::I32(MatI32::zeros(0, 0))
    }

    /// An empty f32 output buffer for F32/daBNN.
    pub fn new_f32() -> Self {
        GemmOut::F32(MatF32::zeros(0, 0))
    }

    pub fn rows(&self) -> usize {
        match self {
            GemmOut::I32(m) => m.rows,
            GemmOut::F32(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            GemmOut::I32(m) => m.cols,
            GemmOut::F32(m) => m.cols,
        }
    }

    /// Element as f64 (for cross-path comparisons).
    pub fn at(&self, r: usize, c: usize) -> f64 {
        match self {
            GemmOut::I32(m) => m.get(r, c) as f64,
            GemmOut::F32(m) => m.get(r, c) as f64,
        }
    }

    /// Borrow the i32 matrix, if this is an i32 output.
    pub fn as_i32(&self) -> Option<&MatI32> {
        match self {
            GemmOut::I32(m) => Some(m),
            GemmOut::F32(_) => None,
        }
    }

    /// Borrow the f32 matrix, if this is an f32 output.
    pub fn as_f32(&self) -> Option<&MatF32> {
        match self {
            GemmOut::F32(m) => Some(m),
            GemmOut::I32(_) => None,
        }
    }

    /// Consume into the i32 matrix, if this is an i32 output.
    pub fn into_i32(self) -> Option<MatI32> {
        match self {
            GemmOut::I32(m) => Some(m),
            GemmOut::F32(_) => None,
        }
    }

    /// Consume into the f32 matrix, if this is an f32 output.
    pub fn into_f32(self) -> Option<MatF32> {
        match self {
            GemmOut::F32(m) => Some(m),
            GemmOut::I32(_) => None,
        }
    }

    fn variant(&self) -> &'static str {
        match self {
            GemmOut::I32(_) => "i32",
            GemmOut::F32(_) => "f32",
        }
    }
}

/// Weights handed to [`GemmPlan::new`]: i8 for BNN/TNN/TBN/daBNN, u8
/// with zero points for U8/U4, f32 for the F32 baseline. Borrowed —
/// packing copies what it needs; the caller keeps ownership.
#[derive(Clone, Copy)]
pub enum Weights<'a> {
    I8(&'a MatI8),
    U8 { b: &'a MatU8, za: i32, zb: i32 },
    F32(&'a MatF32),
}

impl Weights<'_> {
    fn dims(&self) -> (usize, usize) {
        match self {
            Weights::I8(m) => (m.rows, m.cols),
            Weights::U8 { b, .. } => (b.rows, b.cols),
            Weights::F32(m) => (m.rows, m.cols),
        }
    }

    fn variant(&self) -> &'static str {
        match self {
            Weights::I8(_) => "i8",
            Weights::U8 { .. } => "u8",
            Weights::F32(_) => "f32",
        }
    }
}

/// Reusable LHS-packing arena shared by every plan a caller runs: packed
/// bit rows (BNN/daBNN) and plane rows (TNN/TBN). Buffers grow on demand
/// and are reused across calls, so steady-state runs perform no heap
/// allocation. `ConvScratch` / `StripeScratch` / `DenseScratch` all embed
/// this one type instead of carrying ad-hoc packing buffers.
pub struct GemmScratch {
    /// Packed binary LHS rows (BNN/daBNN).
    pub bits: BitRows,
    /// Packed ternary LHS planes (TNN/TBN).
    pub planes: PlaneRows,
}

impl GemmScratch {
    pub fn new() -> Self {
        GemmScratch { bits: BitRows::empty(), planes: PlaneRows::empty() }
    }
}

impl Default for GemmScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Packed weights plus epilogue constants, per backend.
enum Packed {
    /// Native bit-columns (BNN/TBN/daBNN weights).
    Bits(BitRows),
    /// Native plane-columns (TNN weights).
    Planes(PlaneRows),
    /// Native f32 8-column panels.
    PanelsF32(Vec<Vec<f32>>),
    /// Native u8 8-column panels + eq. (3) constants (U8/U4).
    PanelsU8 { panels: Vec<Vec<u8>>, col_sums: Vec<i32>, za: i32, zb: i32 },
    /// The emulated driver (owns its own packed panels).
    Emulated(GemmDriver),
    /// Reference copies of the unpacked weights.
    RefI8(MatI8),
    RefU8 { b: MatU8, za: i32, zb: i32 },
    RefF32(MatF32),
}

/// A built-once multiplication plan: packed weights + execution config.
/// See the [module docs](self) for the API story.
pub struct GemmPlan {
    config: GemmConfig,
    /// Depth (rows of B).
    k: usize,
    /// Width (cols of B).
    n: usize,
    packed: Packed,
}

impl GemmPlan {
    /// Pack `weights` for `config`. Fails with a typed [`GemmError`] on a
    /// kind/weights variant mismatch, out-of-domain weight values, or
    /// empty weight dimensions.
    pub fn new(config: GemmConfig, weights: Weights<'_>) -> Result<GemmPlan, GemmError> {
        let kind = config.kind;
        let (k, n) = weights.dims();
        // Variant check first (a structural error beats a size error),
        // then emptiness, then the value-domain scan.
        let expected = match kind {
            Kind::Bnn | Kind::Tnn | Kind::Tbn | Kind::DaBnn => "i8",
            Kind::U8 | Kind::U4 => "u8",
            Kind::F32 => "f32",
        };
        if expected != weights.variant() {
            return Err(GemmError::WeightsMismatch { kind, expected, got: weights.variant() });
        }
        if k == 0 {
            return Err(GemmError::EmptyDim { dim: "k" });
        }
        if n == 0 {
            return Err(GemmError::EmptyDim { dim: "n" });
        }
        let packed = match (kind, &weights) {
            (Kind::Bnn | Kind::Tbn | Kind::DaBnn, Weights::I8(b)) => {
                if !b.is_binary() {
                    return Err(GemmError::WeightDomain { kind, expected: "±1" });
                }
                match config.backend {
                    Backend::Native => Packed::Bits(BitRows::from_binary_transposed(b)),
                    Backend::Emulated => Packed::Emulated(match kind {
                        Kind::Bnn => GemmDriver::new_bnn(b),
                        Kind::Tbn => GemmDriver::new_tbn(b),
                        _ => GemmDriver::new_dabnn(b),
                    }),
                    Backend::Reference => Packed::RefI8((*b).clone()),
                }
            }
            (Kind::Tnn, Weights::I8(b)) => {
                if !b.is_ternary() {
                    return Err(GemmError::WeightDomain { kind, expected: "in {-1, 0, 1}" });
                }
                match config.backend {
                    Backend::Native => Packed::Planes(PlaneRows::from_ternary_transposed(b)),
                    Backend::Emulated => Packed::Emulated(GemmDriver::new_tnn(b)),
                    Backend::Reference => Packed::RefI8((*b).clone()),
                }
            }
            (Kind::U8 | Kind::U4, Weights::U8 { b, za, zb }) => {
                if kind == Kind::U4 && !b.data.iter().all(|&v| v < 16) {
                    return Err(GemmError::WeightDomain { kind, expected: "4-bit (0..=15)" });
                }
                match config.backend {
                    Backend::Native => {
                        let col_sums =
                            (0..b.cols).map(|j| (0..b.rows).map(|t| b.get(t, j) as i32).sum()).collect();
                        Packed::PanelsU8 { panels: pack_b_panels_u8(b), col_sums, za: *za, zb: *zb }
                    }
                    Backend::Emulated => Packed::Emulated(if kind == Kind::U8 {
                        GemmDriver::new_u8(b, *za, *zb)
                    } else {
                        GemmDriver::new_u4(b, *za, *zb)
                    }),
                    Backend::Reference => Packed::RefU8 { b: (*b).clone(), za: *za, zb: *zb },
                }
            }
            (Kind::F32, Weights::F32(b)) => match config.backend {
                Backend::Native => Packed::PanelsF32(pack_b_panels_f32(b)),
                Backend::Emulated => Packed::Emulated(GemmDriver::new_f32(b)),
                Backend::Reference => Packed::RefF32((*b).clone()),
            },
            // The variant check above makes this unreachable; stay
            // total (and panic-free) regardless.
            _ => return Err(GemmError::WeightsMismatch { kind, expected, got: weights.variant() }),
        };
        Ok(GemmPlan { config, k, n, packed })
    }

    /// The plan's execution config.
    pub fn config(&self) -> GemmConfig {
        self.config
    }

    pub fn kind(&self) -> Kind {
        self.config.kind
    }

    pub fn backend(&self) -> Backend {
        self.config.backend
    }

    /// Packed depth (rows of B; LHS must have this many columns).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Packed width (cols of B and of the output).
    pub fn n(&self) -> usize {
        self.n
    }

    /// True when this plan produces f32 output (F32 and daBNN kinds).
    pub fn output_is_f32(&self) -> bool {
        matches!(self.config.kind, Kind::F32 | Kind::DaBnn)
    }

    /// Change the worker-thread config without repacking.
    pub fn set_threading(&mut self, threading: Threading) {
        self.config.threading = threading;
    }

    /// Change the depth-blocking config without repacking.
    pub fn set_k_panel(&mut self, k_panel: KPanel) {
        self.config.k_panel = k_panel;
    }

    /// Change the register-tile config without repacking.
    pub fn set_tile(&mut self, tile: Tile) {
        self.config.tile = tile;
    }

    /// Execute `C = A·B` into `out`, packing the LHS into `scratch`.
    ///
    /// `out` is resized to `m × n` in place (reusing its buffer — steady
    /// state reallocates nothing); `scratch` is only touched by the
    /// native low-bit kinds. Value domains of the LHS (±1 / ternary /
    /// 4-bit) are the caller's contract, checked in debug builds.
    pub fn run(&self, lhs: Lhs<'_>, out: &mut GemmOut, scratch: &mut GemmScratch) -> Result<(), GemmError> {
        let kind = self.config.kind;
        let expected_lhs = match kind {
            Kind::Bnn | Kind::Tnn | Kind::Tbn | Kind::DaBnn => "i8",
            Kind::U8 | Kind::U4 => "u8",
            Kind::F32 => "f32",
        };
        if expected_lhs != lhs.variant() {
            return Err(GemmError::LhsMismatch { kind, expected: expected_lhs, got: lhs.variant() });
        }
        let (m, lk) = lhs.dims();
        if lk != self.k {
            return Err(GemmError::DepthMismatch { expected: self.k, got: lk });
        }
        if m == 0 {
            return Err(GemmError::EmptyDim { dim: "m" });
        }
        let expected_out = if self.output_is_f32() { "f32" } else { "i32" };
        if expected_out != out.variant() {
            return Err(GemmError::OutputMismatch { kind, expected: expected_out, got: out.variant() });
        }
        // Size the caller-owned output in place (no realloc once capacity
        // has grown to the largest shape seen).
        match out {
            GemmOut::I32(c) => {
                c.rows = m;
                c.cols = self.n;
                c.data.clear();
                c.data.resize(m * self.n, 0);
            }
            GemmOut::F32(c) => {
                c.rows = m;
                c.cols = self.n;
                c.data.clear();
                c.data.resize(m * self.n, 0.0);
            }
        }
        // `Tile::Tuned` is a resolution request, not a kernel: look up
        // the full execution config for this shape (tuning store, then
        // cost-model ranking) and run that. `NetPlan::build` resolves at
        // build time instead, where the per-layer shapes are static.
        let (tile, threading, k_panel) = if self.config.tile == Tile::Tuned && self.config.backend == Backend::Native
        {
            let choice = crate::tune::resolve(kind, (m, self.n, self.k));
            (choice.tile, choice.threading, choice.k_panel)
        } else {
            (self.config.tile, self.config.threading, self.config.k_panel)
        };
        match (&self.packed, lhs, &mut *out) {
            // ---- native backend --------------------------------------
            (Packed::Bits(bt), Lhs::I8(a), GemmOut::I32(c)) if kind == Kind::Bnn => {
                debug_assert!(a.is_binary());
                scratch.bits.repack_binary(a);
                match tile {
                    Tile::Rowdot => bnn_gemm_rowdot(&scratch.bits, bt, c),
                    Tile::Wide => bnn_gemm_wide_mt(&scratch.bits, bt, c, threading, k_panel),
                    _ => bnn_gemm_kp_mt(&scratch.bits, bt, c, threading, k_panel),
                }
            }
            (Packed::Planes(bt), Lhs::I8(a), GemmOut::I32(c)) => {
                debug_assert!(a.is_ternary());
                scratch.planes.repack_ternary(a);
                match tile {
                    Tile::Rowdot => tnn_gemm_rowdot(&scratch.planes, bt, c),
                    Tile::Wide => tnn_gemm_wide_mt(&scratch.planes, bt, c, threading, k_panel),
                    _ => tnn_gemm_kp_mt(&scratch.planes, bt, c, threading, k_panel),
                }
            }
            (Packed::Bits(bt), Lhs::I8(a), GemmOut::I32(c)) => {
                // Tbn: ternary activations against binary bit-columns.
                debug_assert!(a.is_ternary());
                scratch.planes.repack_ternary(a);
                match tile {
                    Tile::Rowdot => tbn_gemm_rowdot(&scratch.planes, bt, c),
                    _ => tbn_gemm_kp_mt(&scratch.planes, bt, c, threading, k_panel),
                }
            }
            (Packed::Bits(bt), Lhs::I8(a), GemmOut::F32(c)) => {
                // DaBnn (the only f32-output bit kind).
                debug_assert!(a.is_binary());
                scratch.bits.repack_binary(a);
                dabnn_gemm_kp_mt(&scratch.bits, bt, c, threading, k_panel);
            }
            (Packed::PanelsF32(panels), Lhs::F32(a), GemmOut::F32(c)) => {
                f32_gemm_kp_mt(a, panels, self.n, c, threading, k_panel);
            }
            (Packed::PanelsU8 { panels, col_sums, za, zb }, Lhs::U8(a), GemmOut::I32(c)) => {
                if kind == Kind::U4 {
                    // U4 carries its own fixed 16-bit-safe depth blocks
                    // (eq. (4): ≤290) and is single-threaded; the
                    // threading / k_panel knobs do not apply.
                    debug_assert!(a.data.iter().all(|&v| v < 16));
                    u4_gemm(a, panels, self.n, *za, *zb, col_sums, c);
                } else {
                    u8_gemm_kp_mt(a, panels, self.n, *za, *zb, col_sums, c, threading, k_panel);
                }
            }
            // ---- emulated backend ------------------------------------
            (Packed::Emulated(driver), lhs, out) => {
                // Correctness/tracing path: the microkernel emulation
                // allocates internally; copy its result into the
                // caller-owned buffer. Its per-kind drivers assert value
                // domains, so check them here and fail typed instead.
                match (kind, &lhs) {
                    (Kind::Bnn | Kind::DaBnn, Lhs::I8(a)) if !a.is_binary() => {
                        return Err(GemmError::LhsDomain { kind, expected: "±1" })
                    }
                    (Kind::Tnn | Kind::Tbn, Lhs::I8(a)) if !a.is_ternary() => {
                        return Err(GemmError::LhsDomain { kind, expected: "in {-1, 0, 1}" })
                    }
                    (Kind::U4, Lhs::U8(a)) if !a.data.iter().all(|&v| v < 16) => {
                        return Err(GemmError::LhsDomain { kind, expected: "4-bit (0..=15)" })
                    }
                    _ => {}
                }
                let res = driver.multiply_emulated(lhs);
                match (res, out) {
                    (GemmOut::I32(r), GemmOut::I32(c)) => c.data.copy_from_slice(&r.data),
                    (GemmOut::F32(r), GemmOut::F32(c)) => c.data.copy_from_slice(&r.data),
                    // Output variant was validated above.
                    (_, out) => {
                        return Err(GemmError::OutputMismatch {
                            kind,
                            expected: expected_out,
                            got: out.variant(),
                        })
                    }
                }
            }
            // ---- reference backend (in place, allocation-free) -------
            (Packed::RefI8(b), Lhs::I8(a), GemmOut::I32(c)) => {
                for i in 0..m {
                    for j in 0..self.n {
                        let mut acc = 0i32;
                        for t in 0..self.k {
                            acc += a.get(i, t) as i32 * b.get(t, j) as i32;
                        }
                        c.set(i, j, acc);
                    }
                }
            }
            (Packed::RefI8(b), Lhs::I8(a), GemmOut::F32(c)) => {
                // DaBnn reference: the popcount form is exactly the
                // integer dot product, emitted as f32.
                for i in 0..m {
                    for j in 0..self.n {
                        let mut acc = 0i32;
                        for t in 0..self.k {
                            acc += a.get(i, t) as i32 * b.get(t, j) as i32;
                        }
                        c.set(i, j, acc as f32);
                    }
                }
            }
            (Packed::RefU8 { b, za, zb }, Lhs::U8(a), GemmOut::I32(c)) => {
                // The centered form of eq. (3), the U8/U4 ground truth.
                for i in 0..m {
                    for j in 0..self.n {
                        let mut acc = 0i32;
                        for t in 0..self.k {
                            acc += (a.get(i, t) as i32 - za) * (b.get(t, j) as i32 - zb);
                        }
                        c.set(i, j, acc);
                    }
                }
            }
            (Packed::RefF32(b), Lhs::F32(a), GemmOut::F32(c)) => {
                for i in 0..m {
                    for j in 0..self.n {
                        let mut acc = 0f32;
                        for t in 0..self.k {
                            acc += a.get(i, t) * b.get(t, j);
                        }
                        c.set(i, j, acc);
                    }
                }
            }
            // The variant checks above make this unreachable; stay total
            // (and panic-free) regardless.
            (_, lhs, _) => {
                return Err(GemmError::LhsMismatch { kind, expected: expected_lhs, got: lhs.variant() })
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::reference;
    use crate::util::Rng;

    fn run_native(kind: Kind, a: &MatI8, b: &MatI8) -> GemmOut {
        let plan = GemmPlan::new(GemmConfig::native(kind), Weights::I8(b)).expect("plan");
        let mut out = if plan.output_is_f32() { GemmOut::new_f32() } else { GemmOut::new_i32() };
        let mut scratch = GemmScratch::new();
        plan.run(Lhs::I8(a), &mut out, &mut scratch).expect("run");
        out
    }

    #[test]
    fn all_backends_agree_on_bnn() {
        let mut rng = Rng::new(0x9A1);
        let a = MatI8::random_binary(9, 70, &mut rng);
        let b = MatI8::random_binary(70, 5, &mut rng);
        let want = reference::gemm_i8(&a, &b);
        for backend in Backend::ALL {
            let plan = GemmPlan::new(GemmConfig::new(Kind::Bnn, backend), Weights::I8(&b)).expect("plan");
            let mut out = GemmOut::new_i32();
            let mut scratch = GemmScratch::new();
            plan.run(Lhs::I8(&a), &mut out, &mut scratch).expect("run");
            assert_eq!(out.as_i32().unwrap().data, want.data, "{backend:?}");
        }
    }

    #[test]
    fn native_tnn_and_tbn_match_oracle() {
        let mut rng = Rng::new(0x9A2);
        let at = MatI8::random_ternary(7, 130, &mut rng);
        let btern = MatI8::random_ternary(130, 6, &mut rng);
        let bbin = MatI8::random_binary(130, 6, &mut rng);
        let out = run_native(Kind::Tnn, &at, &btern);
        assert_eq!(out.as_i32().unwrap().data, reference::gemm_i8(&at, &btern).data);
        let out = run_native(Kind::Tbn, &at, &bbin);
        assert_eq!(out.as_i32().unwrap().data, reference::gemm_i8(&at, &bbin).data);
    }

    #[test]
    fn dabnn_produces_f32_equal_to_integer_oracle() {
        let mut rng = Rng::new(0x9A3);
        let a = MatI8::random_binary(5, 200, &mut rng);
        let b = MatI8::random_binary(200, 4, &mut rng);
        let want = reference::gemm_i8(&a, &b);
        let out = run_native(Kind::DaBnn, &a, &b);
        let c = out.as_f32().unwrap();
        for i in 0..5 {
            for j in 0..4 {
                assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn error_cases_are_typed() {
        let mut rng = Rng::new(0x9A4);
        let b = MatI8::random_binary(16, 4, &mut rng);
        let plan = GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&b)).expect("plan");
        let mut scratch = GemmScratch::new();

        // Wrong LHS variant.
        let au8 = MatU8::random(2, 16, &mut rng);
        let mut out = GemmOut::new_i32();
        assert_eq!(
            plan.run(Lhs::U8(&au8), &mut out, &mut scratch),
            Err(GemmError::LhsMismatch { kind: Kind::Bnn, expected: "i8", got: "u8" })
        );
        // Depth mismatch.
        let a = MatI8::random_binary(2, 8, &mut rng);
        assert_eq!(
            plan.run(Lhs::I8(&a), &mut out, &mut scratch),
            Err(GemmError::DepthMismatch { expected: 16, got: 8 })
        );
        // Wrong output variant.
        let a = MatI8::random_binary(2, 16, &mut rng);
        let mut fout = GemmOut::new_f32();
        assert_eq!(
            plan.run(Lhs::I8(&a), &mut fout, &mut scratch),
            Err(GemmError::OutputMismatch { kind: Kind::Bnn, expected: "i32", got: "f32" })
        );
        // Empty LHS.
        let empty = MatI8::zeros(0, 16);
        assert_eq!(
            plan.run(Lhs::I8(&empty), &mut out, &mut scratch),
            Err(GemmError::EmptyDim { dim: "m" })
        );
        // Build-time: weights variant, domain, empty dims.
        let f = MatF32::zeros(4, 4);
        assert!(matches!(
            GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::F32(&f)),
            Err(GemmError::WeightsMismatch { .. })
        ));
        let tern = MatI8::zeros(4, 4); // zeros are not ±1
        assert!(matches!(
            GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&tern)),
            Err(GemmError::WeightDomain { .. })
        ));
        assert_eq!(
            GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&MatI8::zeros(0, 4))).err(),
            Some(GemmError::EmptyDim { dim: "k" })
        );
        assert_eq!(
            GemmPlan::new(GemmConfig::native(Kind::Bnn), Weights::I8(&MatI8::zeros(16, 0))).err(),
            Some(GemmError::EmptyDim { dim: "n" })
        );
    }

    #[test]
    fn u4_weight_domain_is_checked() {
        let b = MatU8 { rows: 2, cols: 2, data: vec![3, 16, 0, 1] };
        assert!(matches!(
            GemmPlan::new(GemmConfig::native(Kind::U4), Weights::U8 { b: &b, za: 0, zb: 0 }),
            Err(GemmError::WeightDomain { .. })
        ));
    }

    #[test]
    fn error_display_is_informative() {
        let e = GemmError::DepthMismatch { expected: 64, got: 32 };
        assert!(e.to_string().contains("K=64"));
        let e = GemmError::WeightDomain { kind: Kind::Bnn, expected: "±1" };
        assert_eq!(e.to_string(), "BNN weights must be ±1");
    }
}
