//! Naive scalar GEMM oracles. Every packed microkernel and every native
//! fast path in this crate is tested against these.

use crate::util::mat::{MatF32, MatI32, MatI8, MatU8};

/// `C = A·B` over i8 matrices (binary/ternary values), i32 output.
pub fn gemm_i8(a: &MatI8, b: &MatI8) -> MatI32 {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for t in 0..k {
                acc += a.get(i, t) as i32 * b.get(t, j) as i32;
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// `C = A·B` over f32 matrices.
pub fn gemm_f32(a: &MatF32, b: &MatF32) -> MatF32 {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatF32::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for t in 0..k {
                acc += a.get(i, t) * b.get(t, j);
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Raw unsigned product `C = Â·B̂` over u8 matrices (before zero-point
/// compensation), i32 output.
pub fn gemm_u8_raw(a: &MatU8, b: &MatU8) -> MatI32 {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for t in 0..k {
                acc += a.get(i, t) as i32 * b.get(t, j) as i32;
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Zero-point-compensated integer product, the paper's eq. (3):
/// `C̃ᵢⱼ = Σ ÂᵢₜB̂ₜⱼ − z_B Σ Âᵢₜ − z_A Σ B̂ₜⱼ + k·z_A·z_B`.
pub fn gemm_u8_zp(a: &MatU8, b: &MatU8, za: i32, zb: i32) -> MatI32 {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let raw = gemm_u8_raw(a, b);
    let mut c = MatI32::zeros(m, n);
    let row_sums: Vec<i32> = (0..m).map(|i| (0..k).map(|t| a.get(i, t) as i32).sum()).collect();
    let col_sums: Vec<i32> = (0..n).map(|j| (0..k).map(|t| b.get(t, j) as i32).sum()).collect();
    for i in 0..m {
        for j in 0..n {
            c.set(i, j, raw.get(i, j) - zb * row_sums[i] - za * col_sums[j] + k as i32 * za * zb);
        }
    }
    c
}

/// Direct computation of `Σ (Âᵢₜ − z_A)(B̂ₜⱼ − z_B)` — used to validate
/// that eq. (3) is an identity.
pub fn gemm_u8_centered(a: &MatU8, b: &MatU8, za: i32, zb: i32) -> MatI32 {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for t in 0..k {
                acc += (a.get(i, t) as i32 - za) * (b.get(t, j) as i32 - zb);
            }
            c.set(i, j, acc);
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gemm_i8_hand_example() {
        // [[1,-1],[0,1]] · [[1,1],[-1,0]] = [[2,1],[-1,0]]
        let a = MatI8 { rows: 2, cols: 2, data: vec![1, -1, 0, 1] };
        let b = MatI8 { rows: 2, cols: 2, data: vec![1, 1, -1, 0] };
        let c = gemm_i8(&a, &b);
        assert_eq!(c.data, vec![2, 1, -1, 0]);
    }

    #[test]
    fn eq3_is_an_identity() {
        let mut rng = Rng::new(123);
        for _ in 0..20 {
            let m = 1 + rng.below(8);
            let k = 1 + rng.below(16);
            let n = 1 + rng.below(8);
            let a = MatU8::random(m, k, &mut rng);
            let b = MatU8::random(k, n, &mut rng);
            let za = rng.below(256) as i32;
            let zb = rng.below(256) as i32;
            assert_eq!(gemm_u8_zp(&a, &b, za, zb).data, gemm_u8_centered(&a, &b, za, zb).data);
        }
    }

    #[test]
    fn gemm_f32_identity_matrix() {
        let mut rng = Rng::new(4);
        let a = MatF32::random(5, 5, &mut rng);
        let eye = MatF32::from_fn(5, 5, |r, c| if r == c { 1.0 } else { 0.0 });
        let c = gemm_f32(&a, &eye);
        for i in 0..25 {
            assert!((c.data[i] - a.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn ternary_times_zero_is_zero() {
        let mut rng = Rng::new(5);
        let a = MatI8::random_ternary(4, 9, &mut rng);
        let b = MatI8::zeros(9, 3);
        assert!(gemm_i8(&a, &b).data.iter().all(|&v| v == 0));
    }
}
