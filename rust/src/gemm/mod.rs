//! The paper's contribution: bit-packed GEMM for binary (BNN), ternary
//! (TNN) and ternary-binary (TBN) matrices, plus the four baselines it is
//! evaluated against (F32, U8/gemmlowp-style, U4, daBNN-style binary).
//!
//! Layout of the module:
//!
//! * [`plan`] — **the public boundary**: the plan/execute API
//!   ([`GemmPlan`], [`GemmConfig`], [`Backend`]) — weights are packed
//!   once into a plan, which then runs any number of multiplications
//!   into caller-owned output with typed errors ([`GemmError`]).
//! * [`encode`] — the paper's §III-A: 1-bit binary and 2-bit ternary value
//!   encodings and the Boolean product formulas of Table I.
//! * [`pack`] — §III-B/C/D: the `Ablock` / `Bblock` storage orders each
//!   microkernel consumes (and the baselines' panel packing).
//! * [`micro`] — the microkernels as emulated-NEON instruction sequences
//!   (Figs. 1-3), traced for Table II.
//! * [`native`] — portable fast paths (u64 bit-ops) implementing the same
//!   algorithms for wall-clock benchmarks (Table III); dispatched as
//!   [`Backend::Native`].
//! * `driver` (crate-internal) — the paper's Algorithm 2 over the
//!   emulated microkernels; dispatched as [`Backend::Emulated`].
//! * [`reference`] — naive scalar oracles every path is tested against;
//!   dispatched as [`Backend::Reference`].

pub(crate) mod driver;
pub mod encode;
pub mod micro;
pub mod native;
pub mod pack;
pub mod plan;
pub mod reference;

pub use native::{safe_k, KPanel, Threading};
pub use plan::{Backend, GemmConfig, GemmError, GemmOut, GemmPlan, GemmScratch, Lhs, Tile, Weights};

/// The three low-bit multiplications the paper proposes plus the four
/// baselines it compares against (Table II / Table III row order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kind {
    F32,
    U8,
    U4,
    Tnn,
    Tbn,
    Bnn,
    DaBnn,
}

impl Kind {
    /// All kinds in the paper's table order.
    pub const ALL: [Kind; 7] = [Kind::F32, Kind::U8, Kind::U4, Kind::Tnn, Kind::Tbn, Kind::Bnn, Kind::DaBnn];

    /// Paper's label for the algorithm.
    pub fn label(self) -> &'static str {
        match self {
            Kind::F32 => "F32",
            Kind::U8 => "U8",
            Kind::U4 => "U4",
            Kind::Tnn => "TNN",
            Kind::Tbn => "TBN",
            Kind::Bnn => "BNN",
            Kind::DaBnn => "daBNN",
        }
    }

    /// Microkernel shape `(m_mk, n_mk, k_step)` — Table II's `m×n×k`.
    pub fn micro_shape(self) -> (usize, usize, usize) {
        match self {
            Kind::F32 => (12, 8, 1),
            Kind::U8 => (12, 8, 2),
            Kind::U4 => (24, 8, 2),
            Kind::Tnn => (16, 8, 8),
            Kind::Tbn => (16, 8, 8),
            Kind::Bnn => (16, 8, 8),
            Kind::DaBnn => (8, 6, 128),
        }
    }

    /// Maximum depth that guarantees no accumulator overflow — Table II's
    /// `k_max` (eq. (4) for the quantized kinds; register width for the
    /// low-bit kinds; f32 significand for daBNN).
    pub fn k_max(self) -> Option<u64> {
        match self {
            Kind::F32 => None,
            // (2^32 - 1) / 255^2
            Kind::U8 => Some((u32::MAX as u64) / (255 * 255)),
            // (2^16 - 1) / 15^2
            Kind::U4 => Some((u16::MAX as u64) / (15 * 15)),
            // |z| <= 1 accumulated in signed 16-bit
            Kind::Tnn | Kind::Tbn | Kind::Bnn => Some((1u64 << 15) - 1),
            // f32 significand: integers up to 2^23 are exact
            Kind::DaBnn => Some((1u64 << 23) - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_max_matches_paper_table2() {
        assert_eq!(Kind::U8.k_max(), Some(66051));
        assert_eq!(Kind::U4.k_max(), Some(291));
        assert_eq!(Kind::Tnn.k_max(), Some(32767));
        assert_eq!(Kind::Tbn.k_max(), Some(32767));
        assert_eq!(Kind::Bnn.k_max(), Some(32767));
        assert_eq!(Kind::DaBnn.k_max(), Some(8_388_607));
        assert_eq!(Kind::F32.k_max(), None);
    }

    #[test]
    fn micro_shapes_match_paper_table2() {
        assert_eq!(Kind::F32.micro_shape(), (12, 8, 1));
        assert_eq!(Kind::U8.micro_shape(), (12, 8, 2));
        assert_eq!(Kind::U4.micro_shape(), (24, 8, 2));
        assert_eq!(Kind::Tnn.micro_shape(), (16, 8, 8));
        assert_eq!(Kind::Tbn.micro_shape(), (16, 8, 8));
        assert_eq!(Kind::Bnn.micro_shape(), (16, 8, 8));
        assert_eq!(Kind::DaBnn.micro_shape(), (8, 6, 128));
    }
}
