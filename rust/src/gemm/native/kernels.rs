//! Native GEMM kernels (wall-clock path). Each function mirrors one of
//! the seven algorithms; all are tested against the scalar oracles and
//! against the emulated drivers.
//!
//! Hot-loop conventions (see [`crate::gemm::native`] module docs for the
//! full hierarchy): the right matrix is pre-packed (transposed,
//! bit-packed where applicable) — the "PackedB packed once, offline" rule
//! of Algorithm 2 — and the inner loops compute R×C *register tiles* of
//! output (4×2 for BNN/daBNN, 2×2 for TNN/TBN, 4×8 for F32/U8) with all
//! accumulators live in registers, so each packed A-row word is loaded
//! once per C columns and each B word once per R rows instead of once per
//! output element. Around the tiles, the column loop is cache-blocked
//! into L1-sized B panels ([`blocks`]/[`n_panel`] in
//! [`crate::gemm::native::block`]) so a panel of B stays hot across the
//! whole row loop.
//!
//! Every kernel also has a band form (`*_band`, crate-private) computing
//! rows `row0..row0+rows` into a caller-provided output slice; the
//! multithreaded drivers in [`crate::gemm::native::block`] split C into
//! disjoint row bands and run the band kernels in parallel.
//!
//! The seed's single-row "row-dot" kernels are preserved as
//! `*_gemm_rowdot` — they remain the differential baseline and the
//! reference point for the tiling speedup tracked by `benches/gemm_micro`.

use crate::gemm::native::bits::{BitRows, PlaneRows};
use crate::gemm::native::block::{blocks, n_panel};
use crate::gemm::native::simd_popcnt::{
    tbn_popcnt, tbn_popcnt_2x2, tnn_popcnt, tnn_popcnt_2x2, tnn_popcnt_2x4, xor_popcnt, xor_popcnt2,
    xor_popcnt_4x2, xor_popcnt_4x4,
};
use crate::util::mat::{MatF32, MatI32, MatU8};

// -------------------------------------------------------------------
// BNN: C = k − 2·popcount(a ⊕ b)
// -------------------------------------------------------------------

/// Binary GEMM. `a` holds bit rows of A, `bt` bit rows of Bᵀ.
/// Register-tiled (4 A-rows × 2 B-columns) with L1-blocked B panels.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn bnn_gemm(a: &BitRows, bt: &BitRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    bnn_band(a, bt, 0, a.rows, &mut c.data);
}

/// Rows `row0..row0+rows` of the BNN product into `band` (`rows × n`).
pub(crate) fn bnn_band(a: &BitRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [i32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let k = a.k as i32;
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 1)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 4 <= rows {
            let ar = [a.row(row0 + i), a.row(row0 + i + 1), a.row(row0 + i + 2), a.row(row0 + i + 3)];
            let mut j = j0;
            while j + 2 <= jend {
                let s = xor_popcnt_4x2(ar, bt.row(j), bt.row(j + 1));
                for (r, sr) in s.iter().enumerate() {
                    band[(i + r) * n + j] = k - 2 * sr[0] as i32;
                    band[(i + r) * n + j + 1] = k - 2 * sr[1] as i32;
                }
                j += 2;
            }
            if j < jend {
                for (r, arr) in ar.iter().enumerate() {
                    band[(i + r) * n + j] = k - 2 * xor_popcnt(arr, bt.row(j)) as i32;
                }
            }
            i += 4;
        }
        // Remainder rows (< 4): the 2-column row-dot path.
        while i < rows {
            let arr = a.row(row0 + i);
            let mut j = j0;
            while j + 2 <= jend {
                let (s0, s1) = xor_popcnt2(arr, bt.row(j), bt.row(j + 1));
                band[i * n + j] = k - 2 * s0 as i32;
                band[i * n + j + 1] = k - 2 * s1 as i32;
                j += 2;
            }
            if j < jend {
                band[i * n + j] = k - 2 * xor_popcnt(arr, bt.row(j)) as i32;
            }
            i += 1;
        }
    }
}

/// Rows `row0..row0+rows` of the BNN product into `band` with the
/// widened 4×4 register tile ([`crate::gemm::plan::Tile::Wide`]): each
/// loaded A word feeds 4 B columns and each B word 4 A rows, halving the
/// loads-per-output of the 4×2 tile on wide outputs. Column remainders
/// fall back to the 4×2 / 2×1 paths and row remainders to the row-dot
/// path, so results are bit-identical to [`bnn_band`] (integer popcount
/// sums regroup freely).
pub(crate) fn bnn_band_wide(a: &BitRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [i32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let k = a.k as i32;
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 1)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 4 <= rows {
            let ar = [a.row(row0 + i), a.row(row0 + i + 1), a.row(row0 + i + 2), a.row(row0 + i + 3)];
            let mut j = j0;
            while j + 4 <= jend {
                let s = xor_popcnt_4x4(ar, [bt.row(j), bt.row(j + 1), bt.row(j + 2), bt.row(j + 3)]);
                for (r, sr) in s.iter().enumerate() {
                    for (c, &v) in sr.iter().enumerate() {
                        band[(i + r) * n + j + c] = k - 2 * v as i32;
                    }
                }
                j += 4;
            }
            while j + 2 <= jend {
                let s = xor_popcnt_4x2(ar, bt.row(j), bt.row(j + 1));
                for (r, sr) in s.iter().enumerate() {
                    band[(i + r) * n + j] = k - 2 * sr[0] as i32;
                    band[(i + r) * n + j + 1] = k - 2 * sr[1] as i32;
                }
                j += 2;
            }
            if j < jend {
                for (r, arr) in ar.iter().enumerate() {
                    band[(i + r) * n + j] = k - 2 * xor_popcnt(arr, bt.row(j)) as i32;
                }
            }
            i += 4;
        }
        // Remainder rows (< 4): the 2-column row-dot path.
        while i < rows {
            let arr = a.row(row0 + i);
            let mut j = j0;
            while j + 2 <= jend {
                let (s0, s1) = xor_popcnt2(arr, bt.row(j), bt.row(j + 1));
                band[i * n + j] = k - 2 * s0 as i32;
                band[i * n + j + 1] = k - 2 * s1 as i32;
                j += 2;
            }
            if j < jend {
                band[i * n + j] = k - 2 * xor_popcnt(arr, bt.row(j)) as i32;
            }
            i += 1;
        }
    }
}

/// Rows `row0..row0+rows` of the BNN product into `band`, computed in
/// K panels of `kp_words` u64 words each. Within a panel the register
/// tiles accumulate raw popcounts (the sums that must fit the paper's
/// 16-bit accumulators, eq. (4)); between panels the partials spill into
/// the i32 `band`, and the eq. (6) epilogue `k − 2·s` runs once at the
/// end over the full-depth sums. Bit-identical to [`bnn_band`] because
/// popcount partial sums are exact integers under any regrouping.
pub(crate) fn bnn_band_kp(a: &BitRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [i32], kp_words: usize) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let w = a.words_per_row;
    let kp = kp_words.max(1);
    band.fill(0);
    for (j0, jn) in blocks(n, n_panel(kp.min(w.max(1)), 1)) {
        let jend = j0 + jn;
        for (w0, wn) in blocks(w, kp) {
            let mut i = 0;
            while i + 4 <= rows {
                let ar = [
                    a.row_window(row0 + i, w0, wn),
                    a.row_window(row0 + i + 1, w0, wn),
                    a.row_window(row0 + i + 2, w0, wn),
                    a.row_window(row0 + i + 3, w0, wn),
                ];
                let mut j = j0;
                while j + 2 <= jend {
                    let s = xor_popcnt_4x2(ar, bt.row_window(j, w0, wn), bt.row_window(j + 1, w0, wn));
                    for (r, sr) in s.iter().enumerate() {
                        band[(i + r) * n + j] += sr[0] as i32;
                        band[(i + r) * n + j + 1] += sr[1] as i32;
                    }
                    j += 2;
                }
                if j < jend {
                    for (r, arr) in ar.iter().enumerate() {
                        band[(i + r) * n + j] += xor_popcnt(arr, bt.row_window(j, w0, wn)) as i32;
                    }
                }
                i += 4;
            }
            while i < rows {
                let arr = a.row_window(row0 + i, w0, wn);
                let mut j = j0;
                while j + 2 <= jend {
                    let (s0, s1) = xor_popcnt2(arr, bt.row_window(j, w0, wn), bt.row_window(j + 1, w0, wn));
                    band[i * n + j] += s0 as i32;
                    band[i * n + j + 1] += s1 as i32;
                    j += 2;
                }
                if j < jend {
                    band[i * n + j] += xor_popcnt(arr, bt.row_window(j, w0, wn)) as i32;
                }
                i += 1;
            }
        }
    }
    let k = a.k as i32;
    for v in band.iter_mut() {
        *v = k - 2 * *v;
    }
}

/// The seed's BNN kernel: independent row-dots, 2× column unrolling.
/// Kept as the differential / benchmark baseline for the tiled kernel.
pub(crate) fn bnn_gemm_rowdot(a: &BitRows, bt: &BitRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let k = a.k as i32;
    let n = bt.rows;
    for i in 0..a.rows {
        let ar = a.row(i);
        let mut j = 0;
        while j + 2 <= n {
            let (s0, s1) = xor_popcnt2(ar, bt.row(j), bt.row(j + 1));
            c.set(i, j, k - 2 * s0 as i32);
            c.set(i, j + 1, k - 2 * s1 as i32);
            j += 2;
        }
        if j < n {
            let s = xor_popcnt(ar, bt.row(j));
            c.set(i, j, k - 2 * s as i32);
        }
    }
}

// -------------------------------------------------------------------
// TNN: plane products, eq. (7)
// -------------------------------------------------------------------

/// Ternary GEMM. `a` holds plane rows of A, `bt` plane rows of Bᵀ.
/// Register-tiled (2×2; each output needs two accumulators, z⁺ and z⁻)
/// with L1-blocked B panels.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn tnn_gemm(a: &PlaneRows, bt: &PlaneRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    tnn_band(a, bt, 0, a.rows, &mut c.data);
}

/// Rows `row0..row0+rows` of the TNN product into `band` (`rows × n`).
pub(crate) fn tnn_band(a: &PlaneRows, bt: &PlaneRows, row0: usize, rows: usize, band: &mut [i32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 2)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 2 <= rows {
            let ap = [a.plus_row(row0 + i), a.plus_row(row0 + i + 1)];
            let am = [a.minus_row(row0 + i), a.minus_row(row0 + i + 1)];
            let mut j = j0;
            while j + 2 <= jend {
                let s =
                    tnn_popcnt_2x2(ap, am, bt.plus_row(j), bt.minus_row(j), bt.plus_row(j + 1), bt.minus_row(j + 1));
                for (r, sr) in s.iter().enumerate() {
                    band[(i + r) * n + j] = sr[0].0 as i32 - sr[0].1 as i32;
                    band[(i + r) * n + j + 1] = sr[1].0 as i32 - sr[1].1 as i32;
                }
                j += 2;
            }
            if j < jend {
                for r in 0..2 {
                    let (p, m) = tnn_popcnt(ap[r], am[r], bt.plus_row(j), bt.minus_row(j));
                    band[(i + r) * n + j] = p as i32 - m as i32;
                }
            }
            i += 2;
        }
        if i < rows {
            let (ap, am) = (a.plus_row(row0 + i), a.minus_row(row0 + i));
            for j in j0..jend {
                let (p, m) = tnn_popcnt(ap, am, bt.plus_row(j), bt.minus_row(j));
                band[i * n + j] = p as i32 - m as i32;
            }
        }
    }
}

/// Rows `row0..row0+rows` of the TNN product into `band` with the
/// widened 2×4 register tile ([`crate::gemm::plan::Tile::Wide`]): each
/// loaded A plane pair feeds 4 B columns and each B plane pair 2 A rows,
/// halving the loads-per-output of the 2×2 tile on wide outputs. Column
/// remainders fall back to the 2×2 / 1-column paths and row remainders
/// to the row-dot path, so results are bit-identical to [`tnn_band`]
/// (integer plane popcount sums regroup freely).
pub(crate) fn tnn_band_wide(a: &PlaneRows, bt: &PlaneRows, row0: usize, rows: usize, band: &mut [i32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 2)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 2 <= rows {
            let ap = [a.plus_row(row0 + i), a.plus_row(row0 + i + 1)];
            let am = [a.minus_row(row0 + i), a.minus_row(row0 + i + 1)];
            let mut j = j0;
            while j + 4 <= jend {
                let s = tnn_popcnt_2x4(
                    ap,
                    am,
                    [bt.plus_row(j), bt.plus_row(j + 1), bt.plus_row(j + 2), bt.plus_row(j + 3)],
                    [bt.minus_row(j), bt.minus_row(j + 1), bt.minus_row(j + 2), bt.minus_row(j + 3)],
                );
                for (r, sr) in s.iter().enumerate() {
                    for (c, &(p, m)) in sr.iter().enumerate() {
                        band[(i + r) * n + j + c] = p as i32 - m as i32;
                    }
                }
                j += 4;
            }
            while j + 2 <= jend {
                let s =
                    tnn_popcnt_2x2(ap, am, bt.plus_row(j), bt.minus_row(j), bt.plus_row(j + 1), bt.minus_row(j + 1));
                for (r, sr) in s.iter().enumerate() {
                    band[(i + r) * n + j] = sr[0].0 as i32 - sr[0].1 as i32;
                    band[(i + r) * n + j + 1] = sr[1].0 as i32 - sr[1].1 as i32;
                }
                j += 2;
            }
            if j < jend {
                for r in 0..2 {
                    let (p, m) = tnn_popcnt(ap[r], am[r], bt.plus_row(j), bt.minus_row(j));
                    band[(i + r) * n + j] = p as i32 - m as i32;
                }
            }
            i += 2;
        }
        if i < rows {
            let (ap, am) = (a.plus_row(row0 + i), a.minus_row(row0 + i));
            for j in j0..jend {
                let (p, m) = tnn_popcnt(ap, am, bt.plus_row(j), bt.minus_row(j));
                band[i * n + j] = p as i32 - m as i32;
            }
        }
    }
}

/// K-paneled TNN band: per-panel plane popcounts (z⁺, z⁻) — each bounded
/// by the panel depth, the 16-bit-safe quantity — spill their signed
/// difference into the i32 `band` between panels. Bit-identical to
/// [`tnn_band`] (integer partial sums regroup freely).
pub(crate) fn tnn_band_kp(a: &PlaneRows, bt: &PlaneRows, row0: usize, rows: usize, band: &mut [i32], kp_words: usize) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let w = a.words_per_row;
    let kp = kp_words.max(1);
    band.fill(0);
    for (j0, jn) in blocks(n, n_panel(kp.min(w.max(1)), 2)) {
        let jend = j0 + jn;
        for (w0, wn) in blocks(w, kp) {
            let mut i = 0;
            while i + 2 <= rows {
                let ap = [a.plus_window(row0 + i, w0, wn), a.plus_window(row0 + i + 1, w0, wn)];
                let am = [a.minus_window(row0 + i, w0, wn), a.minus_window(row0 + i + 1, w0, wn)];
                let mut j = j0;
                while j + 2 <= jend {
                    let s = tnn_popcnt_2x2(
                        ap,
                        am,
                        bt.plus_window(j, w0, wn),
                        bt.minus_window(j, w0, wn),
                        bt.plus_window(j + 1, w0, wn),
                        bt.minus_window(j + 1, w0, wn),
                    );
                    for (r, sr) in s.iter().enumerate() {
                        band[(i + r) * n + j] += sr[0].0 as i32 - sr[0].1 as i32;
                        band[(i + r) * n + j + 1] += sr[1].0 as i32 - sr[1].1 as i32;
                    }
                    j += 2;
                }
                if j < jend {
                    for r in 0..2 {
                        let (p, m) =
                            tnn_popcnt(ap[r], am[r], bt.plus_window(j, w0, wn), bt.minus_window(j, w0, wn));
                        band[(i + r) * n + j] += p as i32 - m as i32;
                    }
                }
                i += 2;
            }
            if i < rows {
                let (ap, am) = (a.plus_window(row0 + i, w0, wn), a.minus_window(row0 + i, w0, wn));
                for j in j0..jend {
                    let (p, m) = tnn_popcnt(ap, am, bt.plus_window(j, w0, wn), bt.minus_window(j, w0, wn));
                    band[i * n + j] += p as i32 - m as i32;
                }
            }
        }
    }
}

/// The seed's TNN kernel: one vectorized plane-product pass per (i, j).
pub(crate) fn tnn_gemm_rowdot(a: &PlaneRows, bt: &PlaneRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let n = bt.rows;
    for i in 0..a.rows {
        let (ap, am) = (a.plus_row(i), a.minus_row(i));
        for j in 0..n {
            let (p, m) = tnn_popcnt(ap, am, bt.plus_row(j), bt.minus_row(j));
            c.set(i, j, p as i32 - m as i32);
        }
    }
}

// -------------------------------------------------------------------
// TBN: ternary A × binary B via the plane form of §III-A
// -------------------------------------------------------------------

/// Ternary-binary GEMM. `a` holds plane rows of A, `bt` bit rows of Bᵀ.
/// Register-tiled (2×2) with L1-blocked B panels.
///
/// y⁺ = ¬y♭, y⁻ = y♭. Note ¬y♭ sets the depth-padding bits of the last
/// word, but a⁺/a⁻ padding bits are 0, so the AND masks them out.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn tbn_gemm(a: &PlaneRows, bt: &BitRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    tbn_band(a, bt, 0, a.rows, &mut c.data);
}

/// Rows `row0..row0+rows` of the TBN product into `band` (`rows × n`).
pub(crate) fn tbn_band(a: &PlaneRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [i32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 1)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 2 <= rows {
            let ap = [a.plus_row(row0 + i), a.plus_row(row0 + i + 1)];
            let am = [a.minus_row(row0 + i), a.minus_row(row0 + i + 1)];
            let mut j = j0;
            while j + 2 <= jend {
                let s = tbn_popcnt_2x2(ap, am, bt.row(j), bt.row(j + 1));
                for (r, sr) in s.iter().enumerate() {
                    band[(i + r) * n + j] = sr[0].0 as i32 - sr[0].1 as i32;
                    band[(i + r) * n + j + 1] = sr[1].0 as i32 - sr[1].1 as i32;
                }
                j += 2;
            }
            if j < jend {
                for r in 0..2 {
                    let (p, m) = tbn_popcnt(ap[r], am[r], bt.row(j));
                    band[(i + r) * n + j] = p as i32 - m as i32;
                }
            }
            i += 2;
        }
        if i < rows {
            let (ap, am) = (a.plus_row(row0 + i), a.minus_row(row0 + i));
            for j in j0..jend {
                let (p, m) = tbn_popcnt(ap, am, bt.row(j));
                band[i * n + j] = p as i32 - m as i32;
            }
        }
    }
}

/// K-paneled TBN band: as [`tnn_band_kp`] but against binary bit-columns.
/// The ¬t padding-bit argument of [`tbn_gemm`] holds per window too:
/// a⁺/a⁻ padding bits are 0, so the AND masks them in every panel.
pub(crate) fn tbn_band_kp(a: &PlaneRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [i32], kp_words: usize) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let w = a.words_per_row;
    let kp = kp_words.max(1);
    band.fill(0);
    for (j0, jn) in blocks(n, n_panel(kp.min(w.max(1)), 1)) {
        let jend = j0 + jn;
        for (w0, wn) in blocks(w, kp) {
            let mut i = 0;
            while i + 2 <= rows {
                let ap = [a.plus_window(row0 + i, w0, wn), a.plus_window(row0 + i + 1, w0, wn)];
                let am = [a.minus_window(row0 + i, w0, wn), a.minus_window(row0 + i + 1, w0, wn)];
                let mut j = j0;
                while j + 2 <= jend {
                    let s = tbn_popcnt_2x2(ap, am, bt.row_window(j, w0, wn), bt.row_window(j + 1, w0, wn));
                    for (r, sr) in s.iter().enumerate() {
                        band[(i + r) * n + j] += sr[0].0 as i32 - sr[0].1 as i32;
                        band[(i + r) * n + j + 1] += sr[1].0 as i32 - sr[1].1 as i32;
                    }
                    j += 2;
                }
                if j < jend {
                    for r in 0..2 {
                        let (p, m) = tbn_popcnt(ap[r], am[r], bt.row_window(j, w0, wn));
                        band[(i + r) * n + j] += p as i32 - m as i32;
                    }
                }
                i += 2;
            }
            if i < rows {
                let (ap, am) = (a.plus_window(row0 + i, w0, wn), a.minus_window(row0 + i, w0, wn));
                for j in j0..jend {
                    let (p, m) = tbn_popcnt(ap, am, bt.row_window(j, w0, wn));
                    band[i * n + j] += p as i32 - m as i32;
                }
            }
        }
    }
}

/// The seed's TBN kernel: one vectorized pass per (i, j).
pub(crate) fn tbn_gemm_rowdot(a: &PlaneRows, bt: &BitRows, c: &mut MatI32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let n = bt.rows;
    for i in 0..a.rows {
        let (ap, am) = (a.plus_row(i), a.minus_row(i));
        for j in 0..n {
            let (p, m) = tbn_popcnt(ap, am, bt.row(j));
            c.set(i, j, p as i32 - m as i32);
        }
    }
}

// -------------------------------------------------------------------
// daBNN-style binary: f32 accumulation every 128-bit chunk
// -------------------------------------------------------------------

/// Binary GEMM with daBNN's structure: per (row, col) the popcount of each
/// 128-bit chunk is reduced and accumulated in f32 (daBNN keeps its
/// running sums in f32 registers), which costs an int→float convert per
/// chunk — the structural reason it trails the paper's BNN kernel.
///
/// Tiled over 4 A-rows (B words loaded once per 4 rows) while keeping the
/// per-output chunk order — and therefore the f32 rounding — bit-identical
/// to the row-dot form.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn dabnn_gemm(a: &BitRows, bt: &BitRows, c: &mut MatF32) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    dabnn_band(a, bt, 0, a.rows, &mut c.data);
}

/// Rows `row0..row0+rows` of the daBNN product into `band` (`rows × n`).
pub(crate) fn dabnn_band(a: &BitRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [f32]) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let w = a.words_per_row;
    let kf = a.k as f32;
    for (j0, jn) in blocks(n, n_panel(bt.words_per_row, 1)) {
        let jend = j0 + jn;
        let mut i = 0;
        while i + 4 <= rows {
            let ar = [a.row(row0 + i), a.row(row0 + i + 1), a.row(row0 + i + 2), a.row(row0 + i + 3)];
            for j in j0..jend {
                let br = bt.row(j);
                let mut acc = [0f32; 4];
                let mut t = 0;
                while t + 2 <= w {
                    for (r, arr) in ar.iter().enumerate() {
                        let s = (arr[t] ^ br[t]).count_ones() + (arr[t + 1] ^ br[t + 1]).count_ones();
                        acc[r] += s as f32; // per-128-bit convert, as in daBNN
                    }
                    t += 2;
                }
                while t < w {
                    for (r, arr) in ar.iter().enumerate() {
                        acc[r] += (arr[t] ^ br[t]).count_ones() as f32;
                    }
                    t += 1;
                }
                for (r, &av) in acc.iter().enumerate() {
                    band[(i + r) * n + j] = kf - 2.0 * av;
                }
            }
            i += 4;
        }
        while i < rows {
            let arr = a.row(row0 + i);
            for j in j0..jend {
                let br = bt.row(j);
                let mut acc = 0f32;
                let mut t = 0;
                while t + 2 <= w {
                    let s = (arr[t] ^ br[t]).count_ones() + (arr[t + 1] ^ br[t + 1]).count_ones();
                    acc += s as f32;
                    t += 2;
                }
                while t < w {
                    acc += (arr[t] ^ br[t]).count_ones() as f32;
                    t += 1;
                }
                band[i * n + j] = kf - 2.0 * acc;
            }
            i += 1;
        }
    }
}

/// K-paneled daBNN band: per-panel f32 chunk sums spill into the f32
/// `band` between panels. Popcount partials are exact f32 integers
/// (≤ k < 2²³), so any regrouping — including the panel boundaries
/// falling inside a 2-word chunk pair — leaves results bit-identical
/// to [`dabnn_band`].
pub(crate) fn dabnn_band_kp(a: &BitRows, bt: &BitRows, row0: usize, rows: usize, band: &mut [f32], kp_words: usize) {
    let n = bt.rows;
    debug_assert_eq!(band.len(), rows * n);
    let w = a.words_per_row;
    let kp = kp_words.max(1);
    band.fill(0.0);
    for (j0, jn) in blocks(n, n_panel(kp.min(w.max(1)), 1)) {
        let jend = j0 + jn;
        for (w0, wn) in blocks(w, kp) {
            let mut i = 0;
            while i + 4 <= rows {
                let ar = [
                    a.row_window(row0 + i, w0, wn),
                    a.row_window(row0 + i + 1, w0, wn),
                    a.row_window(row0 + i + 2, w0, wn),
                    a.row_window(row0 + i + 3, w0, wn),
                ];
                for j in j0..jend {
                    let br = bt.row_window(j, w0, wn);
                    let mut acc = [0f32; 4];
                    let mut t = 0;
                    while t + 2 <= wn {
                        for (r, arr) in ar.iter().enumerate() {
                            let s = (arr[t] ^ br[t]).count_ones() + (arr[t + 1] ^ br[t + 1]).count_ones();
                            acc[r] += s as f32; // per-128-bit convert, as in daBNN
                        }
                        t += 2;
                    }
                    while t < wn {
                        for (r, arr) in ar.iter().enumerate() {
                            acc[r] += (arr[t] ^ br[t]).count_ones() as f32;
                        }
                        t += 1;
                    }
                    for (r, &av) in acc.iter().enumerate() {
                        band[(i + r) * n + j] += av;
                    }
                }
                i += 4;
            }
            while i < rows {
                let arr = a.row_window(row0 + i, w0, wn);
                for j in j0..jend {
                    let br = bt.row_window(j, w0, wn);
                    let mut acc = 0f32;
                    let mut t = 0;
                    while t + 2 <= wn {
                        let s = (arr[t] ^ br[t]).count_ones() + (arr[t + 1] ^ br[t + 1]).count_ones();
                        acc += s as f32;
                        t += 2;
                    }
                    while t < wn {
                        acc += (arr[t] ^ br[t]).count_ones() as f32;
                        t += 1;
                    }
                    band[i * n + j] += acc;
                }
                i += 1;
            }
        }
    }
    let kf = a.k as f32;
    for v in band.iter_mut() {
        *v = kf - 2.0 * *v;
    }
}

// -------------------------------------------------------------------
// F32 baseline
// -------------------------------------------------------------------

/// f32 GEMM, register-blocked 4×8 with B pre-transposed to row-panels of
/// 8 columns (`bp[d*8 + c]` = B[d][col0+c]), k-major streams.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn f32_gemm(a: &MatF32, b_panels: &[Vec<f32>], n: usize, c: &mut MatF32) {
    let m = a.rows;
    assert_eq!((c.rows, c.cols), (m, n));
    f32_band(a, b_panels, n, 0, m, &mut c.data);
}

/// Rows `row0..row0+rows` of the f32 product into `band` (`rows × n`).
pub(crate) fn f32_band(a: &MatF32, b_panels: &[Vec<f32>], n: usize, row0: usize, rows: usize, band: &mut [f32]) {
    let k = a.cols;
    debug_assert_eq!(band.len(), rows * n);
    for (cb, panel) in b_panels.iter().enumerate() {
        let j0 = cb * 8;
        let n_eff = (n - j0).min(8);
        let mut i = 0;
        while i + 4 <= rows {
            let mut acc = [[0f32; 8]; 4];
            let rows4 = [
                a.row_slice(row0 + i),
                a.row_slice(row0 + i + 1),
                a.row_slice(row0 + i + 2),
                a.row_slice(row0 + i + 3),
            ];
            for d in 0..k {
                let bv = &panel[d * 8..d * 8 + 8];
                for (r, row) in rows4.iter().enumerate() {
                    let av = row[d];
                    for j in 0..8 {
                        acc[r][j] += av * bv[j];
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                for (j, &v) in accr.iter().take(n_eff).enumerate() {
                    band[(i + r) * n + j0 + j] = v;
                }
            }
            i += 4;
        }
        while i < rows {
            let mut acc = [0f32; 8];
            let row = a.row_slice(row0 + i);
            for d in 0..k {
                let bv = &panel[d * 8..d * 8 + 8];
                for j in 0..8 {
                    acc[j] += row[d] * bv[j];
                }
            }
            for (j, &v) in acc.iter().take(n_eff).enumerate() {
                band[i * n + j0 + j] = v;
            }
            i += 1;
        }
    }
}

/// K-paneled f32 band: the depth loop is split into `kp` panels; each
/// panel accumulates in registers and spills into per-tile wide sums.
/// Unlike the integer kinds this *changes the rounding association*, so
/// results can differ from [`f32_band`] in the last ulp — callers compare
/// with a tolerance (as all f32 paths are tested against the oracle).
pub(crate) fn f32_band_kp(
    a: &MatF32,
    b_panels: &[Vec<f32>],
    n: usize,
    row0: usize,
    rows: usize,
    band: &mut [f32],
    kp: usize,
) {
    let k = a.cols;
    let kp = kp.max(1);
    debug_assert_eq!(band.len(), rows * n);
    for (cb, panel) in b_panels.iter().enumerate() {
        let j0 = cb * 8;
        let n_eff = (n - j0).min(8);
        let mut i = 0;
        while i + 4 <= rows {
            let mut wide = [[0f32; 8]; 4];
            let rows4 = [
                a.row_slice(row0 + i),
                a.row_slice(row0 + i + 1),
                a.row_slice(row0 + i + 2),
                a.row_slice(row0 + i + 3),
            ];
            for (d0, dn) in blocks(k, kp) {
                let mut acc = [[0f32; 8]; 4];
                for d in d0..d0 + dn {
                    let bv = &panel[d * 8..d * 8 + 8];
                    for (r, row) in rows4.iter().enumerate() {
                        let av = row[d];
                        for j in 0..8 {
                            acc[r][j] += av * bv[j];
                        }
                    }
                }
                for r in 0..4 {
                    for j in 0..8 {
                        wide[r][j] += acc[r][j];
                    }
                }
            }
            for (r, wr) in wide.iter().enumerate() {
                for (j, &v) in wr.iter().take(n_eff).enumerate() {
                    band[(i + r) * n + j0 + j] = v;
                }
            }
            i += 4;
        }
        while i < rows {
            let mut wide = [0f32; 8];
            let row = a.row_slice(row0 + i);
            for (d0, dn) in blocks(k, kp) {
                let mut acc = [0f32; 8];
                for d in d0..d0 + dn {
                    let bv = &panel[d * 8..d * 8 + 8];
                    for j in 0..8 {
                        acc[j] += row[d] * bv[j];
                    }
                }
                for j in 0..8 {
                    wide[j] += acc[j];
                }
            }
            for (j, &v) in wide.iter().take(n_eff).enumerate() {
                band[i * n + j0 + j] = v;
            }
            i += 1;
        }
    }
}

// -------------------------------------------------------------------
// U8: gemmlowp-style with eq. (3) epilogue
// -------------------------------------------------------------------

/// u8 GEMM with zero-point compensation. `b_panels` pack 8 columns per
/// panel, k-major (`panel[d*8 + c]`); `col_sums` precomputed offline.
/// Register-tiled 4×8 (each loaded B vector feeds four row accumulators).
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn u8_gemm(a: &MatU8, b_panels: &[Vec<u8>], n: usize, za: i32, zb: i32, col_sums: &[i32], c: &mut MatI32) {
    let (m, _) = (a.rows, a.cols);
    assert_eq!((c.rows, c.cols), (m, n));
    u8_band(a, b_panels, n, za, zb, col_sums, 0, m, &mut c.data);
}

/// Rows `row0..row0+rows` of the u8 product into `band` (`rows × n`).
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn u8_band(
    a: &MatU8,
    b_panels: &[Vec<u8>],
    n: usize,
    za: i32,
    zb: i32,
    col_sums: &[i32],
    row0: usize,
    rows: usize,
    band: &mut [i32],
) {
    let k = a.cols;
    debug_assert_eq!(band.len(), rows * n);
    for (cb, panel) in b_panels.iter().enumerate() {
        let j0 = cb * 8;
        let n_eff = (n - j0).min(8);
        let mut i = 0;
        while i + 4 <= rows {
            let rows4 = [
                &a.data[(row0 + i) * k..(row0 + i + 1) * k],
                &a.data[(row0 + i + 1) * k..(row0 + i + 2) * k],
                &a.data[(row0 + i + 2) * k..(row0 + i + 3) * k],
                &a.data[(row0 + i + 3) * k..(row0 + i + 4) * k],
            ];
            let mut acc = [[0u32; 8]; 4];
            let mut row_sum = [0u32; 4];
            for d in 0..k {
                let bv = &panel[d * 8..d * 8 + 8];
                for (r, row) in rows4.iter().enumerate() {
                    let a32 = row[d] as u32;
                    row_sum[r] += a32;
                    for j in 0..8 {
                        acc[r][j] += a32 * bv[j] as u32;
                    }
                }
            }
            for r in 0..4 {
                for j in 0..n_eff {
                    let v = acc[r][j] as i32 - zb * row_sum[r] as i32 - za * col_sums[j0 + j] + k as i32 * za * zb;
                    band[(i + r) * n + j0 + j] = v;
                }
            }
            i += 4;
        }
        while i < rows {
            let row = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
            let mut acc = [0u32; 8];
            let mut row_sum = 0u32;
            for (d, &av) in row.iter().enumerate() {
                let bv = &panel[d * 8..d * 8 + 8];
                let a32 = av as u32;
                row_sum += a32;
                for j in 0..8 {
                    acc[j] += a32 * bv[j] as u32;
                }
            }
            for j in 0..n_eff {
                let v = acc[j] as i32 - zb * row_sum as i32 - za * col_sums[j0 + j] + k as i32 * za * zb;
                band[i * n + j0 + j] = v;
            }
            i += 1;
        }
    }
}

/// K-paneled u8 band: in-panel dot products and row sums accumulate in
/// u32 (safe for panel depths up to the paper's k_max = 66051, eq. (4))
/// and spill into i64 wide sums between panels; the eq. (3) epilogue runs
/// in i64 over the full depth, so the paneled path stays exact at depths
/// where the unpaneled u32 accumulation would wrap.
#[allow(clippy::too_many_arguments)]
pub(crate) fn u8_band_kp(
    a: &MatU8,
    b_panels: &[Vec<u8>],
    n: usize,
    za: i32,
    zb: i32,
    col_sums: &[i32],
    row0: usize,
    rows: usize,
    band: &mut [i32],
    kp: usize,
) {
    let k = a.cols;
    // The driver (`KPanel::elems`) owns the u32-safe Table II depth
    // bound; like the other band kernels this only guards degeneracy.
    let kp = kp.max(1);
    debug_assert_eq!(band.len(), rows * n);
    for (cb, panel) in b_panels.iter().enumerate() {
        let j0 = cb * 8;
        let n_eff = (n - j0).min(8);
        let mut i = 0;
        while i + 4 <= rows {
            let rows4 = [
                &a.data[(row0 + i) * k..(row0 + i + 1) * k],
                &a.data[(row0 + i + 1) * k..(row0 + i + 2) * k],
                &a.data[(row0 + i + 2) * k..(row0 + i + 3) * k],
                &a.data[(row0 + i + 3) * k..(row0 + i + 4) * k],
            ];
            let mut wide = [[0i64; 8]; 4];
            let mut row_sum = [0i64; 4];
            for (d0, dn) in blocks(k, kp) {
                let mut acc = [[0u32; 8]; 4];
                let mut rs = [0u32; 4];
                for d in d0..d0 + dn {
                    let bv = &panel[d * 8..d * 8 + 8];
                    for (r, row) in rows4.iter().enumerate() {
                        let a32 = row[d] as u32;
                        rs[r] += a32;
                        for j in 0..8 {
                            acc[r][j] += a32 * bv[j] as u32;
                        }
                    }
                }
                for r in 0..4 {
                    row_sum[r] += rs[r] as i64;
                    for j in 0..8 {
                        wide[r][j] += acc[r][j] as i64;
                    }
                }
            }
            for r in 0..4 {
                for j in 0..n_eff {
                    let v = wide[r][j] - zb as i64 * row_sum[r] - za as i64 * col_sums[j0 + j] as i64
                        + k as i64 * za as i64 * zb as i64;
                    band[(i + r) * n + j0 + j] = v as i32;
                }
            }
            i += 4;
        }
        while i < rows {
            let row = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
            let mut wide = [0i64; 8];
            let mut row_sum = 0i64;
            for (d0, dn) in blocks(k, kp) {
                let mut acc = [0u32; 8];
                let mut rs = 0u32;
                for d in d0..d0 + dn {
                    let bv = &panel[d * 8..d * 8 + 8];
                    let a32 = row[d] as u32;
                    rs += a32;
                    for j in 0..8 {
                        acc[j] += a32 * bv[j] as u32;
                    }
                }
                row_sum += rs as i64;
                for j in 0..8 {
                    wide[j] += acc[j] as i64;
                }
            }
            for j in 0..n_eff {
                let v = wide[j] - zb as i64 * row_sum - za as i64 * col_sums[j0 + j] as i64
                    + k as i64 * za as i64 * zb as i64;
                band[i * n + j0 + j] = v as i32;
            }
            i += 1;
        }
    }
}

// -------------------------------------------------------------------
// U4: 16-bit-blocked accumulation (the [20] scheme)
// -------------------------------------------------------------------

/// 4-bit GEMM: values 0..=15, accumulated in u16 within ≤290-deep blocks
/// (the eq. (4) bound), widened to i32 between blocks, eq. (3) epilogue.
/// The u16 accumulators are the structural speed advantage over U8: twice
/// the SIMD lanes per vector op after auto-vectorization.
#[allow(clippy::too_many_arguments)]
pub(crate) fn u4_gemm(a: &MatU8, b_panels: &[Vec<u8>], n: usize, za: i32, zb: i32, col_sums: &[i32], c: &mut MatI32) {
    let (m, k) = (a.rows, a.cols);
    assert_eq!((c.rows, c.cols), (m, n));
    const KB: usize = 290;
    for (cb, panel) in b_panels.iter().enumerate() {
        let j0 = cb * 8;
        let n_eff = (n - j0).min(8);
        for i in 0..m {
            let row = &a.data[i * k..(i + 1) * k];
            let mut wide = [0i32; 8];
            let mut row_sum = 0i32;
            let mut d0 = 0;
            while d0 < k {
                let k_eff = (k - d0).min(KB);
                let mut acc = [0u16; 8];
                let mut rs16 = 0u16;
                for d in d0..d0 + k_eff {
                    let av = row[d] as u16;
                    rs16 += av;
                    let bv = &panel[d * 8..d * 8 + 8];
                    for j in 0..8 {
                        acc[j] += av * bv[j] as u16;
                    }
                }
                for j in 0..8 {
                    wide[j] += acc[j] as i32;
                }
                row_sum += rs16 as i32;
                d0 += k_eff;
            }
            for j in 0..n_eff {
                let v = wide[j] - zb * row_sum - za * col_sums[j0 + j] + k as i32 * za * zb;
                c.set(i, j0 + j, v);
            }
        }
    }
}

// -------------------------------------------------------------------
// Panel packing helpers for the native f32/u8/u4 paths
// -------------------------------------------------------------------

/// Pack B (k×n f32) into 8-column k-major panels for [`f32_gemm`].
pub(crate) fn pack_b_panels_f32(b: &MatF32) -> Vec<Vec<f32>> {
    (0..b.cols.div_ceil(8))
        .map(|cb| {
            let mut p = vec![0f32; b.rows * 8];
            for d in 0..b.rows {
                for j in 0..8 {
                    let col = cb * 8 + j;
                    if col < b.cols {
                        p[d * 8 + j] = b.get(d, col);
                    }
                }
            }
            p
        })
        .collect()
}

/// Pack B (k×n u8) into 8-column k-major panels for [`u8_gemm`]/[`u4_gemm`].
pub(crate) fn pack_b_panels_u8(b: &MatU8) -> Vec<Vec<u8>> {
    (0..b.cols.div_ceil(8))
        .map(|cb| {
            let mut p = vec![0u8; b.rows * 8];
            for d in 0..b.rows {
                for j in 0..8 {
                    let col = cb * 8 + j;
                    if col < b.cols {
                        p[d * 8 + j] = b.get(d, col);
                    }
                }
            }
            p
        })
        .collect()
}

impl MatF32 {
    /// Contiguous row slice (hot-path helper for the native kernels).
    #[inline]
    pub fn row_slice(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::reference;
    use crate::util::mat::MatI8;
    use crate::util::proptest::{check, gemm_shape, Config};

    #[test]
    fn bnn_native_vs_oracle() {
        check(Config { cases: 32, base_seed: 0xC0 }, "bnn native", |rng| {
            let (m, n, k) = gemm_shape(rng, 40, 40, 200);
            let a = MatI8::random_binary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let ab = BitRows::from_binary(&a);
            let bb = BitRows::from_binary_transposed(&b);
            let mut c = MatI32::zeros(m, n);
            bnn_gemm(&ab, &bb, &mut c);
            assert_eq!(c.data, reference::gemm_i8(&a, &b).data, "m={m} n={n} k={k}");
        });
    }

    #[test]
    fn tnn_native_vs_oracle() {
        check(Config { cases: 32, base_seed: 0xC1 }, "tnn native", |rng| {
            let (m, n, k) = gemm_shape(rng, 40, 40, 200);
            let a = MatI8::random_ternary(m, k, rng);
            let b = MatI8::random_ternary(k, n, rng);
            let ap = PlaneRows::from_ternary(&a);
            let bp = PlaneRows::from_ternary_transposed(&b);
            let mut c = MatI32::zeros(m, n);
            tnn_gemm(&ap, &bp, &mut c);
            assert_eq!(c.data, reference::gemm_i8(&a, &b).data, "m={m} n={n} k={k}");
        });
    }

    #[test]
    fn tbn_native_vs_oracle() {
        check(Config { cases: 32, base_seed: 0xC2 }, "tbn native", |rng| {
            let (m, n, k) = gemm_shape(rng, 40, 40, 200);
            let a = MatI8::random_ternary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let ap = PlaneRows::from_ternary(&a);
            let bb = BitRows::from_binary_transposed(&b);
            let mut c = MatI32::zeros(m, n);
            tbn_gemm(&ap, &bb, &mut c);
            assert_eq!(c.data, reference::gemm_i8(&a, &b).data, "m={m} n={n} k={k}");
        });
    }

    /// Tiled kernels ≡ the seed row-dot kernels on adversarial shapes:
    /// m/n not multiples of the tile, k not a multiple of 64, and
    /// single-row / single-column matrices.
    #[test]
    fn tiled_matches_rowdot_adversarial() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 7, 64),
            (7, 1, 65),
            (2, 2, 63),
            (3, 3, 127),
            (4, 2, 128),
            (5, 9, 130),
            (6, 3, 66),
            (9, 5, 191),
            (17, 33, 257),
        ];
        let mut rng = crate::util::Rng::new(0xC8);
        for &(m, n, k) in &shapes {
            let a = MatI8::random_binary(m, k, &mut rng);
            let b = MatI8::random_binary(k, n, &mut rng);
            let ab = BitRows::from_binary(&a);
            let bb = BitRows::from_binary_transposed(&b);
            let (mut c_tiled, mut c_rd) = (MatI32::zeros(m, n), MatI32::zeros(m, n));
            bnn_gemm(&ab, &bb, &mut c_tiled);
            bnn_gemm_rowdot(&ab, &bb, &mut c_rd);
            assert_eq!(c_tiled.data, c_rd.data, "bnn m={m} n={n} k={k}");

            let at = MatI8::random_ternary(m, k, &mut rng);
            let bt3 = MatI8::random_ternary(k, n, &mut rng);
            let ap = PlaneRows::from_ternary(&at);
            let bp = PlaneRows::from_ternary_transposed(&bt3);
            let (mut c_tiled, mut c_rd) = (MatI32::zeros(m, n), MatI32::zeros(m, n));
            tnn_gemm(&ap, &bp, &mut c_tiled);
            tnn_gemm_rowdot(&ap, &bp, &mut c_rd);
            assert_eq!(c_tiled.data, c_rd.data, "tnn m={m} n={n} k={k}");

            let (mut c_tiled, mut c_rd) = (MatI32::zeros(m, n), MatI32::zeros(m, n));
            tbn_gemm(&ap, &bb, &mut c_tiled);
            tbn_gemm_rowdot(&ap, &bb, &mut c_rd);
            assert_eq!(c_tiled.data, c_rd.data, "tbn m={m} n={n} k={k}");
        }
    }

    /// The widened 4×4 BNN tile is bit-identical to the 4×2 tiled kernel
    /// on shapes breaking every boundary: n % 4 ∈ {0,1,2,3}, m % 4 ≠ 0,
    /// k not a multiple of 64.
    #[test]
    fn bnn_wide_tile_matches_tiled() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 4, 64),
            (5, 3, 65),
            (8, 9, 127),
            (4, 6, 128),
            (3, 11, 130),
            (12, 13, 191),
            (17, 33, 257),
        ];
        let mut rng = crate::util::Rng::new(0xC9);
        for &(m, n, k) in &shapes {
            let a = MatI8::random_binary(m, k, &mut rng);
            let b = MatI8::random_binary(k, n, &mut rng);
            let ab = BitRows::from_binary(&a);
            let bb = BitRows::from_binary_transposed(&b);
            let mut c_tiled = MatI32::zeros(m, n);
            bnn_gemm(&ab, &bb, &mut c_tiled);
            let mut c_wide = MatI32::zeros(m, n);
            bnn_band_wide(&ab, &bb, 0, m, &mut c_wide.data);
            assert_eq!(c_wide.data, c_tiled.data, "m={m} n={n} k={k}");
        }
    }

    /// The widened 2×4 TNN tile is bit-identical to the 2×2 tiled kernel
    /// on shapes breaking every boundary: n % 4 ∈ {0,1,2,3}, m % 2 ≠ 0,
    /// k not a multiple of 64.
    #[test]
    fn tnn_wide_tile_matches_tiled() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (2, 4, 64),
            (5, 3, 65),
            (8, 9, 127),
            (4, 6, 128),
            (3, 11, 130),
            (12, 13, 191),
            (17, 33, 257),
        ];
        let mut rng = crate::util::Rng::new(0xCA);
        for &(m, n, k) in &shapes {
            let a = MatI8::random_ternary(m, k, &mut rng);
            let b = MatI8::random_ternary(k, n, &mut rng);
            let ap = PlaneRows::from_ternary(&a);
            let bp = PlaneRows::from_ternary_transposed(&b);
            let mut c_tiled = MatI32::zeros(m, n);
            tnn_gemm(&ap, &bp, &mut c_tiled);
            let mut c_wide = MatI32::zeros(m, n);
            tnn_band_wide(&ap, &bp, 0, m, &mut c_wide.data);
            assert_eq!(c_wide.data, c_tiled.data, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn dabnn_native_vs_oracle() {
        check(Config { cases: 16, base_seed: 0xC3 }, "dabnn native", |rng| {
            let (m, n, k) = gemm_shape(rng, 24, 18, 300);
            let a = MatI8::random_binary(m, k, rng);
            let b = MatI8::random_binary(k, n, rng);
            let ab = BitRows::from_binary(&a);
            let bb = BitRows::from_binary_transposed(&b);
            let mut c = MatF32::zeros(m, n);
            dabnn_gemm(&ab, &bb, &mut c);
            let want = reference::gemm_i8(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(c.get(i, j) as i32, want.get(i, j), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn f32_native_vs_oracle() {
        check(Config { cases: 16, base_seed: 0xC4 }, "f32 native", |rng| {
            let (m, n, k) = gemm_shape(rng, 30, 30, 60);
            let a = MatF32::random(m, k, rng);
            let b = MatF32::random(k, n, rng);
            let panels = pack_b_panels_f32(&b);
            let mut c = MatF32::zeros(m, n);
            f32_gemm(&a, &panels, n, &mut c);
            let want = reference::gemm_f32(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    let (g, w) = (c.get(i, j), want.get(i, j));
                    assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "({i},{j}): {g} vs {w}");
                }
            }
        });
    }

    #[test]
    fn u8_native_vs_oracle() {
        check(Config { cases: 16, base_seed: 0xC5 }, "u8 native", |rng| {
            let (m, n, k) = gemm_shape(rng, 30, 30, 60);
            let a = MatU8::random(m, k, rng);
            let b = MatU8::random(k, n, rng);
            let za = rng.below(256) as i32;
            let zb = rng.below(256) as i32;
            let panels = pack_b_panels_u8(&b);
            let col_sums: Vec<i32> = (0..n).map(|j| (0..k).map(|t| b.get(t, j) as i32).sum()).collect();
            let mut c = MatI32::zeros(m, n);
            u8_gemm(&a, &panels, n, za, zb, &col_sums, &mut c);
            assert_eq!(c.data, reference::gemm_u8_centered(&a, &b, za, zb).data);
        });
    }

    #[test]
    fn u4_native_vs_oracle_deep_k() {
        check(Config { cases: 12, base_seed: 0xC6 }, "u4 native", |rng| {
            let m = 1 + rng.below(24);
            let n = 1 + rng.below(24);
            let k = 200 + rng.below(300); // crosses the 290 block boundary
            let a = MatU8::random_below(m, k, 15, rng);
            let b = MatU8::random_below(k, n, 15, rng);
            let za = rng.below(16) as i32;
            let zb = rng.below(16) as i32;
            let panels = pack_b_panels_u8(&b);
            let col_sums: Vec<i32> = (0..n).map(|j| (0..k).map(|t| b.get(t, j) as i32).sum()).collect();
            let mut c = MatI32::zeros(m, n);
            u4_gemm(&a, &panels, n, za, zb, &col_sums, &mut c);
            assert_eq!(c.data, reference::gemm_u8_centered(&a, &b, za, zb).data);
        });
    }

    /// Native and emulated paths agree exactly on the low-bit kinds.
    #[test]
    fn native_matches_emulated() {
        use crate::gemm::driver::GemmDriver;
        use crate::gemm::plan::{GemmOut, Lhs};
        check(Config { cases: 8, base_seed: 0xC7 }, "native vs emulated", |rng| {
            let (m, n, k) = gemm_shape(rng, 33, 25, 100);
            let a = MatI8::random_ternary(m, k, rng);
            let b = MatI8::random_ternary(k, n, rng);
            let emu = match GemmDriver::new_tnn(&b).multiply_emulated(Lhs::I8(&a)) {
                GemmOut::I32(m) => m,
                GemmOut::F32(_) => panic!("expected i32 output"),
            };
            let ap = PlaneRows::from_ternary(&a);
            let bp = PlaneRows::from_ternary_transposed(&b);
            let mut c = MatI32::zeros(m, n);
            tnn_gemm(&ap, &bp, &mut c);
            assert_eq!(c.data, emu.data);
        });
    }
}
