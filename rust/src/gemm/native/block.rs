//! Cache blocking and multithreading for the native kernels: the outer
//! two levels of the blocked execution hierarchy (see the module docs of
//! [`crate::gemm::native`]).
//!
//! * [`blocks`] — an rten-style panel iterator: walk a dimension in
//!   fixed-size blocks, yielding `(start, len)` with a short final block.
//! * [`n_panel`] — the B-panel width (in B rows) sized so one panel of
//!   packed B words fits in L1, so the panel stays hot across the whole
//!   A-row loop of a band.
//! * [`Threading`] — the per-call parallelism cap of a multiplication,
//!   resolved against the persistent worker pool. Plumbed through
//!   [`crate::conv::conv2d::LowBitConv`],
//!   [`crate::conv::stripe::StripeConv`] and the coordinator's
//!   [`crate::coordinator::engine::NativeEngine`].
//! * [`parallel_row_bands`] — row-panel parallelism on the process-wide
//!   pool ([`crate::util::pool`]): C is split into disjoint contiguous
//!   row bands, one pool task per band. Rows of C are independent in
//!   every algorithm here, and the band split is a pure function of the
//!   cap and the shape (never of scheduling), so results are
//!   bit-identical to the single-threaded kernels at any worker count.

use crate::gemm::native::bits::{BitRows, PlaneRows};
use crate::gemm::native::kernels;
use crate::gemm::Kind;
use crate::util::mat::{MatF32, MatI32, MatU8};

/// Walk `0..total` in blocks of `step`: yields `(start, len)` pairs with
/// `len == step` except possibly the last.
pub fn blocks(total: usize, step: usize) -> impl Iterator<Item = (usize, usize)> {
    assert!(step > 0, "block step must be positive");
    (0..total).step_by(step).map(move |s| (s, step.min(total - s)))
}

/// Number of u64 words assumed to fit in L1 (32 KiB).
const L1_WORDS: usize = 4096;

/// B-panel width in B rows for the cache-blocked column loop. `streams`
/// is the number of u64 planes per packed B row (1 for bit rows, 2 for
/// ternary plane rows). Kept even so the 2-column register tiles divide
/// the panel; clamped so tiny panels don't degenerate to per-column
/// overhead and huge `k` never yields a zero-width panel.
pub fn n_panel(words_per_row: usize, streams: usize) -> usize {
    let per_row = (words_per_row * streams).max(1);
    let p = (L1_WORDS / per_row).clamp(8, 256);
    p & !1
}

/// Largest depth (elements of K) whose in-panel accumulation is safe for
/// `kind` — the paper's Table II `k_max`: the 16-bit register bound for
/// the low-bit kinds (eq. (4)), the u32 bound for U8, the f32 exact-
/// integer bound for daBNN, unbounded for F32.
pub fn safe_k(kind: Kind) -> usize {
    kind.k_max().map(|v| v as usize).unwrap_or(usize::MAX)
}

/// K-panel configuration: the depth-blocking level of the execution
/// hierarchy (between the L1 column panels and the register tiles).
/// Depth is split into panels whose in-panel accumulator sums fit the
/// kind's [`safe_k`] bound; panel partials spill into 32-bit (or i64 /
/// f32) accumulators between panels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KPanel {
    /// One panel when the whole depth fits [`safe_k`]; otherwise the
    /// smallest number of evenly-sized panels that all fit the bound.
    #[default]
    Auto,
    /// Explicit panel depth in K elements (bits for the bit-packed kinds;
    /// rounded up to whole u64 words there). Clamped to `1..=safe_k`.
    Depth(usize),
}

impl KPanel {
    /// Even split of `total` units into the fewest panels of at most
    /// `bound` units each.
    fn even_split(total: usize, bound: usize) -> usize {
        if total == 0 {
            return 1;
        }
        let panels = total.div_ceil(bound.max(1));
        total.div_ceil(panels)
    }

    /// Resolve to a panel length in u64 words for a bit-packed kind with
    /// depth `k` bits packed into `total_words` words per row.
    ///
    /// A single panel spanning all words is safe whenever `k <= safe_k`,
    /// even if `total_words·64 > safe_k` — only real depth bits
    /// accumulate. Interior panels of a split cover their full `w·64`
    /// bits, so splits are bounded in words.
    pub fn words(self, k: usize, total_words: usize, kind: Kind) -> usize {
        let bound = safe_k(kind);
        let bound_words = (bound / 64).max(1);
        match self {
            KPanel::Auto if k <= bound => total_words.max(1),
            KPanel::Auto => Self::even_split(total_words, bound_words),
            // An explicit depth covering the whole (bound-safe) product
            // is a single panel; otherwise clamp to the word-safe bound.
            KPanel::Depth(d) if d >= k && k <= bound => total_words.max(1),
            KPanel::Depth(d) => d.div_ceil(64).clamp(1, bound_words),
        }
    }

    /// Resolve to a panel length in K elements for the byte/float kinds.
    pub fn elems(self, k: usize, kind: Kind) -> usize {
        let bound = safe_k(kind);
        match self {
            KPanel::Auto => Self::even_split(k, bound),
            KPanel::Depth(d) => d.clamp(1, bound),
        }
    }
}

/// Minimum C rows worth one worker: below this the pool-dispatch
/// overhead outweighs the kernel work.
const MIN_ROWS_PER_THREAD: usize = 8;

/// Threading configuration for a native multiplication: a **per-call
/// parallelism cap** resolved against the persistent worker pool
/// ([`crate::util::pool`]), not a spawn count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Threading {
    /// One thread (the default; runs inline, bit-identical to the plain
    /// kernels).
    #[default]
    Single,
    /// At most `n` concurrent bands (clamped to ≥ 1 and to the row count).
    Fixed(usize),
    /// The whole pool: [`crate::util::pool::default_workers`] — resolved
    /// **once** per process (`TBGEMM_POOL_THREADS` override, else
    /// `std::thread::available_parallelism`), never a syscall on the
    /// GEMM hot path.
    Auto,
}

impl Threading {
    /// Resolve to a worker count for a problem with `rows` output rows.
    pub fn worker_count(self, rows: usize) -> usize {
        let want = match self {
            Threading::Single => 1,
            Threading::Fixed(n) => n.max(1),
            Threading::Auto => crate::util::pool::default_workers(),
        };
        want.min(rows.div_ceil(MIN_ROWS_PER_THREAD).max(1))
    }
}

/// Split `data` (a `rows × cols` row-major output) into `threads`
/// contiguous row bands and run `f(row0, band_rows, band)` on each, in
/// parallel on the process-wide worker pool. With `threads <= 1` runs
/// inline on the caller (the `Single` / `TBGEMM_FORCE_SCALAR`-friendly
/// path — no pool interaction at all). The band split depends only on
/// `threads` and the shape, so results are bit-identical however the
/// pool schedules the bands.
pub fn parallel_row_bands<T, F>(data: &mut [T], cols: usize, rows: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    debug_assert_eq!(data.len(), rows * cols);
    if threads <= 1 || rows == 0 || cols == 0 {
        f(0, rows, data);
        return;
    }
    let band_rows = rows.div_ceil(threads);
    let f = &f;
    let tasks: Vec<crate::util::pool::ScopedTask<'_>> = data
        .chunks_mut(band_rows * cols)
        .enumerate()
        .map(|(b, band)| {
            let row0 = b * band_rows;
            let rows_here = band.len() / cols;
            Box::new(move || f(row0, rows_here, band)) as crate::util::pool::ScopedTask<'_>
        })
        .collect();
    crate::util::pool::global().run_scoped(tasks);
}

// ---- threaded, K-paneled drivers ---------------------------------------
//
// Each `*_gemm_kp_mt` driver composes all four hierarchy levels: row
// bands (threads) → L1 column panels → K panels → register tiles. The
// `*_gemm_mt` forms are the production entry points and delegate with
// `KPanel::Auto`, which resolves to a single panel whenever the depth
// fits the kind's `safe_k` bound — making them bit-identical to the
// unpaneled tiled kernels there, and exact beyond it for the integer
// kinds (i32/i64 spill; daBNN's f32 spill stays exact only while the
// total popcount fits f32's integer range, K < 2²⁴).

/// Binary GEMM, K-paneled + tiled + cache-blocked + threaded.
pub(crate) fn bnn_gemm_kp_mt(a: &BitRows, bt: &BitRows, c: &mut MatI32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::Bnn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            // One panel spans the whole depth: the unpaneled band is the
            // same computation without the zero-fill + spill passes.
            kernels::bnn_band(a, bt, row0, rows, band);
        } else {
            kernels::bnn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// Binary GEMM, tiled + cache-blocked + threaded over row bands.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn bnn_gemm_mt(a: &BitRows, bt: &BitRows, c: &mut MatI32, threading: Threading) {
    bnn_gemm_kp_mt(a, bt, c, threading, KPanel::Auto);
}

/// Binary GEMM with the widened 4×4 register tile
/// ([`crate::gemm::plan::Tile::Wide`]) on the shallow-K path. Deep-K
/// products (more than one K panel) fall back to the 4×2 spill kernel,
/// so results are bit-identical to [`bnn_gemm_kp_mt`] everywhere.
pub(crate) fn bnn_gemm_wide_mt(a: &BitRows, bt: &BitRows, c: &mut MatI32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::Bnn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::bnn_band_wide(a, bt, row0, rows, band);
        } else {
            kernels::bnn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// Ternary GEMM, K-paneled + tiled + cache-blocked + threaded.
pub(crate) fn tnn_gemm_kp_mt(a: &PlaneRows, bt: &PlaneRows, c: &mut MatI32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::Tnn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::tnn_band(a, bt, row0, rows, band);
        } else {
            kernels::tnn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// Ternary GEMM, tiled + cache-blocked + threaded over row bands.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn tnn_gemm_mt(a: &PlaneRows, bt: &PlaneRows, c: &mut MatI32, threading: Threading) {
    tnn_gemm_kp_mt(a, bt, c, threading, KPanel::Auto);
}

/// Ternary GEMM with the widened 2×4 register tile
/// ([`crate::gemm::plan::Tile::Wide`]) on the shallow-K path. Deep-K
/// products (more than one K panel) fall back to the 2×2 spill kernel,
/// so results are bit-identical to [`tnn_gemm_kp_mt`] everywhere.
pub(crate) fn tnn_gemm_wide_mt(a: &PlaneRows, bt: &PlaneRows, c: &mut MatI32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::Tnn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::tnn_band_wide(a, bt, row0, rows, band);
        } else {
            kernels::tnn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// Ternary-binary GEMM, K-paneled + tiled + cache-blocked + threaded.
pub(crate) fn tbn_gemm_kp_mt(a: &PlaneRows, bt: &BitRows, c: &mut MatI32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::Tbn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::tbn_band(a, bt, row0, rows, band);
        } else {
            kernels::tbn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// Ternary-binary GEMM, tiled + cache-blocked + threaded over row bands.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn tbn_gemm_mt(a: &PlaneRows, bt: &BitRows, c: &mut MatI32, threading: Threading) {
    tbn_gemm_kp_mt(a, bt, c, threading, KPanel::Auto);
}

/// daBNN-style binary GEMM, K-paneled + threaded. f32 popcount partials
/// are exact integers while sums stay below 2²⁴ (total K < 2²⁴, far
/// above any real im2col depth), so results are bit-identical to
/// [`kernels::dabnn_gemm`] at any thread count and panel size there.
pub(crate) fn dabnn_gemm_kp_mt(a: &BitRows, bt: &BitRows, c: &mut MatF32, threading: Threading, k_panel: KPanel) {
    assert_eq!(a.k, bt.k, "depth mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows));
    let threads = threading.worker_count(a.rows);
    let kpw = k_panel.words(a.k, a.words_per_row, Kind::DaBnn);
    let single = kpw >= a.words_per_row;
    parallel_row_bands(&mut c.data, bt.rows, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::dabnn_band(a, bt, row0, rows, band);
        } else {
            kernels::dabnn_band_kp(a, bt, row0, rows, band, kpw);
        }
    });
}

/// f32 GEMM, K-paneled + threaded. With `KPanel::Auto` the depth stays a
/// single panel (no f32 safe-K bound), keeping results bit-identical to
/// [`kernels::f32_gemm`]; explicit panels change rounding association.
pub(crate) fn f32_gemm_kp_mt(
    a: &MatF32,
    b_panels: &[Vec<f32>],
    n: usize,
    c: &mut MatF32,
    threading: Threading,
    k_panel: KPanel,
) {
    assert_eq!((c.rows, c.cols), (a.rows, n));
    let threads = threading.worker_count(a.rows);
    let kp = k_panel.elems(a.cols, Kind::F32);
    let single = kp >= a.cols;
    parallel_row_bands(&mut c.data, n, a.rows, threads, |row0, rows, band| {
        if single {
            kernels::f32_band(a, b_panels, n, row0, rows, band);
        } else {
            kernels::f32_band_kp(a, b_panels, n, row0, rows, band, kp);
        }
    });
}

/// u8 GEMM with zero-point compensation, K-paneled + threaded: u32
/// in-panel accumulation, i64 spill and epilogue (exact past the u32
/// depth bound where the unpaneled kernel would wrap).
#[allow(clippy::too_many_arguments)]
pub(crate) fn u8_gemm_kp_mt(
    a: &MatU8,
    b_panels: &[Vec<u8>],
    n: usize,
    za: i32,
    zb: i32,
    col_sums: &[i32],
    c: &mut MatI32,
    threading: Threading,
    k_panel: KPanel,
) {
    assert_eq!((c.rows, c.cols), (a.rows, n));
    let threads = threading.worker_count(a.rows);
    let kp = k_panel.elems(a.cols, Kind::U8);
    parallel_row_bands(&mut c.data, n, a.rows, threads, |row0, rows, band| {
        kernels::u8_band_kp(a, b_panels, n, za, zb, col_sums, row0, rows, band, kp);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::native::kernels::{bnn_gemm, tbn_gemm, tnn_gemm};
    use crate::util::mat::MatI8;
    use crate::util::Rng;

    #[test]
    fn blocks_cover_exactly_once() {
        for total in [0usize, 1, 7, 8, 9, 63, 64, 65, 200] {
            for step in [1usize, 2, 8, 64] {
                let mut seen = vec![false; total];
                for (s, l) in blocks(total, step) {
                    assert!(l >= 1 && l <= step);
                    for x in s..s + l {
                        assert!(!seen[x], "double cover at {x}");
                        seen[x] = true;
                    }
                }
                assert!(seen.iter().all(|&b| b), "total={total} step={step}");
            }
        }
    }

    #[test]
    fn n_panel_even_and_bounded() {
        for wpr in [0usize, 1, 2, 8, 32, 100, 10_000] {
            for streams in [1usize, 2] {
                let p = n_panel(wpr, streams);
                assert!(p >= 8 && p <= 256, "wpr={wpr} streams={streams} p={p}");
                assert_eq!(p % 2, 0);
            }
        }
    }

    #[test]
    fn worker_count_clamps() {
        assert_eq!(Threading::Single.worker_count(1000), 1);
        assert_eq!(Threading::Fixed(0).worker_count(1000), 1);
        assert_eq!(Threading::Fixed(4).worker_count(1000), 4);
        // Not more workers than 8-row tiles of work.
        assert_eq!(Threading::Fixed(64).worker_count(16), 2);
        assert_eq!(Threading::Fixed(3).worker_count(0), 1);
        assert!(Threading::Auto.worker_count(1_000_000) >= 1);
    }

    /// Satellite pin: `Auto` resolves to the pool's cached size — one
    /// process-wide resolution (no per-call `available_parallelism`
    /// syscall), stable across calls, still clamped by the row count.
    #[test]
    fn auto_equals_cached_pool_resolution() {
        let cached = crate::util::pool::default_workers();
        for _ in 0..4 {
            assert_eq!(Threading::Auto.worker_count(1 << 20), cached);
        }
        assert_eq!(Threading::Auto.worker_count(8), 1);
    }

    #[test]
    fn parallel_row_bands_partitions_rows() {
        for rows in [0usize, 1, 5, 8, 17, 64] {
            for threads in 1..=8usize {
                let cols = 3;
                let mut data = vec![0u32; rows * cols];
                parallel_row_bands(&mut data, cols, rows, threads, |row0, band_rows, band| {
                    assert_eq!(band.len(), band_rows * cols);
                    for r in 0..band_rows {
                        for c in 0..cols {
                            band[r * cols + c] += (row0 + r) as u32 + 1;
                        }
                    }
                });
                for r in 0..rows {
                    for c in 0..cols {
                        assert_eq!(data[r * cols + c], r as u32 + 1, "rows={rows} threads={threads} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn safe_k_matches_paper_table2() {
        assert_eq!(safe_k(Kind::Bnn), 32767);
        assert_eq!(safe_k(Kind::Tnn), 32767);
        assert_eq!(safe_k(Kind::Tbn), 32767);
        assert_eq!(safe_k(Kind::U8), 66051);
        assert_eq!(safe_k(Kind::DaBnn), (1 << 23) - 1);
        assert_eq!(safe_k(Kind::F32), usize::MAX);
    }

    #[test]
    fn kpanel_auto_splits_evenly_below_bound() {
        // Any depth up to safe_k = 32767 is a single panel spanning all
        // words — including 32767 bits in 512 words (only real depth
        // bits accumulate).
        assert_eq!(KPanel::Auto.words(32704, 511, Kind::Bnn), 511);
        assert_eq!(KPanel::Auto.words(32767, 512, Kind::Bnn), 512);
        // One bit past the bound splits; interior panels cover their
        // full w·64 bits, so splits use the 511-word bound.
        assert_eq!(KPanel::Auto.words(32768, 512, Kind::Bnn), 256);
        // 1023 words of real depth need 3 panels under the 511-word
        // bound → 341 each.
        assert_eq!(KPanel::Auto.words(1023 * 64, 1023, Kind::Tnn), 341);
        assert_eq!(KPanel::Auto.words(0, 0, Kind::Bnn), 1);
        // Byte kinds split on element counts.
        assert_eq!(KPanel::Auto.elems(66051, Kind::U8), 66051);
        assert_eq!(KPanel::Auto.elems(66052, Kind::U8), 33026);
        assert_eq!(KPanel::Auto.elems(1 << 20, Kind::F32), 1 << 20);
    }

    #[test]
    fn kpanel_depth_rounds_and_clamps() {
        assert_eq!(KPanel::Depth(1).words(6400, 100, Kind::Bnn), 1);
        assert_eq!(KPanel::Depth(64).words(6400, 100, Kind::Bnn), 1);
        assert_eq!(KPanel::Depth(65).words(6400, 100, Kind::Bnn), 2);
        // An explicit depth covering a bound-safe product: one panel.
        assert_eq!(KPanel::Depth(1 << 30).words(1000, 16, Kind::Bnn), 16);
        // Requests above the safe bound on a deep product clamp to it.
        assert_eq!(KPanel::Depth(1 << 30).words(1 << 26, 1 << 20, Kind::Bnn), 511);
        assert_eq!(KPanel::Depth(1 << 30).elems(1 << 20, Kind::U8), 66051);
        assert_eq!(KPanel::Depth(0).elems(10, Kind::U8), 1);
    }

    /// K-paneled drivers are bit-identical to the unpaneled tiled kernels
    /// at every panel size, including panels of one word and panels
    /// spanning the whole depth, at 1 and 4 threads.
    #[test]
    fn kp_matches_tiled_all_panel_sizes() {
        let mut rng = Rng::new(0xB10D);
        let (m, n, k) = (9usize, 7usize, 450usize); // 8 words incl. partial last
        let ab1 = MatI8::random_binary(m, k, &mut rng);
        let bb1 = MatI8::random_binary(k, n, &mut rng);
        let at = MatI8::random_ternary(m, k, &mut rng);
        let bt3 = MatI8::random_ternary(k, n, &mut rng);
        let a_bits = BitRows::from_binary(&ab1);
        let b_bits = BitRows::from_binary_transposed(&bb1);
        let a_planes = PlaneRows::from_ternary(&at);
        let b_planes = PlaneRows::from_ternary_transposed(&bt3);

        let mut want_bnn = MatI32::zeros(m, n);
        bnn_gemm(&a_bits, &b_bits, &mut want_bnn);
        let mut want_tnn = MatI32::zeros(m, n);
        tnn_gemm(&a_planes, &b_planes, &mut want_tnn);
        let mut want_tbn = MatI32::zeros(m, n);
        tbn_gemm(&a_planes, &b_bits, &mut want_tbn);

        for depth in [1usize, 63, 64, 65, 128, 200, 449, 450, 1000] {
            for threads in [1usize, 4] {
                let th = Threading::Fixed(threads);
                let kp = KPanel::Depth(depth);
                let mut c = MatI32::zeros(m, n);
                bnn_gemm_kp_mt(&a_bits, &b_bits, &mut c, th, kp);
                assert_eq!(c.data, want_bnn.data, "bnn depth={depth} t={threads}");
                let mut c = MatI32::zeros(m, n);
                tnn_gemm_kp_mt(&a_planes, &b_planes, &mut c, th, kp);
                assert_eq!(c.data, want_tnn.data, "tnn depth={depth} t={threads}");
                let mut c = MatI32::zeros(m, n);
                tbn_gemm_kp_mt(&a_planes, &b_bits, &mut c, th, kp);
                assert_eq!(c.data, want_tbn.data, "tbn depth={depth} t={threads}");
            }
        }
    }

    /// Threaded low-bit GEMM is bit-identical to the single-threaded
    /// tiled kernels at 1–8 threads on shapes that don't divide evenly.
    #[test]
    fn mt_matches_single_thread() {
        let mut rng = Rng::new(0xB10C);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (13, 7, 100), (33, 19, 129), (64, 24, 256)] {
            let ab1 = MatI8::random_binary(m, k, &mut rng);
            let bb1 = MatI8::random_binary(k, n, &mut rng);
            let at = MatI8::random_ternary(m, k, &mut rng);
            let bt3 = MatI8::random_ternary(k, n, &mut rng);
            let a_bits = BitRows::from_binary(&ab1);
            let b_bits = BitRows::from_binary_transposed(&bb1);
            let a_planes = PlaneRows::from_ternary(&at);
            let b_planes = PlaneRows::from_ternary_transposed(&bt3);

            let mut want_bnn = MatI32::zeros(m, n);
            bnn_gemm(&a_bits, &b_bits, &mut want_bnn);
            let mut want_tnn = MatI32::zeros(m, n);
            tnn_gemm(&a_planes, &b_planes, &mut want_tnn);
            let mut want_tbn = MatI32::zeros(m, n);
            tbn_gemm(&a_planes, &b_bits, &mut want_tbn);

            for threads in 1..=8usize {
                let th = Threading::Fixed(threads);
                let mut c = MatI32::zeros(m, n);
                bnn_gemm_mt(&a_bits, &b_bits, &mut c, th);
                assert_eq!(c.data, want_bnn.data, "bnn m={m} n={n} k={k} t={threads}");
                let mut c = MatI32::zeros(m, n);
                tnn_gemm_mt(&a_planes, &b_planes, &mut c, th);
                assert_eq!(c.data, want_tnn.data, "tnn m={m} n={n} k={k} t={threads}");
                let mut c = MatI32::zeros(m, n);
                tbn_gemm_mt(&a_planes, &b_bits, &mut c, th);
                assert_eq!(c.data, want_tbn.data, "tbn m={m} n={n} k={k} t={threads}");
            }
        }
    }
}
