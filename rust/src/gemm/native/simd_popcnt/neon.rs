//! aarch64 NEON implementations of the vectorized popcount inner loops —
//! the paper's actual target ISA, realized with `std::arch::aarch64`
//! intrinsics instead of the instruction-exact emulator in
//! [`crate::simd`].
//!
//! Structure of every kernel, mirroring the paper's microkernel
//! accumulation discipline (§III-B, Table II):
//!
//! * product words via `veorq_u8` (binary XOR), `vandq_u8`/`vorrq_u8`
//!   (ternary plane products, eq. (7)) and `vbicq_u8` (ternary×binary
//!   selector form),
//! * per-byte counts via `vcntq_u8` — the NEON `CNT` the whole paper is
//!   built around,
//! * in-register 16-bit accumulation via `vpadalq_u8` (pairwise add
//!   and accumulate long, the `PADAL` of Table II),
//! * a u16→u32 spill via `vpadalq_u16` every [`SPILL_WORDS`] words, so
//!   the u16 lanes can never saturate regardless of slice length, and a
//!   final `vaddvq_u32` horizontal reduction.
//!
//! The mnemonics these intrinsics compile to are declared in
//! [`super::isa`] and pinned against the emulated microkernels' traced
//! streams by `tests/isa_parity.rs`; bit-identity with the scalar and
//! emulated paths is proven by the differential suite run under
//! `qemu-aarch64` in CI.
//!
//! NEON is a baseline aarch64 feature, so these functions need no
//! `target_feature` gating; they are `unsafe` because the inner loops
//! read through raw pointers under the length invariants the safe
//! wrappers in [`super`] debug-assert.

use std::arch::aarch64::*;

/// Words of one u16 accumulation block (2 u64 words per 16-byte step);
/// see the saturation math at the definition in [`super`]. The
/// spill-boundary differential test straddles this constant.
use super::NEON_SPILL_WORDS as SPILL_WORDS;

/// Unaligned 128-bit load of words `s[i..i + 2]` as sixteen bytes.
///
/// # Safety
/// `i + 2 <= s.len()` must hold — the callers iterate `i < pairs` with
/// `pairs = s.len() & !1`, which guarantees it.
#[inline]
unsafe fn loadu(s: &[u64], i: usize) -> uint8x16_t {
    // SAFETY: the caller guarantees `i + 2 <= s.len()`, so the sixteen
    // bytes at `s[i..i + 2]` are in bounds; `vld1q_u8` imposes no
    // alignment requirement beyond `u8`.
    unsafe { vld1q_u8(s.as_ptr().add(i) as *const u8) }
}

/// z⁺/z⁻ plane products of eq. (7):
/// `((x⁺∧y⁺)∨(x⁻∧y⁻), (x⁺∧y⁻)∨(x⁻∧y⁺))`. Safe: on aarch64 the
/// register-only NEON value intrinsics are safe functions (the baseline
/// feature is statically available; no memory is touched).
#[inline]
fn tnn_products(xp: uint8x16_t, xm: uint8x16_t, yp: uint8x16_t, ym: uint8x16_t) -> (uint8x16_t, uint8x16_t) {
    let zp = vorrq_u8(vandq_u8(xp, yp), vandq_u8(xm, ym));
    let zm = vorrq_u8(vandq_u8(xp, ym), vandq_u8(xm, yp));
    (zp, zm)
}

/// Ternary×binary products with bit-column `t` (1 encodes −1):
/// `((x⁺∧¬t)∨(x⁻∧t), (x⁺∧t)∨(x⁻∧¬t))`. Safe for the same reason as
/// [`tnn_products`].
#[inline]
fn tbn_products(xp: uint8x16_t, xm: uint8x16_t, t: uint8x16_t) -> (uint8x16_t, uint8x16_t) {
    let zp = vorrq_u8(vbicq_u8(xp, t), vandq_u8(xm, t));
    let zm = vorrq_u8(vandq_u8(xp, t), vbicq_u8(xm, t));
    (zp, zm)
}

pub unsafe fn xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = a.len();
        let pairs = n & !1;
        let mut total = vdupq_n_u32(0);
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut acc = vdupq_n_u16(0);
            while i < end {
                let x = veorq_u8(loadu(a, i), loadu(b, i));
                acc = vpadalq_u8(acc, vcntq_u8(x));
                i += 2;
            }
            total = vpadalq_u16(total, acc);
        }
        let mut s = vaddvq_u32(total);
        if n > pairs {
            s += (a[pairs] ^ b[pairs]).count_ones();
        }
        s
    }
}

pub unsafe fn xor_popcnt2(a: &[u64], b0: &[u64], b1: &[u64]) -> (u32, u32) {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = a.len();
        let pairs = n & !1;
        let mut t0 = vdupq_n_u32(0);
        let mut t1 = vdupq_n_u32(0);
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut acc0 = vdupq_n_u16(0);
            let mut acc1 = vdupq_n_u16(0);
            while i < end {
                let av = loadu(a, i);
                acc0 = vpadalq_u8(acc0, vcntq_u8(veorq_u8(av, loadu(b0, i))));
                acc1 = vpadalq_u8(acc1, vcntq_u8(veorq_u8(av, loadu(b1, i))));
                i += 2;
            }
            t0 = vpadalq_u16(t0, acc0);
            t1 = vpadalq_u16(t1, acc1);
        }
        let mut s0 = vaddvq_u32(t0);
        let mut s1 = vaddvq_u32(t1);
        if n > pairs {
            s0 += (a[pairs] ^ b0[pairs]).count_ones();
            s1 += (a[pairs] ^ b1[pairs]).count_ones();
        }
        (s0, s1)
    }
}

pub unsafe fn tnn_popcnt(ap: &[u64], am: &[u64], bp: &[u64], bm: &[u64]) -> (u32, u32) {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = ap.len();
        let pairs = n & !1;
        let mut tp = vdupq_n_u32(0);
        let mut tm = vdupq_n_u32(0);
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut accp = vdupq_n_u16(0);
            let mut accm = vdupq_n_u16(0);
            while i < end {
                let (zp, zm) = tnn_products(loadu(ap, i), loadu(am, i), loadu(bp, i), loadu(bm, i));
                accp = vpadalq_u8(accp, vcntq_u8(zp));
                accm = vpadalq_u8(accm, vcntq_u8(zm));
                i += 2;
            }
            tp = vpadalq_u16(tp, accp);
            tm = vpadalq_u16(tm, accm);
        }
        let mut p = vaddvq_u32(tp);
        let mut m = vaddvq_u32(tm);
        if n > pairs {
            let w = pairs;
            p += ((ap[w] & bp[w]) | (am[w] & bm[w])).count_ones();
            m += ((ap[w] & bm[w]) | (am[w] & bp[w])).count_ones();
        }
        (p, m)
    }
}

pub unsafe fn tbn_popcnt(ap: &[u64], am: &[u64], t: &[u64]) -> (u32, u32) {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = ap.len();
        let pairs = n & !1;
        let mut tp = vdupq_n_u32(0);
        let mut tm = vdupq_n_u32(0);
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut accp = vdupq_n_u16(0);
            let mut accm = vdupq_n_u16(0);
            while i < end {
                let (zp, zm) = tbn_products(loadu(ap, i), loadu(am, i), loadu(t, i));
                accp = vpadalq_u8(accp, vcntq_u8(zp));
                accm = vpadalq_u8(accm, vcntq_u8(zm));
                i += 2;
            }
            tp = vpadalq_u16(tp, accp);
            tm = vpadalq_u16(tm, accm);
        }
        let mut p = vaddvq_u32(tp);
        let mut m = vaddvq_u32(tm);
        if n > pairs {
            let w = pairs;
            p += ((ap[w] & !t[w]) | (am[w] & t[w])).count_ones();
            m += ((ap[w] & t[w]) | (am[w] & !t[w])).count_ones();
        }
        (p, m)
    }
}

pub unsafe fn xor_popcnt_4x2(a: [&[u64]; 4], b0: &[u64], b1: &[u64]) -> [[u32; 2]; 4] {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = b0.len();
        let pairs = n & !1;
        let mut total = [[vdupq_n_u32(0); 2]; 4];
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut acc = [[vdupq_n_u16(0); 2]; 4];
            while i < end {
                let bv0 = loadu(b0, i);
                let bv1 = loadu(b1, i);
                for r in 0..4 {
                    let av = loadu(a[r], i);
                    acc[r][0] = vpadalq_u8(acc[r][0], vcntq_u8(veorq_u8(av, bv0)));
                    acc[r][1] = vpadalq_u8(acc[r][1], vcntq_u8(veorq_u8(av, bv1)));
                }
                i += 2;
            }
            for r in 0..4 {
                for c in 0..2 {
                    total[r][c] = vpadalq_u16(total[r][c], acc[r][c]);
                }
            }
        }
        let mut s = [[0u32; 2]; 4];
        for r in 0..4 {
            for c in 0..2 {
                s[r][c] = vaddvq_u32(total[r][c]);
            }
            for t in pairs..n {
                s[r][0] += (a[r][t] ^ b0[t]).count_ones();
                s[r][1] += (a[r][t] ^ b1[t]).count_ones();
            }
        }
        s
    }
}

pub unsafe fn xor_popcnt_4x4(a: [&[u64]; 4], b: [&[u64]; 4]) -> [[u32; 4]; 4] {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = b[0].len();
        let pairs = n & !1;
        let mut total = [[vdupq_n_u32(0); 4]; 4];
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut acc = [[vdupq_n_u16(0); 4]; 4];
            while i < end {
                let bv = [loadu(b[0], i), loadu(b[1], i), loadu(b[2], i), loadu(b[3], i)];
                for r in 0..4 {
                    let av = loadu(a[r], i);
                    for c in 0..4 {
                        acc[r][c] = vpadalq_u8(acc[r][c], vcntq_u8(veorq_u8(av, bv[c])));
                    }
                }
                i += 2;
            }
            for r in 0..4 {
                for c in 0..4 {
                    total[r][c] = vpadalq_u16(total[r][c], acc[r][c]);
                }
            }
        }
        let mut s = [[0u32; 4]; 4];
        for r in 0..4 {
            for c in 0..4 {
                s[r][c] = vaddvq_u32(total[r][c]);
                for t in pairs..n {
                    s[r][c] += (a[r][t] ^ b[c][t]).count_ones();
                }
            }
        }
        s
    }
}

pub unsafe fn tnn_popcnt_2x2(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp0: &[u64],
    bm0: &[u64],
    bp1: &[u64],
    bm1: &[u64],
) -> [[(u32, u32); 2]; 2] {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = bp0.len();
        let pairs = n & !1;
        let mut tp = [[vdupq_n_u32(0); 2]; 2];
        let mut tm = [[vdupq_n_u32(0); 2]; 2];
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut accp = [[vdupq_n_u16(0); 2]; 2];
            let mut accm = [[vdupq_n_u16(0); 2]; 2];
            while i < end {
                let yp = [loadu(bp0, i), loadu(bp1, i)];
                let ym = [loadu(bm0, i), loadu(bm1, i)];
                for r in 0..2 {
                    let xp = loadu(ap[r], i);
                    let xm = loadu(am[r], i);
                    for c in 0..2 {
                        let (zp, zm) = tnn_products(xp, xm, yp[c], ym[c]);
                        accp[r][c] = vpadalq_u8(accp[r][c], vcntq_u8(zp));
                        accm[r][c] = vpadalq_u8(accm[r][c], vcntq_u8(zm));
                    }
                }
                i += 2;
            }
            for r in 0..2 {
                for c in 0..2 {
                    tp[r][c] = vpadalq_u16(tp[r][c], accp[r][c]);
                    tm[r][c] = vpadalq_u16(tm[r][c], accm[r][c]);
                }
            }
        }
        let mut s = [[(0u32, 0u32); 2]; 2];
        let cols = [(bp0, bm0), (bp1, bm1)];
        for r in 0..2 {
            for (c, &(bp, bm)) in cols.iter().enumerate() {
                let (mut p, mut m) = (vaddvq_u32(tp[r][c]), vaddvq_u32(tm[r][c]));
                for t in pairs..n {
                    p += ((ap[r][t] & bp[t]) | (am[r][t] & bm[t])).count_ones();
                    m += ((ap[r][t] & bm[t]) | (am[r][t] & bp[t])).count_ones();
                }
                s[r][c] = (p, m);
            }
        }
        s
    }
}

pub unsafe fn tnn_popcnt_2x4(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp: [&[u64]; 4],
    bm: [&[u64]; 4],
) -> [[(u32, u32); 4]; 2] {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = bp[0].len();
        let pairs = n & !1;
        let mut tp = [[vdupq_n_u32(0); 4]; 2];
        let mut tm = [[vdupq_n_u32(0); 4]; 2];
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut accp = [[vdupq_n_u16(0); 4]; 2];
            let mut accm = [[vdupq_n_u16(0); 4]; 2];
            while i < end {
                let yp = [loadu(bp[0], i), loadu(bp[1], i), loadu(bp[2], i), loadu(bp[3], i)];
                let ym = [loadu(bm[0], i), loadu(bm[1], i), loadu(bm[2], i), loadu(bm[3], i)];
                for r in 0..2 {
                    let xp = loadu(ap[r], i);
                    let xm = loadu(am[r], i);
                    for c in 0..4 {
                        let (zp, zm) = tnn_products(xp, xm, yp[c], ym[c]);
                        accp[r][c] = vpadalq_u8(accp[r][c], vcntq_u8(zp));
                        accm[r][c] = vpadalq_u8(accm[r][c], vcntq_u8(zm));
                    }
                }
                i += 2;
            }
            for r in 0..2 {
                for c in 0..4 {
                    tp[r][c] = vpadalq_u16(tp[r][c], accp[r][c]);
                    tm[r][c] = vpadalq_u16(tm[r][c], accm[r][c]);
                }
            }
        }
        let mut s = [[(0u32, 0u32); 4]; 2];
        for r in 0..2 {
            for c in 0..4 {
                let (mut p, mut m) = (vaddvq_u32(tp[r][c]), vaddvq_u32(tm[r][c]));
                for t in pairs..n {
                    p += ((ap[r][t] & bp[c][t]) | (am[r][t] & bm[c][t])).count_ones();
                    m += ((ap[r][t] & bm[c][t]) | (am[r][t] & bp[c][t])).count_ones();
                }
                s[r][c] = (p, m);
            }
        }
        s
    }
}

pub unsafe fn tbn_popcnt_2x2(ap: [&[u64]; 2], am: [&[u64]; 2], t0: &[u64], t1: &[u64]) -> [[(u32, u32); 2]; 2] {
    // SAFETY: the wrapper debug-asserts that all slices share length
    // `n`, so every `loadu` — reading words `i..i + 2` only while
    // `i < pairs` with `pairs = n & !1` — is in bounds for each slice,
    // and the scalar tail index `pairs` is below `n`. NEON itself is a
    // baseline aarch64 feature (no runtime detection required).
    unsafe {
        let n = t0.len();
        let pairs = n & !1;
        let mut tp = [[vdupq_n_u32(0); 2]; 2];
        let mut tm = [[vdupq_n_u32(0); 2]; 2];
        let mut i = 0;
        while i < pairs {
            let end = usize::min(i + SPILL_WORDS, pairs);
            let mut accp = [[vdupq_n_u16(0); 2]; 2];
            let mut accm = [[vdupq_n_u16(0); 2]; 2];
            while i < end {
                let tv = [loadu(t0, i), loadu(t1, i)];
                for r in 0..2 {
                    let xp = loadu(ap[r], i);
                    let xm = loadu(am[r], i);
                    for c in 0..2 {
                        let (zp, zm) = tbn_products(xp, xm, tv[c]);
                        accp[r][c] = vpadalq_u8(accp[r][c], vcntq_u8(zp));
                        accm[r][c] = vpadalq_u8(accm[r][c], vcntq_u8(zm));
                    }
                }
                i += 2;
            }
            for r in 0..2 {
                for c in 0..2 {
                    tp[r][c] = vpadalq_u16(tp[r][c], accp[r][c]);
                    tm[r][c] = vpadalq_u16(tm[r][c], accm[r][c]);
                }
            }
        }
        let mut s = [[(0u32, 0u32); 2]; 2];
        let cols = [t0, t1];
        for r in 0..2 {
            for (c, &tw) in cols.iter().enumerate() {
                let (mut p, mut m) = (vaddvq_u32(tp[r][c]), vaddvq_u32(tm[r][c]));
                for w in pairs..n {
                    p += ((ap[r][w] & !tw[w]) | (am[r][w] & tw[w])).count_ones();
                    m += ((ap[r][w] & tw[w]) | (am[r][w] & !tw[w])).count_ones();
                }
                s[r][c] = (p, m);
            }
        }
        s
    }
}
