//! Native (portable-Rust) fast paths for every algorithm — the wall-clock
//! measurement substrate for the paper's Table III.
//!
//! The emulated microkernels in [`crate::gemm::micro`] reproduce the
//! paper's *instruction streams*; these paths reproduce the paper's
//! *arithmetic structure* — XOR + popcount for binary, the AND/OR plane
//! products for ternary, 16-bit-blocked accumulation for U4 — using
//! 64-bit words and `u64::count_ones`, which the host compiles to native
//! `popcnt`/vector instructions. Relative wall-clock between the seven
//! algorithms then reflects the same bits-per-operation and
//! memory-traffic ratios that drive the paper's measured Table III.
//!
//! Layout types ([`BitRows`], [`PlaneRows`]) hold bit-packed rows of the
//! left matrix and bit-packed *columns* of the right matrix (i.e. `B` is
//! stored transposed), so all inner loops stream contiguous words.

pub mod bits;
pub mod pack_fast;
pub mod simd_popcnt;
pub mod kernels;

pub use bits::{BitRows, PlaneRows};
pub use kernels::*;
