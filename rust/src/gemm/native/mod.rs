//! Native (portable-Rust) fast paths for every algorithm — the wall-clock
//! measurement substrate for the paper's Table III, organized as a
//! four-level blocked execution hierarchy.
//!
//! The emulated microkernels in [`crate::gemm::micro`] reproduce the
//! paper's *instruction streams*; these paths reproduce the paper's
//! *arithmetic structure* — XOR + popcount for binary, the AND/OR plane
//! products for ternary, 16-bit-blocked accumulation for U4 — using
//! 64-bit words and `u64::count_ones`, which the host compiles to native
//! `popcnt`/vector instructions. Relative wall-clock between the seven
//! algorithms then reflects the same bits-per-operation and
//! memory-traffic ratios that drive the paper's measured Table III.
//!
//! # The execution hierarchy
//!
//! From the outside in, a native multiplication is structured as four
//! blocking levels (register tile → K panel → N panel → row band, reading
//! inside-out):
//!
//! ```text
//! row band   (threads)   ┌──────────────────────────────────────────┐
//!                        │ N panel (L1)   ┌───────────────────────┐ │
//!                        │                │ K panel (16-bit safe) │ │
//!                        │                │  ┌─────────────────┐  │ │
//!                        │                │  │ register tile   │  │ │
//!                        │                │  │ 4×2 / 2×2 / 4×8 │  │ │
//!                        │                │  └─────────────────┘  │ │
//!                        │                │   spill → i32 band    │ │
//!                        │                └───────────────────────┘ │
//!                        └──────────────────────────────────────────┘
//! ```
//!
//! 1. **Thread bands** ([`block::parallel_row_bands`]): C is split into
//!    contiguous row bands, one scoped worker thread per band (row count
//!    chosen by a [`block::Threading`] config). Rows of C are independent
//!    in every algorithm, so bands share nothing and results are
//!    bit-identical at any thread count.
//! 2. **Cache-blocked column panels** ([`block::blocks`] /
//!    [`block::n_panel`]): within a band, the column loop walks B in
//!    panels sized so a panel's packed words fit in L1; the panel then
//!    stays hot across the band's entire row loop instead of being
//!    re-streamed from memory once per A-row.
//! 3. **K panels** ([`block::KPanel`], `kernels::*_band_kp`): within a
//!    column panel, the depth loop is split into panels whose in-panel
//!    accumulator sums fit the kind's safe bound ([`block::safe_k`], the
//!    paper's Table II `k_max`); panel partials spill into the row band's
//!    i32 (daBNN: f32, U8: i64) accumulators between panels, and the
//!    per-kind epilogue runs once over the full-depth sums. Per-kind
//!    safe-K formula (eq. (4) family):
//!
//!    | kind            | in-panel accumulator    | safe K               |
//!    |-----------------|-------------------------|----------------------|
//!    | BNN / TNN / TBN | signed 16-bit (\|z\|≤1) | 2¹⁵ − 1 = 32767      |
//!    | U4              | u16 / (15·15)           | 291                  |
//!    | U8              | u32 / (255·255)         | 66051                |
//!    | daBNN           | f32 exact integers      | 2²³ − 1              |
//!    | F32             | f32 (lossy anyway)      | unbounded            |
//!
//!    `KPanel::Auto` resolves to a single panel whenever K fits the
//!    bound, and the drivers then dispatch straight to the unpaneled
//!    band kernels (no spill passes, trivially bit-identical; U8 is the
//!    deliberate exception — it always takes the paneled band, whose
//!    i64 epilogue is exact where `u8_band`'s i32 epilogue can wrap) —
//!    proven
//!    word-for-word by `tests/gemm_property.rs` — while the multi-panel
//!    spill makes deep products (K > 32767) exact where pure 16-bit
//!    accumulation would wrap (`tests/overflow_boundary.rs`). The
//!    `*_band` / `*_band_kp` kernel pairs are deliberate: `*_band` is
//!    the shallow-K fast path, `*_band_kp` the deep-K path; changes to
//!    the tile loops must be mirrored in both (the property suite pins
//!    them together).
//! 4. **Register tiles** (`kernels::*_band`): within a panel, outputs are
//!    computed as R×C tiles — 4×2 for BNN/daBNN, 2×2 for TNN/TBN (each
//!    ternary output carries two accumulators, z⁺ and z⁻), 4×8 for
//!    F32/U8 — with all accumulators live in registers. Each loaded A
//!    word is used C times and each B word R times, the same
//!    loads-per-operation reduction the paper's 16×8 NEON microkernel
//!    achieves with value broadcasting (§III-B).
//!
//! Below the tiles, the **vectorized inner dots** ([`simd_popcnt`]): on
//! aarch64 the per-tile word loop is real NEON — `veorq`/`vandq`/`vbicq`
//! products, `vcntq_u8` per-byte popcount, `vpadalq_u8` 16-bit
//! in-register accumulation, the paper's actual instruction diet — and
//! on x86-64 it is an AVX2 `vpshufb` nibble-LUT popcount (Mula's
//! method), with scalar `count_ones` fallback elsewhere or under
//! `TBGEMM_FORCE_SCALAR=1` (dispatch order documented in
//! [`simd_popcnt`]). Differential tests pin every arm to the scalar
//! path, and CI's cross-ISA lane runs the full suite under
//! `qemu-aarch64` so the NEON arm is continuously proven bit-identical
//! to the `Reference` and `Emulated` backends.
//!
//! The seed's one-output-at-a-time kernels survive as
//! `kernels::*_gemm_rowdot`; `benches/gemm_micro` tracks the tiled and
//! threaded speedup over them (through `Tile::Rowdot` plans) and emits
//! `BENCH_gemm.json` for trend tracking across PRs.
//!
//! Everything in this module is **crate-internal execution machinery**:
//! the public entry point is the plan/execute API
//! ([`crate::gemm::GemmPlan`]), which dispatches to these kernels as
//! [`crate::gemm::Backend::Native`]. Only the layout types
//! ([`BitRows`], [`PlaneRows`]), the config vocabulary ([`Threading`],
//! [`KPanel`], [`safe_k`]) and the vectorized primitives
//! ([`simd_popcnt`], [`pack_fast`], for the ablation benches) stay
//! public.
//!
//! Layout types ([`BitRows`], [`PlaneRows`]) hold bit-packed rows of the
//! left matrix and bit-packed *columns* of the right matrix (i.e. `B` is
//! stored transposed), so all inner loops stream contiguous words. Both
//! support allocation-free repacking (`repack_*`) into caller-owned
//! storage — the conv layers' scratch arenas
//! ([`crate::conv::conv2d::ConvScratch`]) rely on this to keep
//! steady-state forward passes heap-allocation-free.

pub mod bits;
pub mod block;
pub(crate) mod kernels;
pub mod pack_fast;
pub mod simd_popcnt;

pub use bits::{BitRows, PlaneRows};
pub use block::{safe_k, KPanel, Threading};
