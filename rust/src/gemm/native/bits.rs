//! Bit-packed storage for the native fast paths.

use crate::gemm::encode::{encode_binary, encode_ternary};
use crate::gemm::native::pack_fast;
use crate::util::mat::MatI8;

/// Rows of single-bit values packed into u64 words (LSB-first).
/// For the right matrix, pack the transpose so columns become rows.
#[derive(Clone, Debug)]
pub struct BitRows {
    pub rows: usize,
    pub k: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl BitRows {
    /// Pack binary values (`±1`) of `m` row-major into bit rows
    /// (vectorized: this runs on the request path for activations).
    pub fn from_binary(m: &MatI8) -> Self {
        debug_assert!(m.is_binary());
        let words = m.cols.div_ceil(64);
        let mut data = vec![0u64; m.rows * words];
        for r in 0..m.rows {
            pack_fast::pack_binary_row(m.row(r), &mut data[r * words..(r + 1) * words]);
        }
        BitRows { rows: m.rows, k: m.cols, words_per_row: words, data }
    }

    /// An empty packing, for use as reusable scratch via
    /// [`BitRows::repack_binary`].
    pub fn empty() -> Self {
        BitRows { rows: 0, k: 0, words_per_row: 0, data: Vec::new() }
    }

    /// Re-pack `m` into this storage, reusing the existing allocation
    /// (steady state: no heap allocation once capacity has grown to the
    /// largest shape seen). Equivalent to `*self = BitRows::from_binary(m)`.
    pub fn repack_binary(&mut self, m: &MatI8) {
        debug_assert!(m.is_binary());
        let words = m.cols.div_ceil(64);
        self.rows = m.rows;
        self.k = m.cols;
        self.words_per_row = words;
        self.data.clear();
        self.data.resize(m.rows * words, 0);
        for r in 0..m.rows {
            pack_fast::pack_binary_row(m.row(r), &mut self.data[r * words..(r + 1) * words]);
        }
    }

    /// Pack the transpose of `m` (columns become rows).
    pub fn from_binary_transposed(m: &MatI8) -> Self {
        Self::pack_t(m, |v| encode_binary(v) as u64)
    }

    fn pack_t(m: &MatI8, f: impl Fn(i8) -> u64) -> Self {
        let words = m.rows.div_ceil(64);
        let mut data = vec![0u64; m.cols * words];
        for c in 0..m.cols {
            for t in 0..m.rows {
                data[c * words + t / 64] |= f(m.get(t, c)) << (t % 64);
            }
        }
        BitRows { rows: m.cols, k: m.rows, words_per_row: words, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// K-sliced row view: words `w0..w0+wn` of row `r` — one K panel of
    /// the depth. The K-paneled kernels stream these windows so per-panel
    /// popcount sums stay within the 16-bit accumulation bound.
    #[inline]
    pub fn row_window(&self, r: usize, w0: usize, wn: usize) -> &[u64] {
        debug_assert!(w0 + wn <= self.words_per_row);
        &self.data[r * self.words_per_row + w0..r * self.words_per_row + w0 + wn]
    }
}

/// Rows of 2-bit ternary values as two bit planes (`+` and `−`).
#[derive(Clone, Debug)]
pub struct PlaneRows {
    pub rows: usize,
    pub k: usize,
    pub words_per_row: usize,
    pub plus: Vec<u64>,
    pub minus: Vec<u64>,
}

impl PlaneRows {
    /// Pack ternary values of `m` row-major into plane rows
    /// (vectorized: this runs on the request path for activations).
    pub fn from_ternary(m: &MatI8) -> Self {
        debug_assert!(m.is_ternary());
        let words = m.cols.div_ceil(64);
        let mut plus = vec![0u64; m.rows * words];
        let mut minus = vec![0u64; m.rows * words];
        for r in 0..m.rows {
            pack_fast::pack_ternary_row(
                m.row(r),
                &mut plus[r * words..(r + 1) * words],
                &mut minus[r * words..(r + 1) * words],
            );
        }
        PlaneRows { rows: m.rows, k: m.cols, words_per_row: words, plus, minus }
    }

    /// An empty packing, for use as reusable scratch via
    /// [`PlaneRows::repack_ternary`].
    pub fn empty() -> Self {
        PlaneRows { rows: 0, k: 0, words_per_row: 0, plus: Vec::new(), minus: Vec::new() }
    }

    /// Re-pack `m` into this storage, reusing the existing allocations.
    /// Equivalent to `*self = PlaneRows::from_ternary(m)`.
    pub fn repack_ternary(&mut self, m: &MatI8) {
        debug_assert!(m.is_ternary());
        let words = m.cols.div_ceil(64);
        self.rows = m.rows;
        self.k = m.cols;
        self.words_per_row = words;
        self.plus.clear();
        self.plus.resize(m.rows * words, 0);
        self.minus.clear();
        self.minus.resize(m.rows * words, 0);
        for r in 0..m.rows {
            pack_fast::pack_ternary_row(
                m.row(r),
                &mut self.plus[r * words..(r + 1) * words],
                &mut self.minus[r * words..(r + 1) * words],
            );
        }
    }

    /// Pack the transpose of `m` (columns become rows).
    pub fn from_ternary_transposed(m: &MatI8) -> Self {
        let words = m.rows.div_ceil(64);
        let mut plus = vec![0u64; m.cols * words];
        let mut minus = vec![0u64; m.cols * words];
        for c in 0..m.cols {
            for t in 0..m.rows {
                let (p, mi) = encode_ternary(m.get(t, c));
                plus[c * words + t / 64] |= (p as u64) << (t % 64);
                minus[c * words + t / 64] |= (mi as u64) << (t % 64);
            }
        }
        PlaneRows { rows: m.cols, k: m.rows, words_per_row: words, plus, minus }
    }

    #[inline]
    pub fn plus_row(&self, r: usize) -> &[u64] {
        &self.plus[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    #[inline]
    pub fn minus_row(&self, r: usize) -> &[u64] {
        &self.minus[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// K-sliced `+`-plane view: words `w0..w0+wn` of row `r` (one K panel).
    #[inline]
    pub fn plus_window(&self, r: usize, w0: usize, wn: usize) -> &[u64] {
        debug_assert!(w0 + wn <= self.words_per_row);
        &self.plus[r * self.words_per_row + w0..r * self.words_per_row + w0 + wn]
    }

    /// K-sliced `−`-plane view: words `w0..w0+wn` of row `r` (one K panel).
    #[inline]
    pub fn minus_window(&self, r: usize, w0: usize, wn: usize) -> &[u64] {
        debug_assert!(w0 + wn <= self.words_per_row);
        &self.minus[r * self.words_per_row + w0..r * self.words_per_row + w0 + wn]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bitrows_roundtrip_binary() {
        let mut rng = Rng::new(70);
        let m = MatI8::random_binary(5, 130, &mut rng);
        let b = BitRows::from_binary(&m);
        assert_eq!(b.words_per_row, 3);
        for r in 0..5 {
            for t in 0..130 {
                let bit = (b.row(r)[t / 64] >> (t % 64)) & 1;
                let want = if m.get(r, t) == 1 { 0 } else { 1 };
                assert_eq!(bit, want, "r={r} t={t}");
            }
        }
    }

    #[test]
    fn bitrows_transposed_swaps_axes() {
        let mut rng = Rng::new(71);
        let m = MatI8::random_binary(7, 9, &mut rng);
        let bt = BitRows::from_binary_transposed(&m);
        assert_eq!(bt.rows, 9);
        assert_eq!(bt.k, 7);
        for c in 0..9 {
            for t in 0..7 {
                let bit = (bt.row(c)[0] >> t) & 1;
                let want = if m.get(t, c) == 1 { 0 } else { 1 };
                assert_eq!(bit, want);
            }
        }
    }

    #[test]
    fn planerows_valid_encoding() {
        let mut rng = Rng::new(72);
        let m = MatI8::random_ternary(6, 100, &mut rng);
        let p = PlaneRows::from_ternary(&m);
        for r in 0..6 {
            // (1,1) never occurs
            for (pw, mw) in p.plus_row(r).iter().zip(p.minus_row(r)) {
                assert_eq!(pw & mw, 0);
            }
            for t in 0..100 {
                let pb = (p.plus_row(r)[t / 64] >> (t % 64)) & 1;
                let mb = (p.minus_row(r)[t / 64] >> (t % 64)) & 1;
                assert_eq!(pb as i8 - mb as i8, m.get(r, t));
            }
        }
    }

    /// Repacking into reused storage ≡ packing fresh, across shrinking and
    /// growing shapes, and reuses the allocation once capacity suffices.
    #[test]
    fn repack_matches_fresh_pack() {
        let mut rng = Rng::new(73);
        let mut bits = BitRows::empty();
        let mut planes = PlaneRows::empty();
        for &(rows, cols) in &[(5usize, 130usize), (2, 64), (9, 300), (1, 1), (9, 300)] {
            let mb = MatI8::random_binary(rows, cols, &mut rng);
            bits.repack_binary(&mb);
            let fresh = BitRows::from_binary(&mb);
            assert_eq!((bits.rows, bits.k, bits.words_per_row), (fresh.rows, fresh.k, fresh.words_per_row));
            assert_eq!(bits.data, fresh.data, "{rows}x{cols}");

            let mt = MatI8::random_ternary(rows, cols, &mut rng);
            planes.repack_ternary(&mt);
            let fresh = PlaneRows::from_ternary(&mt);
            assert_eq!((planes.rows, planes.k, planes.words_per_row), (fresh.rows, fresh.k, fresh.words_per_row));
            assert_eq!(planes.plus, fresh.plus);
            assert_eq!(planes.minus, fresh.minus);
        }
        // Steady state: same shape twice must not reallocate.
        let m = MatI8::random_binary(9, 300, &mut rng);
        bits.repack_binary(&m);
        let ptr = bits.data.as_ptr();
        bits.repack_binary(&m);
        assert_eq!(bits.data.as_ptr(), ptr, "repack reallocated at steady state");
    }

    /// K-sliced windows are exactly the corresponding sub-slices of the
    /// full rows, for every window position and length.
    #[test]
    fn row_windows_match_row_slices() {
        let mut rng = Rng::new(74);
        let mb = MatI8::random_binary(4, 300, &mut rng);
        let bits = BitRows::from_binary(&mb);
        let mt = MatI8::random_ternary(4, 300, &mut rng);
        let planes = PlaneRows::from_ternary(&mt);
        let w = bits.words_per_row;
        assert_eq!(w, 5);
        for r in 0..4 {
            for w0 in 0..w {
                for wn in 0..=(w - w0) {
                    assert_eq!(bits.row_window(r, w0, wn), &bits.row(r)[w0..w0 + wn]);
                    assert_eq!(planes.plus_window(r, w0, wn), &planes.plus_row(r)[w0..w0 + wn]);
                    assert_eq!(planes.minus_window(r, w0, wn), &planes.minus_row(r)[w0..w0 + wn]);
                }
            }
        }
    }

    #[test]
    fn padding_bits_are_zero() {
        let m = MatI8::from_fn(1, 65, |_, _| -1);
        let b = BitRows::from_binary(&m);
        // Bits 65..128 of the second word must be zero.
        assert_eq!(b.row(0)[1] >> 1, 0);
    }
}
