//! Vectorized bit/plane packing (the PackNRowsA of the native path).
//!
//! Profiling the Table III harness showed the naive per-element packing
//! loop consuming ~80% of the timed region for TNN/TBN/BNN — the paper's
//! packing is a handful of byte shuffles per 128 values, so a scalar
//! `for` over elements badly misrepresents the algorithm. These routines
//! pack 32 values per instruction pair with SSE2/AVX2 compare+movemask
//! (with a branchless scalar fallback), bringing packing back to the
//! small fraction of runtime it occupies in the paper.
//!
//! The wrappers here share [`super::simd_popcnt`]'s `simd_dispatch!`
//! preamble (forced scalar → best native SIMD arm → scalar), in its
//! no-NEON form: the movemask trick has no single-instruction NEON
//! equivalent, and on aarch64 the branchless scalar loop is already a
//! small fraction of kernel time. The `TBGEMM_FORCE_SCALAR=1` override
//! applies to these wrappers too, so the scalar CI lane covers packing
//! as well as the popcount loops.

use crate::gemm::native::simd_popcnt::{force_scalar, simd_dispatch};

/// Pack one row of binary values (`±1`, encoding `1→0, −1→1`) into bit
/// words (LSB-first). `out` must hold `ceil(row.len()/64)` words and is
/// fully overwritten.
pub fn pack_binary_row(row: &[i8], out: &mut [u64]) {
    debug_assert!(out.len() >= row.len().div_ceil(64));
    simd_dispatch!(
        avx2: avx2::pack_binary_row(row, out),
        scalar: scalar_pack_binary_row(row, out),
    )
}

/// Pack one row of ternary values into its two planes.
pub fn pack_ternary_row(row: &[i8], plus: &mut [u64], minus: &mut [u64]) {
    debug_assert!(plus.len() >= row.len().div_ceil(64));
    simd_dispatch!(
        avx2: avx2::pack_ternary_row(row, plus, minus),
        scalar: scalar_pack_ternary_row(row, plus, minus),
    )
}

pub fn scalar_pack_binary_row(row: &[i8], out: &mut [u64]) {
    for (w, chunk) in row.chunks(64).enumerate() {
        let mut bits = 0u64;
        for (i, &v) in chunk.iter().enumerate() {
            // sign bit of the i8 is exactly the encoding (−1 → 1).
            bits |= (((v as u8) >> 7) as u64) << i;
        }
        out[w] = bits;
    }
    for w in out.iter_mut().skip(row.len().div_ceil(64)) {
        *w = 0;
    }
}

pub fn scalar_pack_ternary_row(row: &[i8], plus: &mut [u64], minus: &mut [u64]) {
    for (w, chunk) in row.chunks(64).enumerate() {
        let mut p = 0u64;
        let mut m = 0u64;
        for (i, &v) in chunk.iter().enumerate() {
            p |= ((v > 0) as u64) << i;
            m |= (((v as u8) >> 7) as u64) << i;
        }
        plus[w] = p;
        minus[w] = m;
    }
    for w in row.len().div_ceil(64)..plus.len() {
        plus[w] = 0;
        minus[w] = 0;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// movemask of the sign bits of 32 i8 values = 32 bits of the binary
    /// encoding in one instruction.
    #[inline]
    unsafe fn sign_mask32(p: *const i8) -> u32 {
        // SAFETY: the caller guarantees AVX2 and 32 readable bytes at `p`;
        // `_mm256_loadu_si256` imposes no alignment requirement.
        unsafe {
            let v = _mm256_loadu_si256(p as *const __m256i);
            _mm256_movemask_epi8(v) as u32
        }
    }

    /// movemask of (v > 0) for 32 i8 values.
    #[inline]
    unsafe fn pos_mask32(p: *const i8) -> u32 {
        // SAFETY: the caller guarantees AVX2 and 32 readable bytes at `p`;
        // `_mm256_loadu_si256` imposes no alignment requirement.
        unsafe {
            let v = _mm256_loadu_si256(p as *const __m256i);
            let gt = _mm256_cmpgt_epi8(v, _mm256_setzero_si256());
            _mm256_movemask_epi8(gt) as u32
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_binary_row(row: &[i8], out: &mut [u64]) {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in. The mask helpers read 32 bytes at `base` and `base + 32`,
        // in bounds because the loop guard holds `(w + 1) * 64 <= n`; all
        // output writes are bounds-checked slice indexing.
        unsafe {
            let n = row.len();
            let words = n.div_ceil(64);
            let mut w = 0;
            while (w + 1) * 64 <= n {
                let base = row.as_ptr().add(w * 64);
                out[w] = sign_mask32(base) as u64 | ((sign_mask32(base.add(32)) as u64) << 32);
                w += 1;
            }
            if w < words {
                let mut bits = 0u64;
                for (i, &v) in row[w * 64..].iter().enumerate() {
                    bits |= (((v as u8) >> 7) as u64) << i;
                }
                out[w] = bits;
                w += 1;
            }
            for o in out.iter_mut().skip(w) {
                *o = 0;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_ternary_row(row: &[i8], plus: &mut [u64], minus: &mut [u64]) {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in. The mask helpers read 32 bytes at `base` and `base + 32`,
        // in bounds because the loop guard holds `(w + 1) * 64 <= n`; all
        // output writes are bounds-checked slice indexing.
        unsafe {
            let n = row.len();
            let words = n.div_ceil(64);
            let mut w = 0;
            while (w + 1) * 64 <= n {
                let base = row.as_ptr().add(w * 64);
                plus[w] = pos_mask32(base) as u64 | ((pos_mask32(base.add(32)) as u64) << 32);
                minus[w] = sign_mask32(base) as u64 | ((sign_mask32(base.add(32)) as u64) << 32);
                w += 1;
            }
            if w < words {
                let mut p = 0u64;
                let mut m = 0u64;
                for (i, &v) in row[w * 64..].iter().enumerate() {
                    p |= ((v > 0) as u64) << i;
                    m |= (((v as u8) >> 7) as u64) << i;
                }
                plus[w] = p;
                minus[w] = m;
                w += 1;
            }
            for i in w..plus.len() {
                plus[i] = 0;
                minus[i] = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Upper bound of the length sweeps: natively 200 covers the main
    /// loop, the 64-boundary and every tail size; under Miri 70 keeps
    /// one full 64-element word plus every tail size while bounding the
    /// interpreter's wall-clock.
    fn sweep_max() -> usize {
        if cfg!(miri) {
            70
        } else {
            200
        }
    }

    /// Differential: vectorized ≡ scalar on every length in the sweep
    /// (covers main loop, 64-boundary, and all tail sizes).
    #[test]
    fn binary_pack_matches_scalar() {
        let mut rng = Rng::new(0xFA0);
        for n in 0usize..=sweep_max() {
            let row: Vec<i8> = (0..n).map(|_| rng.binary()).collect();
            let words = n.div_ceil(64).max(1);
            let a_init = 0xAAu64.wrapping_mul(0x0101_0101_0101_0101);
            let mut a = vec![a_init; words];
            let mut b = a.clone();
            pack_binary_row(&row, &mut a);
            scalar_pack_binary_row(&row, &mut b);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn ternary_pack_matches_scalar() {
        let mut rng = Rng::new(0xFA1);
        for n in 0usize..=sweep_max() {
            let row: Vec<i8> = (0..n).map(|_| rng.ternary()).collect();
            let words = n.div_ceil(64).max(1);
            let (mut p1, mut m1) = (vec![1u64; words], vec![2u64; words]);
            let (mut p2, mut m2) = (vec![3u64; words], vec![4u64; words]);
            pack_ternary_row(&row, &mut p1, &mut m1);
            scalar_pack_ternary_row(&row, &mut p2, &mut m2);
            assert_eq!((p1, m1), (p2, m2), "n={n}");
        }
    }

    #[test]
    fn encoding_semantics() {
        let row = [1i8, -1, 1, 1, -1];
        let mut out = vec![0u64; 1];
        pack_binary_row(&row, &mut out);
        assert_eq!(out[0], 0b10010);
        let trow = [1i8, 0, -1];
        let (mut p, mut m) = (vec![0u64; 1], vec![0u64; 1]);
        pack_ternary_row(&trow, &mut p, &mut m);
        assert_eq!(p[0], 0b001);
        assert_eq!(m[0], 0b100);
    }
}
