//! Vectorized popcount inner loops for the low-bit kernels.
//!
//! The paper's microkernels lean on NEON `CNT` — a per-byte vector
//! popcount. Two real SIMD arms implement that idea here:
//!
//! * **aarch64 NEON** (the `neon` submodule) — the paper's actual ISA:
//!   `veorq` / `vandq` / `vbicq` / `vorrq` product words, `vcntq_u8` per-byte
//!   counts, `vpadalq_u8` pairwise accumulation into 16-bit lanes (the
//!   paper's in-register accumulation discipline, Table II), spilled
//!   into 32-bit lanes well before the 16-bit bound.
//! * **x86-64 AVX2** (the `avx2` submodule) — AVX2 has no vector popcount
//!   instruction, which is the main structural difference between an x86
//!   host and the paper's Cortex-A73. The classic `vpshufb` nibble-LUT
//!   popcount + `vpsadbw` horizontal accumulation (Mula's method) closes
//!   most of that gap, processing 256 bits of product per ~6
//!   instructions.
//!
//! # Dispatch order
//!
//! Every public wrapper in this module selects an implementation the
//! same way, in this order:
//!
//! 1. `TBGEMM_FORCE_SCALAR=1` (any non-empty value other than `0`; read
//!    once per process) forces the scalar fallback everywhere. CI uses
//!    this to exercise the scalar paths on hosts whose best SIMD arm
//!    would otherwise shadow them.
//! 2. On aarch64 the NEON arm runs unconditionally — NEON is a baseline
//!    aarch64 feature, so no runtime detection is needed.
//! 3. On x86-64 the AVX2 arm runs when runtime feature detection finds
//!    AVX2.
//! 4. Otherwise the scalar `count_ones` loops run.
//!
//! Every routine is differentially tested against the scalar
//! implementation on the host ISA, and the cross-ISA CI lane
//! additionally runs the full differential suite under `qemu-aarch64`,
//! proving the NEON arm bit-identical to the `Reference` and `Emulated`
//! backends on every push (see `tests/isa_parity.rs` and
//! `.github/workflows/ci.yml`).

#[cfg(target_arch = "aarch64")]
mod neon;

/// Words per u16 accumulation block of the NEON kernels: one
/// `vpadalq_u8` adds at most 2·8 = 16 per u16 lane, so a block of 2048
/// 16-byte steps (2 words each) reaches at most 32768 < `u16::MAX`
/// before spilling into the u32 accumulators. Defined here — outside
/// the cfg'd `neon` submodule — so the spill-boundary differential test
/// stays tied to the real constant on every host.
#[cfg_attr(not(target_arch = "aarch64"), allow(dead_code))]
pub(crate) const NEON_SPILL_WORDS: usize = 2 * 2048;

/// True when `TBGEMM_FORCE_SCALAR` requests the scalar fallbacks (step 1
/// of the dispatch order in the module docs). The read-once parse lives
/// in the central env registry ([`crate::util::env`]) with every other
/// `TBGEMM_*` knob; re-exported here because this module and
/// [`super::pack_fast`] are its only consumers.
pub(crate) use crate::util::env::force_scalar;

/// The A64 SIMD mnemonics the `neon` kernels compile to, per kernel
/// family — the shared vocabulary `tests/isa_parity.rs` pins against the
/// emulated microkernels' traced instruction streams (mnemonics
/// collapsed by [`crate::simd::trace::family`]). Declared
/// unconditionally so the parity test also runs on non-ARM hosts.
pub mod isa {
    /// Binary dots/tiles: `vld1q_u8`→LD1, `vdupq_n_*(0)`→MOVI,
    /// `veorq_u8`→EOR, `vcntq_u8`→CNT, `vpadalq_u8`/`vpadalq_u16`→UADALP,
    /// `vaddvq_u32`→ADDV.
    pub const BNN: &[&str] = &["LD1", "MOVI", "EOR", "CNT", "UADALP", "ADDV"];
    /// Ternary dots/tiles add the eq. (7) plane products: `vandq_u8`→AND,
    /// `vorrq_u8`→ORR.
    pub const TNN: &[&str] = &["LD1", "MOVI", "AND", "ORR", "CNT", "UADALP", "ADDV"];
    /// Ternary×binary replaces one AND pair with `vbicq_u8`→BIC
    /// (`a & !t`, the binary column used as a selector).
    pub const TBN: &[&str] = &["LD1", "MOVI", "AND", "BIC", "ORR", "CNT", "UADALP", "ADDV"];
    /// The product-forming logic family — the compute core shared with
    /// the emulated microkernels, independent of accumulation shape.
    pub const LOGIC: &[&str] = &["EOR", "AND", "ORR", "ORN", "BIC", "MVN"];
}

/// The one arm-selection preamble shared by every dispatch wrapper in
/// the native path (the popcount wrappers below and the packing
/// wrappers in [`super::pack_fast`]), so the documented dispatch order
/// is structurally identical across entry points — a wrapper cannot
/// forget the forced-scalar lane or reorder the arms: forced scalar →
/// NEON (aarch64) → AVX2 (x86-64) → scalar. The no-`neon:` form is for
/// wrappers without a NEON arm (packing), which fall through to scalar
/// on aarch64.
macro_rules! simd_dispatch {
    (neon: $neon:expr, avx2: $avx2:expr, scalar: $scalar:expr $(,)?) => {{
        if !force_scalar() {
            #[cfg(target_arch = "aarch64")]
            {
                // SAFETY: NEON is a baseline aarch64 feature (no runtime
                // detection needed), and the arm's only other contract —
                // slice-length agreement — is debug-asserted by every
                // wrapper right before this dispatch. The `allow` exists
                // because clippy cannot associate this macro-definition
                // comment with the block's expansion at each call site.
                #[allow(clippy::undocumented_unsafe_blocks)]
                let out = unsafe { $neon };
                return out;
            }
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    // SAFETY: AVX2 availability was just established by
                    // the runtime detection on the line above, and the
                    // arm's slice-length contract is debug-asserted by
                    // every wrapper right before this dispatch. The
                    // `allow` exists because clippy cannot associate
                    // this macro-definition comment with the block's
                    // expansion at each call site.
                    #[allow(clippy::undocumented_unsafe_blocks)]
                    let out = unsafe { $avx2 };
                    return out;
                }
            }
        }
        $scalar
    }};
    (avx2: $avx2:expr, scalar: $scalar:expr $(,)?) => {{
        if !force_scalar() {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2") {
                    // SAFETY: AVX2 availability was just established by
                    // the runtime detection on the line above, and the
                    // arm's slice-length contract is debug-asserted by
                    // every wrapper right before this dispatch. The
                    // `allow` exists because clippy cannot associate
                    // this macro-definition comment with the block's
                    // expansion at each call site.
                    #[allow(clippy::undocumented_unsafe_blocks)]
                    let out = unsafe { $avx2 };
                    return out;
                }
            }
        }
        $scalar
    }};
}
pub(crate) use simd_dispatch;

/// Binary row dot: Σ popcount(a ⊕ b).
#[inline]
pub fn xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    simd_dispatch!(
        neon: neon::xor_popcnt(a, b),
        avx2: avx2::xor_popcnt(a, b),
        scalar: scalar_xor_popcnt(a, b),
    )
}

/// Two-column binary row dot: (Σ popcount(a ⊕ b0), Σ popcount(a ⊕ b1)).
/// Amortizes the A-row loads across two B columns — the same register
/// reuse the paper's 16×8 microkernel gets from broadcasting b bytes.
#[inline]
pub fn xor_popcnt2(a: &[u64], b0: &[u64], b1: &[u64]) -> (u32, u32) {
    debug_assert!(a.len() == b0.len() && a.len() == b1.len());
    simd_dispatch!(
        neon: neon::xor_popcnt2(a, b0, b1),
        avx2: avx2::xor_popcnt2(a, b0, b1),
        scalar: (scalar_xor_popcnt(a, b0), scalar_xor_popcnt(a, b1)),
    )
}

/// Ternary row dot: (Σ popcount((a⁺∧b⁺)∨(a⁻∧b⁻)), Σ popcount((a⁺∧b⁻)∨(a⁻∧b⁺))).
#[inline]
pub fn tnn_popcnt(ap: &[u64], am: &[u64], bp: &[u64], bm: &[u64]) -> (u32, u32) {
    debug_assert!(ap.len() == am.len() && am.len() == bp.len() && bp.len() == bm.len());
    simd_dispatch!(
        neon: neon::tnn_popcnt(ap, am, bp, bm),
        avx2: avx2::tnn_popcnt(ap, am, bp, bm),
        scalar: scalar_tnn_popcnt(ap, am, bp, bm),
    )
}

/// Ternary×binary row dot with bit-row `t` (1 encodes −1):
/// (Σ popcount((a⁺∧¬t)∨(a⁻∧t)), Σ popcount((a⁺∧t)∨(a⁻∧¬t))).
#[inline]
pub fn tbn_popcnt(ap: &[u64], am: &[u64], t: &[u64]) -> (u32, u32) {
    debug_assert!(ap.len() == am.len() && am.len() == t.len());
    simd_dispatch!(
        neon: neon::tbn_popcnt(ap, am, t),
        avx2: avx2::tbn_popcnt(ap, am, t),
        scalar: scalar_tbn_popcnt(ap, am, t),
    )
}

// ---- register-tile primitives -----------------------------------------
//
// The row-dot entry points above amortize one A-row across at most two B
// columns. The tile entry points below are the inner loops of the blocked
// kernels: R A-rows × C B-columns of output with all R·C (or 2·R·C for
// the signed plane kinds) accumulators live in registers, so each loaded
// word of A is used C times and each loaded word of B is used R times —
// the register-reuse structure of the paper's 16×8 microkernel.

/// 4×2 binary tile: `s[r][c] = Σ popcount(a[r] ⊕ b_c)`.
#[inline]
pub fn xor_popcnt_4x2(a: [&[u64]; 4], b0: &[u64], b1: &[u64]) -> [[u32; 2]; 4] {
    debug_assert!(a.iter().all(|r| r.len() == b0.len()) && b0.len() == b1.len());
    simd_dispatch!(
        neon: neon::xor_popcnt_4x2(a, b0, b1),
        avx2: avx2::xor_popcnt_4x2(a, b0, b1),
        scalar: scalar_xor_popcnt_4x2(a, b0, b1),
    )
}

/// 4×4 binary tile: `s[r][c] = Σ popcount(a[r] ⊕ b[c])`. The widened
/// BNN tile ([`crate::gemm::plan::Tile::Wide`]): each loaded A word
/// feeds 4 columns and each B word 4 rows.
#[inline]
pub fn xor_popcnt_4x4(a: [&[u64]; 4], b: [&[u64]; 4]) -> [[u32; 4]; 4] {
    debug_assert!(a.iter().all(|r| r.len() == b[0].len()) && b.iter().all(|r| r.len() == b[0].len()));
    simd_dispatch!(
        neon: neon::xor_popcnt_4x4(a, b),
        avx2: avx2::xor_popcnt_4x4(a, b),
        scalar: scalar_xor_popcnt_4x4(a, b),
    )
}

/// 2×2 ternary tile: `s[r][c] = (z⁺, z⁻)` plane popcounts of row `r`
/// against column `c` (eq. (7) per output).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn tnn_popcnt_2x2(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp0: &[u64],
    bm0: &[u64],
    bp1: &[u64],
    bm1: &[u64],
) -> [[(u32, u32); 2]; 2] {
    debug_assert!(ap[0].len() == bp0.len() && bp0.len() == bp1.len());
    simd_dispatch!(
        neon: neon::tnn_popcnt_2x2(ap, am, bp0, bm0, bp1, bm1),
        avx2: avx2::tnn_popcnt_2x2(ap, am, bp0, bm0, bp1, bm1),
        scalar: scalar_tnn_popcnt_2x2(ap, am, bp0, bm0, bp1, bm1),
    )
}

/// 2×4 ternary tile: `s[r][c] = (z⁺, z⁻)` plane popcounts of row `r`
/// against column `c`. The widened TNN tile
/// ([`crate::gemm::plan::Tile::Wide`]): each loaded A plane pair feeds 4
/// columns and each B plane pair 2 rows, halving the loads-per-output of
/// the 2×2 tile on wide outputs.
#[inline]
pub fn tnn_popcnt_2x4(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp: [&[u64]; 4],
    bm: [&[u64]; 4],
) -> [[(u32, u32); 4]; 2] {
    debug_assert!(ap[0].len() == bp[0].len() && bp.iter().all(|c| c.len() == bp[0].len()));
    simd_dispatch!(
        neon: neon::tnn_popcnt_2x4(ap, am, bp, bm),
        avx2: avx2::tnn_popcnt_2x4(ap, am, bp, bm),
        scalar: scalar_tnn_popcnt_2x4(ap, am, bp, bm),
    )
}

/// 2×2 ternary×binary tile (bit-columns `t0`, `t1`; 1 encodes −1).
#[inline]
pub fn tbn_popcnt_2x2(ap: [&[u64]; 2], am: [&[u64]; 2], t0: &[u64], t1: &[u64]) -> [[(u32, u32); 2]; 2] {
    debug_assert!(ap[0].len() == t0.len() && t0.len() == t1.len());
    simd_dispatch!(
        neon: neon::tbn_popcnt_2x2(ap, am, t0, t1),
        avx2: avx2::tbn_popcnt_2x2(ap, am, t0, t1),
        scalar: scalar_tbn_popcnt_2x2(ap, am, t0, t1),
    )
}

// ---- scalar reference paths (and non-x86 fallback) --------------------

pub fn scalar_xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum()
}

pub fn scalar_tnn_popcnt(ap: &[u64], am: &[u64], bp: &[u64], bm: &[u64]) -> (u32, u32) {
    let (mut p, mut m) = (0u32, 0u32);
    for i in 0..ap.len() {
        p += ((ap[i] & bp[i]) | (am[i] & bm[i])).count_ones();
        m += ((ap[i] & bm[i]) | (am[i] & bp[i])).count_ones();
    }
    (p, m)
}

pub fn scalar_tbn_popcnt(ap: &[u64], am: &[u64], t: &[u64]) -> (u32, u32) {
    let (mut p, mut m) = (0u32, 0u32);
    for i in 0..ap.len() {
        p += ((ap[i] & !t[i]) | (am[i] & t[i])).count_ones();
        m += ((ap[i] & t[i]) | (am[i] & !t[i])).count_ones();
    }
    (p, m)
}

pub fn scalar_xor_popcnt_4x2(a: [&[u64]; 4], b0: &[u64], b1: &[u64]) -> [[u32; 2]; 4] {
    let mut s = [[0u32; 2]; 4];
    for t in 0..b0.len() {
        let (w0, w1) = (b0[t], b1[t]);
        for r in 0..4 {
            let av = a[r][t];
            s[r][0] += (av ^ w0).count_ones();
            s[r][1] += (av ^ w1).count_ones();
        }
    }
    s
}

pub fn scalar_xor_popcnt_4x4(a: [&[u64]; 4], b: [&[u64]; 4]) -> [[u32; 4]; 4] {
    let mut s = [[0u32; 4]; 4];
    for t in 0..b[0].len() {
        let bw = [b[0][t], b[1][t], b[2][t], b[3][t]];
        for (r, ar) in a.iter().enumerate() {
            let av = ar[t];
            for (c, &bv) in bw.iter().enumerate() {
                s[r][c] += (av ^ bv).count_ones();
            }
        }
    }
    s
}

#[allow(clippy::too_many_arguments)]
pub fn scalar_tnn_popcnt_2x2(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp0: &[u64],
    bm0: &[u64],
    bp1: &[u64],
    bm1: &[u64],
) -> [[(u32, u32); 2]; 2] {
    let mut s = [[(0u32, 0u32); 2]; 2];
    for t in 0..bp0.len() {
        let cols = [(bp0[t], bm0[t]), (bp1[t], bm1[t])];
        for r in 0..2 {
            let (xp, xm) = (ap[r][t], am[r][t]);
            for (c, &(yp, ym)) in cols.iter().enumerate() {
                s[r][c].0 += ((xp & yp) | (xm & ym)).count_ones();
                s[r][c].1 += ((xp & ym) | (xm & yp)).count_ones();
            }
        }
    }
    s
}

pub fn scalar_tnn_popcnt_2x4(
    ap: [&[u64]; 2],
    am: [&[u64]; 2],
    bp: [&[u64]; 4],
    bm: [&[u64]; 4],
) -> [[(u32, u32); 4]; 2] {
    let mut s = [[(0u32, 0u32); 4]; 2];
    for t in 0..bp[0].len() {
        let cols = [(bp[0][t], bm[0][t]), (bp[1][t], bm[1][t]), (bp[2][t], bm[2][t]), (bp[3][t], bm[3][t])];
        for r in 0..2 {
            let (xp, xm) = (ap[r][t], am[r][t]);
            for (c, &(yp, ym)) in cols.iter().enumerate() {
                s[r][c].0 += ((xp & yp) | (xm & ym)).count_ones();
                s[r][c].1 += ((xp & ym) | (xm & yp)).count_ones();
            }
        }
    }
    s
}

pub fn scalar_tbn_popcnt_2x2(ap: [&[u64]; 2], am: [&[u64]; 2], t0: &[u64], t1: &[u64]) -> [[(u32, u32); 2]; 2] {
    let mut s = [[(0u32, 0u32); 2]; 2];
    for t in 0..t0.len() {
        let cols = [t0[t], t1[t]];
        for r in 0..2 {
            let (xp, xm) = (ap[r][t], am[r][t]);
            for (c, &tv) in cols.iter().enumerate() {
                s[r][c].0 += ((xp & !tv) | (xm & tv)).count_ones();
                s[r][c].1 += ((xp & tv) | (xm & !tv)).count_ones();
            }
        }
    }
    s
}

// ---- AVX2 implementations ---------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-byte popcount of a 256-bit vector (Mula's vpshufb nibble LUT).
    #[inline]
    unsafe fn popcnt_bytes(x: __m256i) -> __m256i {
        // SAFETY: register-only AVX2 operations (no memory access); every
        // caller reaches this helper from a path that has already
        // established AVX2 (runtime detection in the dispatch preamble).
        unsafe {
            let lut = _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            );
            let low_mask = _mm256_set1_epi8(0x0f);
            let lo = _mm256_and_si256(x, low_mask);
            let hi = _mm256_and_si256(_mm256_srli_epi16(x, 4), low_mask);
            _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
        }
    }

    /// Horizontal sum of four u64 lanes.
    #[inline]
    unsafe fn hsum_epi64(v: __m256i) -> u64 {
        // SAFETY: register-only AVX2 operations (no memory access); every
        // caller reaches this helper from a path that has already
        // established AVX2 (runtime detection in the dispatch preamble).
        unsafe {
            let lo = _mm256_castsi256_si128(v);
            let hi = _mm256_extracti128_si256(v, 1);
            let s = _mm_add_epi64(lo, hi);
            (_mm_extract_epi64(s, 0) + _mm_extract_epi64(s, 1)) as u64
        }
    }

    /// Unaligned 256-bit load of four u64 words.
    ///
    /// # Safety
    /// The caller must guarantee AVX2 and that `p..p + 4` words are
    /// readable — the kernels below load only while `i + 4 <= n`.
    #[inline]
    unsafe fn loadu(p: *const u64) -> __m256i {
        // SAFETY: the caller guarantees AVX2 and four readable words at
        // `p`; `_mm256_loadu_si256` imposes no alignment requirement.
        unsafe { _mm256_loadu_si256(p as *const __m256i) }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = a.len();
            let mut acc = _mm256_setzero_si256();
            let zero = _mm256_setzero_si256();
            let mut i = 0;
            while i + 4 <= n {
                let x = _mm256_xor_si256(loadu(a.as_ptr().add(i)), loadu(b.as_ptr().add(i)));
                // vpsadbw: per-64-bit-lane sum of the 8 byte counts.
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(popcnt_bytes(x), zero));
                i += 4;
            }
            let mut total = hsum_epi64(acc) as u32;
            while i < n {
                total += (a[i] ^ b[i]).count_ones();
                i += 1;
            }
            total
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcnt2(a: &[u64], b0: &[u64], b1: &[u64]) -> (u32, u32) {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = a.len();
            let mut acc0 = _mm256_setzero_si256();
            let mut acc1 = _mm256_setzero_si256();
            let zero = _mm256_setzero_si256();
            let mut i = 0;
            while i + 4 <= n {
                let av = loadu(a.as_ptr().add(i));
                let x0 = _mm256_xor_si256(av, loadu(b0.as_ptr().add(i)));
                let x1 = _mm256_xor_si256(av, loadu(b1.as_ptr().add(i)));
                acc0 = _mm256_add_epi64(acc0, _mm256_sad_epu8(popcnt_bytes(x0), zero));
                acc1 = _mm256_add_epi64(acc1, _mm256_sad_epu8(popcnt_bytes(x1), zero));
                i += 4;
            }
            let mut s0 = hsum_epi64(acc0) as u32;
            let mut s1 = hsum_epi64(acc1) as u32;
            while i < n {
                s0 += (a[i] ^ b0[i]).count_ones();
                s1 += (a[i] ^ b1[i]).count_ones();
                i += 1;
            }
            (s0, s1)
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn tnn_popcnt(ap: &[u64], am: &[u64], bp: &[u64], bm: &[u64]) -> (u32, u32) {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = ap.len();
            let mut accp = _mm256_setzero_si256();
            let mut accm = _mm256_setzero_si256();
            let zero = _mm256_setzero_si256();
            let mut i = 0;
            while i + 4 <= n {
                let xp = loadu(ap.as_ptr().add(i));
                let xm = loadu(am.as_ptr().add(i));
                let yp = loadu(bp.as_ptr().add(i));
                let ym = loadu(bm.as_ptr().add(i));
                let zp = _mm256_or_si256(_mm256_and_si256(xp, yp), _mm256_and_si256(xm, ym));
                let zm = _mm256_or_si256(_mm256_and_si256(xp, ym), _mm256_and_si256(xm, yp));
                accp = _mm256_add_epi64(accp, _mm256_sad_epu8(popcnt_bytes(zp), zero));
                accm = _mm256_add_epi64(accm, _mm256_sad_epu8(popcnt_bytes(zm), zero));
                i += 4;
            }
            let mut p = hsum_epi64(accp) as u32;
            let mut m = hsum_epi64(accm) as u32;
            while i < n {
                p += ((ap[i] & bp[i]) | (am[i] & bm[i])).count_ones();
                m += ((ap[i] & bm[i]) | (am[i] & bp[i])).count_ones();
                i += 1;
            }
            (p, m)
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn tbn_popcnt(ap: &[u64], am: &[u64], t: &[u64]) -> (u32, u32) {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = ap.len();
            let mut accp = _mm256_setzero_si256();
            let mut accm = _mm256_setzero_si256();
            let zero = _mm256_setzero_si256();
            let mut i = 0;
            while i + 4 <= n {
                let xp = loadu(ap.as_ptr().add(i));
                let xm = loadu(am.as_ptr().add(i));
                let tv = loadu(t.as_ptr().add(i));
                let zp = _mm256_or_si256(_mm256_andnot_si256(tv, xp), _mm256_and_si256(xm, tv));
                let zm = _mm256_or_si256(_mm256_and_si256(xp, tv), _mm256_andnot_si256(tv, xm));
                accp = _mm256_add_epi64(accp, _mm256_sad_epu8(popcnt_bytes(zp), zero));
                accm = _mm256_add_epi64(accm, _mm256_sad_epu8(popcnt_bytes(zm), zero));
                i += 4;
            }
            let mut p = hsum_epi64(accp) as u32;
            let mut m = hsum_epi64(accm) as u32;
            while i < n {
                p += ((ap[i] & !t[i]) | (am[i] & t[i])).count_ones();
                m += ((ap[i] & t[i]) | (am[i] & !t[i])).count_ones();
                i += 1;
            }
            (p, m)
        }
    }

    /// One byte-popcount + per-lane horizontal add into a u64 accumulator.
    #[inline]
    unsafe fn acc_popcnt(acc: __m256i, x: __m256i, zero: __m256i) -> __m256i {
        // SAFETY: register-only AVX2 operations plus the register-only
        // `popcnt_bytes`; callers have already established AVX2.
        unsafe {
            _mm256_add_epi64(acc, _mm256_sad_epu8(popcnt_bytes(x), zero))
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcnt_4x2(a: [&[u64]; 4], b0: &[u64], b1: &[u64]) -> [[u32; 2]; 4] {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = b0.len();
            let zero = _mm256_setzero_si256();
            let mut acc = [[zero; 2]; 4];
            let mut i = 0;
            while i + 4 <= n {
                let bv0 = loadu(b0.as_ptr().add(i));
                let bv1 = loadu(b1.as_ptr().add(i));
                for r in 0..4 {
                    let av = loadu(a[r].as_ptr().add(i));
                    acc[r][0] = acc_popcnt(acc[r][0], _mm256_xor_si256(av, bv0), zero);
                    acc[r][1] = acc_popcnt(acc[r][1], _mm256_xor_si256(av, bv1), zero);
                }
                i += 4;
            }
            let mut s = [[0u32; 2]; 4];
            for r in 0..4 {
                s[r][0] = hsum_epi64(acc[r][0]) as u32;
                s[r][1] = hsum_epi64(acc[r][1]) as u32;
                for t in i..n {
                    s[r][0] += (a[r][t] ^ b0[t]).count_ones();
                    s[r][1] += (a[r][t] ^ b1[t]).count_ones();
                }
            }
            s
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcnt_4x4(a: [&[u64]; 4], b: [&[u64]; 4]) -> [[u32; 4]; 4] {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = b[0].len();
            let zero = _mm256_setzero_si256();
            let mut acc = [[zero; 4]; 4];
            let mut i = 0;
            while i + 4 <= n {
                let bv = [
                    loadu(b[0].as_ptr().add(i)),
                    loadu(b[1].as_ptr().add(i)),
                    loadu(b[2].as_ptr().add(i)),
                    loadu(b[3].as_ptr().add(i)),
                ];
                for r in 0..4 {
                    let av = loadu(a[r].as_ptr().add(i));
                    for c in 0..4 {
                        acc[r][c] = acc_popcnt(acc[r][c], _mm256_xor_si256(av, bv[c]), zero);
                    }
                }
                i += 4;
            }
            let mut s = [[0u32; 4]; 4];
            for r in 0..4 {
                for c in 0..4 {
                    s[r][c] = hsum_epi64(acc[r][c]) as u32;
                    for t in i..n {
                        s[r][c] += (a[r][t] ^ b[c][t]).count_ones();
                    }
                }
            }
            s
        }
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn tnn_popcnt_2x2(
        ap: [&[u64]; 2],
        am: [&[u64]; 2],
        bp0: &[u64],
        bm0: &[u64],
        bp1: &[u64],
        bm1: &[u64],
    ) -> [[(u32, u32); 2]; 2] {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = bp0.len();
            let zero = _mm256_setzero_si256();
            let mut accp = [[zero; 2]; 2];
            let mut accm = [[zero; 2]; 2];
            let mut i = 0;
            while i + 4 <= n {
                let yp = [loadu(bp0.as_ptr().add(i)), loadu(bp1.as_ptr().add(i))];
                let ym = [loadu(bm0.as_ptr().add(i)), loadu(bm1.as_ptr().add(i))];
                for r in 0..2 {
                    let xp = loadu(ap[r].as_ptr().add(i));
                    let xm = loadu(am[r].as_ptr().add(i));
                    for c in 0..2 {
                        let zp = _mm256_or_si256(_mm256_and_si256(xp, yp[c]), _mm256_and_si256(xm, ym[c]));
                        let zm = _mm256_or_si256(_mm256_and_si256(xp, ym[c]), _mm256_and_si256(xm, yp[c]));
                        accp[r][c] = acc_popcnt(accp[r][c], zp, zero);
                        accm[r][c] = acc_popcnt(accm[r][c], zm, zero);
                    }
                }
                i += 4;
            }
            let mut s = [[(0u32, 0u32); 2]; 2];
            let cols = [(bp0, bm0), (bp1, bm1)];
            for r in 0..2 {
                for c in 0..2 {
                    let (mut p, mut m) = (hsum_epi64(accp[r][c]) as u32, hsum_epi64(accm[r][c]) as u32);
                    let (bp, bm) = cols[c];
                    for t in i..n {
                        p += ((ap[r][t] & bp[t]) | (am[r][t] & bm[t])).count_ones();
                        m += ((ap[r][t] & bm[t]) | (am[r][t] & bp[t])).count_ones();
                    }
                    s[r][c] = (p, m);
                }
            }
            s
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn tnn_popcnt_2x4(
        ap: [&[u64]; 2],
        am: [&[u64]; 2],
        bp: [&[u64]; 4],
        bm: [&[u64]; 4],
    ) -> [[(u32, u32); 4]; 2] {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = bp[0].len();
            let zero = _mm256_setzero_si256();
            let mut accp = [[zero; 4]; 2];
            let mut accm = [[zero; 4]; 2];
            let mut i = 0;
            while i + 4 <= n {
                let yp = [
                    loadu(bp[0].as_ptr().add(i)),
                    loadu(bp[1].as_ptr().add(i)),
                    loadu(bp[2].as_ptr().add(i)),
                    loadu(bp[3].as_ptr().add(i)),
                ];
                let ym = [
                    loadu(bm[0].as_ptr().add(i)),
                    loadu(bm[1].as_ptr().add(i)),
                    loadu(bm[2].as_ptr().add(i)),
                    loadu(bm[3].as_ptr().add(i)),
                ];
                for r in 0..2 {
                    let xp = loadu(ap[r].as_ptr().add(i));
                    let xm = loadu(am[r].as_ptr().add(i));
                    for c in 0..4 {
                        let zp = _mm256_or_si256(_mm256_and_si256(xp, yp[c]), _mm256_and_si256(xm, ym[c]));
                        let zm = _mm256_or_si256(_mm256_and_si256(xp, ym[c]), _mm256_and_si256(xm, yp[c]));
                        accp[r][c] = acc_popcnt(accp[r][c], zp, zero);
                        accm[r][c] = acc_popcnt(accm[r][c], zm, zero);
                    }
                }
                i += 4;
            }
            let mut s = [[(0u32, 0u32); 4]; 2];
            for r in 0..2 {
                for c in 0..4 {
                    let (mut p, mut m) = (hsum_epi64(accp[r][c]) as u32, hsum_epi64(accm[r][c]) as u32);
                    for t in i..n {
                        p += ((ap[r][t] & bp[c][t]) | (am[r][t] & bm[c][t])).count_ones();
                        m += ((ap[r][t] & bm[c][t]) | (am[r][t] & bp[c][t])).count_ones();
                    }
                    s[r][c] = (p, m);
                }
            }
            s
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn tbn_popcnt_2x2(
        ap: [&[u64]; 2],
        am: [&[u64]; 2],
        t0: &[u64],
        t1: &[u64],
    ) -> [[(u32, u32); 2]; 2] {
        // SAFETY: the dispatch preamble runtime-detected AVX2 before calling
        // in, and the wrapper debug-asserts that all slices share length
        // `n`. Every `loadu` reads words `i..i + 4` only while `i + 4 <= n`,
        // so all vector loads are in bounds, and the scalar tail only
        // indexes below `n`.
        unsafe {
            let n = t0.len();
            let zero = _mm256_setzero_si256();
            let mut accp = [[zero; 2]; 2];
            let mut accm = [[zero; 2]; 2];
            let mut i = 0;
            while i + 4 <= n {
                let tv = [loadu(t0.as_ptr().add(i)), loadu(t1.as_ptr().add(i))];
                for r in 0..2 {
                    let xp = loadu(ap[r].as_ptr().add(i));
                    let xm = loadu(am[r].as_ptr().add(i));
                    for c in 0..2 {
                        let zp = _mm256_or_si256(_mm256_andnot_si256(tv[c], xp), _mm256_and_si256(xm, tv[c]));
                        let zm = _mm256_or_si256(_mm256_and_si256(xp, tv[c]), _mm256_andnot_si256(tv[c], xm));
                        accp[r][c] = acc_popcnt(accp[r][c], zp, zero);
                        accm[r][c] = acc_popcnt(accm[r][c], zm, zero);
                    }
                }
                i += 4;
            }
            let mut s = [[(0u32, 0u32); 2]; 2];
            let cols = [t0, t1];
            for r in 0..2 {
                for c in 0..2 {
                    let (mut p, mut m) = (hsum_epi64(accp[r][c]) as u32, hsum_epi64(accm[r][c]) as u32);
                    let tw = cols[c];
                    for t in i..n {
                        p += ((ap[r][t] & !tw[t]) | (am[r][t] & tw[t])).count_ones();
                        m += ((ap[r][t] & tw[t]) | (am[r][t] & !tw[t])).count_ones();
                    }
                    s[r][c] = (p, m);
                }
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_words(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    /// Upper bound of the per-test length sweeps. Natively 67 covers the
    /// 4-word main loop plus every tail length; under Miri (which runs
    /// these differential tests on the scalar arms at interpreter speed)
    /// 19 keeps the same main-loop/tail coverage for the widest (4-word)
    /// stride while bounding the lane's wall-clock.
    fn sweep_max() -> usize {
        if cfg!(miri) {
            19
        } else {
            67
        }
    }

    /// Differential test: vectorized ≡ scalar on all lengths 0..=67
    /// (covers the 4-word main loop and every tail length).
    #[test]
    fn xor_popcnt_matches_scalar() {
        let mut rng = Rng::new(0xABC);
        for n in 0usize..=sweep_max() {
            let a = random_words(&mut rng, n);
            let b = random_words(&mut rng, n);
            assert_eq!(xor_popcnt(&a, &b), scalar_xor_popcnt(&a, &b), "n={n}");
        }
    }

    /// The NEON arm spills its u16 `vpadalq_u8` accumulators into u32
    /// lanes every [`NEON_SPILL_WORDS`] words; straddle that boundary
    /// for **all nine** entry points so every kernel's block-reset and
    /// cross-block `vpadalq_u16` accumulation is differentially tested
    /// (the 0..=67 sweeps never reach it, and the deepest K-panel in
    /// the test suite is 512 words). `+2` enters a second, short block;
    /// `2·SPILL+1` runs two full blocks plus the odd-word tail.
    /// Worst-case density (all bits set) doubles as an in-lane
    /// saturation check on the binary dot. Ignored under Miri: the
    /// ~4096-word sweeps exist to stress the NEON spill schedule, which
    /// Miri (scalar arms, interpreter speed) cannot reach anyway.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn spill_boundary_matches_scalar_all_kernels() {
        let mut rng = Rng::new(0xAC4);
        for n in [NEON_SPILL_WORDS - 1, NEON_SPILL_WORDS, NEON_SPILL_WORDS + 2, 2 * NEON_SPILL_WORDS + 1] {
            let a: Vec<Vec<u64>> = (0..4).map(|_| random_words(&mut rng, n)).collect();
            let b: Vec<Vec<u64>> = (0..4).map(|_| random_words(&mut rng, n)).collect();
            let ar = [&a[0][..], &a[1][..], &a[2][..], &a[3][..]];
            let br = [&b[0][..], &b[1][..], &b[2][..], &b[3][..]];
            assert_eq!(xor_popcnt(&a[0], &b[0]), scalar_xor_popcnt(&a[0], &b[0]), "n={n}");
            let s2 = xor_popcnt2(&a[0], &b[0], &b[1]);
            assert_eq!(s2, (scalar_xor_popcnt(&a[0], &b[0]), scalar_xor_popcnt(&a[0], &b[1])), "n={n}");
            assert_eq!(xor_popcnt_4x2(ar, &b[0], &b[1]), scalar_xor_popcnt_4x2(ar, &b[0], &b[1]), "n={n}");
            assert_eq!(xor_popcnt_4x4(ar, br), scalar_xor_popcnt_4x4(ar, br), "n={n}");
            let (ap0, am0) = random_planes(&mut rng, n);
            let (ap1, am1) = random_planes(&mut rng, n);
            let (bp0, bm0) = random_planes(&mut rng, n);
            let (bp1, bm1) = random_planes(&mut rng, n);
            assert_eq!(tnn_popcnt(&ap0, &am0, &bp0, &bm0), scalar_tnn_popcnt(&ap0, &am0, &bp0, &bm0), "n={n}");
            assert_eq!(tbn_popcnt(&ap0, &am0, &b[0]), scalar_tbn_popcnt(&ap0, &am0, &b[0]), "n={n}");
            let apr = [&ap0[..], &ap1[..]];
            let amr = [&am0[..], &am1[..]];
            assert_eq!(
                tnn_popcnt_2x2(apr, amr, &bp0, &bm0, &bp1, &bm1),
                scalar_tnn_popcnt_2x2(apr, amr, &bp0, &bm0, &bp1, &bm1),
                "n={n}"
            );
            let bpr = [&bp0[..], &bp1[..], &bm0[..], &bm1[..]];
            let bmr = [&bm0[..], &bm1[..], &bp0[..], &bp1[..]];
            assert_eq!(tnn_popcnt_2x4(apr, amr, bpr, bmr), scalar_tnn_popcnt_2x4(apr, amr, bpr, bmr), "n={n}");
            assert_eq!(tbn_popcnt_2x2(apr, amr, &b[0], &b[1]), scalar_tbn_popcnt_2x2(apr, amr, &b[0], &b[1]), "n={n}");
            let ones = vec![u64::MAX; n];
            let zeros = vec![0u64; n];
            assert_eq!(xor_popcnt(&ones, &zeros), 64 * n as u32, "dense n={n}");
        }
    }

    #[test]
    fn tnn_popcnt_matches_scalar() {
        let mut rng = Rng::new(0xABD);
        for n in 0usize..=sweep_max() {
            // valid plane encoding: plus & minus disjoint
            let raw = random_words(&mut rng, 4 * n);
            let ap: Vec<u64> = (0..n).map(|i| raw[i] & !raw[n + i]).collect();
            let am: Vec<u64> = (0..n).map(|i| raw[n + i] & !raw[i]).collect();
            let bp: Vec<u64> = (0..n).map(|i| raw[2 * n + i] & !raw[3 * n + i]).collect();
            let bm: Vec<u64> = (0..n).map(|i| raw[3 * n + i] & !raw[2 * n + i]).collect();
            assert_eq!(tnn_popcnt(&ap, &am, &bp, &bm), scalar_tnn_popcnt(&ap, &am, &bp, &bm), "n={n}");
        }
    }

    #[test]
    fn tbn_popcnt_matches_scalar() {
        let mut rng = Rng::new(0xABE);
        for n in 0usize..=sweep_max() {
            let raw = random_words(&mut rng, 3 * n);
            let ap: Vec<u64> = (0..n).map(|i| raw[i] & !raw[n + i]).collect();
            let am: Vec<u64> = (0..n).map(|i| raw[n + i] & !raw[i]).collect();
            let t: Vec<u64> = (0..n).map(|i| raw[2 * n + i]).collect();
            assert_eq!(tbn_popcnt(&ap, &am, &t), scalar_tbn_popcnt(&ap, &am, &t), "n={n}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(xor_popcnt(&[0, u64::MAX], &[0, 0]), 64);
        assert_eq!(scalar_tnn_popcnt(&[0b11], &[0], &[0b01], &[0]), (1, 0));
        assert_eq!(scalar_tbn_popcnt(&[0b11], &[0], &[0b01]), (1, 1));
    }

    /// Tile primitives ≡ the corresponding single-dot primitives, per
    /// output element, on all lengths covering main loop + every tail.
    #[test]
    fn xor_popcnt_4x2_matches_dots() {
        let mut rng = Rng::new(0xABF);
        for n in 0usize..=sweep_max() {
            let a: Vec<Vec<u64>> = (0..4).map(|_| random_words(&mut rng, n)).collect();
            let b0 = random_words(&mut rng, n);
            let b1 = random_words(&mut rng, n);
            let s = xor_popcnt_4x2([&a[0], &a[1], &a[2], &a[3]], &b0, &b1);
            let sc = scalar_xor_popcnt_4x2([&a[0], &a[1], &a[2], &a[3]], &b0, &b1);
            assert_eq!(s, sc, "n={n}");
            for r in 0..4 {
                assert_eq!(s[r][0], scalar_xor_popcnt(&a[r], &b0), "n={n} r={r}");
                assert_eq!(s[r][1], scalar_xor_popcnt(&a[r], &b1), "n={n} r={r}");
            }
        }
    }

    #[test]
    fn xor_popcnt_4x4_matches_dots() {
        let mut rng = Rng::new(0xAC2);
        for n in 0usize..=sweep_max() {
            let a: Vec<Vec<u64>> = (0..4).map(|_| random_words(&mut rng, n)).collect();
            let b: Vec<Vec<u64>> = (0..4).map(|_| random_words(&mut rng, n)).collect();
            let ar = [&a[0][..], &a[1][..], &a[2][..], &a[3][..]];
            let br = [&b[0][..], &b[1][..], &b[2][..], &b[3][..]];
            let s = xor_popcnt_4x4(ar, br);
            assert_eq!(s, scalar_xor_popcnt_4x4(ar, br), "n={n}");
            for r in 0..4 {
                for c in 0..4 {
                    assert_eq!(s[r][c], scalar_xor_popcnt(&a[r], &b[c]), "n={n} r={r} c={c}");
                }
            }
        }
    }

    fn random_planes(rng: &mut Rng, n: usize) -> (Vec<u64>, Vec<u64>) {
        let x = random_words(rng, n);
        let y = random_words(rng, n);
        let p: Vec<u64> = (0..n).map(|i| x[i] & !y[i]).collect();
        let m: Vec<u64> = (0..n).map(|i| y[i] & !x[i]).collect();
        (p, m)
    }

    #[test]
    fn tnn_popcnt_2x2_matches_dots() {
        let mut rng = Rng::new(0xAC0);
        for n in 0usize..=sweep_max() {
            let (ap0, am0) = random_planes(&mut rng, n);
            let (ap1, am1) = random_planes(&mut rng, n);
            let (bp0, bm0) = random_planes(&mut rng, n);
            let (bp1, bm1) = random_planes(&mut rng, n);
            let s = tnn_popcnt_2x2([&ap0, &ap1], [&am0, &am1], &bp0, &bm0, &bp1, &bm1);
            assert_eq!(s, scalar_tnn_popcnt_2x2([&ap0, &ap1], [&am0, &am1], &bp0, &bm0, &bp1, &bm1), "n={n}");
            assert_eq!(s[0][0], scalar_tnn_popcnt(&ap0, &am0, &bp0, &bm0), "n={n}");
            assert_eq!(s[0][1], scalar_tnn_popcnt(&ap0, &am0, &bp1, &bm1), "n={n}");
            assert_eq!(s[1][0], scalar_tnn_popcnt(&ap1, &am1, &bp0, &bm0), "n={n}");
            assert_eq!(s[1][1], scalar_tnn_popcnt(&ap1, &am1, &bp1, &bm1), "n={n}");
        }
    }

    #[test]
    fn tnn_popcnt_2x4_matches_dots() {
        let mut rng = Rng::new(0xAC3);
        for n in 0usize..=sweep_max() {
            let (ap0, am0) = random_planes(&mut rng, n);
            let (ap1, am1) = random_planes(&mut rng, n);
            let cols: Vec<(Vec<u64>, Vec<u64>)> = (0..4).map(|_| random_planes(&mut rng, n)).collect();
            let bp = [&cols[0].0[..], &cols[1].0[..], &cols[2].0[..], &cols[3].0[..]];
            let bm = [&cols[0].1[..], &cols[1].1[..], &cols[2].1[..], &cols[3].1[..]];
            let s = tnn_popcnt_2x4([&ap0, &ap1], [&am0, &am1], bp, bm);
            assert_eq!(s, scalar_tnn_popcnt_2x4([&ap0, &ap1], [&am0, &am1], bp, bm), "n={n}");
            for (c, col) in cols.iter().enumerate() {
                assert_eq!(s[0][c], scalar_tnn_popcnt(&ap0, &am0, &col.0, &col.1), "n={n} c={c}");
                assert_eq!(s[1][c], scalar_tnn_popcnt(&ap1, &am1, &col.0, &col.1), "n={n} c={c}");
            }
        }
    }

    #[test]
    fn tbn_popcnt_2x2_matches_dots() {
        let mut rng = Rng::new(0xAC1);
        for n in 0usize..=sweep_max() {
            let (ap0, am0) = random_planes(&mut rng, n);
            let (ap1, am1) = random_planes(&mut rng, n);
            let t0 = random_words(&mut rng, n);
            let t1 = random_words(&mut rng, n);
            let s = tbn_popcnt_2x2([&ap0, &ap1], [&am0, &am1], &t0, &t1);
            assert_eq!(s, scalar_tbn_popcnt_2x2([&ap0, &ap1], [&am0, &am1], &t0, &t1), "n={n}");
            assert_eq!(s[0][0], scalar_tbn_popcnt(&ap0, &am0, &t0), "n={n}");
            assert_eq!(s[0][1], scalar_tbn_popcnt(&ap0, &am0, &t1), "n={n}");
            assert_eq!(s[1][0], scalar_tbn_popcnt(&ap1, &am1, &t0), "n={n}");
            assert_eq!(s[1][1], scalar_tbn_popcnt(&ap1, &am1, &t1), "n={n}");
        }
    }
}
