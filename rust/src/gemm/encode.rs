//! §III-A of the paper: value encodings and Boolean product formulas.
//!
//! * Binary values use a single bit: `1 → 0`, `-1 → 1`, so that the product
//!   `z = x·y` satisfies `z^b = x^b ⊕ y^b` and a dot product is
//!   `c = k − 2·Σ (x^b ⊕ y^b)` (the paper's eq. (6)).
//! * Ternary values use the 2-bit `(x⁺, x⁻)` encoding:
//!   `1 → (1,0)`, `0 → (0,0)`, `-1 → (0,1)`; `(1,1)` is invalid. The
//!   product planes are
//!   `z⁺ = (x⁺∧y⁺)∨(x⁻∧y⁻)`, `z⁻ = (x⁺∧y⁻)∨(x⁻∧y⁺)`
//!   and a dot product is `c = Σ (z⁺ − z⁻)` (eq. (7)).
//! * Ternary×binary uses
//!   `z⁺ = (x⁺∨y^b)∧(x⁻∨¬y^b)`, `z⁻ = (x⁺∨¬y^b)∧(x⁻∨y^b)`
//!   — note these are the paper's ORN-based forms, which assume the
//!   encoding is valid (never `(1,1)`).
//!
//! These scalar definitions are the ground truth for Table I; the packed
//! microkernels and native paths are all tested against them.

/// Binary encoding: `1 → 0`, `-1 → 1`.
#[inline]
pub fn encode_binary(x: i8) -> u8 {
    debug_assert!(x == 1 || x == -1, "binary value must be ±1, got {x}");
    if x == 1 {
        0
    } else {
        1
    }
}

/// Inverse of [`encode_binary`].
#[inline]
pub fn decode_binary(b: u8) -> i8 {
    if b == 0 {
        1
    } else {
        -1
    }
}

/// Ternary 2-bit encoding: `1 → (1,0)`, `0 → (0,0)`, `-1 → (0,1)`.
#[inline]
pub fn encode_ternary(x: i8) -> (u8, u8) {
    debug_assert!((-1..=1).contains(&x), "ternary value must be in {{-1,0,1}}, got {x}");
    match x {
        1 => (1, 0),
        0 => (0, 0),
        _ => (0, 1),
    }
}

/// Inverse of [`encode_ternary`]. `(1,1)` is invalid and rejected.
#[inline]
pub fn decode_ternary(p: u8, m: u8) -> i8 {
    debug_assert!(!(p == 1 && m == 1), "(1,1) is an invalid ternary code");
    p as i8 - m as i8
}

/// Binary product in encoded form: `z^b = x^b ⊕ y^b`.
#[inline]
pub fn binary_mul(xb: u8, yb: u8) -> u8 {
    xb ^ yb
}

/// Ternary product in encoded form (paper Table I, columns `z⁺ z⁻`):
/// `z⁺ = (x⁺∧y⁺)∨(x⁻∧y⁻)`, `z⁻ = (x⁺∧y⁻)∨(x⁻∧y⁺)`.
#[inline]
pub fn ternary_mul(xp: u8, xm: u8, yp: u8, ym: u8) -> (u8, u8) {
    ((xp & yp) | (xm & ym), (xp & ym) | (xm & yp))
}

/// Ternary×binary product in encoded form (paper Table I, columns
/// `u⁺ u⁻`): `u⁺ = (x⁺∨y^b)∧(x⁻∨¬y^b)`, `u⁻ = (x⁺∨¬y^b)∧(x⁻∨y^b)`.
///
/// Wait — direct transcription of the paper's formula gives, for
/// `x = 1 (1,0), y = 1 (y^b = 0)`: `u⁺ = (1∨0)∧(0∨1) = 1` ✓. The formula
/// is stated over single bits; here it is applied bitwise.
#[inline]
pub fn tbn_mul(xp: u8, xm: u8, yb: u8) -> (u8, u8) {
    let nyb = yb ^ 1;
    ((xp | yb) & (xm | nyb), (xp | nyb) & (xm | yb))
}

/// Alternative TBN product used by the packed kernels: a binary `y` has
/// plane form `y⁺ = ¬y^b`, `y⁻ = y^b`, so the ternary formula applies:
/// `u⁺ = (x⁺∧¬y^b)∨(x⁻∧y^b)`, `u⁻ = (x⁺∧y^b)∨(x⁻∧¬y^b)`.
/// Equivalent to [`tbn_mul`] on all valid encodings (proved by the
/// exhaustive test below).
#[inline]
pub fn tbn_mul_planes(xp: u8, xm: u8, yb: u8) -> (u8, u8) {
    let nyb = yb ^ 1;
    ((xp & nyb) | (xm & yb), (xp & yb) | (xm & nyb))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I, left half: ternary multiplication z = x·y over all nine
    /// (x, y) pairs.
    #[test]
    fn table1_ternary_truth_table() {
        for x in [-1i8, 0, 1] {
            for y in [-1i8, 0, 1] {
                let (xp, xm) = encode_ternary(x);
                let (yp, ym) = encode_ternary(y);
                let (zp, zm) = ternary_mul(xp, xm, yp, ym);
                assert!(!(zp == 1 && zm == 1), "invalid code produced");
                assert_eq!(decode_ternary(zp, zm), x * y, "x={x} y={y}");
            }
        }
    }

    /// Table I, right half: ternary-binary multiplication u = x·y over all
    /// six valid (x, y) pairs — via the paper's ORN form.
    #[test]
    fn table1_tbn_truth_table() {
        for x in [-1i8, 0, 1] {
            for y in [-1i8, 1] {
                let (xp, xm) = encode_ternary(x);
                let yb = encode_binary(y);
                let (up, um) = tbn_mul(xp, xm, yb);
                assert!(!(up == 1 && um == 1), "invalid code produced");
                assert_eq!(decode_ternary(up, um), x * y, "x={x} y={y}");
            }
        }
    }

    /// The plane form the packed kernels use is equivalent to the paper's
    /// ORN form on all valid encodings.
    #[test]
    fn tbn_forms_equivalent() {
        for x in [-1i8, 0, 1] {
            for y in [-1i8, 1] {
                let (xp, xm) = encode_ternary(x);
                let yb = encode_binary(y);
                assert_eq!(tbn_mul(xp, xm, yb), tbn_mul_planes(xp, xm, yb), "x={x} y={y}");
            }
        }
    }

    /// eq. (6): binary dot product via XOR/popcount equals the direct dot
    /// product, for all 4 scalar combinations and for random vectors.
    #[test]
    fn binary_mul_via_xor() {
        for x in [-1i8, 1] {
            for y in [-1i8, 1] {
                let zb = binary_mul(encode_binary(x), encode_binary(y));
                assert_eq!(decode_binary(zb), x * y);
                // the 1 - 2*(x^b ⊕ y^b) identity:
                assert_eq!((x * y) as i32, 1 - 2 * zb as i32);
            }
        }
    }

    #[test]
    fn binary_dot_product_identity() {
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let k = 1 + rng.below(200);
            let xs: Vec<i8> = (0..k).map(|_| rng.binary()).collect();
            let ys: Vec<i8> = (0..k).map(|_| rng.binary()).collect();
            let direct: i32 = xs.iter().zip(&ys).map(|(&a, &b)| a as i32 * b as i32).sum();
            let xor_sum: i32 = xs
                .iter()
                .zip(&ys)
                .map(|(&a, &b)| (encode_binary(a) ^ encode_binary(b)) as i32)
                .sum();
            assert_eq!(direct, k as i32 - 2 * xor_sum);
        }
    }

    /// eq. (7): ternary dot product via plane counts.
    #[test]
    fn ternary_dot_product_identity() {
        use crate::util::Rng;
        let mut rng = Rng::new(78);
        for _ in 0..50 {
            let k = 1 + rng.below(200);
            let xs: Vec<i8> = (0..k).map(|_| rng.ternary()).collect();
            let ys: Vec<i8> = (0..k).map(|_| rng.ternary()).collect();
            let direct: i32 = xs.iter().zip(&ys).map(|(&a, &b)| a as i32 * b as i32).sum();
            let plane_sum: i32 = xs
                .iter()
                .zip(&ys)
                .map(|(&a, &b)| {
                    let (xp, xm) = encode_ternary(a);
                    let (yp, ym) = encode_ternary(b);
                    let (zp, zm) = ternary_mul(xp, xm, yp, ym);
                    zp as i32 - zm as i32
                })
                .sum();
            assert_eq!(direct, plane_sum);
        }
    }

    #[test]
    fn roundtrip_encodings() {
        for x in [-1i8, 1] {
            assert_eq!(decode_binary(encode_binary(x)), x);
        }
        for x in [-1i8, 0, 1] {
            let (p, m) = encode_ternary(x);
            assert_eq!(decode_ternary(p, m), x);
        }
    }
}
