//! daBNN-style binary microkernel: 8×6, depth step 128 — the paper's
//! published comparison point (Zhang et al., "daBNN: A Super Fast
//! Inference Framework for Binary Neural Networks on ARM Devices", 2019).
//!
//! Structure (per the daBNN source and the paper's Table II row): each
//! iteration loads eight full 128-bit rows of A and six 128-bit columns of
//! B, computes all 48 XOR+CNT pairs, reduces each with `ADDV` and inserts
//! the scalars into staging registers that are added into the running
//! accumulators. daBNN keeps its accumulators in f32 (hence k_max =
//! 2²³−1); we accumulate u32 in-kernel and the driver converts, which
//! preserves daBNN's k_max bound since every intermediate sum stays below
//! 2²³ for any k ≤ k_max.
//!
//! Per-iteration cost of this sequence: COM = 48×3 + 16 = 160 (paper:
//! 156), LD = 14 (paper: 12), MOV = 48 INS + 8 MOVI = 56 (paper: 36).
//! INS_metric ≈ 0.037 vs the paper's 0.033 — both well below BNN's
//! 0.041, but daBNN's per-element *loads* are 6× BNN's, which is why the
//! paper measures BNN 1.15× faster end-to-end despite the lower INS.

use crate::simd::reg::{Neon, Reg128};

/// Run the daBNN microkernel over `chunks` 128-deep iterations. `ablock`
/// is `chunks*128` bytes (8 rows × 16 bytes per chunk, packed by
/// [`crate::gemm::pack::pack_a_dabnn`]), `bblock` `chunks*96`. Returns
/// the 8×6 row-major tile of XOR-popcount sums.
pub fn dabnn_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [u32; 8 * 6] {
    debug_assert!(ablock.len() >= chunks * 128);
    debug_assert!(bblock.len() >= chunks * 96);
    // acc[r][h]: columns 4h..4h+4 of row r (h=1 uses lanes 0..2 only).
    let mut acc = [[Reg128::ZERO; 2]; 8];
    for d in 0..chunks {
        let mut a = [Reg128::ZERO; 8];
        for (r, ar) in a.iter_mut().enumerate() {
            *ar = cpu.ld1q(&ablock[d * 128 + r * 16..]);
        }
        let mut b = [Reg128::ZERO; 6];
        for (c, bc) in b.iter_mut().enumerate() {
            *bc = cpu.ld1q(&bblock[d * 96 + c * 16..]);
        }
        for r in 0..8 {
            // st[0]'s four lanes are fully overwritten by INS; st[1]
            // keeps stale lanes 2..4 and must be zeroed.
            let mut st = [Reg128::ZERO, cpu.movi0()];
            for (c, bc) in b.iter().enumerate() {
                let x = cpu.eor(a[r], *bc);
                let p = cpu.cnt(x);
                let s = cpu.addv(p);
                st[c / 4] = cpu.ins_u32(st[c / 4], c % 4, s);
            }
            acc[r][0] = cpu.add32(acc[r][0], st[0]);
            acc[r][1] = cpu.add32(acc[r][1], st[1]);
        }
    }
    let mut out = [0u32; 8 * 6];
    for r in 0..8 {
        let v0 = acc[r][0].to_u32x4();
        let v1 = acc[r][1].to_u32x4();
        for c in 0..6 {
            out[r * 6 + c] = if c < 4 { v0[c] } else { v1[c - 4] };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_dabnn, pack_b_dabnn};
    use crate::gemm::reference::gemm_i8;
    use crate::util::mat::MatI8;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatI8::random_binary(8, k, &mut rng);
        let b = MatI8::random_binary(k, 6, &mut rng);
        let pa = pack_a_dabnn(&a, 0, k);
        let pb = pack_b_dabnn(&b, 0, k);
        let mut cpu = Neon::new();
        let s = dabnn_microkernel(&mut cpu, &pa, &pb, k.div_ceil(128));
        let oracle = gemm_i8(&a, &b);
        for r in 0..8 {
            for c in 0..6 {
                let got = k as i32 - 2 * s[r * 6 + c] as i32;
                assert_eq!(got, oracle.get(r, c), "r={r} c={c} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_k128() {
        check_case(128, 60);
    }

    #[test]
    fn matches_oracle_k512() {
        check_case(512, 61);
    }

    #[test]
    fn matches_oracle_partial_chunk() {
        for k in [1, 8, 100, 129, 200] {
            check_case(k, 600 + k as u64);
        }
    }

    /// Table II daBNN row: COM/LD/MOV near the paper's 156/12/36
    /// (see module docs); INS below BNN's 0.041 as in the paper.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(62);
        let a = MatI8::random_binary(8, 256, &mut rng);
        let b = MatI8::random_binary(256, 6, &mut rng);
        let pa = pack_a_dabnn(&a, 0, 256);
        let pb = pack_b_dabnn(&b, 0, 256);
        let mut c1 = Neon::new();
        dabnn_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        dabnn_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        assert_eq!(d.com, 160, "COM within 3% of the paper's 156");
        assert_eq!(d.ld, 14);
        assert_eq!(d.mov, 56);
        let ins = d.ins_metric(8, 6, 128);
        assert!(ins < 0.041, "daBNN INS {ins} must stay below BNN's 0.041");
    }
}
