//! The paper's binary GeMM microkernel (Fig. 1): shape 16×8, depth step 8.
//!
//! Per depth iteration:
//!
//! 1. `LD1.16B` — one column of 8-bit values from `Ablock` (16 rows ×
//!    8 depth bits),
//! 2. `LD1.8B` — one row of 8-bit values from `Bblock` (8 columns),
//! 3. for each of the 8 columns: `DUP` the column byte, `EOR` with the A
//!    register, `CNT` the ones in the "product", and accumulate with
//!    `SADDW`/`SADDW2` into the sixteen 16-bit-lane accumulators
//!    `c00..c07, c10..c17`.
//!
//! Steady-state cost: COM = 8×(EOR+CNT+SADDW+SADDW2) = 32, LD = 2,
//! MOV = 8 DUPs — exactly the paper's Table II row for BNN.
//!
//! The kernel returns the raw XOR-popcount sums `s`; the driver applies
//! the paper's eq. (6) epilogue `C = k − 2s`.

use crate::simd::reg::{Neon, Reg128};

/// Run the BNN microkernel over `chunks` depth iterations (each covering
/// 8 depth bits). `ablock` is `chunks*16` bytes, `bblock` `chunks*8`.
/// Returns `s[r][j]` = Σ popcount(a_r ⊕ b_j) as a 16×8 row-major tile.
pub fn bnn_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [i16; 16 * 8] {
    debug_assert!(ablock.len() >= chunks * 16);
    debug_assert!(bblock.len() >= chunks * 8);
    // c[0][j]: rows 0..8 of column j; c[1][j]: rows 8..16.
    let mut c = [[Reg128::ZERO; 8]; 2];
    for d in 0..chunks {
        let a = cpu.ld1q(&ablock[d * 16..]);
        let b = cpu.ld1d(&bblock[d * 8..]);
        for j in 0..8 {
            let bj = cpu.dup_b(b, j);
            let x = cpu.eor(a, bj);
            let p = cpu.cnt(x);
            c[0][j] = cpu.saddw(c[0][j], p);
            c[1][j] = cpu.saddw2(c[1][j], p);
        }
    }
    let mut out = [0i16; 16 * 8];
    for j in 0..8 {
        let lo = c[0][j].to_i16x8();
        let hi = c[1][j].to_i16x8();
        for r in 0..8 {
            out[r * 8 + j] = lo[r];
            out[(8 + r) * 8 + j] = hi[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_bnn, pack_b_bnn};
    use crate::gemm::reference::gemm_i8;
    use crate::util::mat::MatI8;
    use crate::util::Rng;

    /// Drive the microkernel on a full 16×k × k×8 problem and check
    /// against the scalar oracle via the eq. (6) epilogue.
    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatI8::random_binary(16, k, &mut rng);
        let b = MatI8::random_binary(k, 8, &mut rng);
        let pa = pack_a_bnn(&a, 0, k);
        let pb = pack_b_bnn(&b, 0, k);
        let chunks = k.div_ceil(8);
        let mut cpu = Neon::new();
        let s = bnn_microkernel(&mut cpu, &pa, &pb, chunks);
        let oracle = gemm_i8(&a, &b);
        for r in 0..16 {
            for j in 0..8 {
                let c = k as i32 - 2 * s[r * 8 + j] as i32;
                assert_eq!(c, oracle.get(r, j), "r={r} j={j} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_k8() {
        check_case(8, 1);
    }

    #[test]
    fn matches_oracle_k128() {
        check_case(128, 2);
    }

    #[test]
    fn matches_oracle_k_not_multiple_of_8() {
        for k in [1, 3, 7, 9, 15, 33, 100] {
            check_case(k, 100 + k as u64);
        }
    }

    /// Steady-state instruction counts = paper Table II BNN row:
    /// COM=32, LD=2, MOV=8, INS=0.041.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(3);
        let a = MatI8::random_binary(16, 16, &mut rng);
        let b = MatI8::random_binary(16, 8, &mut rng);
        let pa = pack_a_bnn(&a, 0, 16);
        let pb = pack_b_bnn(&b, 0, 16);
        let mut cpu1 = Neon::new();
        bnn_microkernel(&mut cpu1, &pa, &pb, 1);
        let mut cpu2 = Neon::new();
        bnn_microkernel(&mut cpu2, &pa, &pb, 2);
        let d = cpu2.trace.delta(&cpu1.trace);
        assert_eq!(d.com, 32);
        assert_eq!(d.ld, 2);
        assert_eq!(d.mov, 8);
        assert!((d.ins_metric(16, 8, 8) - 0.041_015_625).abs() < 1e-9);
    }

    /// 16-bit accumulators never overflow up to the paper's k_max.
    #[test]
    fn accumulator_bound_at_kmax_sample() {
        // Worst case for s is all bits differing: s = k. At k = 32767 the
        // i16 accumulator holds exactly 32767. Use a smaller k here but
        // verify the adversarial all-disagree pattern is exact.
        let k = 4096;
        let a = MatI8::from_fn(16, k, |_, _| 1);
        let b = MatI8::from_fn(k, 8, |_, _| -1);
        let pa = pack_a_bnn(&a, 0, k);
        let pb = pack_b_bnn(&b, 0, k);
        let mut cpu = Neon::new();
        let s = bnn_microkernel(&mut cpu, &pa, &pb, k / 8);
        assert!(s.iter().all(|&v| v == k as i16));
    }
}
