//! U8 baseline microkernel: 12×8, depth step 2 — the gemmlowp-style 8-bit
//! quantized multiplication (§II-B). Values are unsigned 8-bit with
//! zero-points handled by the driver's eq. (3) epilogue; the kernel
//! computes the raw product Σ Âᵢₜ·B̂ₜⱼ into 32-bit accumulators.
//!
//! Per 2-deep iteration: 3 SIMD loads (two 16-byte A columns, one 16-byte
//! B row pair), 6 `UXTL`/`UXTL2` widenings, and 48 by-element
//! `UMLAL`/`UMLAL2` into the 24 u32×4 accumulators. The paper reports
//! COM=48, LD=5, MOV=5 (total 58); our sequence totals 57 — one load
//! fewer because the packed A panel pads 12 rows to 16 and needs 2 loads.

use crate::simd::reg::{Neon, Reg128};

/// Run the U8 microkernel over `chunks` 2-deep iterations. `ablock` is
/// `chunks*32` bytes (packed by [`crate::gemm::pack::pack_a_u8`]),
/// `bblock` `chunks*16`. Returns the 12×8 row-major raw-product tile.
pub fn u8_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [u32; 12 * 8] {
    debug_assert!(ablock.len() >= chunks * 32);
    debug_assert!(bblock.len() >= chunks * 16);
    // c[g][j]: rows 4g..4g+4 of column j, u32 lanes.
    let mut c = [[Reg128::ZERO; 8]; 3];
    for d in 0..chunks {
        let a0 = cpu.ld1q(&ablock[d * 32..]); // depth 2d, rows 0..12 (+pad)
        let a1 = cpu.ld1q(&ablock[d * 32 + 16..]); // depth 2d+1
        let b = cpu.ld1q(&bblock[d * 16..]); // both depths, cols 0..8
        let b0 = cpu.uxtl(b); // depth 2d as u16 lanes
        let b1 = cpu.uxtl2(b); // depth 2d+1
        for (a, bt) in [(a0, b0), (a1, b1)] {
            let al = cpu.uxtl(a); // rows 0..8 as u16
            let ah = cpu.uxtl2(a); // rows 8..12 (+pad)
            for j in 0..8 {
                c[0][j] = cpu.umlal_lane(c[0][j], al, bt, j); // rows 0..4
                c[1][j] = cpu.umlal2_lane(c[1][j], al, bt, j); // rows 4..8
                c[2][j] = cpu.umlal_lane(c[2][j], ah, bt, j); // rows 8..12
            }
        }
    }
    let mut out = [0u32; 12 * 8];
    for j in 0..8 {
        for g in 0..3 {
            let v = c[g][j].to_u32x4();
            for l in 0..4 {
                out[(4 * g + l) * 8 + j] = v[l];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_u8, pack_b_u8};
    use crate::gemm::reference::gemm_u8_raw;
    use crate::util::mat::MatU8;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatU8::random(12, k, &mut rng);
        let b = MatU8::random(k, 8, &mut rng);
        let pa = pack_a_u8(&a, 0, k);
        let pb = pack_b_u8(&b, 0, k);
        let mut cpu = Neon::new();
        let t = u8_microkernel(&mut cpu, &pa, &pb, k.div_ceil(2));
        let oracle = gemm_u8_raw(&a, &b);
        for r in 0..12 {
            for j in 0..8 {
                assert_eq!(t[r * 8 + j] as i64, oracle.get(r, j) as i64, "r={r} j={j} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_even_k() {
        check_case(2, 40);
        check_case(64, 41);
    }

    #[test]
    fn matches_oracle_odd_k() {
        for k in [1, 3, 9, 33] {
            check_case(k, 400 + k as u64);
        }
    }

    /// Table II U8 row: COM=48 UMLAL(+2), LD vs paper discussed in module
    /// docs; UMLAL count is exact.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(42);
        let a = MatU8::random(12, 4, &mut rng);
        let b = MatU8::random(4, 8, &mut rng);
        let pa = pack_a_u8(&a, 0, 4);
        let pb = pack_b_u8(&b, 0, 4);
        let mut c1 = Neon::new();
        u8_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        u8_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        assert_eq!(d.com, 48, "48 UMLAL/UMLAL2 per iteration (paper: 48)");
        assert_eq!(d.ld, 3);
        assert_eq!(d.mov, 6);
        // Paper total 58, ours 57; INS within 2%.
        let ins = d.ins_metric(12, 8, 2);
        assert!((ins - 0.302).abs() / 0.302 < 0.03, "INS {ins} vs paper 0.302");
    }

    /// Accumulators hold the worst case at the paper's k_max = 66051:
    /// spot-check the adversarial all-255 pattern at a smaller depth.
    #[test]
    fn worst_case_values_exact() {
        let k = 512;
        let a = MatU8 { rows: 12, cols: k, data: vec![255; 12 * k] };
        let b = MatU8 { rows: k, cols: 8, data: vec![255; k * 8] };
        let pa = pack_a_u8(&a, 0, k);
        let pb = pack_b_u8(&b, 0, k);
        let mut cpu = Neon::new();
        let t = u8_microkernel(&mut cpu, &pa, &pb, k / 2);
        assert!(t.iter().all(|&v| v == 255 * 255 * k as u32));
    }
}
