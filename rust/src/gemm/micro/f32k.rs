//! F32 baseline microkernel: 12×8, depth step 1 — "our implementation of
//! floating-point 32-bit baseline which uses the same register layout as
//! gemmlowp" (§IV).
//!
//! The 12×8 f32 output block is 96 values = 24 `Q` registers of 4 lanes.
//! Per depth step: 3 loads of A (12 f32), 2 loads of B (8 f32) and 24
//! by-element `FMLA`s — COM=24, LD=5, MOV=0, exactly the paper's Table II
//! row for F32 (INS = 29/96 = 0.302).

use crate::simd::reg::{Neon, Reg128};

fn f32s(bytes: &[f32]) -> [u8; 16] {
    let mut b = [0u8; 16];
    for i in 0..4 {
        b[4 * i..4 * i + 4].copy_from_slice(&bytes[i].to_le_bytes());
    }
    b
}

/// Run the F32 microkernel over `k` depth steps. `ablock` is `k*12` f32
/// (packed by [`crate::gemm::pack::pack_a_f32`]), `bblock` `k*8` f32.
/// Returns the 12×8 row-major output tile.
pub fn f32_microkernel(cpu: &mut Neon, ablock: &[f32], bblock: &[f32], k: usize) -> [f32; 12 * 8] {
    debug_assert!(ablock.len() >= k * 12);
    debug_assert!(bblock.len() >= k * 8);
    // c[g][j]: rows 4g..4g+4 of column j.
    let mut c = [[Reg128::ZERO; 8]; 3];
    for d in 0..k {
        let a = &ablock[d * 12..d * 12 + 12];
        let b = &bblock[d * 8..d * 8 + 8];
        let a0 = cpu.ld1q(&f32s(&a[0..4]));
        let a1 = cpu.ld1q(&f32s(&a[4..8]));
        let a2 = cpu.ld1q(&f32s(&a[8..12]));
        let b0 = cpu.ld1q(&f32s(&b[0..4]));
        let b1 = cpu.ld1q(&f32s(&b[4..8]));
        for (g, ag) in [a0, a1, a2].into_iter().enumerate() {
            for j in 0..8 {
                let (breg, lane) = if j < 4 { (b0, j) } else { (b1, j - 4) };
                c[g][j] = cpu.fmla_lane(c[g][j], ag, breg, lane);
            }
        }
    }
    let mut out = [0f32; 12 * 8];
    for j in 0..8 {
        for g in 0..3 {
            let v = c[g][j].to_f32x4();
            for l in 0..4 {
                out[(4 * g + l) * 8 + j] = v[l];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_f32, pack_b_f32};
    use crate::gemm::reference::gemm_f32;
    use crate::util::mat::MatF32;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatF32::random(12, k, &mut rng);
        let b = MatF32::random(k, 8, &mut rng);
        let pa = pack_a_f32(&a, 0, k);
        let pb = pack_b_f32(&b, 0, k);
        let mut cpu = Neon::new();
        let t = f32_microkernel(&mut cpu, &pa, &pb, k);
        let oracle = gemm_f32(&a, &b);
        for r in 0..12 {
            for j in 0..8 {
                let got = t[r * 8 + j];
                let want = oracle.get(r, j);
                assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()), "r={r} j={j} {got} vs {want}");
            }
        }
    }

    #[test]
    fn matches_oracle_small() {
        check_case(1, 30);
        check_case(7, 31);
    }

    #[test]
    fn matches_oracle_k128() {
        check_case(128, 32);
    }

    /// Table II F32 row: COM=24 FMLA, LD=5, MOV=0, INS=0.302.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(33);
        let a = MatF32::random(12, 2, &mut rng);
        let b = MatF32::random(2, 8, &mut rng);
        let pa = pack_a_f32(&a, 0, 2);
        let pb = pack_b_f32(&b, 0, 2);
        let mut c1 = Neon::new();
        f32_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        f32_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        assert_eq!(d.com, 24);
        assert_eq!(d.ld, 5);
        assert_eq!(d.mov, 0);
        assert!((d.ins_metric(12, 8, 1) - 29.0 / 96.0).abs() < 1e-9);
        assert_eq!(d.by_mnemonic["FMLA"], 24);
    }
}
