//! The paper's ternary GeMM microkernel (Fig. 2): shape 16×8, depth step 8.
//!
//! `Ablock` holds the two bit-planes of 16 rows interleaved by groups of
//! eight (`[A⁺r0..8 | A⁻r0..8]` in `a0`, `[A⁺r8..16 | A⁻r8..16]` in `a1`);
//! `Bblock` holds `[B⁺c, B⁻c]` byte pairs for the 8 columns.
//!
//! Per column `j` the kernel builds the broadcast registers
//! `b1 = [b⁺×8 | b⁻×8]` and `b2 = [b⁻×8 | b⁺×8]` and, for each row group
//! `a`:
//!
//! * `u⁺ = AND(a, b1)` → per byte, the `(x⁺∧y⁺)` counts in the low half
//!   and `(x⁻∧y⁻)` in the high half,
//! * `u⁻ = AND(a, b2)` → the cross terms,
//! * `CNT` both, `SSUBL`/`SSUBL2` the count difference for both halves,
//!   and two `ADD.8H` into the 16-bit accumulators.
//!
//! Per-bit the accumulated value is `(x⁺∧y⁺) + (x⁻∧y⁻) − (x⁺∧y⁻) −
//! (x⁻∧y⁺)`, which by Table I equals the ternary product — eq. (7).
//!
//! Steady-state cost: COM = 8×16 = 128, LD = 3, MOV = 8×4 = 32, total 163
//! — identical to the paper's total (96+3+64 = 163); the paper's assembly
//! splits the same work differently between COM and MOV. INS = 0.159
//! matches Table II exactly.

use crate::simd::reg::{Neon, Reg128};

/// Run the TNN microkernel over `chunks` depth iterations (8 bits each).
/// `ablock` is `chunks*32` bytes, `bblock` `chunks*16`. Returns the
/// 16×8 row-major tile of signed products Σ(z⁺ − z⁻).
pub fn tnn_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [i16; 16 * 8] {
    debug_assert!(ablock.len() >= chunks * 32);
    debug_assert!(bblock.len() >= chunks * 16);
    let mut c = [[Reg128::ZERO; 8]; 2];
    for d in 0..chunks {
        let a0 = cpu.ld1q(&ablock[d * 32..]);
        let a1 = cpu.ld1q(&ablock[d * 32 + 16..]);
        let b = cpu.ld1q(&bblock[d * 16..]);
        for j in 0..8 {
            let dp = cpu.dup_b(b, 2 * j);
            let dm = cpu.dup_b(b, 2 * j + 1);
            let b1 = cpu.ext(dp, dm, 8); // [b⁺×8 | b⁻×8]
            let b2 = cpu.ext(dm, dp, 8); // [b⁻×8 | b⁺×8]
            for (g, a) in [a0, a1].into_iter().enumerate() {
                let up = cpu.and(a, b1);
                let um = cpu.and(a, b2);
                let cp = cpu.cnt(up);
                let cm = cpu.cnt(um);
                let dl = cpu.ssubl(cp, cm);
                let dh = cpu.ssubl2(cp, cm);
                c[g][j] = cpu.add16(c[g][j], dl);
                c[g][j] = cpu.add16(c[g][j], dh);
            }
        }
    }
    let mut out = [0i16; 16 * 8];
    for j in 0..8 {
        let lo = c[0][j].to_i16x8();
        let hi = c[1][j].to_i16x8();
        for r in 0..8 {
            out[r * 8 + j] = lo[r];
            out[(8 + r) * 8 + j] = hi[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_tnn, pack_b_tnn};
    use crate::gemm::reference::gemm_i8;
    use crate::util::mat::MatI8;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatI8::random_ternary(16, k, &mut rng);
        let b = MatI8::random_ternary(k, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, k);
        let pb = pack_b_tnn(&b, 0, k);
        let mut cpu = Neon::new();
        let t = tnn_microkernel(&mut cpu, &pa, &pb, k.div_ceil(8));
        let oracle = gemm_i8(&a, &b);
        for r in 0..16 {
            for j in 0..8 {
                assert_eq!(t[r * 8 + j] as i32, oracle.get(r, j), "r={r} j={j} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_k8() {
        check_case(8, 10);
    }

    #[test]
    fn matches_oracle_k256() {
        check_case(256, 11);
    }

    #[test]
    fn matches_oracle_odd_k() {
        for k in [1, 5, 9, 23, 65, 127] {
            check_case(k, 200 + k as u64);
        }
    }

    /// Zero-padding in depth contributes nothing (ternary 0 encoding).
    #[test]
    fn zero_values_contribute_nothing() {
        let a = MatI8::zeros(16, 64);
        let mut rng = Rng::new(12);
        let b = MatI8::random_ternary(64, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, 64);
        let pb = pack_b_tnn(&b, 0, 64);
        let mut cpu = Neon::new();
        let t = tnn_microkernel(&mut cpu, &pa, &pb, 8);
        assert!(t.iter().all(|&v| v == 0));
    }

    /// Steady-state instruction counts: total = 163 = the paper's
    /// 96 COM + 3 LD + 64 MOV; our split is COM=128, LD=3, MOV=32.
    /// INS = 163/1024 = 0.159 (Table II).
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(13);
        let a = MatI8::random_ternary(16, 16, &mut rng);
        let b = MatI8::random_ternary(16, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, 16);
        let pb = pack_b_tnn(&b, 0, 16);
        let mut c1 = Neon::new();
        tnn_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        tnn_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        assert_eq!(d.total(), 163, "total must equal the paper's 96+3+64");
        assert_eq!(d.ld, 3);
        assert_eq!(d.com, 128);
        assert_eq!(d.mov, 32);
        assert!((d.ins_metric(16, 8, 8) - 163.0 / 1024.0).abs() < 1e-9);
    }
}
