//! The paper's ternary-binary GeMM microkernel (Fig. 3): 16×8, depth
//! step 8. `Ablock` is packed as in TNN; `Bblock` as in BNN (one bit per
//! value, 8 bytes per chunk, loaded with a 64-bit `LD1.8B`).
//!
//! A binary `y` in plane form is `y⁺ = ¬y♭`, `y⁻ = y♭`, so per column we
//! build a single selector `b1 = [¬y♭×8 | y♭×8]` with `DUP` + `EOR`
//! against the constant `MASK = [0xFF×8 | 0x00×8]` (hoisted out of the
//! depth loop). Then for each row-group register `a = [A⁺ | A⁻]`:
//!
//! * `u⁺ = AND(a, b1)` → `(x⁺∧¬y♭)` low / `(x⁻∧y♭)` high — the z⁺ parts,
//! * `u⁻ = BIC(a, b1)` → `(x⁺∧y♭)` low / `(x⁻∧¬y♭)` high — the z⁻ parts,
//!
//! and the same CNT/SSUBL/ADD tail as TNN. The BIC reuse of `b1` is why
//! TBN is cheaper than TNN ("simpler data flow in Bblock"): per column it
//! needs 1 DUP + 1 EOR instead of 2 DUP + 2 EXT.
//!
//! Steady-state: COM = 8×(1 + 16) = 136, LD = 3, MOV = 8, total 147 —
//! slightly below the paper's 155 (the paper's ORN sequence spends one
//! extra arrangement op per column). The orderings the paper reports
//! (INS: BNN < TBN < TNN) are preserved: 0.041 < 0.143 < 0.159.

use crate::simd::reg::{Neon, Reg128};

/// Constant selector: low 8 bytes 0xFF, high 8 bytes 0x00.
const MASK_LOW: [u8; 16] = [0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0, 0, 0, 0, 0, 0, 0, 0];

/// Run the TBN microkernel over `chunks` depth iterations. `ablock` is
/// packed by [`crate::gemm::pack::pack_a_tnn`] (`chunks*32` bytes),
/// `bblock` by [`crate::gemm::pack::pack_b_bnn`] (`chunks*8` bytes).
/// Returns the 16×8 tile of signed products.
pub fn tbn_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [i16; 16 * 8] {
    debug_assert!(ablock.len() >= chunks * 32);
    debug_assert!(bblock.len() >= chunks * 8);
    // Hoisted constant (one load outside the steady-state loop).
    let mask = cpu.ld1q(&MASK_LOW);
    let mut c = [[Reg128::ZERO; 8]; 2];
    for d in 0..chunks {
        let a0 = cpu.ld1q(&ablock[d * 32..]);
        let a1 = cpu.ld1q(&ablock[d * 32 + 16..]);
        let b = cpu.ld1d(&bblock[d * 8..]);
        for j in 0..8 {
            let db = cpu.dup_b(b, j); // [y♭ × 16]
            let b1 = cpu.eor(db, mask); // [¬y♭×8 | y♭×8]
            for (g, a) in [a0, a1].into_iter().enumerate() {
                let up = cpu.and(a, b1);
                let um = cpu.bic(a, b1);
                let cp = cpu.cnt(up);
                let cm = cpu.cnt(um);
                let dl = cpu.ssubl(cp, cm);
                let dh = cpu.ssubl2(cp, cm);
                c[g][j] = cpu.add16(c[g][j], dl);
                c[g][j] = cpu.add16(c[g][j], dh);
            }
        }
    }
    let mut out = [0i16; 16 * 8];
    for j in 0..8 {
        let lo = c[0][j].to_i16x8();
        let hi = c[1][j].to_i16x8();
        for r in 0..8 {
            out[r * 8 + j] = lo[r];
            out[(8 + r) * 8 + j] = hi[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_tnn, pack_b_bnn};
    use crate::gemm::reference::gemm_i8;
    use crate::util::mat::MatI8;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatI8::random_ternary(16, k, &mut rng);
        let b = MatI8::random_binary(k, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, k);
        let pb = pack_b_bnn(&b, 0, k);
        let mut cpu = Neon::new();
        let t = tbn_microkernel(&mut cpu, &pa, &pb, k.div_ceil(8));
        let oracle = gemm_i8(&a, &b);
        for r in 0..16 {
            for j in 0..8 {
                assert_eq!(t[r * 8 + j] as i32, oracle.get(r, j), "r={r} j={j} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_k8() {
        check_case(8, 20);
    }

    #[test]
    fn matches_oracle_k512() {
        check_case(512, 21);
    }

    #[test]
    fn matches_oracle_odd_k() {
        for k in [2, 6, 11, 31, 77] {
            check_case(k, 300 + k as u64);
        }
    }

    /// Depth padding safety: binary B pads with 0-bits (decoded +1) but
    /// ternary A pads with the value 0 — products over padded depth are
    /// 0·(±1) = 0, so no epilogue correction is needed for TBN.
    #[test]
    fn depth_padding_contributes_nothing() {
        let k = 13;
        let a = MatI8::zeros(16, k);
        let mut rng = Rng::new(22);
        let b = MatI8::random_binary(k, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, k);
        let pb = pack_b_bnn(&b, 0, k);
        let mut cpu = Neon::new();
        let t = tbn_microkernel(&mut cpu, &pa, &pb, k.div_ceil(8));
        assert!(t.iter().all(|&v| v == 0));
    }

    /// Steady-state counts: COM=136, LD=3, MOV=8, total 147 (paper: 155;
    /// see module docs). TBN must be strictly cheaper than TNN and
    /// strictly more expensive than BNN in total instructions.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(23);
        let a = MatI8::random_ternary(16, 16, &mut rng);
        let b = MatI8::random_binary(16, 8, &mut rng);
        let pa = pack_a_tnn(&a, 0, 16);
        let pb = pack_b_bnn(&b, 0, 16);
        let mut c1 = Neon::new();
        tbn_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        tbn_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        assert_eq!(d.com, 136);
        assert_eq!(d.ld, 3);
        assert_eq!(d.mov, 8);
        assert_eq!(d.total(), 147);
        // Orderings from Table II hold: BNN (42) < TBN (146) < TNN (163).
        assert!(d.total() < 163);
        assert!(d.total() > 42);
    }
}
