//! U4 baseline microkernel: 24×8, depth step 2 — the 4-bit quantized
//! multiplication of Trusov et al. (ICPR 2020, ref. [20]), with the
//! microkernel upscaled from 24×4 (ARMv7) to 24×8 (AArch64) as the paper
//! describes in §IV.
//!
//! Values are unsigned 4-bit (0..=15), stored two per byte; products are
//! accumulated in **16-bit** lanes, which is what limits the depth to
//! k_max = ⌊(2¹⁶−1)/15²⌋ = 291 (Table II) — the driver splits deeper
//! multiplications into ≤290-deep blocks and widens between blocks.
//!
//! Per 2-deep iteration: 3 loads (24 packed A bytes, 8 packed B bytes),
//! 6 nibble-unpack ops (`AND`/`USHR` against a hoisted 0x0F mask),
//! 16 `DUP`s (one per depth×column — the paper's MOV=16) and 48 vector
//! `UMLAL`/`UMLAL2` into the 24 u16×8 accumulators (the paper's COM=48).

use crate::simd::reg::{Neon, Reg128};

const NIBBLE_MASK: [u8; 16] = [0x0F; 16];

/// Run the U4 microkernel over `chunks` 2-deep iterations. `ablock` is
/// `chunks*24` bytes (packed by [`crate::gemm::pack::pack_a_u4`]),
/// `bblock` `chunks*8`. Returns the 24×8 row-major raw-product tile in
/// u16 (the caller must respect k ≤ 291 per call).
pub fn u4_microkernel(cpu: &mut Neon, ablock: &[u8], bblock: &[u8], chunks: usize) -> [u16; 24 * 8] {
    debug_assert!(ablock.len() >= chunks * 24);
    debug_assert!(bblock.len() >= chunks * 8);
    debug_assert!(chunks * 2 <= 291, "U4 16-bit accumulators overflow past k=291");
    let mask = cpu.ld1q(&NIBBLE_MASK); // hoisted constant
    // c[g][j]: rows 8g..8g+8 of column j, u16 lanes.
    let mut c = [[Reg128::ZERO; 8]; 3];
    for d in 0..chunks {
        let a0 = cpu.ld1q(&ablock[d * 24..]); // rows 0..16, both depths packed
        let a1 = cpu.ld1d(&ablock[d * 24 + 16..]); // rows 16..24
        let b = cpu.ld1d(&bblock[d * 8..]); // cols 0..8, both depths packed
        // Nibble unpack: t=0 plane in low nibbles, t=1 in high.
        let a0_t0 = cpu.and(a0, mask);
        let a0_t1 = cpu.ushr8(a0, 4);
        let a1_t0 = cpu.and(a1, mask);
        let a1_t1 = cpu.ushr8(a1, 4);
        let b_t0 = cpu.and(b, mask);
        let b_t1 = cpu.ushr8(b, 4);
        for (a_lo, a_hi, bt) in [(a0_t0, a1_t0, b_t0), (a0_t1, a1_t1, b_t1)] {
            for j in 0..8 {
                let bj = cpu.dup_b(bt, j);
                c[0][j] = cpu.umlal_v8(c[0][j], a_lo, bj); // rows 0..8
                c[1][j] = cpu.umlal2_v8(c[1][j], a_lo, bj); // rows 8..16
                c[2][j] = cpu.umlal_v8(c[2][j], a_hi, bj); // rows 16..24
            }
        }
    }
    let mut out = [0u16; 24 * 8];
    for j in 0..8 {
        for g in 0..3 {
            let v = c[g][j].to_u16x8();
            for l in 0..8 {
                out[(8 * g + l) * 8 + j] = v[l];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::pack::{pack_a_u4, pack_b_u4};
    use crate::gemm::reference::gemm_u8_raw;
    use crate::util::mat::MatU8;
    use crate::util::Rng;

    fn check_case(k: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = MatU8::random_below(24, k, 15, &mut rng);
        let b = MatU8::random_below(k, 8, 15, &mut rng);
        let pa = pack_a_u4(&a, 0, k);
        let pb = pack_b_u4(&b, 0, k);
        let mut cpu = Neon::new();
        let t = u4_microkernel(&mut cpu, &pa, &pb, k.div_ceil(2));
        let oracle = gemm_u8_raw(&a, &b);
        for r in 0..24 {
            for j in 0..8 {
                assert_eq!(t[r * 8 + j] as i32, oracle.get(r, j), "r={r} j={j} k={k}");
            }
        }
    }

    #[test]
    fn matches_oracle_even_k() {
        check_case(2, 50);
        check_case(128, 51);
    }

    #[test]
    fn matches_oracle_odd_k() {
        for k in [1, 5, 17, 63] {
            check_case(k, 500 + k as u64);
        }
    }

    /// Table II U4 row: COM=48 UMLAL + 6 unpack, LD=3, MOV=16 DUPs.
    #[test]
    fn table2_counts() {
        let mut rng = Rng::new(52);
        let a = MatU8::random_below(24, 4, 15, &mut rng);
        let b = MatU8::random_below(4, 8, 15, &mut rng);
        let pa = pack_a_u4(&a, 0, 4);
        let pb = pack_b_u4(&b, 0, 4);
        let mut c1 = Neon::new();
        u4_microkernel(&mut c1, &pa, &pb, 1);
        let mut c2 = Neon::new();
        u4_microkernel(&mut c2, &pa, &pb, 2);
        let d = c2.trace.delta(&c1.trace);
        let umlal = d.by_mnemonic.get("UMLAL.8B").copied().unwrap_or(0)
            + d.by_mnemonic.get("UMLAL2.16B").copied().unwrap_or(0);
        assert_eq!(umlal, 48, "48 multiply-accumulates per iteration (paper: 48)");
        assert_eq!(d.mov, 16, "16 DUPs per iteration (paper MOV=16)");
        assert_eq!(d.ld, 3);
        // INS must sit strictly between TNN (0.159) and U8 (0.302),
        // preserving the paper's ordering.
        let ins = d.ins_metric(24, 8, 2);
        assert!(ins > 0.159 && ins < 0.302, "INS {ins} out of order");
    }

    /// The worst-case bound at k = 290 (the largest even depth under
    /// k_max): all values 15, accumulators must not wrap.
    #[test]
    fn no_overflow_at_kmax() {
        let k = 290;
        let a = MatU8 { rows: 24, cols: k, data: vec![15; 24 * k] };
        let b = MatU8 { rows: k, cols: 8, data: vec![15; k * 8] };
        let pa = pack_a_u4(&a, 0, k);
        let pb = pack_b_u4(&b, 0, k);
        let mut cpu = Neon::new();
        let t = u4_microkernel(&mut cpu, &pa, &pb, k / 2);
        assert!(t.iter().all(|&v| v as usize == 225 * k));
        assert!(225 * k <= u16::MAX as usize);
    }
}
