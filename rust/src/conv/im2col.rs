//! The im2col transformation: unroll a feature map into the left GEMM
//! matrix. Row `(oy·W_out + ox)` holds the receptive field of output
//! pixel `(oy, ox)`, laid out `(ky, kx, c)`-major; the matching weight
//! matrix is `(H_k·W_k·C_in) × C_out` in the same depth order.

use crate::conv::conv2d::ConvParams;
use crate::conv::tensor::Tensor3;

/// Unroll `input` (HWC) for the convolution `p`, padding out-of-bounds
/// taps with `pad_value`. Output: `(out_h·out_w) × (hk·wk·c)` row-major.
pub fn im2col<T: Copy + Default>(input: &Tensor3<T>, p: &ConvParams, pad_value: T) -> (Vec<T>, usize, usize) {
    let mut out = Vec::new();
    let (rows, depth) = im2col_into(input, p, pad_value, &mut out);
    (out, rows, depth)
}

/// [`im2col`] into a caller-owned buffer: `out` is cleared and refilled,
/// reusing its allocation (steady state: no heap allocation once capacity
/// has grown to the largest unrolled size seen). Returns `(rows, depth)`.
pub fn im2col_into<T: Copy + Default>(
    input: &Tensor3<T>,
    p: &ConvParams,
    pad_value: T,
    out: &mut Vec<T>,
) -> (usize, usize) {
    let (oh, ow) = p.out_dims(input.h, input.w);
    let depth = p.hk * p.wk * input.c;
    out.clear();
    out.resize(oh * ow * depth, T::default());
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let base = row * depth;
            let mut idx = base;
            for ky in 0..p.hk {
                let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                for kx in 0..p.wk {
                    let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                    if iy >= 0 && (iy as usize) < input.h && ix >= 0 && (ix as usize) < input.w {
                        let (iy, ix) = (iy as usize, ix as usize);
                        let src = (iy * input.w + ix) * input.c;
                        out[idx..idx + input.c].copy_from_slice(&input.data[src..src + input.c]);
                    } else {
                        for v in &mut out[idx..idx + input.c] {
                            *v = pad_value;
                        }
                    }
                    idx += input.c;
                }
            }
        }
    }
    (oh * ow, depth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_no_pad() {
        // 1×1 kernel, stride 1, no pad: im2col is the pixel list itself.
        let t = Tensor3::from_fn(2, 2, 3, |y, x, c| (y * 100 + x * 10 + c) as i32);
        let p = ConvParams { hk: 1, wk: 1, stride: 1, pad: 0 };
        let (m, rows, depth) = im2col(&t, &p, 0);
        assert_eq!((rows, depth), (4, 3));
        assert_eq!(m, t.data);
    }

    #[test]
    fn three_by_three_padded_shape() {
        let t: Tensor3<i8> = Tensor3::zeros(5, 7, 2);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let (m, rows, depth) = im2col(&t, &p, 0);
        assert_eq!(rows, 35); // same-size output
        assert_eq!(depth, 18);
        assert_eq!(m.len(), 35 * 18);
    }

    #[test]
    fn padding_taps_use_pad_value() {
        let t = Tensor3::from_fn(2, 2, 1, |_, _, _| 5i32);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let (m, _, depth) = im2col(&t, &p, 9);
        // Output pixel (0,0): top-left taps fall outside → pad value 9.
        let row0 = &m[0..depth];
        assert_eq!(row0[0], 9); // (ky=0,kx=0)
        assert_eq!(row0[4], 5); // (ky=1,kx=1) = input (0,0)
    }

    #[test]
    fn stride_two_downsamples() {
        let t = Tensor3::from_fn(4, 4, 1, |y, x, _| (y * 4 + x) as i32);
        let p = ConvParams { hk: 1, wk: 1, stride: 2, pad: 0 };
        let (m, rows, _) = im2col(&t, &p, 0);
        assert_eq!(rows, 4);
        assert_eq!(m, vec![0, 2, 8, 10]);
    }

    #[test]
    fn into_variant_matches_and_reuses_buffer() {
        let t = Tensor3::from_fn(4, 5, 2, |y, x, c| (y * 100 + x * 10 + c) as i32);
        let p = ConvParams { hk: 3, wk: 2, stride: 1, pad: 1 };
        let (want, rows, depth) = im2col(&t, &p, -7);
        let mut buf = Vec::new();
        assert_eq!(im2col_into(&t, &p, -7, &mut buf), (rows, depth));
        assert_eq!(buf, want);
        let ptr = buf.as_ptr();
        im2col_into(&t, &p, -7, &mut buf);
        assert_eq!(buf.as_ptr(), ptr, "im2col_into reallocated at steady state");
        assert_eq!(buf, want);
    }

    #[test]
    fn receptive_field_order_is_ky_kx_c() {
        let t = Tensor3::from_fn(3, 3, 2, |y, x, c| (y * 100 + x * 10 + c) as i32);
        let p = ConvParams { hk: 2, wk: 2, stride: 1, pad: 0 };
        let (m, rows, depth) = im2col(&t, &p, -1);
        assert_eq!((rows, depth), (4, 8));
        // Row for output (0,0): taps (0,0),(0,1),(1,0),(1,1), channels inner.
        assert_eq!(&m[0..8], &[0, 1, 10, 11, 100, 101, 110, 111]);
    }
}
