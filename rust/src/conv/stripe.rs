//! Memory-frugal stripe convolution — the paper's memory theme made
//! concrete.
//!
//! §II of the paper motivates its Algorithm 2 variant with mobile memory
//! limits ("buffer A_buf is noticeably smaller ... That can help the
//! inference on mobile devices where memory is limited") and cites the
//! authors' p-im2col (ref. [13]) as the established way to trade im2col
//! memory for time. This module implements that idea for the low-bit
//! kernels: instead of materializing the full `(OH·OW) × depth` im2col
//! matrix, it materializes **one output row's** patch matrix at a time
//! (`OW × depth`), runs the native low-bit GEMM on the stripe, and reuses
//! the buffer — peak extra memory drops from `O(OH·OW·depth)` to
//! `O(OW·depth)` (an `OH`-fold reduction) with identical results.

use crate::conv::conv2d::{ConvKind, ConvParams};
use crate::conv::tensor::Tensor3;
use crate::gemm::{GemmConfig, GemmOut, GemmPlan, GemmScratch, KPanel, Lhs, Threading, Weights};
use crate::util::mat::MatI8;

/// Reusable scratch arena for [`StripeConv::forward_into`]: one stripe's
/// patch matrix, the shared GEMM packing arena
/// ([`crate::gemm::GemmScratch`]), and the stripe GEMM output. Grown on
/// demand; steady-state forward passes perform no heap allocation.
pub struct StripeScratch {
    stripe: MatI8,
    /// The plan's LHS packing arena.
    pub gemm: GemmScratch,
    c: GemmOut,
}

impl StripeScratch {
    pub fn new() -> Self {
        StripeScratch {
            stripe: MatI8::zeros(0, 0),
            gemm: GemmScratch::new(),
            c: GemmOut::new_i32(),
        }
    }
}

impl Default for StripeScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A convolution layer computed stripe-by-stripe over a built-once
/// [`GemmPlan`] (weights packed offline exactly as in
/// [`crate::conv::conv2d::LowBitConv`]).
pub struct StripeConv {
    pub kind: ConvKind,
    pub params: ConvParams,
    pub c_in: usize,
    pub c_out: usize,
    /// The built-once multiplication plan (native backend); its
    /// threading applies per stripe GEMM (stripes are short, so it pays
    /// off only for wide outputs).
    plan: GemmPlan,
}

impl StripeConv {
    pub fn new(kind: ConvKind, params: ConvParams, c_in: usize, weights: &MatI8) -> Self {
        assert_eq!(weights.rows, params.depth(c_in), "weight depth mismatch");
        let c_out = weights.cols;
        let plan = GemmPlan::new(GemmConfig::native(kind.gemm_kind()), Weights::I8(weights))
            .unwrap_or_else(|e| panic!("{kind:?} stripe-conv weights rejected: {e}"));
        StripeConv { kind, params, c_in, c_out, plan }
    }

    /// Builder-style threading override.
    pub fn with_threading(mut self, threading: Threading) -> Self {
        self.plan.set_threading(threading);
        self
    }

    /// Builder-style K-panel override (deep-K depth blocking).
    pub fn with_k_panel(mut self, k_panel: KPanel) -> Self {
        self.plan.set_k_panel(k_panel);
        self
    }

    /// Peak scratch elements this convolution needs (one stripe).
    pub fn stripe_scratch_elems(&self, in_w: usize) -> usize {
        let (_, ow) = self.params.out_dims(in_w, in_w);
        ow * self.params.depth(self.c_in)
    }

    /// Run the convolution with one-row stripes. Allocates fresh scratch;
    /// hot callers should hold a [`StripeScratch`] + output tensor and
    /// use [`StripeConv::forward_into`].
    pub fn forward(&self, input: &Tensor3<i8>) -> Tensor3<i32> {
        let mut scratch = StripeScratch::new();
        let mut out = Tensor3::zeros(0, 0, 0);
        self.forward_into(input, &mut scratch, &mut out);
        out
    }

    /// Run the convolution with one-row stripes into caller-owned scratch
    /// and output storage (steady state: no heap allocation).
    pub fn forward_into(&self, input: &Tensor3<i8>, scratch: &mut StripeScratch, out: &mut Tensor3<i32>) {
        assert_eq!(input.c, self.c_in);
        let p = &self.params;
        let (oh, ow) = p.out_dims(input.h, input.w);
        let depth = p.depth(self.c_in);
        let pad_value = if self.kind == ConvKind::Bnn { 1i8 } else { 0i8 };
        out.h = oh;
        out.w = ow;
        out.c = self.c_out;
        out.data.clear();
        out.data.resize(oh * ow * self.c_out, 0);
        // Reused stripe buffer (the plan sizes the output in place).
        scratch.stripe.rows = ow;
        scratch.stripe.cols = depth;
        scratch.stripe.data.clear();
        scratch.stripe.data.resize(ow * depth, 0);
        for oy in 0..oh {
            // Fill the stripe: patch rows for output row oy.
            for ox in 0..ow {
                let mut idx = 0;
                for ky in 0..p.hk {
                    let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                    for kx in 0..p.wk {
                        let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                        for ch in 0..self.c_in {
                            let v = if iy >= 0
                                && (iy as usize) < input.h
                                && ix >= 0
                                && (ix as usize) < input.w
                            {
                                input.get(iy as usize, ix as usize, ch)
                            } else {
                                pad_value
                            };
                            scratch.stripe.set(ox, idx, v);
                            idx += 1;
                        }
                    }
                }
            }
            self.plan
                .run(Lhs::I8(&scratch.stripe), &mut scratch.c, &mut scratch.gemm)
                .unwrap_or_else(|e| panic!("stripe GEMM plan invariant violated: {e}"));
            // Stripe output is (ox, f)-major — exactly the HWC slice of
            // output row oy.
            let row_base = oy * ow * self.c_out;
            match &scratch.c {
                GemmOut::I32(cm) => {
                    out.data[row_base..row_base + ow * self.c_out].copy_from_slice(&cm.data)
                }
                GemmOut::F32(_) => unreachable!("stripe kinds produce i32 output"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d::{direct_conv_i8, LowBitConv};
    use crate::util::proptest::{check, Config};
    use crate::util::Rng;

    fn random_case(rng: &mut Rng, kind: ConvKind) {
        let c_in = 1 + rng.below(5);
        let c_out = 1 + rng.below(9);
        let h = 4 + rng.below(7);
        let w = 4 + rng.below(7);
        let p = ConvParams { hk: 1 + rng.below(3), wk: 1 + rng.below(3), stride: 1 + rng.below(2), pad: rng.below(2) };
        let depth = p.depth(c_in);
        let input = match kind {
            ConvKind::Bnn => Tensor3::random_binary(h, w, c_in, rng),
            _ => Tensor3::random_ternary(h, w, c_in, rng),
        };
        let weights = match kind {
            ConvKind::Tnn => MatI8::random_ternary(depth, c_out, rng),
            _ => MatI8::random_binary(depth, c_out, rng),
        };
        let stripe = StripeConv::new(kind, p, c_in, &weights);
        let full = LowBitConv::new(kind, p, c_in, &weights);
        let got = stripe.forward(&input);
        let via_full = full.forward(&input);
        assert_eq!(got.data, via_full.data, "stripe ≡ full im2col, {kind:?}");
        let pad_value = if kind == ConvKind::Bnn { 1 } else { 0 };
        let oracle = direct_conv_i8(&input, &weights, &p, pad_value);
        assert_eq!(got.data, oracle.data, "stripe ≡ direct, {kind:?}");
    }

    #[test]
    fn stripe_matches_full_and_direct_tnn() {
        check(Config { cases: 16, base_seed: 0xAB0 }, "stripe tnn", |rng| random_case(rng, ConvKind::Tnn));
    }

    #[test]
    fn stripe_matches_full_and_direct_bnn() {
        check(Config { cases: 16, base_seed: 0xAB1 }, "stripe bnn", |rng| random_case(rng, ConvKind::Bnn));
    }

    #[test]
    fn stripe_matches_full_and_direct_tbn() {
        check(Config { cases: 16, base_seed: 0xAB2 }, "stripe tbn", |rng| random_case(rng, ConvKind::Tbn));
    }

    /// `forward_into` matches `forward`, reuses its arena, and threading
    /// does not change results.
    #[test]
    fn stripe_scratch_steady_state_and_threading() {
        let mut rng = Rng::new(0xAB4);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let w = MatI8::random_ternary(p.depth(3), 5, &mut rng);
        let conv = StripeConv::new(ConvKind::Tnn, p, 3, &w);
        let input = Tensor3::random_ternary(10, 12, 3, &mut rng);
        let want = conv.forward(&input);
        let mut scratch = StripeScratch::new();
        let mut out = Tensor3::zeros(0, 0, 0);
        conv.forward_into(&input, &mut scratch, &mut out);
        assert_eq!(out.data, want.data);
        let (s_ptr, o_ptr) = (scratch.stripe.data.as_ptr(), out.data.as_ptr());
        conv.forward_into(&input, &mut scratch, &mut out);
        assert_eq!(scratch.stripe.data.as_ptr(), s_ptr, "stripe scratch reallocated");
        assert_eq!(out.data.as_ptr(), o_ptr, "stripe output reallocated");
        assert_eq!(out.data, want.data);

        use crate::gemm::native::Threading;
        let threaded = StripeConv::new(ConvKind::Tnn, p, 3, &w).with_threading(Threading::Fixed(4));
        assert_eq!(threaded.forward(&input).data, want.data);
    }

    /// The memory claim: stripe scratch is OH× smaller than full im2col.
    #[test]
    fn scratch_is_one_row() {
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let mut rng = Rng::new(0xAB3);
        let w = MatI8::random_ternary(p.depth(8), 16, &mut rng);
        let conv = StripeConv::new(ConvKind::Tnn, p, 8, &w);
        let stripe_elems = conv.stripe_scratch_elems(28);
        let full_elems = 28 * 28 * p.depth(8);
        assert_eq!(stripe_elems * 28, full_elems);
    }
}
