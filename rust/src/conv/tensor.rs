//! Single-image HWC tensors.

use crate::util::Rng;

/// A height × width × channels tensor, row-major with channels innermost.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3<T> {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Tensor3<T> {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Tensor3 { h, w, c, data: vec![T::default(); h * w * c] }
    }

    pub fn from_fn(h: usize, w: usize, c: usize, mut f: impl FnMut(usize, usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(h * w * c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    data.push(f(y, x, ch));
                }
            }
        }
        Tensor3 { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> T {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: T) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    /// Number of pixels (`h·w`) — the GEMM "height" after im2col.
    pub fn pixels(&self) -> usize {
        self.h * self.w
    }

    /// Resize in place to `h × w × c`, zero-filled, reusing the existing
    /// allocation: once capacity has grown to the largest shape a caller
    /// uses, no further heap allocation occurs (the arena contract of
    /// [`crate::nn::NetScratch`]).
    pub fn resize_to(&mut self, h: usize, w: usize, c: usize) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.clear();
        self.data.resize(h * w * c, T::default());
    }
}

impl Tensor3<i8> {
    pub fn random_binary(h: usize, w: usize, c: usize, rng: &mut Rng) -> Self {
        Tensor3::from_fn(h, w, c, |_, _, _| rng.binary())
    }

    pub fn random_ternary(h: usize, w: usize, c: usize, rng: &mut Rng) -> Self {
        Tensor3::from_fn(h, w, c, |_, _, _| rng.ternary())
    }
}

impl Tensor3<f32> {
    pub fn random(h: usize, w: usize, c: usize, rng: &mut Rng) -> Self {
        Tensor3::from_fn(h, w, c, |_, _, _| rng.normalish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hwc_indexing() {
        let t = Tensor3::from_fn(2, 3, 4, |y, x, c| (y * 100 + x * 10 + c) as i32);
        assert_eq!(t.get(1, 2, 3), 123);
        assert_eq!(t.data.len(), 24);
        // channels innermost
        assert_eq!(t.data[0], 0);
        assert_eq!(t.data[1], 1);
        assert_eq!(t.data[4], 10);
    }

    #[test]
    fn set_then_get() {
        let mut t: Tensor3<i8> = Tensor3::zeros(3, 3, 2);
        t.set(2, 1, 1, -1);
        assert_eq!(t.get(2, 1, 1), -1);
        assert_eq!(t.get(2, 1, 0), 0);
    }
}
