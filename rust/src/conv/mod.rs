//! GeMM-based convolution (§I / §II of the paper): the `im2col`
//! transformation plus the low-bit GEMM drivers turn a convolution into
//! one matrix multiplication, exactly the deployment path the paper
//! targets ("Our algorithms can be used in the GeMM-based convolution
//! implementations of CNNs").
//!
//! Tensors are single-image HWC (height × width × channels) so that each
//! im2col row — one output pixel's receptive field, `(ky, kx, c)`-major —
//! is assembled from contiguous channel runs.
//!
//! Padding values follow the encodings: ternary activations pad with `0`
//! (which contributes nothing to a dot product); binary activations have
//! no zero, so binary convolutions pad with `+1`, the convention used by
//! XNOR-Net-style BNNs.

pub mod conv2d;
pub mod im2col;
pub mod stripe;
pub mod tensor;

pub use conv2d::{direct_conv_i8, ConvParams};
pub use im2col::im2col;
pub use stripe::StripeConv;
pub use tensor::Tensor3;
