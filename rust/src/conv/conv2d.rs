//! Convolution parameters, the direct (oracle) convolution, and the
//! GeMM-based convolution built on im2col + the low-bit drivers.

use crate::conv::im2col::im2col;
use crate::conv::tensor::Tensor3;
use crate::gemm::native::{BitRows, PlaneRows};
use crate::gemm::native::kernels::{bnn_gemm, tbn_gemm, tnn_gemm};
use crate::util::mat::{MatI32, MatI8};

/// Square-window convolution hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvParams {
    pub hk: usize,
    pub wk: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvParams {
    /// Output spatial dimensions for an `h × w` input.
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        let oh = (h + 2 * self.pad).saturating_sub(self.hk) / self.stride + 1;
        let ow = (w + 2 * self.pad).saturating_sub(self.wk) / self.stride + 1;
        (oh, ow)
    }

    /// GEMM depth after im2col for `c_in` channels.
    pub fn depth(&self, c_in: usize) -> usize {
        self.hk * self.wk * c_in
    }
}

/// Direct (nested-loop) convolution over i8 values — the oracle.
/// `weights` is `(hk·wk·c_in) × c_out` in `(ky, kx, c)`-major depth order,
/// matching im2col. Out-of-bounds taps read `pad_value`.
pub fn direct_conv_i8(input: &Tensor3<i8>, weights: &MatI8, p: &ConvParams, pad_value: i8) -> Tensor3<i32> {
    let c_out = weights.cols;
    assert_eq!(weights.rows, p.depth(input.c));
    let (oh, ow) = p.out_dims(input.h, input.w);
    let mut out = Tensor3::zeros(oh, ow, c_out);
    for oy in 0..oh {
        for ox in 0..ow {
            for f in 0..c_out {
                let mut acc = 0i32;
                let mut d = 0;
                for ky in 0..p.hk {
                    for kx in 0..p.wk {
                        let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                        let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                        for ch in 0..input.c {
                            let v = if iy >= 0 && (iy as usize) < input.h && ix >= 0 && (ix as usize) < input.w {
                                input.get(iy as usize, ix as usize, ch)
                            } else {
                                pad_value
                            };
                            acc += v as i32 * weights.get(d, f) as i32;
                            d += 1;
                        }
                    }
                }
                out.set(oy, ox, f, acc);
            }
        }
    }
    out
}

/// Which low-bit multiplication implements the convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKind {
    /// Binary activations × binary weights.
    Bnn,
    /// Ternary activations × ternary weights.
    Tnn,
    /// Ternary activations × binary weights (the TBN of ref. [28]).
    Tbn,
}

/// A convolution layer with pre-packed weights, executed as
/// im2col + native low-bit GEMM (the deployment path of the paper).
pub struct LowBitConv {
    pub kind: ConvKind,
    pub params: ConvParams,
    pub c_in: usize,
    pub c_out: usize,
    /// Weights packed offline: bit rows (binary) or plane rows (ternary)
    /// of the transposed weight matrix.
    packed_bits: Option<BitRows>,
    packed_planes: Option<PlaneRows>,
}

impl LowBitConv {
    /// Pack `weights` (`depth × c_out`, im2col depth order) offline.
    pub fn new(kind: ConvKind, params: ConvParams, c_in: usize, weights: &MatI8) -> Self {
        assert_eq!(weights.rows, params.depth(c_in), "weight depth mismatch");
        let c_out = weights.cols;
        let (packed_bits, packed_planes) = match kind {
            ConvKind::Bnn | ConvKind::Tbn => {
                assert!(weights.is_binary(), "{kind:?} weights must be ±1");
                (Some(BitRows::from_binary_transposed(weights)), None)
            }
            ConvKind::Tnn => {
                assert!(weights.is_ternary());
                (None, Some(PlaneRows::from_ternary_transposed(weights)))
            }
        };
        LowBitConv { kind, params, c_in, c_out, packed_bits, packed_planes }
    }

    /// Run the convolution. Binary activations pad with `+1`, ternary
    /// with `0`.
    pub fn forward(&self, input: &Tensor3<i8>) -> Tensor3<i32> {
        assert_eq!(input.c, self.c_in);
        let (oh, ow) = self.params.out_dims(input.h, input.w);
        let pad_value = match self.kind {
            ConvKind::Bnn => 1i8,
            ConvKind::Tnn | ConvKind::Tbn => 0i8,
        };
        let (cols, rows, depth) = im2col(input, &self.params, pad_value);
        let a = MatI8 { rows, cols: depth, data: cols };
        let mut c = MatI32::zeros(rows, self.c_out);
        match self.kind {
            ConvKind::Bnn => {
                let ab = BitRows::from_binary(&a);
                bnn_gemm(&ab, self.packed_bits.as_ref().unwrap(), &mut c);
            }
            ConvKind::Tnn => {
                let ap = PlaneRows::from_ternary(&a);
                tnn_gemm(&ap, self.packed_planes.as_ref().unwrap(), &mut c);
            }
            ConvKind::Tbn => {
                let ap = PlaneRows::from_ternary(&a);
                tbn_gemm(&ap, self.packed_bits.as_ref().unwrap(), &mut c);
            }
        }
        Tensor3 { h: oh, w: ow, c: self.c_out, data: c.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Rng;

    fn random_conv_case(rng: &mut Rng, kind: ConvKind) {
        let c_in = 1 + rng.below(6);
        let c_out = 1 + rng.below(10);
        let h = 3 + rng.below(8);
        let w = 3 + rng.below(8);
        let hk = 1 + rng.below(3);
        let wk = 1 + rng.below(3);
        let stride = 1 + rng.below(2);
        let pad = rng.below(2);
        let p = ConvParams { hk, wk, stride, pad };
        let depth = p.depth(c_in);
        let input = match kind {
            ConvKind::Bnn => Tensor3::random_binary(h, w, c_in, rng),
            _ => Tensor3::random_ternary(h, w, c_in, rng),
        };
        let weights = match kind {
            ConvKind::Tnn => MatI8::random_ternary(depth, c_out, rng),
            _ => MatI8::random_binary(depth, c_out, rng),
        };
        let pad_value = if kind == ConvKind::Bnn { 1 } else { 0 };
        let conv = LowBitConv::new(kind, p, c_in, &weights);
        let got = conv.forward(&input);
        let want = direct_conv_i8(&input, &weights, &p, pad_value);
        assert_eq!(got.data, want.data, "kind={kind:?} h={h} w={w} cin={c_in} cout={c_out} k={hk}x{wk} s={stride} p={pad}");
    }

    #[test]
    fn bnn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD0 }, "bnn conv", |rng| random_conv_case(rng, ConvKind::Bnn));
    }

    #[test]
    fn tnn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD1 }, "tnn conv", |rng| random_conv_case(rng, ConvKind::Tnn));
    }

    #[test]
    fn tbn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD2 }, "tbn conv", |rng| random_conv_case(rng, ConvKind::Tbn));
    }

    #[test]
    fn out_dims_formulas() {
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        assert_eq!(p.out_dims(28, 28), (28, 28));
        let p2 = ConvParams { hk: 2, wk: 2, stride: 2, pad: 0 };
        assert_eq!(p2.out_dims(28, 28), (14, 14));
    }

    #[test]
    fn depth_is_hk_wk_cin() {
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        assert_eq!(p.depth(64), 576);
    }
}
