//! Convolution parameters, the direct (oracle) convolution, and the
//! GeMM-based convolution built on im2col + a built-once low-bit
//! [`crate::gemm::GemmPlan`].

use crate::conv::im2col::im2col_into;
use crate::conv::tensor::Tensor3;
use crate::gemm::{
    Backend, GemmConfig, GemmError, GemmOut, GemmPlan, GemmScratch, KPanel, Kind, Lhs, Threading, Tile,
    Weights,
};
use crate::util::mat::{MatI32, MatI8};

/// Square-window convolution hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvParams {
    pub hk: usize,
    pub wk: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvParams {
    /// Output spatial dimensions for an `h × w` input.
    pub fn out_dims(&self, h: usize, w: usize) -> (usize, usize) {
        let oh = (h + 2 * self.pad).saturating_sub(self.hk) / self.stride + 1;
        let ow = (w + 2 * self.pad).saturating_sub(self.wk) / self.stride + 1;
        (oh, ow)
    }

    /// GEMM depth after im2col for `c_in` channels.
    pub fn depth(&self, c_in: usize) -> usize {
        self.hk * self.wk * c_in
    }
}

/// Direct (nested-loop) convolution over i8 values — the oracle.
/// `weights` is `(hk·wk·c_in) × c_out` in `(ky, kx, c)`-major depth order,
/// matching im2col. Out-of-bounds taps read `pad_value`.
pub fn direct_conv_i8(input: &Tensor3<i8>, weights: &MatI8, p: &ConvParams, pad_value: i8) -> Tensor3<i32> {
    let c_out = weights.cols;
    assert_eq!(weights.rows, p.depth(input.c));
    let (oh, ow) = p.out_dims(input.h, input.w);
    let mut out = Tensor3::zeros(oh, ow, c_out);
    for oy in 0..oh {
        for ox in 0..ow {
            for f in 0..c_out {
                let mut acc = 0i32;
                let mut d = 0;
                for ky in 0..p.hk {
                    for kx in 0..p.wk {
                        let iy = (oy * p.stride + ky) as isize - p.pad as isize;
                        let ix = (ox * p.stride + kx) as isize - p.pad as isize;
                        for ch in 0..input.c {
                            let v = if iy >= 0 && (iy as usize) < input.h && ix >= 0 && (ix as usize) < input.w {
                                input.get(iy as usize, ix as usize, ch)
                            } else {
                                pad_value
                            };
                            acc += v as i32 * weights.get(d, f) as i32;
                            d += 1;
                        }
                    }
                }
                out.set(oy, ox, f, acc);
            }
        }
    }
    out
}

/// Which low-bit multiplication implements the convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvKind {
    /// Binary activations × binary weights.
    Bnn,
    /// Ternary activations × ternary weights.
    Tnn,
    /// Ternary activations × binary weights (the TBN of ref. [28]).
    Tbn,
}

impl ConvKind {
    /// The GEMM kind implementing this convolution.
    pub fn gemm_kind(self) -> Kind {
        match self {
            ConvKind::Bnn => Kind::Bnn,
            ConvKind::Tnn => Kind::Tnn,
            ConvKind::Tbn => Kind::Tbn,
        }
    }
}

/// Reusable scratch arena for [`LowBitConv::forward_into`]: the im2col
/// matrix plus the shared GEMM packing arena
/// ([`crate::gemm::GemmScratch`]). All buffers are grown on demand and
/// reused across calls, so a steady-state sequence of forward passes at
/// fixed (or shrinking) shapes performs no heap allocation.
pub struct ConvScratch {
    /// The unrolled im2col activation matrix.
    pub(crate) a: MatI8,
    /// The plan's LHS packing arena (bit rows / plane rows).
    pub gemm: GemmScratch,
}

impl ConvScratch {
    pub fn new() -> Self {
        ConvScratch { a: MatI8::zeros(0, 0), gemm: GemmScratch::new() }
    }

    /// Pre-grow the im2col buffer to `elems` elements (the plan-build
    /// warm-up; steady-state forwards then never reallocate it).
    pub(crate) fn reserve(&mut self, elems: usize) {
        self.a.data.reserve(elems.saturating_sub(self.a.data.len()));
    }
}

impl Default for ConvScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A convolution layer with a pre-built [`GemmPlan`] (weights packed
/// once, offline), executed as im2col + low-bit GEMM — the deployment
/// path of the paper. The GEMM runs tiled + cache-blocked, and
/// multithreaded per the plan's [`Threading`] config.
pub struct LowBitConv {
    pub kind: ConvKind,
    pub params: ConvParams,
    pub c_in: usize,
    pub c_out: usize,
    /// The built-once multiplication plan.
    plan: GemmPlan,
    /// The quantized weights, retained so [`LowBitConv::configure`] can
    /// rebuild the plan on another backend without the original matrix.
    /// Deliberate memory trade-off: the raw i8 copy (~1 byte/weight, a
    /// few hundred KB for the mobile CNN) buys whole-network backend
    /// differentials as a one-flag config change.
    weights: MatI8,
}

impl LowBitConv {
    /// Pack `weights` (`depth × c_out`, im2col depth order) offline into
    /// a native-backend [`GemmPlan`].
    pub fn new(kind: ConvKind, params: ConvParams, c_in: usize, weights: &MatI8) -> Self {
        assert_eq!(weights.rows, params.depth(c_in), "weight depth mismatch");
        let c_out = weights.cols;
        let plan = GemmPlan::new(GemmConfig::native(kind.gemm_kind()), Weights::I8(weights))
            .unwrap_or_else(|e| panic!("{kind:?} conv weights rejected: {e}"));
        LowBitConv { kind, params, c_in, c_out, plan, weights: weights.clone() }
    }

    /// Apply a full execution config. Threading / K-panel / tile land on
    /// the existing plan without repacking; a backend change rebuilds the
    /// plan from the retained weights (packing once for the new backend).
    pub fn configure(
        &mut self,
        backend: Backend,
        threading: Threading,
        k_panel: KPanel,
        tile: Tile,
    ) -> Result<(), GemmError> {
        if backend == self.plan.backend() {
            self.plan.set_threading(threading);
            self.plan.set_k_panel(k_panel);
            self.plan.set_tile(tile);
        } else {
            let config = GemmConfig { kind: self.kind.gemm_kind(), backend, threading, k_panel, tile };
            self.plan = GemmPlan::new(config, Weights::I8(&self.weights))?;
        }
        Ok(())
    }

    /// Builder-style threading override.
    pub fn with_threading(mut self, threading: Threading) -> Self {
        self.plan.set_threading(threading);
        self
    }

    pub fn set_threading(&mut self, threading: Threading) {
        self.plan.set_threading(threading);
    }

    /// Builder-style K-panel override (deep-K depth blocking).
    pub fn with_k_panel(mut self, k_panel: KPanel) -> Self {
        self.plan.set_k_panel(k_panel);
        self
    }

    /// Builder-style register-tile override (e.g. the widened 4×4 BNN
    /// tile, [`Tile::Wide`]).
    pub fn with_tile(mut self, tile: Tile) -> Self {
        self.plan.set_tile(tile);
        self
    }

    /// Run the convolution. Binary activations pad with `+1`, ternary
    /// with `0`. Allocates fresh scratch and panics on a malformed input
    /// (a convenience wrapper for tests and benches); hot callers hold a
    /// [`ConvScratch`] + output tensor and use [`LowBitConv::forward_into`].
    pub fn forward(&self, input: &Tensor3<i8>) -> Tensor3<i32> {
        let mut scratch = ConvScratch::new();
        let mut out = Tensor3::zeros(0, 0, 0);
        self.forward_into(input, &mut scratch, &mut out)
            .unwrap_or_else(|e| panic!("LowBitConv::forward: {e}"));
        out
    }

    /// Run the convolution into caller-owned scratch and output storage.
    /// `out` is resized to `oh × ow × c_out`; in steady state (same or
    /// smaller shape as a previous call) no heap allocation occurs.
    ///
    /// A channel-count mismatch surfaces as the plan's typed
    /// [`GemmError::DepthMismatch`] (the im2col depth no longer matches
    /// the packed weights); nothing on this path panics.
    pub fn forward_into(
        &self,
        input: &Tensor3<i8>,
        scratch: &mut ConvScratch,
        out: &mut Tensor3<i32>,
    ) -> Result<(), GemmError> {
        let (oh, ow) = self.params.out_dims(input.h, input.w);
        let pad_value = match self.kind {
            ConvKind::Bnn => 1i8,
            ConvKind::Tnn | ConvKind::Tbn => 0i8,
        };
        let (rows, depth) = im2col_into(input, &self.params, pad_value, &mut scratch.a.data);
        scratch.a.rows = rows;
        scratch.a.cols = depth;
        debug_assert_eq!(rows, oh * ow);
        out.h = oh;
        out.w = ow;
        out.c = self.c_out;
        // The GEMM output layout (row = oy·ow + ox, col = channel) is
        // exactly the HWC tensor layout, so the plan writes straight into
        // the output tensor's storage (moved into the GemmOut wrapper and
        // back; the plan sizes it in place).
        let mut c = GemmOut::I32(MatI32 { rows: 0, cols: 0, data: std::mem::take(&mut out.data) });
        let run = self.plan.run(Lhs::I8(&scratch.a), &mut c, &mut scratch.gemm);
        if let GemmOut::I32(m) = c {
            out.data = m.data;
        }
        run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Rng;

    fn random_conv_case(rng: &mut Rng, kind: ConvKind) {
        let c_in = 1 + rng.below(6);
        let c_out = 1 + rng.below(10);
        let h = 3 + rng.below(8);
        let w = 3 + rng.below(8);
        let hk = 1 + rng.below(3);
        let wk = 1 + rng.below(3);
        let stride = 1 + rng.below(2);
        let pad = rng.below(2);
        let p = ConvParams { hk, wk, stride, pad };
        let depth = p.depth(c_in);
        let input = match kind {
            ConvKind::Bnn => Tensor3::random_binary(h, w, c_in, rng),
            _ => Tensor3::random_ternary(h, w, c_in, rng),
        };
        let weights = match kind {
            ConvKind::Tnn => MatI8::random_ternary(depth, c_out, rng),
            _ => MatI8::random_binary(depth, c_out, rng),
        };
        let pad_value = if kind == ConvKind::Bnn { 1 } else { 0 };
        let conv = LowBitConv::new(kind, p, c_in, &weights);
        let got = conv.forward(&input);
        let want = direct_conv_i8(&input, &weights, &p, pad_value);
        assert_eq!(
            got.data, want.data,
            "kind={kind:?} h={h} w={w} cin={c_in} cout={c_out} k={hk}x{wk} s={stride} p={pad}"
        );
    }

    #[test]
    fn bnn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD0 }, "bnn conv", |rng| random_conv_case(rng, ConvKind::Bnn));
    }

    #[test]
    fn tnn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD1 }, "tnn conv", |rng| random_conv_case(rng, ConvKind::Tnn));
    }

    #[test]
    fn tbn_conv_matches_direct() {
        check(Config { cases: 20, base_seed: 0xD2 }, "tbn conv", |rng| random_conv_case(rng, ConvKind::Tbn));
    }

    /// `forward_into` matches `forward`, and at steady state neither the
    /// scratch arena nor the output tensor reallocates.
    #[test]
    fn forward_into_is_zero_alloc_at_steady_state() {
        let mut rng = Rng::new(0xD4);
        for kind in [ConvKind::Bnn, ConvKind::Tnn, ConvKind::Tbn] {
            let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
            let (c_in, c_out) = (4, 6);
            let weights = match kind {
                ConvKind::Tnn => MatI8::random_ternary(p.depth(c_in), c_out, &mut rng),
                _ => MatI8::random_binary(p.depth(c_in), c_out, &mut rng),
            };
            let conv = LowBitConv::new(kind, p, c_in, &weights);
            let input = match kind {
                ConvKind::Bnn => Tensor3::random_binary(9, 9, c_in, &mut rng),
                _ => Tensor3::random_ternary(9, 9, c_in, &mut rng),
            };
            let mut scratch = ConvScratch::new();
            let mut out = Tensor3::zeros(0, 0, 0);
            conv.forward_into(&input, &mut scratch, &mut out).expect("conv");
            assert_eq!(out.data, conv.forward(&input).data, "{kind:?}");
            let (a_ptr, out_ptr) = (scratch.a.data.as_ptr(), out.data.as_ptr());
            conv.forward_into(&input, &mut scratch, &mut out).expect("conv");
            assert_eq!(scratch.a.data.as_ptr(), a_ptr, "{kind:?}: scratch reallocated");
            assert_eq!(out.data.as_ptr(), out_ptr, "{kind:?}: output reallocated");
            assert_eq!(out.data, conv.forward(&input).data, "{kind:?} second pass");
        }
    }

    /// Threaded convolution is bit-identical to single-threaded.
    #[test]
    fn threaded_conv_matches_single() {
        use crate::gemm::native::Threading;
        let mut rng = Rng::new(0xD5);
        for kind in [ConvKind::Bnn, ConvKind::Tnn, ConvKind::Tbn] {
            let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
            let (c_in, c_out) = (5, 7);
            let weights = match kind {
                ConvKind::Tnn => MatI8::random_ternary(p.depth(c_in), c_out, &mut rng),
                _ => MatI8::random_binary(p.depth(c_in), c_out, &mut rng),
            };
            let input = match kind {
                ConvKind::Bnn => Tensor3::random_binary(13, 11, c_in, &mut rng),
                _ => Tensor3::random_ternary(13, 11, c_in, &mut rng),
            };
            let single = LowBitConv::new(kind, p, c_in, &weights);
            let want = single.forward(&input);
            for threads in [2usize, 3, 8] {
                let conv = LowBitConv::new(kind, p, c_in, &weights).with_threading(Threading::Fixed(threads));
                assert_eq!(conv.forward(&input).data, want.data, "{kind:?} t={threads}");
            }
        }
    }

    /// Deep-im2col conv (3×3×128 → K = 1152) with explicit K panels and
    /// threading matches the direct oracle — the end-to-end form of the
    /// K-panel contract on the conv path.
    #[test]
    fn deep_k_conv_with_explicit_panels_matches_direct() {
        use crate::gemm::native::Threading;
        let mut rng = Rng::new(0xD6);
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        let (c_in, c_out) = (128, 6);
        for kind in [ConvKind::Bnn, ConvKind::Tnn, ConvKind::Tbn] {
            let weights = match kind {
                ConvKind::Tnn => MatI8::random_ternary(p.depth(c_in), c_out, &mut rng),
                _ => MatI8::random_binary(p.depth(c_in), c_out, &mut rng),
            };
            let input = match kind {
                ConvKind::Bnn => Tensor3::random_binary(5, 5, c_in, &mut rng),
                _ => Tensor3::random_ternary(5, 5, c_in, &mut rng),
            };
            let pad_value = if kind == ConvKind::Bnn { 1 } else { 0 };
            let want = direct_conv_i8(&input, &weights, &p, pad_value);
            for kp in [KPanel::Auto, KPanel::Depth(256), KPanel::Depth(64)] {
                let conv =
                    LowBitConv::new(kind, p, c_in, &weights).with_k_panel(kp).with_threading(Threading::Fixed(3));
                assert_eq!(conv.forward(&input).data, want.data, "{kind:?} kp={kp:?}");
            }
        }
    }

    #[test]
    fn out_dims_formulas() {
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        assert_eq!(p.out_dims(28, 28), (28, 28));
        let p2 = ConvParams { hk: 2, wk: 2, stride: 2, pad: 0 };
        assert_eq!(p2.out_dims(28, 28), (14, 14));
    }

    #[test]
    fn depth_is_hk_wk_cin() {
        let p = ConvParams { hk: 3, wk: 3, stride: 1, pad: 1 };
        assert_eq!(p.depth(64), 576);
    }
}
