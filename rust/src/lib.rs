//! # tbgemm — fast matrix multiplication for binary and ternary CNNs
//!
//! A full reproduction of Trusov, Limonova, Nikolaev, Arlazarov,
//! *"Fast matrix multiplication for binary and ternary CNNs on ARM CPU"*
//! (2022), as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper contributes bit-packed GEMM algorithms for three low-bit
//! matrix products on ARMv8 NEON:
//!
//! * **BNN** — binary × binary (values in `{-1, 1}`, 1 bit/value,
//!   XOR + popcount),
//! * **TNN** — ternary × ternary (values in `{-1, 0, 1}`, 2-bit `(+,-)`
//!   plane encoding, AND/OR + popcount),
//! * **TBN** — ternary × binary (mixed encoding),
//!
//! all accumulated in signed 16-bit lanes, wrapped in a blocked GEMM
//! driver (the paper's Algorithm 2), and compared against F32, 8-bit
//! (gemmlowp-style), 4-bit and daBNN binary baselines.
//!
//! This crate implements **everything from scratch**, twice:
//!
//! * [`simd`] + [`gemm::micro`] — a register-level emulation of the NEON
//!   instruction sequences the paper describes, with per-class instruction
//!   tracing. This regenerates the paper's Table II by *counting executed
//!   instructions*, not by transcribing the paper.
//! * [`gemm::native`] — portable fast paths (u64 bit-ops + `count_ones`)
//!   implementing the same algorithms for wall-clock benchmarking on the
//!   host CPU; these regenerate the *shape* of the paper's Table III.
//!
//! On top of the GEMM core sit [`quant`] (linear quantization, eq. (1)-(3),
//! overflow limits eq. (4)-(5)), [`conv`] (im2col + GEMM convolution),
//! [`nn`] (a QNN inference engine), [`costmodel`] (a Cortex-A73 throughput
//! model that predicts the ratio table), [`runtime`] (PJRT loader for the
//! JAX/Pallas AOT artifacts) and [`coordinator`] (a batching inference
//! server).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! The crate exposes **two plan/execute boundaries**, one per level:
//!
//! * [`gemm::plan`] — a [`gemm::GemmConfig`] + weights build a
//!   [`gemm::GemmPlan`] once, which then runs any number of
//!   multiplications into caller-owned output across all kinds and
//!   backends. The per-kind kernel free functions are crate-internal.
//! * [`nn::plan`] — the same split at the network level: a layer chain
//!   + [`nn::NetPlanConfig`] build a [`nn::NetPlan`] (all shapes and
//!   quantization domains verified statically, all weights packed),
//!   which runs whole-CNN inference with zero steady-state allocation
//!   and typed [`nn::NetError`]s; the coordinator's replica pool serves
//!   shared clones of one plan.

// Kernel-style codebase conventions: indexed loop nests mirror the
// paper's algorithms (and index several buffers at once), blocked-GEMM
// driver signatures carry the full blocking configuration, and scratch
// arenas expose `new()` constructors alongside `Default`.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::new_without_default)]
// The static half of the crate's unsafe-code policy (the dynamic half
// is the Miri/TSan/loom CI lanes): every unsafe operation inside an
// `unsafe fn` must sit in an explicit inner `unsafe {}` block, and
// every `unsafe {}` block must be justified by a `// SAFETY:` comment
// (also enforced textually by tools/structural_lint.py, rule `safety`).
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod bench;
pub mod conv;
pub mod coordinator;
pub mod costmodel;
pub mod gemm;
pub mod nn;
pub mod quant;
/// PJRT bridge for AOT-compiled XLA artifacts. Gated behind the
/// off-by-default `xla` cargo feature so the default build has zero
/// external native dependencies (see Cargo.toml).
#[cfg(feature = "xla")]
pub mod runtime;
pub mod simd;
pub mod tune;
pub mod util;
