//! PJRT runtime: load AOT-compiled XLA artifacts (HLO **text**, produced
//! by `python/compile/aot.py`) and execute them from Rust.
//!
//! This is the L3↔L2 bridge of the three-layer architecture: Python/JAX
//! (with the Pallas kernels) runs once at build time and lowers the model
//! to `artifacts/*.hlo.txt`; this module compiles those artifacts on the
//! PJRT CPU client and executes them on the request path — Python is
//! never loaded at runtime.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format
//! because jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU runtime holding the client connection.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel { exe, name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default() })
    }
}

/// A compiled executable plus metadata.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl LoadedModel {
    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 outputs. The artifact must have been lowered with
    /// `return_tuple=True` (aot.py does); single- and multi-output tuples
    /// are both handled.
    pub fn run_f32(&self, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).context("executing")?;
        let out = result[0][0].to_literal_sync().context("fetching result")?;
        // Outputs are a tuple (return_tuple=True at lowering time).
        let elems = out.to_tuple().context("untupling result")?;
        elems.into_iter().map(|lit| lit.to_vec::<f32>().context("reading f32 output")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Runtime tests need the PJRT shared library; they build a
    /// computation with XlaBuilder so they run without artifacts.
    #[test]
    fn cpu_client_builds_and_runs() {
        let rt = XlaRuntime::cpu().expect("client");
        assert!(!rt.platform().is_empty());
        let builder = xla::XlaBuilder::new("t");
        let c = builder.constant_r1(&[1f32, 2.0]).unwrap();
        let comp = (c + builder.constant_r0(1f32).unwrap()).unwrap().build().unwrap();
        let exe = rt.client.compile(&comp).unwrap();
        let out = exe.execute::<xla::Literal>(&[]).unwrap()[0][0].to_literal_sync().unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![2f32, 3.0]);
    }

    /// Full AOT round-trip — runs only when `make artifacts` has produced
    /// the model artifact.
    #[test]
    fn loads_aot_artifact_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tnn_gemm.hlo.txt");
        if !std::path::Path::new(path).exists() {
            eprintln!("skipping: {path} not built (run `make artifacts`)");
            return;
        }
        let rt = XlaRuntime::cpu().expect("client");
        let model = rt.load_hlo_text(path).expect("load");
        // file_stem of "tnn_gemm.hlo.txt" keeps the inner ".hlo".
        assert_eq!(model.name, "tnn_gemm.hlo");
    }
}
